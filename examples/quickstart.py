"""Quickstart: the paper's three-pronged study in ~50 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the LRU and S3-FIFO queueing models, derives the analytic throughput
bound, simulates the exact network, drives the real cache implementation
through the compiled replay engine, and prints where LRU's throughput
inverts (the paper's headline).

The script doubles as a smoke test of the replay engine's differential
contract: the compiled ``backend="jax"`` scan and the pure-Python
``backend="py"`` oracle must produce bit-identical (hits, ops) arrays for
the same trace and coin streams.
"""

import numpy as np

from repro.core import build
from repro.core.harness import measure_cache, run_cache_trace, zipf_trace
from repro.core.simulator import simulate_network

P = np.array([0.5, 0.7, 0.85, 0.95, 0.99])

# Differential contract first: scan engine == python oracle, bit for bit.
trace = zipf_trace(4_000, key_space=512, seed=0)
for policy in ("lru", "s3fifo"):
    h_jax, ops_jax = run_cache_trace(policy, 64, trace, backend="jax",
                                     key_space=512)
    h_py, ops_py = run_cache_trace(policy, 64, trace, backend="py")
    assert np.array_equal(h_jax, h_py), f"{policy}: hit sequences diverge"
    assert np.array_equal(ops_jax, ops_py), f"{policy}: op vectors diverge"
print("differential contract OK: backend='jax' == backend='py' "
      "(hits and op vectors bit-identical)")

for policy in ("lru", "s3fifo"):
    net = build(policy, disk_us=100.0)  # 72-core closed loop, 100us disk

    # Prong A: analytic upper bound (Thm 7.1) + critical hit ratio
    bound = net.throughput_upper(P)
    p_star = net.p_star()

    # Prong B: event-driven simulation of the exact network
    sim = simulate_network(net, P, n_requests=12_000, seeds=(0,))

    # Prong C: the real (array-based) cache under a Zipf workload, replayed
    # by the compiled scan engine (same numbers as the py oracle, ~10-80x
    # faster)
    meas = measure_cache(policy, capacity=512, key_space=4096,
                         n_requests=30_000, backend="jax")

    print(f"\n=== {policy.upper()}  (p* = {p_star:.3f})")
    print("p_hit      " + "  ".join(f"{p:6.2f}" for p in P))
    print("X theory   " + "  ".join(f"{x:6.3f}" for x in bound))
    print("X sim      " + "  ".join(f"{x:6.3f}" for x in sim.throughput))
    print(f"impl: measured hit ratio {meas.hit_ratio:.3f} at 512 pages, "
          f"X bound {meas.throughput_bound():.3f} Mreq/s")
    if p_star < 0.99:
        print(f"  -> raising hit ratio past {p_star:.2f} HURTS throughput "
              f"(hit-path delink becomes the bottleneck)")
    else:
        print("  -> throughput is monotone in hit ratio (no hit-path ops)")

# Tiered differential: the cross-tier MSHR event kernel and its heapq
# oracle must agree on an L1 -> sharded L2 -> origin hierarchy (throughput
# and the per-tier delayed-hit split -- statistical twins, not bit twins).
from repro.hierarchy import hierarchy_network  # noqa: E402
from repro.hierarchy.sim import (  # noqa: E402
    simulate_hierarchy, simulate_hierarchy_py)

hier = hierarchy_network("lru", "lru", n_clients=2, n_shards=2,
                         mpl=16, disk_us=50.0)
tj = simulate_hierarchy(hier, [0.5], n_requests=12_000, seeds=(0, 1),
                        coalesce_flows=2)
tp = simulate_hierarchy_py(hier, 0.5, n_requests=12_000, seed=0,
                           coalesce_flows=2)
x_jax, x_py = float(tj.throughput[0]), float(tp.throughput[0])
assert abs(x_jax - x_py) / max(x_jax, x_py) < 0.2, (x_jax, x_py)
assert abs(float(tj.delayed_l1_frac[0]) - float(tp.delayed_l1_frac[0])) < 0.1
print(f"\ntiered differential OK: jax X={x_jax:.3f} vs heapq oracle "
      f"X={x_py:.3f} (cross-tier MSHR twins agree)")
