"""Quickstart: the paper's three-pronged study in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the LRU and S3-FIFO queueing models, derives the analytic throughput
bound, simulates the exact network, drives the real cache implementation,
and prints where LRU's throughput inverts (the paper's headline).
"""

import numpy as np

from repro.core import build
from repro.core.harness import measure_cache
from repro.core.simulator import simulate_network

P = np.array([0.5, 0.7, 0.85, 0.95, 0.99])

for policy in ("lru", "s3fifo"):
    net = build(policy, disk_us=100.0)  # 72-core closed loop, 100us disk

    # Prong A: analytic upper bound (Thm 7.1) + critical hit ratio
    bound = net.throughput_upper(P)
    p_star = net.p_star()

    # Prong B: event-driven simulation of the exact network
    sim = simulate_network(net, P, n_requests=12_000, seeds=(0,))

    # Prong C: the real (array-based) cache under a Zipf workload
    meas = measure_cache(policy, capacity=512, key_space=4096,
                         n_requests=30_000)

    print(f"\n=== {policy.upper()}  (p* = {p_star:.3f})")
    print("p_hit      " + "  ".join(f"{p:6.2f}" for p in P))
    print("X theory   " + "  ".join(f"{x:6.3f}" for x in bound))
    print("X sim      " + "  ".join(f"{x:6.3f}" for x in sim.throughput))
    print(f"impl: measured hit ratio {meas.hit_ratio:.3f} at 512 pages, "
          f"X bound {meas.throughput_bound():.3f} Mreq/s")
    if p_star < 0.99:
        print(f"  -> raising hit ratio past {p_star:.2f} HURTS throughput "
              f"(hit-path delink becomes the bottleneck)")
    else:
        print("  -> throughput is monotone in hit ratio (no hit-path ops)")
