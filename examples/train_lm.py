"""End-to-end training driver example: train a ~small LM for a few hundred
steps on CPU and watch the loss drop; checkpoints + exact resume included.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

(The same driver targets the production mesh with --mesh single/multi on
real hardware; see repro/launch/train.py.)
"""

import argparse
import sys

from repro.launch import train as train_cli

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="internlm2-1.8b")
args = ap.parse_args()

losses = train_cli.main([
    "--arch", args.arch, "--reduced",
    "--steps", str(args.steps), "--batch", "8", "--seq", "64",
    "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "100",
    "--log-every", "20",
])
import numpy as np
print(f"\nloss {np.mean(losses[:5]):.3f} -> {np.mean(losses[-5:]):.3f} "
      f"over {len(losses)} steps")
sys.exit(0 if np.mean(losses[-5:]) < np.mean(losses[:5]) else 1)
