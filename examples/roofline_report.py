"""Render the §Roofline table (plus multi-pod deltas) from the dry-run JSONs.

    PYTHONPATH=src:. python examples/roofline_report.py
"""

from benchmarks.roofline import load, main

main()
multi = load("multi")
if multi:
    print("\n# multi-pod (512 chips) spot-check: collective deltas")
    single = load("single")
    for key in sorted(multi):
        if key in single and "roofline" in multi[key] and "roofline" in single[key]:
            s, m = single[key]["roofline"], multi[key]["roofline"]
            print(f"{key[0]:24s} {key[1]:12s} coll {s['collective_s']*1e3:8.2f}ms"
                  f" -> {m['collective_s']*1e3:8.2f}ms")
