"""Serve the same Zipf request stream under every Table-1 eviction policy
and compare (a) hit ratios from the real engine, (b) controller op
profiles, (c) the closed-loop throughput prediction at production MPL.

    PYTHONPATH=src python examples/serve_cache_ablation.py
"""

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core.harness import PAPER_SERVICES, parameterized_network
from repro.models import transformer
from repro.models.layers import param_values
from repro.serving import Engine, ServeConfig
from repro.training.data import zipf_request_stream

cfg = get_config("internlm2-1.8b", reduced=True)
params = param_values(transformer.init_params(cfg, jax.random.PRNGKey(0)))
reqs = zipf_request_stream(40, n_prefixes=12, prefix_len=32, vocab=cfg.vocab,
                           seed=0, new_tokens=4)

print(f"{'policy':10s} {'hit%':>6s} {'hit-ops':>8s} {'X@p95 bound':>12s} "
      f"{'p*':>6s}")
for policy in ("lru", "slru", "clock", "s3fifo", "sieve", "fifo"):
    eng = Engine(cfg, params, ServeConfig(
        max_seqs=4, max_seq_len=128, page_size=8, n_pages=256,
        prefix_capacity=64, policy=policy, max_new_tokens=3))
    outs = [eng.submit(t) for _, t in reqs]
    eng.run()
    s = eng.prefix.stats
    hit_ops, miss_ops = eng.prefix.mean_ops_per_chunk()
    net = parameterized_network(policy, hit_ops, miss_ops,
                                service=PAPER_SERVICES[policy])
    p_star = net.p_star()
    print(f"{policy:10s} {100*s.hit_ratio:6.1f} {hit_ops.sum():8.2f} "
          f"{net.throughput_upper(0.95):12.3f} {p_star:6.3f}")

print("\nLRU-family controllers saturate past p*; FIFO-family don't —")
print("swap `policy=` in ServeConfig to fix it (the paper's takeaway).")
