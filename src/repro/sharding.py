"""Logical-axis sharding context.

Model code annotates params/activations with *logical* axis names
("batch", "model", None).  The launcher installs a :class:`MeshContext`
that resolves logical names to concrete mesh axes:

    single-pod:  batch -> ("data",)          model -> "model"
    multi-pod:   batch -> ("pod", "data")    model -> "model"

With no context installed (CPU tests), every annotation is a no-op, so the
same model code runs unsharded.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_mesh_ctx", default=None)


@dataclasses.dataclass(frozen=True)
class MeshContext:
    mesh: Mesh
    rules: dict  # logical axis name -> mesh axis name or tuple of names
    # GSPMD supports uneven (padded) partitions; archs whose head counts do
    # not divide the model axis rely on this at baseline (see DESIGN.md §5).
    allow_uneven: bool = True

    def axis_size(self, logical: str) -> int:
        ax = self.rules.get(logical)
        if ax is None:
            return 1
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= self.mesh.shape[a]
        return size


def default_rules(multi_pod: bool) -> dict:
    return {
        "batch": ("pod", "data") if multi_pod else ("data",),
        "model": "model",
        "expert": "model",
    }


def get_ctx() -> Optional[MeshContext]:
    return _CTX.get()


@contextlib.contextmanager
def use_mesh(ctx: MeshContext):
    token = _CTX.set(ctx)
    try:
        with ctx.mesh:
            yield ctx
    finally:
        _CTX.reset(token)


def resolve_spec(logical_spec) -> PartitionSpec:
    """Logical spec tuple -> PartitionSpec under the installed context."""
    ctx = get_ctx()
    if ctx is None:
        return PartitionSpec()
    out = []
    for item in logical_spec:
        if item is None:
            out.append(None)
        elif isinstance(item, tuple):
            resolved = []
            for sub in item:
                r = ctx.rules.get(sub)
                if r is not None:
                    resolved.extend(r if isinstance(r, tuple) else (r,))
            out.append(tuple(resolved) if resolved else None)
        else:
            r = ctx.rules.get(item)
            out.append(r if r is not None else None)
    return PartitionSpec(*out)


def sharding_for(logical_spec) -> Optional[NamedSharding]:
    ctx = get_ctx()
    if ctx is None:
        return None
    return NamedSharding(ctx.mesh, resolve_spec(logical_spec))


def constrain(x, *logical_spec):
    """with_sharding_constraint under a context; identity otherwise."""
    ctx = get_ctx()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, resolve_spec(logical_spec))
    )


def shard_dim_ok(size: int, logical: str = "model") -> bool:
    """True when `size` divides the logical axis (even partitioning)."""
    ctx = get_ctx()
    if ctx is None:
        return True
    n = ctx.axis_size(logical)
    return size % n == 0 or ctx.allow_uneven
