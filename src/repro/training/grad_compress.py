"""Gradient-compression for the data-parallel all-reduce.

Two modes, both with error feedback (the quantization residual is carried
to the next step so compression error doesn't accumulate as bias):

  * "bf16": cast grads to bfloat16 before the psum — halves all-reduce
    bytes vs f32 with negligible quality cost; the production default.
  * "int8": per-tensor-scale int8; 4x fewer wire bytes.  The psum itself
    runs in f32 after dequant *per shard-group hop* under shard_map, so the
    HLO collective operand is s8 only for the reduce-scatter stage.

Used via shard_map over the "data" axis inside the train step (see
repro/launch/train.py --grad-compress); on CPU tests it runs on a 1-device
mesh where psum is the identity.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grad(g, error, mode: str):
    """Returns (wire_value, new_error).  wire_value is what gets psummed."""
    g32 = g.astype(jnp.float32) + (error if error is not None else 0.0)
    if mode == "bf16":
        wire = g32.astype(jnp.bfloat16)
        return wire, g32 - wire.astype(jnp.float32)
    if mode == "int8":
        q, scale = _quantize_int8(g32)
        deq = q.astype(jnp.float32) * scale
        return (q, scale), g32 - deq
    raise ValueError(mode)


def decompress_grad(wire, mode: str):
    if mode == "bf16":
        return wire.astype(jnp.float32)
    q, scale = wire
    return q.astype(jnp.float32) * scale


def psum_compressed(grads: Any, errors: Any, axis_name: str, mode: str = "bf16"):
    """All-reduce grads over `axis_name` with error feedback.

    Call INSIDE shard_map.  Returns (mean_grads_f32, new_errors).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        wire, new_e = compress_grad(g, e, mode)
        if mode == "bf16":
            summed = jax.lax.psum(wire, axis_name)
            return summed.astype(jnp.float32) / n, new_e
        q, scale = wire
        # int8 payload all-gathered then reduced locally in f32 (saturation-
        # safe); wire bytes: 1B/elem + one scalar per shard.
        qs = jax.lax.all_gather(q, axis_name)
        ss = jax.lax.all_gather(scale, axis_name)
        summed = jnp.tensordot(
            ss, qs.astype(jnp.float32), axes=((0,), (0,))
        )
        return summed / n, new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = (jax.tree_util.tree_flatten(errors)[0] if errors is not None
              else [None] * len(flat_g))
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return mean, new_err


def init_errors(grads: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads
    )
