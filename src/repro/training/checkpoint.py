"""Sharding-aware checkpointing with async save and elastic restore.

Format: one .npy per leaf + a msgpack manifest (tree structure, shapes,
dtypes, step).  Restore can re-target a different mesh ("elastic"): arrays
are loaded host-side and re-placed with jax.device_put under the new
sharding, so a 512-chip checkpoint restores onto 256 chips (or CPU) —
the re-mesh path exercised by tests/test_training.py.

Fault-tolerance contract:
  * saves are atomic (write to .tmp dir, fsync, rename);
  * an interrupted save never corrupts the previous checkpoint;
  * `latest_step` scans for complete checkpoints only;
  * async mode runs the serialization off-thread (training continues) —
    callers must join() before the next save of the same directory.
"""

from __future__ import annotations

import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

MANIFEST = "manifest.msgpack"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, tree: Any, step: int) -> None:
    """Atomic synchronous save of `tree` at `path`/step_<step>."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = _flatten(tree)
    meta = {"step": step, "n_leaves": len(leaves), "treedef": str(treedef)}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
    with open(os.path.join(tmp, MANIFEST), "wb") as f:
        f.write(msgpack.packb(meta))
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)


class AsyncCheckpointer:
    """Off-thread saver: snapshot on the caller thread (device_get), then
    serialize in the background so the train loop keeps stepping."""

    def __init__(self, path: str):
        self.path = path
        self._thread: Optional[threading.Thread] = None

    def save(self, tree: Any, step: int) -> None:
        self.join()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree
        )
        self._thread = threading.Thread(
            target=save, args=(self.path, host_tree, step), daemon=True
        )
        self._thread.start()

    def join(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = []
    for name in os.listdir(path):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(path, name, MANIFEST)):
                steps.append(int(name[5:]))
    return max(steps) if steps else None


def restore(path: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of `like`.

    shardings: optional pytree of jax.sharding.Sharding matching `like` —
    the elastic path: device_put under the (possibly different) new mesh.
    """
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, MANIFEST), "rb") as f:
        meta = msgpack.unpackb(f.read())

    leaves, treedef = _flatten(like)
    if meta["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {meta['n_leaves']} leaves, target {len(leaves)}"
        )
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
        else [None] * len(leaves)
    )
    out = []
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
        want_dtype = ref.dtype if hasattr(ref, "dtype") else arr.dtype
        arr = arr.astype(want_dtype)
        out.append(jax.device_put(arr, shd) if shd is not None else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
