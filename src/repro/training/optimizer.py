"""Optimizers: AdamW (dtype-configurable moments) and Adafactor, plus
ZeRO-1 spec transforms for optimizer-state sharding.

No optax dependency — the state layouts must be sharding-annotated, so we
own them.  States are pytrees of plain arrays mirroring the param tree,
making them checkpoint- and pjit-friendly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # moment dtype: float32 for quality, bfloat16 to halve optimizer HBM
    # (the arctic-480b config needs bf16 moments to fit 256 chips; see
    # EXPERIMENTS.md §Dry-run)
    moment_dtype: str = "float32"
    warmup_steps: int = 100
    # adafactor
    factored_min_dim: int = 128


class AdamWState(NamedTuple):
    m: Any
    v: Any


class AdafactorState(NamedTuple):
    vr: Any  # row statistics (or full v for small/1D params)
    vc: Any  # col statistics (or None sentinel zeros)


def lr_at(cfg: OptimizerConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def _clip_by_global_norm(grads, max_norm):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), gn


def init_state(cfg: OptimizerConfig, params):
    dt = jnp.dtype(cfg.moment_dtype)
    if cfg.name == "adamw":
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return AdamWState(
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros, params),
        )
    if cfg.name == "adafactor":
        def vr(p):
            if p.ndim >= 2 and min(p.shape[-2:]) >= cfg.factored_min_dim:
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc(p):
            if p.ndim >= 2 and min(p.shape[-2:]) >= cfg.factored_min_dim:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        return AdafactorState(
            vr=jax.tree_util.tree_map(vr, params),
            vc=jax.tree_util.tree_map(vc, params),
        )
    raise ValueError(cfg.name)


def apply_updates(cfg: OptimizerConfig, step, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = _clip_by_global_norm(grads, cfg.grad_clip)
    lr = lr_at(cfg, step)

    if cfg.name == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        bc1 = 1.0 - b1 ** (step.astype(jnp.float32) + 1.0)
        bc2 = 1.0 - b2 ** (step.astype(jnp.float32) + 1.0)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (
                (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(m.dtype),
                v32.astype(v.dtype),
            )

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat = [
            upd(p, g, m, v)
            for p, g, m, v in zip(
                flat_p,
                jax.tree_util.tree_leaves(grads),
                jax.tree_util.tree_leaves(state.m),
                jax.tree_util.tree_leaves(state.v),
            )
        ]
        unflat = lambda i: jax.tree_util.tree_unflatten(
            treedef, [t[i] for t in flat]
        )
        return unflat(0), AdamWState(unflat(1), unflat(2)), {
            "grad_norm": gnorm, "lr": lr,
        }

    if cfg.name == "adafactor":
        d = 1.0 - cfg.b2  # decay toward RMS statistics

        def upd(p, g, vr, vc):
            g32 = jnp.square(g.astype(jnp.float32)) + 1e-30
            factored = p.ndim >= 2 and min(p.shape[-2:]) >= cfg.factored_min_dim
            if factored:
                vr2 = (1 - d) * vr + d * g32.mean(axis=-1)
                vc2 = (1 - d) * vc + d * g32.mean(axis=-2)
                denom = (
                    vr2[..., :, None]
                    * vc2[..., None, :]
                    / jnp.maximum(vr2.mean(axis=-1)[..., None, None], 1e-30)
                )
            else:
                vr2 = (1 - d) * vr + d * g32
                vc2 = vc
                denom = vr2
            delta = g.astype(jnp.float32) / (jnp.sqrt(denom) + cfg.eps)
            if p.ndim >= 2:
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype), vr2, vc2)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat = [
            upd(p, g, vr, vc)
            for p, g, vr, vc in zip(
                flat_p,
                jax.tree_util.tree_leaves(grads),
                jax.tree_util.tree_leaves(state.vr),
                jax.tree_util.tree_leaves(state.vc),
            )
        ]
        unflat = lambda i: jax.tree_util.tree_unflatten(
            treedef, [t[i] for t in flat]
        )
        return unflat(0), AdafactorState(unflat(1), unflat(2)), {
            "grad_norm": gnorm, "lr": lr,
        }

    raise ValueError(cfg.name)


# ---------------------------------------------------------------------------
# ZeRO-1: shard optimizer moments over the data axis too.
# ---------------------------------------------------------------------------


def zero1_moment_spec(param_spec: tuple, shape: tuple, data_axis_size: int = 16) -> tuple:
    """Add "batch" sharding to the first evenly-divisible unsharded dim.

    Moments are only read/written inside the optimizer, so GSPMD inserts an
    all-gather around the update instead of keeping N data-parallel copies —
    the ZeRO-1 trade (collective bytes for HBM).  Dims already sharded over
    "model" keep their spec; stacked-layer leading dims (g not divisible by
    the data axis) are skipped in favour of an inner dim.
    """
    if len(shape) < 2 or len(shape) != len(param_spec):
        return param_spec  # vectors/scalars: not worth the gather
    flat = [a for s in param_spec if s is not None
            for a in (s if isinstance(s, tuple) else (s,))]
    if "batch" in flat:
        return param_spec  # already data-sharded (e.g. 2-D expert sharding)
    out = list(param_spec)
    for i, (s, d) in enumerate(zip(param_spec, shape)):
        if s is None and d >= data_axis_size and d % data_axis_size == 0:
            out[i] = "batch"
            return tuple(out)
    return param_spec
