"""repro.training — optimizers, train step, data, checkpointing, compression."""

from repro.training.optimizer import OptimizerConfig, init_state, apply_updates
from repro.training.train_state import TrainState, init_train_state, make_train_step
from repro.training.data import DataConfig, SyntheticLM
