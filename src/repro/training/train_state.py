"""Train state + train-step factory (loss, grads, optimizer, metrics)."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.config import ModelConfig
from repro.training import optimizer as opt_lib
from repro.training.optimizer import OptimizerConfig


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt: Any


def init_train_state(cfg: ModelConfig, opt_cfg: OptimizerConfig, key,
                     abstract: bool = False) -> TrainState:
    init_fn = encdec.init_params if cfg.encdec else transformer.init_params
    from repro.models.layers import param_values

    params = param_values(init_fn(cfg, key, abstract=abstract))
    if abstract:
        opt_state = jax.eval_shape(lambda p: opt_lib.init_state(opt_cfg, p), params)
    else:
        opt_state = opt_lib.init_state(opt_cfg, params)
    step = jax.ShapeDtypeStruct((), jnp.int32) if abstract else jnp.int32(0)
    return TrainState(step=step, params=params, opt=opt_state)


def lm_loss(logits, targets, mask=None):
    """Token-mean cross entropy in f32.  logits: (B, T, V); targets: (B, T)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    if mask is None:
        return -ll.mean()
    mask = mask.astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    remat: str = "dots", aux_weight: float = 0.01,
                    use_pallas: bool = False):
    """Returns train_step(state, batch) -> (state, metrics).

    batch: {"tokens": (B, T) int32[, "frames": (B, S_enc, D)]}.  Next-token
    prediction; MoE aux loss is added with `aux_weight`.
    """

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        if cfg.encdec:
            logits = encdec.forward(params, batch["frames"], tokens[:, :-1], cfg)
            loss = lm_loss(logits, tokens[:, 1:])
            return loss, {"xent": loss}
        logits, _, aux = transformer.forward(
            params, tokens[:, :-1], cfg, remat=remat, use_pallas=use_pallas
        )
        xent = lm_loss(logits, tokens[:, 1:])
        loss = xent + aux_weight * aux["moe_aux_loss"]
        return loss, {"xent": xent, **aux}

    def train_step(state: TrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch
        )
        params, opt_state, opt_metrics = opt_lib.apply_updates(
            opt_cfg, state.step, state.params, grads, state.opt
        )
        metrics = {"loss": loss, **metrics, **opt_metrics}
        return TrainState(state.step + 1, params, opt_state), metrics

    return train_step
