"""Deterministic, resumable synthetic data pipeline.

Batches are a pure function of (seed, step): restart-from-checkpoint
reproduces the exact token stream with no iterator state beyond the step
counter (which lives in TrainState).  The distribution is a Zipf-weighted
token mix with short repeated motifs so tiny models have learnable
structure (loss decreases measurably within ~50 steps on CPU).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_theta: float = 1.2
    motif_len: int = 8
    n_motifs: int = 64


def _motifs(cfg: DataConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed)
    return rng.integers(0, cfg.vocab, size=(cfg.n_motifs, cfg.motif_len))


class SyntheticLM:
    """Stateless batch source: batch_at(step) is deterministic."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._motifs = jnp.asarray(_motifs(cfg), jnp.int32)
        ranks = np.arange(1, cfg.n_motifs + 1, dtype=np.float64)
        p = ranks**-cfg.zipf_theta
        self._probs = jnp.asarray(p / p.sum(), jnp.float32)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        n_slots = -(-cfg.seq_len // cfg.motif_len)
        picks = jax.random.choice(
            key, cfg.n_motifs, (cfg.global_batch, n_slots), p=self._probs
        )
        toks = self._motifs[picks].reshape(cfg.global_batch, -1)[:, : cfg.seq_len]
        # sprinkle noise tokens so the task is not pure memorization
        nkey = jax.random.fold_in(key, 1)
        noise = jax.random.randint(nkey, toks.shape, 0, cfg.vocab)
        mask = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.05, toks.shape)
        return {"tokens": jnp.where(mask, noise, toks).astype(jnp.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def zipf_request_stream(n_requests: int, n_prefixes: int, prefix_len: int,
                        vocab: int, theta: float = 0.99, seed: int = 0,
                        new_tokens: int = 8):
    """Serving workload: requests share Zipf-popular prefixes (the serving
    analogue of the paper's Zipf block workload).  Returns a list of
    (prefix_id, tokens) with tokens = shared prefix + unique suffix."""
    rng = np.random.default_rng(seed)
    prefixes = rng.integers(0, vocab, size=(n_prefixes, prefix_len))
    ranks = np.arange(1, n_prefixes + 1, dtype=np.float64)
    p = ranks**-theta
    p /= p.sum()
    perm = rng.permutation(n_prefixes)
    out = []
    for _ in range(n_requests):
        pid = perm[rng.choice(n_prefixes, p=p)]
        suffix = rng.integers(0, vocab, size=(new_tokens,))
        out.append((int(pid), np.concatenate([prefixes[pid], suffix])))
    return out
