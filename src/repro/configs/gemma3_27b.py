"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        d_ff=21504,
        vocab=262144,
        act="geglu",
        attn_pattern=("local", "local", "local", "local", "local", "global"),
        local_window=1024,
        rope_base=1_000_000.0,
        tie_embeddings=True,
    )
