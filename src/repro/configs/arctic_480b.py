"""arctic-480b [moe] — 128 experts top-2 + dense residual FFN.
[hf:Snowflake/snowflake-arctic-base; hf]"""

from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,  # dense residual branch
        vocab=32000,
        act="swiglu",
        moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                      dense_residual=True),
    )
