"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,           # shared block MLP
        vocab=32000,
        act="swiglu",
        block="mamba2",
        shared_attn_every=6,
        ssm_state=64,
    )
