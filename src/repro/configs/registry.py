"""Architecture registry: --arch <id> -> ModelConfig.

All ten assigned architectures (exact dimensions from the assignment table)
plus the paper's own "policy lab" needs no model at all — the cache layer is
model-agnostic.  Sources are cited per file.
"""

from __future__ import annotations

import importlib

ARCHS = [
    "arctic-480b",
    "llama4-scout-17b-a16e",
    "chameleon-34b",
    "qwen3-32b",
    "gemma3-27b",
    "internlm2-1.8b",
    "nemotron-4-15b",
    "rwkv6-7b",
    "zamba2-1.2b",
    "whisper-tiny",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(arch: str, reduced: bool = False, **overrides):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(_MODULES[arch])
    cfg = mod.config()
    if reduced:
        cfg = cfg.reduced()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg
