"""whisper-tiny [audio] — enc-dec backbone; conv/log-mel frontend is a STUB
(input_specs provides precomputed frame embeddings).  Tiny model: runs
data-parallel only (no TP).  [arXiv:2212.04356; unverified]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,          # decoder layers
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab=51865,
        act="gelu",
        encdec=True,
        enc_layers=4,
        enc_positions=1500,
        tie_embeddings=True,
        tensor_parallel=False,
        max_seq=32768,
    )
