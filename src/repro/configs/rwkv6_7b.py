"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        n_layers=32,
        d_model=4096,
        n_heads=64,          # = d_model / rwkv_head_dim, bookkeeping only
        n_kv_heads=64,
        d_ff=14336,
        vocab=65536,
        act="sqrelu",        # rwkv channel-mix uses squared ReLU
        block="rwkv6",
        rwkv_head_dim=64,
    )
