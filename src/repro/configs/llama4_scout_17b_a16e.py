"""llama4-scout-17b-a16e [moe] — 16 experts top-1, early fusion (text
backbone; the fused-modality tokens live in the 202k vocab).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab=202048,
        act="swiglu",
        moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192),
    )
