"""chameleon-34b [vlm] — early-fusion: VQ image tokens share the 65536
vocab, so the backbone is a plain dense decoder (frontend = tokenizer stub).
[arXiv:2405.09818; unverified]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab=65536,
        act="swiglu",
        qk_norm=True,  # chameleon uses qk-norm for stability
    )
