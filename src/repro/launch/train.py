"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Production use targets the (16,16) or (2,16,16) mesh (--mesh single|multi);
on this CPU container use --reduced (tiny same-family config, 1-device
mesh).  Fault tolerance: async checkpoints every --ckpt-every steps, exact
resume (data is a pure function of the step counter), atomic saves.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import sharding as shardlib
from repro.configs.registry import ARCHS, get_config
from repro.launch.mesh import make_context, single_device_context
from repro.training import checkpoint as ckpt_lib
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import OptimizerConfig
from repro.training.train_state import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", choices=["adamw", "adafactor"], default="adamw")
    ap.add_argument("--remat", choices=["none", "dots", "full"], default="none")
    ap.add_argument("--mesh", choices=["cpu", "single", "multi"], default="cpu")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    opt = OptimizerConfig(name=args.optimizer, lr=args.lr, warmup_steps=10)
    ctx = (single_device_context() if args.mesh == "cpu"
           else make_context(multi_pod=args.mesh == "multi"))

    with shardlib.use_mesh(ctx):
        state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch))
        step_fn = jax.jit(make_train_step(cfg, opt, remat=args.remat),
                          donate_argnums=(0,))

        start = 0
        saver = None
        if args.ckpt_dir:
            saver = ckpt_lib.AsyncCheckpointer(args.ckpt_dir)
            if args.resume and (last := ckpt_lib.latest_step(args.ckpt_dir)) is not None:
                state = ckpt_lib.restore(args.ckpt_dir, like=state, step=last)
                start = last
                print(f"resumed from step {last}")

        losses = []
        t0 = time.time()
        for step in range(start, args.steps):
            state, metrics = step_fn(state, data.batch_at(step))
            losses.append(float(metrics["loss"]))
            if (step + 1) % args.log_every == 0:
                dt = time.time() - t0
                tok_s = args.log_every * args.batch * args.seq / max(dt, 1e-9)
                print(f"step {step+1:5d}  loss {losses[-1]:.4f}  "
                      f"grad_norm {float(metrics['grad_norm']):.3f}  "
                      f"{tok_s:,.0f} tok/s")
                t0 = time.time()
            if saver and (step + 1) % args.ckpt_every == 0:
                saver.save(state, step + 1)
        if saver:
            saver.save(state, args.steps)
            saver.join()
        print(f"final loss {np.mean(losses[-5:]):.4f} "
              f"(first {np.mean(losses[:5]):.4f})")
        return losses


if __name__ == "__main__":
    main()
