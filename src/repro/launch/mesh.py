"""Production meshes + logical-axis rules.

Single pod  : (16, 16)     axes ("data", "model")          = 256 chips
Multi-pod   : (2, 16, 16)  axes ("pod", "data", "model")   = 512 chips

`make_production_mesh` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — only the dry-run
process sets XLA_FLAGS for 512 host devices.
"""

from __future__ import annotations

import jax

from repro import sharding as shardlib


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_context(mesh=None, *, multi_pod: bool = False) -> shardlib.MeshContext:
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    return shardlib.MeshContext(
        mesh=mesh, rules=shardlib.default_rules(multi_pod="pod" in mesh.axis_names)
    )


def single_device_context() -> shardlib.MeshContext:
    """1-device mesh for CPU smoke runs of the launch drivers."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return shardlib.MeshContext(mesh=mesh, rules=shardlib.default_rules(False))
