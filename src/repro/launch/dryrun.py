import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape decode_32k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Per cell this records (benchmarks/dryrun_results/<arch>__<shape>__<mesh>.json):
    * memory_analysis()  — per-device argument/output/temp/code bytes,
    * cost_analysis()    — per-device HLO flops + bytes accessed,
    * collective bytes   — parsed from the partitioned HLO text,
    * the three roofline terms + MODEL_FLOPS ratio (§Roofline).

The XLA_FLAGS line above MUST precede any jax import: jax locks the device
count on first backend init.  Never set it globally — tests and benchmarks
must see one device.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro import sharding as shardlib  # noqa: E402
from repro.configs.registry import ARCHS, get_config  # noqa: E402
from repro.launch.mesh import make_context  # noqa: E402
from repro.launch.specs import SHAPES, SKIP, build_cell  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "dryrun_results")

# v5e hardware constants (targets; this host is CPU)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|s64|u64|pred|s16|u16)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device wire-byte estimate per collective kind.

    Shapes in the partitioned module are per-device.  all-reduce is charged
    2x its buffer (ring send+recv); *-done lines are skipped so async pairs
    aren't double counted.
    """
    out = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m or "-done(" in line:
            continue
        result_type, kind = m.group(1), m.group(2)
        b = _shape_bytes(result_type)
        if kind == "all-reduce":
            b *= 2
        out[kind] = out.get(kind, 0) + b
        out.setdefault("count_" + kind, 0)
        out["count_" + kind] += 1
    out["total_bytes"] = sum(v for k, v in out.items() if not k.startswith("count"))
    return out


def model_flops_per_device(arch: str, shape_name: str, n_devices: int) -> float:
    """Analytic MODEL_FLOPS (6ND / 2ND + attention terms).

    This is the roofline's compute term: XLA-CPU's cost_analysis undercounts
    FLOPs on this backend (dots lower to oneDNN custom-calls; while bodies
    are counted once, not trip-count times), so the *exact* analytic count
    is both stricter and more reliable — it is the MFU numerator.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    L, H, dh = cfg.n_layers, cfg.n_heads, cfg.d_head
    local_frac = (cfg.attn_pattern.count("local") / len(cfg.attn_pattern)
                  if cfg.block == "attn" else 0.0)

    def attn_flops(q_tokens, kv_len):
        # per q token: 2*H*dh*kv (QK^T) + 2*H*dh*kv (PV); local layers see
        # min(window, kv_len) keys
        eff = local_frac * min(cfg.local_window, kv_len) + (1 - local_frac) * kv_len
        return 4.0 * H * dh * L * eff * q_tokens

    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        flops = 6.0 * n_active * tokens + 3 * attn_flops(tokens, shape.seq / 2)
    elif shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        flops = 2.0 * n_active * tokens + attn_flops(tokens, shape.seq / 2)
    else:  # decode: one token per sequence, attention reads the full KV
        flops = 2.0 * n_active * shape.batch
        if cfg.block == "attn":
            flops += attn_flops(shape.batch, shape.seq)
    return flops / n_devices


def projected_hbm_bytes_per_device(arch: str, shape_name: str,
                                   n_devices: int) -> float:
    """TPU-projected HBM traffic (analytic).

    The CPU backend's measured 'bytes accessed' is inflated by bf16->f32
    normalization converts that a TPU never executes; this projection is
    the memory-term numerator (raw HLO bytes are recorded alongside).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    pbytes = 2  # bf16 params
    n_params = cfg.param_count()
    D, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    kv_bytes = (2 * L * cfg.n_kv_heads * cfg.d_head * 2
                if cfg.block == "attn" else 64 * D)  # per token
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        # fwd read + bwd read + grad write + update write  (+ moments r/w)
        param_traffic = n_params * pbytes * 4 + n_params * 4 * 2
        act_traffic = tokens * D * L * 2 * 4  # carry save + recompute r/w
        logit_traffic = tokens * V * 4 * 2
        return (param_traffic + act_traffic + logit_traffic) / n_devices
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        act = tokens * D * L * 2 * 4
        return (n_params * pbytes + tokens * kv_bytes + act) / n_devices
    # decode
    if cfg.moe is not None:
        e = cfg.moe
        expert_frac = min(1.0, shape.batch * e.top_k / e.n_experts)
        mult = 3 if cfg.act in ("swiglu", "geglu") else 2
        expert_bytes = L * e.n_experts * mult * D * e.d_ff_expert * pbytes
        params_read = (n_params * pbytes - expert_bytes
                       + expert_bytes * expert_frac)
    else:
        params_read = n_params * pbytes
    cache_read = shape.batch * shape.seq * kv_bytes
    if cfg.block != "attn":
        cache_read = shape.batch * 64 * D  # O(1) recurrent state
    return (params_read + cache_read) / n_devices


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "") -> dict:
    t0 = time.time()
    ctx = make_context(multi_pod=multi_pod)
    n_dev = ctx.mesh.size
    cfg = None
    if variant:
        from repro.launch.specs import variant_config

        cfg = variant_config(arch, variant)
        if cfg is None:
            return {"arch": arch, "shape": shape_name,
                    "skipped": f"no {variant} variant for {arch}"}
    with shardlib.use_mesh(ctx):
        plan = build_cell(arch, shape_name, cfg=cfg)
        if plan is None:
            return {"arch": arch, "shape": shape_name, "skipped":
                    SKIP[(arch, shape_name)]}
        jitted = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                         out_shardings=plan.out_shardings,
                         donate_argnums=plan.donate)
        lowered = jitted.lower(*plan.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_rec = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "peak_memory_in_bytes"):
        mem_rec[f] = int(getattr(mem, f, 0) or 0)
    mem_rec["resident_bytes_per_device"] = (
        mem_rec["argument_size_in_bytes"] + mem_rec["peak_memory_in_bytes"]
        - mem_rec["alias_size_in_bytes"]
    )

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))

    coll = parse_collectives(compiled.as_text())

    mf = model_flops_per_device(arch, shape_name, n_dev)
    proj_bytes = projected_hbm_bytes_per_device(arch, shape_name, n_dev)
    compute_s = mf / PEAK_FLOPS
    memory_s = proj_bytes / HBM_BW
    memory_s_hlo = bytes_accessed / HBM_BW  # CPU-inflated upper bound
    collective_s = coll["total_bytes"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    step_s = max(terms.values())
    # hardware envelope = max(compute, memory); collectives that fit under
    # it are overlappable, so fraction = envelope / step estimate.
    envelope = max(compute_s, memory_s)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev,
        "note": plan.note,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem_rec,
        "hlo_flops_per_device": flops,  # unreliable on CPU backend; see doc
        "hbm_bytes_per_device_hlo": bytes_accessed,
        "hbm_bytes_per_device_projected": proj_bytes,
        "collectives": coll,
        "roofline": {
            **terms,
            "memory_s_hlo": memory_s_hlo,
            "dominant": max(terms, key=terms.get),
            "model_flops_per_device": mf,
            "roofline_fraction": envelope / step_s if step_s else 0.0,
            "mfu_bound": compute_s / step_s if step_s else 0.0,
            "hlo_vs_model_flops": (flops / mf) if mf else 0.0,
        },
    }
    return rec


def cell_path(arch, shape_name, multi_pod, variant=""):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    mesh = "multi" if multi_pod else "single"
    suffix = f"__{variant}" if variant else ""
    return os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="")
    args = ap.parse_args()

    archs = ARCHS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for multi_pod in meshes:
        for arch in archs:
            for shape_name in shapes:
                path = cell_path(arch, shape_name, multi_pod, args.variant)
                if os.path.exists(path) and not args.force:
                    print(f"[skip cached] {path}")
                    continue
                label = (f"{arch} x {shape_name} x "
                         f"{'multi' if multi_pod else 'single'}"
                         + (f" [{args.variant}]" if args.variant else ""))
                print(f"=== {label}", flush=True)
                try:
                    rec = run_cell(arch, shape_name, multi_pod, args.variant)
                except Exception as e:  # record failures; the sweep continues
                    traceback.print_exc()
                    failures.append(label)
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if multi_pod else "single",
                           "error": f"{type(e).__name__}: {e}"}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if "roofline" in rec:
                    r = rec["roofline"]
                    print(
                        f"    compile {rec['compile_s']}s | "
                        f"peak/dev {rec['memory'].get('peak_memory_in_bytes', 0)/2**30:.2f} GiB | "
                        f"compute {r['compute_s']*1e3:.2f}ms mem {r['memory_s']*1e3:.2f}ms "
                        f"coll {r['collective_s']*1e3:.2f}ms -> {r['dominant']} | "
                        f"roofline {r['roofline_fraction']:.2f}",
                        flush=True,
                    )
    if failures:
        print("FAILURES:\n  " + "\n  ".join(failures))
        raise SystemExit(1)
    print("dry-run sweep complete")


if __name__ == "__main__":
    main()
