"""Elastic re-meshing: restore a checkpoint onto a different mesh.

A 512-chip (2,16,16) checkpoint restores onto a 256-chip (16,16) mesh (or
onto CPU for debugging) by re-resolving every logical partition spec under
the new MeshContext and device_put-ing host arrays — node failures that
shrink the fleet do not strand training state.

    new_state = reshard(ckpt_dir, like=state_abs, ctx=make_context())
"""

from __future__ import annotations

from typing import Any, Optional

import jax

from repro import sharding as shardlib
from repro.training import checkpoint as ckpt_lib


def reshard(path: str, like: Any, ctx: shardlib.MeshContext,
            logical_specs: Any = None, step: Optional[int] = None) -> Any:
    """Restore `path` under mesh context `ctx`.

    logical_specs: optional pytree of logical spec tuples matching `like`
    (e.g. from repro.launch.specs.train_state_spec_tree).  Without it, all
    leaves restore replicated on the new mesh — correct, just larger.
    """
    with shardlib.use_mesh(ctx):
        if logical_specs is None:
            shardings = jax.tree_util.tree_map(
                lambda _: shardlib.sharding_for(()), like
            )
        else:
            from repro.launch.specs import _to_shardings

            shardings = _to_shardings(logical_specs, like)
        return ckpt_lib.restore(path, like=like, step=step, shardings=shardings)
