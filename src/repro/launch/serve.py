"""Serving driver: run the continuous-batching engine on a Zipf request
stream under any of the Table-1 eviction policies, then report both the
measured controller statistics and the paper-model throughput prediction.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --policy lru --requests 32
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import ARCHS, get_config
from repro.core.harness import PAPER_SERVICES, ServiceTimes, empirical_network
from repro.models import transformer
from repro.models.layers import param_values
from repro.serving import Engine, ServeConfig
from repro.training.data import zipf_request_stream


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="internlm2-1.8b")
    ap.add_argument("--policy", default="lru")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prefixes", type=int, default=4)
    ap.add_argument("--prefix-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--bypass", type=float, default=0.0)
    ap.add_argument("--mpl", type=int, default=72)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    if cfg.encdec:
        raise SystemExit("enc-dec archs are served via examples/; see DESIGN.md")
    params = param_values(transformer.init_params(cfg, jax.random.PRNGKey(0)))
    eng = Engine(cfg, params, ServeConfig(
        max_seqs=4, max_seq_len=256, page_size=8, n_pages=128,
        prefix_capacity=64, policy=args.policy, max_new_tokens=args.max_new,
        bypass_fraction=args.bypass,
    ))
    reqs = zipf_request_stream(args.requests, args.prefixes, args.prefix_len,
                               cfg.vocab, seed=0, new_tokens=6)
    for _, toks in reqs:
        eng.submit(toks)
    stats = eng.run()
    print("engine stats:", stats)

    # paper-model throughput prediction from the measured controller profile
    s = eng.prefix.stats
    n = s.chunk_hits + s.chunk_misses
    hits = np.zeros(n, dtype=bool)
    hits[: s.chunk_hits] = True
    hit_ops, miss_ops = eng.prefix.mean_ops_per_chunk()
    ops = np.where(hits[:, None], np.round(hit_ops), np.round(miss_ops)).astype(int)
    meas = empirical_network(args.policy, hits, ops,
                             service=PAPER_SERVICES.get(args.policy, ServiceTimes()),
                             mpl=args.mpl, warmup_frac=0.0)
    print(f"chunk hit ratio: {meas.hit_ratio:.3f}")
    print(f"controller throughput bound (Thm 7.1): "
          f"{meas.throughput_bound():.3f} Mreq/s at MPL={args.mpl}")
    return stats


if __name__ == "__main__":
    main()
