"""Per-(arch × shape) cell plans for the multi-pod dry-run.

A *cell* = (architecture, input shape, mesh).  Each plan carries:
  * the step function to lower (train_step / prefill / decode),
  * abstract inputs (ShapeDtypeStructs — no allocation),
  * input NamedShardings resolved from the logical specs.

Shapes (assignment):
  train_4k     seq 4096,    global_batch 256   (training)
  prefill_32k  seq 32768,   global_batch 32    (inference prefill)
  decode_32k   seq 32768,   global_batch 128   (one token, 32k KV)
  long_500k    seq 524288,  global_batch 1     (long-context decode)

Sharding policy (DESIGN.md §5): batch over ("pod","data"); vocab / heads /
FFN / experts over "model"; KV-cache sequence over "model" (plus "data"
when batch=1) whenever kv_heads doesn't divide the model axis.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro import sharding as shardlib
from repro.configs.registry import get_config
from repro.models import encdec, transformer
from repro.models.config import ModelConfig
from repro.models.layers import param_specs, param_values
from repro.training.optimizer import OptimizerConfig
from repro.training.train_state import TrainState, init_train_state, make_train_step


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq: int
    batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k is skipped only where architecturally meaningless (enc-dec
# decoder with bounded context).  Pure full-attention archs are *eligible*
# to skip per the assignment; we compile them anyway (decode is linear-cost
# per token) and flag them in the roofline table.
SKIP = {("whisper-tiny", "long_500k"): "enc-dec decoder context is bounded"}

FULL_ATTENTION_ARCHS = {
    "arctic-480b", "llama4-scout-17b-a16e", "chameleon-34b", "qwen3-32b",
    "internlm2-1.8b", "nemotron-4-15b",
}


# §Perf variants: named cfg overrides applied on top of the baseline
# (see EXPERIMENTS.md §Perf for the hypothesis -> result log per cell)
VARIANTS = {
    "opt": {
        "zamba2-1.2b": dict(ssm_split_proj=True, sequence_parallel=True),
        "arctic-480b": dict(moe_ep2d=True, sequence_parallel=True),
        "gemma3-27b": dict(sequence_parallel=True),
        "rwkv6-7b": dict(sequence_parallel=True),
        "qwen3-32b": dict(sequence_parallel=True),
        "whisper-tiny": dict(),
    },
    "sp_only": {
        "zamba2-1.2b": dict(sequence_parallel=True),
        "arctic-480b": dict(sequence_parallel=True),
    },
    "split_only": {
        "zamba2-1.2b": dict(ssm_split_proj=True),
    },
    "ep2d_only": {
        "arctic-480b": dict(moe_ep2d=True),
    },
}


def variant_config(arch: str, variant: str):
    import dataclasses as _dc
    cfg = get_config(arch)
    over = VARIANTS.get(variant, {}).get(arch)
    if over is None:
        return None
    return _dc.replace(cfg, **over)


def optimizer_for(arch: str) -> OptimizerConfig:
    # 480B params: bf16 moments or the optimizer alone overflows 256 chips
    # (see EXPERIMENTS.md §Dry-run memory table)
    if arch in ("arctic-480b", "llama4-scout-17b-a16e"):
        return OptimizerConfig(moment_dtype="bfloat16")
    return OptimizerConfig()


# ---------------------------------------------------------------------------
# sharding spec builders
# ---------------------------------------------------------------------------


def _resolve(spec_tuple) -> NamedSharding:
    return shardlib.sharding_for(spec_tuple)


def _leaf_name(path) -> str:
    return getattr(path[-1], "name", None) or str(path[-1])


def cache_spec_tree(cfg: ModelConfig, caches_abs, batch_shardable: bool):
    """Logical specs for a decode-cache pytree (leaf-name driven)."""
    ctx = shardlib.get_ctx()
    model_n = ctx.axis_size("model") if ctx else 1
    batch_ax = "batch" if batch_shardable else None

    def kv_axes():
        if cfg.tensor_parallel and cfg.n_kv_heads % model_n == 0:
            return ("model", None if batch_shardable else "batch")
        return (None, "model" if batch_shardable else ("batch", "model"))

    def spec(path, leaf):
        name = _leaf_name(path)
        if name in ("k", "v"):
            head_ax, s_ax = kv_axes()
            return (None, batch_ax, s_ax, head_ax, None)
        if name == "index":
            return (None, batch_ax)
        mshard = lambda n: "model" if (cfg.tensor_parallel and n % model_n == 0) else None
        if name == "wkv":  # (g, B, H, dh, dh)
            return (None, batch_ax, mshard(leaf.shape[2]), None, None)
        if name == "ssm":  # (g, B, H, hd, ds)
            return (None, batch_ax, mshard(leaf.shape[2]), None, None)
        if name == "conv":  # (g, B, W-1, C)
            return (None, batch_ax, None, mshard(leaf.shape[3]))
        if name in ("x_prev_att", "x_prev_ffn"):  # (g, B, D)
            return (None, batch_ax, mshard(leaf.shape[2]))
        # fallback: batch-shard dim 1, replicate the rest
        return (None, batch_ax) + (None,) * (leaf.ndim - 2)

    return jax.tree_util.tree_map_with_path(spec, caches_abs)


def train_state_spec_tree(state_abs: TrainState, params_logical, zero1: bool = True):
    """Specs for TrainState: params via their logical specs; optimizer
    moments likewise (+ ZeRO-1 data-sharding on dim 0); step replicated."""
    from repro.training.optimizer import zero1_moment_spec

    flat_p = spec_leaves(params_logical)

    def moments(tree):
        flat_m = jax.tree_util.tree_leaves(tree)
        out = []
        for spec, leaf in zip(flat_p, flat_m):
            s = spec if len(spec) == leaf.ndim else (None,) * leaf.ndim
            if zero1:
                ctx = shardlib.get_ctx()
                n = ctx.axis_size("batch") if ctx else 16
                s = zero1_moment_spec(tuple(s), leaf.shape, n)
            out.append(s)
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(tree), out
        )

    opt = state_abs.opt
    opt_specs = type(opt)(*[moments(getattr(opt, f)) for f in opt._fields])
    return TrainState(step=(), params=params_logical, opt=opt_specs)


def _is_spec(x) -> bool:
    """A logical partition spec: tuple of None | axis-name | tuple-of-names.
    Distinguishes spec leaves from structural tuples/NamedTuples in trees."""
    if not isinstance(x, tuple) or hasattr(x, "_fields"):
        return False
    for e in x:
        if e is None or isinstance(e, str):
            continue
        if (isinstance(e, tuple) and not hasattr(e, "_fields")
                and e and all(isinstance(s, str) for s in e)):
            continue
        return False
    return True


def spec_leaves(tree):
    return jax.tree_util.tree_leaves(tree, is_leaf=_is_spec)


def _to_shardings(logical_tree, abs_tree):
    """Resolve a logical-spec tree to NamedShardings (leaf-aligned)."""
    flat_spec = spec_leaves(logical_tree)
    flat_abs, treedef = jax.tree_util.tree_flatten(abs_tree)
    assert len(flat_spec) == len(flat_abs), (len(flat_spec), len(flat_abs))
    return jax.tree_util.tree_unflatten(
        treedef, [_resolve(s) for s in flat_spec]
    )


# ---------------------------------------------------------------------------
# cell plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CellPlan:
    arch: str
    shape: ShapeSpec
    fn: Callable
    args: tuple  # abstract args
    in_shardings: tuple
    out_shardings: Any = None  # pytree prefix; None -> compiler's choice
    donate: tuple = ()
    note: str = ""


def _logits_sharding(cfg: ModelConfig, batch_shardable: bool):
    ctx = shardlib.get_ctx()
    model_n = ctx.axis_size("model") if ctx else 1
    v_ax = "model" if (cfg.tensor_parallel and cfg.vocab % model_n == 0) else None
    return _resolve(("batch" if batch_shardable else None, None, v_ax))


def _abstract_params(cfg: ModelConfig):
    init_fn = encdec.init_params if cfg.encdec else transformer.init_params
    tree = init_fn(cfg, jax.random.PRNGKey(0), abstract=True)
    return param_values(tree), param_specs(tree)


def _token_sharding(batch_shardable: bool, ndim: int = 2):
    spec = ("batch" if batch_shardable else None,) + (None,) * (ndim - 1)
    return _resolve(spec)


def build_cell(arch: str, shape_name: str, cfg=None,
               shape: Optional[ShapeSpec] = None) -> Optional[CellPlan]:
    """Must be called inside sharding.use_mesh(ctx).  cfg/shape overrides
    exist for tests (reduced configs on small meshes)."""
    if (arch, shape_name) in SKIP:
        return None
    cfg = cfg or get_config(arch)
    shape = shape or SHAPES[shape_name]
    ctx = shardlib.get_ctx()
    batch_n = ctx.axis_size("batch") if ctx else 1
    batch_shardable = shape.batch % batch_n == 0

    if shape.kind == "train":
        opt = optimizer_for(arch)
        state_abs = init_train_state(cfg, opt, jax.random.PRNGKey(0), abstract=True)
        _, logical = _abstract_params(cfg)
        state_specs = train_state_spec_tree(state_abs, logical)
        state_sh = _to_shardings(state_specs, state_abs)
        batch_abs = {"tokens": jax.ShapeDtypeStruct(
            (shape.batch, shape.seq + 1), jnp.int32)}
        batch_sh = {"tokens": _token_sharding(batch_shardable)}
        if cfg.encdec:
            batch_abs["frames"] = jax.ShapeDtypeStruct(
                (shape.batch, cfg.enc_positions, cfg.d_model), jnp.float32)
            batch_sh["frames"] = _token_sharding(batch_shardable, 3)
        step = make_train_step(cfg, opt, remat="full")
        return CellPlan(arch, shape, step, (state_abs, batch_abs),
                        (state_sh, batch_sh),
                        out_shardings=(state_sh, _resolve(())), donate=(0,))

    params_abs, logical = _abstract_params(cfg)
    params_sh = _to_shardings(logical, params_abs)

    if cfg.encdec:
        return _build_encdec_cell(arch, cfg, shape, params_abs, params_sh,
                                  batch_shardable)

    if shape.kind == "prefill":
        caches_abs = jax.eval_shape(
            lambda: transformer.init_cache(cfg, shape.batch, shape.seq,
                                           jnp.dtype(cfg.compute_dtype))
        )
        cache_sh = _to_shardings(
            cache_spec_tree(cfg, caches_abs, batch_shardable), caches_abs)
        tokens = jax.ShapeDtypeStruct((shape.batch, shape.seq), jnp.int32)
        clen = jax.ShapeDtypeStruct((shape.batch,), jnp.int32)

        def prefill(params, tokens, caches, cache_len):
            logits, new_caches, _ = transformer.forward(
                params, tokens, cfg, caches=caches, cache_len=cache_len,
                unembed_last_only=True,
            )
            return logits, new_caches

        return CellPlan(
            arch, shape, prefill,
            (params_abs, tokens, caches_abs, clen),
            (params_sh, _token_sharding(batch_shardable), cache_sh,
             _resolve(("batch" if batch_shardable else None,))),
            out_shardings=(_logits_sharding(cfg, batch_shardable), cache_sh),
            donate=(2,),
        )

    # decode
    caches_abs = jax.eval_shape(
        lambda: transformer.init_cache(cfg, shape.batch, shape.seq,
                                       jnp.dtype(cfg.compute_dtype))
    )
    cache_sh = _to_shardings(
        cache_spec_tree(cfg, caches_abs, batch_shardable), caches_abs)
    tokens = jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32)
    clen = jax.ShapeDtypeStruct((shape.batch,), jnp.int32)

    def serve_step(params, tokens, caches, cache_len):
        return transformer.decode_step(params, tokens, caches, cache_len, cfg)

    note = ("beyond-requirement (pure full-attention; linear per-token cost)"
            if shape_name == "long_500k" and arch in FULL_ATTENTION_ARCHS else "")
    return CellPlan(
        arch, shape, serve_step,
        (params_abs, tokens, caches_abs, clen),
        (params_sh, _token_sharding(batch_shardable), cache_sh,
         _resolve(("batch" if batch_shardable else None,))),
        out_shardings=(_logits_sharding(cfg, batch_shardable), cache_sh),
        donate=(2,), note=note,
    )


def _build_encdec_cell(arch, cfg, shape, params_abs, params_sh, batch_shardable):
    frames = jax.ShapeDtypeStruct(
        (shape.batch, cfg.enc_positions, cfg.d_model), jnp.float32)
    frames_sh = _token_sharding(batch_shardable, 3)
    if shape.kind == "prefill":
        tokens = jax.ShapeDtypeStruct((shape.batch, shape.seq), jnp.int32)

        def prefill(params, frames, tokens):
            enc = encdec.encode(params, frames, cfg)
            logits, _ = encdec.decode(params, tokens, enc, cfg)
            return logits[:, -1:]

        return CellPlan(arch, shape, prefill, (params_abs, frames, tokens),
                        (params_sh, frames_sh, _token_sharding(batch_shardable)))

    # decode: self-KV caches at seq + precomputed cross K/V
    def make_caches(params):
        enc = jnp.zeros((shape.batch, cfg.enc_positions, cfg.d_model),
                        jnp.dtype(cfg.compute_dtype))
        return encdec.init_dec_cache(params, enc, cfg, shape.batch, shape.seq)

    caches_abs = jax.eval_shape(make_caches, params_abs)
    batch_ax = "batch" if batch_shardable else None

    def spec(path, leaf):
        name = _leaf_name(path)
        if name in ("k", "v") and leaf.ndim == 5:
            # self-KV sequence over "model": whisper has no TP, and leaving
            # the cache replicated over the model axis makes GSPMD emit a
            # full-cache all-reduce per decode step (see EXPERIMENTS §Perf)
            s_ax = "model" if leaf.shape[2] % 16 == 0 else None
            return (None, batch_ax, s_ax, None, None)
        if name == "index":
            return (None, batch_ax)
        return (None, batch_ax) + (None,) * (leaf.ndim - 2)

    cache_sh = _to_shardings(
        jax.tree_util.tree_map_with_path(spec, caches_abs), caches_abs)
    tokens = jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32)
    enc_out = jax.ShapeDtypeStruct(
        (shape.batch, cfg.enc_positions, cfg.d_model),
        jnp.dtype(cfg.compute_dtype))

    def serve_step(params, tokens, enc_out, caches, cache_len):
        logits, new_caches = encdec.decode(
            params, tokens, enc_out, cfg, caches=caches, cache_len=cache_len)
        return logits, new_caches

    clen = jax.ShapeDtypeStruct((), jnp.int32)
    return CellPlan(
        arch, shape, serve_step,
        (params_abs, tokens, enc_out, caches_abs, clen),
        (params_sh, _token_sharding(batch_shardable), frames_sh, cache_sh,
         _resolve(())),
        out_shardings=(_logits_sharding(cfg, batch_shardable), cache_sh),
        donate=(3,),
    )
