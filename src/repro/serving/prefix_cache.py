"""Host-side prefix cache controller — where the paper lives in serving.

Token prefixes are chunked at page granularity and hashed with a rolling
(parent, chunk) hash; chunk-hash -> page-id entries are managed by ANY of
the Table-1 eviction policies (repro.cache.py_ref).  Every controller
operation's metadata ops are accounted against the paper's queue stations
(delink / head / tail / scan), so a serving run yields exactly the
measurements the queueing model consumes (benchmarks/serving_integration).

LRU here = vLLM/SGLang-style prefix caching; the paper predicts (and the
benchmark shows) its controller saturates at high hit ratio, while
S3-FIFO/SIEVE/CLOCK controllers do not — the actionable finding.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.cache.py_ref import PY_POLICIES
from repro.serving.kv_pages import PageAllocator

HASH_SEED = 0x9E3779B97F4A7C15


def chunk_hashes(tokens: Sequence[int], page_size: int) -> List[int]:
    """Rolling hash per full chunk: h_i = H(h_{i-1}, tokens of chunk i)."""
    out = []
    h = HASH_SEED
    n_full = len(tokens) // page_size
    for i in range(n_full):
        chunk = tuple(int(t) for t in tokens[i * page_size : (i + 1) * page_size])
        h = hash((h, chunk)) & 0x7FFFFFFFFFFFFFFF
        out.append(h)
    return out


@dataclasses.dataclass
class ControllerStats:
    lookups: int = 0
    chunk_hits: int = 0
    chunk_misses: int = 0
    inserts: int = 0
    evictions: int = 0
    ops: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(4, dtype=np.int64)
    )
    bypassed: int = 0

    @property
    def hit_ratio(self) -> float:
        tot = self.chunk_hits + self.chunk_misses
        return self.chunk_hits / tot if tot else 0.0


class PrefixCache:
    """chunk-hash -> page-id map under a pluggable eviction policy."""

    def __init__(self, allocator: PageAllocator, capacity: int,
                 policy: str = "lru", **policy_kwargs):
        if capacity > allocator.n_pages:
            raise ValueError("prefix cache capacity exceeds page pool")
        self.allocator = allocator
        self.policy_name = policy
        self.policy = PY_POLICIES[policy](capacity, **policy_kwargs)
        self.pages: dict = {}  # chunk_hash -> page_id
        self.stats = ControllerStats()

    # -- lookup walks chunks until the first miss (prefix property) --------
    def lookup(self, hashes: List[int]) -> Tuple[List[int], int]:
        """Returns (hit page ids, number of hit chunks).

        Only hit chunks touch the policy (promotion ops on the hit path —
        the paper's delink+head for LRU).  Misses are charged at insert.
        """
        self.stats.lookups += 1
        hit_pages: List[int] = []
        for h in hashes:
            if h not in self.pages:
                break
            a = self.policy.access(h)
            assert a.hit, "policy/table divergence"
            self.stats.ops += np.asarray(a.ops, dtype=np.int64)
            self.stats.chunk_hits += 1
            hit_pages.append(self.pages[h])
        self.stats.chunk_misses += len(hashes) - len(hit_pages)
        return hit_pages, len(hit_pages)

    # -- insert a freshly computed chunk ----------------------------------
    def insert(self, chunk_hash: int, u: float = 0.0) -> Optional[int]:
        """Allocate a page for the chunk; returns page_id (None if present).

        The policy access is a miss -> insertion (+ possible eviction whose
        page returns to the allocator): the paper's miss-path tail+head ops.
        """
        if chunk_hash in self.pages:
            return None
        a = self.policy.access(chunk_hash, u)
        assert not a.hit
        self.stats.ops += np.asarray(a.ops, dtype=np.int64)
        self.stats.inserts += 1
        if a.evicted_key != -1 and a.evicted_key in self.pages:
            self.allocator.free(self.pages.pop(a.evicted_key))
            self.stats.evictions += 1
        page_id = self.allocator.alloc()
        self.pages[chunk_hash] = page_id
        return page_id

    def mean_ops_per_chunk(self) -> Tuple[np.ndarray, np.ndarray]:
        """(hit-path, miss-path) mean op vectors — queueing-model inputs."""
        hits = max(self.stats.chunk_hits, 1)
        misses = max(self.stats.inserts, 1)
        # promotion ops happen on lookup hits; insert ops on misses.  The
        # split is exact for the list policies because hit ops and miss ops
        # are disjoint events in this controller.
        hit_ops = np.zeros(4, np.float64)
        miss_ops = np.zeros(4, np.float64)
        if self.policy_name in ("lru", "slru", "prob_lru"):
            # delink ops only occur on hits for these policies
            hit_ops[0] = self.stats.ops[0] / hits
            hit_ops[1] = self.stats.ops[0] / hits  # paired head update
            miss_ops[1] = max(self.stats.ops[1] - self.stats.ops[0], 0) / misses
            miss_ops[2] = self.stats.ops[2] / misses
        else:  # FIFO-like: all ops are on the miss path
            miss_ops = self.stats.ops.astype(np.float64) / misses
        return hit_ops, miss_ops
