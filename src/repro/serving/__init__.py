"""repro.serving — continuous batching engine + prefix cache controller."""

from repro.serving.engine import Engine, Request, ServeConfig
from repro.serving.prefix_cache import PrefixCache, chunk_hashes
from repro.serving.kv_pages import PageAllocator
