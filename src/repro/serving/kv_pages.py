"""Device-side KV page pool + host-side allocator.

Pages hold `page_size` tokens of per-layer K/V (mirroring the stage-stacked
cache structure of repro.models.transformer).  The prefix cache is the sole
owner of pool pages: admission *gathers* hit pages into the request's dense
decode-cache slot, so pages are never referenced by in-flight requests and
eviction is always safe (no refcounting needed — see DESIGN.md).
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp


class PageAllocator:
    """Host-side free list over page ids [0, n_pages)."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages))

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("page pool exhausted")
        return self._free.pop()

    def free(self, page_id: int) -> None:
        self._free.append(page_id)

    @property
    def n_free(self) -> int:
        return len(self._free)


def make_kv_pool_leaf(leaf, n_pages: int, page_size: int, is_kv: bool):
    """Pool array for one cache leaf.

    K/V leaves (g, B, S, KV, dh) -> chunk pages (g, n_pages, page, KV, dh);
    recurrent-state leaves (g, B, *state) -> snapshots (g, n_pages, *state).
    """
    g = leaf.shape[0]
    if is_kv:
        _, _, _, kvh, dh = leaf.shape
        return jnp.zeros((g, n_pages, page_size, kvh, dh), leaf.dtype)
    return jnp.zeros((g, n_pages) + leaf.shape[2:], leaf.dtype)


@jax.jit
def store_chunk(pool_leaf, cache_leaf, slot, start, page_id):
    """pool[page_id] <- cache[slot, start : start+page] (one K/V leaf)."""
    page = pool_leaf.shape[2]
    chunk = jax.lax.dynamic_slice_in_dim(
        cache_leaf[:, slot], start, page, axis=1
    )  # (g, page, KV, dh)
    return pool_leaf.at[:, page_id].set(chunk)


@jax.jit
def gather_pages(cache_leaf, pool_leaf, slot, page_ids):
    """cache[slot, 0 : n*page] <- pool[page_ids] (one K/V leaf)."""
    g = pool_leaf.shape[0]
    pages = pool_leaf[:, page_ids]  # (g, n, page, KV, dh)
    n, page = pages.shape[1], pages.shape[2]
    flat = pages.reshape(g, n * page, *pages.shape[3:])
    updated = jax.lax.dynamic_update_slice_in_dim(
        cache_leaf[:, slot], flat, 0, axis=1
    )
    return cache_leaf.at[:, slot].set(updated)


@jax.jit
def store_state(pool_leaf, state_leaf, slot, page_id):
    """Snapshot pool[page_id] <- state[slot] (recurrent-state leaf)."""
    return pool_leaf.at[:, page_id].set(state_leaf[:, slot])


@jax.jit
def restore_state(state_leaf, pool_leaf, slot, page_id):
    return state_leaf.at[:, slot].set(pool_leaf[:, page_id])
