"""Serving engine: continuous batching + prefix cache + paged KV pool.

Design (DESIGN.md §2):
  * a fixed pool of `max_seqs` dense decode slots (the closed-loop MPL N —
    exactly the paper's multiprogramming limit);
  * a host-side **controller**: prefix-cache lookup/insert under a
    pluggable eviction policy, page allocator, slot scheduler.  Every
    controller action's metadata ops are recorded — these are the paper's
    serialized queue-station visits;
  * admission: chunk the prompt, gather prefix-cache hit pages into the
    slot's dense cache (attention archs) or restore a state snapshot (SSM
    archs), prefill only the uncached remainder, then insert the newly
    computed chunks into the cache;
  * decode: one batched step for all active slots per engine tick;
  * bypass (paper §5.2 mitigation): a fraction of requests skip the
    controller entirely.

Works for every non-encdec arch in the pool; whisper (enc-dec) is served
by examples/ with per-request cross-KV instead (no prefix reuse — see
DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.obs.metrics import Metrics
from repro.serving import kv_pages
from repro.serving.kv_pages import PageAllocator
from repro.serving.prefix_cache import PrefixCache, chunk_hashes


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seqs: int = 4  # MPL (decode slots)
    max_seq_len: int = 256
    page_size: int = 16  # tokens per KV page / prefix chunk
    n_pages: int = 64
    prefix_capacity: int = 48  # policy capacity (pages)
    policy: str = "lru"
    bypass_fraction: float = 0.0
    max_new_tokens: int = 16
    seed: int = 0
    # Closed-loop forecast knobs (paper Sec. 6 "future systems"): the pod's
    # physical core count drives the controller's effective MPL in the p*
    # forecast (the paper's testbed pinned one client thread per core on a
    # 72-core Xeon — real pods differ), and disk_servers > 0 models the
    # backing store / prefill path as a bounded-concurrency queue station
    # instead of the paper's infinite-server disk.  n_shards > 1 lifts the
    # forecast to a hash-routed cluster of identical pods (repro.cluster):
    # per-shard station replicas, cluster-level p*.
    cores: int = 72
    disk_servers: int = 0
    n_shards: int = 1
    # Streaming-observability knobs (repro.obs.streaming): sketch_cap > 0
    # threads the exact-counting PyStreamSketch through admission — every
    # looked-up chunk hash feeds the popularity estimator and its hit /
    # miss outcome feeds the windowed + EWMA hit estimators, with
    # ``sketch_window_ticks`` engine ticks per tumbling window (the
    # engine's clock is ticks, so decoded rates are per tick).  0 keeps
    # admission sketch-free.
    sketch_cap: int = 0
    sketch_window_ticks: int = 64


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray  # prompt
    max_new: int
    out: Optional[List[int]] = None
    slot: int = -1
    done: bool = False
    prefill_tokens_computed: int = 0
    prefill_tokens_skipped: int = 0


def _leaf_is_kv(path) -> bool:
    name = getattr(path[-1], "name", None)
    return name in ("k", "v")


def _leaf_is_index(path) -> bool:
    return getattr(path[-1], "name", None) == "index"


class Engine:
    def __init__(self, cfg: ModelConfig, params, serve: ServeConfig):
        if cfg.encdec:
            raise ValueError("enc-dec archs are served via examples/, not Engine")
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.state_mode = cfg.block in ("rwkv6", "mamba2")  # snapshot caching

        self.caches = transformer.init_cache(
            cfg, serve.max_seqs, serve.max_seq_len
        )
        self.pool = jax.tree_util.tree_map_with_path(
            lambda p, leaf: None if _leaf_is_index(p) else (
                kv_pages.make_kv_pool_leaf(leaf, serve.n_pages, serve.page_size,
                                           is_kv=_leaf_is_kv(p))
            ),
            self.caches,
        )
        self.allocator = PageAllocator(serve.n_pages)
        self.prefix = PrefixCache(
            self.allocator, serve.prefix_capacity, policy=serve.policy
        )
        self.lengths = np.zeros(serve.max_seqs, dtype=np.int64)
        self.active: Dict[int, Request] = {}  # slot -> request
        self.free_slots = list(range(serve.max_seqs))
        self.waiting: List[Request] = []
        self._rng = np.random.default_rng(serve.seed)
        self.ticks = 0
        self.decode_steps = 0
        self.metrics = Metrics()
        self._sketch = None
        if serve.sketch_cap:
            from repro.obs.streaming import PyStreamSketch

            # branch 0 = chunk hit, branch 1 = chunk miss
            self._sketch = PyStreamSketch(
                serve.sketch_cap, n_branches=2,
                window_us=float(serve.sketch_window_ticks))

        self._decode = jax.jit(
            lambda p, t, c, l: transformer.decode_step(p, t, c, l, cfg)
        )
        self._prefill = jax.jit(
            lambda p, t, c, l: transformer.forward(p, t, cfg, caches=c,
                                                   cache_len=l)[:2]
        )

    # ------------------------------------------------------------- admission
    def submit(self, tokens, max_new: Optional[int] = None, rid: Optional[int] = None):
        r = Request(
            rid=len(self.waiting) if rid is None else rid,
            tokens=np.asarray(tokens, dtype=np.int64),
            max_new=max_new or self.serve.max_new_tokens,
        )
        self.waiting.append(r)
        return r

    def _slot_cache(self, slot: int):
        """Fresh single-sequence cache view for prefill of `slot`."""
        return transformer.init_cache(self.cfg, 1, self.serve.max_seq_len)

    def _admit(self, r: Request, slot: int) -> None:
        ps = self.serve.page_size
        bypass = self._rng.random() < self.serve.bypass_fraction
        hashes = [] if bypass else chunk_hashes(r.tokens, ps)
        if bypass:
            self.prefix.stats.bypassed += 1

        cache1 = self._slot_cache(slot)

        if self.state_mode:
            logits, cache1, r_stats = self._admit_state(r, cache1, hashes)
            r.prefill_tokens_skipped, r.prefill_tokens_computed = r_stats
        else:
            n_hit = 0
            if hashes:
                pages, n_hit = self.prefix.lookup(hashes)
                if n_hit:
                    cache1 = self._gather(cache1, pages)

            start = n_hit * ps
            remainder = r.tokens[start:]
            r.prefill_tokens_skipped = start
            r.prefill_tokens_computed = len(remainder)
            if len(remainder) == 0:  # full hit: re-prefill the last token
                # (idempotent for KV caches: position len-1 is overwritten
                # with identical values)
                remainder = r.tokens[-1:]
                start = len(r.tokens) - 1
                r.prefill_tokens_computed = 1

            toks = jnp.asarray(remainder, jnp.int32)[None, :]
            cache_len = jnp.full((1,), start, jnp.int32)
            if n_hit:
                cache1 = self._set_index(cache1, start)
            logits, cache1 = self._prefill(self.params, toks, cache1, cache_len)

            # insert newly computed full chunks into the prefix cache
            if hashes:
                n_full = len(r.tokens) // ps
                for i in range(n_hit, n_full):
                    page = self.prefix.insert(hashes[i], self._rng.random())
                    if page is not None:
                        self._store_chunk(cache1, i * ps, page)

        if self._sketch is not None and hashes:
            # one stream event per looked-up chunk: the hash is the
            # popularity key, skipped tokens mark it a hit (bypassed
            # requests never reach the controller, so never the stream)
            t = float(self.ticks)
            n_hit_chunks = r.prefill_tokens_skipped // ps
            for i, h in enumerate(hashes):
                self._sketch.arrival(t)
                self._sketch.key(h)
                self._sketch.done(t, 0 if i < n_hit_chunks else 1,
                                  is_hit=i < n_hit_chunks)

        self._install(cache1, slot)
        self.lengths[slot] = len(r.tokens)
        first = int(np.asarray(logits[0, -1]).argmax())
        r.out = [first]
        r.slot = slot
        self.active[slot] = r
        self.metrics.count("admissions_count")
        self.metrics.count("prefill_tokens_computed_count",
                           r.prefill_tokens_computed)
        self.metrics.count("prefill_tokens_skipped_count",
                           r.prefill_tokens_skipped)
        self.metrics.observe(
            "prefill_hit_frac",
            r.prefill_tokens_skipped
            / max(r.prefill_tokens_skipped + r.prefill_tokens_computed, 1),
        )

    def _admit_state(self, r: Request, cache1, hashes):
        """SSM/hybrid admission: all-or-nothing snapshot of the recurrent
        state at len(prompt)-1; the final prompt token is always prefilled
        fresh (state updates are not idempotent, unlike KV writes)."""
        full = hashes[-1] if hashes else None
        hit = full is not None and full in self.prefix.pages

        if hit:
            pages, _ = self.prefix.lookup([full])
            cache1 = self._restore_state(cache1, pages[0])
            head, start = r.tokens[-1:], len(r.tokens) - 1
            skipped, computed = len(r.tokens) - 1, 1
            logits, cache1 = self._prefill(
                self.params, jnp.asarray(head, jnp.int32)[None, :], cache1,
                jnp.full((1,), start, jnp.int32),
            )
            return logits, cache1, (skipped, computed)

        if full is not None:
            self.prefix.stats.chunk_misses += 1
        skipped, computed = 0, len(r.tokens)
        head, last = r.tokens[:-1], r.tokens[-1:]
        if len(head):
            _, cache1 = self._prefill(
                self.params, jnp.asarray(head, jnp.int32)[None, :], cache1,
                jnp.zeros((1,), jnp.int32),
            )
        if full is not None:  # snapshot the state at len-1
            page = self.prefix.insert(full, self._rng.random())
            if page is not None:
                self._store_state(cache1, page)
        logits, cache1 = self._prefill(
            self.params, jnp.asarray(last, jnp.int32)[None, :], cache1,
            jnp.full((1,), len(head), jnp.int32),
        )
        return logits, cache1, (skipped, computed)

    # ------------------------------------------------ cache <-> pool plumbing
    # The pool tree carries None at index leaves, so pool goes FIRST in every
    # tree_map (None treated as a leaf via is_leaf) and the cache rides along.
    _IS_NONE = staticmethod(lambda x: x is None)

    def _gather(self, cache1, pages: List[int]):
        ids = jnp.asarray(pages, jnp.int32)

        def fn(path, pleaf, cleaf):
            if pleaf is None or not _leaf_is_kv(path):
                return cleaf
            return kv_pages.gather_pages(cleaf, pleaf, 0, ids)

        return jax.tree_util.tree_map_with_path(
            fn, self.pool, cache1, is_leaf=self._IS_NONE
        )

    def _store_chunk(self, cache1, start: int, page_id: int):
        def fn(path, pleaf, cleaf):
            if pleaf is None or not _leaf_is_kv(path):
                return pleaf
            return kv_pages.store_chunk(pleaf, cleaf, 0, start, page_id)

        self.pool = jax.tree_util.tree_map_with_path(
            fn, self.pool, cache1, is_leaf=self._IS_NONE
        )

    def _store_state(self, cache1, page_id: int):
        def fn(path, pleaf, cleaf):
            if pleaf is None or _leaf_is_kv(path):
                return pleaf
            return kv_pages.store_state(pleaf, cleaf, 0, page_id)

        self.pool = jax.tree_util.tree_map_with_path(
            fn, self.pool, cache1, is_leaf=self._IS_NONE
        )

    def _restore_state(self, cache1, page_id: int):
        def fn(path, pleaf, cleaf):
            if pleaf is None or _leaf_is_kv(path):
                return cleaf
            return kv_pages.restore_state(cleaf, pleaf, 0, page_id)

        return jax.tree_util.tree_map_with_path(
            fn, self.pool, cache1, is_leaf=self._IS_NONE
        )

    def _set_index(self, cache1, value: int):
        def fn(path, leaf):
            if _leaf_is_index(path):
                return jnp.full_like(leaf, value)
            return leaf

        return jax.tree_util.tree_map_with_path(fn, cache1)

    def _install(self, cache1, slot: int):
        def fn(batch_leaf, single_leaf):
            return batch_leaf.at[:, slot].set(single_leaf[:, 0])

        self.caches = jax.tree_util.tree_map(fn, self.caches, cache1)

    # ------------------------------------------------------------------ tick
    def tick(self) -> bool:
        """Admit waiting requests, run one batched decode step.
        Returns True while work remains."""
        self.ticks += 1
        self.metrics.count("ticks_count")
        while self.waiting and self.free_slots:
            slot = self.free_slots.pop()
            self._admit(self.waiting.pop(0), slot)
        self.metrics.gauge("active_slots_count", len(self.active))
        self.metrics.gauge("waiting_count", len(self.waiting))
        self.metrics.gauge("pages_free_count", self.allocator.n_free)

        if not self.active:
            return bool(self.waiting)
        self.metrics.observe("decode_batch_count", len(self.active))

        B = self.serve.max_seqs
        tokens = np.zeros((B, 1), dtype=np.int32)
        for slot, r in self.active.items():
            tokens[slot, 0] = r.out[-1]
        lens = jnp.asarray(self.lengths + np.arange(B) * 0, jnp.int32)
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tokens), self.caches, lens
        )
        self.decode_steps += 1
        self.metrics.count("decode_steps_count")
        self.metrics.count("decode_tokens_count", len(self.active))
        nxt = np.asarray(logits[:, 0].argmax(axis=-1))

        finished = []
        for slot, r in list(self.active.items()):
            self.lengths[slot] += 1
            if len(r.out) >= r.max_new:
                r.done = True
                finished.append(slot)
            else:
                r.out.append(int(nxt[slot]))
        for slot in finished:
            del self.active[slot]
            self.free_slots.append(slot)
            self.lengths[slot] = 0
        if finished:
            self.metrics.count("completions_count", len(finished))
        return bool(self.active or self.waiting)

    def run(self, max_ticks: int = 10_000):
        while self.tick():
            if self.ticks >= max_ticks:
                raise RuntimeError("engine did not drain")
        return self.stats()

    def stats(self) -> dict:
        s = self.prefix.stats
        return {
            "decode_steps": self.decode_steps,
            "chunk_hit_ratio": s.hit_ratio,
            "controller_ops": s.ops.tolist(),
            "evictions": s.evictions,
            "bypassed": s.bypassed,
            "pages_free": self.allocator.n_free,
        }

    def telemetry(self) -> dict:
        """Full observability snapshot: the per-tick metric registry
        (counters / gauges / distribution sketches, unit-suffixed names —
        see :mod:`repro.obs.metrics`) alongside :meth:`stats`.  With
        ``ServeConfig.sketch_cap > 0`` the snapshot additionally carries
        a ``"streaming"`` summary of the admission-stream estimators and
        a ``"alarms"`` list (phase-change drift on the windowed chunk
        hit fraction, sketch-saturation pressure)."""
        out = {"metrics": self.metrics.snapshot(), "stats": self.stats()}
        if self._sketch is not None:
            est = self._sketch.estimates()
            keys, counts, _ = est.topk(8)
            out["streaming"] = {
                "window_ticks": self._sketch.window_us,
                "window_id": est.window_id.tolist(),
                "win_hit_frac": est.win_hit_frac.tolist(),
                "win_done_rate_per_tick": est.win_done_rate.tolist(),
                "win_arrival_rate_per_tick": est.win_arrival_rate.tolist(),
                "ewma_hit_frac": est.ewma_hit_frac,
                "ewma_delayed_frac": est.ewma_delayed_frac,
                "key_count": est.key_count,
                "saturation_frac": est.saturation_frac(),
                "topk_key": keys.tolist(),
                "topk_count": counts.tolist(),
            }
            out["alarms"] = self._stream_alarms(est)
        return out

    def _stream_alarms(self, est) -> list:
        """Drift alarms over the decoded admission-stream estimates:
        a Page-Hinkley scan over the windowed chunk hit fraction flags
        phase changes; SpaceSaving pressure past 5% flags saturation."""
        from repro.obs.drift import page_hinkley_scan

        alarms = []
        ok = np.isfinite(est.win_hit_frac)
        hit, wid = est.win_hit_frac[ok], est.window_id[ok]
        for i in page_hinkley_scan(hit, warmup=4):
            alarms.append({
                "kind": "phase-change", "window_id": int(wid[i]),
                "measured": float(hit[i]),
                "detail": "windowed chunk hit fraction drifted",
            })
        sat = est.saturation_frac()
        if sat > 0.05:
            alarms.append({
                "kind": "sketch-saturation",
                "window_id": int(est.window_id[-1])
                if len(est.window_id) else -1,
                "measured": sat,
                "detail": "SpaceSaving table thrashing; raise sketch_cap",
            })
        return alarms

    def observed_profile(self, caps=None):
        """Online measured profile of this engine's chunk stream — the
        observation half of the ROADMAP item 4 control loop, recovered
        with no Mattson sweep.  Returns a
        :class:`repro.obs.profile.ObservedProfile`: estimated chunk-
        popularity masses (over the observed chunk hashes) fed through
        the Che approximation into a cap → hit-ratio curve, alongside
        the measured EWMA hit / delayed fractions.  ``caps`` overrides
        the capacity grid (pages); pass ``ServeConfig.prefix_capacity``
        neighbourhoods to ask "would a bigger prefix cache pay off".
        Requires ``ServeConfig.sketch_cap > 0``."""
        if self._sketch is None:
            raise ValueError(
                "observed_profile needs ServeConfig.sketch_cap > 0")
        from repro.obs.profile import observed_profile

        return observed_profile(self._sketch.estimates(), key_space=None,
                                caps=caps)

    def forecast_network(self, step_us: float, prefill_us: float,
                         replicas: int = 1, batched_update: bool = False,
                         cores: int | None = None,
                         coalesce_flows: int = 0,
                         n_shards: int | None = None,
                         shard_profile=None,
                         tiers: int = 0,
                         tier_profile=None):
        """Closed-network p* forecast for this engine's prefix controller.

        Uses the measured controller op profile plus the ServeConfig
        deployment knobs: the effective MPL is ``replicas * cores`` (one
        closed-loop client per physical core, the paper's convention — not
        the paper's 72-core testbed unless configured so), and
        ``disk_servers`` bounds the chunk-prefill concurrency when > 0.
        ``batched_update`` models the TPU-batched LRU sweep (promotions
        coalesce, so per-access delink/head demand divides by the MPL).
        ``cores`` overrides ``ServeConfig.cores`` for what-if forecasts —
        the knob only affects the forecast, so re-running the engine for a
        different pod shape would measure the identical profile.
        ``coalesce_flows > 0`` models prefill deduplication (concurrent
        misses on the same hot chunk share one recompute — the serving
        analogue of MSHR miss coalescing) over that many hot chunks, via
        :func:`repro.core.queueing.coalesced_network` with the prefill
        latency as the in-flight window.

        ``n_shards`` (default ``ServeConfig.n_shards``) > 1 lifts the
        measured-profile network to a hash-routed cluster of identical
        pods via :func:`repro.cluster.compose_cluster` and returns the
        composed cluster network — per-shard station replicas, cluster
        MPL ``n_shards * replicas * cores``, cluster-level p*.
        ``shard_profile`` (a :class:`repro.cluster.ShardProfile`) supplies
        routing skew + per-shard local hit ratios; the default is a
        perfectly balanced homogeneous cluster.  ``coalesce_flows`` and
        ``n_shards > 1`` compose: the cluster network is built first and
        :func:`repro.core.queueing.coalesced_network` then solves one
        shard-local sigma_k per ``sK:disk`` (prefill dedup never spans
        shards — the router sends each chunk to exactly one pod).

        ``tiers > 0`` lifts the forecast to a cache *hierarchy* instead:
        ``tiers`` client-local L1 instances of this pod's measured
        profile in front of ``n_shards`` L2 instances of the same
        profile in front of the chunk-prefill origin, composed via
        :func:`repro.hierarchy.compose_tiers`.  ``tier_profile`` (a
        :class:`repro.hierarchy.TieredProfile`) maps the global knob to
        (L1 hit ratio, per-shard residual hit ratios); the default is a
        constant profile with every L2 shard at 0.5.  The return value
        is still one ClosedNetwork — Thm-7.1 p*, MVA, and the Erlang-C
        forecasts work on it unchanged; with ``coalesce_flows`` the
        cross-tier :func:`repro.hierarchy.coalesced_hierarchy` transform
        is applied on top.
        """
        from repro.core.harness import PAPER_SERVICES, ServiceTimes
        from repro.core.queueing import (QUEUE, THINK, Branch, ClosedNetwork,
                                         Station, coalesced_network,
                                         disk_station)

        hit_ops, miss_ops = self.prefix.mean_ops_per_chunk()
        svc = PAPER_SERVICES.get(self.serve.policy, ServiceTimes())
        mpl = int(replicas) * int(self.serve.cores if cores is None else cores)
        delink = svc.delink / mpl if batched_update else svc.delink
        head = svc.head / mpl if batched_update else svc.head
        disk = disk_station(prefill_us, self.serve.disk_servers)
        stations = [
            Station("lookup", THINK, 0.51),
            disk,  # miss: chunk prefill recompute
            Station("step", THINK, step_us, dist="det"),
            Station("delink", QUEUE, delink),
            Station("head", QUEUE, head),
            Station("tail", QUEUE, svc.tail, bound="upper"),
            Station("scan", QUEUE, svc.scan),
        ]

        def visits(ops, miss):
            v = ["lookup", "step"] + (["disk"] if miss else [])
            d, h, t, s = (int(round(x)) for x in ops)
            return tuple(v + ["delink"] * d + ["head"] * h + ["tail"] * t
                         + ["scan"] * s)

        branches = [
            Branch("hit", lambda p: p, visits(hit_ops, False)),
            Branch("miss", lambda p: 1.0 - p, visits(miss_ops, True)),
        ]
        net = ClosedNetwork(f"serving-{self.serve.policy}", tuple(stations),
                            tuple(branches), mpl)
        n_shards = self.serve.n_shards if n_shards is None else int(n_shards)
        if tiers:
            from repro.hierarchy import (TieredProfile, TierSpec,
                                         coalesced_hierarchy, compose_tiers)

            profile = tier_profile or TieredProfile.constant(
                0.5, n_shards=max(n_shards, 1))
            hm = compose_tiers(
                TierSpec(net=net, n_instances=int(tiers), name="l1"),
                TierSpec(net=net, n_instances=max(n_shards, 1), name="l2"),
                profile=profile, disk_us=prefill_us,
                disk_servers=self.serve.disk_servers,
                mpl=mpl * int(tiers))
            if coalesce_flows:
                return coalesced_hierarchy(hm, flows=coalesce_flows,
                                           window_us=prefill_us)
            return hm.network
        if n_shards > 1:
            from repro.cluster import compose_cluster, uniform_profile

            profile = shard_profile or uniform_profile(n_shards)
            net = compose_cluster(net, profile, mpl=mpl * n_shards).network
        if coalesce_flows:
            net = coalesced_network(net, flows=coalesce_flows,
                                    window_us=prefill_us)
        return net

    def forecast_slo(self, step_us: float, prefill_us: float,
                     arrival_rate: float, slo_us: float,
                     percentile: float = 0.99, p_grid=None,
                     profile=None, **net_kwargs):
        """Open-loop SLO forecast for this engine's prefix controller.

        Builds the same measured-profile network as
        :meth:`forecast_network` (all of whose kwargs pass through), then
        evaluates it under Poisson arrivals at ``arrival_rate`` requests/µs
        via :func:`repro.latency.slo_forecast`: mean and ``percentile``
        tail response across the hit-ratio grid, the stability boundary
        lambda_max(p), and the three operating points — throughput-optimal
        p* (the closed-loop knee), latency-optimal p* at the offered rate,
        and SLO-capacity-optimal p* (argmax of the largest arrival rate
        whose tail still meets ``slo_us``).  This is the "should this pod
        chase a higher hit ratio" answer in the units users feel.

        ``profile`` (default: this engine's :meth:`observed_profile` when
        ``ServeConfig.sketch_cap > 0``) restricts the sweep to the
        measured achievable hit-ratio range and annotates each grid
        point with the prefix-cache capacity achieving it.
        """
        from repro.latency import slo_forecast

        if profile is None and self._sketch is not None \
                and self._sketch.key_count > 0:
            profile = self.observed_profile()
        net = self.forecast_network(step_us, prefill_us, **net_kwargs)
        return slo_forecast(net, arrival_rate, slo_us,
                            percentile=percentile, p_grid=p_grid,
                            profile=profile)
