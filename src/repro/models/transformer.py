"""Decoder-LM assembly for all pool families.

Layers are organized into **stages**: a stage is ``(group_count, block
pattern)`` and is executed as a ``lax.scan`` over stacked per-group params —
this keeps HLO size and compile time O(pattern) instead of O(n_layers),
which matters when dry-running 40 (arch × shape) cells.

  qwen3/internlm2/nemotron/chameleon : [(L, (attn-global,))]
  gemma3 (5 local : 1 global, 62L)   : [(10, (l,l,l,l,l,g)), (1, (l,l))]
  arctic/llama4 (MoE)                : [(L, (attn-global+moe,))]
  rwkv6                              : [(L, (rwkv6,))]
  zamba2 (38L, shared attn every 6)  : [(6, (m*,m,m,m,m,m)), (1, (m*,m))]
                                       (m* = mamba2 + shared attn block)

KV caches / recurrent states mirror the stage structure (leaves carry a
leading group axis and are scanned alongside params).
"""

from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

from repro import sharding
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import attention, init_attention, init_kv_cache
from repro.models.config import ModelConfig
from repro.models.layers import Initializer, rms_norm


# ---------------------------------------------------------------------------
# Stage plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockDesc:
    kind: str  # "attn" | "rwkv6" | "mamba2"
    attn_kind: str = "global"  # for attn blocks: global | local
    shared_attn: bool = False  # zamba2: run the shared attn block first


def build_stages(cfg: ModelConfig):
    """Returns [(group_count, tuple[BlockDesc, ...]), ...]."""
    if cfg.block == "attn":
        pattern = tuple(BlockDesc("attn", k) for k in cfg.attn_pattern)
    elif cfg.block == "rwkv6":
        pattern = (BlockDesc("rwkv6"),)
    elif cfg.block == "mamba2":
        k = cfg.shared_attn_every
        if k:
            pattern = (BlockDesc("mamba2", shared_attn=True),) + tuple(
                BlockDesc("mamba2") for _ in range(k - 1)
            )
        else:
            pattern = (BlockDesc("mamba2"),)
    else:
        raise ValueError(cfg.block)

    P = len(pattern)
    stages = []
    if cfg.n_layers // P:
        stages.append((cfg.n_layers // P, pattern))
    if cfg.n_layers % P:
        stages.append((1, pattern[: cfg.n_layers % P]))
    return stages


class VInit:
    """Initializer wrapper that stacks a group axis onto every param."""

    def __init__(self, inner: Initializer, g: int):
        self.inner = inner
        self.g = g

    def normal(self, shape, spec, **kw):
        return self.inner.normal((self.g,) + tuple(shape), (None,) + tuple(spec), **kw)

    def zeros(self, shape, spec, **kw):
        return self.inner.zeros((self.g,) + tuple(shape), (None,) + tuple(spec), **kw)

    def ones(self, shape, spec, **kw):
        return self.inner.ones((self.g,) + tuple(shape), (None,) + tuple(spec), **kw)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def _init_block(init, cfg: ModelConfig, desc: BlockDesc):
    p = {"ln1": L.init_rms_norm(init, cfg.d_model)}
    if desc.kind == "attn":
        p["attn"] = init_attention(init, cfg)
        p["ln2"] = L.init_rms_norm(init, cfg.d_model)
        if cfg.moe is not None:
            p["moe"] = moe_lib.init_moe(init, cfg)
        else:
            p["mlp"] = L.init_mlp(
                init, cfg.d_model, cfg.d_ff, cfg.act,
                m=L.MODEL if cfg.tensor_parallel else None,
            )
    elif desc.kind == "rwkv6":
        p["rwkv"] = rwkv_lib.init_rwkv_block(init, cfg)
        p["ln2"] = L.init_rms_norm(init, cfg.d_model)
    elif desc.kind == "mamba2":
        p["mamba"] = ssm_lib.init_mamba_block(init, cfg)
    return p


def init_params(cfg: ModelConfig, key, abstract: bool = False):
    init = Initializer(key, cfg.param_dtype, abstract=abstract)
    params: dict = {
        "embed": L.init_embedding(
            init, cfg.vocab, cfg.d_model,
            shard_vocab=cfg.tensor_parallel and cfg.vocab % 16 == 0,
        ),
        "final_norm": L.init_rms_norm(init, cfg.d_model),
        "stages": [],
    }
    for g, pattern in build_stages(cfg):
        vinit = VInit(init, g)
        params["stages"].append(
            tuple(_init_block(vinit, cfg, desc) for desc in pattern)
        )
    if cfg.shared_attn_every:
        # zamba2's shared transformer block (params reused at every call site)
        params["shared"] = {
            "ln1": L.init_rms_norm(init, cfg.d_model),
            "attn": init_attention(init, cfg),
            "ln2": L.init_rms_norm(init, cfg.d_model),
            "mlp": L.init_mlp(init, cfg.d_model, cfg.d_ff, cfg.act,
                              m=L.MODEL if cfg.tensor_parallel else None),
        }
    if not cfg.tie_embeddings:
        params["unembed"] = L.init_embedding(
            init, cfg.vocab, cfg.d_model,
            shard_vocab=cfg.tensor_parallel and cfg.vocab % 16 == 0,
        )
    return params


# ---------------------------------------------------------------------------
# Caches / recurrent state
# ---------------------------------------------------------------------------


def _stack(tree, g):
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (g,) + x.shape), tree
    )


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    """Cache pytree mirroring the stage structure."""
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    caches = []
    for g, pattern in build_stages(cfg):
        stage = []
        for desc in pattern:
            if desc.kind == "attn":
                c = init_kv_cache(batch, max_seq, cfg.n_kv_heads, cfg.d_head, dtype)
            elif desc.kind == "rwkv6":
                c = rwkv_lib.init_rwkv_state(cfg, batch, dtype)
            else:
                c = ssm_lib.init_mamba_state(cfg, batch, dtype)
            if desc.shared_attn:
                c = (init_kv_cache(batch, max_seq, cfg.n_kv_heads, cfg.d_head, dtype), c)
            stage.append(_stack(c, g))
        caches.append(tuple(stage))
    return caches


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _apply_block(h, bp, desc: BlockDesc, cfg, positions, cache, shared_params,
                 use_pallas: bool):
    """One block.  Returns (h, new_cache, aux)."""
    aux = {"moe_aux_loss": jnp.float32(0.0), "moe_drop_frac": jnp.float32(0.0)}

    if desc.shared_attn and shared_params is not None:
        sc, inner_cache = cache if cache is not None else (None, None)
        a, sc = attention(
            rms_norm(h, shared_params["ln1"]["scale"]), shared_params["attn"],
            cfg, "global", positions, kv_cache=sc, use_pallas=use_pallas,
        )
        h = h + a
        h = h + L.mlp(rms_norm(h, shared_params["ln2"]["scale"]),
                      shared_params["mlp"], cfg.act)
    else:
        sc, inner_cache = None, cache

    if desc.kind == "attn":
        a, new_c = attention(
            rms_norm(h, bp["ln1"]["scale"]), bp["attn"], cfg, desc.attn_kind,
            positions, kv_cache=inner_cache, use_pallas=use_pallas,
        )
        h = h + a
        hn = rms_norm(h, bp["ln2"]["scale"])
        if cfg.moe is not None:
            y, aux = moe_lib.moe_layer(hn, bp["moe"], cfg)
        else:
            y = L.mlp(hn, bp["mlp"], cfg.act)
        h = h + y
    elif desc.kind == "rwkv6":
        h, new_c = rwkv_lib.rwkv_block(h, bp["rwkv"], cfg, inner_cache)
    elif desc.kind == "mamba2":
        y, new_c = ssm_lib.mamba_block(
            rms_norm(h, bp["ln1"]["scale"]), bp["mamba"], cfg, inner_cache
        )
        h = h + y
    else:
        raise ValueError(desc.kind)

    if desc.shared_attn and shared_params is not None:
        new_c = (sc, new_c)
    if cfg.sequence_parallel and h.shape[1] > 1:
        # §Perf: residual stream sequence-sharded between blocks — GSPMD
        # turns per-block TP all-reduces into reduce-scatter + all-gather
        h = sharding.constrain(h, "batch", "model", None)
    else:
        h = sharding.constrain(h, "batch", None, None)
    return h, new_c, aux


def forward(
    params,
    tokens,
    cfg: ModelConfig,
    caches=None,
    cache_len=None,
    embeddings=None,
    remat: str = "none",
    use_pallas: bool = False,
    unembed_last_only: bool = False,
):
    """tokens: (B, T) int32 (or embeddings: (B, T, D) for stub frontends).

    caches None  -> train/prefill without cache retention.
    caches given -> positions offset by cache_len; caches are updated
                    (prefill writes T entries, decode writes 1).

    Returns (logits_f32, new_caches, aux).
    """
    compute = jnp.dtype(cfg.compute_dtype)
    if embeddings is None:
        h = L.embed(tokens, params["embed"]["table"], compute)
        B, T = tokens.shape
    else:
        h = embeddings.astype(compute)
        B, T = embeddings.shape[:2]
    h = sharding.constrain(h, "batch", None, None)

    base = jnp.int32(0) if cache_len is None else cache_len
    base = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(base, jnp.int32)), (B,))
    positions = base[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]

    shared = params.get("shared")
    stages = build_stages(cfg)
    new_caches = [] if caches is not None else None
    aux_tot = {"moe_aux_loss": jnp.float32(0.0), "moe_drop_frac": jnp.float32(0.0)}

    for si, (g, pattern) in enumerate(stages):
        stage_params = params["stages"][si]
        stage_cache = caches[si] if caches is not None else tuple(
            None for _ in pattern
        )

        def body(h, xs, pattern=pattern):
            bps, cs = xs
            auxes = []
            new_cs = []
            for desc, bp, c in zip(pattern, bps, cs):
                h, nc, aux = _apply_block(
                    h, bp, desc, cfg, positions, c, shared, use_pallas
                )
                new_cs.append(nc)
                auxes.append(aux)
            aux = jax.tree_util.tree_map(lambda *a: sum(a), *auxes)
            return h, (tuple(new_cs), aux)

        if remat == "full":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable
            )
        elif remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )

        if caches is not None:
            h, (stage_new_cache, stage_aux) = jax.lax.scan(
                body, h, (stage_params, stage_cache)
            )
            new_caches.append(stage_new_cache)
        else:
            dummy = tuple(
                jax.tree_util.tree_map(lambda x: None, c) for c in stage_cache
            )
            h, (_, stage_aux) = jax.lax.scan(body, h, (stage_params, dummy))
        aux_tot = jax.tree_util.tree_map(
            lambda a, b: a + b.sum(), aux_tot, stage_aux
        )

    h = rms_norm(h, params["final_norm"]["scale"])
    if unembed_last_only:
        # serving prefill: only next-token logits — a (B, T, V) f32 buffer
        # at 32k tokens x 150k vocab would be hundreds of GB per chip
        h = h[:, -1:]
    table = (params["embed"] if cfg.tie_embeddings else params["unembed"])["table"]
    logits = L.unembed(h, table)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    logits = sharding.constrain(logits, "batch", None, "model")
    return logits, new_caches, aux_tot


def decode_step(params, tokens, caches, cache_len, cfg: ModelConfig,
                use_pallas: bool = False):
    """One decode step.  tokens: (B, 1).  Returns (logits, new_caches)."""
    logits, new_caches, _ = forward(
        params, tokens, cfg, caches=caches, cache_len=cache_len,
        use_pallas=use_pallas,
    )
    return logits, new_caches
