"""Model configuration for the assigned architecture pool.

One frozen dataclass covers all ten families; the block pattern / stage
machinery in transformer.py interprets it.  Full-size configs are only ever
lowered abstractly (dry-run); smoke tests use reduced() variants.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    d_head: int = 0  # 0 -> d_model // n_heads
    act: str = "swiglu"  # swiglu | sqrelu | gelu
    qk_norm: bool = False
    # attention pattern, cycled over layers: e.g. 5 local + 1 global (gemma3)
    attn_pattern: Tuple[str, ...] = ("global",)
    local_window: int = 1024
    rope_base: float = 10_000.0
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    moe: Optional[MoEConfig] = None

    # block type: attn | rwkv6 | mamba2 (hybrid uses mamba2 + shared attn)
    block: str = "attn"
    shared_attn_every: int = 0  # zamba2: run the shared attn block every k
    ssm_state: int = 64
    ssm_conv_width: int = 4
    rwkv_head_dim: int = 64

    # encoder-decoder (whisper backbone); frontend is a stub that yields
    # precomputed frame embeddings of length enc_positions.
    encdec: bool = False
    enc_layers: int = 0
    enc_positions: int = 1500

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # tiny models (whisper) skip tensor parallelism: all params replicated
    tensor_parallel: bool = True

    # ---- perf knobs (EXPERIMENTS.md §Perf; all default to the paper-
    # faithful / naive baseline) ----
    # shard the residual stream's sequence dim over "model" between blocks
    # (sequence parallelism: converts TP all-reduces into RS+AG)
    sequence_parallel: bool = False
    # split the Mamba2 in_proj so B/C/dt are replicated (kills the
    # per-timestep all-gathers of cross-sharded small tensors in the scan)
    ssm_split_proj: bool = False
    # 2D expert sharding: experts over "data", expert-FFN hidden over
    # "model" (vs experts over "model" only) — 16x less expert HBM/chip
    moe_ep2d: bool = False

    # sequence limit used by serving caches (not a hard model limit)
    max_seq: int = 524_288

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.block == "attn" and self.n_heads % self.n_kv_heads != 0:
            raise ValueError(f"{self.name}: n_heads % n_kv_heads != 0")

    # ------------------------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return self.block == "rwkv6" or (self.block == "mamba2" and self.shared_attn_every == 0)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token contexts without full global KV?"""
        if self.block in ("rwkv6", "mamba2"):
            return True
        # local:global mixes are window-bounded on most layers
        return "local" in self.attn_pattern

    def layer_kinds(self):
        """Per-layer attention kind, cycling attn_pattern."""
        pat = self.attn_pattern
        return [pat[i % len(pat)] for i in range(self.n_layers)]

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        H, KV, dh = self.n_heads, self.n_kv_heads, self.d_head
        per_layer = 0
        if self.block == "attn":
            per_layer += D * (H * dh) + 2 * D * (KV * dh) + (H * dh) * D
            if self.qk_norm:
                per_layer += 2 * dh
        elif self.block == "rwkv6":
            # r,k,v,g,w projections + out + ddlerp loras (rank 32) + u
            per_layer += 6 * D * D + 5 * (2 * 32 * D) + 2 * D
        elif self.block == "mamba2":
            d_inner = 2 * D  # expansion 2 (repro.models.ssm.EXPAND)
            n_h = max(1, d_inner // 64)
            per_layer += D * (2 * d_inner + 2 * self.ssm_state + n_h)  # in_proj
            per_layer += self.ssm_conv_width * (d_inner + 2 * self.ssm_state)
            per_layer += d_inner * D  # out_proj
            per_layer += 3 * n_h + d_inner  # A, D, dt bias, norm
        if self.moe is not None:
            e = self.moe
            per_layer += D * e.n_experts  # router
            mult = 3 if self.act in ("swiglu", "geglu") else 2
            per_layer += e.n_experts * mult * D * e.d_ff_expert
            if e.dense_residual:
                per_layer += mult * D * F
        elif self.block != "mamba2":  # mamba2 blocks have no separate FFN
            mult = 3 if self.act in ("swiglu", "geglu") else 2
            per_layer += mult * D * F
        per_layer += 2 * D  # norms
        total = self.n_layers * per_layer
        if self.shared_attn_every:
            total += D * (H * dh) + 2 * D * (KV * dh) + (H * dh) * D  # shared attn
            total += (3 if self.act in ("swiglu", "geglu") else 2) * D * F + 2 * D
        total += V * D  # embedding
        if not self.tie_embeddings:
            total += V * D
        total += D  # final norm
        if self.encdec:
            el = self.enc_layers
            enc_per = 4 * D * D + (2 if self.act == "gelu" else 3) * D * F + 2 * D
            dec_cross = 4 * D * D + D  # cross-attn per decoder layer
            total += el * enc_per + self.n_layers * dec_cross
        return int(total)

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE counts top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        mult = 3 if self.act in ("swiglu", "geglu") else 2
        inactive = (e.n_experts - e.top_k) * mult * self.d_model * e.d_ff_expert
        return int(self.param_count() - self.n_layers * inactive)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=max(2, len(self.attn_pattern)) if len(self.attn_pattern) > 1 else 2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.block == "attn" else 4,
            d_ff=128,
            vocab=256,
            d_head=16,
            local_window=16,
            param_dtype="float32",
            compute_dtype="float32",
            rwkv_head_dim=16,
            ssm_state=8,
            enc_layers=2 if self.encdec else 0,
            enc_positions=24 if self.encdec else 1500,
            shared_attn_every=2 if self.shared_attn_every else 0,
            max_seq=512,
        )
        if self.moe is not None:
            small["moe"] = MoEConfig(
                n_experts=4, top_k=self.moe.top_k, d_ff_expert=128,
                dense_residual=self.moe.dense_residual,
                # no-drop capacity in smoke tests so cache-path consistency
                # checks are exact (capacity dropping is batch-order dependent)
                capacity_factor=4.0,
            )
        if self.block == "mamba2":
            small["n_kv_heads"] = 4
        small.update(overrides)
        return dataclasses.replace(self, name=self.name + "-reduced", **small)
