"""Mamba2 (SSD) blocks — the zamba2 hybrid's recurrent layers.

Structure follows Mamba2 (expansion 2, grouped B/C with one group, per-head
scalar decay): in_proj -> [z | xBC | dt]; short causal conv over xBC;
selective state update h' = exp(-dt·exp(A))·h + dt·x⊗B; y = C·h + D·x,
gated by silu(z).  Train/prefill scan over time (chunked optimized form in
kernels/linear_scan.py); decode is one state update + conv-window shift.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import MODEL, Initializer, rms_norm

EXPAND = 2
HEAD_DIM = 64


def _dims(cfg: ModelConfig):
    d_inner = EXPAND * cfg.d_model
    n_heads = d_inner // HEAD_DIM if d_inner >= HEAD_DIM else 1
    head_dim = d_inner // n_heads
    return d_inner, n_heads, head_dim


def init_mamba_block(init: Initializer, cfg: ModelConfig):
    D = cfg.d_model
    d_inner, n_heads, _ = _dims(cfg)
    ds = cfg.ssm_state
    m = MODEL if cfg.tensor_parallel else None
    p = {
        "conv_w": init.normal((cfg.ssm_conv_width, d_inner + 2 * ds), (None, None),
                              scale=0.5),
        "conv_b": init.zeros((d_inner + 2 * ds,), (None,)),
        "A_log": init.zeros((n_heads,), (None,), dtype="float32"),
        "D": init.ones((n_heads,), (None,), dtype="float32"),
        "dt_bias": init.zeros((n_heads,), (None,), dtype="float32"),
        "norm": init.ones((d_inner,), (None,), dtype="float32"),
        "out_proj": init.normal((d_inner, D), (m, None)),
    }
    if cfg.ssm_split_proj:
        # §Perf: z/x head-sharded; B/C/dt tiny and REPLICATED so the
        # per-timestep scan never crosses a sharding boundary.
        p["in_z"] = init.normal((D, d_inner), (None, m))
        p["in_x"] = init.normal((D, d_inner), (None, m))
        p["in_bc"] = init.normal((D, 2 * ds), (None, None))
        p["in_dt"] = init.normal((D, n_heads), (None, None))
    else:
        in_dim = 2 * d_inner + 2 * ds + n_heads  # z | x | B | C | dt
        p["in_proj"] = init.normal((D, in_dim), (None, m))
    return p


class MambaState(NamedTuple):
    conv: jax.Array  # (B, W-1, d_inner + 2*ds) — conv window tail
    ssm: jax.Array  # (B, n_heads, head_dim, d_state) fp32


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    d_inner, n_heads, head_dim = _dims(cfg)
    return MambaState(
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, d_inner + 2 * cfg.ssm_state),
                       dtype),
        ssm=jnp.zeros((batch, n_heads, head_dim, cfg.ssm_state), jnp.float32),
    )


def _causal_conv(x, w, b, prefix):
    """x: (B, T, C); w: (W, C) depthwise; prefix: (B, W-1, C) from state."""
    W = w.shape[0]
    xp = jnp.concatenate([prefix, x], axis=1)  # (B, T+W-1, C)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    return out + b[None, None, :], xp[:, -(W - 1):, :]


def mamba_block(x, p, cfg: ModelConfig, state: MambaState = None):
    """x: (B, T, D) -> (out, new_state)."""
    B, T, D = x.shape
    d_inner, n_heads, head_dim = _dims(cfg)
    ds = cfg.ssm_state

    if state is None:
        state = init_mamba_state(cfg, B, x.dtype)

    cw = p["conv_w"].astype(x.dtype)
    cb = p["conv_b"].astype(x.dtype)
    if cfg.ssm_split_proj:
        # §Perf variant: conv applied piecewise so the (sharded) x stream
        # and the (replicated) B/C stream never get concatenated.
        z = x @ p["in_z"].astype(x.dtype)
        xs_in = x @ p["in_x"].astype(x.dtype)
        bc = x @ p["in_bc"].astype(x.dtype)
        dt_raw = x @ p["in_dt"].astype(x.dtype)
        xs_c, conv_x = _causal_conv(xs_in, cw[:, :d_inner], cb[:d_inner],
                                    state.conv[..., :d_inner])
        bc_c, conv_bc = _causal_conv(bc, cw[:, d_inner:], cb[d_inner:],
                                     state.conv[..., d_inner:])
        new_conv = jnp.concatenate([conv_x, conv_bc], axis=-1)
        xs = jax.nn.silu(xs_c).reshape(B, T, n_heads, head_dim)
        bc_c = jax.nn.silu(bc_c)
        Bmat, Cmat = bc_c[..., :ds], bc_c[..., ds:]
    else:
        zxbcdt = x @ p["in_proj"].astype(x.dtype)
        z = zxbcdt[..., :d_inner]
        xbc = zxbcdt[..., d_inner : 2 * d_inner + 2 * ds]
        dt_raw = zxbcdt[..., 2 * d_inner + 2 * ds :]  # (B, T, n_heads)

        xbc, new_conv = _causal_conv(xbc, cw, cb, state.conv)
        xbc = jax.nn.silu(xbc)
        xs = xbc[..., :d_inner].reshape(B, T, n_heads, head_dim)
        Bmat = xbc[..., d_inner : d_inner + ds]  # (B, T, ds) one group
        Cmat = xbc[..., d_inner + ds :]  # (B, T, ds)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,T,H)
    decay = jnp.exp(-dt * jnp.exp(p["A_log"].astype(jnp.float32)))  # (B,T,H)

    def step(h, inputs):
        xt, bt, ct, dct, dtt = inputs  # (B,H,hd), (B,ds), (B,ds), (B,H), (B,H)
        dx = dtt[..., None] * xt.astype(jnp.float32)  # (B,H,hd)
        h = dct[..., None, None] * h + dx[..., None] * bt[:, None, None, :]
        y = jnp.einsum("bhds,bs->bhd", h, ct.astype(jnp.float32))
        # emit per-step outputs in compute dtype: the stacked (T,B,H,hd) ys
        # crosses shards at the output norm, and f32 doubles those bytes
        return h, y.astype(x.dtype)

    xs_t = xs.swapaxes(0, 1)  # (T,B,H,hd)
    b_t = Bmat.astype(jnp.float32).swapaxes(0, 1)
    c_t = Cmat.astype(jnp.float32).swapaxes(0, 1)
    dc_t = decay.swapaxes(0, 1)
    dt_t = dt.swapaxes(0, 1)
    new_ssm, ys = jax.lax.scan(step, state.ssm, (xs_t, b_t, c_t, dc_t, dt_t))
    ys = ys.swapaxes(0, 1)  # (B,T,H,hd) in compute dtype
    ys = ys + p["D"].astype(x.dtype)[None, None, :, None] * xs

    y = ys.reshape(B, T, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["out_proj"].astype(x.dtype)
    return out, MambaState(conv=new_conv, ssm=new_ssm)
