"""Shared layers: params-with-specs, norms, embeddings, MLPs, RoPE.

Parameters are created as :class:`Param` leaves carrying a *logical*
partition spec (axis names "batch" / "model" / None).  The launch layer
resolves logical names to mesh axes (see repro/launch/mesh.py) — model code
never references a concrete mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

MODEL = "model"
BATCH = "batch"


@dataclasses.dataclass
class Param:
    value: Any  # jax.Array | ShapeDtypeStruct
    spec: tuple  # logical partition spec


def is_param(x) -> bool:
    return isinstance(x, Param)


def param_values(tree):
    return jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_param)


def param_specs(tree):
    return jax.tree_util.tree_map(lambda p: p.spec, tree, is_leaf=is_param)


def tree_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(param_values(tree))
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in leaves)


class Initializer:
    """Keyed parameter factory.  abstract=True yields ShapeDtypeStructs
    (used by the dry-run to build full-size param trees without memory)."""

    def __init__(self, key, dtype: str, abstract: bool = False):
        self.key = key
        self.dtype = jnp.dtype(dtype)
        self.abstract = abstract

    def _next(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def normal(self, shape, spec, scale: Optional[float] = None, dtype=None) -> Param:
        dtype = jnp.dtype(dtype) if dtype else self.dtype
        if self.abstract:
            return Param(jax.ShapeDtypeStruct(shape, dtype), spec)
        scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
        v = (jax.random.normal(self._next(), shape, jnp.float32) * scale).astype(dtype)
        return Param(v, spec)

    def zeros(self, shape, spec, dtype=None) -> Param:
        dtype = jnp.dtype(dtype) if dtype else self.dtype
        if self.abstract:
            return Param(jax.ShapeDtypeStruct(shape, dtype), spec)
        return Param(jnp.zeros(shape, dtype), spec)

    def ones(self, shape, spec, dtype=None) -> Param:
        dtype = jnp.dtype(dtype) if dtype else self.dtype
        if self.abstract:
            return Param(jax.ShapeDtypeStruct(shape, dtype), spec)
        return Param(jnp.ones(shape, dtype), spec)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def init_rms_norm(init: Initializer, d: int):
    return {"scale": init.ones((d,), (None,), dtype="float32")}


def layer_norm(x, p, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


def init_layer_norm(init: Initializer, d: int):
    return {
        "scale": init.ones((d,), (None,), dtype="float32"),
        "bias": init.zeros((d,), (None,), dtype="float32"),
    }


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(init: Initializer, vocab: int, d: int, shard_vocab: bool):
    spec = (MODEL if shard_vocab else None, None)
    return {"table": init.normal((vocab, d), spec, scale=1.0)}


def embed(tokens, table, compute_dtype):
    return jnp.take(table.astype(compute_dtype), tokens, axis=0)


def unembed(x, table):
    # logits in f32 for a stable softmax/xent
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      table.astype(jnp.float32))


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


GATED_ACTS = ("swiglu", "geglu")


def init_mlp(init: Initializer, d: int, f: int, act: str, m=MODEL):
    p = {"down": init.normal((f, d), (m, None))}
    if act in GATED_ACTS:
        p["gate"] = init.normal((d, f), (None, m))
        p["up"] = init.normal((d, f), (None, m))
    else:  # sqrelu / gelu: single up-projection
        p["up"] = init.normal((d, f), (None, m))
    return p


def mlp(x, p, act: str):
    if act in GATED_ACTS:
        gate_fn = jax.nn.silu if act == "swiglu" else jax.nn.gelu
        h = gate_fn(x @ p["gate"].astype(x.dtype)) * (x @ p["up"].astype(x.dtype))
    elif act == "sqrelu":  # nemotron-4: squared ReLU
        h = jnp.square(jax.nn.relu(x @ p["up"].astype(x.dtype)))
    elif act == "gelu":
        h = jax.nn.gelu(x @ p["up"].astype(x.dtype))
    else:
        raise ValueError(act)
    return h @ p["down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(d_head: int, base: float):
    return base ** (-jnp.arange(0, d_head // 2, dtype=jnp.float32) / (d_head // 2))


def apply_rope(x, positions, base: float):
    """x: (..., T, H, D); positions: (..., T) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, base)  # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,T,1,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
