"""Grouped-query attention with chunked (flash-style) softmax.

Covers the pool's attention variants: GQA (all), qk-norm (qwen3), local
sliding-window / global mixes (gemma3), MHA (zamba2 shared block, whisper),
bidirectional (whisper encoder) and cross attention (whisper decoder).

The jnp chunked implementation is the reference semantics for the Pallas
flash kernel (kernels/flash_attention.py); `use_pallas=True` swaps it in
(interpret mode on CPU).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import sharding
from repro.models.config import ModelConfig
from repro.models.layers import MODEL, Initializer, apply_rope, rms_norm

NEG_INF = -2.0e38


def init_attention(init: Initializer, cfg: ModelConfig, n_heads=None, n_kv=None):
    H = n_heads or cfg.n_heads
    KV = n_kv or cfg.n_kv_heads
    D, dh = cfg.d_model, cfg.d_head
    m = MODEL if cfg.tensor_parallel else None
    p = {
        "wq": init.normal((D, H * dh), (None, m)),
        "wk": init.normal((D, KV * dh), (None, m)),
        "wv": init.normal((D, KV * dh), (None, m)),
        "wo": init.normal((H * dh, D), (m, None)),
    }
    if cfg.qk_norm:
        p["q_norm"] = init.ones((dh,), (None,), dtype="float32")
        p["k_norm"] = init.ones((dh,), (None,), dtype="float32")
    return p


class KVCache(NamedTuple):
    """Dense per-layer KV cache for decode.

    `index` is PER SEQUENCE (continuous batching: each slot has its own
    length).  Prefill (T > 1) requires all batch entries at equal index
    (the serving engine prefills one slot at a time); decode (T = 1)
    scatters at per-slot positions.
    """

    k: jax.Array  # (B, S, KV, dh)
    v: jax.Array  # (B, S, KV, dh)
    index: jax.Array  # (B,) int32 — next write position (= current length)


def init_kv_cache(batch: int, max_seq: int, n_kv: int, d_head: int, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, max_seq, n_kv, d_head), dtype),
        v=jnp.zeros((batch, max_seq, n_kv, d_head), dtype),
        index=jnp.zeros((batch,), jnp.int32),
    )


def _split_heads(x, n, dh):
    return x.reshape(*x.shape[:-1], n, dh)


def chunked_attention(
    q, k, v, q_pos, k_valid_len, causal: bool, window: int = 0, chunk: int = 1024
):
    """Online-softmax attention, scanning KV in chunks (flash algorithm).

    q: (B, T, H, dh); k/v: (B, S, KV, dh); q_pos: (B, T) absolute positions.
    k positions are arange(S); entries >= k_valid_len (scalar or per-batch
    (B,)) are masked out.  window > 0 => sliding-window (local) attention.
    Returns (B, T, H, dh) in q.dtype.
    """
    B, T, H, dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = dh**-0.5
    qg = (q * scale).reshape(B, T, KV, G, dh).astype(jnp.float32)

    if T == 1 or S <= chunk:
        # Decode / short-KV: one-shot masked softmax.  No chunk scan means
        # no reshape/dynamic-slice of the (possibly sequence-sharded) KV —
        # GSPMD partitions the contraction and all-reduces the softmax
        # stats instead of rematerializing the cache.  K/V are read in their
        # storage dtype with f32 MXU accumulation (a full-cache f32 cast
        # would triple decode HBM traffic).  See EXPERIMENTS.md §Perf.
        logits = jnp.einsum(
            "btkgd,bskd->btkgs", qg.astype(k.dtype), k,
            preferred_element_type=jnp.float32,
        )
        kpos = jnp.arange(S, dtype=jnp.int32)
        kv_lim = jnp.atleast_1d(jnp.asarray(k_valid_len))[:, None, None]
        valid = kpos[None, None, :] < kv_lim  # (B|1, 1, S)
        if causal:
            valid = valid & (kpos[None, None, :] <= q_pos[:, :, None])
        if window > 0:
            valid = valid & (kpos[None, None, :] > q_pos[:, :, None] - window)
        logits = jnp.where(valid[:, :, None, None, :], logits, NEG_INF)
        m = logits.max(axis=-1, keepdims=True)
        p = jnp.exp(logits - m)
        out = jnp.einsum(
            "btkgs,bskd->btkgd", p.astype(v.dtype), v,
            preferred_element_type=jnp.float32,
        )
        out = out / jnp.maximum(p.sum(axis=-1), 1e-30)[..., None]
        return out.reshape(B, T, H, dh).astype(q.dtype)

    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KV, dh).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, chunk, KV, dh).swapaxes(0, 1)

    def step(carry, inputs):
        m, l, acc = carry
        ci, kb, vb = inputs
        kpos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)  # (C,)
        logits = jnp.einsum(
            "btkgd,bckd->btkgc", qg.astype(kb.dtype), kb,
            preferred_element_type=jnp.float32,
        )  # (B,T,KV,G,C)
        kv_lim = jnp.atleast_1d(jnp.asarray(k_valid_len))[:, None, None]  # (B|1,1,1)
        valid = kpos[None, None, :] < kv_lim  # (B|1,1,C)
        if causal:
            valid = valid & (kpos[None, None, :] <= q_pos[:, :, None])
        if window > 0:
            valid = valid & (kpos[None, None, :] > q_pos[:, :, None] - window)
        logits = jnp.where(valid[:, :, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "btkgc,bckd->btkgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, T, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, T, KV, G), jnp.float32)
    acc0 = jnp.zeros((B, T, KV, G, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (jnp.arange(n_chunks, dtype=jnp.int32), kc, vc)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, T, H, dh).astype(q.dtype)


def attention(
    x,
    p,
    cfg: ModelConfig,
    kind: str = "global",
    positions=None,
    kv_cache: Optional[KVCache] = None,
    cross_kv=None,
    use_rope: bool = True,
    n_heads=None,
    n_kv=None,
    use_pallas: bool = False,
):
    """Full attention block (projections + attention + output proj).

    Modes:
      * train/prefill (kv_cache None): causal (kind: global/local) or
        bidirectional (kind="bidir"), optionally writing a fresh cache.
      * decode (kv_cache given): x is (B, 1, D), append and attend.
      * cross (cross_kv given): attend over precomputed encoder K/V.
    """
    H = n_heads or cfg.n_heads
    KV = n_kv or cfg.n_kv_heads
    dh = cfg.d_head
    B, T, D = x.shape

    q = _split_heads(x @ p["wq"].astype(x.dtype), H, dh)
    if cross_kv is None:
        k = _split_heads(x @ p["wk"].astype(x.dtype), KV, dh)
        v = _split_heads(x @ p["wv"].astype(x.dtype), KV, dh)
    else:
        k, v = cross_kv

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        if cross_kv is None:
            k = rms_norm(k, p["k_norm"])

    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    if use_rope and cross_kv is None:
        q = apply_rope(q, positions, cfg.rope_base)
        k = apply_rope(k, positions, cfg.rope_base)

    q = sharding.constrain(q, "batch", None, "model", None)

    new_cache = None
    if kv_cache is not None and cross_kv is None:
        if T == 1:
            # decode: write each sequence's token at its own position.  A
            # one-hot masked select, NOT a batched scatter: GSPMD cannot
            # prove at[arange(B), index] batch-local and emits an all-reduce
            # of the WHOLE cache (found via the whisper decode_32k cell —
            # see EXPERIMENTS.md §Perf).
            pos = jnp.arange(kv_cache.k.shape[1], dtype=jnp.int32)
            hit = (pos[None, :] == kv_cache.index[:, None])[:, :, None, None]
            k_full = jnp.where(hit, k[:, 0][:, None].astype(kv_cache.k.dtype),
                               kv_cache.k)
            v_full = jnp.where(hit, v[:, 0][:, None].astype(kv_cache.v.dtype),
                               kv_cache.v)
        else:
            # prefill: contiguous write (all batch entries at equal index)
            k_full = jax.lax.dynamic_update_slice(
                kv_cache.k, k.astype(kv_cache.k.dtype), (0, kv_cache.index[0], 0, 0)
            )
            v_full = jax.lax.dynamic_update_slice(
                kv_cache.v, v.astype(kv_cache.v.dtype), (0, kv_cache.index[0], 0, 0)
            )
        new_cache = KVCache(k_full, v_full, kv_cache.index + T)
        k, v = k_full, v_full
        k_valid = kv_cache.index + T  # (B,)
        S = k.shape[1]
    else:
        k_valid = jnp.full((B,), k.shape[1], jnp.int32)
        S = k.shape[1]

    causal = kind in ("global", "local") and cross_kv is None
    window = cfg.local_window if kind == "local" else 0

    if use_pallas and kv_cache is None and cross_kv is None:
        from repro.kernels import ops as kops

        out = kops.flash_attention(
            q, k, v, causal=causal, window=window, interpret=True
        )
    else:
        chunk = min(1024, max(128, S)) if S >= 128 else S
        out = chunked_attention(
            q, k, v, positions, k_valid, causal=causal, window=window, chunk=chunk
        )

    out = sharding.constrain(out, "batch", None, "model", None)
    out = out.reshape(B, T, H * dh) @ p["wo"].astype(x.dtype)
    return sharding.constrain(out, "batch", None, None), new_cache
