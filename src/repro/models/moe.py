"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

Scalable dispatch (no (tokens × experts × capacity) one-hot einsum): token
assignments are ranked per expert via a cumulative-sum position, dropped
beyond capacity, and scattered into an (experts, capacity, d_model) buffer
that is expert-sharded over the "model" mesh axis (expert parallelism).
GSPMD materializes the token shuffle as all-to-all collectives.

Covers the pool's variants: arctic-480b (128e top-2 + dense residual FFN),
llama4-scout (16e top-1).  A router load-balancing auxiliary loss (Switch
Transformer style) is returned for the training loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import sharding
from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.layers import MODEL, Initializer


def init_moe(init: Initializer, cfg: ModelConfig):
    e = cfg.moe
    D, F = cfg.d_model, e.d_ff_expert
    m = MODEL if cfg.tensor_parallel else None
    if cfg.moe_ep2d:
        # §Perf: 2D expert sharding — experts over the data axis, expert-FFN
        # hidden over the model axis: per-chip expert HBM drops by |data|.
        e_ax, up_spec, down_spec = "batch", ("batch", None, m), ("batch", m, None)
    else:
        e_ax, up_spec, down_spec = m, (m, None, None), (m, None, None)
    p = {
        "router": init.normal((D, e.n_experts), (None, None), dtype="float32"),
        "down": init.normal((e.n_experts, F, D), down_spec),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["gate"] = init.normal((e.n_experts, D, F), up_spec)
        p["up"] = init.normal((e.n_experts, D, F), up_spec)
    else:
        p["up"] = init.normal((e.n_experts, D, F), up_spec)
    if e.dense_residual:
        p["dense"] = layers.init_mlp(init, D, cfg.d_ff, cfg.act, m=m)
    return p


def _expert_ffn(buf, p, act: str):
    """buf: (E, C, D) -> (E, C, D), batched over experts."""
    if act in ("swiglu", "geglu"):
        gate_fn = jax.nn.silu if act == "swiglu" else jax.nn.gelu
        h = gate_fn(jnp.einsum("ecd,edf->ecf", buf, p["gate"].astype(buf.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, p["up"].astype(buf.dtype))
    else:
        h = jnp.einsum("ecd,edf->ecf", buf, p["up"].astype(buf.dtype))
        h = jnp.square(jax.nn.relu(h)) if act == "sqrelu" else jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["down"].astype(buf.dtype))


def moe_layer(x, p, cfg: ModelConfig):
    """x: (B, T, D) -> (out, aux_metrics)."""
    e = cfg.moe
    B, T, D = x.shape
    N = B * T
    K = e.top_k
    E = e.n_experts
    xf = x.reshape(N, D)

    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (N, E)
    gate_w, expert_ids = jax.lax.top_k(probs, K)  # (N, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # Decode steps (T == 1) run dropless: a dropped token at decode time is a
    # corrupted response, and N is small, so worst-case capacity N is cheap.
    if T == 1:
        capacity = N
    else:
        capacity = max(1, int(N * K * e.capacity_factor / E))

    flat_e = expert_ids.reshape(-1)  # (N*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (N*K, E)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1  # rank within expert
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, 0)

    # scatter tokens into the expert-sharded buffer (E, C, D)
    xrep = jnp.repeat(xf, K, axis=0)  # (N*K, D) token per assignment
    contrib = jnp.where(keep[:, None], xrep, 0).astype(cfg.compute_dtype)
    e_ax = "batch" if cfg.moe_ep2d else "expert"
    buf = jnp.zeros((E, capacity, D), cfg.compute_dtype)
    buf = buf.at[flat_e, pos_c].add(contrib, mode="drop")
    buf = sharding.constrain(buf, e_ax, None, None)

    y = _expert_ffn(buf, p, cfg.act)  # (E, C, D)
    y = sharding.constrain(y, e_ax, None, None)

    # gather back and combine with gate weights
    gathered = y[flat_e, pos_c]  # (N*K, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = gate_w.reshape(-1).astype(gathered.dtype)
    out = (gathered * w[:, None]).reshape(N, K, D).sum(axis=1)

    if e.dense_residual:
        out = out + layers.mlp(xf, p["dense"], cfg.act)

    out = out.reshape(B, T, D).astype(x.dtype)

    # Switch-style load-balance aux loss + drop fraction diagnostic
    frac_tokens = jnp.mean(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=(0, 1))
    frac_prob = probs.mean(axis=0)
    aux = {
        "moe_aux_loss": E * jnp.sum(frac_tokens * frac_prob),
        "moe_drop_frac": 1.0 - keep.mean(),
    }
    return out, aux
