"""RWKV6 ("Finch") blocks — attention-free, data-dependent decay.

Time-mix: data-dependent token-shift (ddlerp with rank-32 LoRA) feeding
r/k/v/g/w projections; the WKV6 recurrence keeps a per-head (dh x dh) state
with a *data-dependent per-channel decay* w_t (arXiv:2404.05892).
Channel-mix: squared-ReLU FFN with receptance gating.

Train/prefill run the recurrence as a lax.scan over time (the optimized
chunked form is kernels/linear_scan.py); decode is a single state update —
this is why rwkv6 runs the 500k-context shape in O(1) memory.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import MODEL, Initializer, rms_norm

LORA_RANK = 32
MIX_KEYS = ("r", "k", "v", "g", "w")


def init_rwkv_block(init: Initializer, cfg: ModelConfig):
    D = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = D // dh
    m = MODEL if cfg.tensor_parallel else None
    p = {
        "mu_base": init.normal((D,), (None,), scale=0.02),
        "wr": init.normal((D, D), (None, m)),
        "wk": init.normal((D, D), (None, m)),
        "wv": init.normal((D, D), (None, m)),
        "wg": init.normal((D, D), (None, m)),
        "wo": init.normal((D, D), (m, None)),
        "u": init.normal((H, dh), (m, None), scale=0.02),  # bonus
        "w_bias": init.normal((D,), (None,), scale=0.02),
        "ln_x": init.ones((D,), (None,), dtype="float32"),  # per-head group norm
        # channel mix (squared-ReLU FFN, receptance gated)
        "ffn_k": init.normal((D, cfg.d_ff), (None, m)),
        "ffn_v": init.normal((cfg.d_ff, D), (m, None)),
        "ffn_r": init.normal((D, D), (None, m)),
        "mu_ffn_k": init.normal((D,), (None,), scale=0.02),
        "mu_ffn_r": init.normal((D,), (None,), scale=0.02),
    }
    for z in MIX_KEYS:
        p[f"mu_{z}"] = init.normal((D,), (None,), scale=0.02)
        p[f"lora_a_{z}"] = init.normal((D, LORA_RANK), (None, None), scale=0.02)
        p[f"lora_b_{z}"] = init.normal((LORA_RANK, D), (None, None), scale=0.02)
    return p


class RWKVState(NamedTuple):
    x_prev_att: jax.Array  # (B, D) last token fed to time-mix
    x_prev_ffn: jax.Array  # (B, D)
    wkv: jax.Array  # (B, H, dh, dh) fp32 recurrent state


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> RWKVState:
    D = cfg.d_model
    dh = cfg.rwkv_head_dim
    return RWKVState(
        x_prev_att=jnp.zeros((batch, D), dtype),
        x_prev_ffn=jnp.zeros((batch, D), dtype),
        wkv=jnp.zeros((batch, D // dh, dh, dh), jnp.float32),
    )


def _ddlerp(x, x_prev, p, z: str):
    """Data-dependent lerp between x and the shifted sequence (v6)."""
    xx = x_prev - x
    base = x + xx * p["mu_base"].astype(x.dtype)
    lora = jnp.tanh(base @ p[f"lora_a_{z}"].astype(x.dtype)) @ p[f"lora_b_{z}"].astype(x.dtype)
    return x + xx * (p[f"mu_{z}"].astype(x.dtype) + lora)


def _wkv_scan(r, k, v, w, u, state):
    """The WKV6 recurrence.  r,k,v,w: (B, T, H, dh); state: (B, H, dh, dh).

    y_t = r_t · (S + u ⊙ k_t ⊗ v_t);  S' = diag(w_t)·S + k_t ⊗ v_t
    """
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))

    def step(S, inputs):
        rt, kt, vt, wt = inputs  # (B, H, dh)
        kv = kt[..., :, None] * vt[..., None, :]  # (B, H, dh, dh)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    xs = tuple(a.swapaxes(0, 1) for a in (rf, kf, vf, wf))  # (T, B, H, dh)
    state, ys = jax.lax.scan(step, state, xs)
    return state, ys.swapaxes(0, 1)  # (B, T, H, dh)


def rwkv_block(x, p, cfg: ModelConfig, state: RWKVState = None):
    """x: (B, T, D).  Returns (out, new_state)."""
    B, T, D = x.shape
    dh = cfg.rwkv_head_dim
    H = D // dh

    if state is None:
        state = init_rwkv_state(cfg, B, x.dtype)

    # ---- time mix
    x_shift = jnp.concatenate([state.x_prev_att[:, None, :], x[:, :-1, :]], axis=1)
    r = _ddlerp(x, x_shift, p, "r") @ p["wr"].astype(x.dtype)
    k = _ddlerp(x, x_shift, p, "k") @ p["wk"].astype(x.dtype)
    v = _ddlerp(x, x_shift, p, "v") @ p["wv"].astype(x.dtype)
    g = jax.nn.silu(_ddlerp(x, x_shift, p, "g") @ p["wg"].astype(x.dtype))
    w_lin = _ddlerp(x, x_shift, p, "w") + p["w_bias"].astype(x.dtype)
    # clamp the log-log decay: exp(x) overflows f32 past ~88 and the grad of
    # exp(-exp(x)) becomes inf*0 = NaN; [-8, 4] spans decay in [~0, 0.9997]
    w_lin = jnp.clip(w_lin.astype(jnp.float32), -8.0, 4.0)
    w = jnp.exp(-jnp.exp(w_lin))  # per-channel decay in (0,1)

    hd = lambda a: a.reshape(B, T, H, dh)
    new_wkv, y = _wkv_scan(hd(r), hd(k), hd(v), hd(w), p["u"].astype(jnp.float32),
                           state.wkv)
    y = y.reshape(B, T, D)
    y = rms_norm(y, p["ln_x"])  # group-norm stand-in over channels
    att_out = (y.astype(x.dtype) * g) @ p["wo"].astype(x.dtype)
    h = x + att_out

    # ---- channel mix
    h_shift = jnp.concatenate([state.x_prev_ffn[:, None, :], h[:, :-1, :]], axis=1)
    xx = h_shift - h
    hk = h + xx * p["mu_ffn_k"].astype(h.dtype)
    hr = h + xx * p["mu_ffn_r"].astype(h.dtype)
    kk = jnp.square(jax.nn.relu(hk @ p["ffn_k"].astype(h.dtype)))
    ffn = jax.nn.sigmoid(hr @ p["ffn_r"].astype(h.dtype)) * (kk @ p["ffn_v"].astype(h.dtype))
    out = h + ffn

    new_state = RWKVState(
        x_prev_att=x[:, -1, :], x_prev_ffn=h[:, -1, :], wkv=new_wkv
    )
    return out, new_state
