"""Encoder-decoder backbone (whisper-tiny).

The audio frontend (log-mel + conv downsampling) is a STUB per the
assignment: ``input_specs`` provides precomputed frame embeddings of shape
(B, enc_positions, d_model).  The backbone is faithful: LayerNorm (not
RMSNorm), learned positions, MHA, GELU MLPs, causal decoder with
cross-attention.  whisper-tiny is small (d=384, 6 heads) so it runs
data-parallel only (cfg.tensor_parallel=False): see DESIGN.md §5.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import sharding
from repro.models import layers as L
from repro.models.attention import attention, init_attention, init_kv_cache
from repro.models.config import ModelConfig
from repro.models.layers import Initializer, layer_norm

DEC_POSITIONS = 32_768  # sized for the decode_32k shape


def _init_enc_block(init, cfg):
    return {
        "ln1": L.init_layer_norm(init, cfg.d_model),
        "attn": init_attention(init, cfg),
        "ln2": L.init_layer_norm(init, cfg.d_model),
        "mlp": L.init_mlp(init, cfg.d_model, cfg.d_ff, "gelu", m=None),
    }


def _init_dec_block(init, cfg):
    p = _init_enc_block(init, cfg)
    p["ln_cross"] = L.init_layer_norm(init, cfg.d_model)
    p["cross"] = init_attention(init, cfg)
    return p


def init_params(cfg: ModelConfig, key, abstract: bool = False):
    from repro.models.transformer import VInit

    init = Initializer(key, cfg.param_dtype, abstract=abstract)
    enc_v = VInit(init, cfg.enc_layers)
    dec_v = VInit(init, cfg.n_layers)
    return {
        "enc_pos": init.normal((cfg.enc_positions, cfg.d_model), (None, None),
                               scale=0.02),
        "enc_blocks": _init_enc_block(enc_v, cfg),
        "enc_norm": L.init_layer_norm(init, cfg.d_model),
        "embed": L.init_embedding(init, cfg.vocab, cfg.d_model, shard_vocab=False),
        "dec_pos": init.normal((min(DEC_POSITIONS, cfg.max_seq), cfg.d_model),
                               (None, None), scale=0.02),
        "dec_blocks": _init_dec_block(dec_v, cfg),
        "dec_norm": L.init_layer_norm(init, cfg.d_model),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, S_enc, D) from the frontend stub -> encoder output."""
    h = frames.astype(cfg.compute_dtype)
    h = h + params["enc_pos"][None, : h.shape[1]].astype(h.dtype)
    h = sharding.constrain(h, "batch", None, None)

    def body(h, bp):
        a, _ = attention(layer_norm(h, bp["ln1"]), bp["attn"], cfg, kind="bidir",
                         use_rope=False)
        h = h + a
        h = h + L.mlp(layer_norm(h, bp["ln2"]), bp["mlp"], "gelu")
        return h, None

    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return layer_norm(h, params["enc_norm"])


def _cross_kv(bp, enc_out, cfg):
    B, S, D = enc_out.shape
    k = (enc_out @ bp["cross"]["wk"].astype(enc_out.dtype)).reshape(
        B, S, cfg.n_kv_heads, cfg.d_head)
    v = (enc_out @ bp["cross"]["wv"].astype(enc_out.dtype)).reshape(
        B, S, cfg.n_kv_heads, cfg.d_head)
    return k, v


def decode(params, tokens, enc_out, cfg: ModelConfig, caches=None, cache_len=None):
    """Teacher-forcing (caches None) or incremental decode.

    caches: (kv_caches stacked over layers, precomputed cross K/V) or None.
    Returns (logits, new_caches).
    """
    B, T = tokens.shape
    h = L.embed(tokens, params["embed"]["table"], jnp.dtype(cfg.compute_dtype))
    base = jnp.int32(0) if cache_len is None else cache_len
    pos_idx = base + jnp.arange(T, dtype=jnp.int32)
    h = h + params["dec_pos"].astype(h.dtype)[pos_idx][None]
    positions = jnp.broadcast_to(pos_idx[None, :], (B, T))

    if caches is None:
        def body(h, bp):
            a, _ = attention(layer_norm(h, bp["ln1"]), bp["attn"], cfg, "global",
                             positions, use_rope=False)
            h = h + a
            ck, cv = _cross_kv(bp, enc_out, cfg)
            a, _ = attention(layer_norm(h, bp["ln_cross"]), bp["cross"], cfg,
                             "bidir", positions, cross_kv=(ck, cv), use_rope=False)
            h = h + a
            h = h + L.mlp(layer_norm(h, bp["ln2"]), bp["mlp"], "gelu")
            return h, None

        h, _ = jax.lax.scan(body, h, params["dec_blocks"])
        new_caches = None
    else:
        kv_caches, cross = caches
        # UNROLLED over the (few) decoder layers: scanning stacked KV caches
        # makes GSPMD all-reduce the whole stacked cache per step when the
        # model is replicated (whisper runs DP-only) — see §Perf.
        n_layers = cfg.n_layers
        pick = lambda tree, i: jax.tree_util.tree_map(lambda x: x[i], tree)
        new_kv_layers = []
        for i in range(n_layers):
            bp = pick(params["dec_blocks"], i)
            kvc = pick(kv_caches, i)
            cross_l = pick(cross, i)
            a, kvc = attention(layer_norm(h, bp["ln1"]), bp["attn"], cfg, "global",
                               positions, kv_cache=kvc, use_rope=False)
            h = h + a
            a, _ = attention(layer_norm(h, bp["ln_cross"]), bp["cross"], cfg,
                             "bidir", positions, cross_kv=cross_l, use_rope=False)
            h = h + a
            h = h + L.mlp(layer_norm(h, bp["ln2"]), bp["mlp"], "gelu")
            new_kv_layers.append(kvc)
        new_kv = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *new_kv_layers
        )
        new_caches = (new_kv, cross)

    h = layer_norm(h, params["dec_norm"])
    logits = L.unembed(h, params["embed"]["table"])
    return logits, new_caches


def init_dec_cache(params, enc_out, cfg: ModelConfig, batch: int, max_seq: int):
    """KV caches for incremental decode + precomputed per-layer cross K/V."""
    kv = jax.vmap(
        lambda _: init_kv_cache(batch, max_seq, cfg.n_kv_heads, cfg.d_head,
                                jnp.dtype(cfg.compute_dtype))
    )(jnp.arange(cfg.n_layers))

    def one_layer(bp):
        return _cross_kv(bp, enc_out, cfg)

    cross = jax.vmap(one_layer)(params["dec_blocks"])
    return (kv, cross)


def forward(params, frames, tokens, cfg: ModelConfig):
    """End-to-end teacher forcing: (frames, tokens) -> logits."""
    enc_out = encode(params, frames, cfg)
    logits, _ = decode(params, tokens, enc_out, cfg)
    return logits
