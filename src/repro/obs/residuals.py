"""Live model-vs-measured residual monitoring.

The analytic half of the paper (MVA for closed loops, the M/G/c
decomposition behind :func:`repro.latency.analytic.analyze_open` for
open ones) predicts throughput / response time *for a given profile*.
The :class:`ResidualMonitor` closes the loop at runtime: every window
it compares the measured rate (closed X, or open mean sojourn R)
against the forecast at the currently *estimated* operating point, and
feeds drift detectors with the relative residuals.  Structured
:class:`Alarm` records come out in three kinds:

``model-drift``
    The CUSUM over relative forecast residuals tripped: measured
    behaviour has walked away from the analytic model at the estimated
    operating point (service times shifted, a station saturated in a
    way the model misses, burst arrivals against a Poisson model, ...).
``phase-change``
    The Page-Hinkley test over the estimated hit-ratio stream tripped:
    the workload itself changed regime (popularity churn, ON/OFF
    bursts) — re-estimate the profile before trusting any forecast.
``sketch-saturation``
    The SpaceSaving table's error bound crossed ``saturation_limit`` —
    the estimated masses themselves are suspect; widen ``sketch_cap``.

The monitor is plain host-side Python (it consumes decoded
:class:`repro.obs.streaming.SketchEstimates`, not kernel state) and is
surfaced through ``Engine.telemetry()``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.queueing import ClosedNetwork
from repro.latency.analytic import analyze_open
from repro.obs.drift import Cusum, PageHinkley

__all__ = ["Alarm", "ResidualMonitor"]


@dataclasses.dataclass(frozen=True)
class Alarm:
    """One structured monitor alarm.

    ``kind`` is one of ``model-drift`` / ``phase-change`` /
    ``sketch-saturation``; ``measured`` / ``expected`` give the pair
    that tripped it (hit ratio for phase changes, X or R for model
    drift, the saturation fraction and its limit for saturation) and
    ``score`` the detector statistic at the alarm."""

    kind: str
    window_id: int
    measured: float
    expected: float
    score: float
    detail: str = ""

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ResidualMonitor:
    """Window-by-window model-vs-measured comparison with drift alarms.

    ``mode="closed"`` forecasts throughput ``X = net.mva_throughput(p)``
    and compares against the measured windowed completion rate;
    ``mode="open"`` forecasts the mean sojourn ``R`` via
    :func:`analyze_open` at the measured windowed arrival rate.  Both
    feed the *relative* residual ``(measured - expected) / expected``
    to a CUSUM; the estimated hit-ratio stream feeds a Page-Hinkley
    test.  Alarms accumulate on :attr:`alarms`.
    """

    def __init__(self, net: ClosedNetwork, mode: str = "closed",
                 tail_mode: str = "nominal",
                 resid_k: float = 0.02, resid_h: float = 0.25,
                 phase_delta: float = 0.005, phase_lam: float = 0.08,
                 warmup: int = 8, saturation_limit: float = 0.05):
        if mode not in ("closed", "open"):
            raise ValueError(f"mode must be 'closed' or 'open', got {mode!r}")
        self.net = net
        self.mode = mode
        self.tail_mode = tail_mode
        self.saturation_limit = float(saturation_limit)
        self.resid_cusum = Cusum(k_slack=resid_k, h_threshold=resid_h,
                                 warmup=warmup)
        self.phase_ph = PageHinkley(delta_slack=phase_delta,
                                    lam_threshold=phase_lam, warmup=warmup)
        self.alarms: list = []
        self._saturated = False

    def expected(self, p_hat: float, arrival_rate: float | None = None
                 ) -> float:
        """Model forecast at the estimated operating point: closed
        throughput (per µs) or open mean sojourn (µs)."""
        p = float(np.clip(p_hat, 0.0, 0.999))
        if self.mode == "closed":
            return float(self.net.mva_throughput(p))
        if arrival_rate is None or not np.isfinite(arrival_rate):
            return float("nan")
        return float(analyze_open(self.net, p, float(arrival_rate),
                                  tail_mode=self.tail_mode).mean)

    def observe(self, window_id: int, p_hat: float,
                measured: float, arrival_rate: float | None = None,
                saturation_frac: float = 0.0) -> list:
        """Feed one window; returns the alarms it raised (also kept on
        :attr:`alarms`).  ``measured`` is the windowed completion rate
        (closed) or mean sojourn (open)."""
        out = []
        if np.isfinite(p_hat) and self.phase_ph.update(p_hat):
            out.append(Alarm(
                kind="phase-change", window_id=int(window_id),
                measured=float(p_hat), expected=float(self.phase_ph.mean),
                score=float(self.phase_ph.lam_threshold),
                detail="estimated hit ratio changed regime"))
        exp = self.expected(p_hat, arrival_rate)
        if np.isfinite(exp) and exp > 0 and np.isfinite(measured):
            resid = (float(measured) - exp) / exp
            if self.resid_cusum.update(resid):
                out.append(Alarm(
                    kind="model-drift", window_id=int(window_id),
                    measured=float(measured), expected=exp,
                    score=float(resid),
                    detail=f"{self.mode} forecast residual tripped CUSUM"))
        if saturation_frac > self.saturation_limit and not self._saturated:
            self._saturated = True
            out.append(Alarm(
                kind="sketch-saturation", window_id=int(window_id),
                measured=float(saturation_frac),
                expected=self.saturation_limit,
                score=float(saturation_frac),
                detail="SpaceSaving error bound exceeded the limit; "
                       "estimated masses are suspect"))
        elif saturation_frac <= self.saturation_limit:
            self._saturated = False
        self.alarms.extend(out)
        return out

    def run(self, window_ids, p_hats, measured, arrival_rates=None,
            saturation_frac: float = 0.0) -> list:
        """Feed a whole series of windows; returns all alarms raised."""
        window_ids = np.asarray(window_ids)
        p_hats = np.asarray(p_hats, float)
        measured = np.asarray(measured, float)
        if arrival_rates is None:
            arrival_rates = np.full(len(window_ids), np.nan)
        arrival_rates = np.asarray(arrival_rates, float)
        out = []
        for i in range(len(window_ids)):
            out.extend(self.observe(
                int(window_ids[i]), float(p_hats[i]), float(measured[i]),
                arrival_rate=float(arrival_rates[i]),
                saturation_frac=saturation_frac))
        return out
