"""Chrome / Perfetto ``trace_event`` JSON export for decoded traces.

Renders :class:`~repro.obs.trace.TraceRecords` as complete-duration
(``ph="X"``) slices — one per station visit, plus one ``mshr_park``
slice per delayed hit — in the JSON object format Perfetto and
``chrome://tracing`` both accept.  Timestamps are microseconds, matching
the simulators' absolute ``elapsed_us`` clock, so slice positions are
the simulation timeline verbatim.

Stations map to Perfetto "threads" (one lane per station) inside a
single "process" (one simulated node/lane); request id, branch and
sojourn class ride along in ``args`` for querying.
"""

from __future__ import annotations

import json

from repro.obs.trace import CLASS_NAMES, TraceRecords


def to_perfetto(
    trace: TraceRecords,
    station_names=None,
    pid: int = 0,
    process_name: str = "repro-sim",
) -> dict:
    """Render a trace as a ``{"traceEvents": [...]}`` Perfetto object."""
    events: list[dict] = []
    events.append(
        {
            "name": "process_name",
            "ph": "M",
            "pid": pid,
            "tid": 0,
            "args": {"name": process_name},
        }
    )
    seen_tids = set()

    def thread_meta(tid: int, name: str) -> None:
        if tid in seen_tids:
            return
        seen_tids.add(tid)
        events.append(
            {
                "name": "thread_name",
                "ph": "M",
                "pid": pid,
                "tid": tid,
                "args": {"name": name},
            }
        )

    n = len(trace)
    for i in range(n):
        nvis = int(trace.nvis[i])
        cls = int(trace.cls[i])
        args = {
            "req": int(trace.req[i]),
            "branch": int(trace.branch[i]),
            "cls": CLASS_NAMES.get(cls, str(cls)),
        }
        for v in range(nvis):
            st = int(trace.station[i, v])
            tid = st if st >= 0 else 10_000 + v
            if station_names is not None and 0 <= st < len(station_names):
                thread_meta(tid, str(station_names[st]))
            else:
                thread_meta(tid, f"station-{tid}")
            ts = float(trace.enter_us[i, v])
            dur = float(trace.leave_us[i, v]) - ts
            events.append(
                {
                    "name": (
                        str(station_names[st])
                        if station_names is not None
                        and 0 <= st < len(station_names)
                        else f"visit-{v}"
                    ),
                    "cat": "visit",
                    "ph": "X",
                    "pid": pid,
                    "tid": tid,
                    "ts": ts,
                    "dur": max(dur, 0.0),
                    "args": args,
                }
            )
        parked_us = float(trace.parked_us[i])
        if parked_us > 0.0 and nvis > 0:
            # The park interval is the tail of the last (park) visit.
            st = int(trace.station[i, nvis - 1])
            tid = st if st >= 0 else 10_000 + nvis - 1
            end = float(trace.leave_us[i, nvis - 1])
            events.append(
                {
                    "name": "mshr_park",
                    "cat": "mshr",
                    "ph": "X",
                    "pid": pid,
                    "tid": tid,
                    "ts": end - parked_us,
                    "dur": parked_us,
                    "args": args,
                }
            )
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_perfetto(path, trace: TraceRecords, station_names=None, **kw) -> dict:
    obj = to_perfetto(trace, station_names=station_names, **kw)
    with open(path, "w") as fh:
        json.dump(obj, fh)
    return obj


def read_perfetto(path) -> dict:
    with open(path) as fh:
        return json.load(fh)


def summarize_events(obj: dict) -> dict:
    """Round-trip check summary: slice counts/durations by category & class."""
    slices = [e for e in obj.get("traceEvents", []) if e.get("ph") == "X"]
    by_cat: dict[str, int] = {}
    by_cls: dict[str, int] = {}
    total_dur_us = 0.0
    reqs = set()
    for e in slices:
        by_cat[e.get("cat", "?")] = by_cat.get(e.get("cat", "?"), 0) + 1
        total_dur_us += float(e.get("dur", 0.0))
        args = e.get("args", {})
        if "req" in args:
            reqs.add(int(args["req"]))
        if e.get("cat") == "visit" and "cls" in args:
            by_cls[args["cls"]] = by_cls.get(args["cls"], 0)
    # Count classes once per request, not per slice.
    cls_per_req: dict[int, str] = {}
    for e in slices:
        args = e.get("args", {})
        if e.get("cat") == "visit" and "req" in args and "cls" in args:
            cls_per_req[int(args["req"])] = args["cls"]
    for c in by_cls:
        by_cls[c] = sum(1 for v in cls_per_req.values() if v == c)
    return {
        "slices_count": len(slices),
        "requests_count": len(reqs),
        "total_dur_us": total_dur_us,
        "by_cat_count": by_cat,
        "by_cls_count": by_cls,
    }
