"""Online measured-profile recovery from streaming-sketch estimates.

The observation half of the ROADMAP item 4 control loop: decoded
:class:`repro.obs.streaming.SketchEstimates` (top-k key counts + the
windowed / EWMA rate estimators) are turned into the same profile
objects the offline Mattson-sweep path produces — a cap → hit-ratio
curve (:class:`ObservedProfile`), a cluster
:class:`repro.cluster.model.ShardProfile`, or a hierarchy
:class:`repro.hierarchy.model.TieredProfile` — with **no sweep**: the
recovered popularity masses feed the Che approximation directly.

This module sits *above* the cluster / hierarchy model layers, unlike
:mod:`repro.obs.streaming` itself, which stays kernel-side (imported by
``repro.core.simulator``) and must not close an import cycle back
through those packages.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.cluster.model import ShardProfile, _default_caps
from repro.hierarchy.model import che_hit, tiered_profile
from repro.obs.streaming import SketchEstimates

__all__ = [
    "ObservedProfile", "estimate_key_masses", "observed_profile",
    "observed_shard_profile", "observed_tiered_profile",
]


def estimate_key_masses(est: SketchEstimates, key_space: int | None = None,
                        ) -> np.ndarray:
    """Recover a normalized key-popularity mass vector from decoded
    sketch estimates.

    Top-k keys get their SpaceSaving lower-bound share
    ``(count - err) / key_count`` (exact share on the exact twin); the
    residual mass is spread over the unseen keys as a Zipf tail whose
    exponent is fitted to the observed head (log count vs log rank).
    Which unseen id gets which tail rank is arbitrary (ascending id
    order) — irrelevant for cap → hit curves, and hash-random with
    respect to any shard assignment.

    ``key_space=None`` sizes the universe to the observed keys only (no
    tail) — the serving engine's unbounded chunk-hash space.
    """
    keys, counts, errs = est.topk()
    total = max(est.key_count, 1)
    lb = np.maximum(counts.astype(np.float64) - errs, 1.0)
    if key_space is None:
        masses = np.zeros(len(keys))
        masses[np.arange(len(keys))] = lb
        return masses / masses.sum() if len(masses) else masses
    masses = np.zeros(int(key_space))
    seen = keys[keys < key_space]
    masses[seen] = lb[: len(seen)] / total
    residual = max(1.0 - masses.sum(), 0.0)
    cold = np.flatnonzero(masses == 0)
    if residual > 0 and len(cold):
        k = len(seen)
        if k >= 4:
            ranks = np.arange(1, k + 1, dtype=np.float64)
            theta = -np.polyfit(np.log(ranks), np.log(lb[:k]), 1)[0]
            theta = float(np.clip(theta, 0.0, 3.0))
        else:
            theta = 1.0
        tail = np.arange(k + 1, k + 1 + len(cold),
                         dtype=np.float64) ** (-theta)
        masses[cold] = residual * tail / tail.sum()
    s = masses.sum()
    return masses / s if s > 0 else masses


@dataclasses.dataclass(frozen=True)
class ObservedProfile:
    """Online measured profile — produced with no Mattson sweep.

    ``hit_curve[i]`` is the Che-approximation hit ratio of an LRU cache
    of ``caps[i]`` keys under the estimated ``masses``; ``hit_frac`` /
    ``delayed_frac`` are the debiased EWMA *measured* fractions;
    ``arrival_rate`` is the latest windowed arrival rate (NaN for
    closed-loop streams); ``saturation_frac`` carries the sketch
    pressure the residual monitor alarms on."""

    caps: np.ndarray  # (C,) cache capacities (keys)
    hit_curve: np.ndarray  # (C,) Che hit ratio per capacity
    masses: np.ndarray  # (N,) estimated key-popularity masses
    hit_frac: float  # measured (EWMA, debiased), NaN before data
    delayed_frac: float
    arrival_rate: float  # per µs, NaN for closed-loop streams
    key_count: int
    saturation_frac: float

    def p_of_cap(self, cap: float) -> float:
        """Estimated hit ratio at capacity ``cap`` (interpolated)."""
        return float(np.interp(cap, self.caps, self.hit_curve))

    def cap_of_p(self, p: float) -> float:
        """Smallest capacity achieving hit ratio ``p`` (interpolated;
        clipped to the achievable range)."""
        return float(np.interp(p, self.hit_curve, self.caps))

    def p_range(self) -> tuple:
        """(min, max) achievable hit ratio over the cap grid."""
        return float(self.hit_curve[0]), float(self.hit_curve[-1])

    def shard_profile(self, assign, caps=None,
                      n_shards: int | None = None) -> ShardProfile:
        """Lift to a cluster :class:`repro.cluster.model.ShardProfile`
        through ``assign``."""
        return observed_shard_profile(self.masses, assign, caps=caps,
                                      n_shards=n_shards)

    def tiered(self, l1_caps, l2_cap: float, assign,
               n_shards: int | None = None):
        """Lift to a hierarchy :class:`repro.hierarchy.model.TieredProfile`
        (Che at L1 and at the L1-filtered L2 shards — same path as the
        offline builder)."""
        return observed_tiered_profile(self.masses, l1_caps, l2_cap,
                                       assign, n_shards=n_shards)


def _che_curve(masses: np.ndarray, caps: np.ndarray) -> np.ndarray:
    return np.array([float(masses @ che_hit(masses, float(c)))
                     for c in caps])


def observed_profile(est: SketchEstimates, key_space: int | None = None,
                     caps=None) -> ObservedProfile:
    """Build the online :class:`ObservedProfile` from decoded sketch
    estimates: recovered masses -> Che cap → hit curve + the measured
    EWMA fractions and latest windowed arrival rate."""
    masses = estimate_key_masses(est, key_space)
    if caps is None:
        caps = _default_caps(max(len(masses), 1))
    caps = np.asarray(caps, np.float64)
    rate = (float(est.win_arrival_rate[-1])
            if len(est.win_arrival_rate) else float("nan"))
    return ObservedProfile(
        caps=caps,
        hit_curve=_che_curve(masses, caps),
        masses=masses,
        hit_frac=est.ewma_hit_frac,
        delayed_frac=est.ewma_delayed_frac,
        arrival_rate=rate,
        key_count=est.key_count,
        saturation_frac=est.saturation_frac(),
    )


def observed_shard_profile(masses, assign, caps=None,
                           n_shards: int | None = None) -> ShardProfile:
    """Che-approximation :class:`repro.cluster.model.ShardProfile` from
    estimated masses — the online analogue of
    :func:`repro.cluster.model.ideal_shard_profile` (which stacks exact
    cumulative mass instead of Che occupancy)."""
    masses = np.asarray(masses, np.float64)
    assign = np.asarray(assign)
    N = int(n_shards if n_shards is not None else assign.max() + 1)
    weights = np.array([masses[assign == k].sum() for k in range(N)])
    weights = weights / weights.sum()
    if caps is None:
        caps = _default_caps(int(max((assign == k).sum()
                                     for k in range(N))))
    caps = np.asarray(caps, np.float64)
    shard_hit = np.zeros((N, len(caps)))
    for k in range(N):
        cond = masses[assign == k]
        tot = cond.sum()
        if tot <= 0:
            continue
        cond = cond / tot
        shard_hit[k] = _che_curve(cond, caps)
    shard_hit = np.maximum.accumulate(shard_hit, axis=1)
    return ShardProfile(weights=weights, caps=caps, shard_hit=shard_hit)


def observed_tiered_profile(masses, l1_caps, l2_cap: float, assign,
                            n_shards: int | None = None):
    """Online :class:`repro.hierarchy.model.TieredProfile` from estimated
    masses (delegates to the offline Che builder — same math, streamed
    inputs)."""
    return tiered_profile(masses, l1_caps, l2_cap, assign,
                          n_shards=n_shards)
