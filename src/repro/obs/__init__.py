"""Observability layer: per-request trace records, per-station timelines,
and provenance-stamped bench lineage.

The paper's second prong is *implementation and measurement*: its
throughput-vs-hit-ratio inversions were found by instrumenting a real
cache.  This package is that instrument for the reproduction:

* :mod:`repro.obs.trace` — the structured per-request trace-record
  schema (request id, class, per-station enter/leave timestamps, MSHR
  parked interval) plus the fixed-capacity ring-buffer helpers the
  jitted simulators fill in-kernel and the collector the heapq oracles
  use, so trace equality is a differential twin contract.
* :mod:`repro.obs.metrics` — a small registry (counters, gauges,
  distribution sketches, unit-suffixed names) and the trace-derived
  per-station occupancy/utilization timelines and busy-period (convoy)
  statistics.
* :mod:`repro.obs.export` — Chrome/Perfetto ``trace_event`` JSON
  rendering for timeline inspection.
* :mod:`repro.obs.provenance` — git-sha / version / seed / config-hash
  stamping of ``benchmarks/run.py --json`` payloads, payload schema
  validation and the BENCH lineage diff.
* :mod:`repro.obs.streaming` — fixed-shape in-kernel streaming
  estimators (windowed/EWMA rates, count-min + SpaceSaving popularity
  sketch) threaded through the simulators behind ``sketch_cap=0``.
* :mod:`repro.obs.drift` — CUSUM / Page-Hinkley sequential change
  detectors over the estimator series.
* :mod:`repro.obs.profile` / :mod:`repro.obs.residuals` — online
  measured-profile recovery (sketch → Che cap→hit curve) and the
  model-vs-measured residual monitor.  These two sit *above* the
  cluster / hierarchy / latency layers and are therefore imported
  directly, not re-exported here (the package ``__init__`` must stay
  importable from ``repro.core.simulator``).

Tracing is **off by default** and bit-identical to the untraced
simulators when off; when on, every ring-buffer capacity is a static
(Python-int) shape so the compiled programs stay shape-static
(``tools/analysis/obs_lint.py`` gates this).
"""

from __future__ import annotations

from repro.obs.drift import Cusum, PageHinkley, cusum_scan, page_hinkley_scan
from repro.obs.metrics import DistSketch, Metrics
from repro.obs.streaming import (PyStreamSketch, SketchEstimates,
                                 sketch_trace, sketch_trace_py)
from repro.obs.trace import TraceRecords, make_records, trace_from_rings

__all__ = [
    "Cusum",
    "DistSketch",
    "Metrics",
    "PageHinkley",
    "PyStreamSketch",
    "SketchEstimates",
    "TraceRecords",
    "cusum_scan",
    "make_records",
    "page_hinkley_scan",
    "sketch_trace",
    "sketch_trace_py",
    "trace_from_rings",
]
