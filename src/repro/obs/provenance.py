"""Provenance stamping and lineage checks for BENCH_*.json artifacts.

Every ``benchmarks/run.py --json`` payload gains a ``provenance`` block:
git sha (+dirty flag), python/numpy/jax/jaxlib versions, the jax
backend, the seeds in play, the wall/compile-time split collected by
``benchmarks.common.compile_monitor``, and a content hash of the
producing config — so artifacts uploaded across PRs form a comparable
lineage.

The module doubles as a CLI used by the CI ``bench-artifacts`` job::

    python -m repro.obs.provenance check BENCH.json --expect benchmarks/expected_series.json
    python -m repro.obs.provenance diff OLD.json NEW.json

``check`` validates the payload schema (provenance present and
well-formed, failures mapped to tracebacks) and fails loudly if any
series named in the guard list is missing; ``diff`` prints the
added/removed series between two payloads and exits non-zero on a loss.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import platform
import subprocess
import sys

SCHEMA_VERSION = "repro.obs.provenance/v1"

#: Payload keys that are bookkeeping, not result series.
META_KEYS = {"bench_seconds", "bench_timings", "failures", "provenance"}

REQUIRED_PROVENANCE_KEYS = (
    "schema",
    "git_sha",
    "git_dirty",
    "versions",
    "backend",
    "seeds",
    "config_sha256",
)


def _repo_root() -> str:
    d = os.path.dirname(os.path.abspath(__file__))
    while d != os.path.dirname(d):
        if os.path.isdir(os.path.join(d, ".git")):
            return d
        d = os.path.dirname(d)
    return os.getcwd()


def _git(*args: str) -> str | None:
    try:
        out = subprocess.run(
            ["git", *args],
            cwd=_repo_root(),
            capture_output=True,
            text=True,
            timeout=10,
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    if out.returncode != 0:
        return None
    return out.stdout.strip()


def config_hash(config) -> str:
    """sha256 of the canonical-JSON form of the producing config."""
    blob = json.dumps(config, sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode()).hexdigest()


def collect(config=None, seeds=None, timings=None) -> dict:
    """Gather the provenance block (deterministic under a fixed config)."""
    versions = {"python": platform.python_version()}
    backend = "unknown"
    try:
        import numpy

        versions["numpy"] = numpy.__version__
    except Exception:  # pragma: no cover - numpy is a hard dep in practice
        pass
    try:
        import jax
        import jaxlib

        versions["jax"] = jax.__version__
        versions["jaxlib"] = jaxlib.__version__
        backend = jax.default_backend()
    except Exception:
        pass
    prov = {
        "schema": SCHEMA_VERSION,
        "git_sha": _git("rev-parse", "HEAD") or "unknown",
        "git_dirty": bool(_git("status", "--porcelain") or ""),
        "versions": versions,
        "backend": backend,
        "seeds": list(seeds) if seeds is not None else [],
        "config_sha256": config_hash(config if config is not None else {}),
    }
    if timings is not None:
        prov["timings"] = dict(timings)
    return prov


def stamp(payload: dict, config=None, seeds=None, timings=None) -> dict:
    """Attach a provenance block to a bench payload (in place) and return it."""
    payload["provenance"] = collect(config=config, seeds=seeds, timings=timings)
    return payload


def series_keys(payload: dict) -> list[str]:
    """Result-series names in a payload (top-level keys minus bookkeeping)."""
    return sorted(k for k in payload if k not in META_KEYS)


def validate_payload(payload: dict) -> list[str]:
    """Schema check for a stamped bench payload; returns problem strings."""
    problems: list[str] = []
    prov = payload.get("provenance")
    if not isinstance(prov, dict):
        problems.append("missing provenance block")
    else:
        for key in REQUIRED_PROVENANCE_KEYS:
            if key not in prov:
                problems.append(f"provenance missing key {key!r}")
        if prov.get("schema") not in (None, SCHEMA_VERSION):
            problems.append(
                f"provenance schema {prov.get('schema')!r} != {SCHEMA_VERSION!r}"
            )
    failures = payload.get("failures")
    if failures is not None and not isinstance(failures, dict):
        problems.append(
            "failures must map bench name -> traceback string "
            f"(got {type(failures).__name__})"
        )
    if isinstance(failures, dict):
        for name, tb in failures.items():
            if not isinstance(tb, str) or not tb:
                problems.append(f"failure {name!r} lacks a traceback")
    if not series_keys(payload) and not failures:
        problems.append("payload has no result series and no failures")
    return problems


def lineage_diff(old: dict, new: dict) -> dict:
    """Series-level diff between two payloads: what appeared / vanished."""
    old_keys = set(series_keys(old))
    new_keys = set(series_keys(new))
    return {
        "added": sorted(new_keys - old_keys),
        "removed": sorted(old_keys - new_keys),
    }


def _load(path: str) -> dict:
    with open(path) as fh:
        return json.load(fh)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.obs.provenance")
    sub = parser.add_subparsers(dest="cmd", required=True)
    p_check = sub.add_parser("check", help="validate a stamped bench payload")
    p_check.add_argument("payload")
    p_check.add_argument(
        "--expect",
        default=None,
        help="JSON file: {artifact-name: [required series...]} guard list",
    )
    p_diff = sub.add_parser("diff", help="series lineage diff old -> new")
    p_diff.add_argument("old")
    p_diff.add_argument("new")
    args = parser.parse_args(argv)

    if args.cmd == "check":
        payload = _load(args.payload)
        problems = validate_payload(payload)
        if args.expect:
            guard = _load(args.expect)
            name = os.path.basename(args.payload)
            required = guard.get(name, guard.get("*", []))
            present = set(series_keys(payload))
            for series in required:
                if series not in present:
                    problems.append(
                        f"guarded series {series!r} missing from {name}"
                    )
        for p in problems:
            print(f"provenance-check: {args.payload}: {p}", file=sys.stderr)
        if not problems:
            print(
                f"provenance-check: {args.payload}: ok "
                f"({len(series_keys(payload))} series)"
            )
        return 1 if problems else 0

    diff = lineage_diff(_load(args.old), _load(args.new))
    print(json.dumps(diff, indent=2))
    if diff["removed"]:
        print(
            f"lineage-diff: series removed: {diff['removed']}", file=sys.stderr
        )
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
