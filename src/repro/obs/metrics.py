"""Metric registry and trace-derived station timelines.

Two halves:

* :class:`Metrics` — a tiny process-local registry of counters, gauges
  and log-bucketed distribution sketches.  Every metric name must carry
  one of the repo's established unit suffixes (``_us``, ``_rate``,
  ``_count``, …) — enforced here at registration time and statically by
  ``tools/analysis/obs_lint.py``.
* timeline functions — per-station occupancy/utilization step functions
  and busy-period (convoy) statistics computed from decoded
  :class:`~repro.obs.trace.TraceRecords`.  These give the first direct
  measurement of the PR-8 convoy regime: a fill-synchronized convoy is
  a long busy period with high mean occupancy at the disk station.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.obs.trace import TraceRecords

#: Allowed metric-name unit suffixes.  Time units match tools/analysis/
#: units_lint.py; the dimensionless tails make intent explicit.
UNIT_SUFFIXES = (
    "_ns",
    "_us",
    "_ms",
    "_s",
    "_rate",
    "_count",
    "_frac",
    "_ratio",
    "_bytes",
)


def check_metric_name(name: str) -> str:
    if not name.endswith(UNIT_SUFFIXES):
        raise ValueError(
            f"metric name {name!r} lacks a unit suffix; expected one of "
            f"{UNIT_SUFFIXES}"
        )
    return name


@dataclasses.dataclass
class DistSketch:
    """Log-bucketed distribution sketch (count/sum/min/max + histogram)."""

    lo: float = 1e-3
    hi: float = 1e7
    bins: int = 64

    def __post_init__(self) -> None:
        self.counts = np.zeros(self.bins + 2, dtype=np.int64)
        self.n_count = 0
        self.total = 0.0
        self.min_v = math.inf
        self.max_v = -math.inf
        self._log_lo = math.log(self.lo)
        self._log_hi = math.log(self.hi)

    def _bucket(self, x: float) -> int:
        if x < self.lo:
            return 0
        if x >= self.hi:
            return self.bins + 1
        frac = (math.log(x) - self._log_lo) / (self._log_hi - self._log_lo)
        return 1 + min(self.bins - 1, int(frac * self.bins))

    def add(self, x: float) -> None:
        x = float(x)
        self.counts[self._bucket(x)] += 1
        self.n_count += 1
        self.total += x
        self.min_v = min(self.min_v, x)
        self.max_v = max(self.max_v, x)

    def extend(self, xs) -> None:
        for x in np.asarray(xs).ravel():
            self.add(x)

    @property
    def mean(self) -> float:
        return self.total / self.n_count if self.n_count else math.nan

    def quantile(self, q: float) -> float:
        """Bucket-upper-edge quantile estimate (exact for min/max ends)."""
        if self.n_count == 0:
            return math.nan
        if q <= 0.0:
            return self.min_v
        if q >= 1.0:
            return self.max_v
        target = q * self.n_count
        seen = 0
        for b, c in enumerate(self.counts):
            seen += int(c)
            if seen >= target:
                if b == 0:
                    return self.lo
                if b == self.bins + 1:
                    return self.max_v
                frac = b / self.bins
                return math.exp(
                    self._log_lo + frac * (self._log_hi - self._log_lo)
                )
        return self.max_v

    def snapshot(self) -> dict:
        return {
            "count": int(self.n_count),
            "sum": float(self.total),
            "min": float(self.min_v) if self.n_count else None,
            "max": float(self.max_v) if self.n_count else None,
            "mean": float(self.mean) if self.n_count else None,
            "p50": float(self.quantile(0.5)) if self.n_count else None,
            "p99": float(self.quantile(0.99)) if self.n_count else None,
        }


class Metrics:
    """Process-local registry of unit-suffixed counters/gauges/sketches."""

    def __init__(self) -> None:
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._dists: dict[str, DistSketch] = {}

    def count(self, name: str, inc: float = 1) -> None:
        check_metric_name(name)
        self._counters[name] = self._counters.get(name, 0) + inc

    def gauge(self, name: str, value: float) -> None:
        check_metric_name(name)
        self._gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        check_metric_name(name)
        if name not in self._dists:
            self._dists[name] = DistSketch()
        self._dists[name].add(value)

    def snapshot(self) -> dict:
        return {
            "counters": dict(self._counters),
            "gauges": dict(self._gauges),
            "dists": {k: d.snapshot() for k, d in self._dists.items()},
        }


# ---------------------------------------------------------------------------
# Trace-derived timelines
# ---------------------------------------------------------------------------


def visit_intervals(trace: TraceRecords):
    """Flatten a trace into (station, t_enter_us, t_leave_us) interval arrays.

    Only real visits (col < nvis, station >= 0) are kept.  The MSHR
    parked tail of a delayed hit is part of its park visit's interval.
    """
    mask = ~np.isnan(trace.enter_us) & ~np.isnan(trace.leave_us)
    mask &= trace.station >= 0 if trace.station.size else mask
    station = trace.station[mask]
    t_enter_us = trace.enter_us[mask]
    t_leave_us = trace.leave_us[mask]
    return station, t_enter_us, t_leave_us


def occupancy_timeline(trace: TraceRecords, station: int):
    """Step-function occupancy at one station: (times_us, occupancy_count).

    ``occupancy_count[i]`` holds on ``[times_us[i], times_us[i+1])``.
    Counts jobs present (queued + in service + parked) at the station.
    """
    st, enter_us, leave_us = visit_intervals(trace)
    sel = st == station
    edges = np.concatenate([enter_us[sel], leave_us[sel]])
    deltas = np.concatenate(
        [np.ones(sel.sum(), dtype=np.int64), -np.ones(sel.sum(), dtype=np.int64)]
    )
    order = np.argsort(edges, kind="stable")
    times_us = edges[order]
    occupancy_count = np.cumsum(deltas[order])
    return times_us, occupancy_count


def station_utilization(trace: TraceRecords, n_stations: int) -> dict:
    """Per-station busy-time fraction and time-averaged occupancy.

    Measured over the trace's own span ``[min enter, max leave]``.
    Returns ``{station: {"busy_frac", "mean_occupancy_count", "span_us"}}``.
    """
    st, enter_us, leave_us = visit_intervals(trace)
    if enter_us.size == 0:
        return {}
    t0 = float(enter_us.min())
    t1 = float(leave_us.max())
    span_us = max(t1 - t0, 1e-9)
    out = {}
    for k in range(n_stations):
        times_us, occ = occupancy_timeline(trace, k)
        if times_us.size == 0:
            continue
        widths = np.diff(times_us)
        occ_steps = occ[:-1]
        busy_us = float(widths[occ_steps > 0].sum())
        occ_time = float((widths * occ_steps).sum())
        out[k] = {
            "busy_frac": busy_us / span_us,
            "mean_occupancy_count": occ_time / span_us,
            "span_us": span_us,
        }
    return out


def busy_periods(trace: TraceRecords, station: int) -> np.ndarray:
    """Durations (µs) of maximal occupancy>0 intervals at one station."""
    times_us, occ = occupancy_timeline(trace, station)
    if times_us.size == 0:
        return np.zeros(0)
    periods = []
    start = None
    for i in range(len(times_us)):
        if occ[i] > 0 and start is None:
            start = times_us[i]
        elif occ[i] == 0 and start is not None:
            periods.append(times_us[i] - start)
            start = None
    if start is not None:
        periods.append(times_us[-1] - start)
    return np.asarray(periods)


def convoy_stats(trace: TraceRecords, station: int) -> dict:
    """Busy-period (convoy) summary at one station.

    A fill-synchronized convoy (PR 8) shows up as a small number of long
    busy periods that together cover most of the span.
    """
    periods_us = busy_periods(trace, station)
    if periods_us.size == 0:
        return {
            "n_count": 0,
            "mean_us": math.nan,
            "max_us": math.nan,
            "total_us": 0.0,
        }
    return {
        "n_count": int(periods_us.size),
        "mean_us": float(periods_us.mean()),
        "max_us": float(periods_us.max()),
        "total_us": float(periods_us.sum()),
    }


def trace_summary(trace: TraceRecords, n_stations: int | None = None) -> dict:
    """One-call rollup used by benches: classes, sojourns, utilization."""
    out: dict = {
        "records_count": len(trace),
        "emitted_count": trace.n_emitted,
        "dropped_count": trace.n_dropped,
        "classes_count": trace.class_counts(),
    }
    if len(trace):
        soj = trace.sojourn_us
        out["sojourn_mean_us"] = float(soj.mean())
        out["sojourn_max_us"] = float(soj.max())
        out["parked_mean_us"] = float(trace.parked_us.mean())
    if n_stations:
        out["stations"] = {
            str(k): v for k, v in station_utilization(trace, n_stations).items()
        }
    return out
