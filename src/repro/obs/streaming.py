"""Streaming observability: fixed-shape, scan-friendly estimators that
run *inside* the request loop.

Every measured profile in the repo before this module was an offline
artifact — a full Mattson sweep or a completed trace decode.  This
module is the online instrument: estimators whose state is a small,
shape-static pytree (:class:`SketchState`) updated once per simulator
event, so they ride inside the jitted ``lax.while_loop`` kernels (and
the heapq oracles) behind a ``sketch_cap=0`` flag that is bit-identical
off (state is ``()`` — a pytree with no leaves — so the compiled HLO is
unchanged).

Three estimator families share the state:

* **Windowed + EWMA rates** — a tumbling ring of ``N_WINDOWS`` windows
  of ``window_us`` each (completion / hit / delayed-hit / arrival
  counts, per-branch completion counts for shard heat), plus
  exponentially-weighted hit/delayed fractions with an explicit debias
  norm (``(1 - alpha)^n``).  Ring rows store their absolute window id,
  so stale rows are zeroed lazily on first touch — no per-window flush.
* **Key-popularity sketch** — a count-min sketch (``CM_DEPTH`` rows of
  deterministic integer hashes; overestimate-only by construction) and
  a SpaceSaving top-k table (``sketch_cap`` slots; every count is an
  upper bound and ``count - err`` a lower bound).  Recovered top-k
  masses plus a fitted Zipf tail feed the Che approximation to produce
  an **online measured profile** with no Mattson sweep — that recovery
  layer lives in :mod:`repro.obs.profile` (it imports the cluster /
  hierarchy model types, which this kernel-side module must not).
* **Per-shard heat gauges** — per-branch windowed completion rates fold
  to per-shard heat / imbalance via the model's branch → shard map.

Both masked-update tricks mirror :mod:`repro.obs.trace`: every array
carries one scrap row (index ``-1``) that masked lane-updates are
steered into, so updates are branch-free under ``vmap``.

The exact-counting Python twin is :class:`PyStreamSketch` (dict
counters, float32 EWMA in the same operation order); the differential
pair ``stream-sketch`` (:func:`sketch_trace` vs :func:`sketch_trace_py`)
is registered in ``tools/analysis/contracts.py``.  Sketch error bounds
documented here and asserted by tests: count-min never underestimates;
SpaceSaving ``count - err <= true <= count``; top-k recall >= 0.9 at the
default widths on Zipf streams.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "CM_DEPTH", "N_WINDOWS", "EWMA_ALPHA",
    "SketchState", "SketchEstimates", "PyStreamSketch",
    "sketch_init", "stream_tick", "stream_arrival", "stream_key",
    "stream_done", "stream_done_many",
    "decode_sketch", "decode_sketch_grid",
    "sketch_trace", "sketch_trace_py",
]

#: Tumbling windows kept in the ring (plus one scrap row).
N_WINDOWS = 64
#: Count-min hash rows.
CM_DEPTH = 4
#: Per-completion EWMA decay for the hit/delayed fraction estimators.
EWMA_ALPHA = 0.01

# Distinct odd 32-bit salts, one per count-min row (splitmix/murmur
# finalizer constants — any fixed odd constants work).
_CM_SALTS = (0x9E3779B9, 0x85EBCA6B, 0xC2B2AE35, 0x27D4EB2F)
_CM_MULT = 0x9E3779B1


def cm_width(sketch_cap: int) -> int:
    """Count-min columns for a given SpaceSaving capacity: 8x the top-k
    width (error ~ 2/width of the stream length per row) with a floor."""
    return max(64, 8 * int(sketch_cap))


class SketchState(NamedTuple):
    """In-kernel streaming estimator state (one lane's pytree).

    All integer counters are int32; EWMA scalars are float32.  Shapes
    are static functions of ``(sketch_cap, n_branches, n_windows)``:
    ring arrays carry ``n_windows + 1`` rows and the SpaceSaving table
    ``sketch_cap + 1`` rows — the extra row is write-only scrap for
    masked updates.  ``win_id`` holds the absolute tumbling-window index
    occupying each ring row (-1 = never used)."""

    win_id: jnp.ndarray  # (W+1,) i32 absolute window index, -1 empty
    win_done_count: jnp.ndarray  # (W+1,) i32 completions
    win_hit_count: jnp.ndarray  # (W+1,) i32 hit-branch completions
    win_delayed_count: jnp.ndarray  # (W+1,) i32 delayed-hit completions
    win_arrival_count: jnp.ndarray  # (W+1,) i32 arrivals (open loop)
    win_branch_count: jnp.ndarray  # (W+1, B) i32 per-branch completions
    ewma_hit_frac: jnp.ndarray  # f32 scalar, debias with ewma_norm_frac
    ewma_delayed_frac: jnp.ndarray  # f32 scalar
    ewma_norm_frac: jnp.ndarray  # f32 scalar (1-alpha)^n debias norm
    cm_count: jnp.ndarray  # (CM_DEPTH, width+1) i32, last col scrap
    ss_key: jnp.ndarray  # (K+1,) i32 SpaceSaving keys, -1 empty
    ss_count: jnp.ndarray  # (K+1,) i32 upper-bound counts
    ss_err_count: jnp.ndarray  # (K+1,) i32 overestimation bounds
    key_count: jnp.ndarray  # i32 total key observations


def sketch_init(sketch_cap: int, n_branches: int,
                n_windows: int = N_WINDOWS):
    """Fresh :class:`SketchState`, or ``()`` when ``sketch_cap == 0`` —
    a pytree with no leaves, so carrying it through ``lax.while_loop``
    leaves the compiled program bit-identical to the sketch-free one."""
    if sketch_cap <= 0:
        return ()
    W, K = int(n_windows), int(sketch_cap)
    width = cm_width(K)
    z = lambda *s: jnp.zeros(s, jnp.int32)  # noqa: E731
    return SketchState(
        win_id=jnp.full((W + 1,), -1, jnp.int32),
        win_done_count=z(W + 1), win_hit_count=z(W + 1),
        win_delayed_count=z(W + 1), win_arrival_count=z(W + 1),
        win_branch_count=z(W + 1, int(n_branches)),
        ewma_hit_frac=jnp.float32(0.0),
        ewma_delayed_frac=jnp.float32(0.0),
        ewma_norm_frac=jnp.float32(1.0),
        cm_count=z(CM_DEPTH, width + 1),
        ss_key=jnp.full((K + 1,), -1, jnp.int32),
        ss_count=z(K + 1), ss_err_count=z(K + 1),
        key_count=jnp.int32(0),
    )


def stream_tick(sk: SketchState, elapsed_us, window_us: float):
    """Advance the tumbling-window ring to the window containing
    ``elapsed_us``; returns ``(state, slot)`` where ``slot`` is the ring
    row subsequent adds for this event should target.  A row whose
    stored absolute window id differs is stale (its window scrolled out
    ``n_windows`` windows ago) and is zeroed before reuse."""
    W = sk.win_id.shape[0] - 1
    wid = jnp.floor(elapsed_us / jnp.float32(window_us)).astype(jnp.int32)
    wid = jnp.maximum(wid, 0)
    slot = jnp.remainder(wid, W)
    fresh = sk.win_id[slot] == wid

    def keep(a):
        row = jnp.where(fresh, a[slot], jnp.zeros_like(a[slot]))
        return a.at[slot].set(row)

    sk = sk._replace(
        win_id=sk.win_id.at[slot].set(wid),
        win_done_count=keep(sk.win_done_count),
        win_hit_count=keep(sk.win_hit_count),
        win_delayed_count=keep(sk.win_delayed_count),
        win_arrival_count=keep(sk.win_arrival_count),
        win_branch_count=keep(sk.win_branch_count),
    )
    return sk, slot


def stream_arrival(sk: SketchState, slot, mask) -> SketchState:
    """Count one (masked) arrival into the current window."""
    W = sk.win_id.shape[0] - 1
    s = jnp.where(mask, slot, W)
    return sk._replace(win_arrival_count=sk.win_arrival_count.at[s].add(1))


def stream_done(sk: SketchState, slot, branch_j, is_hit, delayed,
                mask) -> SketchState:
    """Record one (masked) request completion: window counters plus one
    EWMA step (``x = is_hit`` for the hit estimator, ``x = delayed`` for
    the delayed-hit estimator, norm decays by ``1 - alpha``)."""
    W = sk.win_id.shape[0] - 1
    s = jnp.where(mask, slot, W)
    a = jnp.float32(EWMA_ALPHA)
    decay = jnp.where(mask, jnp.float32(1.0) - a, jnp.float32(1.0))
    return sk._replace(
        win_done_count=sk.win_done_count.at[s].add(1),
        win_hit_count=sk.win_hit_count.at[s].add(
            jnp.where(is_hit, 1, 0)),
        win_delayed_count=sk.win_delayed_count.at[s].add(
            jnp.where(delayed, 1, 0)),
        win_branch_count=sk.win_branch_count.at[s, branch_j].add(1),
        ewma_hit_frac=sk.ewma_hit_frac * decay
        + jnp.where(mask & is_hit, a, jnp.float32(0.0)),
        ewma_delayed_frac=sk.ewma_delayed_frac * decay
        + jnp.where(mask & delayed, a, jnp.float32(0.0)),
        ewma_norm_frac=sk.ewma_norm_frac * decay,
    )


def stream_done_many(sk: SketchState, slot, branch_vec,
                     mask_vec) -> SketchState:
    """Record a batch of delayed-hit completions (an MSHR fill waking
    every parked request at once): window scatter-adds per branch, and
    the closed-form batch EWMA step for ``n`` identical ``x = 1``
    delayed observations (``s' = s * d^n + (1 - d^n)``)."""
    W = sk.win_id.shape[0] - 1
    s = jnp.where(mask_vec, slot, W)
    n = jnp.sum(mask_vec.astype(jnp.int32))
    decay_n = jnp.power(jnp.float32(1.0) - jnp.float32(EWMA_ALPHA),
                        n.astype(jnp.float32))
    return sk._replace(
        win_done_count=sk.win_done_count.at[s].add(1),
        win_delayed_count=sk.win_delayed_count.at[s].add(1),
        win_branch_count=sk.win_branch_count.at[s, branch_vec].add(1),
        ewma_hit_frac=sk.ewma_hit_frac * decay_n,
        ewma_delayed_frac=sk.ewma_delayed_frac * decay_n
        + (jnp.float32(1.0) - decay_n),
        ewma_norm_frac=sk.ewma_norm_frac * decay_n,
    )


def _mix32(x):
    """splitmix32 finalizer over uint32 (wrapping) — deterministic, no
    RNG draws, identical in jnp and np.uint32 arithmetic."""
    x = (x ^ (x >> 16)) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * np.uint32(0x846CA68B)
    return x ^ (x >> 16)


def _cm_cols(key_u32, width: int):
    """Per-row count-min columns for one key (tuple of CM_DEPTH i32)."""
    cols = []
    for salt in _CM_SALTS[:CM_DEPTH]:
        h = _mix32(key_u32 * np.uint32(_CM_MULT) + np.uint32(salt))
        cols.append((h % np.uint32(width)).astype(jnp.int32)
                    if isinstance(h, jnp.ndarray) else int(h % width))
    return cols


def stream_key(sk: SketchState, key, mask) -> SketchState:
    """Feed one (masked) key observation to the popularity sketches.

    Count-min: +1 in one hashed column per row (so the per-key minimum
    over rows never underestimates).  SpaceSaving: increment the key's
    slot if present, else evict the minimum-count slot, inheriting its
    count as the new key's overestimation bound ``err``."""
    K = sk.ss_key.shape[0] - 1
    width = sk.cm_count.shape[1] - 1
    ku = key.astype(jnp.uint32)
    col = jnp.stack(_cm_cols(ku, width))
    col = jnp.where(mask, col, width)
    cm = sk.cm_count.at[jnp.arange(CM_DEPTH), col].add(1)

    match = (sk.ss_key[:K] == key) & mask
    has = match.any()
    j = jnp.where(has, jnp.argmax(match), jnp.argmin(sk.ss_count[:K]))
    s = jnp.where(mask, j, K)
    err_new = jnp.where(has, sk.ss_err_count[j], sk.ss_count[j])
    return sk._replace(
        cm_count=cm,
        ss_key=sk.ss_key.at[s].set(key.astype(jnp.int32)),
        ss_count=sk.ss_count.at[s].set(sk.ss_count[j] + 1),
        ss_err_count=sk.ss_err_count.at[s].set(err_new),
        key_count=sk.key_count + jnp.where(mask, 1, 0),
    )


# --------------------------------------------------------------- host side


@dataclasses.dataclass(frozen=True)
class SketchEstimates:
    """Decoded, host-side view of one lane's :class:`SketchState`.

    Window arrays are sorted by ascending absolute window id with empty
    and scrap rows dropped; rates are per µs over ``window_us``.  EWMA
    fractions are debiased (divided by ``1 - (1 - alpha)^n``; NaN before
    the first completion).  ``exact=True`` marks estimates produced by
    the exact-counting twin, which additionally carries the full
    ``exact_key``/``exact_count`` tables (its ``topk_err_count`` is 0
    and ``cm_depth_count`` is None)."""

    window_us: float
    window_id: np.ndarray  # (w,) ascending absolute window ids
    win_done_count: np.ndarray  # (w,)
    win_hit_frac: np.ndarray  # (w,) NaN where no completions
    win_delayed_frac: np.ndarray  # (w,)
    win_done_rate: np.ndarray  # (w,) completions / µs
    win_arrival_rate: np.ndarray  # (w,) arrivals / µs
    win_branch_rate: np.ndarray  # (w, B) completions / µs per branch
    ewma_hit_frac: float
    ewma_delayed_frac: float
    topk_key: np.ndarray  # (k,) by descending count upper bound
    topk_count: np.ndarray  # (k,) upper bounds
    topk_err_count: np.ndarray  # (k,) overestimation bounds
    key_count: int
    exact: bool = False
    cm_depth_count: np.ndarray | None = None  # (CM_DEPTH, width)
    exact_key: np.ndarray | None = None
    exact_count: np.ndarray | None = None

    def cm_estimate(self, keys) -> np.ndarray:
        """Count-min frequency estimates (never below the true count).
        On the exact twin, returns the true counts."""
        keys = np.asarray(keys, np.int64)
        if self.exact:
            lut = dict(zip(self.exact_key.tolist(),
                           self.exact_count.tolist()))
            return np.array([lut.get(int(k), 0) for k in keys], np.int64)
        width = self.cm_depth_count.shape[1]
        ku = keys.astype(np.uint32)
        est = np.full(len(keys), np.iinfo(np.int64).max)
        for r, salt in enumerate(_CM_SALTS[:CM_DEPTH]):
            h = _mix32(ku * np.uint32(_CM_MULT) + np.uint32(salt))
            est = np.minimum(est, self.cm_depth_count[r, h % width])
        return est.astype(np.int64)

    def topk(self, k: int | None = None):
        """``(keys, count_upper, err)`` for the heaviest ``k`` keys."""
        k = len(self.topk_key) if k is None else min(k, len(self.topk_key))
        return (self.topk_key[:k], self.topk_count[:k],
                self.topk_err_count[:k])

    def saturation_frac(self) -> float:
        """SpaceSaving pressure: the minimum slot count (the bound on
        how much any stored count may overestimate) over the stream
        length.  ~0 while the table comfortably holds the head of the
        popularity distribution; -> 1 as it thrashes."""
        if self.exact or len(self.topk_count) == 0 or self.key_count == 0:
            return 0.0
        return float(self.topk_count.min()) / float(self.key_count)

    def shard_heat(self, branch_shard, n_shards: int) -> np.ndarray:
        """Per-window, per-shard completion rates (w, n_shards) folded
        from the per-branch windowed counters."""
        shard = np.asarray(branch_shard)
        out = np.zeros((len(self.window_id), n_shards))
        for k in range(n_shards):
            out[:, k] = self.win_branch_rate[:, shard == k].sum(axis=1)
        return out

    def heat_imbalance(self, branch_shard, n_shards: int) -> float:
        """max/mean of the per-shard mean completion rates (1.0 =
        perfectly balanced; NaN with no completions)."""
        heat = self.shard_heat(branch_shard, n_shards).mean(axis=0)
        mean = heat.mean()
        return float(heat.max() / mean) if mean > 0 else float("nan")


def _debias(s: float, norm: float) -> float:
    denom = 1.0 - norm
    return float(s / denom) if denom > 0 else float("nan")


def decode_sketch(sk, window_us: float) -> SketchEstimates:
    """Decode one lane's :class:`SketchState` (jnp or np leaves)."""
    win_id = np.asarray(sk.win_id)[:-1]
    keep = np.flatnonzero(win_id >= 0)
    keep = keep[np.argsort(win_id[keep], kind="stable")]
    done = np.asarray(sk.win_done_count)[keep]
    hit = np.asarray(sk.win_hit_count)[keep]
    dly = np.asarray(sk.win_delayed_count)[keep]
    arr = np.asarray(sk.win_arrival_count)[keep]
    br = np.asarray(sk.win_branch_count)[keep]
    with np.errstate(invalid="ignore", divide="ignore"):
        hit_frac = np.where(done > 0, hit / np.maximum(done, 1), np.nan)
        dly_frac = np.where(done > 0, dly / np.maximum(done, 1), np.nan)

    ss_key = np.asarray(sk.ss_key)[:-1]
    ss_count = np.asarray(sk.ss_count)[:-1]
    ss_err = np.asarray(sk.ss_err_count)[:-1]
    filled = np.flatnonzero(ss_key >= 0)
    order = filled[np.lexsort((ss_key[filled], -ss_count[filled]))]
    return SketchEstimates(
        window_us=float(window_us),
        window_id=win_id[keep],
        win_done_count=done,
        win_hit_frac=hit_frac,
        win_delayed_frac=dly_frac,
        win_done_rate=done / window_us,
        win_arrival_rate=arr / window_us,
        win_branch_rate=br / window_us,
        ewma_hit_frac=_debias(float(np.asarray(sk.ewma_hit_frac)),
                              float(np.asarray(sk.ewma_norm_frac))),
        ewma_delayed_frac=_debias(float(np.asarray(sk.ewma_delayed_frac)),
                                  float(np.asarray(sk.ewma_norm_frac))),
        topk_key=ss_key[order],
        topk_count=ss_count[order].astype(np.int64),
        topk_err_count=ss_err[order].astype(np.int64),
        key_count=int(np.asarray(sk.key_count)),
        cm_depth_count=np.asarray(sk.cm_count)[:, :-1],
    )


def decode_sketch_grid(sk, n_seeds: int, n_p: int,
                       window_us: float) -> list:
    """Decode a vmapped (seed x p) grid of sketch states into
    ``[seed][p]`` :class:`SketchEstimates` (lane order matches
    :func:`repro.obs.trace.decode_trace_grid`: ``lane = s * n_p + p``)."""
    leaves = [np.asarray(leaf) for leaf in sk]
    out = []
    for s in range(n_seeds):
        row = []
        for p in range(n_p):
            lane = SketchState(*(leaf[s * n_p + p] for leaf in leaves))
            row.append(decode_sketch(lane, window_us))
        out.append(row)
    return out


# ------------------------------------------------------ trace-stream twins


@partial(jax.jit, static_argnames=("sketch_cap", "window_us", "n_windows"))
def _sketch_trace(keys, t_us, hits, sketch_cap, window_us,
                  n_windows=N_WINDOWS):
    sk0 = sketch_init(sketch_cap, 1, n_windows)

    def step(sk, inp):
        key, t, h = inp
        sk, slot = stream_tick(sk, t, window_us)
        sk = stream_arrival(sk, slot, jnp.bool_(True))
        sk = stream_key(sk, key, jnp.bool_(True))
        sk = stream_done(sk, slot, jnp.int32(0), h > 0, jnp.bool_(False),
                         jnp.bool_(True))
        return sk, ()

    sk, _ = jax.lax.scan(step, sk0, (keys, t_us, hits))
    return sk


def sketch_trace(keys, t_us=None, hits=None, sketch_cap: int = 64,
                 window_us: float = 1000.0,
                 n_windows: int = N_WINDOWS) -> SketchEstimates:
    """Run the in-kernel streaming estimators over a key trace via one
    jitted ``lax.scan`` — the standalone path for replayed traces (and
    the fast half of the ``stream-sketch`` differential pair).

    ``t_us`` defaults to one event per µs; ``hits`` (0/1 per event)
    feeds the hit-ratio estimators when given.
    """
    if sketch_cap <= 0:
        raise ValueError("sketch_trace needs sketch_cap > 0")
    if window_us <= 0:
        raise ValueError("sketch_trace needs window_us > 0")
    keys = jnp.asarray(keys, jnp.int32)
    n = keys.shape[0]
    t = (jnp.arange(n, dtype=jnp.float32) if t_us is None
         else jnp.asarray(t_us, jnp.float32))
    h = (jnp.zeros(n, jnp.int32) if hits is None
         else jnp.asarray(hits, jnp.int32))
    sk = _sketch_trace(keys, t, h, sketch_cap, float(window_us), n_windows)
    est = decode_sketch(sk, float(window_us))
    if hits is None:
        est = dataclasses.replace(est, ewma_hit_frac=float("nan"),
                                  win_hit_frac=np.full_like(
                                      est.win_hit_frac, np.nan))
    return est


def sketch_trace_py(keys, t_us=None, hits=None, sketch_cap: int = 64,
                    window_us: float = 1000.0,
                    n_windows: int = N_WINDOWS) -> SketchEstimates:
    """Exact-counting oracle twin of :func:`sketch_trace` (dict
    counters, same float32 EWMA order, same ring retention)."""
    if sketch_cap <= 0:
        raise ValueError("sketch_trace_py needs sketch_cap > 0")
    if window_us <= 0:
        raise ValueError("sketch_trace_py needs window_us > 0")
    keys = np.asarray(keys, np.int64)
    n = len(keys)
    t = (np.arange(n, dtype=np.float32) if t_us is None
         else np.asarray(t_us, np.float32))
    h = (np.zeros(n, np.int64) if hits is None
         else np.asarray(hits, np.int64))
    py = PyStreamSketch(sketch_cap, n_branches=1, window_us=window_us,
                        n_windows=n_windows)
    for i in range(n):
        py.arrival(float(t[i]))
        py.key(int(keys[i]))
        py.done(float(t[i]), 0, is_hit=bool(h[i]))
    est = py.estimates()
    if hits is None:
        est = dataclasses.replace(est, ewma_hit_frac=float("nan"),
                                  win_hit_frac=np.full_like(
                                      est.win_hit_frac, np.nan))
    return est


class PyStreamSketch:
    """Exact-counting Python twin of the in-kernel estimators.

    Keys are counted exactly (a dict), windows keep exact per-window
    counters, and the EWMA scalars apply the identical float32
    operations in the identical per-event order as the kernels, so the
    decoded :class:`SketchEstimates` agree with the jitted side within
    documented bounds (exactly, for every integer counter on the same
    event stream; to float32 round-off for the EWMAs; count-min/
    SpaceSaving replaced by the truth).  ``estimates`` emulates the ring
    retention: per ring row only the most recent window survives."""

    def __init__(self, sketch_cap: int, n_branches: int = 1,
                 window_us: float = 1000.0, n_windows: int = N_WINDOWS):
        if sketch_cap <= 0:
            raise ValueError("PyStreamSketch needs sketch_cap > 0")
        if window_us <= 0:
            raise ValueError("PyStreamSketch needs window_us > 0")
        self.sketch_cap = int(sketch_cap)
        self.n_branches = int(n_branches)
        self.window_us = float(window_us)
        self.n_windows = int(n_windows)
        self.key_freq: dict = {}
        self.key_count = 0
        # wid -> [done, hit, delayed, arrivals, per-branch np array]
        self.windows: dict = {}
        self.ewma_hit = np.float32(0.0)
        self.ewma_delayed = np.float32(0.0)
        self.ewma_norm = np.float32(1.0)

    def _win(self, t_us: float):
        wid = max(int(np.float32(t_us) / np.float32(self.window_us)), 0)
        w = self.windows.get(wid)
        if w is None:
            w = [0, 0, 0, 0, np.zeros(self.n_branches, np.int64)]
            self.windows[wid] = w
        return w

    def key(self, key: int) -> None:
        self.key_freq[key] = self.key_freq.get(key, 0) + 1
        self.key_count += 1

    def arrival(self, t_us: float) -> None:
        self._win(t_us)[3] += 1

    def done(self, t_us: float, branch: int = 0, is_hit: bool = False,
             delayed: bool = False) -> None:
        w = self._win(t_us)
        w[0] += 1
        w[1] += 1 if is_hit else 0
        w[2] += 1 if delayed else 0
        w[4][branch] += 1
        a = np.float32(EWMA_ALPHA)
        decay = np.float32(1.0) - a
        self.ewma_hit = self.ewma_hit * decay + (a if is_hit
                                                 else np.float32(0.0))
        self.ewma_delayed = self.ewma_delayed * decay + (
            a if delayed else np.float32(0.0))
        self.ewma_norm = self.ewma_norm * decay

    def estimates(self) -> SketchEstimates:
        W = self.n_windows
        survivors: dict = {}
        for wid in self.windows:
            r = wid % W
            if r not in survivors or wid > survivors[r]:
                survivors[r] = wid
        wids = sorted(survivors.values())
        done = np.array([self.windows[w][0] for w in wids], np.int64)
        hit = np.array([self.windows[w][1] for w in wids], np.int64)
        dly = np.array([self.windows[w][2] for w in wids], np.int64)
        arr = np.array([self.windows[w][3] for w in wids], np.int64)
        br = (np.stack([self.windows[w][4] for w in wids])
              if wids else np.zeros((0, self.n_branches), np.int64))
        with np.errstate(invalid="ignore", divide="ignore"):
            hit_frac = np.where(done > 0, hit / np.maximum(done, 1), np.nan)
            dly_frac = np.where(done > 0, dly / np.maximum(done, 1), np.nan)
        items = sorted(self.key_freq.items(),
                       key=lambda kv: (-kv[1], kv[0]))
        keys = np.array([k for k, _ in items], np.int64)
        counts = np.array([c for _, c in items], np.int64)
        k = min(self.sketch_cap, len(items))
        return SketchEstimates(
            window_us=self.window_us,
            window_id=np.asarray(wids, np.int64),
            win_done_count=done,
            win_hit_frac=hit_frac,
            win_delayed_frac=dly_frac,
            win_done_rate=done / self.window_us,
            win_arrival_rate=arr / self.window_us,
            win_branch_rate=br / self.window_us,
            ewma_hit_frac=_debias(float(self.ewma_hit),
                                  float(self.ewma_norm)),
            ewma_delayed_frac=_debias(float(self.ewma_delayed),
                                      float(self.ewma_norm)),
            topk_key=keys[:k],
            topk_count=counts[:k],
            topk_err_count=np.zeros(k, np.int64),
            key_count=self.key_count,
            exact=True,
            exact_key=keys,
            exact_count=counts,
        )
