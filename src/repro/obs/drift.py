"""Drift detectors over the streaming estimator series.

Two classical sequential change detectors, both self-referencing (the
baseline mean is learned from the first ``warmup`` observations, and
re-learned after every alarm):

* :class:`Cusum` — two-sided cumulative-sum test: ``g+ = max(0, g+ +
  (x - mean) - k_slack)`` (and the mirrored ``g-``), alarm when either
  statistic exceeds ``h_threshold``.  Tuned by the slack ``k_slack``
  (half the shift you want to ignore) and the threshold (trade
  detection lag against false alarms).
* :class:`PageHinkley` — cumulative deviation from the running mean
  with a min/max tracker: alarm when the cumulative sum rises
  ``lam_threshold`` above its running minimum (or, two-sided, falls
  below its running maximum).

Each class is the streaming form (call :meth:`update` per observation);
the ``*_scan`` functions run the identical recurrence over a whole
series and return the alarm indices — the pair is registered in the
contracts REGISTRY (``drift-cusum`` / ``drift-page-hinkley``) so the
kwarg surfaces can never diverge.
"""

from __future__ import annotations

import numpy as np

__all__ = ["Cusum", "PageHinkley", "cusum_scan", "page_hinkley_scan"]


class Cusum:
    """Two-sided CUSUM with a self-learned baseline.

    During the first ``warmup`` observations the detector only
    estimates the baseline mean; afterwards each :meth:`update` returns
    True on an alarm, which also resets the statistics and starts a new
    warmup (so repeated alarms mean repeated shifts, not one long one).
    """

    def __init__(self, k_slack: float = 0.005, h_threshold: float = 0.05,
                 warmup: int = 8, two_sided: bool = True):
        if warmup < 1:
            raise ValueError("warmup must be >= 1")
        self.k_slack = float(k_slack)
        self.h_threshold = float(h_threshold)
        self.warmup = int(warmup)
        self.two_sided = bool(two_sided)
        self.reset()

    def reset(self) -> None:
        self.mean = 0.0
        self.n_seen = 0
        self.g_pos = 0.0
        self.g_neg = 0.0
        self.n_alarms = 0

    def update(self, x: float) -> bool:
        x = float(x)
        if not np.isfinite(x):
            return False
        if self.n_seen < self.warmup:
            self.mean += (x - self.mean) / (self.n_seen + 1)
            self.n_seen += 1
            return False
        self.n_seen += 1
        dev = x - self.mean
        self.g_pos = max(0.0, self.g_pos + dev - self.k_slack)
        self.g_neg = max(0.0, self.g_neg - dev - self.k_slack)
        alarm = self.g_pos > self.h_threshold or (
            self.two_sided and self.g_neg > self.h_threshold)
        if alarm:
            n = self.n_alarms + 1
            self.reset()
            self.n_alarms = n
        return alarm

    def scan(self, xs) -> np.ndarray:
        """Alarm indices over a series (the streaming recurrence)."""
        return np.array([i for i, x in enumerate(np.asarray(xs, float))
                         if self.update(x)], np.int64)


class PageHinkley:
    """Page-Hinkley test against the running mean.

    Tracks ``m_t = sum(x_i - mean_i - delta_slack)`` and alarms when
    ``m_t - min(m)`` exceeds ``lam_threshold`` (downward shifts, via
    the mirrored max-tracker, when ``two_sided``).  Alarms reset the
    detector.
    """

    def __init__(self, delta_slack: float = 0.005,
                 lam_threshold: float = 0.05, warmup: int = 8,
                 two_sided: bool = True):
        if warmup < 1:
            raise ValueError("warmup must be >= 1")
        self.delta_slack = float(delta_slack)
        self.lam_threshold = float(lam_threshold)
        self.warmup = int(warmup)
        self.two_sided = bool(two_sided)
        self.reset()

    def reset(self) -> None:
        self.mean = 0.0
        self.n_seen = 0
        # Separate slacked sums per direction: the up test tracks the
        # running minimum of sum(x - mean - delta), the down test the
        # running maximum of sum(x - mean + delta) — sharing one sum
        # would let the slack itself walk the statistic into the
        # opposite-direction threshold on stationary data.
        self.m_up = 0.0
        self.min_up = 0.0
        self.m_dn = 0.0
        self.max_dn = 0.0
        self.n_alarms = 0

    def update(self, x: float) -> bool:
        x = float(x)
        if not np.isfinite(x):
            return False
        self.mean += (x - self.mean) / (self.n_seen + 1)
        self.n_seen += 1
        if self.n_seen <= self.warmup:
            return False
        self.m_up += x - self.mean - self.delta_slack
        self.min_up = min(self.min_up, self.m_up)
        self.m_dn += x - self.mean + self.delta_slack
        self.max_dn = max(self.max_dn, self.m_dn)
        alarm = (self.m_up - self.min_up > self.lam_threshold) or (
            self.two_sided
            and self.max_dn - self.m_dn > self.lam_threshold)
        if alarm:
            n = self.n_alarms + 1
            self.reset()
            self.n_alarms = n
        return alarm

    def scan(self, xs) -> np.ndarray:
        """Alarm indices over a series (the streaming recurrence)."""
        return np.array([i for i, x in enumerate(np.asarray(xs, float))
                         if self.update(x)], np.int64)


def cusum_scan(xs, k_slack: float = 0.005, h_threshold: float = 0.05,
               warmup: int = 8, two_sided: bool = True) -> np.ndarray:
    """Alarm indices of :class:`Cusum` over a whole series."""
    return Cusum(k_slack=k_slack, h_threshold=h_threshold, warmup=warmup,
                 two_sided=two_sided).scan(xs)


def page_hinkley_scan(xs, delta_slack: float = 0.005,
                      lam_threshold: float = 0.05, warmup: int = 8,
                      two_sided: bool = True) -> np.ndarray:
    """Alarm indices of :class:`PageHinkley` over a whole series."""
    return PageHinkley(delta_slack=delta_slack,
                       lam_threshold=lam_threshold, warmup=warmup,
                       two_sided=two_sided).scan(xs)
