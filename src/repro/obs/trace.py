"""Per-request trace records and the ring buffers the simulators fill.

One trace record describes one *completed request* (one pass through a
routing branch of the queueing network):

==============  =======  ====================================================
field           dtype    meaning
==============  =======  ====================================================
``req``         int32    global completion index (0-based, includes warmup)
``branch``      int32    routing-branch id (encodes key class / tier / shard)
``cls``         int32    sojourn class: 0 miss, 1 true hit, 2 delayed hit
``nvis``        int32    stations visited (delayed hits stop at the park
                         visit; the MSHR leader's fill serves them)
``parked_us``   float32  interval parked on an MSHR entry (0 unless delayed)
``enter_us``    float32  ``(L,)`` absolute sim-clock µs entering visit *i*
``leave_us``    float32  ``(L,)`` absolute sim-clock µs leaving visit *i*
==============  =======  ====================================================

Station ids are not stored per record — they are a pure function of
``branch`` via the network's static ``visits`` table, and are rebuilt at
decode time (`make_records`).

Inside the jitted kernels the records live in a :class:`TraceRings`
struct-of-arrays ring buffer with ``cap + 1`` rows: row ``cap`` is a
scrap row that absorbs masked-off scatter writes (the same
out-of-bounds-drop idiom the open kernel already uses for sojourns), so
recording is branch-free.  ``cap`` is always a static Python int
(``trace_cap`` in the kernels' ``static_argnames``) — tracing changes
shapes, never introduces traced sizes, and draws no RNG, so disabling it
is bit-identical to not compiling it in.

The heapq oracles use :class:`PyTraceCollector` and both sides decode to
the same :class:`TraceRecords`, making trace equality a differential
twin contract (see ``tools/analysis/contracts.py`` and
``tests/test_obs.py``).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

import jax.numpy as jnp

# Sojourn classes (shared with repro.latency).
CLS_MISS = 0
CLS_HIT = 1
CLS_DELAYED = 2

CLASS_NAMES = {CLS_MISS: "miss", CLS_HIT: "hit", CLS_DELAYED: "delayed"}


# ---------------------------------------------------------------------------
# In-kernel structures (JAX)
# ---------------------------------------------------------------------------


class TraceRings(NamedTuple):
    """Fixed-capacity struct-of-arrays ring buffer of completed records.

    All record arrays have ``cap + 1`` rows; the last row is scrap for
    masked writes and is dropped at decode time.  ``n_count`` is the
    total number of records *emitted* (including overwritten ones), so
    ``max(0, n_count - cap)`` is the overflow drop count.
    """

    n_count: jnp.ndarray  # () int32
    req: jnp.ndarray  # (cap+1,) int32, -1 = never written
    branch: jnp.ndarray  # (cap+1,) int32
    cls: jnp.ndarray  # (cap+1,) int32
    nvis: jnp.ndarray  # (cap+1,) int32
    parked_us: jnp.ndarray  # (cap+1,) float32
    enter_us: jnp.ndarray  # (cap+1, L) float32
    leave_us: jnp.ndarray  # (cap+1, L) float32


class TraceScratch(NamedTuple):
    """Per-job in-flight visit timestamps (N jobs/slots x L visit slots)."""

    enter_us: jnp.ndarray  # (N, L) float32
    leave_us: jnp.ndarray  # (N, L) float32


def init_trace(cap: int, n_jobs: int, route_len: int) -> tuple:
    """Build the (rings, scratch) trace carry, or ``()`` when disabled.

    ``cap``, ``n_jobs`` and ``route_len`` must be Python ints (static
    shapes) — ``obs_lint`` enforces that every caller threads ``cap``
    through ``static_argnames``.
    """
    if cap <= 0:
        return ()
    rings = TraceRings(
        n_count=jnp.int32(0),
        req=jnp.full((cap + 1,), -1, dtype=jnp.int32),
        branch=jnp.zeros((cap + 1,), dtype=jnp.int32),
        cls=jnp.zeros((cap + 1,), dtype=jnp.int32),
        nvis=jnp.zeros((cap + 1,), dtype=jnp.int32),
        parked_us=jnp.zeros((cap + 1,), dtype=jnp.float32),
        enter_us=jnp.zeros((cap + 1, route_len), dtype=jnp.float32),
        leave_us=jnp.zeros((cap + 1, route_len), dtype=jnp.float32),
    )
    scratch = TraceScratch(
        enter_us=jnp.zeros((n_jobs, route_len), dtype=jnp.float32),
        leave_us=jnp.zeros((n_jobs, route_len), dtype=jnp.float32),
    )
    return (rings, scratch)


def ring_write_one(
    rings: TraceRings,
    write,
    req,
    branch,
    cls,
    nvis,
    parked_us,
    enter_row,
    leave_row,
) -> TraceRings:
    """Append one record when ``write`` is True (scrap-row write otherwise)."""
    cap = rings.req.shape[0] - 1
    idx = jnp.where(write, req % cap, cap)
    return TraceRings(
        n_count=rings.n_count + write.astype(jnp.int32),
        req=rings.req.at[idx].set(req),
        branch=rings.branch.at[idx].set(branch),
        cls=rings.cls.at[idx].set(cls),
        nvis=rings.nvis.at[idx].set(nvis),
        parked_us=rings.parked_us.at[idx].set(parked_us),
        enter_us=rings.enter_us.at[idx].set(enter_row),
        leave_us=rings.leave_us.at[idx].set(leave_row),
    )


def ring_write_many(
    rings: TraceRings,
    mask,
    base_req,
    branch,
    cls,
    nvis,
    parked_us,
    enter_rows,
    leave_rows,
) -> TraceRings:
    """Append one record per True in ``mask`` (shape (N,)), in slot order.

    Request ids are assigned ``base_req + rank`` where rank is the
    masked prefix count — the same ordering the open kernel already uses
    for its ``soj_us`` buffer, and the ordering the python oracles
    reproduce.  Masked-off rows scatter into the scrap row.
    """
    cap = rings.req.shape[0] - 1
    m32 = mask.astype(jnp.int32)
    req_ids = base_req + jnp.cumsum(m32) - 1
    idx = jnp.where(mask, req_ids % cap, cap)
    return TraceRings(
        n_count=rings.n_count + m32.sum(),
        req=rings.req.at[idx].set(jnp.where(mask, req_ids, rings.req[cap])),
        branch=rings.branch.at[idx].set(branch),
        cls=rings.cls.at[idx].set(cls),
        nvis=rings.nvis.at[idx].set(nvis),
        parked_us=rings.parked_us.at[idx].set(parked_us),
        enter_us=rings.enter_us.at[idx].set(enter_rows),
        leave_us=rings.leave_us.at[idx].set(leave_rows),
    )


# ---------------------------------------------------------------------------
# Host-side decoded trace
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceRecords:
    """A decoded, req-sorted batch of trace records (host-side numpy)."""

    req: np.ndarray  # (M,) int64, ascending
    branch: np.ndarray  # (M,) int64
    cls: np.ndarray  # (M,) int64
    nvis: np.ndarray  # (M,) int64
    parked_us: np.ndarray  # (M,) float64
    enter_us: np.ndarray  # (M, L) float64, NaN past nvis
    leave_us: np.ndarray  # (M, L) float64, NaN past nvis
    station: np.ndarray  # (M, L) int64, -1 past nvis (or visits unknown)
    n_emitted: int  # total records the run produced (>= M on overflow)

    def __len__(self) -> int:
        return int(self.req.shape[0])

    @property
    def n_dropped(self) -> int:
        """Records lost to ring-buffer overflow."""
        return max(0, self.n_emitted - len(self))

    @property
    def start_us(self) -> np.ndarray:
        return self.enter_us[:, 0]

    @property
    def end_us(self) -> np.ndarray:
        if len(self) == 0:
            return np.zeros(0)
        last = np.maximum(self.nvis - 1, 0)
        return self.leave_us[np.arange(len(self)), last]

    @property
    def sojourn_us(self) -> np.ndarray:
        return self.end_us - self.start_us

    def class_counts(self) -> dict[str, int]:
        return {
            name: int((self.cls == c).sum()) for c, name in CLASS_NAMES.items()
        }

    def branch_counts(self, n_branches: int) -> np.ndarray:
        return np.bincount(self.branch, minlength=n_branches)[:n_branches]


def make_records(
    req,
    branch,
    cls,
    nvis,
    parked_us,
    enter_us,
    leave_us,
    visits=None,
    n_emitted=None,
) -> TraceRecords:
    """Normalize python-collector output (lists/arrays) into TraceRecords.

    This is the oracle-side constructor of the trace twin pair: it takes
    already-valid per-record arrays, sorts them by ``req``, and rebuilds
    per-visit station ids from the network's static ``visits`` table.
    """
    req = np.asarray(req, dtype=np.int64)
    order = np.argsort(req, kind="stable")
    req = req[order]
    branch = np.asarray(branch, dtype=np.int64)[order]
    cls = np.asarray(cls, dtype=np.int64)[order]
    nvis = np.asarray(nvis, dtype=np.int64)[order]
    parked_us = np.asarray(parked_us, dtype=np.float64)[order]
    enter_us = np.asarray(enter_us, dtype=np.float64)[order]
    leave_us = np.asarray(leave_us, dtype=np.float64)[order]
    if enter_us.ndim == 1:
        enter_us = enter_us[:, None]
        leave_us = leave_us[:, None]
    m, route_len = enter_us.shape
    cols = np.arange(route_len)[None, :]
    pad = cols >= nvis[:, None]
    enter_us = np.where(pad, np.nan, enter_us)
    leave_us = np.where(pad, np.nan, leave_us)
    if visits is not None:
        station = np.asarray(visits, dtype=np.int64)[branch]
        station = np.where(pad, -1, station[:, :route_len])
    else:
        station = np.full((m, route_len), -1, dtype=np.int64)
    return TraceRecords(
        req=req,
        branch=branch,
        cls=cls,
        nvis=nvis,
        parked_us=parked_us,
        enter_us=enter_us,
        leave_us=leave_us,
        station=station,
        n_emitted=int(len(req) if n_emitted is None else n_emitted),
    )


def trace_from_rings(
    n,
    req,
    branch,
    cls,
    nvis,
    parked_us,
    enter_us,
    leave_us,
    visits=None,
) -> TraceRecords:
    """Decode one lane's :class:`TraceRings` arrays into TraceRecords.

    This is the fast-side constructor of the trace twin pair.  The scrap
    row (last) and never-written slots (``req < 0``) are dropped; on
    overflow the surviving slots are exactly the last ``cap`` records.
    """
    req = np.asarray(req)[:-1]
    keep = req >= 0
    return make_records(
        req[keep],
        np.asarray(branch)[:-1][keep],
        np.asarray(cls)[:-1][keep],
        np.asarray(nvis)[:-1][keep],
        np.asarray(parked_us)[:-1][keep],
        np.asarray(enter_us)[:-1][keep],
        np.asarray(leave_us)[:-1][keep],
        visits=visits,
        n_emitted=int(n),
    )


def decode_trace_grid(rings, visits, S: int, P: int):
    """Decode vmapped :class:`TraceRings` (lane-major, lane ``s*P + p``)
    into ``[seed][p]`` :class:`TraceRecords` lists."""
    n = np.asarray(rings.n_count)
    req = np.asarray(rings.req)
    branch = np.asarray(rings.branch)
    cls = np.asarray(rings.cls)
    nvis = np.asarray(rings.nvis)
    parked_us = np.asarray(rings.parked_us)
    enter_us = np.asarray(rings.enter_us)
    leave_us = np.asarray(rings.leave_us)
    visits = np.asarray(visits)
    out = []
    for s in range(S):
        row = []
        for p in range(P):
            i = s * P + p
            row.append(
                trace_from_rings(
                    n[i], req[i], branch[i], cls[i], nvis[i], parked_us[i],
                    enter_us[i], leave_us[i], visits=visits,
                )
            )
        out.append(row)
    return out


# ---------------------------------------------------------------------------
# Python-oracle collector
# ---------------------------------------------------------------------------


class PyTraceCollector:
    """Trace collector for the heapq oracles (same schema, same capping).

    The oracle stamps ``enter(j, pos, t)`` when job *j* is placed at its
    ``pos``-th visit, ``leave(j, pos, t)`` when that visit's service (or
    MSHR park) ends, and ``complete(...)`` when the request finishes.
    ``finish(visits)`` keeps the last ``cap`` records, mirroring the
    ring buffer's overwrite semantics.
    """

    def __init__(self, cap: int, n_jobs: int, route_len: int):
        self.cap = int(cap)
        self.route_len = int(route_len)
        self._enter_us = [[np.nan] * route_len for _ in range(n_jobs)]
        self._leave_us = [[np.nan] * route_len for _ in range(n_jobs)]
        self._records: list[tuple] = []
        self.n_emitted = 0

    def start(self, j: int, t_us: float) -> None:
        self._enter_us[j] = [np.nan] * self.route_len
        self._leave_us[j] = [np.nan] * self.route_len
        self._enter_us[j][0] = t_us

    def enter(self, j: int, pos: int, t_us: float) -> None:
        self._enter_us[j][pos] = t_us

    def leave(self, j: int, pos: int, t_us: float) -> None:
        self._leave_us[j][pos] = t_us

    def enter_at(self, j: int, pos: int) -> float:
        return self._enter_us[j][pos]

    def complete(
        self, j: int, branch: int, cls: int, nvis: int, parked_us: float
    ) -> int:
        """Emit job j's record; returns the assigned request id."""
        req = self.n_emitted
        self.n_emitted += 1
        self._records.append(
            (
                req,
                branch,
                cls,
                nvis,
                parked_us,
                list(self._enter_us[j]),
                list(self._leave_us[j]),
            )
        )
        if self.cap > 0 and len(self._records) > self.cap:
            del self._records[0]
        return req

    def finish(self, visits=None) -> TraceRecords:
        if not self._records:
            empty_l = np.zeros((0, self.route_len))
            return make_records(
                np.zeros(0, dtype=np.int64),
                np.zeros(0, dtype=np.int64),
                np.zeros(0, dtype=np.int64),
                np.zeros(0, dtype=np.int64),
                np.zeros(0),
                empty_l,
                empty_l,
                visits=visits,
                n_emitted=self.n_emitted,
            )
        req, branch, cls, nvis, parked_us, enter_us, leave_us = zip(
            *self._records
        )
        return make_records(
            req,
            branch,
            cls,
            nvis,
            parked_us,
            np.asarray(enter_us),
            np.asarray(leave_us),
            visits=visits,
            n_emitted=self.n_emitted,
        )
