"""SLO-aware operating points: where should the hit ratio sit?

The closed-loop stack picks the *throughput-optimal* hit ratio p* (largest
p still achieving the peak bound).  An operator running against a latency
SLO cares about two different optima:

* the **latency-optimal** p — argmin of R(p, lambda) at the offered load;
* the **SLO-capacity-optimal** p — argmax of the largest arrival rate
  whose tail response still meets the SLO.

For FIFO-like policies all three coincide at p = 1 (hits are free, so more
hits always help).  For LRU-like policies they diverge: past the knee the
hit path's serialized metadata stations congest, so both the sustainable
rate and the response time get *worse* as the hit ratio rises — the
paper's inversion, restated in the units users feel.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.queueing import ClosedNetwork
from repro.latency.analytic import analyze_open, lambda_max

_REL_TOL = 1.0 - 1e-9  # "achieves the max" tolerance, as in ClosedNetwork.p_star


def max_arrival_for_slo(net: ClosedNetwork, p_hit: float, slo_us: float,
                        percentile: float = 0.99, tail_mode: str = "nominal",
                        iters: int = 50) -> float:
    """Largest Poisson arrival rate whose ``percentile`` sojourn meets the
    SLO at hit ratio ``p_hit``.  0 when even an empty system misses it
    (the no-wait response already exceeds ``slo_us``)."""
    if slo_us <= 0.0:
        raise ValueError("slo_us must be > 0")
    if analyze_open(net, p_hit, 0.0, tail_mode=tail_mode) \
            .percentile(percentile) > slo_us:
        return 0.0
    hi = lambda_max(net, p_hit, tail_mode=tail_mode)
    if math.isinf(hi):  # no queue demand: delay-only network meets any load
        return math.inf
    lo = 0.0
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        r = analyze_open(net, p_hit, mid, tail_mode=tail_mode)
        if r.stable and r.percentile(percentile) <= slo_us:
            lo = mid
        else:
            hi = mid
    return lo


@dataclasses.dataclass(frozen=True)
class LatencyForecast:
    """Grid forecast of the three operating points (see module docstring).

    ``lambda_max`` uses ``tail_mode="zero"`` so ``p_star_throughput``
    matches the closed-loop ``ClosedNetwork.p_star`` convention exactly;
    the response columns use the pessimistic ``"nominal"`` services.
    ``r_mean``/``r_tail`` are +inf where ``arrival_rate`` is unstable, and
    ``feasible`` marks grid points whose tail meets the SLO at that rate.
    """

    network: str
    arrival_rate: float
    slo_us: float
    percentile: float
    p_grid: np.ndarray
    lambda_max: np.ndarray
    r_mean: np.ndarray
    r_tail: np.ndarray
    slo_lambda: np.ndarray
    feasible: np.ndarray
    p_star_throughput: float
    p_star_latency: float
    p_star_slo: float
    # capacity (keys / pages) achieving each grid hit ratio, mapped
    # through the online ObservedProfile that drove the sweep; None for
    # plain (profile-free) forecasts.
    cap_grid: np.ndarray | None = None


def slo_forecast(net: ClosedNetwork, arrival_rate: float, slo_us: float,
                 percentile: float = 0.99, p_grid=None,
                 tail_mode: str = "nominal",
                 profile=None) -> LatencyForecast:
    """Sweep the hit ratio and report throughput-, latency- and
    SLO-capacity-optimal operating points for ``net``.

    ``p_star_latency`` follows the ``p_star`` convention (largest p still
    achieving the optimum — here the minimum mean response at
    ``arrival_rate``); NaN when the offered rate is unstable at every p.

    ``profile`` accepts an online measured profile (anything with the
    :class:`repro.obs.profile.ObservedProfile` surface — ``p_range()``
    and ``cap_of_p``): when ``p_grid`` is None the sweep is restricted
    to the profile's *achievable* hit-ratio range, and every grid point
    is annotated with the cache capacity achieving it on the result's
    ``cap_grid`` — turning the three p* answers into sizing answers.
    """
    if p_grid is None:
        if profile is not None:
            lo, hi = profile.p_range()
            p_grid = np.linspace(lo, min(hi, 1.0), 201)
        else:
            p_grid = np.linspace(0.0, 1.0, 201)
    p_grid = np.asarray(p_grid, dtype=np.float64)
    cap_grid = (np.array([profile.cap_of_p(float(p)) for p in p_grid])
                if profile is not None else None)

    lmax = lambda_max(net, p_grid, tail_mode="zero")
    # one open solve per grid point yields the mean AND the tail (the
    # OpenAnalysis carries the branch mixture), so mean/tail/feasibility
    # stay consistent by construction.
    solved = [analyze_open(net, float(p), arrival_rate, tail_mode=tail_mode)
              for p in p_grid]
    r_mean = np.array([a.mean for a in solved])
    r_tail = np.array([a.percentile(percentile) for a in solved])
    slo_lam = np.array([
        max_arrival_for_slo(net, float(p), slo_us, percentile=percentile,
                            tail_mode=tail_mode)
        for p in p_grid
    ])
    feasible = np.isfinite(r_tail) & (r_tail <= slo_us)

    def largest_at_max(values: np.ndarray, maximize: bool) -> float:
        vals = values if maximize else -values
        # +inf is a legitimate optimum (e.g. lambda_max with zero queue
        # demand — FIFO at p=1); -inf/NaN mark unstable points.
        if np.isposinf(vals).any():
            return float(p_grid[int(np.nonzero(np.isposinf(vals))[0][-1])])
        finite = np.isfinite(vals)
        if not finite.any():
            return math.nan
        best = float(np.max(vals[finite]))
        thresh = best * _REL_TOL if best > 0 else best - 1e-12
        at = np.nonzero(finite & (vals >= thresh))[0]
        return float(p_grid[int(at[-1])])

    return LatencyForecast(
        network=net.name,
        arrival_rate=float(arrival_rate),
        slo_us=float(slo_us),
        percentile=float(percentile),
        p_grid=p_grid,
        lambda_max=np.atleast_1d(lmax),
        r_mean=np.atleast_1d(r_mean),
        r_tail=np.atleast_1d(r_tail),
        slo_lambda=slo_lam,
        feasible=feasible,
        p_star_throughput=largest_at_max(np.atleast_1d(lmax), True),
        p_star_latency=largest_at_max(np.atleast_1d(r_mean), False),
        p_star_slo=largest_at_max(slo_lam, True),
        cap_grid=cap_grid,
    )
