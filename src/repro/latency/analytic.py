"""Open queueing-network response-time analysis — the analytic latency prong.

The closed-loop model (:mod:`repro.core.queueing`) fixes the *population*
(MPL) and solves for throughput; response time only appears as the cycle
time N/X.  Real cache front-ends are open-loop: requests arrive at some
rate lambda regardless of how many are already in the system, and the
quantity that matters is the *sojourn* (response) time R(p, lambda).

This module evaluates the same :class:`~repro.core.queueing.ClosedNetwork`
definitions (stations, branches, p_hit-parameterized services and
probabilities — the MPL field is simply ignored) as an open Jackson/BCMP
network under Poisson(lambda) arrivals:

* **think stations** (infinite-server): pure delay, per-visit sojourn equals
  the mean service time regardless of load or distribution.
* **queue stations** (c-server FCFS): per-visit sojourn is the M/M/c value
  ``S + C(c, a) * S / (c - a)`` with offered load ``a = lambda_k * S`` and
  ``C`` the Erlang-C waiting probability.  For the exponential analogue of
  a network this is exact (BCMP: FCFS stations with class-independent
  exponential service); for the paper's det/pareto services it is the same
  kind of insensitivity approximation the closed-loop MVA already leans on.

The **stability boundary** ``lambda_max(p) = min_k c_k / D_k`` is exactly
the saturated term of the closed-loop Thm-7.1 bound, so the open-loop
knee — the hit ratio beyond which the sustainable arrival rate *drops* —
coincides with the closed-loop p*.  That is the paper's phenomenon restated
in latency terms: past the knee, a higher hit ratio buys you a *lower*
ceiling and, at fixed lambda, a *longer* response time.

Tails are a per-branch **moment-matched phase-type mixture**: each
branch's sojourn is a sum of per-visit components (deterministic or
exponential think stages, M/M/c waits + exponential services), so its
first two moments are known in closed form; the branch tail is the
gamma / generalized-Erlang distribution matching them — the continuous
interpolation of the equal-rate hypoexponential (Erlang-k) family, with
``cv² = 1`` collapsing to the exponential exactly.  The overall sojourn
CDF is the probability-weighted mixture over branches.  For a
single-visit M/M/1 route the branch sojourn is exactly exponential and
the fit is exact; for multi-visit routes the old per-branch exponential
tail (still available as ``tail="exp"``) badly inflates p99 when a
branch is a sum of many comparable stages — the miss path's 100µs disk
stage plus sub-µs metadata visits has ``cv² ≪ 1``, nothing like an
exponential.  Units are microseconds and requests/µs throughout,
matching :mod:`repro.core.queueing`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import numpy as np

from repro.core.queueing import QUEUE, ClosedNetwork


def erlang_c(c: int, a: float) -> float:
    """Erlang-C waiting probability P{wait > 0} for M/M/c at offered load
    ``a = lambda * S`` erlangs.  Requires ``a < c`` (an overloaded queue
    has no steady state); the Erlang-B recursion keeps it numerically
    stable for large ``c``."""
    if a <= 0.0:
        return 0.0
    if c < 1:
        raise ValueError("c must be >= 1")
    if a >= c:
        raise ValueError(f"offered load a={a} must be < c={c} servers")
    b = 1.0
    for k in range(1, c + 1):
        b = a * b / (k + a * b)
    rho = a / c
    return b / (1.0 - rho * (1.0 - b))


def _gammainc_reg(s: float, x: float) -> float:
    """Regularized lower incomplete gamma P(s, x) — series for x < s+1,
    Lentz continued fraction otherwise (Numerical Recipes 6.2).  Above
    shape 50 the series/CF need O(sqrt(s))..O(s) terms, so the
    Wilson-Hilferty cube-root normal approximation takes over (abs error
    < ~1e-4 there — far below the tail model's own error), keeping each
    CDF evaluation O(1) inside the percentile/SLO bisections."""
    if x <= 0.0:
        return 0.0
    if s > 50.0:
        z = ((x / s) ** (1.0 / 3.0) - (1.0 - 1.0 / (9.0 * s))) \
            * 3.0 * math.sqrt(s)
        return 0.5 * (1.0 + math.erf(z / math.sqrt(2.0)))
    lg = math.lgamma(s)
    pref = math.exp(-x + s * math.log(x) - lg)
    if x < s + 1.0:
        term = 1.0 / s
        total = term
        n = 0
        while n < 100_000:
            n += 1
            term *= x / (s + n)
            total += term
            if term < total * 1e-13:
                break
        return min(1.0, total * pref)
    tiny = 1e-300
    b = x + 1.0 - s
    c = 1.0 / tiny
    d = 1.0 / b if b != 0.0 else 1.0 / tiny
    h = d
    for i in range(1, 100_000):
        an = -i * (i - s)
        b += 2.0
        d = an * d + b
        if abs(d) < tiny:
            d = tiny
        c = b + an / c
        if abs(c) < tiny:
            c = tiny
        d = 1.0 / d
        delta = d * c
        h *= delta
        if abs(delta - 1.0) < 1e-13:
            break
    return max(0.0, min(1.0, 1.0 - pref * h))


def _branch_cdf(t: float, mean: float, var: float) -> float:
    """Moment-matched branch sojourn CDF at ``t``.

    gamma(shape m²/v, scale v/m): shape 1 == exponential (single M/M/1
    visit — exact), integer shapes == Erlang == equal-rate
    hypoexponential, shape < 1 covers the heavy low-utilization M/M/c
    wait mixtures (cv² > 1).  Degenerate variance (an all-deterministic
    route) is a step at the mean."""
    if mean <= 0.0:
        return 1.0
    shape = mean * mean / var if var > 0.0 else math.inf
    if shape > 1e6:  # numerically deterministic
        return 1.0 if t >= mean else 0.0
    return _gammainc_reg(shape, t * shape / mean)


def _mixture_quantile(comps, q: float) -> float:
    """Bisect the branch-mixture CDF; ``comps`` rows are (prob, mean, cdf)."""
    def cdf(t: float) -> float:
        return sum(pb * f(t) for pb, _, f in comps)

    hi = max(rb for _, rb, _ in comps) + 1e-12
    while cdf(hi) < q:
        hi *= 2.0
    lo = 0.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if cdf(mid) < q:
            lo = mid
        else:
            hi = mid
        if hi - lo <= 1e-9 * hi:
            break
    return 0.5 * (lo + hi)


def lambda_max(net: ClosedNetwork, p_hit, tail_mode: str = "zero"):
    """Open-loop stability boundary: the largest Poisson arrival rate the
    network can sustain at hit ratio p, ``min_k c_k / D_k`` over queue
    stations.  This is exactly the saturated (second) term of the
    closed-loop Thm-7.1 bound, so its knee recovers the closed-loop p*.
    Vectorized over ``p_hit``; +inf for a network with no queue demand."""
    servers = net.queue_servers()
    p_arr = np.atleast_1d(np.asarray(p_hit, dtype=np.float64))
    out = np.empty_like(p_arr)
    for i, p in enumerate(p_arr):
        d = net.demands(float(p), tail_mode=tail_mode)
        terms = [servers[k] / dk for k, dk in d.items() if dk > 0.0]
        out[i] = min(terms) if terms else math.inf
    return out if np.ndim(p_hit) else float(out[0])


@dataclasses.dataclass(frozen=True)
class OpenAnalysis:
    """One (p_hit, lambda) operating point of the open network.

    ``station_time`` maps each station to its per-visit sojourn (wait +
    service); ``branches`` carries (name, probability, mean response,
    response variance) per route — the moment-matched mixture components
    behind :meth:`percentile`.  An unstable point (some queue station
    with offered load >= c) has ``stable=False`` and infinite means.
    """

    p_hit: float
    arrival_rate: float
    stable: bool
    mean: float
    utilization: Dict[str, float]
    station_time: Dict[str, float]
    branches: Tuple[tuple, ...]  # (name, prob, mean_response, var_response)

    def percentile(self, q: float = 0.99, tail: str = "hypo") -> float:
        """Sojourn-time percentile, solved by bisection on the mixture CDF.

        ``tail="hypo"`` (default): each branch uses the moment-matched
        gamma / generalized-Erlang tail (the equal-rate hypoexponential
        family, continuously interpolated) fitted to the branch's exact
        first two moments — exact for a single M/M/1 visit (cv² = 1 →
        exponential) and far tighter than the exponential at high
        utilization, where a branch is a sum of many stages.
        ``tail="exp"`` keeps the legacy per-branch exponential mixture
        for comparison.
        """
        if not 0.0 < q < 1.0:
            raise ValueError("percentile q must be in (0, 1)")
        if tail not in ("hypo", "exp"):
            raise ValueError(f"unknown tail {tail!r} (want 'hypo' or 'exp')")
        if not self.stable:
            return math.inf
        if tail == "exp":
            comps = [
                (pb, rb,
                 (lambda t, rb=rb: -math.expm1(-t / rb)) if rb > 0.0
                 else (lambda t: 1.0))
                for _, pb, rb, _ in self.branches if pb > 0.0
            ]
        else:
            comps = [
                (pb, rb, (lambda t, rb=rb, vb=vb: _branch_cdf(t, rb, vb)))
                for _, pb, rb, vb in self.branches if pb > 0.0
            ]
        if not comps:
            return 0.0
        return _mixture_quantile(comps, q)


def analyze_open(net: ClosedNetwork, p_hit: float, arrival_rate: float,
                 tail_mode: str = "nominal") -> OpenAnalysis:
    """Solve the open network at one (p_hit, lambda) point.

    ``tail_mode`` follows the closed-loop convention: ``"nominal"``
    (default, matching MVA) charges ``bound="upper"`` stations their stated
    upper-bound service — pessimistic but physical; ``"zero"`` drops them
    (matching the throughput upper bound).
    """
    if arrival_rate < 0.0:
        raise ValueError("arrival_rate must be >= 0")
    p = float(p_hit)
    counts = net.visit_counts(p)
    station_time: Dict[str, float] = {}
    station_var: Dict[str, float] = {}
    util: Dict[str, float] = {}
    stable = True
    for s in net.stations:
        svc = s.mean_service(p)
        if s.bound == "upper" and tail_mode == "zero":
            svc = 0.0
        if s.kind != QUEUE:
            station_time[s.name] = svc
            # det stages contribute no variance; exp (and, approximately,
            # pareto) stages contribute svc^2.
            station_var[s.name] = 0.0 if s.dist == "det" else svc * svc
            continue
        lam_k = arrival_rate * counts[s.name]
        a = lam_k * svc
        c = int(s.servers)
        util[s.name] = a / c
        if a >= c:
            stable = False
            station_time[s.name] = math.inf
            station_var[s.name] = math.inf
            continue
        wait = erlang_c(c, a) * svc / (c - a) if svc > 0.0 else 0.0
        station_time[s.name] = svc + wait
        # M/M/c sojourn moments: W = 0 w.p. 1-C, else Exp((c-a)/S), so
        # Var W = (S/(c-a))^2 C(2-C); service Exp(S) adds S^2.  For c=1
        # this collapses to the exact M/M/1 sojourn variance (S/(1-rho))^2.
        if svc > 0.0:
            cw = erlang_c(c, a)
            wu = svc / (c - a)
            station_var[s.name] = wu * wu * cw * (2.0 - cw) + svc * svc
        else:
            station_var[s.name] = 0.0

    branches = []
    mean = 0.0
    for b in net.branches:
        pb = b.probability(p)
        rb = sum(station_time[v] for v in b.visits)
        vb = sum(station_var[v] for v in b.visits)
        branches.append((b.name, pb, rb, vb))
        mean += pb * rb
    return OpenAnalysis(
        p_hit=p, arrival_rate=float(arrival_rate), stable=stable,
        mean=mean if stable else math.inf, utilization=util,
        station_time=station_time, branches=tuple(branches),
    )


def response_time(net: ClosedNetwork, p_hit, arrival_rate: float,
                  tail_mode: str = "nominal"):
    """Mean end-to-end response time R(p, lambda); +inf where unstable.
    Vectorized over ``p_hit``."""
    p_arr = np.atleast_1d(np.asarray(p_hit, dtype=np.float64))
    out = np.array([
        analyze_open(net, float(p), arrival_rate, tail_mode=tail_mode).mean
        for p in p_arr
    ])
    return out if np.ndim(p_hit) else float(out[0])


def response_percentile(net: ClosedNetwork, p_hit, arrival_rate: float,
                        q: float = 0.99, tail_mode: str = "nominal"):
    """Sojourn percentile (exponential-mixture approximation); +inf where
    unstable.  Vectorized over ``p_hit``."""
    p_arr = np.atleast_1d(np.asarray(p_hit, dtype=np.float64))
    out = np.array([
        analyze_open(net, float(p), arrival_rate,
                     tail_mode=tail_mode).percentile(q)
        for p in p_arr
    ])
    return out if np.ndim(p_hit) else float(out[0])


def observed_response(trace, qs=(0.5, 0.95, 0.99)) -> dict:
    """Empirical response-time summary from per-request trace records.

    ``trace`` is a :class:`repro.obs.trace.TraceRecords` (a traced open- or
    closed-loop run); the returned overall / per-class sojourn means and
    percentiles are directly comparable to :func:`response_time` /
    :func:`response_percentile` at the matching (p, lambda) — the
    measurement-side counterpart of the Erlang-C layer.
    """
    from repro.obs.trace import CLASS_NAMES

    soj = np.asarray(trace.sojourn_us, dtype=np.float64)
    cls = np.asarray(trace.cls)
    out = {
        "n_count": int(len(soj)),
        "mean_us": float(soj.mean()) if len(soj) else math.nan,
        "percentiles_us": {
            q: (float(np.percentile(soj, 100.0 * q)) if len(soj)
                else math.nan)
            for q in qs
        },
    }
    by_class = {}
    for c, name in CLASS_NAMES.items():
        sel = soj[cls == c]
        if len(sel):
            by_class[name] = {
                "n_count": int(len(sel)),
                "mean_us": float(sel.mean()),
                "percentiles_us": {
                    q: float(np.percentile(sel, 100.0 * q)) for q in qs
                },
            }
    out["by_class"] = by_class
    return out
