"""Open queueing-network response-time analysis — the analytic latency prong.

The closed-loop model (:mod:`repro.core.queueing`) fixes the *population*
(MPL) and solves for throughput; response time only appears as the cycle
time N/X.  Real cache front-ends are open-loop: requests arrive at some
rate lambda regardless of how many are already in the system, and the
quantity that matters is the *sojourn* (response) time R(p, lambda).

This module evaluates the same :class:`~repro.core.queueing.ClosedNetwork`
definitions (stations, branches, p_hit-parameterized services and
probabilities — the MPL field is simply ignored) as an open Jackson/BCMP
network under Poisson(lambda) arrivals:

* **think stations** (infinite-server): pure delay, per-visit sojourn equals
  the mean service time regardless of load or distribution.
* **queue stations** (c-server FCFS): per-visit sojourn is the M/M/c value
  ``S + C(c, a) * S / (c - a)`` with offered load ``a = lambda_k * S`` and
  ``C`` the Erlang-C waiting probability.  For the exponential analogue of
  a network this is exact (BCMP: FCFS stations with class-independent
  exponential service); for the paper's det/pareto services it is the same
  kind of insensitivity approximation the closed-loop MVA already leans on.

The **stability boundary** ``lambda_max(p) = min_k c_k / D_k`` is exactly
the saturated term of the closed-loop Thm-7.1 bound, so the open-loop
knee — the hit ratio beyond which the sustainable arrival rate *drops* —
coincides with the closed-loop p*.  That is the paper's phenomenon restated
in latency terms: past the knee, a higher hit ratio buys you a *lower*
ceiling and, at fixed lambda, a *longer* response time.

Tails use an exponential-mixture approximation: each branch's sojourn is
approximated as exponential at its mean, and the overall sojourn CDF is the
probability-weighted mixture — exact for single-visit M/M/1 routes,
conservative ordering elsewhere.  Units are microseconds and requests/µs
throughout, matching :mod:`repro.core.queueing`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import numpy as np

from repro.core.queueing import QUEUE, ClosedNetwork


def erlang_c(c: int, a: float) -> float:
    """Erlang-C waiting probability P{wait > 0} for M/M/c at offered load
    ``a = lambda * S`` erlangs.  Requires ``a < c`` (an overloaded queue
    has no steady state); the Erlang-B recursion keeps it numerically
    stable for large ``c``."""
    if a <= 0.0:
        return 0.0
    if c < 1:
        raise ValueError("c must be >= 1")
    if a >= c:
        raise ValueError(f"offered load a={a} must be < c={c} servers")
    b = 1.0
    for k in range(1, c + 1):
        b = a * b / (k + a * b)
    rho = a / c
    return b / (1.0 - rho * (1.0 - b))


def lambda_max(net: ClosedNetwork, p_hit, tail_mode: str = "zero"):
    """Open-loop stability boundary: the largest Poisson arrival rate the
    network can sustain at hit ratio p, ``min_k c_k / D_k`` over queue
    stations.  This is exactly the saturated (second) term of the
    closed-loop Thm-7.1 bound, so its knee recovers the closed-loop p*.
    Vectorized over ``p_hit``; +inf for a network with no queue demand."""
    servers = net.queue_servers()
    p_arr = np.atleast_1d(np.asarray(p_hit, dtype=np.float64))
    out = np.empty_like(p_arr)
    for i, p in enumerate(p_arr):
        d = net.demands(float(p), tail_mode=tail_mode)
        terms = [servers[k] / dk for k, dk in d.items() if dk > 0.0]
        out[i] = min(terms) if terms else math.inf
    return out if np.ndim(p_hit) else float(out[0])


@dataclasses.dataclass(frozen=True)
class OpenAnalysis:
    """One (p_hit, lambda) operating point of the open network.

    ``station_time`` maps each station to its per-visit sojourn (wait +
    service); ``branches`` carries (name, probability, mean response) per
    route — the exponential-mixture components behind :meth:`percentile`.
    An unstable point (some queue station with offered load >= c) has
    ``stable=False`` and infinite means.
    """

    p_hit: float
    arrival_rate: float
    stable: bool
    mean: float
    utilization: Dict[str, float]
    station_time: Dict[str, float]
    branches: Tuple[tuple, ...]  # (name, prob, mean_response)

    def percentile(self, q: float = 0.99) -> float:
        """Sojourn-time percentile via the exponential-mixture tail
        approximation: F(t) = sum_b p_b (1 - exp(-t / R_b)), solved by
        bisection.  Exact when every branch's sojourn is exponential
        (e.g. a single M/M/1 visit); an approximation otherwise."""
        if not 0.0 < q < 1.0:
            raise ValueError("percentile q must be in (0, 1)")
        if not self.stable:
            return math.inf
        comps = [(pb, rb) for _, pb, rb in self.branches if pb > 0.0]
        if not comps:
            return 0.0

        def cdf(t: float) -> float:
            return sum(pb * -math.expm1(-t / rb) if rb > 0.0 else pb
                       for pb, rb in comps)

        hi = max(rb for _, rb in comps) + 1e-12
        while cdf(hi) < q:
            hi *= 2.0
        lo = 0.0
        for _ in range(200):
            mid = 0.5 * (lo + hi)
            if cdf(mid) < q:
                lo = mid
            else:
                hi = mid
        return 0.5 * (lo + hi)


def analyze_open(net: ClosedNetwork, p_hit: float, arrival_rate: float,
                 tail_mode: str = "nominal") -> OpenAnalysis:
    """Solve the open network at one (p_hit, lambda) point.

    ``tail_mode`` follows the closed-loop convention: ``"nominal"``
    (default, matching MVA) charges ``bound="upper"`` stations their stated
    upper-bound service — pessimistic but physical; ``"zero"`` drops them
    (matching the throughput upper bound).
    """
    if arrival_rate < 0.0:
        raise ValueError("arrival_rate must be >= 0")
    p = float(p_hit)
    counts = net.visit_counts(p)
    station_time: Dict[str, float] = {}
    util: Dict[str, float] = {}
    stable = True
    for s in net.stations:
        svc = s.mean_service(p)
        if s.bound == "upper" and tail_mode == "zero":
            svc = 0.0
        if s.kind != QUEUE:
            station_time[s.name] = svc
            continue
        lam_k = arrival_rate * counts[s.name]
        a = lam_k * svc
        c = int(s.servers)
        util[s.name] = a / c
        if a >= c:
            stable = False
            station_time[s.name] = math.inf
            continue
        wait = erlang_c(c, a) * svc / (c - a) if svc > 0.0 else 0.0
        station_time[s.name] = svc + wait

    branches = []
    mean = 0.0
    for b in net.branches:
        pb = b.probability(p)
        rb = sum(station_time[v] for v in b.visits)
        branches.append((b.name, pb, rb))
        mean += pb * rb
    return OpenAnalysis(
        p_hit=p, arrival_rate=float(arrival_rate), stable=stable,
        mean=mean if stable else math.inf, utilization=util,
        station_time=station_time, branches=tuple(branches),
    )


def response_time(net: ClosedNetwork, p_hit, arrival_rate: float,
                  tail_mode: str = "nominal"):
    """Mean end-to-end response time R(p, lambda); +inf where unstable.
    Vectorized over ``p_hit``."""
    p_arr = np.atleast_1d(np.asarray(p_hit, dtype=np.float64))
    out = np.array([
        analyze_open(net, float(p), arrival_rate, tail_mode=tail_mode).mean
        for p in p_arr
    ])
    return out if np.ndim(p_hit) else float(out[0])


def response_percentile(net: ClosedNetwork, p_hit, arrival_rate: float,
                        q: float = 0.99, tail_mode: str = "nominal"):
    """Sojourn percentile (exponential-mixture approximation); +inf where
    unstable.  Vectorized over ``p_hit``."""
    p_arr = np.atleast_1d(np.asarray(p_hit, dtype=np.float64))
    out = np.array([
        analyze_open(net, float(p), arrival_rate,
                     tail_mode=tail_mode).percentile(q)
        for p in p_arr
    ])
    return out if np.ndim(p_hit) else float(out[0])
