"""repro.latency — the open-loop latency prong.

The paper's closed-loop stack answers "how fast can the system go"
(throughput X(p) under a fixed multiprogramming limit).  This package
answers the question users actually feel: "how long does a request take"
— under *open-loop* Poisson arrivals at rate lambda, which is how real
front-ends load a cache.

Three pieces, mirroring the repo's three prongs:

  analytic   -> repro.latency.analytic   (Erlang-C / M/M/c layer over the
                existing Station/Branch networks: R(p, lambda), tails,
                stability boundary lambda_max(p))
  simulation -> repro.core.simulator's ``simulate_network(arrival_rate=...)``
                and the heapq twin ``repro.core.py_sim.simulate_py`` —
                per-request sojourns, including time parked on the MSHR
                outstanding-miss table (delayed hits)
  serving    -> repro.latency.forecast (SLO-aware operating points;
                ``Engine.forecast_slo`` wires it to measured controller
                profiles)
"""

from repro.latency.analytic import (
    OpenAnalysis,
    analyze_open,
    erlang_c,
    lambda_max,
    observed_response,
    response_percentile,
    response_time,
)
from repro.latency.forecast import (
    LatencyForecast,
    max_arrival_for_slo,
    slo_forecast,
)

__all__ = [
    "OpenAnalysis", "analyze_open", "erlang_c", "lambda_max",
    "observed_response", "response_percentile", "response_time",
    "LatencyForecast", "max_arrival_for_slo", "slo_forecast",
]
