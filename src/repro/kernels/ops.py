"""Jitted public wrappers around the Pallas kernels.

Model code calls these (layout adaptation + padding + jit); on CPU pass
interpret=True (the kernels execute in the Pallas interpreter), on TPU the
same calls compile to real kernels.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import cache_update as _cache
from repro.kernels import flash_attention as _flash
from repro.kernels import linear_scan as _scan
from repro.kernels import paged_attention as _paged


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, bq=128, bk=128,
                    interpret=False):
    """(B, T, H, dh) x (B, S, KV, dh) -> (B, T, H, dh) (model layout)."""
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    out = _flash.flash_attention_fwd(
        qt, kt, vt, causal=causal, window=window, bq=bq, bk=bk,
        interpret=interpret,
    )
    return out.swapaxes(1, 2)


@partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, pages_k, pages_v, block_table, seq_lens, *,
                    interpret=False):
    return _paged.paged_attention(
        q, pages_k, pages_v, block_table, seq_lens, interpret=interpret
    )


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_scan(r, k, v, w, u, *, chunk=128, interpret=False):
    T = r.shape[1]
    c = min(chunk, T)
    pad = (-T) % c
    if pad:
        # w=1 on padding keeps the state invariant; outputs are sliced off
        zeros = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zeros(r), zeros(k), zeros(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    y = _scan.wkv6_scan(r, k, v, w, u, chunk=c, interpret=interpret)
    return y[:, :T]


@partial(jax.jit, static_argnames=("tile", "interpret"))
def lru_batch_update(timestamps, accessed, now, *, tile=512, interpret=False):
    return _cache.lru_batch_update(
        timestamps, accessed, now, tile=min(tile, timestamps.shape[0]),
        interpret=interpret,
    )
