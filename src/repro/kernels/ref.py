"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, H, T, dh); k, v: (B, KV, S, dh) -> (B, H, T, dh)."""
    B, H, T, dh = q.shape
    KV, S = k.shape[1], k.shape[2]
    group = H // KV
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum(
        "bhtd,bhsd->bhts", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (dh**-0.5)
    rows = jnp.arange(T)[:, None]
    cols = jnp.arange(S)[None, :]
    valid = jnp.ones((T, S), bool)
    if causal:
        valid = valid & (cols <= rows)
    if window > 0:
        valid = valid & (cols > rows - window)
    logits = jnp.where(valid[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p, v.astype(jnp.float32)).astype(q.dtype)


def paged_attention_ref(q, pages_k, pages_v, block_table, seq_lens):
    """Gather pages into dense KV, then masked softmax attention."""
    B, H, dh = q.shape
    P, page, KV, _ = pages_k.shape
    n_pages = block_table.shape[1]
    k = pages_k[block_table].reshape(B, n_pages * page, KV, dh)
    v = pages_v[block_table].reshape(B, n_pages * page, KV, dh)
    group = H // KV
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum(
        "bhd,bshd->bhs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (dh**-0.5)
    valid = jnp.arange(n_pages * page)[None, :] < seq_lens[:, None]
    logits = jnp.where(valid[:, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)


def wkv6_scan_ref(r, k, v, w, u):
    """Step-by-step WKV6 recurrence (shared with repro.models.rwkv)."""
    from repro.models.rwkv import _wkv_scan

    B, T, H, dh = r.shape
    state = jnp.zeros((B, H, dh, dh), jnp.float32)
    _, y = _wkv_scan(r, k, v, w, u.astype(jnp.float32), state)
    return y.astype(r.dtype)


def lru_batch_update_ref(timestamps, accessed, now):
    hit = jnp.isin(jnp.arange(timestamps.shape[0]), accessed)
    new_ts = jnp.where(hit, now, timestamps)
    return new_ts, jnp.argmin(new_ts).astype(jnp.int32)
