"""Pallas event-driven simulator: the closed-loop (p_hit x seed) grid.

Prong B's measurement grid (`repro.core.simulator.simulate_network`) is a
vmapped ``lax.while_loop`` whose per-event cost is dominated by RNG
plumbing: every event splits a threefry key 4 (closed) to 7 (coalescing)
ways before drawing at most 3 variates.  On an accelerator the split
chains serialize; this kernel replaces them with a **counter-based 32-bit
hash stream** (a splitmix-style finalizer over ``seed ^ ctr``) — one
multiply-xorshift chain per variate, vectorizes over lanes, and stays in
uint32 end to end (the repo's jit-hash64 lint bans 64-bit dtypes in
traced scopes).

Everything else — FIFO release by enqueue sequence, multi-server busy
accounting, route advance, warmup snapshots — is the exact event loop of
``_simulate``, restricted to the closed-loop non-coalescing case (the
open-loop/MSHR prongs keep the scan backend; ``simulate_network`` raises
if you ask the pallas backend for them).

Because the RNG stream differs, agreement with ``simulate_network`` is
*statistical* (same network, same mean/dispersion laws — pinned within a
few percent by tests), while the pallas kernel and the vmapped twin share
:func:`_sim_lane` verbatim and are therefore bit-identical, the same
twin-pair structure as the replay kernel.

``interpret=None`` auto-selects: real kernel on TPU, jitted vmapped twin
on CPU; ``interpret=True`` runs the kernel body under the pallas
interpreter (CI fallback, tests only).
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl

from repro.core.simspec import (BIG_SEQ, INF_NS, SimResult, compile_network,
                                stack_specs)
from repro.kernels import CompilerParams
from repro.obs.trace import (CLS_HIT, CLS_MISS, TraceRings, TraceScratch,
                             decode_trace_grid, init_trace, ring_write_one)

_GOLDEN = np.uint32(0x9E3779B9)
_MIX1 = np.uint32(0x21F0AAAD)
_MIX2 = np.uint32(0x735A2D97)
_INV24 = np.float32(1.0 / (1 << 24))


def _mix(x: jnp.ndarray) -> jnp.ndarray:
    """Murmur3-style 32-bit finalizer (full avalanche)."""
    x = x ^ (x >> 16)
    x = x * _MIX1
    x = x ^ (x >> 15)
    x = x * _MIX2
    x = x ^ (x >> 15)
    return x


class _SpecArrays(NamedTuple):
    """One lane's compiled network (the array fields of SimSpec)."""

    is_queue: jnp.ndarray    # (K,) bool
    svc_ns: jnp.ndarray      # (K,) f32
    dist_id: jnp.ndarray     # (K,) i32
    dist_params: jnp.ndarray  # (K, 4) f32
    branch_cum: jnp.ndarray  # (B,) f32
    visits: jnp.ndarray      # (B, L) i32
    servers: jnp.ndarray     # (K,) i32


def _service_ns(u: jnp.ndarray, spec: _SpecArrays, k: jnp.ndarray):
    """Service draw (ns, int32 >= 1) — the `_sample_service_ns` formulas
    with the uniform supplied by the caller's counter stream."""
    mean = spec.svc_ns[k]
    s_exp = -jnp.log(u)
    alpha, lo, hi, raw_mean = (spec.dist_params[k, i] for i in range(4))
    ratio = 1.0 - (lo / hi) ** alpha
    s_par = lo * (1.0 - u * ratio) ** (-1.0 / alpha) / raw_mean
    unit = jnp.select(
        [spec.dist_id[k] == 0, spec.dist_id[k] == 1, spec.dist_id[k] == 2],
        [jnp.float32(1.0), s_exp, s_par],
    )
    return jnp.maximum(jnp.round(unit * mean), 1.0).astype(jnp.int32)


def _sim_lane(spec: _SpecArrays, seed: jnp.ndarray, *, n_requests: int,
              warmup: int, mpl: int, max_events: int, trace_cap: int = 0,
              bmiss=None):
    """One (p_hit, seed) lane of the closed-loop simulation.

    Shared verbatim by the pallas kernel body and the vmapped CPU twin.
    Returns (x, completed, events, t_measured_us) — plus the filled
    :class:`~repro.obs.trace.TraceRings` when ``trace_cap > 0``
    (``bmiss`` is then the (B,) per-branch miss-class table; tracing
    draws no RNG, so the simulated system is bit-identical either way).
    """
    n = mpl
    base = _mix(seed.astype(jnp.uint32) + _GOLDEN)

    def u01(ctr):
        z = _mix(base + jnp.asarray(ctr).astype(jnp.uint32) * _GOLDEN)
        u = (z >> np.uint32(8)).astype(jnp.float32) * _INV24
        return jnp.clip(u, 1e-7, 1.0 - 1e-7)

    def pick_branch(u):
        # searchsorted-left over the cumulative branch law
        return jnp.sum(spec.branch_cum < u).astype(jnp.int32)

    # --- init: all mpl jobs start a request at their (think) first station.
    idx = jnp.arange(n, dtype=jnp.int32)
    branch0 = jnp.sum(
        spec.branch_cum[None, :] < u01(idx)[:, None], axis=1
    ).astype(jnp.int32)
    station0 = spec.visits[branch0, 0]
    svc0 = jax.vmap(lambda u, k: _service_ns(u, spec, k))(u01(n + idx),
                                                          station0)

    carry = (
        svc0,                                    # ready_ns (N,)
        station0,                                # station (N,)
        branch0,                                 # branch (N,)
        jnp.zeros((n,), jnp.int32),              # pos (N,)
        jnp.full((n,), BIG_SEQ),                 # enq_seq (N,)
        jnp.zeros(spec.is_queue.shape, jnp.int32),  # busy_count (K,)
        jnp.int32(0),                            # seq_ctr
        jnp.int32(0),                            # completed
        jnp.float32(0.0),                        # elapsed_us
        jnp.int32(-1),                           # warm_completed
        jnp.float32(0.0),                        # warm_elapsed_us
        jnp.int32(2 * n),                        # rng counter
        jnp.int32(0),                            # events
    ) + init_trace(trace_cap, n, spec.visits.shape[1])

    def cond(carry):
        completed, events = carry[7], carry[12]
        return (completed < n_requests) & (events < max_events)

    def body(carry):
        (ready_ns, station, branch, pos, enq_seq, busy_count, seq_ctr,
         completed, elapsed_us, warm_completed, warm_elapsed_us, ctr,
         events) = carry[:13]
        if trace_cap:
            rings, scr = carry[13], carry[14]
        u_svc1 = u01(ctr)
        u_svc2 = u01(ctr + 1)
        u_branch = u01(ctr + 2)
        ctr = ctr + 3

        j = jnp.argmin(ready_ns).astype(jnp.int32)
        t = ready_ns[j]
        finite = ready_ns < INF_NS
        ready = jnp.where(finite, ready_ns - t, INF_NS)
        elapsed_us = elapsed_us + t.astype(jnp.float32) * 1e-3
        k_cur = station[j]

        # ---- hand the server job j held (if any) to its FIFO successor.
        def release(args):
            ready, busy_count, enq_seq = args
            waiting = (station == k_cur) & (ready == INF_NS)
            waiting = waiting.at[j].set(False)
            seqs = jnp.where(waiting, enq_seq, BIG_SEQ)
            w = jnp.argmin(seqs).astype(jnp.int32)
            has_waiter = seqs[w] < BIG_SEQ
            svc = _service_ns(u_svc1, spec, k_cur)
            ready = jnp.where(has_waiter, ready.at[w].set(svc), ready)
            enq_seq = jnp.where(has_waiter, enq_seq.at[w].set(BIG_SEQ),
                                enq_seq)
            busy_count = busy_count.at[k_cur].add(
                jnp.where(has_waiter, 0, -1).astype(jnp.int32)
            )
            return ready, busy_count, enq_seq

        ready, busy_count, enq_seq = lax.cond(
            spec.is_queue[k_cur], release, lambda a: a,
            (ready, busy_count, enq_seq),
        )

        # ---- advance job j along its route (or complete + restart).
        nxt_pos = pos[j] + 1
        route_len = spec.visits.shape[1]
        route_next = jnp.where(
            nxt_pos < route_len,
            spec.visits[branch[j], nxt_pos % route_len], -1,
        )
        done = route_next < 0
        new_branch = pick_branch(u_branch)
        branch_j = jnp.where(done, new_branch, branch[j])
        pos_j = jnp.where(done, 0, nxt_pos)
        k_next = jnp.where(done, spec.visits[new_branch, 0], route_next)
        if trace_cap:
            # Stamp j's departure from its current visit; on completion
            # emit the finished request's record (req id = completed so
            # far — the same id the threefry engine would assign).
            leave_m = scr.leave_us.at[j, pos[j]].set(elapsed_us)
            cls_j = jnp.where(bmiss[branch[j]], CLS_MISS,
                              CLS_HIT).astype(jnp.int32)
            rings = ring_write_one(rings, done, completed, branch[j], cls_j,
                                   pos[j] + 1, jnp.float32(0.0),
                                   scr.enter_us[j], leave_m[j])
            scr = TraceScratch(
                enter_us=scr.enter_us.at[j, pos_j].set(elapsed_us),
                leave_us=leave_m,
            )
        completed = completed + done.astype(jnp.int32)

        # ---- place j at k_next.
        svc_next = _service_ns(u_svc2, spec, k_next)
        is_q = spec.is_queue[k_next]
        has_slot = busy_count[k_next] < spec.servers[k_next]
        starts_now = (~is_q) | has_slot
        waits = ~starts_now
        ready = ready.at[j].set(jnp.where(starts_now, svc_next, INF_NS))
        enq_seq = enq_seq.at[j].set(jnp.where(waits, seq_ctr, BIG_SEQ))
        seq_ctr = seq_ctr + waits.astype(jnp.int32)
        busy_count = busy_count.at[k_next].add(
            (is_q & starts_now).astype(jnp.int32)
        )

        # ---- warmup bookkeeping.
        warm_now = (completed >= warmup) & (warm_completed < 0)
        warm_completed = jnp.where(warm_now, completed, warm_completed)
        warm_elapsed_us = jnp.where(warm_now, elapsed_us, warm_elapsed_us)

        return (ready, station.at[j].set(k_next), branch.at[j].set(branch_j),
                pos.at[j].set(pos_j), enq_seq, busy_count, seq_ctr,
                completed, elapsed_us, warm_completed, warm_elapsed_us, ctr,
                events + 1) + ((rings, scr) if trace_cap else ())

    carry = lax.while_loop(cond, body, carry)
    (_, _, _, _, _, _, _, completed, elapsed_us, warm_completed,
     warm_elapsed_us, _, events) = carry[:13]
    n_measured = completed - warm_completed
    t_measured = jnp.maximum(elapsed_us - warm_elapsed_us, 1e-6)
    x = n_measured.astype(jnp.float32) / t_measured
    if trace_cap:
        return x, completed, events, t_measured, carry[13]
    return x, completed, events, t_measured


@functools.partial(jax.jit,
                   static_argnames=("n_requests", "warmup", "mpl",
                                    "max_events", "trace_cap"))
def _twin_grid(spec_arrays, seeds, bmiss=None, *, n_requests: int,
               warmup: int, mpl: int, max_events: int, trace_cap: int = 0):
    if trace_cap:
        def lane_tr(sp, seed, bm):
            return _sim_lane(_SpecArrays(*sp), seed, n_requests=n_requests,
                             warmup=warmup, mpl=mpl, max_events=max_events,
                             trace_cap=trace_cap, bmiss=bm)

        return jax.vmap(lane_tr, in_axes=(0, 0, 0))(spec_arrays, seeds,
                                                    bmiss)

    def lane(sp, seed):
        return _sim_lane(_SpecArrays(*sp), seed, n_requests=n_requests,
                         warmup=warmup, mpl=mpl, max_events=max_events)

    return jax.vmap(lane, in_axes=(0, 0))(spec_arrays, seeds)


def _sim_kernel(isq_ref, svc_ref, did_ref, dpar_ref, bcum_ref, visits_ref,
                srv_ref, seed_ref, x_ref, comp_ref, ev_ref, tmeas_ref, *,
                n_requests: int, warmup: int, mpl: int, max_events: int):
    spec = _SpecArrays(
        is_queue=isq_ref[0] != 0,
        svc_ns=svc_ref[0],
        dist_id=did_ref[0],
        dist_params=dpar_ref[0],
        branch_cum=bcum_ref[0],
        visits=visits_ref[0],
        servers=srv_ref[0],
    )
    x, completed, events, t_meas = _sim_lane(
        spec, seed_ref[0], n_requests=n_requests, warmup=warmup, mpl=mpl,
        max_events=max_events,
    )
    x_ref[0] = x
    comp_ref[0] = completed
    ev_ref[0] = events
    tmeas_ref[0] = t_meas


def _sim_kernel_traced(isq_ref, svc_ref, did_ref, dpar_ref, bcum_ref,
                       visits_ref, srv_ref, seed_ref, bmiss_ref, x_ref,
                       comp_ref, ev_ref, tmeas_ref, tn_ref, treq_ref,
                       tbr_ref, tcls_ref, tnv_ref, tpk_ref, ten_ref,
                       tlv_ref, *, n_requests: int, warmup: int, mpl: int,
                       max_events: int, trace_cap: int):
    """Traced variant of :func:`_sim_kernel` — the ring-buffer outputs ride
    along as extra (shape-static, ``trace_cap + 1``-row) out refs."""
    spec = _SpecArrays(
        is_queue=isq_ref[0] != 0,
        svc_ns=svc_ref[0],
        dist_id=did_ref[0],
        dist_params=dpar_ref[0],
        branch_cum=bcum_ref[0],
        visits=visits_ref[0],
        servers=srv_ref[0],
    )
    x, completed, events, t_meas, rings = _sim_lane(
        spec, seed_ref[0], n_requests=n_requests, warmup=warmup, mpl=mpl,
        max_events=max_events, trace_cap=trace_cap,
        bmiss=bmiss_ref[0] != 0,
    )
    x_ref[0] = x
    comp_ref[0] = completed
    ev_ref[0] = events
    tmeas_ref[0] = t_meas
    tn_ref[0] = rings.n_count
    treq_ref[0] = rings.req
    tbr_ref[0] = rings.branch
    tcls_ref[0] = rings.cls
    tnv_ref[0] = rings.nvis
    tpk_ref[0] = rings.parked_us
    ten_ref[0] = rings.enter_us
    tlv_ref[0] = rings.leave_us


def _pallas_grid(spec_arrays, seeds, bmiss=None, *, n_requests: int,
                 warmup: int, mpl: int, max_events: int, interpret: bool,
                 trace_cap: int = 0):
    isq, svc, did, dpar, bcum, visits, srv = spec_arrays
    n_lanes = seeds.shape[0]
    n_k = isq.shape[1]
    n_b, n_l = visits.shape[1], visits.shape[2]

    def row(*block):
        return pl.BlockSpec(block, lambda i: (i,) + (0,) * (len(block) - 1))

    in_specs = [
        row(1, n_k), row(1, n_k), row(1, n_k), row(1, n_k, 4),
        row(1, n_b), row(1, n_b, n_l), row(1, n_k), row(1),
    ]
    out_specs = [row(1), row(1), row(1), row(1)]
    out_shape = [
        jax.ShapeDtypeStruct((n_lanes,), jnp.float32),
        jax.ShapeDtypeStruct((n_lanes,), jnp.int32),
        jax.ShapeDtypeStruct((n_lanes,), jnp.int32),
        jax.ShapeDtypeStruct((n_lanes,), jnp.float32),
    ]
    operands = [isq.astype(jnp.int32), svc, did, dpar, bcum, visits, srv,
                seeds]
    if trace_cap:
        cap1 = trace_cap + 1
        kernel = functools.partial(
            _sim_kernel_traced, n_requests=n_requests, warmup=warmup,
            mpl=mpl, max_events=max_events, trace_cap=trace_cap,
        )
        in_specs.append(row(1, n_b))
        operands.append(bmiss.astype(jnp.int32))
        out_specs += [row(1), row(1, cap1), row(1, cap1), row(1, cap1),
                      row(1, cap1), row(1, cap1), row(1, cap1, n_l),
                      row(1, cap1, n_l)]
        out_shape += [
            jax.ShapeDtypeStruct((n_lanes,), jnp.int32),
            jax.ShapeDtypeStruct((n_lanes, cap1), jnp.int32),
            jax.ShapeDtypeStruct((n_lanes, cap1), jnp.int32),
            jax.ShapeDtypeStruct((n_lanes, cap1), jnp.int32),
            jax.ShapeDtypeStruct((n_lanes, cap1), jnp.int32),
            jax.ShapeDtypeStruct((n_lanes, cap1), jnp.float32),
            jax.ShapeDtypeStruct((n_lanes, cap1, n_l), jnp.float32),
            jax.ShapeDtypeStruct((n_lanes, cap1, n_l), jnp.float32),
        ]
    else:
        kernel = functools.partial(_sim_kernel, n_requests=n_requests,
                                   warmup=warmup, mpl=mpl,
                                   max_events=max_events)

    out = pl.pallas_call(
        kernel,
        grid=(n_lanes,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(*operands)
    return out


def simulate_grid_pallas(net, p_hits, n_requests: int = 40_000,
                         seeds: Sequence[int] = (0, 1, 2),
                         warmup_frac: float = 0.25,
                         interpret: Optional[bool] = None,
                         trace: int = 0) -> SimResult:
    """Closed-loop (p_hit x seed) grid on the counter-RNG event engine.

    Same grid construction, warmup and summary statistics as
    ``simulate_network`` (per-p_hit specs tiled across seeds, one lane per
    cell, ONE dispatch for the whole grid), but every lane runs
    :func:`_sim_lane` — the kernel-resident event loop.  Agreement with
    the threefry scan engine is statistical; the pallas kernel and the
    CPU twin are bit-identical by shared code.

    ``trace=K`` keeps the last K per-request trace records per lane in a
    kernel-resident ring buffer (shape-static: K is baked into the
    compiled kernel) and decodes them onto the result's ``traces`` field,
    the same schema as the threefry engine's; ``trace=0`` compiles no
    tracing at all.
    """
    p_hits = np.atleast_1d(np.asarray(p_hits, dtype=np.float64))
    specs = [compile_network(net, float(p)) for p in p_hits]
    spec = stack_specs(specs)
    warmup = int(n_requests * warmup_frac)
    max_events = int(n_requests * (spec.visits.shape[-1] + 2) * 3)
    n_p, n_s = len(p_hits), len(seeds)
    trace = int(trace)

    def tile(a):
        return jnp.concatenate([a] * n_s, axis=0) if n_s > 1 else a

    # drop disk_rank (index 7) and the static mpl: the closed-loop
    # non-coalescing kernel never touches the MSHR machinery
    spec_arrays = tuple(tile(a) for a in spec[:7])
    seed_v = jnp.concatenate(
        [jnp.full((n_p,), s, jnp.int32) * 1000
         + jnp.arange(n_p, dtype=jnp.int32) for s in seeds]
    )
    bmiss_v = None
    if trace:
        # Per-branch sojourn class, precomputed host-side (the kernel's
        # _SpecArrays carries no disk_rank): a branch whose route touches
        # a backing store is a miss, anything else a hit (the pallas
        # engine is closed-loop non-coalescing — no delayed hits).
        vis = np.asarray(specs[0].visits)
        dr = np.asarray(specs[0].disk_rank)
        bmiss = ((dr[np.maximum(vis, 0)] >= 0) & (vis >= 0)).any(axis=1)
        bmiss_v = jnp.asarray(
            np.broadcast_to(bmiss, (n_p * n_s, bmiss.shape[0]))
        )

    if interpret is None and jax.default_backend() != "tpu":
        out = _twin_grid(spec_arrays, seed_v, bmiss_v,
                         n_requests=n_requests, warmup=warmup, mpl=net.mpl,
                         max_events=max_events, trace_cap=trace)
        rings = out[4] if trace else None
    else:
        out = _pallas_grid(
            spec_arrays, seed_v, bmiss_v, n_requests=n_requests,
            warmup=warmup, mpl=net.mpl, max_events=max_events,
            interpret=bool(interpret) if interpret is not None else False,
            trace_cap=trace,
        )
        rings = TraceRings(*out[4:12]) if trace else None
    traces = None
    if trace:
        traces = decode_trace_grid(rings, specs[0].visits, n_s, n_p)
    xs = np.asarray(out[0]).reshape(n_s, n_p)
    mean = xs.mean(axis=0)
    ci = (1.96 * xs.std(axis=0, ddof=1) / math.sqrt(n_s) if n_s > 1
          else np.zeros_like(mean))
    return SimResult(p_hit=p_hits, throughput=mean, ci95=ci,
                     n_requests=n_requests, traces=traces)
