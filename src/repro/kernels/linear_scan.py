"""Chunked WKV6 linear-attention scan as a Pallas TPU kernel.

The RWKV6 recurrence S' = diag(w_t)·S + k_t⊗v_t is memory-bound when run
step-by-step from HBM.  The TPU adaptation keeps the (dh × dh) state
resident in VMEM scratch while streaming (r,k,v,w) chunks HBM->VMEM:
grid = (B·H, T/chunk) with the chunk axis sequential, inner fori_loop over
the chunk.  This is the optimized counterpart of the lax.scan reference in
repro/models/rwkv.py (_wkv_scan), which is its correctness oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_ref, *,
                chunk: int, dh: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)  # (1, dh) -> broadcast over k-dim
    u_col = u.reshape(dh, 1)

    def step(t, S):
        rt = r_ref[0, t].astype(jnp.float32).reshape(dh, 1)  # (dh,1)
        kt = k_ref[0, t].astype(jnp.float32).reshape(dh, 1)
        vt = v_ref[0, t].astype(jnp.float32).reshape(1, dh)
        wt = w_ref[0, t].astype(jnp.float32).reshape(dh, 1)
        kv = kt * vt  # (dh, dh) outer product
        y = jnp.sum(rt * (S + u_col * kv), axis=0)  # (dh,)
        y_ref[0, t] = y.astype(y_ref.dtype)
        return wt * S + kv

    s_ref[...] = jax.lax.fori_loop(0, chunk, step, s_ref[...])


def wkv6_scan(r, k, v, w, u, *, chunk: int = 128, interpret: bool = False):
    """r,k,v,w: (B, T, H, dh); u: (H, dh).  Returns y: (B, T, H, dh).

    State starts at zero (training/prefill from scratch); T must be a
    multiple of `chunk` (the wrapper in ops.py pads).
    """
    B, T, H, dh = r.shape
    assert T % chunk == 0

    def to_bh(x):  # (B,T,H,dh) -> (B*H, T, dh)
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, dh)

    rb, kb, vb, wb = map(to_bh, (r, k, v, w))
    n_chunks = T // chunk

    kernel = functools.partial(_wkv_kernel, chunk=chunk, dh=dh)
    yb = pl.pallas_call(
        kernel,
        grid=(B * H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, dh), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, dh), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, dh), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, dh), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, dh), lambda bh, ci, H=H: (bh % H, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dh), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T, dh), r.dtype),
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(rb, kb, vb, wb, u)
    return yb.reshape(B, H, T, dh).transpose(0, 2, 1, 3)
