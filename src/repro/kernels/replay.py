"""Pallas replay engine: the whole (capacity x seed) grid in one dispatch.

This is the promotion of :func:`repro.kernels.cache_update.lru_batch_update`
from demo to engine.  That kernel showed the layout move — recency as a
timestamp array, victim search as a masked argmin — on a single batched
update; here the same flat layout (:mod:`repro.cache.flat`) carries a
*full trace replay* for every policy in the suite:

* the pallas grid axis enumerates (capacity x seed) lanes,
* each lane's cache state (key->slot table, timestamp/presence/bit
  vectors, scalar registers) lives in kernel scratch for the whole
  replay — nothing round-trips through HBM between requests,
* a ``fori_loop`` walks the request stream, calling the *same* pure
  per-policy step functions the CPU twin scans over, and
* the delayed-hit classifier (prong C's ``classify_inflight``) is fused
  into the same loop via a per-key fetch-expiry table in scratch, so the
  Mattson-style sweep + classification pipeline is ONE dispatch instead
  of replay -> host -> classify -> host.

The scan-policy evictions (CLOCK / SIEVE / S3-FIFO) run their hand scans
*inside* the kernel body as bounded ``lax.while_loop``s over the scratch
state — bounded by ``max_scan`` (CLOCK/S3) or the capacity (SIEVE's bit
clearing), emitting the exact (hit, evicted, op-vector) outputs of the
dlist engine.

Three executables share the step functions, so they agree by construction
and are pinned bit-identical in ``tests/test_pallas_replay.py``:

``interpret=None``  auto: the compiled vmapped ``lax.scan`` twin on CPU
                    (single jitted dispatch), the real kernel on TPU
``interpret=True``  the pallas interpreter — the CI fallback that runs the
                    actual kernel body on CPU (slow: grid cells execute
                    sequentially; tests only)
``interpret=False`` force ``pallas_call`` compilation (TPU)

Op vectors are returned *packed* (one int32 per request, see
``flat.pack_ops``) to keep the kernel's output streams narrow; unpack at
the host boundary with ``flat.unpack_ops``.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.cache import flat
from repro.cache.replay import (DELAYED_HIT, TRUE_HIT, TRUE_MISS, _FAR_PAST,
                                _resolve_key_space, _window_stream)
from repro.cache.policies import _padded
from repro.kernels import CompilerParams


class PallasReplayResult(NamedTuple):
    """Device-resident replay grid output, shaped (C, S, T).

    ``ops`` is packed (``flat.unpack_ops`` appends the length-4 op axis);
    ``cls`` is the fused delayed-hit classification (int8, the
    ``classify_inflight`` classes) or None when no window was given.
    Everything stays on device — feed ``hits``/``cls`` straight into the
    downstream jitted reductions without a host bounce.
    """

    hits: jax.Array          # (C, S, T) bool
    evicted: jax.Array       # (C, S, T) int32, -1 if none
    ops: jax.Array           # (C, S, T) int32, packed op vectors
    cls: Optional[jax.Array]  # (C, S, T) int8, or None


def _lane_step(policy: str, carry, x, pvec, q):
    """One request on one lane: policy step + fused classification."""
    st, expiry = carry
    t, k, u, w = x
    st, hit, evicted, ops4 = flat.FLAT_STEPS[policy](st, k, u, pvec, q)
    outstanding = t <= expiry[k]
    cls = jnp.where(outstanding, DELAYED_HIT,
                    jnp.where(hit, TRUE_HIT, TRUE_MISS)).astype(jnp.int8)
    starts_fetch = (~outstanding) & (~hit)
    # scatter a selected scalar (O(1)) rather than selecting between whole
    # arrays — a full-width where would copy the (K,) table every request
    expiry = expiry.at[k].set(jnp.where(starts_fetch, t + w, expiry[k]))
    return (st, expiry), (hit, evicted, flat.pack_ops(ops4), cls)


@functools.partial(jax.jit, static_argnames=("policy", "key_space", "pad"))
def _twin_grid(policy: str, pvecs: jax.Array, qs: jax.Array,
               keys: jax.Array, us: jax.Array, windows: jax.Array,
               key_space: int, pad: int):
    """The CPU twin: vmapped lax.scan over lanes, same step as the kernel."""
    state0 = flat.flat_state_init(key_space, pad)
    expiry0 = jnp.full((key_space,), _FAR_PAST, jnp.int32)
    ts_idx = jnp.arange(keys.shape[-1], dtype=jnp.int32)

    def lane(pvec, q, k, u, w):
        def body(carry, x):
            return _lane_step(policy, carry, x, pvec, q)

        _, out = lax.scan(body, (state0, expiry0), (ts_idx, k, u, w))
        return out

    return jax.vmap(lane)(pvecs, qs, keys, us, windows)


def _replay_kernel(pvec_ref, q_ref, keys_ref, us_ref, win_ref,
                   hits_ref, ev_ref, ops_ref, cls_ref,
                   k2s_s, s2k_s, ts_s, bit_s, aux_s, ghost_s, exp_s, regs_s,
                   *, policy: str, key_space: int, pad: int):
    """One grid cell = one (capacity, seed) lane's full replay.

    All cache state lives in scratch; grid cells may share the physical
    scratch allocation, so every field is re-initialised unconditionally
    at cell entry (which is also what makes the lane axis safely
    ``parallel``).
    """
    k2s_s[...] = jnp.full((key_space,), flat.NIL, jnp.int32)
    s2k_s[...] = jnp.full((pad,), flat.NIL, jnp.int32)
    ts_s[...] = jnp.zeros((pad,), jnp.int32)
    bit_s[...] = jnp.zeros((pad,), jnp.int32)
    aux_s[...] = jnp.zeros((pad,), jnp.int32)
    ghost_s[...] = jnp.full((pad,), flat.NIL, jnp.int32)
    exp_s[...] = jnp.full((key_space,), _FAR_PAST, jnp.int32)
    regs_s[...] = jnp.zeros((flat.N_REGS,), jnp.int32).at[flat.R_HAND].set(
        flat.NIL
    )

    pvec = pvec_ref[0]
    q = q_ref[0]
    n_t = keys_ref.shape[1]

    def body(t, _):
        st = flat.FlatState(k2s_s[...], s2k_s[...], ts_s[...], bit_s[...],
                            aux_s[...], ghost_s[...], regs_s[...])
        x = (t, keys_ref[0, t], us_ref[0, t], win_ref[0, t])
        (st, expiry), (hit, evicted, packed, cls) = _lane_step(
            policy, (st, exp_s[...]), x, pvec, q
        )
        k2s_s[...] = st.key2slot
        s2k_s[...] = st.slot2key
        ts_s[...] = st.ts
        bit_s[...] = st.bit
        aux_s[...] = st.aux
        ghost_s[...] = st.ghost
        regs_s[...] = st.regs
        exp_s[...] = expiry
        hits_ref[0, t] = hit.astype(jnp.int32)
        ev_ref[0, t] = evicted
        ops_ref[0, t] = packed
        cls_ref[0, t] = cls.astype(jnp.int32)
        return 0

    lax.fori_loop(0, n_t, body, 0)


def _pallas_grid(policy: str, pvecs, qs, keys, us, windows,
                 key_space: int, pad: int, interpret: bool):
    n_lanes, n_t = keys.shape
    kernel = functools.partial(_replay_kernel, policy=policy,
                               key_space=key_space, pad=pad)
    lane_row = pl.BlockSpec((1, n_t), lambda i: (i, 0))
    hits, evicted, ops, cls = pl.pallas_call(
        kernel,
        grid=(n_lanes,),
        in_specs=[
            pl.BlockSpec((1, flat.N_PARAMS), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            lane_row, lane_row, lane_row,
        ],
        out_specs=[lane_row, lane_row, lane_row, lane_row],
        out_shape=[
            jax.ShapeDtypeStruct((n_lanes, n_t), jnp.int32),
            jax.ShapeDtypeStruct((n_lanes, n_t), jnp.int32),
            jax.ShapeDtypeStruct((n_lanes, n_t), jnp.int32),
            jax.ShapeDtypeStruct((n_lanes, n_t), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((key_space,), jnp.int32),   # key2slot
            pltpu.VMEM((pad,), jnp.int32),         # slot2key
            pltpu.VMEM((pad,), jnp.int32),         # ts
            pltpu.VMEM((pad,), jnp.int32),         # bit
            pltpu.VMEM((pad,), jnp.int32),         # aux
            pltpu.VMEM((pad,), jnp.int32),         # ghost
            pltpu.VMEM((key_space,), jnp.int32),   # fetch expiry
            pltpu.SMEM((flat.N_REGS,), jnp.int32),  # scalar registers
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(pvecs, qs, keys, us, windows)
    return hits != 0, evicted, ops, cls.astype(jnp.int8)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _lane_inputs(policy: str, keys, us, capacities, key_space, pad_to,
                 params) -> Tuple[np.ndarray, ...]:
    """Host-side lane setup: validate, normalise to (S, T), build per-lane
    parameter vectors, and tile everything to the (C*S,) lane axis
    (lane = c * S + s, so outputs reshape to (C, S, T))."""
    keys = np.asarray(keys)
    us = np.asarray(us)
    if keys.shape != us.shape:
        raise ValueError(f"keys {keys.shape} vs us {us.shape} shape mismatch")
    if keys.ndim == 1:
        keys = keys[None, :]
        us = us[None, :]
    elif keys.ndim != 2:
        raise ValueError(f"keys must be (T,) or (S, T), got {keys.shape}")
    key_space = _resolve_key_space(keys, key_space)
    caps = [int(c) for c in np.atleast_1d(np.asarray(capacities))]
    if not caps:
        raise ValueError("need at least one capacity")
    pad = _padded(max(caps), pad_to)
    per_cap = [flat.flat_lane_params(policy, c, **params) for c in caps]
    pvecs = np.stack([v for v, _ in per_cap])
    qs = np.asarray([q for _, q in per_cap], np.float32)
    n_s = keys.shape[0]
    keys_l = np.tile(keys, (len(caps), 1)).astype(np.int32)
    us_l = np.tile(us, (len(caps), 1)).astype(np.float32)
    pvecs_l = np.repeat(pvecs, n_s, axis=0)
    qs_l = np.repeat(qs, n_s)
    return keys_l, us_l, pvecs_l, qs_l, key_space, pad, len(caps), n_s


def replay_grid_pallas(policy: str, keys, us, capacities, *,
                       key_space: Optional[int] = None,
                       pad_to: Optional[int] = None,
                       window=None, fail_prob: float = 0.0,
                       fail_seed: int = 0,
                       interpret: Optional[bool] = None,
                       **params: Any) -> PallasReplayResult:
    """Replay a (capacity x seed) grid with the flat-state engine, fusing
    the delayed-hit classification into the same dispatch.

    Drop-in grid semantics of :func:`repro.cache.replay.replay_grid` (same
    hits / evicted keys / op counts, bit-identical, pinned by tests) plus
    the ``classify_inflight`` post-pass computed in the same pass over the
    stream when ``window`` is given (scalar or per-request (T,) array;
    ``fail_prob`` stretches windows by geometric re-issue attempts exactly
    like the classifier).

    ``interpret=None`` picks the fastest correct executable for the
    backend: the real pallas kernel on TPU, the jitted scan twin on CPU
    (same step functions, one dispatch).  ``True`` forces the pallas
    interpreter (the kernel body itself, run on CPU — the CI fallback).
    """
    (keys_l, us_l, pvecs_l, qs_l, key_space, pad,
     n_caps, n_s) = _lane_inputs(policy, keys, us, capacities, key_space,
                                 pad_to, params)
    win_l = np.broadcast_to(
        _window_stream(window, keys_l.shape[1], fail_prob, fail_seed),
        keys_l.shape,
    )
    args = (jnp.asarray(pvecs_l), jnp.asarray(qs_l), jnp.asarray(keys_l),
            jnp.asarray(us_l), jnp.asarray(win_l))
    if interpret is None and not _on_tpu():
        hits, evicted, ops, cls = _twin_grid(
            policy, *args, key_space=key_space, pad=pad
        )
    else:
        hits, evicted, ops, cls = _pallas_grid(
            policy, *args, key_space=key_space, pad=pad,
            interpret=bool(interpret) if interpret is not None else False,
        )
    shape = (n_caps, n_s, keys_l.shape[1])
    return PallasReplayResult(
        hits=hits.reshape(shape),
        evicted=evicted.reshape(shape),
        ops=ops.reshape(shape),
        cls=cls.reshape(shape) if window is not None else None,
    )


def unpack_grid_ops(res: PallasReplayResult) -> np.ndarray:
    """Host-side (C, S, T, 4) int64 op counts, matching ReplayResult.ops."""
    return np.asarray(flat.unpack_ops(res.ops), np.int64)
