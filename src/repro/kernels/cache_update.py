"""Batched LRU metadata update — the paper's bottleneck, TPU-adapted.

The paper shows LRU throughput collapses because every *hit* serializes a
delink + head-update on a global linked list (demand = p_hit · S_delink per
request).  A linked list is the wrong structure for a TPU: the adaptation
(DESIGN.md §3) replaces it with a recency-timestamp array and performs a
whole batch of N accesses as ONE vectorized sweep:

    timestamps[slot in batch] <- now ;  victim = argmin(timestamps)

The sweep is tiled over VMEM (grid over slot tiles, each tile compared
against the access batch), so its cost is O(C / membw) *per batch* instead
of O(N · S_delink) serialized — the per-request demand on the serialized
resource drops by ~N·S_delink / (C/membw), which pushes the critical hit
ratio p* -> 1 (quantified in benchmarks/serving_integration.py).

Eviction semantics match LRU exactly: argmin of last-access time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

INT_MAX = jnp.int32(2**31 - 1)


def _sweep_kernel(ts_ref, acc_ref, now_ref, new_ts_ref, min_ref, arg_ref, *,
                  tile: int):
    gi = pl.program_id(0)
    ts = ts_ref[...]  # (tile,)
    accessed = acc_ref[...]  # (N,)
    now = now_ref[0]

    ids = gi * tile + jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0)[:, 0]
    hit = jnp.any(ids[:, None] == accessed[None, :], axis=1)
    new_ts = jnp.where(hit, now, ts)
    new_ts_ref[...] = new_ts

    # per-tile min + argmin (final cross-tile reduction happens in ops.py)
    tile_min = jnp.min(new_ts)
    min_ref[0] = tile_min
    arg_ref[0] = ids[jnp.argmin(new_ts)]


def lru_batch_update(timestamps, accessed, now, *, tile: int = 512,
                     interpret: bool = False):
    """timestamps: (C,) int32; accessed: (N,) int32 slot ids (pad with -1);
    now: scalar int32.  Returns (new_timestamps, victim_slot).

    victim = least-recently-used slot AFTER the batch is applied.
    """
    C = timestamps.shape[0]
    N = accessed.shape[0]
    tile = min(tile, C)
    # Pad to the next tile multiple with INT_MAX sentinels.  Slot ids past C
    # never appear in `accessed` (ids are < C, padding is -1), so sentinels
    # survive the sweep untouched and can never win the argmin victim search
    # (any real slot's timestamp is < INT_MAX).
    pad = (-C) % tile
    if pad:
        timestamps = jnp.concatenate(
            [timestamps, jnp.full((pad,), INT_MAX, jnp.int32)]
        )
    n_tiles = (C + pad) // tile

    kernel = functools.partial(_sweep_kernel, tile=tile)
    new_ts, mins, args = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((N,), lambda i: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C + pad,), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles,), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles,), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(timestamps, accessed, jnp.asarray([now], jnp.int32))

    best = jnp.argmin(mins)
    return new_ts[:C], args[best]
