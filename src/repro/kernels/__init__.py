# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

"""Version compatibility for the Pallas TPU kernels.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and will
eventually drop the old name).  The pinned toolchain (jax 0.4.37) only has
``TPUCompilerParams``; newer releases only have ``CompilerParams``.  Resolve
whichever exists once, here, so every kernel imports the same symbol.
"""

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(
    _pltpu, "CompilerParams", getattr(_pltpu, "TPUCompilerParams", None)
)
if CompilerParams is None:  # pragma: no cover - future-proofing only
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; unsupported jax version"
    )
