"""Paged decode attention as a Pallas TPU kernel.

The serving layer stores KV in fixed-size pages; a request's pages are
scattered (block table indirection).  The kernel uses **scalar prefetch**:
the block table rides in SMEM and the K/V BlockSpec index maps dereference
it, so Pallas' pipeline logic issues the HBM->VMEM page copies for exactly
the pages each sequence owns — the TPU-native analogue of a gather.

Grid = (B, KV, n_pages); pages are the sequential axis with online-softmax
state in VMEM scratch.  All `group` query heads of a KV head are processed
together (GQA).  Padded pages (beyond seq_len) are masked to -inf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

NEG_INF = -2.0e38


def _paged_kernel(block_table, seq_lens, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale, page: int, n_pages: int):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)  # (page, dh)
    v = v_ref[0, 0].astype(jnp.float32)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (G, page)
    pos = pi * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    valid = pos < seq_lens[b]
    logits = jnp.where(valid, logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )

    @pl.when(pi == n_pages - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


def paged_attention(
    q, pages_k, pages_v, block_table, seq_lens, *, interpret: bool = False
):
    """Decode attention over paged KV.

    q:           (B, H, dh)        one query token per sequence
    pages_k/v:   (P, page, KV, dh) global page pool
    block_table: (B, n_pages) int32 — page ids per sequence (pad with 0)
    seq_lens:    (B,) int32 — valid token count per sequence
    Returns (B, H, dh).
    """
    B, H, dh = q.shape
    P, page, KV, _ = pages_k.shape
    n_pages = block_table.shape[1]
    group = H // KV
    scale = dh**-0.5

    qg = q.reshape(B, KV, group, dh)
    # (P, page, KV, dh) -> (P, KV, page, dh) so a block is one page x head
    kt = pages_k.swapaxes(1, 2)
    vt = pages_v.swapaxes(1, 2)

    kernel = functools.partial(
        _paged_kernel, scale=scale, page=page, n_pages=n_pages
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, group, dh),
                         lambda b, kv, pi, bt, sl: (b, kv, 0, 0)),
            pl.BlockSpec((1, 1, page, dh),
                         lambda b, kv, pi, bt, sl: (bt[b, pi], kv, 0, 0)),
            pl.BlockSpec((1, 1, page, dh),
                         lambda b, kv, pi, bt, sl: (bt[b, pi], kv, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, dh),
                               lambda b, kv, pi, bt, sl: (b, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, group, dh), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_table, seq_lens, qg, kt, vt)
    return out.reshape(B, H, dh)
