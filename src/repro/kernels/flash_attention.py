"""Flash attention (forward) as a Pallas TPU kernel.

Tiling: grid = (batch, q_heads, T/bq, S/bk); the KV axis is the innermost
(sequential) grid dimension, with the online-softmax running state (m, l,
acc) held in VMEM scratch across KV steps.  Block shapes are MXU-aligned
(bq, bk multiples of 128; d_head padded by the caller if needed).  GQA is
handled in the K/V index maps (kv_head = q_head // group), so grouped K/V
blocks are fetched once per group without materializing a repeat.

Causal and sliding-window (local) masks are applied from global indices.
Validated on CPU via interpret=True against kernels/ref.py; on TPU the same
call lowers to a pipelined VMEM kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  nk: int, seq_q: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, dh)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, dh)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, dh)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bk)

    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = cols < seq_k
    if causal:
        valid = valid & (cols <= rows)
    if window > 0:
        valid = valid & (cols > rows - window)
    logits = jnp.where(valid, logits, NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


def flash_attention_fwd(
    q, k, v, *, causal: bool = True, window: int = 0,
    bq: int = 128, bk: int = 128, interpret: bool = False,
):
    """q: (B, H, T, dh); k, v: (B, KV, S, dh).  Returns (B, H, T, dh)."""
    B, H, T, dh = q.shape
    KV, S = k.shape[1], k.shape[2]
    assert H % KV == 0, "GQA requires H % KV == 0"
    group = H // KV
    scale = dh**-0.5

    bq = min(bq, T)
    bk = min(bk, S)
    nq = -(-T // bq)
    nk = -(-S // bk)
    if T % bq or S % bk:
        # pad sequence dims to block multiples; masked out via seq_k
        q = jnp.pad(q, ((0, 0), (0, 0), (0, nq * bq - T), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, nk * bk - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, nk * bk - S), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk, seq_q=T, seq_k=S,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :T]
