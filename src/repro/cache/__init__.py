"""repro.cache — array-based, jittable cache eviction policies (prong C).

The policies of the paper's Table 1 (+ SIEVE), each available in two
property-tested-equivalent forms:

  * :mod:`repro.cache.policies` — pure-JAX, jit/scan-compatible, for
    on-device use and the TPU-batched adaptation;
  * :mod:`repro.cache.py_ref`  — Python references, used by the host-side
    serving controller and as hypothesis oracles.

:mod:`repro.cache.replay` batches the JAX policies into a compiled
(capacity x seed) trace-replay grid — the fast path of the prong-C
measurement harness.  The linked-list primitives in
:mod:`repro.cache.dlist` map 1:1 to the paper's queue stations
(delink / head update / tail update).
"""

from repro.cache.policies import POLICIES, AccessResult, OpCounts, run_trace
from repro.cache.py_ref import PY_POLICIES, classify_inflight_py
from repro.cache.replay import (
    DELAYED_HIT,
    TRUE_HIT,
    TRUE_MISS,
    ReplayResult,
    classify_inflight,
    lru_sweep,
    refetch_attempts,
    replay_grid,
    replay_trace,
)

__all__ = [
    "POLICIES", "PY_POLICIES", "AccessResult", "OpCounts", "run_trace",
    "ReplayResult", "lru_sweep", "replay_grid", "replay_trace",
    "classify_inflight", "classify_inflight_py", "refetch_attempts",
    "TRUE_MISS", "TRUE_HIT", "DELAYED_HIT",
]
