"""Pure-Python reference implementations of the cache eviction policies.

Semantics mirror :mod:`repro.cache.policies` exactly — same warmup slot
allocation, same bounded scans, same op accounting — so hypothesis-based
property tests can compare hit/eviction/op sequences element-wise.

These are also what the *host-side* serving controller uses (the cache
controller runs in Python on the host; the JAX versions are for on-device /
in-step use and for the batched TPU adaptation in kernels/cache_update.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Access:
    hit: bool
    evicted_key: int  # -1 if none
    ops: tuple  # (delink, head, tail, scan)


class _KeyList(list):
    """Key list with an O(1) membership set kept in sync.

    Cache lists hold each key at most once, and are only mutated through
    ``insert`` / ``pop`` / ``remove`` — exactly the operations shadowed here.
    Rebuilding ``set(self)`` per membership probe (the old ``_ListCache``
    behaviour) made every access O(n) with a hidden allocation, which times
    out the hypothesis differential tests and the host-side serving
    controller at realistic capacities.
    """

    def __init__(self, iterable=()):
        super().__init__(iterable)
        self._set = set(self)

    def insert(self, index, key):
        super().insert(index, key)
        self._set.add(key)

    def append(self, key):
        super().append(key)
        self._set.add(key)

    def pop(self, index=-1):
        key = super().pop(index)
        self._set.discard(key)
        return key

    def remove(self, key):
        super().remove(key)
        self._set.discard(key)

    def __contains__(self, key):
        return key in self._set


class _ListCache:
    """Shared machinery: key list ordered head(0) .. tail(-1)."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.order: _KeyList = _KeyList()  # keys

    def __contains__(self, key):
        return key in self.order


class LRU(_ListCache):
    name = "lru"
    lru_like = True

    def access(self, key: int, u: float = 0.0) -> Access:
        if key in self.order:
            self.order.remove(key)  # delink
            self.order.insert(0, key)  # head update
            return Access(True, -1, (1, 1, 0, 0))
        evicted = -1
        tail = 0
        if len(self.order) >= self.capacity:
            evicted = self.order.pop()  # tail update
            tail = 1
        self.order.insert(0, key)  # head update
        return Access(False, evicted, (0, 1, tail, 0))


class FIFO(_ListCache):
    name = "fifo"
    lru_like = False

    def access(self, key: int, u: float = 0.0) -> Access:
        if key in self.order:
            return Access(True, -1, (0, 0, 0, 0))
        evicted = -1
        tail = 0
        if len(self.order) >= self.capacity:
            evicted = self.order.pop()
            tail = 1
        self.order.insert(0, key)
        return Access(False, evicted, (0, 1, tail, 0))


class ProbLRU(_ListCache):
    name = "prob_lru"
    lru_like = True

    def __init__(self, capacity: int, q: float = 0.5):
        super().__init__(capacity)
        # float32 threshold: the jax implementation compares the coin
        # against float32(q), and the harness coin stream is float32 — a
        # float64 q here would diverge from the jax backend whenever a
        # coin lands exactly on float32(q) (non-representable q like
        # 1 - 1/72 rounds DOWN in float32).
        self.q = float(np.float32(q))

    def access(self, key: int, u: float = 0.0) -> Access:
        if key in self.order:
            if u >= self.q:  # promote with prob 1-q
                self.order.remove(key)
                self.order.insert(0, key)
                return Access(True, -1, (1, 1, 0, 0))
            return Access(True, -1, (0, 0, 0, 0))
        evicted = -1
        tail = 0
        if len(self.order) >= self.capacity:
            evicted = self.order.pop()
            tail = 1
        self.order.insert(0, key)
        return Access(False, evicted, (0, 1, tail, 0))


class Clock(_ListCache):
    name = "clock"
    lru_like = False

    def __init__(self, capacity: int, max_scan: int = 3):
        super().__init__(capacity)
        self.max_scan = max_scan
        self.bit: dict = {}

    def _evict(self):
        scans = 0
        heads = 0
        while True:
            s = self.order[-1]
            if self.bit.get(s, False) and scans < self.max_scan:
                self.order.pop()
                self.order.insert(0, s)  # reinsert (head update)
                self.bit[s] = False
                scans += 1
                heads += 1
            else:
                self.order.pop()
                self.bit.pop(s, None)
                return s, (0, heads, 1, scans)

    def access(self, key: int, u: float = 0.0) -> Access:
        if key in self.order:
            self.bit[key] = True
            return Access(True, -1, (0, 0, 0, 0))
        evicted = -1
        ops = (0, 0, 0, 0)
        if len(self.order) >= self.capacity:
            evicted, ops = self._evict()
        self.order.insert(0, key)
        self.bit[key] = False
        ops = (ops[0], ops[1] + 1, ops[2], ops[3])
        return Access(False, evicted, ops)


class SLRU:
    name = "slru"
    lru_like = True

    def __init__(self, capacity: int, protected_frac: float = 0.5):
        self.capacity = capacity
        self.protected_cap = max(1, int(capacity * protected_frac))
        self.B: _KeyList = _KeyList()  # probationary, head..tail
        self.T: _KeyList = _KeyList()  # protected

    def access(self, key: int, u: float = 0.0) -> Access:
        if key in self.T:
            self.T.remove(key)
            self.T.insert(0, key)
            return Access(True, -1, (1, 1, 0, 0))
        if key in self.B:
            self.B.remove(key)
            self.T.insert(0, key)
            d, h, t = 1, 1, 0
            if len(self.T) > self.protected_cap:
                demoted = self.T.pop()
                self.B.insert(0, demoted)
                t += 1
                h += 1
            return Access(True, -1, (d, h, t, 0))
        evicted = -1
        tail = 0
        if len(self.B) + len(self.T) >= self.capacity:
            if self.B:
                evicted = self.B.pop()
            else:
                evicted = self.T.pop()
            tail = 1
        self.B.insert(0, key)
        return Access(False, evicted, (0, 1, tail, 0))


class S3FIFO:
    name = "s3fifo"
    lru_like = False

    def __init__(self, capacity: int, small_frac: float = 0.1, max_scan: int = 3):
        if capacity < 2:
            # mirror the jax init: m_cap == 0 has no main list to evict from
            raise ValueError(
                "s3fifo needs capacity >= 2 (one small + one main slot)")
        self.capacity = capacity
        self.s_cap = max(1, int(capacity * small_frac))
        self.m_cap = capacity - self.s_cap
        self.S: _KeyList = _KeyList()
        self.M: _KeyList = _KeyList()
        self.bit: dict = {}
        # ghost is a circular buffer mutated by slot assignment, which
        # _KeyList can't shadow — keep its membership set in sync by hand.
        # A key never re-enters S (the only ghost writer) while its ghost
        # entry is live, so the ring holds no duplicates.
        self.ghost = [-1] * max(1, self.m_cap)
        self.ghost_set: set = set()
        self.ghost_pos = 0

    def _evict_m(self, max_scan=None):
        max_scan = self.__dict__.get("max_scan", 3) if max_scan is None else max_scan
        scans = 0
        heads = 0
        while True:
            s = self.M[-1]
            if self.bit.get(s, False) and scans < 3:
                self.M.pop()
                self.M.insert(0, s)
                self.bit[s] = False
                scans += 1
                heads += 1
            else:
                self.M.pop()
                self.bit.pop(s, None)
                return s, (0, heads, 1, scans)

    def access(self, key: int, u: float = 0.0) -> Access:
        if key in self.S or key in self.M:
            self.bit[key] = True
            return Access(True, -1, (0, 0, 0, 0))

        ops = [0, 0, 0, 0]
        evicted = -1
        in_ghost = key in self.ghost_set

        if in_ghost and len(self.M) >= self.m_cap:
            evicted, eops = self._evict_m()
            ops = [a + b for a, b in zip(ops, eops)]

        if (not in_ghost) and len(self.S) >= self.s_cap:
            s_tail = self.S[-1]
            if self.bit.get(s_tail, False):
                if len(self.M) >= self.m_cap:
                    evicted, eops = self._evict_m()
                    ops = [a + b for a, b in zip(ops, eops)]
                self.S.pop()
                self.M.insert(0, s_tail)
                self.bit[s_tail] = False
                ops[1] += 1  # head (M)
                ops[2] += 1  # tail (S)
            else:
                self.S.pop()
                self.bit.pop(s_tail, None)
                old = self.ghost[self.ghost_pos]
                if old >= 0:
                    self.ghost_set.discard(old)
                self.ghost[self.ghost_pos] = s_tail
                self.ghost_set.add(s_tail)
                self.ghost_pos = (self.ghost_pos + 1) % len(self.ghost)
                evicted = s_tail
                ops[2] += 1

        if in_ghost:
            self.M.insert(0, key)
        else:
            self.S.insert(0, key)
        self.bit[key] = False
        ops[1] += 1
        return Access(False, evicted, tuple(ops))


class Sieve(_ListCache):
    name = "sieve"
    lru_like = False

    def __init__(self, capacity: int):
        super().__init__(capacity)
        self.bit: dict = {}
        self.hand: Optional[int] = None  # a key, or None

    def access(self, key: int, u: float = 0.0) -> Access:
        if key in self.order:
            self.bit[key] = True
            return Access(True, -1, (0, 0, 0, 0))
        evicted = -1
        ops = [0, 0, 0, 0]
        if len(self.order) >= self.capacity:
            h = self.hand if (self.hand is not None and self.hand in self.order) else self.order[-1]
            scans = 0
            while self.bit.get(h, False):
                self.bit[h] = False
                i = self.order.index(h)
                h = self.order[i - 1] if i > 0 else self.order[-1]
                scans += 1
            i = self.order.index(h)
            self.hand = self.order[i - 1] if i > 0 else None
            self.order.remove(h)
            self.bit.pop(h, None)
            evicted = h
            ops[2] += 1
            ops[3] += scans
        self.order.insert(0, key)
        self.bit[key] = False
        ops[1] += 1
        return Access(False, evicted, tuple(ops))


PY_POLICIES = {
    "lru": LRU,
    "fifo": FIFO,
    "prob_lru": ProbLRU,
    "clock": Clock,
    "slru": SLRU,
    "s3fifo": S3FIFO,
    "sieve": Sieve,
}


def classify_inflight_py(keys, hits, window, fail_prob: float = 0.0,
                         fail_seed: int = 0) -> np.ndarray:
    """Reference for :func:`repro.cache.replay.classify_inflight` (one lane).

    Same in-flight-window semantics — a true miss on key k at index t
    starts a fetch outstanding through index t + window; any request for k
    inside that window is a delayed hit — as a dict walk instead of a
    vmapped scan.  ``window`` is a scalar or a (T,) array of per-request
    windows (each true miss's fetch carries its own latency).
    ``fail_prob``/``fail_seed`` apply the same TTL failed-fetch re-issue
    stretch (window × Geometric attempts) as the JAX classifier, drawn
    from the identical substream.  Differential oracle for the JAX
    classifier.
    """
    keys = np.asarray(keys)
    hits = np.asarray(hits, bool)
    if keys.shape != hits.shape or keys.ndim != 1:
        raise ValueError("keys and hits must be matching 1-D arrays")
    windows = np.broadcast_to(np.asarray(window, np.int64), keys.shape)
    if np.any(windows < 0):
        raise ValueError("window must be >= 0")
    if fail_prob:
        from repro.cache.replay import refetch_attempts

        windows = windows * refetch_attempts(len(keys), fail_prob, fail_seed)
    from repro.cache.replay import DELAYED_HIT, TRUE_HIT, TRUE_MISS

    expiry: dict = {}  # key -> last index its outstanding fetch covers
    out = np.empty(len(keys), np.int8)
    for t, (k, h, w) in enumerate(zip(keys.tolist(), hits.tolist(),
                                      windows.tolist())):
        if k in expiry and t <= expiry[k]:
            out[t] = DELAYED_HIT
        elif h:
            out[t] = TRUE_HIT
        else:
            out[t] = TRUE_MISS
            expiry[k] = t + w
    return out
