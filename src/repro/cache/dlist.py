"""Array-based doubly-linked list over cache slots — jittable.

This is the paper's "global linked list": the three primitive operations are
exactly the paper's three queue stations:

  * :func:`delink`    — the *delink* operation (S_delink), hit path of LRU
  * :func:`push_head` — the *cache head update* (S_head)
  * :func:`pop_tail`  — the *cache tail update* (S_tail), miss path

On a CPU these serialize under a lock (the paper's bottleneck).  On TPU we
keep them as pure array updates so a whole batch of them can be fused and
vectorized (see kernels/cache_update.py) — the hardware adaptation discussed
in DESIGN.md §3.

Slots are int32 in [0, capacity); -1 is the nil sentinel.  An empty list has
head == tail == -1.  All functions are total: delinking a slot that is not
in the list is undefined behaviour (callers maintain membership).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax import lax

NIL = -1


class DList(NamedTuple):
    prv: jnp.ndarray  # (C,) int32
    nxt: jnp.ndarray  # (C,) int32
    head: jnp.ndarray  # () int32
    tail: jnp.ndarray  # () int32


def empty(capacity: int) -> DList:
    return DList(
        prv=jnp.full((capacity,), NIL, jnp.int32),
        nxt=jnp.full((capacity,), NIL, jnp.int32),
        head=jnp.int32(NIL),
        tail=jnp.int32(NIL),
    )


def delink(dl: DList, s) -> DList:
    """Remove slot ``s`` from the list (the paper's S_delink)."""
    s = jnp.int32(s)
    p, n = dl.prv[s], dl.nxt[s]
    # fix neighbours (guard NIL with clamped writes that we then select away)
    nxt = dl.nxt.at[jnp.maximum(p, 0)].set(jnp.where(p == NIL, dl.nxt[jnp.maximum(p, 0)], n))
    prv = dl.prv.at[jnp.maximum(n, 0)].set(jnp.where(n == NIL, dl.prv[jnp.maximum(n, 0)], p))
    head = jnp.where(dl.head == s, n, dl.head)
    tail = jnp.where(dl.tail == s, p, dl.tail)
    prv = prv.at[s].set(NIL)
    nxt = nxt.at[s].set(NIL)
    return DList(prv, nxt, head, tail)


def push_head(dl: DList, s) -> DList:
    """Attach slot ``s`` at the head (the paper's S_head, cache head update)."""
    s = jnp.int32(s)
    old = dl.head
    nxt = dl.nxt.at[s].set(old)
    prv = dl.prv.at[s].set(NIL)
    prv = prv.at[jnp.maximum(old, 0)].set(jnp.where(old == NIL, prv[jnp.maximum(old, 0)], s))
    tail = jnp.where(dl.tail == NIL, s, dl.tail)
    return DList(prv, nxt, jnp.int32(s), tail)


def pop_tail(dl: DList):
    """Detach and return the tail slot (the paper's S_tail, cache tail update).

    Returns (list, slot); slot == NIL when the list is empty.
    """
    s = dl.tail
    dl2 = lax.cond(s == NIL, lambda d: d, lambda d: delink(d, s), dl)
    return dl2, s


def delink_if(dl: DList, s, pred) -> DList:
    """Predicated :func:`delink`: a no-op when ``pred`` is False.

    Every write is an unconditional gather-select-scatter (the stored value
    is re-written when disabled), so the op stays branch-free under
    ``lax.scan``/``vmap`` — a ``lax.cond`` here forces XLA to copy the whole
    state at the branch boundary, which dominates replay time on CPU.
    """
    s = jnp.int32(s)
    p, n = dl.prv[s], dl.nxt[s]
    ip = jnp.maximum(p, 0)
    nxt = dl.nxt.at[ip].set(jnp.where(pred & (p != NIL), n, dl.nxt[ip]))
    im = jnp.maximum(n, 0)
    prv = dl.prv.at[im].set(jnp.where(pred & (n != NIL), p, dl.prv[im]))
    head = jnp.where(pred & (dl.head == s), n, dl.head)
    tail = jnp.where(pred & (dl.tail == s), p, dl.tail)
    prv = prv.at[s].set(jnp.where(pred, jnp.int32(NIL), prv[s]))
    nxt = nxt.at[s].set(jnp.where(pred, jnp.int32(NIL), nxt[s]))
    return DList(prv, nxt, head, tail)


def push_head_if(dl: DList, s, pred) -> DList:
    """Predicated :func:`push_head`: a no-op when ``pred`` is False.

    Callers must only enable it for a detached slot (same contract as
    ``push_head``).
    """
    s = jnp.int32(s)
    old = dl.head
    nxt = dl.nxt.at[s].set(jnp.where(pred, old, dl.nxt[s]))
    prv = dl.prv.at[s].set(jnp.where(pred, jnp.int32(NIL), dl.prv[s]))
    io = jnp.maximum(old, 0)
    prv = prv.at[io].set(jnp.where(pred & (old != NIL), s, prv[io]))
    head = jnp.where(pred, s, dl.head)
    tail = jnp.where(pred & (dl.tail == NIL), s, dl.tail)
    return DList(prv, nxt, head, tail)


def is_member(dl: DList, s) -> jnp.ndarray:
    """Membership test (O(1) via link fields + head check)."""
    s = jnp.int32(s)
    return (dl.prv[s] != NIL) | (dl.nxt[s] != NIL) | (dl.head == s)


def length(dl: DList, capacity: int) -> jnp.ndarray:
    """O(C) membership count — debugging/tests only."""
    idx = jnp.arange(capacity, dtype=jnp.int32)
    member = (dl.prv[idx] != NIL) | (dl.nxt[idx] != NIL) | (dl.head == idx)
    return member.sum()
