"""Jittable cache eviction policies over a bounded key space.

Every policy in the paper's Table 1 (plus SIEVE from Table 2), implemented
as pure functions over array state so they can run under ``jax.jit`` — on
the host controller, inside a serving step, or on-device.

Uniform interface::

    state = <policy>.init(capacity, key_space, **params)
    state, res = <policy>.access(state, key, u)   # u: uniform sample in [0,1)

``res`` is an :class:`AccessResult` carrying the hit flag, the evicted key
(or -1), and **op counts mapped to the paper's queue stations** (delink /
head-update / tail-update / tail-scan).  The op counts are what couples this
layer to the queueing model: a virtual-time closed-loop harness charges each
op its calibrated service time (see repro.core.harness).

Keys are ints in [0, key_space) — in the serving layer they are KV block
ids, which are bounded by construction.

Shape uniformity (``pad_to``): every ``<policy>_init`` accepts a
``pad_to`` slot-array size >= ``capacity``.  All per-slot arrays are sized
``pad_to`` while the *traced* ``capacity`` scalar bounds warmup and
eviction (the same trick ``lru_batch_update`` uses with INT_MAX
sentinels), so states for *different* capacities share one pytree shape
and stack under ``jax.vmap``.  ``PolicyDef.batched_init`` builds exactly
that stack, which is what lets :mod:`repro.cache.replay` dispatch a whole
(capacity x seed) measurement grid as one compiled program.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.cache import dlist
from repro.cache.dlist import NIL, DList


class OpCounts(NamedTuple):
    delink: jnp.ndarray  # promotions removed from the middle of a list
    head: jnp.ndarray  # head updates
    tail: jnp.ndarray  # tail updates (evictions/demotions)
    scan: jnp.ndarray  # extra tail-scan steps (CLOCK/SIEVE/S3-FIFO)


def _ops(delink=0, head=0, tail=0, scan=0) -> OpCounts:
    return OpCounts(*(jnp.int32(v) for v in (delink, head, tail, scan)))


def _ops_add(a: OpCounts, b: OpCounts) -> OpCounts:
    return OpCounts(*(x + y for x, y in zip(a, b)))


class AccessResult(NamedTuple):
    hit: jnp.ndarray  # bool
    evicted_key: jnp.ndarray  # int32, -1 if none
    slot: jnp.ndarray  # slot the key now occupies
    ops: OpCounts


class Table(NamedTuple):
    """key<->slot mapping over a bounded key space."""

    key2slot: jnp.ndarray  # (K,) int32, NIL when absent
    slot2key: jnp.ndarray  # (P,) int32 — P = pad_to >= capacity
    size: jnp.ndarray  # () int32


def _table_init(slots: int, key_space: int) -> Table:
    return Table(
        key2slot=jnp.full((key_space,), NIL, jnp.int32),
        slot2key=jnp.full((slots,), NIL, jnp.int32),
        size=jnp.int32(0),
    )


def _padded(capacity: int, pad_to) -> int:
    """Resolve the slot-array size: ``pad_to`` (defaulting to capacity)."""
    pad = int(capacity if pad_to is None else pad_to)
    if pad < capacity:
        raise ValueError(f"pad_to={pad} < capacity={capacity}")
    return pad


def _table_assign(t: Table, key, slot) -> Table:
    return Table(t.key2slot.at[key].set(slot), t.slot2key.at[slot].set(key), t.size)


def _table_evict(t: Table, slot) -> tuple:
    old_key = t.slot2key[slot]
    k2s = jnp.where(
        old_key == NIL, t.key2slot, t.key2slot.at[jnp.maximum(old_key, 0)].set(NIL)
    )
    return Table(k2s, t.slot2key.at[slot].set(NIL), t.size), old_key


def make_batched_init(init: Callable[..., Any]) -> Callable[..., Any]:
    """Lift a policy init to a capacity-grid init.

    ``batched(capacities, key_space, pad_to=None, **params)`` returns one
    state pytree whose leading axis enumerates ``capacities``: every state
    is built with the same ``pad_to`` (default: max capacity) so the slot
    arrays share a shape, then the per-capacity states are stacked.  The
    result is exactly what ``jax.vmap`` over axis 0 expects.
    """

    def batched(capacities, key_space: int, pad_to: int | None = None, **params):
        caps = [int(c) for c in capacities]
        if not caps:
            raise ValueError("batched_init needs at least one capacity")
        pad = _padded(max(caps), pad_to)
        states = [init(c, key_space, pad_to=pad, **params) for c in caps]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)

    return batched


@dataclasses.dataclass(frozen=True)
class PolicyDef:
    """A policy as a pair of pure functions (init, access).

    ``init(capacity, key_space, pad_to=None, **params)`` builds the array
    state; ``access(state, key, u) -> (state, AccessResult)`` is jit/scan
    compatible and consumes one admission coin ``u`` in [0, 1) per request
    (ignored by deterministic policies, but always threaded so every
    policy shares one replay signature).

    ``batched_init(capacities, key_space, pad_to=None, **params)`` stacks
    per-capacity states along a leading axis for ``jax.vmap``: every state
    is built with one shared ``pad_to`` slot-array size (default: the max
    capacity) while its *traced* capacity scalar bounds warmup and
    eviction, so differently-sized caches share a single pytree shape —
    and therefore a single compiled replay program (see
    :func:`make_batched_init` and :mod:`repro.cache.replay`).
    """

    name: str
    init: Callable[..., Any]
    access: Callable[..., Any]  # (state, key, u) -> (state, AccessResult)
    lru_like: bool  # paper Sec. 5.1 classification (ground truth for tests)
    batched_init: Callable[..., Any] = None


# ---------------------------------------------------------------------------
# LRU  (paper Sec. 3) — hit: delink + head update; miss: tail + head update.
# ---------------------------------------------------------------------------


class LRUState(NamedTuple):
    table: Table
    dl: DList
    capacity: jnp.ndarray  # () int32


def lru_init(capacity: int, key_space: int, pad_to: int | None = None) -> LRUState:
    pad = _padded(capacity, pad_to)
    return LRUState(_table_init(pad, key_space), dlist.empty(pad),
                    jnp.int32(capacity))


def _fresh_or_tail(table: Table, dl: DList, capacity):
    """Allocate a slot: unused slot while warming, else evict the tail."""

    def fresh(args):
        table, dl = args
        slot = table.size
        return table, dl, slot, jnp.int32(NIL), _ops()

    def evict(args):
        table, dl = args
        dl, victim = dlist.pop_tail(dl)
        table, old_key = _table_evict(table, victim)
        return table, dl, victim, old_key, _ops(tail=1)

    return lax.cond(table.size < capacity, fresh, evict, (table, dl))


def _list_cache_access(table: Table, dl: DList, cap, key, reorder):
    """Branch-free shared step for the LRU family (LRU / FIFO / Prob-LRU).

    ``reorder`` is a traced bool: promote the key to the head on a hit
    (True for LRU, False for FIFO, the coin flip for Prob-LRU).  Written
    without ``lax.cond`` — every update is a predicated
    gather-select-scatter — because cond boundaries force XLA to copy the
    whole state per request, which is what made the scan replay slower
    than the Python oracle on CPU.

    Returns (table, dl, AccessResult).
    """
    slot = table.key2slot[key]
    hit = slot != NIL
    miss = ~hit
    full = table.size >= cap
    evict = miss & full
    # the slot being touched: the hit slot, else the victim tail, else the
    # next warmup slot (always < capacity <= pad).
    s = jnp.where(hit, slot, jnp.where(full, dl.tail, table.size))
    old_key = table.slot2key[s]
    evicted = jnp.where(evict, old_key, jnp.int32(NIL))

    # table: only misses mutate it.  Clearing the victim's mapping and
    # installing the new key collapse into two predicated scatters (on a
    # non-evicting miss the "clear" targets the new key, which is NIL
    # already, so it is a natural no-op).
    idx_clear = jnp.where(evict, jnp.maximum(old_key, 0), key)
    k2s = table.key2slot.at[idx_clear].set(
        jnp.where(miss, jnp.int32(NIL), table.key2slot[idx_clear])
    )
    k2s = k2s.at[key].set(jnp.where(miss, s, k2s[key]))
    s2k = table.slot2key.at[s].set(jnp.where(miss, key, table.slot2key[s]))
    size = jnp.minimum(table.size + miss.astype(jnp.int32), cap)

    # list: delink + re-push whenever anything moves (a fresh warmup slot is
    # unlinked, so its delink is a structural no-op).
    act = miss | (hit & reorder)
    dl = dlist.delink_if(dl, s, act)
    dl = dlist.push_head_if(dl, s, act)

    promote = hit & reorder
    ops = OpCounts(
        delink=promote.astype(jnp.int32),
        head=act.astype(jnp.int32),
        tail=evict.astype(jnp.int32),
        scan=jnp.int32(0),
    )
    return Table(k2s, s2k, size), dl, AccessResult(hit, evicted, s, ops)


def lru_access(state: LRUState, key, u=0.0):
    del u
    table, dl, cap = state
    table, dl, res = _list_cache_access(table, dl, cap, key, jnp.bool_(True))
    return LRUState(table, dl, cap), res


# ---------------------------------------------------------------------------
# FIFO  (paper Sec. 4.1) — hit: nothing; miss: tail + head update.
# ---------------------------------------------------------------------------


def fifo_access(state: LRUState, key, u=0.0):
    del u
    table, dl, cap = state
    table, dl, res = _list_cache_access(table, dl, cap, key, jnp.bool_(False))
    return LRUState(table, dl, cap), res


# ---------------------------------------------------------------------------
# Probabilistic LRU  (paper Sec. 4.2) — promote on hit only w.p. (1 - q).
# ---------------------------------------------------------------------------


class ProbLRUState(NamedTuple):
    table: Table
    dl: DList
    capacity: jnp.ndarray
    q: jnp.ndarray  # () f32


def prob_lru_init(capacity: int, key_space: int, q: float = 0.5,
                  pad_to: int | None = None) -> ProbLRUState:
    pad = _padded(capacity, pad_to)
    return ProbLRUState(_table_init(pad, key_space), dlist.empty(pad),
                        jnp.int32(capacity), jnp.float32(q))


def prob_lru_access(state: ProbLRUState, key, u):
    table, dl, cap, q = state
    # hit+promote -> LRU behaviour; hit+skip -> no-op; miss -> same either way.
    table, dl, res = _list_cache_access(table, dl, cap, key,
                                        jnp.float32(u) >= q)
    return ProbLRUState(table, dl, cap, q), res


# ---------------------------------------------------------------------------
# CLOCK / FIFO-Reinsertion  (paper Sec. 4.3)
# ---------------------------------------------------------------------------


class ClockState(NamedTuple):
    table: Table
    dl: DList
    bit: jnp.ndarray  # (C,) bool
    capacity: jnp.ndarray
    max_scan: jnp.ndarray  # () int32 — paper scans <= 3 before forced evict


def clock_init(capacity: int, key_space: int, max_scan: int = 3,
               pad_to: int | None = None) -> ClockState:
    pad = _padded(capacity, pad_to)
    return ClockState(_table_init(pad, key_space), dlist.empty(pad),
                      jnp.zeros((pad,), bool), jnp.int32(capacity),
                      jnp.int32(max_scan))


def _clock_evict(dl: DList, bit, max_scan):
    """Scan from the tail; reinsert 1-bit items (clearing), evict first 0-bit.

    After ``max_scan`` reinserts, evict the current tail regardless (paper's
    bounded scan, Sec. 4.3).  Returns (dl, bit, victim, ops).
    """

    def cond(carry):
        dl, bit, scans, done, _ = carry
        return (~done) & (scans <= max_scan)

    def body(carry):
        dl, bit, scans, done, victim = carry
        s = dl.tail
        give_chance = bit[s] & (scans < max_scan)

        def reinsert(args):
            dl, bit = args
            d2, t = dlist.pop_tail(dl)
            d2 = dlist.push_head(d2, t)
            return d2, bit.at[t].set(False), jnp.int32(NIL), False

        def evict(args):
            dl, bit = args
            d2, t = dlist.pop_tail(dl)
            return d2, bit, t, True

        dl, bit, v, now_done = lax.cond(give_chance, reinsert, evict, (dl, bit))
        return dl, bit, scans + 1, now_done, jnp.where(now_done, v, victim)

    dl, bit, scans, _, victim = lax.while_loop(
        cond, body, (dl, bit, jnp.int32(0), False, jnp.int32(NIL))
    )
    # ops: one tail update for the eviction + (scans-1) reinsertion scans,
    # each reinsertion also a head update.
    n_reinsert = scans - 1
    return dl, bit, victim, _ops(tail=1, scan=0) ._replace(
        scan=n_reinsert, head=n_reinsert
    )


def clock_access(state: ClockState, key, u=0.0):
    del u
    table, dl, bit, cap, max_scan = state
    slot = table.key2slot[key]
    hit = slot != NIL

    def on_hit(args):
        table, dl, bit = args
        return table, dl, bit.at[slot].set(True), slot, jnp.int32(NIL), _ops()

    def on_miss(args):
        table, dl, bit = args

        def fresh(args):
            table, dl, bit = args
            return table, dl, bit, table.size, jnp.int32(NIL), _ops()

        def evict(args):
            table, dl, bit = args
            dl, bit, victim, ops = _clock_evict(dl, bit, max_scan)
            table, old_key = _table_evict(table, victim)
            return table, dl, bit, victim, old_key, ops

        table, dl, bit, new_slot, old_key, ops = lax.cond(
            table.size < cap, fresh, evict, (table, dl, bit)
        )
        dl = dlist.push_head(dl, new_slot)
        bit = bit.at[new_slot].set(False)
        table = _table_assign(table, key, new_slot)
        table = Table(table.key2slot, table.slot2key, jnp.minimum(table.size + 1, cap))
        return table, dl, bit, new_slot, old_key, _ops_add(ops, _ops(head=1))

    table, dl, bit, slot_out, evicted, ops = lax.cond(
        hit, on_hit, on_miss, (table, dl, bit)
    )
    return ClockState(table, dl, bit, cap, max_scan), AccessResult(
        hit, evicted, slot_out, ops
    )


# ---------------------------------------------------------------------------
# Segmented LRU  (paper Sec. 4.4) — probationary B list + protected T list.
# ---------------------------------------------------------------------------


class SLRUState(NamedTuple):
    table: Table
    listB: DList
    listT: DList
    in_T: jnp.ndarray  # (C,) bool
    sizeT: jnp.ndarray  # () int32
    capacity: jnp.ndarray
    protected_cap: jnp.ndarray  # () int32


def slru_init(capacity: int, key_space: int, protected_frac: float = 0.5,
              pad_to: int | None = None) -> SLRUState:
    pad = _padded(capacity, pad_to)
    return SLRUState(
        _table_init(pad, key_space),
        dlist.empty(pad),
        dlist.empty(pad),
        jnp.zeros((pad,), bool),
        jnp.int32(0),
        jnp.int32(capacity),
        jnp.int32(max(1, int(capacity * protected_frac))),
    )


def slru_access(state: SLRUState, key, u=0.0):
    del u
    table, listB, listT, in_T, sizeT, cap, prot_cap = state
    slot = table.key2slot[key]
    hit = slot != NIL
    hit_T = hit & in_T[jnp.maximum(slot, 0)]

    def on_hit_T(args):
        table, listB, listT, in_T, sizeT = args
        listT = dlist.push_head(dlist.delink(listT, slot), slot)
        return (table, listB, listT, in_T, sizeT, slot, jnp.int32(NIL),
                _ops(delink=1, head=1))

    def on_hit_B(args):
        table, listB, listT, in_T, sizeT = args
        listB = dlist.delink(listB, slot)
        listT = dlist.push_head(listT, slot)
        in_T = in_T.at[slot].set(True)
        sizeT = sizeT + 1
        ops = _ops(delink=1, head=1)

        def demote(args):
            listB, listT, in_T, sizeT, ops = args
            listT, victim = dlist.pop_tail(listT)
            listB = dlist.push_head(listB, victim)
            in_T = in_T.at[victim].set(False)
            return listB, listT, in_T, sizeT - 1, _ops_add(ops, _ops(tail=1, head=1))

        listB, listT, in_T, sizeT, ops = lax.cond(
            sizeT > prot_cap, demote, lambda a: a, (listB, listT, in_T, sizeT, ops)
        )
        return table, listB, listT, in_T, sizeT, slot, jnp.int32(NIL), ops

    def on_miss(args):
        table, listB, listT, in_T, sizeT = args

        def fresh(args):
            table, listB, listT = args
            return table, listB, listT, table.size, jnp.int32(NIL), _ops()

        def evict(args):
            table, listB, listT = args

            def evict_B(args):
                listB, listT = args
                listB, victim = dlist.pop_tail(listB)
                return listB, listT, victim

            def evict_T(args):
                listB, listT = args
                listT, victim = dlist.pop_tail(listT)
                return listB, listT, victim

            listB, listT, victim = lax.cond(
                listB.tail != NIL, evict_B, evict_T, (listB, listT)
            )
            table, old_key = _table_evict(table, victim)
            return table, listB, listT, victim, old_key, _ops(tail=1)

        table, listB, listT, new_slot, old_key, ops = lax.cond(
            table.size < cap, fresh, evict, (table, listB, listT)
        )
        listB = dlist.push_head(listB, new_slot)
        in_T2 = in_T.at[new_slot].set(False)
        sizeT = sizeT - in_T[new_slot]  # victim might have come from T
        table = _table_assign(table, key, new_slot)
        table = Table(table.key2slot, table.slot2key, jnp.minimum(table.size + 1, cap))
        return (table, listB, listT, in_T2, sizeT, new_slot, old_key,
                _ops_add(ops, _ops(head=1)))

    table, listB, listT, in_T, sizeT, slot_out, evicted, ops = lax.cond(
        hit_T, on_hit_T,
        lambda a: lax.cond(hit, on_hit_B, on_miss, a),
        (table, listB, listT, in_T, sizeT),
    )
    return (
        SLRUState(table, listB, listT, in_T, sizeT, cap, prot_cap),
        AccessResult(hit, evicted, slot_out, ops),
    )


# ---------------------------------------------------------------------------
# S3-FIFO  (paper Sec. 4.5) — small FIFO S + main FIFO M + ghost registry.
# ---------------------------------------------------------------------------


class S3FIFOState(NamedTuple):
    table: Table
    listS: DList
    listM: DList
    in_M: jnp.ndarray  # (P,) bool
    bit: jnp.ndarray  # (P,) bool
    ghost: jnp.ndarray  # (P,) int32 ring of evicted keys; first ghost_cap live
    ghost_pos: jnp.ndarray  # () int32
    ghost_cap: jnp.ndarray  # () int32 — ring length (traced, <= len(ghost))
    sizeS: jnp.ndarray
    sizeM: jnp.ndarray
    s_cap: jnp.ndarray
    m_cap: jnp.ndarray
    capacity: jnp.ndarray
    max_scan: jnp.ndarray


def s3fifo_init(capacity: int, key_space: int, small_frac: float = 0.1,
                max_scan: int = 3, pad_to: int | None = None) -> S3FIFOState:
    if capacity < 2:
        # m_cap would be 0: evicting from an empty M list aliases the NIL
        # sentinel onto a live slot (pad-dependent results) — reject loudly.
        raise ValueError("s3fifo needs capacity >= 2 (one small + one main slot)")
    pad = _padded(capacity, pad_to)
    s_cap = max(1, int(capacity * small_frac))
    m_cap = capacity - s_cap
    return S3FIFOState(
        table=_table_init(pad, key_space),
        listS=dlist.empty(pad),
        listM=dlist.empty(pad),
        in_M=jnp.zeros((pad,), bool),
        bit=jnp.zeros((pad,), bool),
        ghost=jnp.full((max(1, pad),), NIL, jnp.int32),
        ghost_pos=jnp.int32(0),
        ghost_cap=jnp.int32(max(1, m_cap)),
        sizeS=jnp.int32(0),
        sizeM=jnp.int32(0),
        s_cap=jnp.int32(s_cap),
        m_cap=jnp.int32(m_cap),
        capacity=jnp.int32(capacity),
        max_scan=jnp.int32(max_scan),
    )


def _s3_evict_M(listM, bit, sizeM, max_scan):
    """CLOCK-style scan of the M tail (reinsert 1-bits, evict first 0-bit)."""

    def cond(carry):
        _, _, scans, done, _ = carry
        return (~done) & (scans <= max_scan)

    def body(carry):
        listM, bit, scans, done, victim = carry
        s = listM.tail
        give_chance = bit[s] & (scans < max_scan)

        def reinsert(args):
            lm, bit = args
            lm, t = dlist.pop_tail(lm)
            lm = dlist.push_head(lm, t)
            return lm, bit.at[t].set(False), jnp.int32(NIL), False

        def evict(args):
            lm, bit = args
            lm, t = dlist.pop_tail(lm)
            return lm, bit, t, True

        listM, bit, v, now_done = lax.cond(give_chance, reinsert, evict, (listM, bit))
        return listM, bit, scans + 1, now_done, jnp.where(now_done, v, victim)

    listM, bit, scans, _, victim = lax.while_loop(
        cond, body, (listM, bit, jnp.int32(0), False, jnp.int32(NIL))
    )
    return listM, bit, victim, sizeM - 1, OpCounts(
        jnp.int32(0), scans - 1, jnp.int32(1), scans - 1
    )


def s3fifo_access(state: S3FIFOState, key, u=0.0):
    del u
    st = state
    slot = st.table.key2slot[key]
    hit = slot != NIL

    def on_hit(st: S3FIFOState):
        return (
            st._replace(bit=st.bit.at[slot].set(True)),
            AccessResult(True, jnp.int32(NIL), slot, _ops()),
        )

    def on_miss(st: S3FIFOState):
        in_ghost = jnp.any(st.ghost == key)
        evicted_key = jnp.int32(NIL)
        ops = _ops()

        # -- make room in M if an insert into M is coming and M is full.
        need_m = (in_ghost & (st.sizeM >= st.m_cap))

        def mk_room_m(st_ops):
            st, ops, evicted_key = st_ops
            listM, bit, victim, sizeM, eops = _s3_evict_M(
                st.listM, st.bit, st.sizeM, st.max_scan
            )
            table, old_key = _table_evict(st.table, victim)
            st = st._replace(table=table, listM=listM, bit=bit, sizeM=sizeM,
                             in_M=st.in_M.at[victim].set(False))
            return st, _ops_add(ops, eops), old_key

        st, ops, evicted_key = lax.cond(
            need_m, mk_room_m, lambda a: a, (st, ops, evicted_key)
        )

        # -- make room in S if an insert into S is coming and S is full.
        def mk_room_s(st_ops):
            st, ops, evicted_key = st_ops
            s_tail = st.listS.tail
            promote = st.bit[s_tail]

            def do_promote(st_ops):
                st, ops, evicted_key = st_ops
                # move S tail into M (evicting from M first if needed)
                def room(st_ops):
                    st, ops, evicted_key = st_ops
                    listM, bit, victim, sizeM, eops = _s3_evict_M(
                        st.listM, st.bit, st.sizeM, st.max_scan
                    )
                    table, old_key = _table_evict(st.table, victim)
                    st = st._replace(table=table, listM=listM, bit=bit, sizeM=sizeM,
                                     in_M=st.in_M.at[victim].set(False))
                    return st, _ops_add(ops, eops), old_key

                st, ops, evicted_key = lax.cond(
                    st.sizeM >= st.m_cap, room, lambda a: a, (st, ops, evicted_key)
                )
                listS, t = dlist.pop_tail(st.listS)
                listM = dlist.push_head(st.listM, t)
                st = st._replace(
                    listS=listS, listM=listM,
                    in_M=st.in_M.at[t].set(True),
                    bit=st.bit.at[t].set(False),
                    sizeS=st.sizeS - 1, sizeM=st.sizeM + 1,
                )
                return st, _ops_add(ops, _ops(head=1, tail=1)), evicted_key

            def do_evict(st_ops):
                st, ops, evicted_key = st_ops
                listS, t = dlist.pop_tail(st.listS)
                table, old_key = _table_evict(st.table, t)
                ghost = st.ghost.at[st.ghost_pos].set(old_key)
                st = st._replace(
                    table=table, listS=listS, ghost=ghost,
                    ghost_pos=(st.ghost_pos + 1) % st.ghost_cap,
                    sizeS=st.sizeS - 1,
                )
                return st, _ops_add(ops, _ops(tail=1)), old_key

            return lax.cond(promote, do_promote, do_evict, st_ops)

        need_s = (~in_ghost) & (st.sizeS >= st.s_cap)
        st, ops, evicted_key = lax.cond(
            need_s, mk_room_s, lambda a: a, (st, ops, evicted_key)
        )

        # -- place the new key. Slot: first unused slot, else reuse a freed one.
        # A freed slot always exists after the room-making above; find one by
        # scanning slot2key (O(C) vector op — fine at controller scale).
        def fresh(st):
            return st.table.size

        def reuse(st):
            free = st.table.slot2key == NIL
            return jnp.argmax(free).astype(jnp.int32)

        new_slot = lax.cond(st.table.size < st.capacity, fresh, reuse, st)

        def to_M(st):
            listM = dlist.push_head(st.listM, new_slot)
            return st._replace(listM=listM, in_M=st.in_M.at[new_slot].set(True),
                               sizeM=st.sizeM + 1)

        def to_S(st):
            listS = dlist.push_head(st.listS, new_slot)
            return st._replace(listS=listS, in_M=st.in_M.at[new_slot].set(False),
                               sizeS=st.sizeS + 1)

        st = lax.cond(in_ghost, to_M, to_S, st)
        table = _table_assign(st.table, key, new_slot)
        table = Table(table.key2slot, table.slot2key,
                      jnp.minimum(table.size + 1, st.capacity))
        st = st._replace(table=table, bit=st.bit.at[new_slot].set(False))
        return st, AccessResult(
            False, evicted_key, new_slot, _ops_add(ops, _ops(head=1))
        )

    return lax.cond(hit, on_hit, on_miss, st)


# ---------------------------------------------------------------------------
# SIEVE  (Table 2, FIFO-like) — lazy promotion via a scanning hand.
# ---------------------------------------------------------------------------


class SieveState(NamedTuple):
    table: Table
    dl: DList
    bit: jnp.ndarray
    hand: jnp.ndarray  # () int32, NIL when unset
    capacity: jnp.ndarray


def sieve_init(capacity: int, key_space: int, pad_to: int | None = None) -> SieveState:
    pad = _padded(capacity, pad_to)
    return SieveState(_table_init(pad, key_space), dlist.empty(pad),
                      jnp.zeros((pad,), bool), jnp.int32(NIL),
                      jnp.int32(capacity))


def sieve_access(state: SieveState, key, u=0.0):
    del u
    table, dl, bit, hand, cap = state
    slot = table.key2slot[key]
    hit = slot != NIL

    def on_hit(args):
        table, dl, bit, hand = args
        return table, dl, bit.at[slot].set(True), hand, slot, jnp.int32(NIL), _ops()

    def on_miss(args):
        table, dl, bit, hand = args

        def fresh(args):
            table, dl, bit, hand = args
            return table, dl, bit, hand, table.size, jnp.int32(NIL), _ops()

        def evict(args):
            table, dl, bit, hand = args
            start = jnp.where(hand == NIL, dl.tail, hand)

            def cond(carry):
                bit_c, h, _ = carry
                return bit_c[h]

            def body(carry):
                bit, h, scans = carry
                bit = bit.at[h].set(False)
                nh = dl.prv[h]
                nh = jnp.where(nh == NIL, dl.tail, nh)
                return bit, nh, scans + 1

            bit, victim, scans = lax.while_loop(cond, body, (bit, start, jnp.int32(0)))
            new_hand = dl.prv[victim]  # may be NIL -> restart at tail next time
            dl2 = dlist.delink(dl, victim)
            table, old_key = _table_evict(table, victim)
            return (table, dl2, bit, new_hand, victim, old_key,
                    OpCounts(jnp.int32(0), jnp.int32(0), jnp.int32(1), scans))

        table, dl, bit, hand, new_slot, old_key, ops = lax.cond(
            table.size < cap, fresh, evict, (table, dl, bit, hand)
        )
        dl = dlist.push_head(dl, new_slot)
        bit = bit.at[new_slot].set(False)
        table = _table_assign(table, key, new_slot)
        table = Table(table.key2slot, table.slot2key, jnp.minimum(table.size + 1, cap))
        return table, dl, bit, hand, new_slot, old_key, _ops_add(ops, _ops(head=1))

    table, dl, bit, hand, slot_out, evicted, ops = lax.cond(
        hit, on_hit, on_miss, (table, dl, bit, hand)
    )
    return SieveState(table, dl, bit, hand, cap), AccessResult(
        hit, evicted, slot_out, ops
    )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def _policy(name, init, access, lru_like) -> PolicyDef:
    return PolicyDef(name, init, access, lru_like=lru_like,
                     batched_init=make_batched_init(init))


POLICIES = {
    "lru": _policy("lru", lru_init, lru_access, lru_like=True),
    "fifo": _policy("fifo", lru_init, fifo_access, lru_like=False),
    "prob_lru": _policy("prob_lru", prob_lru_init, prob_lru_access, lru_like=True),
    "clock": _policy("clock", clock_init, clock_access, lru_like=False),
    "slru": _policy("slru", slru_init, slru_access, lru_like=True),
    "s3fifo": _policy("s3fifo", s3fifo_init, s3fifo_access, lru_like=False),
    "sieve": _policy("sieve", sieve_init, sieve_access, lru_like=False),
}


@partial(jax.jit, static_argnames=("policy",))
def run_trace(policy: str, state, keys: jnp.ndarray, us: jnp.ndarray):
    """Replay a whole key trace through a policy with lax.scan.

    Returns (final_state, hits(bool[T]), per-request OpCounts arrays).
    """
    pdef = POLICIES[policy]

    def step(state, ku):
        k, u = ku
        state, res = pdef.access(state, k, u)
        return state, (res.hit, res.ops)

    state, (hits, ops) = lax.scan(step, state, (keys, us))
    return state, hits, ops
