"""Batched JAX trace-replay engine — the compiled fast path of prong C.

The measurement stack used to replay traces through the pure-Python
reference caches one request at a time (``repro.core.harness``), looping
cache sizes and policies in Python on top.  This module runs the *same*
policies — the jit-compatible pure functions in
:mod:`repro.cache.policies` — under ``lax.scan`` over the request stream,
and ``vmap``s that scan over a (capacity x seed) grid so an entire
cache-size sweep dispatches as ONE compiled program:

    axis 0  capacities — states stacked by ``PolicyDef.batched_init``
                         (shared ``pad_to`` slot arrays, traced capacity)
    axis 1  seeds      — independent (trace, coin) streams
    axis 2  requests   — the ``lax.scan`` carry

Per request it returns the hit flag, the evicted key (-1 when none) and
the op vector (delink, head, tail, scan) — everything
``repro.core.harness.empirical_network`` needs to build the
measured-profile queueing networks, with no Python in the loop.

The Python references stay as the differential oracle:
``tests/test_replay.py`` pins the scan engine to ``py_ref`` element-wise
on every policy for a shared (trace, u) sequence.
"""

from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from numpy.typing import ArrayLike

from repro.cache.policies import POLICIES, PolicyDef


class ReplayResult(NamedTuple):
    """Per-request replay outputs (leading axes: [capacity, [seed,]] ).

    ``ops`` columns are (delink, head, tail, scan) — the paper's queue
    stations, in the same order as ``repro.cache.py_ref.Access.ops``.
    """

    hits: np.ndarray  # bool   (..., T)
    evicted: np.ndarray  # int64  (..., T), -1 when none
    ops: np.ndarray  # int64  (..., T, 4)


def _scan_replay(
    pdef: PolicyDef, state: Any, keys: jax.Array, us: jax.Array
) -> tuple[Any, jax.Array, jax.Array, jax.Array]:
    """lax.scan a (keys, us) stream through one policy state."""

    def step(state: Any, ku: tuple[jax.Array, jax.Array]) -> Any:
        k, u = ku
        state, res = pdef.access(state, k, u)
        return state, (res.hit, res.evicted_key, jnp.stack(res.ops))

    state, (hits, evicted, ops) = lax.scan(step, state, (keys, us))
    return state, hits, evicted, ops


@partial(jax.jit, static_argnames=("policy",))
def _replay_one(
    policy: str, state: Any, keys: jax.Array, us: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    _, hits, evicted, ops = _scan_replay(POLICIES[policy], state, keys, us)
    return hits, evicted, ops


@partial(jax.jit, static_argnames=("policy",))
def _replay_grid(
    policy: str, states: Any, keys: jax.Array, us: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    pdef = POLICIES[policy]

    def one(state: Any, k: jax.Array, u: jax.Array) -> Any:
        _, hits, evicted, ops = _scan_replay(pdef, state, k, u)
        return hits, evicted, ops

    per_seed = jax.vmap(one, in_axes=(None, 0, 0))  # over the seed axis
    per_cap = jax.vmap(per_seed, in_axes=(0, None, None))  # over capacities
    return per_cap(states, keys, us)


def _as_device(keys: ArrayLike, us: ArrayLike) -> tuple[jax.Array, jax.Array]:
    keys = np.asarray(keys)
    us = np.asarray(us)
    if keys.shape != us.shape:
        raise ValueError(f"keys {keys.shape} vs us {us.shape} shape mismatch")
    return jnp.asarray(keys, jnp.int32), jnp.asarray(us, jnp.float32)


def _resolve_key_space(keys: ArrayLike, key_space: int | None) -> int:
    """Resolve and VALIDATE the key space: out-of-range keys must fail
    loudly — JAX clamps gather indices and drops out-of-bounds scatters,
    so they would otherwise alias other keys and silently corrupt the
    replay (the py_ref oracle, being dict-based, would not notice)."""
    keys = np.asarray(keys)
    if keys.size and keys.min() < 0:
        raise ValueError("trace keys must be non-negative")
    kmax = int(keys.max()) if keys.size else -1
    if not key_space:
        return kmax + 1
    if kmax >= int(key_space):
        raise ValueError(f"trace key {kmax} out of range for "
                         f"key_space={int(key_space)}")
    return int(key_space)


def replay_trace(policy: str, keys: ArrayLike, us: ArrayLike,
                 capacity: int, *, key_space: int | None = None,
                 pad_to: int | None = None, **params: Any) -> ReplayResult:
    """Replay one trace through one policy instance as a compiled scan.

    ``us`` is the admission-coin stream (uniform [0,1)); pass the same
    values to the py_ref oracle for element-wise comparison.  ``pad_to``
    sizes the slot arrays (>= capacity) so differently-sized caches share
    a compiled program.
    """
    key_space = _resolve_key_space(keys, key_space)
    state = POLICIES[policy].init(int(capacity), key_space, pad_to=pad_to,
                                  **params)
    k, u = _as_device(keys, us)
    hits, evicted, ops = _replay_one(policy, state, k, u)
    return ReplayResult(np.asarray(hits), np.asarray(evicted, np.int64),
                        np.asarray(ops, np.int64))


def _count_leq_before(x: np.ndarray, span: int) -> np.ndarray:
    """c[t] = #{s < t : x[s] <= x[t]}, by bottom-up merge counting.

    O(T log^2 T) in vectorized numpy: at each level, elements of every
    right half-block are ranked into their sorted left half-block with one
    global ``searchsorted`` (rows made disjoint by adding ``i * span``,
    which requires every value to sit in [0, span - 1]).
    """
    T = len(x)
    n = 1 << max(1, int(T - 1).bit_length())
    pad_val = span - 1  # sorts after every real value, never counted
    xp = np.full(n, pad_val, np.int64)
    xp[:T] = x
    counts = np.zeros(n, np.int64)
    w = 1
    while w < n:
        npair = n // (2 * w)
        blocks = xp.reshape(npair, 2 * w)
        left_sorted = np.sort(blocks[:, :w], axis=1)
        offs = np.arange(npair, dtype=np.int64)[:, None] * span
        flat_left = (left_sorted + offs).ravel()
        pos = np.searchsorted(flat_left, (blocks[:, w:] + offs).ravel(),
                              side="right")
        c = pos - np.repeat(np.arange(npair, dtype=np.int64) * w, w)
        idx = (np.arange(npair)[:, None] * 2 * w + w
               + np.arange(w)[None, :]).ravel()
        counts[idx] += c
        w *= 2
    return counts[:T]


def lru_sweep(keys: ArrayLike,
              capacities: ArrayLike) -> tuple[np.ndarray, np.ndarray]:
    """Exact LRU replay of one trace at EVERY capacity in one pass.

    LRU is a stack algorithm (Mattson et al. 1970): the cache of size C is
    always the top C entries of the recency stack, so a request hits at
    capacity C iff its stack distance d (distinct keys touched since its
    previous access) satisfies d < C.  One O(T log^2 T) distance
    computation therefore yields the hit sequence of *all* capacities —
    the whole cache-size -> hit-ratio sweep without replaying per size.

    With P[t] the previous occurrence of key_t and D_t the number of
    distinct keys seen before t, ``d_t = D_t - P[t] - 1 + C_t`` where
    ``C_t = #{s < t : 0 <= P[s] <= P[t]}`` counts stack positions below
    P[t] that have already expired (their key was re-accessed).  C_t is
    the merge-count above.

    Returns (hits, ops) shaped (len(capacities), T) / (..., 4), matching
    the scan engine and py_ref bit for bit (LRU op vectors are determined
    by hit/miss and warmup: hit -> (1,1,0,0), miss -> (0,1,evict,0)).
    Evicted keys are not tracked here — use :func:`replay_trace` /
    :func:`replay_grid` when they matter.
    """
    keys = np.asarray(keys, np.int64)
    T = len(keys)
    order = np.lexsort((np.arange(T), keys))
    sk = keys[order]
    P = np.full(T, -1, np.int64)
    same = sk[1:] == sk[:-1]
    P[order[1:][same]] = order[:-1][same]
    first = P < 0
    D = np.cumsum(first) - first  # distinct keys seen strictly before t
    # first occurrences get a sentinel above every real P so they are never
    # counted as expired stack positions (and never produce hits anyway).
    x = np.where(first, np.int64(T + 1), P)
    C = _count_leq_before(x, span=T + 4)
    d = D - P - 1 + C

    caps = np.asarray(list(capacities), np.int64)[:, None]
    hits = (~first)[None, :] & (d[None, :] < caps)
    evict = (~hits) & (D[None, :] >= caps)
    ops = np.zeros((len(caps), T, 4), np.int64)
    ops[..., 0] = hits  # delink on every hit
    ops[..., 1] = 1  # head update on every request
    ops[..., 2] = evict  # tail update when a miss evicts
    return hits, ops


def replay_grid(policy: str, keys: ArrayLike, us: ArrayLike,
                capacities: ArrayLike, *, key_space: int | None = None,
                pad_to: int | None = None, **params: Any) -> ReplayResult:
    """Replay a (capacity x seed) measurement grid in one dispatch.

    ``keys``/``us`` are (T,) for a single stream or (S, T) for S seed
    streams; ``capacities`` is the cache-size grid.  Returns arrays shaped
    (len(capacities), S, T[, 4]) — one full sweep per compiled call.
    """
    keys = np.atleast_2d(np.asarray(keys))
    us = np.atleast_2d(np.asarray(us))
    key_space = _resolve_key_space(keys, key_space)
    states = POLICIES[policy].batched_init(capacities, key_space,
                                           pad_to=pad_to, **params)
    k, u = _as_device(keys, us)
    hits, evicted, ops = _replay_grid(policy, states, k, u)
    return ReplayResult(np.asarray(hits), np.asarray(evicted, np.int64),
                        np.asarray(ops, np.int64))


# ---------------------------------------------------------------------------
# Delayed-hit (in-flight window) classification — prong C of the
# miss-coalescing scenario.
# ---------------------------------------------------------------------------

TRUE_MISS, TRUE_HIT, DELAYED_HIT = 0, 1, 2
_FAR_PAST = np.int32(-(2**30))  # "no fetch ever" sentinel for last-fetch times


def _classify_lane(keys: jax.Array, hits: jax.Array, windows: jax.Array,
                   key_space_arr: jax.Array) -> jax.Array:
    """Scan one (T,) lane: per-request {true miss, true hit, delayed hit}.

    The carried state is the per-key fetch *expiry* index (the fetch that
    started at t with window w stays outstanding through t + w) — for a
    scalar window this is exactly the original last-fetch-time semantics,
    and it lets every true miss carry its own window (per-request miss
    latencies drawn from the disk service distribution).
    """
    T = keys.shape[0]

    def step(expiry: jax.Array,
             x: tuple[jax.Array, ...]) -> tuple[jax.Array, jax.Array]:
        t, k, h, w = x
        outstanding = t <= expiry[k]
        cls = jnp.where(outstanding, DELAYED_HIT,
                        jnp.where(h, TRUE_HIT, TRUE_MISS))
        starts_fetch = (~outstanding) & (~h)
        expiry = jnp.where(
            starts_fetch, expiry.at[k].set(t + w), expiry
        )
        return expiry, cls.astype(jnp.int8)

    exp0 = jnp.full_like(key_space_arr, _FAR_PAST)
    ts = jnp.arange(T, dtype=jnp.int32)
    _, cls = lax.scan(step, exp0, (ts, keys, hits, windows))
    return cls


_classify_grid = jax.jit(jax.vmap(_classify_lane, in_axes=(0, 0, None, None)))


def refetch_attempts(n: int, fail_prob: float, seed: int = 0) -> np.ndarray:
    """Per-request fetch attempt counts under TTL-style failure/re-issue.

    A backing-store fetch fails (times out, returns stale, is dropped)
    with probability ``fail_prob`` and is immediately re-issued, so the
    number of attempts behind request ``t``'s fetch — *if* ``t`` turns
    out to start one — is Geometric(1 - fail_prob) >= 1.  The stream is
    drawn up front from a dedicated SeedSequence substream (independent
    of the trace/coin/window streams at the same seed, reproducible
    alongside them) and consumed identically by the JAX and the py
    classifiers, so the twins stay bit-identical by construction.
    ``fail_prob=0`` yields all-ones.
    """
    if not 0.0 <= fail_prob < 1.0:
        raise ValueError("fail_prob must be in [0, 1)")
    if fail_prob == 0.0:
        return np.ones(n, dtype=np.int64)
    rng = np.random.default_rng(np.random.SeedSequence(seed).spawn(4)[3])
    return rng.geometric(1.0 - fail_prob, size=n).astype(np.int64)


def _window_stream(window: ArrayLike | None, n_t: int, fail_prob: float,
                   fail_seed: int) -> np.ndarray:
    """Shared window plumbing: scalar or (T,) windows, validated and
    stretched by TTL re-issue attempts, resolved to an int32 (T,) stream.

    This is the single source of the fetch-expiry semantics — the host
    classifier below, the device classifier, and the fused pallas replay
    kernel (:mod:`repro.kernels.replay`) all consume windows through it,
    so the three stay bit-identical by construction."""
    windows = np.asarray(0 if window is None else window, dtype=np.int64)
    if windows.ndim > 1:
        raise ValueError(f"window must be a scalar or (T,), got {windows.shape}")
    if np.any(windows < 0):
        raise ValueError("window must be >= 0")
    if windows.ndim == 1 and windows.shape[0] != n_t:
        raise ValueError(f"per-request windows {windows.shape} vs "
                         f"{n_t} requests")
    out = np.broadcast_to(windows, (n_t,))
    if fail_prob:
        out = out * refetch_attempts(n_t, fail_prob, fail_seed)
    return out.astype(np.int32)


def _classify_inflight_device(keys: ArrayLike, hits: jax.Array,
                              window: ArrayLike, key_space: int | None,
                              fail_prob: float, fail_seed: int) -> jax.Array:
    """Device-resident classification — no host round-trip.

    The pallas replay engine (:mod:`repro.kernels.replay`) returns device
    arrays; pulling them through ``np.asarray`` just to push them back for
    the vmapped classifier costs a device->host->device bounce per call.
    Here ``hits`` stays on device end to end: the host only does shape
    plumbing and the (host-input) window stream.  ``key_space`` must be
    explicit — inferring it from the trace would force a device sync,
    which is the bounce this path exists to avoid."""
    if key_space is None or int(key_space) <= 0:
        raise ValueError("device-resident hits need an explicit key_space "
                         "(inferring it from the trace would sync the device)")
    if not isinstance(keys, jax.Array):
        _resolve_key_space(np.asarray(keys), int(key_space))
    kj = jnp.asarray(keys, jnp.int32)
    if kj.ndim == 1:
        kj = kj[None, :]
    elif kj.ndim != 2:
        raise ValueError(f"keys must be (T,) or (S, T), got {kj.shape}")
    n_t = int(kj.shape[-1])
    if int(hits.shape[-1]) != n_t:
        raise ValueError(f"hits {hits.shape} vs keys {kj.shape}: "
                         "trailing request axes differ")
    windows = _window_stream(window, n_t, fail_prob, fail_seed)
    n_s = int(kj.shape[0])
    flat_h = hits.astype(bool).reshape(-1, n_t)
    if n_s > 1:
        if hits.ndim < 2 or int(hits.shape[-2]) != n_s:
            raise ValueError(f"hits {hits.shape} second-to-last axis "
                             f"must match {n_s} key streams")
        key_lane = np.tile(np.arange(n_s), flat_h.shape[0] // n_s)
    else:
        key_lane = np.zeros(flat_h.shape[0], np.int64)
    lanes = _classify_grid(
        kj[jnp.asarray(key_lane)], flat_h, jnp.asarray(windows, jnp.int32),
        jnp.zeros((int(key_space),), jnp.int32),
    )
    return lanes.reshape(hits.shape)


def classify_inflight(keys: ArrayLike, hits: ArrayLike, window: ArrayLike,
                      key_space: int | None = None,
                      fail_prob: float = 0.0,
                      fail_seed: int = 0) -> np.ndarray | jax.Array:
    """Classify each replayed request as true hit / delayed hit / true miss.

    Overlays an MSHR-style in-flight window on an *already replayed* trace:
    a miss at request index ``t`` initiates a backing-store fetch that
    stays outstanding for the next ``window`` requests (``window`` is the
    miss latency expressed in requests — in a closed system running at
    throughput X with fetch latency L, ``window ~= X * L``).  ``window``
    is a scalar, or a ``(T,)`` array of per-request windows (each true
    miss's fetch carries its own latency, e.g. drawn from the disk service
    distribution via ``repro.core.harness.miss_window_stream``); an
    all-``W`` array classifies identically to the scalar ``W``.  Any request
    for the same key at index ``s`` with ``s - t <= window`` — whether the
    policy calls it a hit (the fill has not landed yet, so the "hit" in
    fact waits on the in-flight fetch) or a miss (the key was already
    re-evicted: the would-be second I/O coalesces onto the outstanding
    one) — is a **delayed hit** (Manohar et al. 2020).  Requests outside
    any window keep their policy classification: hit → ``TRUE_HIT``,
    miss → ``TRUE_MISS`` (and each true miss starts a fresh fetch).

    The classification is a pure post-pass: the policy's cache state and
    hit sequence are exactly those of :func:`replay_trace` /
    :func:`replay_grid` (which insert at miss time), so with ``window=0``
    the classes reduce bit-identically to the plain hit/miss split.

    ``keys`` is (T,) or (S, T); ``hits`` is (..., T) with any leading grid
    axes (e.g. the (capacity, seed, T) output of :func:`replay_grid` —
    when ``keys`` is (S, T) the second-to-last hits axis must be S).  All
    lanes classify in one vmapped dispatch.  Returns int8 classes shaped
    like ``hits`` with values {TRUE_MISS=0, TRUE_HIT=1, DELAYED_HIT=2}.

    ``fail_prob`` models TTL-style fetch failure with re-issue (the
    ROADMAP open item): the fetch a true miss starts fails with that
    probability and is retried, so its in-flight window stretches to
    ``window * attempts`` with ``attempts ~ Geometric(1 - fail_prob)``
    (drawn via :func:`refetch_attempts` at ``fail_seed``, identically in
    the py twin) — requests landing inside the extended window are
    delayed hits waiting on the eventually-successful fetch.
    ``fail_prob=0`` (and any ``window=0``) keeps the classification
    bit-identical to the no-failure path.

    The per-window coalescing factor sigma — the fraction of
    fill-requiring requests that found a fetch in flight, i.e.
    ``n_delayed / (n_delayed + n_true_miss)`` — plugs directly into
    :func:`repro.core.queueing.coalesced_network` as the measured
    ``sigma``, with the *true-hit* ratio as its ``p_hit``.

    When ``hits`` is a device-resident ``jax.Array`` (e.g. straight off
    :func:`repro.kernels.replay.replay_grid_pallas`) the classification
    runs end-to-end on device and returns a ``jax.Array`` — no
    device->host->device bounce; ``key_space`` must then be explicit,
    since inferring it from the trace would force a device sync.
    """
    if isinstance(hits, jax.Array):
        return _classify_inflight_device(keys, hits, window, key_space,
                                         fail_prob, fail_seed)
    keys = np.asarray(keys)
    hits_np = np.asarray(hits)
    windows = _window_stream(window, int(keys.shape[-1]), fail_prob, fail_seed)
    key_space = _resolve_key_space(keys, key_space)
    if keys.ndim == 1:
        keys2 = keys[None, :]
    elif keys.ndim == 2:
        keys2 = keys
    else:
        raise ValueError(f"keys must be (T,) or (S, T), got {keys.shape}")
    if hits_np.shape[-1] != keys2.shape[-1]:
        raise ValueError(f"hits {hits_np.shape} vs keys {keys.shape}: "
                         "trailing request axes differ")
    S = keys2.shape[0]
    flat = hits_np.reshape(-1, hits_np.shape[-1])
    if S > 1:
        if hits_np.ndim < 2 or hits_np.shape[-2] != S:
            raise ValueError(f"hits {hits_np.shape} second-to-last axis "
                             f"must match {S} key streams")
        key_lane = np.tile(np.arange(S), len(flat) // S)
    else:
        key_lane = np.zeros(len(flat), np.int64)

    kj = jnp.asarray(keys2, jnp.int32)
    hj = jnp.asarray(flat, bool)
    lanes = _classify_grid(
        kj[jnp.asarray(key_lane)], hj, jnp.asarray(windows, jnp.int32),
        jnp.zeros((key_space,), jnp.int32),
    )
    return np.asarray(lanes).reshape(hits_np.shape)
