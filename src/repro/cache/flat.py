"""Flat-array cache policy state — the kernel-resident layout.

The dlist policies in :mod:`repro.cache.policies` encode recency as
doubly-linked-list *pointers* (``nxt``/``prv`` arrays plus head/tail
registers).  That layout is ideal for an O(1)-per-op CPU scan but hostile
to a Pallas kernel: every list splice is a chain of dependent scalar
scatters, and the state does not decompose into the handful of uniform
vectors a scratch allocation wants.

This module re-expresses every policy over a **timestamp layout**: list
order *is* descending push-timestamp.  One monotone ``now`` counter is
bumped on every (re-)push, so

* the list *tail* is the occupied slot with minimum ``ts``,
* the neighbour *toward the head* of slot ``h`` is the occupied slot with
  the smallest ``ts`` strictly greater than ``ts[h]``,
* two lists sharing one slot array (SLRU's B/T, S3-FIFO's S/M) are just
  membership masks over the same ``ts`` vector.

Victim search becomes a masked argmin over the padded slot axis — O(P)
vector work instead of O(1) pointer chasing, but *vectorizable*, which is
what both the batched ``lax.scan`` twin and the Pallas kernel need (and
measured on the 8-capacity x 60k-request grid the masked-argmin scan
already beats the dlist scan on CPU).

Every policy is a pure step with one uniform signature::

    state, hit, evicted, ops = FLAT_STEPS[policy](state, key, u, p, q)

over a single :class:`FlatState` pytree whose fields are fixed across
policies (unused fields ride along at zero cost inside a fused scan), an
``int32[N_PARAMS]`` per-lane parameter vector ``p`` and a scalar float
coin threshold ``q``.  Capacity-derived parameters are *traced* per-lane
values, so one compiled program serves the whole (capacity x seed) grid.

Bit-identity with :mod:`repro.cache.policies` (and therefore with the
``py_ref`` oracles) is pinned by ``tests/test_pallas_replay.py``: hits,
evicted keys and op vectors must match element-wise, padded and exact.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax

# numpy scalars, not jnp: the Pallas kernel body closes over these, and a
# jnp scalar would be a captured device constant (pallas_call rejects those)
NIL = np.int32(-1)
_INT32_MAX = np.int32(2**31 - 1)
# bias for collapsing a cyclic hand scan into one argmin (see _sieve_step);
# timestamps stay far below this (at most a couple of bumps per request)
_WRAP_BIAS = np.int32(2**30)

# -- regs vector layout (per-lane scalar registers) -------------------------
R_SIZE = 0      # slots ever filled, saturating at capacity
R_NOW = 1       # monotone push counter (list order == descending ts)
R_SIZET = 2     # SLRU: protected-list population
R_SIZES = 3     # S3-FIFO: small-queue population
R_SIZEM = 4     # S3-FIFO: main-queue population
R_GPOS = 5      # S3-FIFO: ghost-ring write cursor
R_HAND = 6      # SIEVE: hand slot, NIL when unset
N_REGS = 8

# -- per-lane parameter vector layout ---------------------------------------
P_CAP = 0
P_MAX_SCAN = 1
P_PROT_CAP = 2
P_S_CAP = 3
P_M_CAP = 4
P_GHOST_CAP = 5
N_PARAMS = 6

# Packed op-vector bit layout (delink, head, tail, scan) -> one int32.
# head is bounded by max_scan + 2 per access, tail by 2, scan by the
# capacity (SIEVE's hand walk); 19 bits cover every capacity in the
# benchmarks with room to spare.
_OPS_HEAD_SHIFT = 1
_OPS_TAIL_SHIFT = 9
_OPS_SCAN_SHIFT = 12
_OPS_HEAD_MASK = 0xFF      # 8 bits
_OPS_TAIL_MASK = 0x7       # 3 bits
_OPS_SCAN_MASK = 0x7FFFF   # 19 bits

_PARAM_NAMES = {
    "lru": (),
    "fifo": (),
    "prob_lru": ("q",),
    "clock": ("max_scan",),
    "slru": ("protected_frac",),
    "s3fifo": ("small_frac", "max_scan"),
    "sieve": (),
}


class FlatState(NamedTuple):
    """Uniform flat policy state (all int32; booleans stored as 0/1).

    ``aux`` is the policy's second membership bit: ``in_T`` for SLRU,
    ``in_M`` for S3-FIFO, unused elsewhere.  ``ghost`` is the S3-FIFO
    ghost ring (NIL-filled for other policies).  ``regs`` packs the
    scalar registers (see the ``R_*`` indices).
    """

    key2slot: jnp.ndarray   # (K,) slot of each key, NIL when absent
    slot2key: jnp.ndarray   # (P,) key in each slot, NIL when free
    ts: jnp.ndarray         # (P,) push timestamp (list position)
    bit: jnp.ndarray        # (P,) CLOCK/SIEVE/S3 reference bit
    aux: jnp.ndarray        # (P,) secondary membership bit
    ghost: jnp.ndarray      # (P,) evicted-key ring (S3-FIFO)
    regs: jnp.ndarray       # (N_REGS,) scalar registers


def flat_state_init(key_space: int, pad: int) -> FlatState:
    """Zero state shared by every policy (SIEVE's hand starts at NIL)."""
    regs = jnp.zeros((N_REGS,), jnp.int32).at[R_HAND].set(NIL)
    return FlatState(
        key2slot=jnp.full((key_space,), NIL, jnp.int32),
        slot2key=jnp.full((pad,), NIL, jnp.int32),
        ts=jnp.zeros((pad,), jnp.int32),
        bit=jnp.zeros((pad,), jnp.int32),
        aux=jnp.zeros((pad,), jnp.int32),
        ghost=jnp.full((pad,), NIL, jnp.int32),
        regs=regs,
    )


def flat_lane_params(policy: str, capacity: int,
                     **params: Any) -> Tuple[np.ndarray, float]:
    """Derive one lane's ``(p_vec, q)`` from the policy's init kwargs.

    Mirrors the ``<policy>_init`` derivations in policies.py exactly
    (``prot_cap = max(1, int(C * protected_frac))`` etc.) so the flat
    engine and the dlist engine agree on every rounded-down boundary.
    """
    if policy not in _PARAM_NAMES:
        raise KeyError(f"unknown policy {policy!r}")
    unknown = set(params) - set(_PARAM_NAMES[policy])
    if unknown:
        raise TypeError(
            f"policy {policy!r} got unexpected params {sorted(unknown)}"
        )
    cap = int(capacity)
    if policy == "s3fifo" and cap < 2:
        # mirror s3fifo_init: m_cap == 0 has no main list to evict from
        raise ValueError(
            "s3fifo needs capacity >= 2 (one small + one main slot)")
    s_cap = max(1, int(cap * float(params.get("small_frac", 0.1))))
    vec = np.zeros((N_PARAMS,), np.int32)
    vec[P_CAP] = cap
    vec[P_MAX_SCAN] = int(params.get("max_scan", 3))
    vec[P_PROT_CAP] = max(1, int(cap * float(params.get("protected_frac", 0.5))))
    vec[P_S_CAP] = s_cap
    vec[P_M_CAP] = cap - s_cap
    vec[P_GHOST_CAP] = max(1, cap - s_cap)
    # stored as float32 by prob_lru_init; replicate the rounding so the
    # coin comparison is bit-identical
    q = float(np.float32(params.get("q", 0.5)))
    return vec, q


def pack_ops(ops: jnp.ndarray) -> jnp.ndarray:
    """Pack an int32[4] (delink, head, tail, scan) op vector into one int32."""
    return (
        ops[0]
        | (ops[1] << _OPS_HEAD_SHIFT)
        | (ops[2] << _OPS_TAIL_SHIFT)
        | (ops[3] << _OPS_SCAN_SHIFT)
    ).astype(jnp.int32)


def unpack_ops(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_ops`; appends a trailing length-4 axis."""
    packed = jnp.asarray(packed, jnp.int32)
    return jnp.stack(
        [
            packed & 1,
            (packed >> _OPS_HEAD_SHIFT) & _OPS_HEAD_MASK,
            (packed >> _OPS_TAIL_SHIFT) & _OPS_TAIL_MASK,
            (packed >> _OPS_SCAN_SHIFT) & _OPS_SCAN_MASK,
        ],
        axis=-1,
    )


def _i32(x: Any) -> jnp.ndarray:
    return jnp.asarray(x).astype(jnp.int32)


def _ops4(delink: Any = 0, head: Any = 0, tail: Any = 0,
          scan: Any = 0) -> jnp.ndarray:
    return jnp.stack([_i32(delink), _i32(head), _i32(tail), _i32(scan)])


def _min_slot(ts: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Slot with minimum ts among ``mask`` — the masked list's tail."""
    return jnp.argmin(jnp.where(mask, ts, _INT32_MAX)).astype(jnp.int32)


def _toward_head(ts: jnp.ndarray, mask: jnp.ndarray,
                 h: jnp.ndarray) -> jnp.ndarray:
    """The list neighbour of ``h`` one step toward the head (NIL at head)."""
    above = mask & (ts > ts[h])
    return jnp.where(jnp.any(above), _min_slot(ts, above), NIL)


def _occupied(st: FlatState) -> jnp.ndarray:
    return st.slot2key != NIL


def _clear_key(key2slot: jnp.ndarray, old_key: jnp.ndarray) -> jnp.ndarray:
    """``_table_evict``'s guarded mapping clear (no-op when old_key is NIL)."""
    return jnp.where(
        old_key == NIL,
        key2slot,
        key2slot.at[jnp.maximum(old_key, 0)].set(NIL),
    )


# ---------------------------------------------------------------------------
# LRU family (LRU / FIFO / Prob-LRU) — branch-free, mirrors
# policies._list_cache_access scatter for scatter.
# ---------------------------------------------------------------------------


def _make_list_step(reorder_of: Callable[[jnp.ndarray, jnp.ndarray],
                                         jnp.ndarray]):
    def step(st: FlatState, key: jnp.ndarray, u: jnp.ndarray,
             p: jnp.ndarray, q: jnp.ndarray):
        slot = st.key2slot[key]
        hit = slot != NIL
        reorder = reorder_of(u, q)
        miss = ~hit
        size = st.regs[R_SIZE]
        now = st.regs[R_NOW]
        cap = p[P_CAP]
        full = size >= cap
        evict = miss & full
        victim = _min_slot(st.ts, _occupied(st))
        s = jnp.where(hit, slot, jnp.where(full, victim, size))
        old_key = st.slot2key[s]
        evicted = jnp.where(evict, old_key, NIL)
        idx_clear = jnp.where(evict, jnp.maximum(old_key, 0), key)
        k2s = st.key2slot.at[idx_clear].set(
            jnp.where(miss, NIL, st.key2slot[idx_clear])
        )
        k2s = k2s.at[key].set(jnp.where(miss, s, k2s[key]))
        s2k = st.slot2key.at[s].set(jnp.where(miss, key, st.slot2key[s]))
        act = miss | (hit & reorder)
        ts = st.ts.at[s].set(jnp.where(act, now, st.ts[s]))
        regs = st.regs.at[R_SIZE].set(
            jnp.minimum(size + miss.astype(jnp.int32), cap)
        )
        regs = regs.at[R_NOW].set(now + act.astype(jnp.int32))
        ops = _ops4(delink=hit & reorder, head=act, tail=evict)
        st = st._replace(key2slot=k2s, slot2key=s2k, ts=ts, regs=regs)
        return st, hit, evicted, ops

    return step


_lru_step = _make_list_step(lambda u, q: jnp.bool_(True))
_fifo_step = _make_list_step(lambda u, q: jnp.bool_(False))
_prob_lru_step = _make_list_step(lambda u, q: jnp.float32(u) >= q)


# ---------------------------------------------------------------------------
# CLOCK — bounded tail scan, reinsert 1-bit items.
# ---------------------------------------------------------------------------


def _clock_scan_evict(ts: jnp.ndarray, bit: jnp.ndarray, now: jnp.ndarray,
                      mask: jnp.ndarray, max_scan: jnp.ndarray):
    """Shared CLOCK/S3-M eviction scan over a fixed membership mask.

    The victim stays *in* the mask for the whole loop (the dlist code only
    pops it as the loop's final act), so the mask never changes — only the
    timestamps of reinserted slots move.  Returns
    (ts, bit, now, victim, n_reinsert).
    """

    def cond(carry):
        _, _, _, scans, done, _ = carry
        return (~done) & (scans <= max_scan)

    def body(carry):
        ts, bit, now, scans, done, victim = carry
        s = _min_slot(ts, mask)
        give_chance = (bit[s] != 0) & (scans < max_scan)
        ts = ts.at[s].set(jnp.where(give_chance, now, ts[s]))
        bit = bit.at[s].set(jnp.where(give_chance, 0, bit[s]))
        now = now + give_chance.astype(jnp.int32)
        return (ts, bit, now, scans + 1, ~give_chance,
                jnp.where(give_chance, victim, s))

    ts, bit, now, scans, _, victim = lax.while_loop(
        cond, body,
        (ts, bit, now, jnp.int32(0), jnp.bool_(False), NIL),
    )
    return ts, bit, now, victim, scans - 1


def _clock_step(st: FlatState, key: jnp.ndarray, u: jnp.ndarray,
                p: jnp.ndarray, q: jnp.ndarray):
    del u, q
    slot = st.key2slot[key]
    hit = slot != NIL
    cap = p[P_CAP]

    def on_hit(st: FlatState):
        bit = st.bit.at[jnp.maximum(slot, 0)].set(1)
        return st._replace(bit=bit), NIL, _ops4()

    def on_miss(st: FlatState):
        def fresh(st: FlatState):
            return st, st.regs[R_SIZE], NIL, _ops4()

        def evict(st: FlatState):
            ts, bit, now, victim, n_re = _clock_scan_evict(
                st.ts, st.bit, st.regs[R_NOW], _occupied(st), p[P_MAX_SCAN]
            )
            old_key = st.slot2key[victim]
            k2s = _clear_key(st.key2slot, old_key)
            s2k = st.slot2key.at[victim].set(NIL)
            regs = st.regs.at[R_NOW].set(now)
            st = st._replace(key2slot=k2s, slot2key=s2k, ts=ts, bit=bit,
                             regs=regs)
            return st, victim, old_key, _ops4(head=n_re, tail=1, scan=n_re)

        st, new_slot, old_key, ops = lax.cond(
            st.regs[R_SIZE] < cap, fresh, evict, st
        )
        now = st.regs[R_NOW]
        k2s = st.key2slot.at[key].set(new_slot)
        s2k = st.slot2key.at[new_slot].set(key)
        ts = st.ts.at[new_slot].set(now)
        bit = st.bit.at[new_slot].set(0)
        regs = st.regs.at[R_NOW].set(now + 1)
        regs = regs.at[R_SIZE].set(jnp.minimum(st.regs[R_SIZE] + 1, cap))
        st = st._replace(key2slot=k2s, slot2key=s2k, ts=ts, bit=bit,
                         regs=regs)
        return st, old_key, ops + _ops4(head=1)

    st, evicted, ops = lax.cond(hit, on_hit, on_miss, st)
    return st, hit, evicted, ops


# ---------------------------------------------------------------------------
# SLRU — probationary (aux=0) + protected (aux=1) masks over one ts vector.
# ---------------------------------------------------------------------------


def _slru_step(st: FlatState, key: jnp.ndarray, u: jnp.ndarray,
               p: jnp.ndarray, q: jnp.ndarray):
    del u, q
    slot0 = st.key2slot[key]
    hit = slot0 != NIL
    slot = jnp.maximum(slot0, 0)
    hit_T = hit & (st.aux[slot] != 0)
    cap = p[P_CAP]
    prot_cap = p[P_PROT_CAP]

    def on_hit_T(st: FlatState):
        now = st.regs[R_NOW]
        ts = st.ts.at[slot].set(now)
        regs = st.regs.at[R_NOW].set(now + 1)
        return (st._replace(ts=ts, regs=regs), NIL,
                _ops4(delink=1, head=1))

    def on_hit_B(st: FlatState):
        now = st.regs[R_NOW]
        size_t = st.regs[R_SIZET]
        aux = st.aux.at[slot].set(1)
        ts = st.ts.at[slot].set(now)
        now = now + 1
        size_t = size_t + 1
        # demote the protected tail back to B when T overflows; the slot
        # we just promoted carries the newest ts, so it is never the tail
        # (size_t > prot_cap >= 1 implies at least one older T member).
        demote = size_t > prot_cap
        t_tail = _min_slot(ts, _occupied(st) & (aux != 0))
        aux = aux.at[t_tail].set(jnp.where(demote, 0, aux[t_tail]))
        ts = ts.at[t_tail].set(jnp.where(demote, now, ts[t_tail]))
        now = now + demote.astype(jnp.int32)
        size_t = size_t - demote.astype(jnp.int32)
        regs = st.regs.at[R_NOW].set(now).at[R_SIZET].set(size_t)
        ops = _ops4(delink=1, head=1 + demote.astype(jnp.int32),
                    tail=demote)
        return st._replace(ts=ts, aux=aux, regs=regs), NIL, ops

    def on_miss(st: FlatState):
        def fresh(st: FlatState):
            return st, st.regs[R_SIZE], NIL, _ops4()

        def evict(st: FlatState):
            occ = _occupied(st)
            b_mask = occ & (st.aux == 0)
            # dlist order: evict B's tail, falling back to T's tail only
            # when B is empty.
            victim = jnp.where(
                jnp.any(b_mask),
                _min_slot(st.ts, b_mask),
                _min_slot(st.ts, occ & (st.aux != 0)),
            )
            old_key = st.slot2key[victim]
            k2s = _clear_key(st.key2slot, old_key)
            s2k = st.slot2key.at[victim].set(NIL)
            st = st._replace(key2slot=k2s, slot2key=s2k)
            return st, victim, old_key, _ops4(tail=1)

        st, new_slot, old_key, ops = lax.cond(
            st.regs[R_SIZE] < cap, fresh, evict, st
        )
        now = st.regs[R_NOW]
        # the victim may have come from T (B empty): shrink sizeT using
        # the *pre-clear* membership bit, then mark the slot probationary.
        size_t = st.regs[R_SIZET] - (st.aux[new_slot] != 0).astype(jnp.int32)
        k2s = st.key2slot.at[key].set(new_slot)
        s2k = st.slot2key.at[new_slot].set(key)
        ts = st.ts.at[new_slot].set(now)
        aux = st.aux.at[new_slot].set(0)
        regs = st.regs.at[R_NOW].set(now + 1).at[R_SIZET].set(size_t)
        regs = regs.at[R_SIZE].set(jnp.minimum(st.regs[R_SIZE] + 1, cap))
        st = st._replace(key2slot=k2s, slot2key=s2k, ts=ts, aux=aux,
                         regs=regs)
        return st, old_key, ops + _ops4(head=1)

    def on_hit_any(st: FlatState):
        return lax.cond(hit_T, on_hit_T, on_hit_B, st)

    st, evicted, ops = lax.cond(hit, on_hit_any, on_miss, st)
    return st, hit, evicted, ops


# ---------------------------------------------------------------------------
# S3-FIFO — small (aux=0) + main (aux=1) masks + ghost ring.
# ---------------------------------------------------------------------------


def _s3_evict_m(st: FlatState, p: jnp.ndarray):
    """Evict from M with the CLOCK scan; returns (st, old_key, ops)."""
    m_mask = _occupied(st) & (st.aux != 0)
    ts, bit, now, victim, n_re = _clock_scan_evict(
        st.ts, st.bit, st.regs[R_NOW], m_mask, p[P_MAX_SCAN]
    )
    old_key = st.slot2key[victim]
    k2s = _clear_key(st.key2slot, old_key)
    s2k = st.slot2key.at[victim].set(NIL)
    aux = st.aux.at[victim].set(0)
    regs = st.regs.at[R_NOW].set(now)
    regs = regs.at[R_SIZEM].set(st.regs[R_SIZEM] - 1)
    st = st._replace(key2slot=k2s, slot2key=s2k, ts=ts, bit=bit, aux=aux,
                     regs=regs)
    return st, old_key, _ops4(head=n_re, tail=1, scan=n_re)


def _s3fifo_step(st: FlatState, key: jnp.ndarray, u: jnp.ndarray,
                 p: jnp.ndarray, q: jnp.ndarray):
    del u, q
    slot = st.key2slot[key]
    hit = slot != NIL
    cap = p[P_CAP]

    def on_hit(st: FlatState):
        bit = st.bit.at[jnp.maximum(slot, 0)].set(1)
        return st._replace(bit=bit), NIL, _ops4()

    def on_miss(st: FlatState):
        in_ghost = jnp.any(st.ghost == key)
        evicted = NIL
        ops = _ops4()

        def mk_room_m(args):
            st, ops, evicted = args
            st, old_key, eops = _s3_evict_m(st, p)
            return st, ops + eops, old_key

        need_m = in_ghost & (st.regs[R_SIZEM] >= p[P_M_CAP])
        st, ops, evicted = lax.cond(
            need_m, mk_room_m, lambda a: a, (st, ops, evicted)
        )

        def mk_room_s(args):
            st, ops, evicted = args
            s_mask = _occupied(st) & (st.aux == 0)
            s_tail = _min_slot(st.ts, s_mask)
            promote = st.bit[s_tail] != 0

            def do_promote(args):
                st, ops, evicted = args
                st, ops, evicted = lax.cond(
                    st.regs[R_SIZEM] >= p[P_M_CAP], mk_room_m,
                    lambda a: a, (st, ops, evicted)
                )
                now = st.regs[R_NOW]
                ts = st.ts.at[s_tail].set(now)
                aux = st.aux.at[s_tail].set(1)
                bit = st.bit.at[s_tail].set(0)
                regs = st.regs.at[R_NOW].set(now + 1)
                regs = regs.at[R_SIZES].set(st.regs[R_SIZES] - 1)
                regs = regs.at[R_SIZEM].set(st.regs[R_SIZEM] + 1)
                st = st._replace(ts=ts, aux=aux, bit=bit, regs=regs)
                return st, ops + _ops4(head=1, tail=1), evicted

            def do_evict(args):
                st, ops, evicted = args
                old_key = st.slot2key[s_tail]
                k2s = _clear_key(st.key2slot, old_key)
                s2k = st.slot2key.at[s_tail].set(NIL)
                gpos = st.regs[R_GPOS]
                ghost = st.ghost.at[gpos].set(old_key)
                regs = st.regs.at[R_GPOS].set((gpos + 1) % p[P_GHOST_CAP])
                regs = regs.at[R_SIZES].set(st.regs[R_SIZES] - 1)
                st = st._replace(key2slot=k2s, slot2key=s2k, ghost=ghost,
                                 regs=regs)
                return st, ops + _ops4(tail=1), old_key

            return lax.cond(promote, do_promote, do_evict,
                            (st, ops, evicted))

        need_s = (~in_ghost) & (st.regs[R_SIZES] >= p[P_S_CAP])
        st, ops, evicted = lax.cond(
            need_s, mk_room_s, lambda a: a, (st, ops, evicted)
        )

        # place: next warmup slot while filling, else first freed slot
        # (room-making above guarantees one exists).
        new_slot = jnp.where(
            st.regs[R_SIZE] < cap,
            st.regs[R_SIZE],
            jnp.argmax(st.slot2key == NIL).astype(jnp.int32),
        )
        now = st.regs[R_NOW]
        to_m = in_ghost
        k2s = st.key2slot.at[key].set(new_slot)
        s2k = st.slot2key.at[new_slot].set(key)
        ts = st.ts.at[new_slot].set(now)
        aux = st.aux.at[new_slot].set(to_m.astype(jnp.int32))
        bit = st.bit.at[new_slot].set(0)
        regs = st.regs.at[R_NOW].set(now + 1)
        regs = regs.at[R_SIZES].set(
            st.regs[R_SIZES] + (~to_m).astype(jnp.int32)
        )
        regs = regs.at[R_SIZEM].set(st.regs[R_SIZEM] + to_m.astype(jnp.int32))
        regs = regs.at[R_SIZE].set(jnp.minimum(st.regs[R_SIZE] + 1, cap))
        st = st._replace(key2slot=k2s, slot2key=s2k, ts=ts, aux=aux,
                         bit=bit, regs=regs)
        return st, evicted, ops + _ops4(head=1)

    st, evicted, ops = lax.cond(hit, on_hit, on_miss, st)
    return st, hit, evicted, ops


# ---------------------------------------------------------------------------
# SIEVE — lazy promotion; the hand is a slot index, NIL when unset.
# ---------------------------------------------------------------------------


def _sieve_step(st: FlatState, key: jnp.ndarray, u: jnp.ndarray,
                p: jnp.ndarray, q: jnp.ndarray):
    del u, q
    slot = st.key2slot[key]
    hit = slot != NIL
    cap = p[P_CAP]

    def on_hit(st: FlatState):
        bit = st.bit.at[jnp.maximum(slot, 0)].set(1)
        return st._replace(bit=bit), NIL, _ops4()

    def on_miss(st: FlatState):
        def fresh(st: FlatState):
            return st, st.regs[R_SIZE], NIL, _ops4()

        def evict(st: FlatState):
            occ = _occupied(st)
            tail = _min_slot(st.ts, occ)
            hand = st.regs[R_HAND]
            start = jnp.where(hand == NIL, tail, hand)

            # The hand walk visits occupied slots in cyclic ts order from
            # ``start`` (toward the head, wrapping to the tail), clearing
            # bits until the first clear-bit slot — which makes the victim
            # and the cleared set computable in ONE vectorized pass instead
            # of an O(P)-per-step while loop: the victim is the first
            # original-bit-0 slot in cyclic order (upper segment
            # ts >= ts[start] first, then the wrapped lower segment), or
            # ``start`` itself after a full clearing cycle; the cleared
            # slots are exactly the cyclic prefix strictly before it.
            ts_start = st.ts[start]
            bit0 = occ & (st.bit == 0)
            # Cyclic order collapses to one argmin by biasing the wrapped
            # lower segment (ts < ts[start]) above the upper one; ts stays
            # far below the bias (one bump per push), so no overflow.
            ck = st.ts + jnp.where(st.ts < ts_start, _WRAP_BIAS, 0)
            idx = jnp.argmin(jnp.where(bit0, ck, _INT32_MAX))
            found = bit0[idx]  # gather beats an any() reduction
            victim = jnp.where(found, idx, start)
            ts_v = st.ts[victim]
            # Cleared set = cyclic prefix strictly before the victim; a
            # full clearing cycle (no clear bit anywhere) clears the lot.
            scanned = occ & jnp.where(found, ck < ck[victim], True)
            bit = jnp.where(scanned, 0, st.bit)
            scans = jnp.sum(scanned.astype(jnp.int32))
            # hand moves one step past the victim (NIL at the head ->
            # restart from the tail next eviction), computed *before* the
            # victim leaves the list, exactly like dl.prv[victim].
            above = occ & (st.ts > ts_v)
            nh = jnp.argmin(jnp.where(above, st.ts, _INT32_MAX))
            new_hand = jnp.where(above[nh], nh, NIL)
            old_key = st.slot2key[victim]
            k2s = _clear_key(st.key2slot, old_key)
            s2k = st.slot2key.at[victim].set(NIL)
            regs = st.regs.at[R_HAND].set(new_hand)
            st = st._replace(key2slot=k2s, slot2key=s2k, bit=bit, regs=regs)
            return st, victim, old_key, _ops4(tail=1, scan=scans)

        st, new_slot, old_key, ops = lax.cond(
            st.regs[R_SIZE] < cap, fresh, evict, st
        )
        now = st.regs[R_NOW]
        k2s = st.key2slot.at[key].set(new_slot)
        s2k = st.slot2key.at[new_slot].set(key)
        ts = st.ts.at[new_slot].set(now)
        bit = st.bit.at[new_slot].set(0)
        regs = st.regs.at[R_NOW].set(now + 1)
        regs = regs.at[R_SIZE].set(jnp.minimum(st.regs[R_SIZE] + 1, cap))
        st = st._replace(key2slot=k2s, slot2key=s2k, ts=ts, bit=bit,
                         regs=regs)
        return st, old_key, ops + _ops4(head=1)

    st, evicted, ops = lax.cond(hit, on_hit, on_miss, st)
    return st, hit, evicted, ops


FLAT_STEPS: Dict[str, Callable[..., Any]] = {
    "lru": _lru_step,
    "fifo": _fifo_step,
    "prob_lru": _prob_lru_step,
    "clock": _clock_step,
    "slru": _slru_step,
    "s3fifo": _s3fifo_step,
    "sieve": _sieve_step,
}
