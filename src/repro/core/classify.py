"""LRU-like vs FIFO-like classification (paper Sec. 5.1, Tables 1-2).

The structural rule the paper derives: a policy is **LRU-like** iff some
serialized (queue) station receives work on the *hit path*, so its demand
grows with ``p_hit`` and eventually becomes the bottleneck — at which point
throughput *decreases* in ``p_hit``.  **FIFO-like** policies only place
queue-station work on the miss path, so demand (and queueing) vanish as
``p_hit → 1`` and throughput is monotone increasing.
"""

from __future__ import annotations

import numpy as np

from repro.core.queueing import ClosedNetwork

LRU_LIKE = "LRU-like"
FIFO_LIKE = "FIFO-like"


def classify_structural(net: ClosedNetwork, eps: float = 1e-9) -> str:
    """Classify by whether any queue station's demand increases in p_hit."""
    ps = np.linspace(0.0, 1.0, 101)
    for s in net.queue_stations():
        d = np.array([net.demands(float(p), tail_mode="nominal")[s.name] for p in ps])
        if np.any(np.diff(d) > eps) and d[-1] > eps:
            return LRU_LIKE
    return FIFO_LIKE


def classify_by_throughput(net: ClosedNetwork, rel_tol: float = 0.01) -> str:
    """Classify by whether the analytic bound ever decreases in p_hit.

    Measured as the cumulative drop below the running max (robust to grid
    resolution, unlike a per-step derivative test).  The 1% behavioural
    threshold matches the paper's reading of Fig. 8: Prob-LRU at
    q = 1 - 1/N is called FIFO-like even though the bound dips ~0.2% in the
    final sliver p_hit > 1 - 1/N.
    """
    ps = np.linspace(0.0, 1.0, 2001)
    x = net.throughput_upper(ps)
    running_max = np.maximum.accumulate(x)
    drop = (running_max - x) / np.maximum(running_max, 1e-12)
    return LRU_LIKE if np.any(drop > rel_tol) else FIFO_LIKE


# Paper Table 1 (evaluated) — "does increasing hit ratio always help?"
TABLE1 = {
    "lru": ("no", LRU_LIKE),
    "fifo": ("yes", FIFO_LIKE),
    "prob_lru(q=0.5)": ("depends on q", LRU_LIKE),
    "prob_lru(q=0.986)": ("depends on q", FIFO_LIKE),
    "clock": ("yes", FIFO_LIKE),
    "slru": ("no", LRU_LIKE),
    "s3fifo": ("yes", FIFO_LIKE),
}

# Paper Table 2 (conjectured) — encoded for the classification benchmark.
TABLE2_CONJECTURE = {
    LRU_LIKE: ["ARC", "LIRS", "TinyLFU", "LeCaR", "CACHEUS", "LFU"],
    FIFO_LIKE: [
        "CLOCK-variants", "SIEVE", "QDLP", "Hyperbolic", "Random", "LHD", "LRB",
    ],
}

# Structural reason strings used in reports.
REASONS = {
    LRU_LIKE: "performs a delink/promotion on the global structure upon a cache hit",
    FIFO_LIKE: "never updates the global structure upon a cache hit "
               "(bit-set only, or no global structure at all)",
}
