"""Closed queueing-network analysis — prong A of the paper's methodology.

The paper models a DRAM cache under a Multi-Programming Limit (MPL) as a
*closed* queueing network:

  - **think stations** (infinite-server): cache lookup, disk/backing store,
    ghost lookup.  No queueing; all MPL requests may be in service at once.
  - **queue stations** (single-server FCFS): the serialized metadata
    operations on the global eviction structure (delink, head update, tail
    update, ...).

Throughput is upper-bounded (Harchol-Balter, "Performance Modeling and
Design of Computer Systems", Theorem 7.1) by::

    X  <=  min( N / (D + E[Z]),  1 / D_max )

where ``D_k`` is the *demand* of queue station ``k`` (expected total service
a single request places on that station per pass through the system),
``D = sum_k D_k``, ``D_max = max_k D_k`` and ``E[Z]`` the total think time.

Everything below is parameterized by the hit ratio ``p_hit`` — demands and
service times are functions of ``p_hit`` — which is what lets the model
expose the paper's central phenomenon: the bottleneck (arg-max demand
station) switching from the miss path to the hit path at ``p*_hit``.

Units: microseconds.  Throughput is requests/µs == millions of requests/s.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence, Union

import numpy as np

ServiceFn = Union[float, Callable[[float], float]]
ProbFn = Union[float, Callable[[float], float]]

QUEUE = "queue"
THINK = "think"


def _as_fn(v: ServiceFn) -> Callable[[float], float]:
    if callable(v):
        return v
    return lambda p, _v=float(v): _v


@dataclasses.dataclass(frozen=True)
class Station:
    """One service station.

    ``bound="upper"`` marks stations whose service time could only be
    bounded from above in the paper's measurements (the tail updates — they
    are never the bottleneck, so they cannot be kept saturated to measure
    the inter-departure time).  The throughput *upper* bound uses 0 for
    these; the pessimistic bound uses ``service``.
    """

    name: str
    kind: str  # QUEUE | THINK
    service: ServiceFn  # mean service time (µs), may depend on p_hit
    bound: str = "exact"  # "exact" | "upper"
    dist: str = "det"  # det | exp | pareto  (used by the simulator)
    dist_params: tuple = ()

    def mean_service(self, p_hit: float) -> float:
        return float(_as_fn(self.service)(p_hit))


@dataclasses.dataclass(frozen=True)
class Branch:
    """A probabilistic route through the network.

    Each completed request samples one branch (probabilities must sum to 1
    at every ``p_hit``) and visits ``visits`` in order.  Station names may
    repeat (a station visited twice contributes twice to demand).
    """

    name: str
    prob: ProbFn
    visits: tuple  # tuple[str, ...]

    def probability(self, p_hit: float) -> float:
        return float(_as_fn(self.prob)(p_hit))


@dataclasses.dataclass(frozen=True)
class ClosedNetwork:
    name: str
    stations: tuple  # tuple[Station, ...]
    branches: tuple  # tuple[Branch, ...]
    mpl: int
    description: str = ""

    # ------------------------------------------------------------------ util
    def station(self, name: str) -> Station:
        for s in self.stations:
            if s.name == name:
                return s
        raise KeyError(name)

    def queue_stations(self):
        return [s for s in self.stations if s.kind == QUEUE]

    def think_stations(self):
        return [s for s in self.stations if s.kind == THINK]

    def validate(self, p_grid: Sequence[float] = (0.0, 0.25, 0.5, 0.75, 0.999)) -> None:
        names = [s.name for s in self.stations]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate station names in {self.name}")
        for b in self.branches:
            for v in b.visits:
                if v not in names:
                    raise ValueError(f"branch {b.name} visits unknown station {v}")
        for p in p_grid:
            tot = sum(b.probability(p) for b in self.branches)
            if not math.isclose(tot, 1.0, abs_tol=1e-6):
                raise ValueError(
                    f"{self.name}: branch probabilities sum to {tot} at p_hit={p}"
                )

    # --------------------------------------------------------------- demands
    def visit_counts(self, p_hit: float) -> dict:
        """Expected visits per request to each station."""
        counts = {s.name: 0.0 for s in self.stations}
        for b in self.branches:
            pb = b.probability(p_hit)
            for v in b.visits:
                counts[v] += pb
        return counts

    def demands(self, p_hit: float, tail_mode: str = "zero") -> dict:
        """Per-queue-station demand D_k.

        tail_mode:
          "zero"    — bound="upper" stations contribute 0   (paper's X upper bound)
          "nominal" — use the stated upper-bound service     (pessimistic)
        """
        counts = self.visit_counts(p_hit)
        out = {}
        for s in self.queue_stations():
            svc = s.mean_service(p_hit)
            if s.bound == "upper" and tail_mode == "zero":
                svc = 0.0
            out[s.name] = counts[s.name] * svc
        return out

    def think_time(self, p_hit: float) -> float:
        counts = self.visit_counts(p_hit)
        return sum(counts[s.name] * s.mean_service(p_hit) for s in self.think_stations())

    # ------------------------------------------------------------ thm 7.1
    def throughput_upper(self, p_hit, tail_mode: str = "zero"):
        """Paper's analytic upper bound, X <= min(N/(D+Z), 1/Dmax).  Vectorized."""
        p_arr = np.atleast_1d(np.asarray(p_hit, dtype=np.float64))
        out = np.empty_like(p_arr)
        for i, p in enumerate(p_arr):
            d = self.demands(float(p), tail_mode=tail_mode)
            D = sum(d.values())
            Dmax = max(d.values()) if d else 0.0
            Z = self.think_time(float(p))
            terms = [self.mpl / (D + Z)]
            if Dmax > 0:
                terms.append(1.0 / Dmax)
            out[i] = min(terms)
        return out if np.ndim(p_hit) else float(out[0])

    def bottleneck(self, p_hit: float, tail_mode: str = "zero") -> str:
        d = self.demands(p_hit, tail_mode=tail_mode)
        return max(d, key=d.get)

    def p_star(self, tail_mode: str = "zero", grid: int = 20001) -> float:
        """Critical hit ratio after which throughput starts to deteriorate.

        The bound can plateau (X = 1/D_max constant while the miss-path
        station stays the bottleneck), so p* is the *largest* hit ratio
        still achieving the maximum.  Returns 1.0 for FIFO-like policies
        (monotone increasing bound).
        """
        ps = np.linspace(0.0, 1.0, grid)
        xs = self.throughput_upper(ps, tail_mode=tail_mode)
        x_max = float(np.max(xs))
        at_max = np.nonzero(xs >= x_max * (1.0 - 1e-9))[0]
        return float(ps[int(at_max[-1])])

    # ---------------------------------------------------------------- MVA
    def mva(self, p_hit: float, n: int | None = None, tail_mode: str = "nominal"):
        """Exact Mean Value Analysis of the (product-form) exponential analogue.

        The paper only derives *bounds*; MVA gives the exact closed-network
        solution when services are exponential.  It is a very good
        approximation for the measured distributions (the paper notes
        insensitivity to service distributions, citing [80]).

        Returns (X, {station: mean queue length}, R_total).
        """
        n = int(n or self.mpl)
        d = self.demands(p_hit, tail_mode=tail_mode)
        names = list(d)
        D = np.array([d[k] for k in names], dtype=np.float64)
        Z = self.think_time(p_hit)
        Q = np.zeros_like(D)
        X = 0.0
        for k in range(1, n + 1):
            R = D * (1.0 + Q)
            Rtot = float(R.sum())
            X = k / (Z + Rtot)
            Q = X * R
        return X, dict(zip(names, Q.tolist())), Z + float((D * (1.0 + Q)).sum())

    def mva_throughput(self, p_hit, n: int | None = None, tail_mode: str = "nominal"):
        p_arr = np.atleast_1d(np.asarray(p_hit, dtype=np.float64))
        out = np.array([self.mva(float(p), n=n, tail_mode=tail_mode)[0] for p in p_arr])
        return out if np.ndim(p_hit) else float(out[0])

    def response_time_upper(self, p_hit, tail_mode: str = "zero"):
        """Mean cycle (response) time lower bound, R = N / X_upper."""
        return self.mpl / self.throughput_upper(p_hit, tail_mode=tail_mode)


# --------------------------------------------------------------------------
# Mitigation (paper §5.2): bypass the cache under load.
# --------------------------------------------------------------------------


def bypass_network(net: ClosedNetwork, beta: ProbFn) -> ClosedNetwork:
    """Send a fraction ``beta`` of requests straight to the backing store.

    Bypassed requests skip all policy metadata stations (and the cache
    cannot hit for them) — they visit only the lookup + disk think stations.
    The remaining ``1-beta`` behave exactly as in ``net``.
    """
    beta_fn = _as_fn(beta)
    scaled = []
    for b in net.branches:
        pf = _as_fn(b.prob)
        scaled.append(
            dataclasses.replace(
                b, prob=(lambda p, pf=pf, bf=beta_fn: (1.0 - bf(p)) * pf(p))
            )
        )
    disk = [s.name for s in net.think_stations() if "disk" in s.name]
    lookup = [s.name for s in net.think_stations() if "lookup" in s.name]
    visits = tuple(lookup[:1] + disk[:1])
    scaled.append(Branch("bypass", lambda p, bf=beta_fn: bf(p), visits))
    return dataclasses.replace(
        net, name=net.name + "+bypass", branches=tuple(scaled)
    )


def optimal_bypass_beta(net: ClosedNetwork, p_hit: float) -> float:
    """Smallest beta that caps the hit-path bottleneck demand at its p* level.

    For p_hit <= p*, no bypass is needed (beta = 0).  Beyond p*, keeping the
    bottleneck demand pinned at D_max(p*) keeps throughput flat instead of
    falling — the behaviour the paper reports for this mitigation.
    """
    p_star = net.p_star()
    if p_hit <= p_star:
        return 0.0
    target = max(net.demands(p_star).values())

    lo, hi = 0.0, 1.0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        d = max(bypass_network(net, mid).demands(p_hit).values())
        if d > target:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)
