"""Closed queueing-network analysis — prong A of the paper's methodology.

The paper models a DRAM cache under a Multi-Programming Limit (MPL) as a
*closed* queueing network:

  - **think stations** (infinite-server): cache lookup, disk/backing store,
    ghost lookup.  No queueing; all MPL requests may be in service at once.
  - **queue stations** (c-server FCFS, default c=1): the serialized metadata
    operations on the global eviction structure (delink, head update, tail
    update, ...), and — for the "future systems" extension — finite-
    concurrency resources such as a backing store with bounded I/O depth.

Throughput is upper-bounded (Harchol-Balter, "Performance Modeling and
Design of Computer Systems", Theorem 7.1; multi-server bottleneck law)
by::

    X  <=  min( N / (D + E[Z]),  min_k c_k / D_k )

where ``D_k`` is the *demand* of queue station ``k`` (expected total service
a single request places on that station per pass through the system),
``c_k`` its server count, ``D = sum_k D_k`` and ``E[Z]`` the total think
time.  A ``c_k``-server station completes at most ``c_k / D_k`` requests per
unit time when saturated; with every ``c_k = 1`` this reduces to the
paper's ``1 / D_max`` form.

Everything below is parameterized by the hit ratio ``p_hit`` — demands and
service times are functions of ``p_hit`` — which is what lets the model
expose the paper's central phenomenon: the bottleneck (arg-max demand
station) switching from the miss path to the hit path at ``p*_hit``.

Units: microseconds.  Throughput is requests/µs == millions of requests/s.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence, Union

import numpy as np

ServiceFn = Union[float, Callable[[float], float]]
ProbFn = Union[float, Callable[[float], float]]

QUEUE = "queue"
THINK = "think"


def _as_fn(v: ServiceFn) -> Callable[[float], float]:
    if callable(v):
        return v
    return lambda p, _v=float(v): _v


@dataclasses.dataclass(frozen=True)
class Station:
    """One service station.

    ``bound="upper"`` marks stations whose service time could only be
    bounded from above in the paper's measurements (the tail updates — they
    are never the bottleneck, so they cannot be kept saturated to measure
    the inter-departure time).  The throughput *upper* bound uses 0 for
    these; the pessimistic bound uses ``service``.
    """

    name: str
    kind: str  # QUEUE | THINK
    service: ServiceFn  # mean service time (µs), may depend on p_hit
    bound: str = "exact"  # "exact" | "upper"
    dist: str = "det"  # det | exp | pareto  (used by the simulator)
    dist_params: tuple = ()
    servers: int = 1  # FCFS server count (QUEUE stations only)

    def mean_service(self, p_hit: float) -> float:
        return float(_as_fn(self.service)(p_hit))


@dataclasses.dataclass(frozen=True)
class Branch:
    """A probabilistic route through the network.

    Each completed request samples one branch (probabilities must sum to 1
    at every ``p_hit``) and visits ``visits`` in order.  Station names may
    repeat (a station visited twice contributes twice to demand).
    """

    name: str
    prob: ProbFn
    visits: tuple  # tuple[str, ...]

    def probability(self, p_hit: float) -> float:
        return float(_as_fn(self.prob)(p_hit))


@dataclasses.dataclass(frozen=True)
class ClosedNetwork:
    name: str
    stations: tuple  # tuple[Station, ...]
    branches: tuple  # tuple[Branch, ...]
    mpl: int
    description: str = ""

    # ------------------------------------------------------------------ util
    def station(self, name: str) -> Station:
        for s in self.stations:
            if s.name == name:
                return s
        raise KeyError(name)

    def queue_stations(self) -> list[Station]:
        return [s for s in self.stations if s.kind == QUEUE]

    def think_stations(self) -> list[Station]:
        return [s for s in self.stations if s.kind == THINK]

    def validate(self, p_grid: Sequence[float] = (0.0, 0.25, 0.5, 0.75, 0.999)) -> None:
        names = [s.name for s in self.stations]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate station names in {self.name}")
        for s in self.stations:
            if s.servers < 1:
                raise ValueError(f"station {s.name}: servers must be >= 1")
        kinds = {s.name: s.kind for s in self.stations}
        for b in self.branches:
            for v in b.visits:
                if v not in names:
                    raise ValueError(f"branch {b.name} visits unknown station {v}")
            # Simulators place all mpl jobs straight into service at their
            # first station, which is only correct for infinite-server
            # stations — queue-first routes would bypass busy accounting.
            if b.visits and kinds[b.visits[0]] != THINK:
                raise ValueError(
                    f"branch {b.name} must start at a think station, "
                    f"not queue station {b.visits[0]}"
                )
        for p in p_grid:
            tot = sum(b.probability(p) for b in self.branches)
            if not math.isclose(tot, 1.0, abs_tol=1e-6):
                raise ValueError(
                    f"{self.name}: branch probabilities sum to {tot} at p_hit={p}"
                )

    # --------------------------------------------------------------- demands
    def visit_counts(self, p_hit: float) -> dict[str, float]:
        """Expected visits per request to each station."""
        counts = {s.name: 0.0 for s in self.stations}
        for b in self.branches:
            pb = b.probability(p_hit)
            for v in b.visits:
                counts[v] += pb
        return counts

    def demands(self, p_hit: float,
                tail_mode: str = "zero") -> dict[str, float]:
        """Per-queue-station demand D_k.

        tail_mode:
          "zero"    — bound="upper" stations contribute 0   (paper's X upper bound)
          "nominal" — use the stated upper-bound service     (pessimistic)
        """
        counts = self.visit_counts(p_hit)
        out = {}
        for s in self.queue_stations():
            svc = s.mean_service(p_hit)
            if s.bound == "upper" and tail_mode == "zero":
                svc = 0.0
            out[s.name] = counts[s.name] * svc
        return out

    def think_time(self, p_hit: float) -> float:
        counts = self.visit_counts(p_hit)
        return sum(counts[s.name] * s.mean_service(p_hit) for s in self.think_stations())

    def queue_servers(self) -> dict[str, int]:
        """Server count c_k per queue station."""
        return {s.name: int(s.servers) for s in self.queue_stations()}

    # ------------------------------------------------------------ thm 7.1
    def throughput_upper(self, p_hit: float | np.ndarray,
                         tail_mode: str = "zero") -> float | np.ndarray:
        """Analytic upper bound, X <= min(N/(D+Z), min_k c_k/D_k).  Vectorized.

        With all-single-server stations this is exactly the paper's
        X <= min(N/(D+Z), 1/Dmax) (Thm 7.1); a c-server station saturates
        at c/D_k instead of 1/D_k.
        """
        servers = self.queue_servers()
        p_arr = np.atleast_1d(np.asarray(p_hit, dtype=np.float64))
        out = np.empty_like(p_arr)
        for i, p in enumerate(p_arr):
            d = self.demands(float(p), tail_mode=tail_mode)
            D = sum(d.values())
            Z = self.think_time(float(p))
            terms = [self.mpl / (D + Z)]
            terms += [servers[k] / dk for k, dk in d.items() if dk > 0]
            out[i] = min(terms)
        return out if np.ndim(p_hit) else float(out[0])

    def bottleneck(self, p_hit: float, tail_mode: str = "zero") -> str:
        """Station that saturates first: arg-max of per-server demand D_k/c_k."""
        servers = self.queue_servers()
        d = self.demands(p_hit, tail_mode=tail_mode)
        return max(d, key=lambda k: d[k] / servers[k])

    def p_star(self, tail_mode: str = "zero", grid: int = 20001) -> float:
        """Critical hit ratio after which throughput starts to deteriorate.

        The bound can plateau (X = 1/D_max constant while the miss-path
        station stays the bottleneck), so p* is the *largest* hit ratio
        still achieving the maximum.  Returns 1.0 for FIFO-like policies
        (monotone increasing bound).
        """
        ps = np.linspace(0.0, 1.0, grid)
        xs = self.throughput_upper(ps, tail_mode=tail_mode)
        x_max = float(np.max(xs))
        at_max = np.nonzero(xs >= x_max * (1.0 - 1e-9))[0]
        return float(ps[int(at_max[-1])])

    # ---------------------------------------------------------------- MVA
    AMVA_AUTO_MPL = 1000  # mode="auto" switches to Schweitzer above this N

    def mva(self, p_hit: float, n: int | None = None,
            tail_mode: str = "nominal", multiserver: str = "exact",
            mode: str = "exact") -> tuple[float, dict[str, float], float]:
        """Mean Value Analysis of the (product-form) exponential analogue.

        The paper only derives *bounds*; MVA gives the exact closed-network
        solution when services are exponential.  It is a very good
        approximation for the measured distributions (the paper notes
        insensitivity to service distributions, citing [80]).

        ``mode`` selects the recursion:

        ``"exact"`` (default)
            The full population recursion, O(N) per station (O(N^2) with
            load-dependent multi-server marginals).
        ``"amva"``
            Schweitzer's approximate MVA: the fixed point of the
            arrival-theorem estimate  Q_k(N-1) ~= Q_k(N) (N-1)/N.  O(1) in
            the population per iteration — the fallback that keeps
            "future systems" sweeps with MPL >> 10^3 tractable.
            Multi-server stations use Seidmann's tandem transform.
        ``"auto"``
            ``"amva"`` when N > AMVA_AUTO_MPL (1000), else ``"exact"``.

        Multi-server (c > 1) stations are handled per ``multiserver``
        (exact mode only):

        ``"exact"`` (default)
            Load-dependent MVA: per-station marginal queue-length
            probabilities with service rate min(j, c)/S — exact for the
            exponential analogue (Reiser & Lavenberg).
        ``"seidmann"``
            Seidmann's tandem decomposition: the c-server station becomes a
            single server with demand D/c plus a pure delay of D(c-1)/c.
            Cheaper, but underestimates X by up to ~15% when the population
            is close to c.

        With every ``servers=1`` both modes reduce to the same plain
        single-server recursion as the seed code, bit for bit.

        Returns (X, {station: mean queue length}, R_total).
        """
        n = int(n or self.mpl)
        d = self.demands(p_hit, tail_mode=tail_mode)
        names = list(d)
        servers = self.queue_servers()
        C = np.array([servers[k] for k in names], dtype=np.float64)
        D = np.array([d[k] for k in names], dtype=np.float64)
        Z = self.think_time(p_hit)

        if mode not in ("exact", "amva", "auto"):
            raise ValueError(f"unknown mva mode {mode!r}")
        if mode == "auto":
            mode = "amva" if n > self.AMVA_AUTO_MPL else "exact"
        if mode == "amva":
            return self._schweitzer(names, D, C, Z, n)
        if multiserver not in ("exact", "seidmann"):
            raise ValueError(f"unknown multiserver mode {multiserver!r}")
        if multiserver == "seidmann" or np.all(C == 1.0):
            Dq = D / C  # queueing portion (per-server demand)
            Zd = float((D * (C - 1.0) / C).sum())  # Seidmann delay portion
            Z = Z + Zd
            Q = np.zeros_like(D)
            X = 0.0
            R = Dq
            for k in range(1, n + 1):
                R = Dq * (1.0 + Q)
                X = k / (Z + float(R.sum()))
                Q = X * R
            # R_total = Z + R(n) = n/X — same Little's-law-consistent
            # convention as the exact branch below.
            return X, dict(zip(names, Q.tolist())), Z + float(R.sum())

        # Exact load-dependent recursion.  Single-server stations only need
        # their mean queue length; c>1 stations carry marginal probabilities
        # p_k(j | pop):  R_k = D_k sum_j (j / min(j, c)) p_k(j-1 | pop-1).
        # The marginal update is renormalized when float error pushes
        # sum_j>0 p_j past 1 — the classic MVA-LD instability at saturation
        # otherwise compounds (the clamped p_0 form can overshoot c_k/D_k).
        K = len(names)
        Q = np.zeros(K)
        j_idx = np.arange(1, n + 1, dtype=np.float64)
        weights = {}  # per multi-server station: j / min(j, c) for j = 1..n
        marg = {}
        for k in range(K):
            if C[k] > 1:
                weights[k] = j_idx / np.minimum(j_idx, C[k])
                pk = np.zeros(n + 1)
                pk[0] = 1.0
                marg[k] = pk
        X = 0.0
        R = np.zeros(K)
        for pop in range(1, n + 1):
            for k in range(K):
                if k in marg:
                    R[k] = D[k] * float((weights[k][:pop] * marg[k][:pop]).sum())
                else:
                    R[k] = D[k] * (1.0 + Q[k])
            X = pop / (Z + float(R.sum()))
            Q = X * R
            for k in marg:
                pk = marg[k]
                new = np.zeros(n + 1)
                new[1:pop + 1] = X * D[k] / np.minimum(j_idx[:pop], C[k]) * pk[:pop]
                s = float(new[1:].sum())
                if s > 1.0:
                    new[1:] /= s
                else:
                    new[0] = 1.0 - s
                marg[k] = new
        return X, dict(zip(names, Q.tolist())), Z + float(R.sum())

    def _schweitzer(self, names: Sequence[str], D: np.ndarray,
                    C: np.ndarray, Z: float,
                    n: int) -> tuple[float, dict[str, float], float]:
        """Schweitzer/approximate MVA fixed point (Bard-Schweitzer).

        Iterates R_k = D_k (1 + Q_k (n-1)/n), X = n/(Z + sum R), Q_k = X R_k
        until the queue lengths settle.  Cost is independent of n, vs the
        exact recursion's O(n) (O(n^2) load-dependent) — the difference
        between milliseconds and minutes at MPL ~ 10^5.  Accuracy is the
        classic AMVA trade: a few percent, pinned <2% vs exact at MPL=500
        in tests/test_multiserver.py.
        """
        # multi-server stations via Seidmann: queueing demand D/c plus a
        # fixed delay D(c-1)/c folded into the think time.
        Dq = D / C
        Z = Z + float((D * (C - 1.0) / C).sum())
        K = len(Dq)
        Q = np.full(K, n / max(K, 1), dtype=np.float64)
        X = 0.0
        R = Dq.copy()
        scale = (n - 1.0) / n if n > 0 else 0.0
        for _ in range(10_000):
            R = Dq * (1.0 + Q * scale)
            X = n / (Z + float(R.sum()))
            Q_new = X * R
            if float(np.abs(Q_new - Q).max()) < 1e-10:
                Q = Q_new
                break
            Q = Q_new
        return X, dict(zip(names, Q.tolist())), Z + float(R.sum())

    def mva_throughput(self, p_hit: float | np.ndarray,
                       n: int | None = None, tail_mode: str = "nominal",
                       multiserver: str = "exact",
                       mode: str = "exact") -> float | np.ndarray:
        p_arr = np.atleast_1d(np.asarray(p_hit, dtype=np.float64))
        out = np.array([
            self.mva(float(p), n=n, tail_mode=tail_mode,
                     multiserver=multiserver, mode=mode)[0]
            for p in p_arr
        ])
        return out if np.ndim(p_hit) else float(out[0])

    def response_time_upper(self, p_hit: float | np.ndarray,
                            tail_mode: str = "zero") -> float | np.ndarray:
        """Mean cycle (response) time lower bound, R = N / X_upper."""
        return self.mpl / self.throughput_upper(p_hit, tail_mode=tail_mode)


def disk_station(disk_us: float, disk_servers: int = 0) -> Station:
    """The backing store: infinite-server think station (the paper's model,
    ``disk_servers=0``) or a c-server FCFS queue station with bounded I/O
    concurrency (the "future systems" extension).  Single definition shared
    by the analytic policy networks and the prong-C harness so the two
    stacks can never model different disks behind the same knob."""
    if disk_servers:
        return Station("disk", QUEUE, float(disk_us), dist="exp",
                       servers=int(disk_servers))
    return Station("disk", THINK, float(disk_us), dist="exp")


def exponential_analogue(net: ClosedNetwork) -> ClosedNetwork:
    """Replace every service distribution by exponential (same means).

    This is the network MVA actually solves; simulate it when validating
    MVA at CI-level precision — the det/pareto originals differ from the
    exponential analogue by a genuine (in)sensitivity gap of several percent
    at saturated single-server stations.
    """
    return dataclasses.replace(
        net,
        stations=tuple(
            dataclasses.replace(s, dist="exp", dist_params=()) for s in net.stations
        ),
    )


# --------------------------------------------------------------------------
# Delayed hits / miss coalescing (Manohar et al. 2020; MSHR-style fill table).
# --------------------------------------------------------------------------

INFLIGHT = "inflight"


def _disk_stations(net: ClosedNetwork, disk_name: str) -> list[str]:
    """All backing-store stations matching ``disk_name`` by suffix: the
    bare single-node ``"disk"`` and the cluster composition's per-shard
    replicas (``"s0:disk"``, ...), in station order."""
    return [s.name for s in net.stations
            if s.name == disk_name or s.name.split(":")[-1] == disk_name]


def _disk_branches(net: ClosedNetwork, disk_name: str) -> list[Branch]:
    names = set(_disk_stations(net, disk_name))
    return [b for b in net.branches if names & set(b.visits)]


def sigma_of(net: ClosedNetwork, p_hit: float) -> float:
    """Recover the coalescing factor sigma(p) of a coalesced network.

    Reads the probability mass of the ``*_delayed`` branches that
    :func:`coalesced_network` creates, relative to all fill-requiring
    traffic (delayed + leader/disk branches).  On a multi-disk (sharded)
    network this is the miss-share-weighted mean of the per-shard
    sigma_k.  Returns 0 for a network without coalescing.  Lives here so
    the ``_delayed`` naming convention stays private to this module.
    """
    delayed = sum(
        b.probability(p_hit) for b in net.branches
        if b.name.endswith("_delayed")
    )
    fills = delayed + sum(
        b.probability(p_hit) for b in _disk_branches(net, "disk")
    )
    return delayed / fills if fills > 0 else 0.0


def zipf_flow_weights(flows: int, theta: float = 0.0) -> np.ndarray:
    """Per-flow popularity weights of the coalescing hot-key ensemble.

    ``w_f ∝ (f+1)^-theta`` normalized to sum 1 (descending); theta=0 is the
    uniform ensemble the original fixed point assumed.  Matching theta to a
    trace's Zipf skew makes the analytic sigma predictable from the per-key
    miss spectrum instead of an effective flow count — the weights are the
    miss-probability shares of the hot keys.
    """
    if flows < 1:
        raise ValueError("flows must be >= 1")
    w = np.arange(1, flows + 1, dtype=np.float64) ** (-float(theta))
    return w / w.sum()


def coalesced_network(
    net: ClosedNetwork,
    flows: int = 64,
    window_us: ServiceFn | None = None,
    sigma: ProbFn | None = None,
    disk_name: str = "disk",
    window_mode: str = "service",
    flow_theta: float = 0.0,
) -> ClosedNetwork:
    """Miss-coalescing transform: concurrent misses on one key share a fetch.

    The base model treats every miss as independent — each pays a full
    backing-store trip and a full pass through the miss-path metadata
    stations.  Real caches keep an outstanding-miss table (MSHRs): a
    request that misses on a key whose fetch is already *in flight* parks
    until the fill lands (a "delayed hit" — Manohar et al. 2020) and issues
    no second I/O and no second insertion.  The disk therefore sees the
    *coalesced* miss rate ``X (1-p) (1-sigma)`` instead of ``X (1-p)``.

    Every branch of ``net`` that visits ``disk_name`` splits in two:

    * the **leader** (probability scaled by ``1 - sigma(p)``) — the request
      that initiates the fetch; it follows the original route, including
      the post-disk fill/eviction metadata stations;
    * the **delayed hit** (probability scaled by ``sigma(p)``) — it keeps
      the pre-disk visits, then parks on a new infinite-server ``inflight``
      station for the *residual* window (window/2 for a deterministic
      fetch latency under a uniformly-positioned arrival) and completes
      without touching the disk or the fill metadata.

    ``window_us`` is the in-flight window — how long a fetch stays
    outstanding; it defaults to the disk station's own mean service time
    (a fetch is in flight exactly while the disk serves it).  May be a
    callable of ``p_hit`` like every other service time.

    ``window_mode="mva"`` makes the default window *queueing-aware*: with a
    bounded-I/O-depth disk (``disk_servers`` > 0) a fetch stays outstanding
    through its queueing delay too, so the window becomes the disk's
    per-visit MVA residence time (service + estimated wait, re-solved
    inside the sigma fixed point) instead of the bare service.  With the
    paper's infinite-server disk the residence equals the service and the
    mode changes nothing.  An explicit ``window_us`` always wins.

    ``flow_theta`` skews the hot-key flow ensemble Zipf(theta)-style (see
    :func:`zipf_flow_weights`): the fixed point becomes the weight-mixture
    ``sigma = sum_f w_f * mu_f L / (1 + mu_f L)`` with per-flow miss rate
    ``mu_f = X * P{miss} * w_f``.  theta=0 reproduces the original uniform
    formula exactly.

    ``sigma`` is the coalescing factor — the fraction of would-be misses
    that find a fetch for their key already in flight.  Pass a constant or
    a callable (e.g. the measured fraction from prong C's
    :func:`repro.cache.replay.classify_inflight`); when omitted it is
    solved self-consistently from the in-flight window: per-flow misses
    initiate fetches as a renewal process (window ``L`` then an idle gap),
    giving

        sigma(p) = mu L / (1 + mu L)

    with the per-flow miss rate ``mu = X(p) * P{miss}(p) / flows`` and
    ``L`` the window; ``X`` is the coalesced
    network's own throughput bound — a contraction solved by fixed-point
    iteration and memoized per ``p``.  ``flows`` is the effective number
    of concurrently-missed hot keys the miss stream spreads over (fewer
    flows => more collisions => more coalescing).

    With ``window_us = 0`` (or ``sigma = 0``) the transform is exact
    identity on every demand and think time: sigma solves to 0, the
    delayed branches carry probability 0, and bounds/MVA/simulation all
    reduce to the base network's values.

    **Sharded networks.**  ``disk_name`` matches by suffix, so a cluster
    composition with per-shard disks (``"s0:disk"``, ..., the PR 5
    naming) gets one coalescing factor **per shard**: each disk gets its
    own ``inflight`` station (``"s0:inflight"``) and its own fixed point
    ``sigma_k = sum_f w_f mu_{k,f} L_k / (1 + mu_{k,f} L_k)`` against
    that shard's *own* miss rate ``mu_{k,f} = X m_k w_f / 1`` (with
    ``m_k`` the probability mass of branches visiting shard ``k``'s
    disk), solved jointly with the shared throughput bound ``X`` — the
    simulator's shard-local MSHR tables, analytically.  Hot shards
    coalesce more; a single flat sigma would average that away.  With
    one disk this reduces exactly to the single fixed point above.
    """
    disks = _disk_stations(net, disk_name)
    if not disks or not _disk_branches(net, disk_name):
        raise ValueError(f"{net.name} has no branch visiting {disk_name!r}")
    if window_mode not in ("service", "mva"):
        raise ValueError(f"unknown window_mode {window_mode!r}")
    weights = zipf_flow_weights(flows, flow_theta)
    if window_us is not None:
        base_window = {d: _as_fn(window_us) for d in disks}
    else:
        base_window = {d: net.station(d).mean_service for d in disks}
    use_mva = window_mode == "mva" and window_us is None

    def inflight_name(d: str) -> str:
        return (f"{d[:-len(disk_name)]}{INFLIGHT}"
                if d.endswith(":" + disk_name) else INFLIGHT)

    def branch_disk(b: Branch) -> str | None:
        for v in b.visits:
            if v in disks:
                return v
        return None

    # sigma_fns / window_fns: disk station name -> callable of p.
    def build(sigma_fns: dict, window_fns: dict) -> ClosedNetwork:
        stations = net.stations + tuple(
            Station(inflight_name(d), THINK,
                    lambda p, d=d: 0.5 * window_fns[d](p), dist="exp")
            for d in disks
        )
        branches = []
        for b in net.branches:
            d = branch_disk(b)
            if d is None:
                branches.append(b)
                continue
            pf = _as_fn(b.prob)
            sfn = sigma_fns[d]
            pre = b.visits[: b.visits.index(d)]
            branches.append(
                dataclasses.replace(
                    b, prob=lambda p, pf=pf, sfn=sfn: pf(p) * (1.0 - sfn(p))
                )
            )
            branches.append(
                Branch(
                    b.name + "_delayed",
                    lambda p, pf=pf, sfn=sfn: pf(p) * sfn(p),
                    pre + (inflight_name(d),),
                )
            )
        return dataclasses.replace(
            net,
            name=net.name + "+coalesce",
            stations=stations,
            branches=tuple(branches),
        )

    def mva_window(p: float, net_s: ClosedNetwork, d: str,
                   base_L: float) -> float:
        """Per-visit disk residence (service + estimated wait) of the
        coalesced network at its current sigma — the queueing-aware
        in-flight window.  A think-station disk has no queueing term, so
        this degenerates to the base window."""
        v = net_s.visit_counts(p).get(d, 0.0)
        if v <= 0.0:
            return base_L
        X, Q, _ = net_s.mva(p, mode="auto")
        if d not in Q or X <= 0.0:
            return base_L
        # Little's law per visit: residence = Q_disk / (X * V_disk).
        return max(base_L, Q[d] / (X * v))

    if sigma is not None:
        sfn = _as_fn(sigma)
        sigma_fns = {d: sfn for d in disks}
        if not use_mva:
            return build(sigma_fns, base_window)
        memo_w: dict = {}

        def window_eff(p: float, d: str) -> float:
            key = (round(float(p), 12), d)
            if key not in memo_w:
                memo_w[key] = mva_window(
                    float(p), build(sigma_fns, base_window), d,
                    float(base_window[d](p))
                )
            return memo_w[key]

        return build(sigma_fns,
                     {d: (lambda p, d=d: window_eff(p, d)) for d in disks})

    def miss_share(p: float, d: str) -> float:
        return sum(b.probability(p) for b in net.branches
                   if branch_disk(b) == d)

    memo: dict = {}  # p -> ({disk: sigma}, {disk: effective window})

    def solve(p: float) -> tuple[dict, dict]:
        key = round(float(p), 12)
        if key in memo:
            return memo[key]
        base_L = {d: float(base_window[d](p)) for d in disks}
        L = dict(base_L)
        m = {d: miss_share(p, d) for d in disks}
        s = {d: 0.0 for d in disks}
        live = [d for d in disks if base_L[d] > 0.0 and m[d] > 0.0]
        if live:
            for _ in range(100):
                net_s = build(
                    {d: (lambda _p, v=s[d]: v) for d in disks},
                    {d: (lambda _p, v=L[d]: v) for d in disks},
                )
                X = float(net_s.throughput_upper(p, tail_mode="zero"))
                if use_mva:
                    for d in live:
                        L[d] = mva_window(p, net_s, d, base_L[d])
                s_new = dict(s)
                for d in live:
                    if flow_theta == 0.0:
                        mu = X * m[d] / flows
                        s_new[d] = mu * L[d] / (1.0 + mu * L[d])
                    else:
                        mu_f = X * m[d] * weights
                        s_new[d] = float(
                            (weights * mu_f * L[d] / (1.0 + mu_f * L[d])).sum()
                        )
                if all(abs(s_new[d] - s[d]) < 1e-12 for d in live):
                    s = s_new
                    break
                # the MVA window couples L to sigma; damp that richer fixed
                # point (plain iteration stays exact for the service window)
                s = ({d: 0.5 * (s[d] + s_new[d]) for d in disks}
                     if use_mva else s_new)
        memo[key] = (s, L)
        return memo[key]

    return build(
        {d: (lambda p, d=d: solve(p)[0][d]) for d in disks},
        {d: (lambda p, d=d: solve(p)[1][d]) for d in disks},
    )


# --------------------------------------------------------------------------
# Mitigation (paper §5.2): bypass the cache under load.
# --------------------------------------------------------------------------


def bypass_network(net: ClosedNetwork, beta: ProbFn) -> ClosedNetwork:
    """Send a fraction ``beta`` of requests straight to the backing store.

    Bypassed requests skip all policy metadata stations (and the cache
    cannot hit for them) — they visit only the lookup + disk think stations.
    The remaining ``1-beta`` behave exactly as in ``net``.
    """
    beta_fn = _as_fn(beta)
    scaled = []
    for b in net.branches:
        pf = _as_fn(b.prob)
        scaled.append(
            dataclasses.replace(
                b, prob=(lambda p, pf=pf, bf=beta_fn: (1.0 - bf(p)) * pf(p))
            )
        )
    # the disk may be a think station (paper) or a c-server queue station
    # (disk_servers > 0) — bypassed traffic hits it either way.
    disk = [s.name for s in net.stations if "disk" in s.name]
    lookup = [s.name for s in net.think_stations() if "lookup" in s.name]
    visits = tuple(lookup[:1] + disk[:1])
    scaled.append(Branch("bypass", lambda p, bf=beta_fn: bf(p), visits))
    return dataclasses.replace(
        net, name=net.name + "+bypass", branches=tuple(scaled)
    )


def optimal_bypass_beta(net: ClosedNetwork, p_hit: float, grid: int = 1001) -> float:
    """Smallest beta that caps the hit-path bottleneck demand at its p* level.

    For p_hit <= p*, no bypass is needed (beta = 0).  Beyond p*, keeping the
    bottleneck demand pinned at D_max(p*) keeps throughput flat instead of
    falling — the behaviour the paper reports for this mitigation.  The cap
    only covers stations the bypass actually relieves: bypassed requests
    still visit the lookup + backing store, so those are excluded (for the
    paper's infinite-server disk this changes nothing — think stations carry
    no queueing demand).

    With a bounded-I/O-depth disk (``disk_servers`` > 0) bypassing *adds*
    disk demand, so the capping beta can saturate the disk and make the
    "mitigation" a net loss; in that case fall back to the beta maximizing
    the analytic bound over a grid (ties resolve to the smallest beta).
    """
    p_star = net.p_star()
    if p_hit <= p_star:
        return 0.0
    servers = net.queue_servers()
    relieved = set(servers) - set(
        next(b for b in bypass_network(net, 0.5).branches
             if b.name == "bypass").visits
    )

    def max_relieved(n: ClosedNetwork, p: float) -> float:
        return max(
            (dk / servers[k] for k, dk in n.demands(p).items() if k in relieved),
            default=0.0,
        )

    target = max_relieved(net, p_star)

    lo, hi = 0.0, 1.0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if max_relieved(bypass_network(net, mid), p_hit) > target:
            lo = mid
        else:
            hi = mid
    beta = 0.5 * (lo + hi)

    if (bypass_network(net, beta).throughput_upper(p_hit)
            < net.throughput_upper(p_hit)):
        betas = np.linspace(0.0, 1.0, grid)
        xs = np.array([
            float(bypass_network(net, float(b)).throughput_upper(p_hit))
            for b in betas
        ])
        beta = float(betas[int(np.argmax(xs))])
    return beta
