"""Per-policy queueing networks with the paper's measured service times.

Each builder returns a :class:`~repro.core.queueing.ClosedNetwork` whose
analytic upper bound reproduces the paper's equations exactly:

  LRU       — Eq. (1)/(2)/(3)        (Sec. 3.2)
  FIFO      — Eq. (4)/(5)/(6)        (Sec. 4.1)
  Prob-LRU  — q = 0.5 and q = 1-1/72 (Sec. 4.2)
  CLOCK     — Sec. 4.3
  SLRU      — Sec. 4.4 (with the 98.71 coefficient; the paper's printed
              88.71 is inconsistent with its own demand derivation)
  S3-FIFO   — Sec. 4.5 (chi^2 fits encoded as printed, clamped to [0,1])

All service times are the paper's measurements on a 72-core Xeon 8360Y
(Sec. 3.1/3.4).  ``disk_us`` selects the emulated backing-store latency
(500 / 100 / 5 µs in the paper), ``mpl`` the multi-programming limit.

"Future systems" knobs (paper Sec. 6 — more cores per CPU, faster disks):

* ``cores`` — number of client cores; the paper runs one closed-loop client
  thread per core, so this simply sets ``mpl = cores`` (overriding ``mpl``).
* ``disk_servers`` — when > 0, the backing store is modeled as a
  ``disk_servers``-server FCFS queue station (bounded I/O concurrency, e.g.
  an NVMe queue depth) instead of the infinite-server think station the
  paper assumes.  0 keeps the paper's infinite-server disk.
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.queueing import (
    QUEUE,
    THINK,
    Branch,
    ClosedNetwork,
    Station,
    coalesced_network,
    disk_station,
)

Z_CACHE_LOOKUP = 0.51  # µs, Sec. 3.1

# Measured service times (µs).  See Figures 2, 4, 6, 9, 11, 13.
LRU_S_DELINK = 0.70
LRU_S_HEAD = 0.59
FIFO_S_HEAD = 0.73
CLOCK_S_BASE = 0.65

# Prob-LRU calibration: S_head/S_delink depend on q because q changes the
# queue lengths and hence the cross-core communication component of the
# service time (Sec. 3.1, Sec. 4.2).  Calibrated at the paper's two settings
# plus the LRU (q=0) and FIFO (q=1) endpoints.
_PROB_Q = np.array([0.0, 0.5, 1.0 - 1.0 / 72.0, 1.0])
_PROB_S_DELINK = np.array([0.70, 0.78, 0.79, 0.79])
_PROB_S_HEAD = np.array([0.59, 0.65, 0.67, 0.73])


def clock_g(x):
    """CLOCK tail-scan overhead fit, Sec. 4.3:  g(x) = 2.43e-5 e^{11.24 x} + 0.187."""
    return 2.43e-5 * np.exp(11.24 * np.asarray(x, dtype=np.float64)) + 0.187


def slru_ell(p):
    """P{hit lands in the protected T list} fit, Sec. 4.4."""
    p = np.asarray(p, dtype=np.float64)
    return -0.1144 * p**2 + 1.009 * p


def chi2_h(x, a, b, c):
    """The paper's chi^2-shaped fit h(x; a, b, c), Sec. 4.5, as printed.

    Zero outside the support x > b.
    """
    x = np.asarray(x, dtype=np.float64)
    z = (x - b) / c
    with np.errstate(invalid="ignore", divide="ignore", over="ignore"):
        val = (
            np.power(np.maximum(z, 0.0), a / 2.0 - 1.0)
            * np.exp(-np.maximum(x - b, 0.0) / (2.0 * c))
            / (2.0 ** (a / 2.0) * math.gamma(a / 2.0) * c**a)
        )
    return np.where(z > 0.0, val, 0.0)


def s3fifo_p_ghost(p_hit):
    """Fraction of misses the ghost routes to the M list (clamped fit)."""
    p = np.asarray(p_hit, dtype=np.float64)
    miss = np.maximum(1.0 - p, 1e-9)
    return np.clip(chi2_h(65.0 * miss, 4.4912, 1.1394, 3.595) / miss, 0.0, 1.0)


def s3fifo_p_m(p_hit):
    """Fraction of S-tail items with bit=1 (promoted to M on eviction)."""
    p = np.asarray(p_hit, dtype=np.float64)
    miss = np.maximum(1.0 - p, 1e-9)
    return np.clip(chi2_h(400.0 * miss, 2.2870, 4.5309, 26.5874) / miss, 0.0, 1.0)


def _common_think(disk_us: float, disk_servers: int = 0):
    return [
        Station("lookup", THINK, Z_CACHE_LOOKUP, dist="det"),
        disk_station(disk_us, disk_servers),
    ]


def _resolve_mpl(mpl: int, cores) -> int:
    """One closed-loop client thread per core (paper Sec. 3.1 testbed)."""
    return int(cores) if cores is not None else int(mpl)


# --------------------------------------------------------------------------
# LRU — Sec. 3
# --------------------------------------------------------------------------


def lru_network(disk_us: float = 100.0, mpl: int = 72, cores: int | None = None,
                disk_servers: int = 0) -> ClosedNetwork:
    """Fig. 2.  Hit: delink + head update.  Miss: disk + tail + head update."""
    mpl = _resolve_mpl(mpl, cores)
    stations = _common_think(disk_us, disk_servers) + [
        # S_head ~ BoundedPareto(alpha=0.45, 0.1..1.2) per Sec 3.1.
        Station("head", QUEUE, LRU_S_HEAD, dist="pareto", dist_params=(0.45, 0.1, 1.2)),
        Station("delink", QUEUE, LRU_S_DELINK, dist="det"),
        Station("tail", QUEUE, LRU_S_HEAD, bound="upper", dist="det"),
    ]
    branches = [
        Branch("hit", lambda p: p, ("lookup", "delink", "head")),
        Branch("miss", lambda p: 1.0 - p, ("lookup", "disk", "tail", "head")),
    ]
    return ClosedNetwork(
        "lru", tuple(stations), tuple(branches), mpl,
        description="LRU: global list touched on every hit (delink+head).",
    )


# --------------------------------------------------------------------------
# FIFO — Sec. 4.1
# --------------------------------------------------------------------------


def fifo_network(disk_us: float = 100.0, mpl: int = 72, cores: int | None = None,
                 disk_servers: int = 0) -> ClosedNetwork:
    """Fig. 4.  Hit: nothing.  Miss: disk + tail + head update."""
    mpl = _resolve_mpl(mpl, cores)
    stations = _common_think(disk_us, disk_servers) + [
        Station("head", QUEUE, FIFO_S_HEAD, dist="pareto", dist_params=(0.45, 0.1, 1.4)),
        Station("tail", QUEUE, FIFO_S_HEAD, bound="upper", dist="det"),
    ]
    branches = [
        Branch("hit", lambda p: p, ("lookup",)),
        Branch("miss", lambda p: 1.0 - p, ("lookup", "disk", "tail", "head")),
    ]
    return ClosedNetwork(
        "fifo", tuple(stations), tuple(branches), mpl,
        description="FIFO: hits never touch the global list.",
    )


# --------------------------------------------------------------------------
# Probabilistic LRU — Sec. 4.2
# --------------------------------------------------------------------------


def prob_lru_service(q: float):
    s_delink = float(np.interp(q, _PROB_Q, _PROB_S_DELINK))
    s_head = float(np.interp(q, _PROB_Q, _PROB_S_HEAD))
    return s_delink, s_head


def prob_lru_network(q: float = 0.5, disk_us: float = 100.0, mpl: int = 72,
                     cores: int | None = None, disk_servers: int = 0) -> ClosedNetwork:
    """Fig. 6.  Hit: with prob (1-q) promote (delink+head), with prob q nothing."""
    if not 0.0 <= q <= 1.0:
        raise ValueError("q must be in [0, 1]")
    mpl = _resolve_mpl(mpl, cores)
    s_delink, s_head = prob_lru_service(q)
    stations = _common_think(disk_us, disk_servers) + [
        Station("head", QUEUE, s_head, dist="pareto", dist_params=(0.45, 0.1, 2 * s_head - 0.1)),
        Station("delink", QUEUE, s_delink, dist="det"),
        Station("tail", QUEUE, s_head, bound="upper", dist="det"),
    ]
    branches = [
        Branch("hit_promote", lambda p: p * (1.0 - q), ("lookup", "delink", "head")),
        Branch("hit_skip", lambda p: p * q, ("lookup",)),
        Branch("miss", lambda p: 1.0 - p, ("lookup", "disk", "tail", "head")),
    ]
    return ClosedNetwork(
        f"prob_lru(q={q:g})", tuple(stations), tuple(branches), mpl,
        description="Probabilistic LRU: promotion only with prob 1-q.",
    )


# --------------------------------------------------------------------------
# CLOCK (FIFO-Reinsertion) — Sec. 4.3
# --------------------------------------------------------------------------


def clock_network(disk_us: float = 100.0, mpl: int = 72, cores: int | None = None,
                  disk_servers: int = 0) -> ClosedNetwork:
    """Fig. 9.  Hit: set bit (~0 cost).  Miss: disk + (scanning) tail + head."""
    mpl = _resolve_mpl(mpl, cores)
    stations = _common_think(disk_us, disk_servers) + [
        Station(
            "tail", QUEUE,
            lambda p: CLOCK_S_BASE + 0.3 * float(clock_g(p)),
            dist="det",
        ),
        Station("head", QUEUE, CLOCK_S_BASE, bound="upper", dist="det"),
    ]
    branches = [
        Branch("hit", lambda p: p, ("lookup",)),
        Branch("miss", lambda p: 1.0 - p, ("lookup", "disk", "tail", "head")),
    ]
    return ClosedNetwork(
        "clock", tuple(stations), tuple(branches), mpl,
        description="CLOCK: second-chance bit; hits only set a bit.",
    )


# --------------------------------------------------------------------------
# Segmented LRU — Sec. 4.4
# --------------------------------------------------------------------------


def slru_network(disk_us: float = 100.0, mpl: int = 72, cores: int | None = None,
                 disk_servers: int = 0) -> ClosedNetwork:
    """Fig. 11.  Probationary B list + protected T list.

    hit-in-T (prob l(p)):  delinkT + headT
    hit-in-B (prob p - l(p)):  delinkB + headT, T overflows -> tailT + headB
    miss (1-p):  disk + tailB + headB
    """
    mpl = _resolve_mpl(mpl, cores)
    stations = _common_think(disk_us, disk_servers) + [
        Station("delinkT", QUEUE, LRU_S_DELINK, dist="det"),
        Station("delinkB", QUEUE, LRU_S_DELINK, dist="det"),
        Station("headT", QUEUE, LRU_S_HEAD, dist="pareto", dist_params=(0.45, 0.1, 1.2)),
        Station("headB", QUEUE, LRU_S_HEAD, dist="pareto", dist_params=(0.45, 0.1, 1.2)),
        Station("tailT", QUEUE, LRU_S_HEAD, bound="upper", dist="det"),
        Station("tailB", QUEUE, LRU_S_HEAD, bound="upper", dist="det"),
    ]
    ell = lambda p: float(slru_ell(p))
    branches = [
        Branch("hit_T", ell, ("lookup", "delinkT", "headT")),
        Branch(
            "hit_B",
            lambda p: p - ell(p),
            ("lookup", "delinkB", "headT", "tailT", "headB"),
        ),
        Branch("miss", lambda p: 1.0 - p, ("lookup", "disk", "tailB", "headB")),
    ]
    return ClosedNetwork(
        "slru", tuple(stations), tuple(branches), mpl,
        description="Segmented LRU: two LRU lists (probationary + protected).",
    )


# --------------------------------------------------------------------------
# S3-FIFO — Sec. 4.5
# --------------------------------------------------------------------------


def s3fifo_network(
    disk_us: float = 100.0,
    mpl: int = 72,
    cores: int | None = None,
    disk_servers: int = 0,
    p_ghost_fn=None,
    p_m_fn=None,
) -> ClosedNetwork:
    """Fig. 13.  Small FIFO S + main FIFO M + ghost registry.

    hit (p): set bit only.
    miss routed to M (ghost hit, prob p_ghost):         headM + tailM
    miss routed to S, S-tail promoted (prob p_M):       headS + tailS + headM + tailM
    miss routed to S, S-tail evicted:                   headS + tailS

    The M-tail scans for a 0 bit like CLOCK; the paper writes its service
    time as the bare g(p_hit) (Sec. 4.5) — encoded as printed.
    """
    mpl = _resolve_mpl(mpl, cores)
    pg = p_ghost_fn or (lambda p: float(s3fifo_p_ghost(p)))
    pm = p_m_fn or (lambda p: float(s3fifo_p_m(p)))
    stations = _common_think(disk_us, disk_servers) + [
        Station("ghost", THINK, Z_CACHE_LOOKUP, dist="det"),
        Station("headS", QUEUE, CLOCK_S_BASE, dist="det"),
        Station("tailS", QUEUE, CLOCK_S_BASE, bound="upper", dist="det"),
        Station("headM", QUEUE, CLOCK_S_BASE, bound="upper", dist="det"),
        Station("tailM", QUEUE, lambda p: float(clock_g(p)), dist="det"),
    ]
    branches = [
        Branch("hit", lambda p: p, ("lookup",)),
        Branch(
            "miss_to_M",
            lambda p: (1.0 - p) * pg(p),
            ("lookup", "ghost", "disk", "headM", "tailM"),
        ),
        Branch(
            "miss_to_S_promote",
            lambda p: (1.0 - p) * (1.0 - pg(p)) * pm(p),
            ("lookup", "ghost", "disk", "headS", "tailS", "headM", "tailM"),
        ),
        Branch(
            "miss_to_S_evict",
            lambda p: (1.0 - p) * (1.0 - pg(p)) * (1.0 - pm(p)),
            ("lookup", "ghost", "disk", "headS", "tailS"),
        ),
    ]
    return ClosedNetwork(
        "s3fifo", tuple(stations), tuple(branches), mpl,
        description="S3-FIFO: small/main FIFO queues + ghost; hits set a bit.",
    )


# --------------------------------------------------------------------------
# Registry + paper closed forms (used by tests to pin the reproduction)
# --------------------------------------------------------------------------

POLICY_BUILDERS = {
    "lru": lru_network,
    "fifo": fifo_network,
    "prob_lru": prob_lru_network,
    "clock": clock_network,
    "slru": slru_network,
    "s3fifo": s3fifo_network,
}


def build(policy: str, disk_us: float = 100.0, mpl: int = 72,
          coalesce_flows: int = 0, coalesce_window_us=None,
          coalesce_sigma=None, coalesce_window_mode: str = "service",
          coalesce_flow_theta: float = 0.0, **kw) -> ClosedNetwork:
    """Build a policy network, optionally with miss coalescing applied.

    ``coalesce_flows > 0`` wraps the network in
    :func:`repro.core.queueing.coalesced_network`: concurrent misses on the
    same (hot) key share one backing-store fetch, so the disk sees the
    coalesced miss rate X·(1-p)·(1-σ).  ``coalesce_window_us`` overrides
    the in-flight window (default: the disk service time itself) and
    ``coalesce_sigma`` pins the coalescing factor (e.g. to a prong-C
    measured value) instead of solving it from the window.
    ``coalesce_window_mode="mva"`` extends the default window to the
    disk's MVA residence (service + estimated wait — what a bounded
    ``disk_servers`` fetch actually stays outstanding for), and
    ``coalesce_flow_theta`` skews the hot-key flow ensemble Zipf-style to
    match a trace's popularity skew.
    """
    net = POLICY_BUILDERS[policy](disk_us=disk_us, mpl=mpl, **kw)
    if coalesce_flows:
        net = coalesced_network(net, flows=coalesce_flows,
                                window_us=coalesce_window_us,
                                sigma=coalesce_sigma,
                                window_mode=coalesce_window_mode,
                                flow_theta=coalesce_flow_theta)
    return net


def paper_lru_bound(p, disk_us: float = 100.0, mpl: int = 72):
    """Paper Eq. (1)-(3), generalized over disk_us — closed form, for tests."""
    p = np.asarray(p, dtype=np.float64)
    denom1 = (Z_CACHE_LOOKUP + LRU_S_HEAD + disk_us) + (LRU_S_DELINK - disk_us) * p
    return np.minimum(mpl / denom1, 1.0 / np.maximum(LRU_S_HEAD, LRU_S_DELINK * p))


def paper_fifo_bound(p, disk_us: float = 100.0, mpl: int = 72):
    """Paper Eq. (4)-(6), generalized over disk_us."""
    p = np.asarray(p, dtype=np.float64)
    denom1 = (Z_CACHE_LOOKUP + FIFO_S_HEAD + disk_us) - (FIFO_S_HEAD + disk_us) * p
    return np.minimum(mpl / denom1, 1.0 / (FIFO_S_HEAD * (1.0 - p)))


def paper_prob_lru_bound(p, q: float, disk_us: float = 100.0, mpl: int = 72):
    """Paper Sec. 4.2 closed forms for q=0.5 / q=1-1/72 (any q via calibration)."""
    p = np.asarray(p, dtype=np.float64)
    s_delink, s_head = prob_lru_service(q)
    d_delink = (1.0 - q) * s_delink * p
    d_head = (1.0 - q * p) * s_head
    Z = Z_CACHE_LOOKUP + (1.0 - p) * disk_us
    return np.minimum(mpl / (Z + d_delink + d_head), 1.0 / np.maximum(d_delink, d_head))
