"""Prong C: virtual-time measurement of the *implemented* caches.

The paper's third prong measures a real cache implementation (HHVM-based)
under a closed loop of 72 client threads.  This container has one CPU core,
so wall-clock lock contention cannot be reproduced; instead we do the
honest equivalent:

  1. Drive the **actual cache implementation** (repro.cache.py_ref — the
     same semantics as the jittable versions, property-tested against them)
     with a Zipf(θ) workload at a given cache size.  This yields the *real*
     hit/miss sequence and the *real* per-request metadata-op counts — no
     Bernoulli assumption.
  2. Aggregate the observed (hit, op-vector) profiles into an *empirical*
     closed queueing network whose branch probabilities are the measured
     frequencies, and whose station service times are the paper's
     calibrated measurements.
  3. Evaluate that network with the validated event-driven simulator (and
     with the Thm-7.1 bound).

Step 1 also gives the cache-size → hit-ratio mapping (the paper sweeps
p_hit the same way — by varying cache size under a fixed Zipf workload).

This closes the loop the paper closes: if the Bernoulli-branch *model*
network and the measured-profile *implementation* network agree (<5%), the
queueing model is a faithful representation of the implementation.
"""

from __future__ import annotations

import dataclasses
from collections import Counter

import numpy as np

from repro.cache.py_ref import PY_POLICIES
from repro.core.queueing import (
    QUEUE,
    THINK,
    Branch,
    ClosedNetwork,
    Station,
    disk_station,
)


@dataclasses.dataclass(frozen=True)
class ServiceTimes:
    """Calibrated per-op service times (µs).  Defaults = paper's LRU numbers."""

    lookup: float = 0.51
    disk: float = 100.0
    delink: float = 0.70
    head: float = 0.59
    tail: float = 0.59
    scan: float = 0.30  # per extra tail-scan step (CLOCK 0.3·g decomposition)


# The paper's measured service times differ per policy family because queue
# lengths change the cross-core communication overhead (Sec. 3.1, 4.1).
PAPER_SERVICES = {
    "lru": ServiceTimes(),
    "fifo": ServiceTimes(head=0.73, tail=0.73),
    "prob_lru": ServiceTimes(delink=0.78, head=0.65, tail=0.65),
    "clock": ServiceTimes(head=0.65, tail=0.65),
    "slru": ServiceTimes(),
    "s3fifo": ServiceTimes(head=0.65, tail=0.65),
    "sieve": ServiceTimes(head=0.65, tail=0.65),
}


def zipf_trace(n: int, key_space: int, theta: float = 0.99, seed: int = 0) -> np.ndarray:
    """Zipfian key trace (θ=0.99 — paper Sec. 3.4 workload)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, key_space + 1, dtype=np.float64)
    probs = ranks ** (-theta)
    probs /= probs.sum()
    # shuffle key identities so key id != popularity rank
    perm = rng.permutation(key_space)
    return perm[rng.choice(key_space, size=n, p=probs)].astype(np.int64)


@dataclasses.dataclass(frozen=True)
class CacheMeasurement:
    policy: str
    capacity: int
    hit_ratio: float
    mean_ops_hit: np.ndarray  # mean (delink, head, tail, scan) on hits
    mean_ops_miss: np.ndarray  # ... on misses
    profiles: dict  # (hit, ops) -> frequency
    network: ClosedNetwork  # empirical-profile network

    def throughput_bound(self, p=None):
        return self.network.throughput_upper(self.hit_ratio if p is None else p)


def run_cache_trace(policy: str, capacity: int, trace: np.ndarray, seed: int = 0,
                    **policy_kwargs):
    """Replay a trace through the Python reference cache; returns (hits, ops)."""
    rng = np.random.default_rng(seed)
    us = rng.random(len(trace))
    cache = PY_POLICIES[policy](capacity, **policy_kwargs)
    hits = np.empty(len(trace), dtype=bool)
    ops = np.empty((len(trace), 4), dtype=np.int64)
    for i, (k, u) in enumerate(zip(trace, us)):
        a = cache.access(int(k), float(u))
        hits[i] = a.hit
        ops[i] = a.ops
    return hits, ops


def empirical_network(
    policy: str,
    hits: np.ndarray,
    ops: np.ndarray,
    service: ServiceTimes | None = None,
    mpl: int = 72,
    warmup_frac: float = 0.25,
    disk_servers: int = 0,
) -> tuple:
    """Build the measured-profile closed network from an execution trace.

    Scan steps are charged at a dedicated queue station (an approximation of
    the paper's folding of scan time into S_tail; documented in DESIGN.md).
    """
    service = service or PAPER_SERVICES.get(policy, ServiceTimes())
    w = int(len(hits) * warmup_frac)
    hits_m, ops_m = hits[w:], ops[w:]
    profiles = Counter(
        (bool(h), tuple(int(x) for x in o)) for h, o in zip(hits_m, ops_m)
    )
    total = sum(profiles.values())

    stations = [
        Station("lookup", THINK, service.lookup, dist="det"),
        disk_station(service.disk, disk_servers),
        Station("delink", QUEUE, service.delink, dist="det"),
        Station("head", QUEUE, service.head, dist="pareto",
                dist_params=(0.45, 0.1, max(2 * service.head - 0.1, 0.2))),
        Station("tail", QUEUE, service.tail, dist="det"),
        Station("scan", QUEUE, service.scan, dist="det"),
    ]
    branches = []
    for (hit, op_vec), count in sorted(profiles.items()):
        n_delink, n_head, n_tail, n_scan = op_vec
        visits = ["lookup"]
        if not hit:
            visits.append("disk")
        visits += (["delink"] * n_delink + ["head"] * n_head
                   + ["tail"] * n_tail + ["scan"] * n_scan)
        branches.append(
            Branch(
                f"{'hit' if hit else 'miss'}_{op_vec}",
                count / total,
                tuple(visits),
            )
        )
    net = ClosedNetwork(
        f"{policy}-empirical", tuple(stations), tuple(branches), mpl,
        description=f"measured-profile network for {policy}",
    )
    hit_ratio = float(hits_m.mean())
    mean_hit = ops_m[hits_m].mean(axis=0) if hits_m.any() else np.zeros(4)
    mean_miss = ops_m[~hits_m].mean(axis=0) if (~hits_m).any() else np.zeros(4)
    return CacheMeasurement(
        policy=policy, capacity=-1, hit_ratio=hit_ratio,
        mean_ops_hit=mean_hit, mean_ops_miss=mean_miss,
        profiles=dict(profiles), network=net,
    )


def parameterized_network(
    policy: str,
    hit_ops,
    miss_ops,
    service: ServiceTimes | None = None,
    mpl: int = 72,
    disk_servers: int = 0,
) -> ClosedNetwork:
    """Hit-ratio-parameterized network from measured op vectors.

    Unlike :func:`empirical_network` (pinned at the measured hit ratio),
    this sweeps p_hit with the *measured* hit/miss op profiles — what you
    need for p* of an implemented controller."""
    service = service or PAPER_SERVICES.get(policy, ServiceTimes())
    stations = [
        Station("lookup", THINK, service.lookup, dist="det"),
        disk_station(service.disk, disk_servers),
        Station("delink", QUEUE, service.delink, dist="det"),
        Station("head", QUEUE, service.head, dist="det"),
        Station("tail", QUEUE, service.tail, dist="det"),
        Station("scan", QUEUE, service.scan, dist="det"),
    ]

    def visits(ops, miss):
        v = ["lookup"] + (["disk"] if miss else [])
        d, h, t, s = (int(round(x)) for x in ops)
        return tuple(v + ["delink"] * d + ["head"] * h + ["tail"] * t
                     + ["scan"] * s)

    branches = [
        Branch("hit", lambda p: p, visits(hit_ops, False)),
        Branch("miss", lambda p: 1.0 - p, visits(miss_ops, True)),
    ]
    return ClosedNetwork(f"{policy}-measured", tuple(stations),
                         tuple(branches), mpl)


def measure_cache(
    policy: str,
    capacity: int,
    key_space: int = 4096,
    n_requests: int = 60_000,
    theta: float = 0.99,
    disk_us: float = 100.0,
    mpl: int = 72,
    seed: int = 0,
    disk_servers: int = 0,
    **policy_kwargs,
) -> CacheMeasurement:
    """End-to-end prong C measurement at one cache size."""
    trace = zipf_trace(n_requests, key_space, theta, seed)
    hits, ops = run_cache_trace(policy, capacity, trace, seed=seed, **policy_kwargs)
    service = dataclasses.replace(
        PAPER_SERVICES.get(policy, ServiceTimes()), disk=disk_us
    )
    meas = empirical_network(policy, hits, ops, service=service, mpl=mpl,
                             disk_servers=disk_servers)
    return dataclasses.replace(meas, capacity=capacity)


def sweep_cache_sizes(
    policy: str,
    sizes,
    key_space: int = 4096,
    n_requests: int = 60_000,
    theta: float = 0.99,
    disk_us: float = 100.0,
    mpl: int = 72,
    simulate: bool = False,
    sim_requests: int = 20_000,
    **policy_kwargs,
):
    """Hit-ratio/throughput curve vs cache size — the paper's x-axis sweep.

    Returns dict of np arrays: sizes, p_hit, x_bound, (x_sim if simulate).
    """
    from repro.core.simulator import simulate_network  # lazy: pulls in jax

    out = {"size": [], "p_hit": [], "x_bound": [], "x_sim": []}
    for c in sizes:
        meas = measure_cache(
            policy, int(c), key_space=key_space, n_requests=n_requests,
            theta=theta, disk_us=disk_us, mpl=mpl, **policy_kwargs,
        )
        out["size"].append(int(c))
        out["p_hit"].append(meas.hit_ratio)
        out["x_bound"].append(float(meas.throughput_bound()))
        if simulate:
            res = simulate_network(
                meas.network, [meas.hit_ratio], n_requests=sim_requests, seeds=(0,)
            )
            out["x_sim"].append(float(res.throughput[0]))
    return {k: np.asarray(v) for k, v in out.items() if v}
