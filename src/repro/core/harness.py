"""Prong C: virtual-time measurement of the *implemented* caches.

The paper's third prong measures a real cache implementation (HHVM-based)
under a closed loop of 72 client threads.  This container has one CPU core,
so wall-clock lock contention cannot be reproduced; instead we do the
honest equivalent:

  1. Drive an **actual cache implementation** with a Zipf(θ) workload at a
     given cache size.  This yields the *real* hit/miss sequence and the
     *real* per-request metadata-op counts — no Bernoulli assumption.
  2. Aggregate the observed (hit, op-vector) profiles into an *empirical*
     closed queueing network whose branch probabilities are the measured
     frequencies, and whose station service times are the paper's
     calibrated measurements.
  3. Evaluate that network with the validated event-driven simulator (and
     with the Thm-7.1 bound).

Step 1 has **two backends**, selected by ``backend=`` on
:func:`run_cache_trace` / :func:`sweep_cache_sizes`:

``"py"``
    The pure-Python references (:mod:`repro.cache.py_ref`), one request at
    a time.  Slow, but dead simple — this is the differential *oracle*.
``"jax"``
    The compiled trace-replay engine (:mod:`repro.cache.replay`): the
    jittable policies under ``lax.scan``, ``vmap``-ed over a
    (capacity x seed) grid so a whole cache-size sweep dispatches as one
    compiled program; for LRU the sweep further collapses into a single
    Mattson stack-distance pass covering every capacity at once.
    Bit-identical to the oracle (tests/test_replay.py) and ~10-80x faster.

Both backends draw the admission coins from an RNG substream independent
of the trace draws (``np.random.SeedSequence(seed).spawn(2)``), so
Prob-LRU / S3-FIFO coin flips never correlate with the key sequence.

Step 1 also gives the cache-size → hit-ratio mapping (the paper sweeps
p_hit the same way — by varying cache size under a fixed Zipf workload).

This closes the loop the paper closes: if the Bernoulli-branch *model*
network and the measured-profile *implementation* network agree (<5%), the
queueing model is a faithful representation of the implementation.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.cache.py_ref import PY_POLICIES
from repro.core.queueing import (
    QUEUE,
    THINK,
    Branch,
    ClosedNetwork,
    Station,
    disk_station,
)


@dataclasses.dataclass(frozen=True)
class ServiceTimes:
    """Calibrated per-op service times (µs).  Defaults = paper's LRU numbers."""

    lookup: float = 0.51
    disk: float = 100.0
    delink: float = 0.70
    head: float = 0.59
    tail: float = 0.59
    scan: float = 0.30  # per extra tail-scan step (CLOCK 0.3·g decomposition)


# The paper's measured service times differ per policy family because queue
# lengths change the cross-core communication overhead (Sec. 3.1, 4.1).
PAPER_SERVICES = {
    "lru": ServiceTimes(),
    "fifo": ServiceTimes(head=0.73, tail=0.73),
    "prob_lru": ServiceTimes(delink=0.78, head=0.65, tail=0.65),
    "clock": ServiceTimes(head=0.65, tail=0.65),
    "slru": ServiceTimes(),
    "s3fifo": ServiceTimes(head=0.65, tail=0.65),
    "sieve": ServiceTimes(head=0.65, tail=0.65),
}


def _seed_streams(seed: int):
    """Independent substreams for (key trace, admission coins).

    Constructing ``default_rng(seed)`` in both :func:`zipf_trace` and the
    coin draw made the Prob-LRU/S3-FIFO admission samples share a stream
    with the trace's permutation/choice draws — the coins were a
    deterministic function of the key sequence.  Spawning from one
    ``SeedSequence`` keeps the pairing reproducible but independent.
    """
    return np.random.SeedSequence(seed).spawn(2)


def zipf_trace(n: int, key_space: int, theta: float = 0.99, seed: int = 0) -> np.ndarray:
    """Zipfian key trace (θ=0.99 — paper Sec. 3.4 workload)."""
    rng = np.random.default_rng(_seed_streams(seed)[0])
    ranks = np.arange(1, key_space + 1, dtype=np.float64)
    probs = ranks ** (-theta)
    probs /= probs.sum()
    # shuffle key identities so key id != popularity rank
    perm = rng.permutation(key_space)
    return perm[rng.choice(key_space, size=n, p=probs)].astype(np.int64)


def coin_stream(n: int, seed: int = 0) -> np.ndarray:
    """Admission-coin samples u ~ U[0,1), independent of zipf_trace(seed).

    float32 so the py and jax backends compare the *same* values against
    q thresholds — identical hit sequences bit for bit.
    """
    rng = np.random.default_rng(_seed_streams(seed)[1])
    return rng.random(n, dtype=np.float32)


def miss_window_stream(n: int, mean_requests: float, seed: int = 0,
                       dist: str = "exp") -> np.ndarray:
    """Per-request in-flight windows (miss latencies in requests) drawn
    from the disk service distribution: ``dist="exp"`` samples
    Exp(mean_requests) rounded to whole requests, ``"det"`` pins every
    window at the mean (equivalent to the scalar ``miss_latency_requests``
    path).  Third ``SeedSequence(seed)`` substream, so the draws are
    independent of both the trace and the admission coins while staying
    reproducible alongside them.
    """
    rng = np.random.default_rng(np.random.SeedSequence(seed).spawn(3)[2])
    if dist == "det":
        return np.full(n, int(round(mean_requests)), dtype=np.int64)
    if dist != "exp":
        raise ValueError(f"unknown window dist {dist!r} (want 'exp' or 'det')")
    return np.round(rng.exponential(mean_requests, n)).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class CacheMeasurement:
    policy: str
    capacity: int
    hit_ratio: float
    mean_ops_hit: np.ndarray  # mean (delink, head, tail, scan) on hits
    mean_ops_miss: np.ndarray  # ... on misses
    profiles: dict  # (hit, ops) -> frequency
    network: ClosedNetwork  # empirical-profile network
    # delayed-hit classification under an in-flight window of
    # ``miss_latency_requests`` requests (0 = classification disabled;
    # the mean window when per-request windows were used):
    # post-warmup fractions of (true miss, true hit, delayed hit).
    miss_latency_requests: int = 0
    class_fracs: np.ndarray | None = None

    def throughput_bound(self, p=None):
        return self.network.throughput_upper(self.hit_ratio if p is None else p)

    @property
    def coalesce_sigma(self) -> float:
        """Measured coalescing factor: of the requests that needed a fill
        (delayed + true miss), the fraction that found one in flight."""
        if self.class_fracs is None:
            return 0.0
        miss, _, delayed = (float(x) for x in self.class_fracs)
        return delayed / (delayed + miss) if (delayed + miss) > 0 else 0.0

    @property
    def true_hit_ratio(self) -> float:
        """Hit ratio with delayed hits reclassified out of the hit count."""
        if self.class_fracs is None:
            return self.hit_ratio
        return float(self.class_fracs[1])

    def coalesced_throughput_bound(self, p=None):
        """Thm-7.1 bound of the measured-profile network with the measured
        coalescing factor applied (delayed hits skip the disk and the fill
        metadata).  Falls back to the plain bound when classification is
        off or found no coalescing."""
        sig = self.coalesce_sigma
        if sig <= 0.0:
            return self.throughput_bound(p)
        from repro.core.queueing import coalesced_network

        net = coalesced_network(self.network, sigma=sig)
        return net.throughput_upper(self.hit_ratio if p is None else p)


def run_cache_trace(policy: str, capacity: int, trace: np.ndarray, seed: int = 0,
                    backend: str = "py", key_space: int | None = None,
                    pad_to: int | None = None, **policy_kwargs):
    """Replay a trace through a cache implementation; returns (hits, ops).

    The two backends are contractually interchangeable:

    ``backend="py"``
        walks the Python reference (:mod:`repro.cache.py_ref`) one request
        at a time.  Slow and dead simple — this is the differential
        *oracle*, and the only backend that never imports jax.
    ``backend="jax"``
        dispatches the compiled ``lax.scan`` engine
        (:mod:`repro.cache.replay`).  ``key_space`` bounds the key-indexed
        arrays (inferred from the trace when omitted) and ``pad_to`` sizes
        the slot arrays so different capacities share a compiled program.
    ``backend="pallas"``
        dispatches the flat-state accelerator engine
        (:mod:`repro.kernels.replay`): the replay runs as a pallas kernel
        with the cache state in scratch memory (its compiled scan twin on
        CPU), same ``key_space``/``pad_to`` knobs.

    All backends consume the same float32 coin substream (admission
    randomness independent of the trace stream) and must return
    bit-identical (hits, ops) arrays — ``tests/test_replay.py`` and
    ``tests/test_pallas_replay.py`` pin that contract element-wise for
    every policy, which is what keeps py_ref usable as the differential
    oracle for any new replay feature.
    """
    us = coin_stream(len(trace), seed)
    if backend == "jax":
        from repro.cache.replay import replay_trace  # lazy: pulls in jax

        res = replay_trace(policy, trace, us, int(capacity),
                           key_space=key_space, pad_to=pad_to,
                           **policy_kwargs)
        return np.asarray(res.hits), res.ops
    if backend == "pallas":
        from repro.kernels.replay import replay_grid_pallas, unpack_grid_ops

        pres = replay_grid_pallas(policy, trace, us, [int(capacity)],
                                  key_space=key_space, pad_to=pad_to,
                                  **policy_kwargs)
        return np.asarray(pres.hits)[0, 0], unpack_grid_ops(pres)[0, 0]
    if backend != "py":
        raise ValueError(f"unknown backend {backend!r} "
                         "(want 'py', 'jax' or 'pallas')")
    cache = PY_POLICIES[policy](capacity, **policy_kwargs)
    hits = np.empty(len(trace), dtype=bool)
    ops = np.empty((len(trace), 4), dtype=np.int64)
    for i, (k, u) in enumerate(zip(trace, us)):
        a = cache.access(int(k), float(u))
        hits[i] = a.hit
        ops[i] = a.ops
    return hits, ops


def empirical_network(
    policy: str,
    hits: np.ndarray,
    ops: np.ndarray,
    service: ServiceTimes | None = None,
    mpl: int = 72,
    warmup_frac: float = 0.25,
    disk_servers: int = 0,
) -> tuple:
    """Build the measured-profile closed network from an execution trace.

    Scan steps are charged at a dedicated queue station (an approximation of
    the paper's folding of scan time into S_tail; documented in DESIGN.md).
    """
    service = service or PAPER_SERVICES.get(policy, ServiceTimes())
    w = int(len(hits) * warmup_frac)
    hits_m, ops_m = hits[w:], ops[w:]
    # vectorized profile histogram: each (hit, op-vector) row packs into one
    # int64 (12 bits per op count), so the unique+count is a scalar sort —
    # a per-request Python Counter (and even np.unique over rows, which
    # sorts void views) dominated sweep time at 60k requests.
    ops64 = np.asarray(ops_m, np.int64)
    if ops64.size and ops64.max() > 0xFFF:
        raise ValueError("op count exceeds 12-bit profile packing")
    code = (
        (np.asarray(hits_m, np.int64) << 48)
        | (ops64[:, 0] << 36) | (ops64[:, 1] << 24)
        | (ops64[:, 2] << 12) | ops64[:, 3]
    )
    uniq, counts = np.unique(code, return_counts=True)
    profiles = {
        (bool(c >> 48), (int((c >> 36) & 0xFFF), int((c >> 24) & 0xFFF),
                         int((c >> 12) & 0xFFF), int(c & 0xFFF))): int(n)
        for c, n in zip(uniq, counts)
    }
    total = int(counts.sum())

    stations = [
        Station("lookup", THINK, service.lookup, dist="det"),
        disk_station(service.disk, disk_servers),
        Station("delink", QUEUE, service.delink, dist="det"),
        Station("head", QUEUE, service.head, dist="pareto",
                dist_params=(0.45, 0.1, max(2 * service.head - 0.1, 0.2))),
        Station("tail", QUEUE, service.tail, dist="det"),
        Station("scan", QUEUE, service.scan, dist="det"),
    ]
    branches = []
    for (hit, op_vec), count in sorted(profiles.items()):
        n_delink, n_head, n_tail, n_scan = op_vec
        visits = ["lookup"]
        if not hit:
            visits.append("disk")
        visits += (["delink"] * n_delink + ["head"] * n_head
                   + ["tail"] * n_tail + ["scan"] * n_scan)
        branches.append(
            Branch(
                f"{'hit' if hit else 'miss'}_{op_vec}",
                count / total,
                tuple(visits),
            )
        )
    net = ClosedNetwork(
        f"{policy}-empirical", tuple(stations), tuple(branches), mpl,
        description=f"measured-profile network for {policy}",
    )

    # hit ratio and per-class mean op vectors straight from the histogram
    # (equivalent to masking the raw arrays, without the large copies)
    def mean_ops(want_hit: bool) -> np.ndarray:
        count = sum(c for (h, _), c in profiles.items() if h == want_hit)
        if not count:
            return np.zeros(4)
        acc = np.zeros(4)
        for (h, vec), c in profiles.items():
            if h == want_hit:
                acc += np.asarray(vec, np.float64) * c
        return acc / count

    n_hits = sum(c for (h, _), c in profiles.items() if h)
    hit_ratio = n_hits / total if total else 0.0
    mean_hit = mean_ops(True)
    mean_miss = mean_ops(False)
    return CacheMeasurement(
        policy=policy, capacity=-1, hit_ratio=hit_ratio,
        mean_ops_hit=mean_hit, mean_ops_miss=mean_miss,
        profiles=dict(profiles), network=net,
    )


def parameterized_network(
    policy: str,
    hit_ops,
    miss_ops,
    service: ServiceTimes | None = None,
    mpl: int = 72,
    disk_servers: int = 0,
) -> ClosedNetwork:
    """Hit-ratio-parameterized network from measured op vectors.

    Unlike :func:`empirical_network` (pinned at the measured hit ratio),
    this sweeps p_hit with the *measured* hit/miss op profiles — what you
    need for p* of an implemented controller."""
    service = service or PAPER_SERVICES.get(policy, ServiceTimes())
    stations = [
        Station("lookup", THINK, service.lookup, dist="det"),
        disk_station(service.disk, disk_servers),
        Station("delink", QUEUE, service.delink, dist="det"),
        Station("head", QUEUE, service.head, dist="det"),
        Station("tail", QUEUE, service.tail, dist="det"),
        Station("scan", QUEUE, service.scan, dist="det"),
    ]

    def visits(ops, miss):
        v = ["lookup"] + (["disk"] if miss else [])
        d, h, t, s = (int(round(x)) for x in ops)
        return tuple(v + ["delink"] * d + ["head"] * h + ["tail"] * t
                     + ["scan"] * s)

    branches = [
        Branch("hit", lambda p: p, visits(hit_ops, False)),
        Branch("miss", lambda p: 1.0 - p, visits(miss_ops, True)),
    ]
    return ClosedNetwork(f"{policy}-measured", tuple(stations),
                         tuple(branches), mpl)


def _class_fracs(cls, warmup_frac: float = 0.25) -> np.ndarray:
    """(true miss, true hit, delayed hit) fractions after warmup, from an
    int8 class stream — host- or device-resident (e.g. the fused ``cls``
    output of :func:`repro.kernels.replay.replay_grid_pallas`)."""
    w = int(cls.shape[-1] * warmup_frac)
    cls_m = np.asarray(cls)[..., w:]
    return np.stack(
        [(cls_m == c).mean(axis=-1) for c in range(3)], axis=-1
    )


def _classify(trace, hits, window, key_space: int, backend: str,
              warmup_frac: float = 0.25, fail_prob: float = 0.0,
              fail_seed: int = 0) -> np.ndarray:
    """Post-warmup (true miss, true hit, delayed hit) fractions.

    ``window`` is a scalar or a (T,) per-request array — passed straight
    to the classifiers, which share the fetch-expiry semantics (including
    the ``fail_prob`` TTL re-issue stretch)."""
    if backend in ("jax", "pallas"):
        from repro.cache.replay import classify_inflight  # lazy: pulls in jax

        cls = classify_inflight(trace, hits, window, key_space=key_space,
                                fail_prob=fail_prob, fail_seed=fail_seed)
    else:
        from repro.cache.py_ref import classify_inflight_py

        cls = classify_inflight_py(trace, hits, window, fail_prob=fail_prob,
                                   fail_seed=fail_seed)
    return _class_fracs(cls, warmup_frac)


def measure_cache(
    policy: str,
    capacity: int,
    key_space: int = 4096,
    n_requests: int = 60_000,
    theta: float = 0.99,
    disk_us: float = 100.0,
    mpl: int = 72,
    seed: int = 0,
    disk_servers: int = 0,
    backend: str = "py",
    miss_latency_requests: int = 0,
    fetch_fail_prob: float = 0.0,
    **policy_kwargs,
) -> CacheMeasurement:
    """End-to-end prong C measurement at one cache size.

    ``miss_latency_requests > 0`` additionally classifies every request
    against an in-flight-miss window of that many requests (see
    :func:`repro.cache.replay.classify_inflight`): the resulting
    ``class_fracs`` / ``coalesce_sigma`` on the returned measurement feed
    the delayed-hits variants of the model (prong A) and simulator
    (prong B).  A ``(n_requests,)`` array gives every request its own
    window (per-request miss latencies, e.g. from
    :func:`miss_window_stream`); the stored ``miss_latency_requests``
    then records the mean.  With 0 the measurement is bit-identical to
    the non-coalesced path.

    ``fetch_fail_prob`` models TTL-style fetch failure: each true miss's
    fetch re-issues on failure, stretching its window by a geometric
    attempt count (see :func:`repro.cache.replay.refetch_attempts`);
    0 keeps the classification unchanged.

    ``backend`` is ``"py"`` (the oracle loop), ``"jax"`` (the compiled
    scan engine) or ``"pallas"`` (the flat-state accelerator engine,
    :mod:`repro.kernels.replay` — replay *and* classification fuse into
    a single dispatch); all three return identical measurements.
    """
    trace = zipf_trace(n_requests, key_space, theta, seed)
    classify = bool(np.any(miss_latency_requests))
    fracs_fused = None
    if backend == "pallas":
        # replay + classification fused in ONE dispatch (the scan/py
        # backends replay first, then run the classifier as a post-pass)
        from repro.kernels.replay import replay_grid_pallas, unpack_grid_ops

        pres = replay_grid_pallas(
            policy, trace, coin_stream(n_requests, seed), [capacity],
            key_space=key_space,
            window=miss_latency_requests if classify else None,
            fail_prob=fetch_fail_prob, fail_seed=seed, **policy_kwargs)
        hits = np.asarray(pres.hits)[0, 0]
        ops = unpack_grid_ops(pres)[0, 0]
        if pres.cls is not None:
            fracs_fused = _class_fracs(pres.cls[0, 0])
    else:
        hits, ops = run_cache_trace(policy, capacity, trace, seed=seed,
                                    backend=backend, key_space=key_space,
                                    **policy_kwargs)
    service = dataclasses.replace(
        PAPER_SERVICES.get(policy, ServiceTimes()), disk=disk_us
    )
    meas = empirical_network(policy, hits, ops, service=service, mpl=mpl,
                             disk_servers=disk_servers)
    meas = dataclasses.replace(meas, capacity=capacity)
    if classify:
        fracs = fracs_fused if fracs_fused is not None else _classify(
            trace, hits, miss_latency_requests, key_space, backend,
            fail_prob=fetch_fail_prob, fail_seed=seed)
        meas = dataclasses.replace(
            meas,
            miss_latency_requests=int(round(float(
                np.mean(miss_latency_requests)))),
            class_fracs=fracs,
        )
    return meas


def sweep_cache_sizes(
    policy: str,
    sizes,
    key_space: int = 4096,
    n_requests: int = 60_000,
    theta: float = 0.99,
    disk_us: float = 100.0,
    mpl: int = 72,
    simulate: bool = False,
    sim_requests: int = 20_000,
    seed: int = 0,
    disk_servers: int = 0,
    backend: str = "jax",
    miss_latency_requests: int = 0,
    fetch_fail_prob: float = 0.0,
    **policy_kwargs,
):
    """Hit-ratio/throughput curve vs cache size — the paper's x-axis sweep.

    ``backend="jax"`` (default) replays every size in one compiled
    dispatch: a single Mattson stack-distance pass for LRU, the vmapped
    (capacity x seed) scan grid for everything else.  ``backend="py"``
    keeps the oracle loop (~10-80x slower, zero jax imports).
    ``backend="pallas"`` runs the flat-state accelerator engine
    (:mod:`repro.kernels.replay`) — every size is a grid lane of ONE
    kernel dispatch with the delayed-hit classification fused into the
    same pass when the sizes share a window stream (per-size scalar
    windows that differ fall back to the device classifier per size).
    All backends consume identical trace/coin streams and return
    identical arrays, so any can cross-check another.

    ``miss_latency_requests`` — a scalar, one window per size (in a
    closed system the window ~= X·L *depends on the operating point*, so
    per-size windows let one sweep carry its own calibration), or one
    window per *request* (an ``(n_requests,)`` array, e.g. from
    :func:`miss_window_stream`, applied to every size) — turns on
    delayed-hit classification and adds per-size columns: ``p_true_hit``,
    ``p_delayed``, ``sigma`` (measured coalescing factor) and
    ``x_bound_coalesced`` (the bound with delayed hits skipping the disk
    and fill metadata).  ``fetch_fail_prob`` stretches each fetch's
    window by its geometric re-issue attempts (TTL failure model).

    Returns dict of np arrays: size, p_hit, x_bound, (x_sim if simulate,
    delayed-hit columns if enabled).
    """
    from repro.core.simulator import simulate_network  # lazy: pulls in jax

    if backend not in ("py", "jax", "pallas"):
        raise ValueError(f"unknown backend {backend!r} "
                         "(want 'py', 'jax' or 'pallas')")
    sizes = [int(c) for c in sizes]
    mlr = np.asarray(miss_latency_requests)
    if mlr.ndim == 1 and mlr.size == n_requests:
        if mlr.size == len(sizes):
            raise ValueError(
                f"ambiguous miss_latency_requests: length {mlr.size} matches "
                "both len(sizes) (per-size windows) and n_requests "
                "(per-request windows) — change one of them")
        windows = [mlr] * len(sizes)  # per-request windows, every size
    else:
        windows = list(np.broadcast_to(mlr, len(sizes)).astype(int))
    classify = any(np.any(w) for w in windows)
    out: dict = {"size": [], "p_hit": [], "x_bound": [], "x_sim": [],
                 "p_true_hit": [], "p_delayed": [], "sigma": [],
                 "x_bound_coalesced": []}

    def _measurements():
        if backend == "py":
            for c, w in zip(sizes, windows):
                yield measure_cache(
                    policy, c, key_space=key_space, n_requests=n_requests,
                    theta=theta, disk_us=disk_us, mpl=mpl, seed=seed,
                    disk_servers=disk_servers,
                    miss_latency_requests=w,
                    fetch_fail_prob=fetch_fail_prob,
                    **policy_kwargs,
                )
            return
        trace = zipf_trace(n_requests, key_space, theta, seed)
        cls_g = hits_dev = None
        if backend == "pallas":
            from repro.kernels.replay import (replay_grid_pallas,
                                              unpack_grid_ops)

            # all sizes + (when the windows agree) the classification in
            # ONE kernel dispatch — the fused prong-C pipeline
            same_w = all(np.array_equal(w, windows[0]) for w in windows[1:])
            pres = replay_grid_pallas(
                policy, trace, coin_stream(n_requests, seed), sizes,
                key_space=key_space,
                window=windows[0] if (classify and same_w) else None,
                fail_prob=fetch_fail_prob, fail_seed=seed, **policy_kwargs)
            hits_dev = pres.hits[:, 0]  # device-resident, for the classifier
            hits_g = np.asarray(hits_dev)
            ops_g = unpack_grid_ops(pres)[:, 0]
            if pres.cls is not None:
                cls_g = pres.cls[:, 0]
        elif policy == "lru":
            from repro.cache.replay import lru_sweep

            hits_g, ops_g = lru_sweep(trace, sizes)
        else:
            from repro.cache.replay import replay_grid  # lazy: pulls in jax

            res = replay_grid(policy, trace, coin_stream(n_requests, seed),
                              sizes, key_space=key_space, **policy_kwargs)
            hits_g, ops_g = res.hits[:, 0], res.ops[:, 0]
        service = dataclasses.replace(
            PAPER_SERVICES.get(policy, ServiceTimes()), disk=disk_us
        )
        for i, (c, w) in enumerate(zip(sizes, windows)):
            meas = empirical_network(policy, hits_g[i], ops_g[i],
                                     service=service, mpl=mpl,
                                     disk_servers=disk_servers)
            meas = dataclasses.replace(meas, capacity=c)
            if np.any(w):
                if cls_g is not None:
                    fracs = _class_fracs(cls_g[i])
                else:
                    h_i = (hits_dev[i] if hits_dev is not None
                           else np.asarray(hits_g[i]))
                    fracs = _classify(trace, h_i, w, key_space, backend,
                                      fail_prob=fetch_fail_prob,
                                      fail_seed=seed)
                meas = dataclasses.replace(
                    meas,
                    miss_latency_requests=int(round(float(np.mean(w)))),
                    class_fracs=fracs,
                )
            yield meas

    for meas in _measurements():
        out["size"].append(meas.capacity)
        out["p_hit"].append(meas.hit_ratio)
        out["x_bound"].append(float(meas.throughput_bound()))
        if classify:
            out["p_true_hit"].append(meas.true_hit_ratio)
            out["p_delayed"].append(
                float(meas.class_fracs[2])
                if meas.class_fracs is not None else 0.0
            )
            out["sigma"].append(meas.coalesce_sigma)
            out["x_bound_coalesced"].append(
                float(meas.coalesced_throughput_bound())
            )
        if simulate:
            res = simulate_network(
                meas.network, [meas.hit_ratio], n_requests=sim_requests, seeds=(0,)
            )
            out["x_sim"].append(float(res.throughput[0]))
    return {k: np.asarray(v) for k, v in out.items() if v}
