"""Network -> simulator-spec compilation, shared by both event engines.

:func:`compile_network` freezes a :class:`repro.core.queueing.ClosedNetwork`
at one hit ratio into flat arrays (:class:`SimSpec`) that an event loop can
index with traced station ids; :func:`stack_specs` stacks a grid of them
for vmap.  The layer lives below the engines so that both the threefry
scan simulator (:mod:`repro.core.simulator`) and the pallas kernel engine
(:mod:`repro.kernels.event_sim`) can import it without the kernels package
and the core package importing each other.

:class:`SimResult` is the closed-loop summary both engines return.

:class:`MshrSpec` is the cross-tier MSHR annotation table for tiered
(hierarchy) networks: per-(branch, visit-position) acquire/release marks
that generalize the single ``disk_rank`` convention to a DAG of caches —
a request can hold an outstanding-fetch entry at its L1 client table
*and* at a shard-local origin table at once (see
:mod:`repro.hierarchy.model`, which builds these tables).
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.queueing import QUEUE, ClosedNetwork

# Sentinels: "idle / not ready" times and "not enqueued" sequence numbers.
# int32 max keeps every traced comparison in 32-bit (jit-hash64 lint).
INF_NS = np.int32(2**31 - 1)
BIG_SEQ = np.int32(2**31 - 1)

_DIST_IDS = {"det": 0, "exp": 1, "pareto": 2}


class SimSpec(NamedTuple):
    """A closed network compiled to arrays at one (or a grid of) p_hit."""

    is_queue: jax.Array  # (K,) bool
    svc_ns: jax.Array  # (K,) f32 mean service in ns
    dist_id: jax.Array  # (K,) i32
    dist_params: jax.Array  # (K, 4) f32: alpha, lo, hi, raw_mean (pareto)
    branch_cum: jax.Array  # (B,) f32 cumulative branch probabilities
    visits: jax.Array  # (B, L) i32 station indices, -1 padded
    servers: jax.Array  # (K,) i32 FCFS server count (1 for think stations)
    disk_rank: jax.Array  # (K,) i32 backing-store group id, -1 for non-disks
    mpl: int


def _bounded_pareto_mean(alpha: float, lo: float, hi: float) -> float:
    if abs(alpha - 1.0) < 1e-9:
        return lo * hi / (hi - lo) * math.log(hi / lo)
    num = lo**alpha * alpha * (lo ** (1 - alpha) - hi ** (1 - alpha))
    den = (alpha - 1.0) * (1.0 - (lo / hi) ** alpha)
    return num / den


def compile_network(net: ClosedNetwork, p_hit: float) -> SimSpec:
    """Freeze a network at a given hit ratio into simulator arrays."""
    names = [s.name for s in net.stations]
    idx = {n: i for i, n in enumerate(names)}
    K = len(names)
    is_queue = np.array([s.kind == QUEUE for s in net.stations], dtype=bool)
    svc_ns = np.array(
        [s.mean_service(p_hit) * 1e3 for s in net.stations], dtype=np.float32
    )
    dist_id = np.array([_DIST_IDS[s.dist] for s in net.stations], dtype=np.int32)
    dist_params = np.zeros((K, 4), dtype=np.float32)
    for i, s in enumerate(net.stations):
        if s.dist == "pareto":
            alpha, lo, hi = s.dist_params
            dist_params[i] = (alpha, lo, hi, _bounded_pareto_mean(alpha, lo, hi))
        else:
            dist_params[i] = (1.0, 1.0, 1.0, 1.0)

    probs = np.array([b.probability(p_hit) for b in net.branches], dtype=np.float64)
    if not math.isclose(probs.sum(), 1.0, abs_tol=1e-5):
        raise ValueError(f"branch probs sum to {probs.sum()} at p={p_hit}")
    probs = np.maximum(probs, 0.0)
    branch_cum = np.cumsum(probs / probs.sum()).astype(np.float32)

    L = max(len(b.visits) for b in net.branches)
    if min(len(b.visits) for b in net.branches) == 0:
        raise ValueError("empty branch routes are not supported")
    visits = np.full((len(net.branches), L), -1, dtype=np.int32)
    for bi, b in enumerate(net.branches):
        for vi, v in enumerate(b.visits):
            visits[bi, vi] = idx[v]
    if is_queue[visits[:, 0]].any():
        # init places all mpl jobs straight into service at their first
        # station; a queue-first route would bypass the busy accounting.
        raise ValueError("branch routes must start at a think station")

    servers = np.array(
        [s.servers if s.kind == QUEUE else 1 for s in net.stations],
        dtype=np.int32,
    )

    # A station is a backing store if it is named "disk" — either the bare
    # single-node disk or a per-shard replica ("s3:disk", the cluster
    # composition's naming).  Each disk gets its own MSHR flow group, so
    # miss coalescing is local to the shard whose disk serves the fetch.
    disk_rank = np.full(K, -1, dtype=np.int32)
    rank = 0
    for i, name in enumerate(names):
        if name.split(":")[-1] == "disk":
            disk_rank[i] = rank
            rank += 1

    return SimSpec(
        is_queue=jnp.asarray(is_queue),
        svc_ns=jnp.asarray(svc_ns),
        dist_id=jnp.asarray(dist_id),
        dist_params=jnp.asarray(dist_params),
        branch_cum=jnp.asarray(branch_cum),
        visits=jnp.asarray(visits),
        servers=jnp.asarray(servers),
        disk_rank=jnp.asarray(disk_rank),
        mpl=net.mpl,
    )


class MshrSpec(NamedTuple):
    """Cross-tier MSHR annotations for one composed (tiered) network.

    All arrays are shaped like ``SimSpec.visits`` (B branches × L route
    positions, -1 meaning "nothing here") and are *hit-ratio independent*
    (branch probabilities change with p, routes do not):

    ``acq_group[b, i]``
        MSHR group acquired on ARRIVAL at visit ``(b, i)``.  With F flows
        per group, the fetch for flow ``f`` of group ``g`` lives at leader
        slot ``g*F + f``.  Groups 0..n_clients-1 are the per-client L1
        tables; the shard-local origin tables follow (PR 5 layout: the
        deeper tier's coalescing never crosses shards).
    ``acq_slot[b, i]``
        Which of the job's ``max_held`` held-entry registers the
        acquisition writes (0 = shallowest tier).
    ``rel_slot[b, i]``
        Held-entry register released on COMPLETION of visit ``(b, i)`` —
        the fill lands, every request parked on that slot completes as a
        delayed hit (cascading across tiers: a woken job releases *its*
        held entries too, waking its own followers).

    Semantics contract (both simulators): a job samples one flow per
    request at its first acquire point; arriving at an acquire position
    whose slot already has a leader, it parks — no queue position, no
    I/O-depth slot, no further route visits — and completes at fill time,
    skipping all fill metadata (the single-tier delayed-hit convention).
    """

    acq_group: np.ndarray  # (B, L) i32, -1 = no acquire at this visit
    acq_slot: np.ndarray  # (B, L) i32, -1 matching acq_group
    rel_slot: np.ndarray  # (B, L) i32, -1 = no release at this visit
    n_groups: int
    max_held: int

    def validate(self, visits: np.ndarray) -> None:
        """Structural checks against a compiled route table."""
        ag = np.asarray(self.acq_group)
        asl = np.asarray(self.acq_slot)
        rs = np.asarray(self.rel_slot)
        if ag.shape != visits.shape or asl.shape != visits.shape \
                or rs.shape != visits.shape:
            raise ValueError(
                f"MshrSpec arrays {ag.shape} do not match visits "
                f"{visits.shape}")
        if ((ag >= 0) != (asl >= 0)).any():
            raise ValueError("acq_group and acq_slot must mark the same "
                             "positions")
        if (ag >= self.n_groups).any() or (asl >= self.max_held).any() \
                or (rs >= self.max_held).any():
            raise ValueError("MshrSpec group/slot index out of range")
        if (ag[:, 0] >= 0).any():
            raise ValueError("a branch cannot acquire at its first visit "
                             "(requests start at a think station)")
        for b in range(ag.shape[0]):
            acquired = {int(s) for s in asl[b] if s >= 0}
            released = {int(s) for s in rs[b] if s >= 0}
            if acquired != released:
                raise ValueError(
                    f"branch {b}: acquired slots {sorted(acquired)} != "
                    f"released slots {sorted(released)} — a leaked leader "
                    f"entry would deadlock the closed loop")
            for s in acquired:
                a_pos = int(np.nonzero(asl[b] == s)[0][0])
                r_pos = int(np.nonzero(rs[b] == s)[0][0])
                if r_pos < a_pos:
                    raise ValueError(
                        f"branch {b}: slot {s} released at position "
                        f"{r_pos} before its acquire at {a_pos}")


def stack_specs(specs) -> SimSpec:
    """Stack per-p_hit specs along a leading axis for vmap."""
    mpl = specs[0].mpl
    assert all(s.mpl == mpl for s in specs)
    return SimSpec(
        *[jnp.stack([getattr(s, f) for s in specs]) for f in SimSpec._fields[:-1]],
        mpl=mpl,
    )


@dataclasses.dataclass(frozen=True)
class SimResult:
    p_hit: np.ndarray
    throughput: np.ndarray  # requests/µs == M req/s
    ci95: np.ndarray  # 95% CI half-width across seeds
    n_requests: int
    # fraction of measured completions that were delayed hits (coalesced
    # onto an in-flight fetch); zeros unless coalesce_flows > 0.
    delayed_frac: np.ndarray | None = None
    # per-branch completion rates (requests/µs), (P, B) in the order of
    # ``net.branches``; ``branch_delayed`` is the delayed-hit subset of the
    # same completions.  The cluster prong folds these into per-shard
    # throughput / hit-ratio / delayed-hit breakdowns.
    branch_throughput: np.ndarray | None = None
    branch_delayed: np.ndarray | None = None
    # tiered (MshrSpec) runs only: delayed-hit completions split by the
    # held-slot level the job parked at, (P, max_held) fractions of
    # measured completions — column 0 is the shallowest tier's table
    # (client-local L1 coalescing), later columns the deeper tables
    # (shard-local origin coalescing).  None for non-tiered runs.
    delayed_tier_frac: np.ndarray | None = None
    # decoded per-lane trace records ([seed][p] repro.obs.trace
    # TraceRecords); None unless the run requested in-kernel tracing
    # (simulate_network(trace=K) / simulate_grid_pallas(trace=K)).
    traces: list | None = None
    # decoded per-lane streaming estimators ([seed][p] repro.obs.streaming
    # SketchEstimates); None unless simulate_network(sketch_cap=K).
    sketches: list | None = None
