"""repro.core — the paper's contribution: closed queueing-network models of
cache eviction policies, analytic throughput bounds, exact MVA, event-driven
simulation, and the LRU-like/FIFO-like classification.

Three-pronged methodology (paper Sec. 1.3):
  A. theory      -> repro.core.queueing / repro.core.policy_models
  B. simulation  -> repro.core.simulator
  C. implementation -> repro.cache (+ virtual-time harness in repro.core.harness)
"""

from repro.core.queueing import (
    QUEUE,
    THINK,
    Branch,
    ClosedNetwork,
    Station,
    bypass_network,
    coalesced_network,
    exponential_analogue,
    optimal_bypass_beta,
    sigma_of,
    zipf_flow_weights,
)
from repro.core.policy_models import (
    POLICY_BUILDERS,
    build,
    clock_network,
    fifo_network,
    lru_network,
    paper_fifo_bound,
    paper_lru_bound,
    paper_prob_lru_bound,
    prob_lru_network,
    s3fifo_network,
    slru_network,
)
from repro.core.classify import (
    FIFO_LIKE,
    LRU_LIKE,
    TABLE1,
    TABLE2_CONJECTURE,
    classify_by_throughput,
    classify_structural,
)

__all__ = [
    "QUEUE", "THINK", "Branch", "ClosedNetwork", "Station",
    "bypass_network", "coalesced_network", "exponential_analogue",
    "optimal_bypass_beta", "sigma_of", "zipf_flow_weights",
    "POLICY_BUILDERS", "build",
    "lru_network", "fifo_network", "prob_lru_network", "clock_network",
    "slru_network", "s3fifo_network",
    "paper_lru_bound", "paper_fifo_bound", "paper_prob_lru_bound",
    "LRU_LIKE", "FIFO_LIKE", "TABLE1", "TABLE2_CONJECTURE",
    "classify_structural", "classify_by_throughput",
]
