"""Pure-Python reference simulator (heapq event loop).

Oracle for the JAX simulator in :mod:`repro.core.simulator` — same network
semantics, independent implementation.  Used by tests and for debugging;
~100x slower than the jitted simulator, so keep ``n_requests`` modest.

Supports the same miss-coalescing (delayed hits) semantics as the JAX
simulator: with ``coalesce_flows > 0`` a job arriving at the ``disk``
station samples a flow (hot key); if a fetch for that flow is already in
flight it parks on an outstanding-miss table — no duplicate disk I/O, no
bounded-``disk_servers`` slot — and completes when the fill lands.
"""

from __future__ import annotations

import heapq
import random

import numpy as np

from repro.core.queueing import ClosedNetwork
from repro.core.simulator import compile_network


def simulate_py(
    net: ClosedNetwork,
    p_hit: float,
    n_requests: int = 20_000,
    seed: int = 0,
    warmup_frac: float = 0.25,
    coalesce_flows: int = 0,
    full: bool = False,
):
    """Simulate and return throughput in requests/µs.

    Service distributions: det and exp are honored; bounded-Pareto stations
    are sampled at their mean (det) — the paper (and our tests) show the
    throughput is insensitive to this.

    With ``full=True`` returns a dict with ``x`` (throughput),
    ``delayed_frac`` (fraction of measured completions that were delayed
    hits) and ``delayed`` (their count); the bare float return stays the
    default for backward compatibility.
    """
    rng = random.Random(seed)
    spec = compile_network(net, p_hit)
    is_q = np.asarray(spec.is_queue)
    svc = np.asarray(spec.svc_ns) / 1e3  # µs
    dist = np.asarray(spec.dist_id)
    cum = np.asarray(spec.branch_cum)
    visits = np.asarray(spec.visits)
    servers = np.asarray(spec.servers)
    disk_idx = int(spec.disk_idx)
    K = len(is_q)
    N = net.mpl
    if coalesce_flows and disk_idx < 0:
        raise ValueError(f"{net.name} has no 'disk' station to coalesce on")

    def sample(k: int) -> float:
        if dist[k] == 1:
            return svc[k] * rng.expovariate(1.0)
        return float(svc[k])

    def new_branch() -> int:
        return int(np.searchsorted(cum, rng.random()))

    heap: list = []
    queues = {k: [] for k in range(K) if is_q[k]}
    # busy count per queue station: jobs in service, <= servers[k] (matches
    # the JAX simulator's busy-count semantics; c-server FCFS).
    busy = {k: 0 for k in range(K) if is_q[k]}
    # outstanding-miss table: flow -> leader job; parked jobs ride along.
    leader: dict = {}
    parked: dict = {}  # flow -> [job ids]
    job_flow = [-1] * N
    job_branch = [0] * N
    job_pos = [0] * N
    for j in range(N):
        b = new_branch()
        job_branch[j] = b
        k = int(visits[b, 0])
        heapq.heappush(heap, (sample(k), j, k))

    t = 0.0
    done = 0
    delayed = 0
    warm_target = int(n_requests * warmup_frac)
    warm_t = warm_c = None
    warm_d = 0

    def complete(j: int, now: float) -> None:
        """Finish j's request and start a fresh one at a think station."""
        nonlocal done, warm_c, warm_t, warm_d
        done += 1
        if warm_c is None and done >= warm_target:
            warm_c, warm_t, warm_d = done, now, delayed
        b = new_branch()
        job_branch[j] = b
        job_pos[j] = 0
        k0 = int(visits[b, 0])
        heapq.heappush(heap, (now + sample(k0), j, k0))

    while done < n_requests:
        t, j, k = heapq.heappop(heap)

        # MSHR fill: j's fetch landed — wake everyone parked on its flow.
        if coalesce_flows and k == disk_idx and job_flow[j] >= 0:
            f = job_flow[j]
            for w in parked.pop(f, []):
                delayed += 1
                job_flow[w] = -1
                complete(w, t)
            del leader[f]
            job_flow[j] = -1

        if is_q[k]:
            if queues[k]:
                w = queues[k].pop(0)  # waiter takes over the freed server
                heapq.heappush(heap, (t + sample(k), w, k))
            else:
                busy[k] -= 1
        b = job_branch[j]
        pos = job_pos[j] + 1
        if pos >= visits.shape[1] or visits[b, pos] < 0:
            complete(j, t)
            continue
        job_pos[j] = pos
        k2 = int(visits[b, pos])
        if coalesce_flows and k2 == disk_idx:
            f = rng.randrange(coalesce_flows)
            job_flow[j] = f
            if f in leader:  # fetch already in flight: park, no new I/O
                parked.setdefault(f, []).append(j)
                continue
            leader[f] = j
        if is_q[k2]:
            if busy[k2] >= servers[k2]:
                queues[k2].append(j)
                continue
            busy[k2] += 1
        heapq.heappush(heap, (t + sample(k2), j, k2))

    n_meas = done - warm_c
    x = n_meas / (t - warm_t)
    if not full:
        return x
    return {
        "x": x,
        "delayed": delayed - warm_d,
        "delayed_frac": (delayed - warm_d) / n_meas,
    }
