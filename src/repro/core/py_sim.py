"""Pure-Python reference simulator (heapq event loop).

Oracle for the JAX simulator in :mod:`repro.core.simulator` — same network
semantics, independent implementation.  Used by tests and for debugging;
~100x slower than the jitted simulator, so keep ``n_requests`` modest.

Supports the same miss-coalescing (delayed hits) semantics as the JAX
simulator: with ``coalesce_flows > 0`` a job arriving at the ``disk``
station samples a flow (hot key, uniformly or Zipf(``coalesce_theta``)-
weighted); if a fetch for that flow is already in flight it parks on an
outstanding-miss table — no duplicate disk I/O, no bounded-``disk_servers``
slot — and completes when the fill lands.

Supports the open-loop latency mode as well (``arrival_rate`` set):
Poisson arrivals into a bounded pool of ``max_in_system`` job slots, with
per-request sojourns and true-hit / true-miss / delayed-hit classes
recorded per completion — the differential twin of
``simulate_network(arrival_rate=...)``.
"""

from __future__ import annotations

import heapq
import random

import numpy as np

from repro.core.queueing import ClosedNetwork, zipf_flow_weights
from repro.core.simulator import (
    CLS_DELAYED,
    CLS_HIT,
    CLS_MISS,
    compile_network,
)
from repro.obs.streaming import PyStreamSketch
from repro.obs.trace import PyTraceCollector


def _flow_sampler(rng: random.Random, flows: int, theta: float):
    """Uniform (theta=0) or Zipf(theta)-weighted flow draw, cf.
    simulator._sample_flow — same weight convention as the model's
    queueing.zipf_flow_weights."""
    if theta == 0.0:
        return lambda: rng.randrange(flows)
    cum = np.cumsum(zipf_flow_weights(flows, theta))
    return lambda: int(np.searchsorted(cum, rng.random()))


def simulate_py(
    net: ClosedNetwork,
    p_hit: float,
    n_requests: int = 20_000,
    seed: int = 0,
    warmup_frac: float = 0.25,
    coalesce_flows: int = 0,
    coalesce_theta: float = 0.0,
    full: bool = False,
    arrival_rate: float | None = None,
    max_in_system: int = 128,
    burst=None,
    tiers=None,
    trace: int = 0,
    sketch_cap: int = 0,
    window_us: float = 0.0,
):
    """Simulate and return throughput in requests/µs.

    Service distributions: det and exp are honored; bounded-Pareto stations
    are sampled at their mean (det) — the paper (and our tests) show the
    throughput is insensitive to this.

    With ``full=True`` returns a dict with ``x`` (throughput),
    ``delayed_frac`` (fraction of measured completions that were delayed
    hits), ``delayed`` (their count), plus per-branch measured completion
    counts ``branch_done`` / ``branch_delayed`` in ``net.branches`` order
    (the cluster prong's per-shard accounting); the bare float return
    stays the default for backward compatibility.

    Multi-disk networks (a cluster composition with per-shard ``sK:disk``
    replicas) coalesce shard-locally: each disk station owns its own flow
    group, mirroring the JAX kernel's ``disk_rank`` tables.  ``burst``
    (open mode only) matches ``simulate_network``'s ON-OFF MMPP knob.

    With ``arrival_rate`` set the loop runs **open**: Poisson arrivals at
    that rate (requests/µs) enter a pool of ``max_in_system`` slots
    (arrivals beyond it are dropped and counted), each completion records
    its sojourn and class, and the return value is always a dict with the
    sojourn statistics (``sojourn_mean``/``sojourn_p50``/``sojourn_p99``,
    ``class_frac``, ``class_sojourn``, ``drop_frac`` — the oracle twin of
    :class:`repro.core.simulator.OpenSimResult`).

    ``tiers`` (an :class:`repro.core.simspec.MshrSpec`) switches MSHR
    coalescing to the **cross-tier** tables of a composed hierarchy
    network: acquire/park/release points come from the annotation arrays
    instead of the ``disk_rank`` convention, and fills cascade across
    tiers (a woken delayed hit force-frees its own held entries, waking
    its followers).  Needs ``coalesce_flows > 0``; with 0 the annotations
    are ignored (the no-coalescing reference).  The oracle twin of
    ``simulate_network(tiers=...)``.

    ``trace > 0`` collects per-request trace records in the
    :mod:`repro.obs.trace` schema (same capping semantics as the JAX
    kernels' ring buffers: the last ``trace`` records survive) and
    returns them under the ``"trace"`` key as a decoded
    :class:`~repro.obs.trace.TraceRecords` — the oracle side of the
    trace twin contract.  Closed/tiered modes require ``full=True``
    (the bare-float return has nowhere to put the trace).

    ``sketch_cap > 0`` runs the exact-counting streaming-estimator twin
    (:class:`repro.obs.streaming.PyStreamSketch`, windowed every
    ``window_us`` simulated µs) over the same event stream the JAX
    kernels feed their in-kernel sketches, returning its decoded
    :class:`~repro.obs.streaming.SketchEstimates` under ``"sketch"`` —
    the oracle side of the sketch twin contract.  Same ``full=True``
    requirement as tracing in closed/tiered modes.
    """
    rng = random.Random(seed)
    spec = compile_network(net, p_hit)
    is_q = np.asarray(spec.is_queue)
    svc = np.asarray(spec.svc_ns) / 1e3  # µs
    dist = np.asarray(spec.dist_id)
    cum = np.asarray(spec.branch_cum)
    visits = np.asarray(spec.visits)
    servers = np.asarray(spec.servers)
    disk_rank = np.asarray(spec.disk_rank)
    K = len(is_q)
    B = len(cum)
    F = max(coalesce_flows, 1)
    if coalesce_flows and disk_rank.max() < 0:
        raise ValueError(f"{net.name} has no 'disk' station to coalesce on")
    sample_flow = (
        _flow_sampler(rng, coalesce_flows, coalesce_theta)
        if coalesce_flows else None
    )

    def sample(k: int) -> float:
        if dist[k] == 1:
            return svc[k] * rng.expovariate(1.0)
        return float(svc[k])

    def new_branch() -> int:
        return int(np.searchsorted(cum, rng.random()))

    vis_rank = disk_rank[np.maximum(visits, 0)]
    branch_has_disk = ((vis_rank >= 0) & (visits >= 0)).any(axis=1)
    if trace and arrival_rate is None and not full:
        raise ValueError("trace > 0 requires full=True in closed/tiered "
                         "modes (the bare-float return drops the records)")
    if sketch_cap:
        if window_us <= 0.0:
            raise ValueError("sketch_cap > 0 requires window_us > 0")
        if arrival_rate is None and not full:
            raise ValueError("sketch_cap > 0 requires full=True in "
                             "closed/tiered modes (the bare-float return "
                             "drops the estimates)")
    if tiers is not None and coalesce_flows:
        if arrival_rate is not None or burst is not None:
            raise ValueError("tiered MSHR coalescing runs the closed loop "
                             "only (no arrival_rate/burst)")
        tiers.validate(visits)
        branch_is_miss = (branch_has_disk
                          | (np.asarray(tiers.acq_group) >= 0).any(axis=1))
        return _simulate_py_tiered(
            rng, is_q, visits, servers, sample, new_branch, sample_flow,
            tiers, coalesce_flows, net.mpl, n_requests, warmup_frac, full,
            branch_is_miss, trace, sketch_cap, window_us,
        )
    if arrival_rate is not None:
        return _simulate_py_open(
            rng, is_q, svc, dist, cum, visits, servers, disk_rank, sample,
            new_branch, sample_flow, n_requests, warmup_frac,
            coalesce_flows, float(arrival_rate), max_in_system, burst,
            trace, sketch_cap, window_us,
        )
    if burst is not None:
        raise ValueError("burst arrivals require arrival_rate "
                         "(open-loop mode)")

    N = net.mpl
    tr = PyTraceCollector(trace, N, visits.shape[1]) if trace else None
    sk = (PyStreamSketch(sketch_cap, n_branches=B, window_us=window_us)
          if sketch_cap else None)
    heap: list = []
    queues = {k: [] for k in range(K) if is_q[k]}
    # busy count per queue station: jobs in service, <= servers[k] (matches
    # the JAX simulator's busy-count semantics; c-server FCFS).
    busy = {k: 0 for k in range(K) if is_q[k]}
    # outstanding-miss table: flow -> leader job; parked jobs ride along.
    leader: dict = {}
    parked: dict = {}  # flow -> [job ids]
    job_flow = [-1] * N
    job_branch = [0] * N
    job_pos = [0] * N
    for j in range(N):
        b = new_branch()
        job_branch[j] = b
        k = int(visits[b, 0])
        if tr is not None:
            tr.start(j, 0.0)
        heapq.heappush(heap, (sample(k), j, k))

    t = 0.0
    done = 0
    delayed = 0
    branch_done = [0] * B
    branch_delayed = [0] * B
    warm_target = int(n_requests * warmup_frac)
    warm_t = warm_c = None
    warm_d = 0
    warm_bd = [0] * B
    warm_bdel = [0] * B

    def complete(j: int, now: float, was_delayed: bool = False) -> None:
        """Finish j's request and start a fresh one at a think station."""
        nonlocal done, warm_c, warm_t, warm_d
        branch_done[job_branch[j]] += 1
        if was_delayed:
            branch_delayed[job_branch[j]] += 1
        if tr is not None:
            if was_delayed:  # the park visit ends with the fill, now
                parked_us = now - tr.enter_at(j, job_pos[j])
                tr.leave(j, job_pos[j], now)
                cls_j = CLS_DELAYED
            else:
                parked_us = 0.0
                cls_j = (CLS_MISS if branch_has_disk[job_branch[j]]
                         else CLS_HIT)
            tr.complete(j, job_branch[j], cls_j, job_pos[j] + 1, parked_us)
            tr.start(j, now)  # the fresh request enters its think station
        if sk is not None:  # delayed hits count as misses (miss branches)
            sk.done(now, job_branch[j],
                    is_hit=not branch_has_disk[job_branch[j]],
                    delayed=was_delayed)
        done += 1
        if warm_c is None and done >= warm_target:
            warm_c, warm_t, warm_d = done, now, delayed
            warm_bd[:] = branch_done
            warm_bdel[:] = branch_delayed
        b = new_branch()
        job_branch[j] = b
        job_pos[j] = 0
        k0 = int(visits[b, 0])
        heapq.heappush(heap, (now + sample(k0), j, k0))

    while done < n_requests:
        t, j, k = heapq.heappop(heap)
        if tr is not None:  # j's service at its current visit ends now
            tr.leave(j, job_pos[j], t)

        # MSHR fill: j's fetch landed — wake everyone parked on its flow.
        if coalesce_flows and disk_rank[k] >= 0 and job_flow[j] >= 0:
            f = job_flow[j]
            for w in parked.pop(f, []):
                delayed += 1
                job_flow[w] = -1
                complete(w, t, was_delayed=True)
            del leader[f]
            job_flow[j] = -1

        if is_q[k]:
            if queues[k]:
                w = queues[k].pop(0)  # waiter takes over the freed server
                heapq.heappush(heap, (t + sample(k), w, k))
            else:
                busy[k] -= 1
        b = job_branch[j]
        pos = job_pos[j] + 1
        if pos >= visits.shape[1] or visits[b, pos] < 0:
            complete(j, t)
            continue
        job_pos[j] = pos
        if tr is not None:  # j enters its next visit now (queue, park or svc)
            tr.enter(j, pos, t)
        k2 = int(visits[b, pos])
        if coalesce_flows and disk_rank[k2] >= 0:
            # flows are local to the disk (shard) the miss arrives at
            f = int(disk_rank[k2]) * F + sample_flow()
            job_flow[j] = f
            if sk is not None:  # every disk arrival, park or lead
                sk.key(f)
            if f in leader:  # fetch already in flight: park, no new I/O
                parked.setdefault(f, []).append(j)
                continue
            leader[f] = j
        if is_q[k2]:
            if busy[k2] >= servers[k2]:
                queues[k2].append(j)
                continue
            busy[k2] += 1
        heapq.heappush(heap, (t + sample(k2), j, k2))

    n_meas = done - warm_c
    x = n_meas / (t - warm_t)
    if not full:
        return x
    return {
        "x": x,
        "delayed": delayed - warm_d,
        "delayed_frac": (delayed - warm_d) / n_meas,
        "branch_done": np.array(branch_done) - np.array(warm_bd),
        "branch_delayed": np.array(branch_delayed) - np.array(warm_bdel),
        "t_measured": t - warm_t,
        "warm_done": warm_c,
        "trace": tr.finish(visits) if tr is not None else None,
        "sketch": sk.estimates() if sk is not None else None,
    }


def _simulate_py_tiered(
    rng, is_q, visits, servers, sample, new_branch, sample_flow,
    tiers, coalesce_flows, mpl, n_requests, warmup_frac, full,
    branch_is_miss=None, trace: int = 0, sketch_cap: int = 0,
    window_us: float = 0.0,
):
    """Closed-loop heapq twin of simulator._simulate_tiered: cross-tier
    MSHR acquire/park/release driven by the MshrSpec annotation arrays,
    with cascading fills (a woken delayed hit frees its own held entries,
    recursively waking their followers at the same instant)."""
    acq_group = np.asarray(tiers.acq_group)
    acq_slot = np.asarray(tiers.acq_slot)
    rel_slot = np.asarray(tiers.rel_slot)
    max_held = int(tiers.max_held)
    F = coalesce_flows
    K = len(is_q)
    B = acq_group.shape[0]
    N = mpl

    heap: list = []
    queues = {k: [] for k in range(K) if is_q[k]}
    busy = {k: 0 for k in range(K) if is_q[k]}
    leader: dict = {}  # slot (group*F + f) -> leader job
    parked: dict = {}  # slot -> [(job, level)]
    job_flow = [-1] * N  # per-request flow, sampled at the first acquire
    job_held = [[-1] * max_held for _ in range(N)]
    job_branch = [0] * N
    job_pos = [0] * N
    tr = PyTraceCollector(trace, N, visits.shape[1]) if trace else None
    sk = (PyStreamSketch(sketch_cap, n_branches=B, window_us=window_us)
          if sketch_cap else None)
    for j in range(N):
        b = new_branch()
        job_branch[j] = b
        k = int(visits[b, 0])
        if tr is not None:
            tr.start(j, 0.0)
        heapq.heappush(heap, (sample(k), j, k))

    t = 0.0
    done = 0
    delayed = 0
    delayed_lvl = [0] * max_held
    branch_done = [0] * B
    branch_delayed = [0] * B
    warm_target = int(n_requests * warmup_frac)
    warm_t = warm_c = None
    warm_d = 0
    warm_dlvl = [0] * max_held
    warm_bd = [0] * B
    warm_bdel = [0] * B

    def complete(j: int, now: float, was_delayed: bool = False) -> None:
        nonlocal done, warm_c, warm_t, warm_d
        branch_done[job_branch[j]] += 1
        if was_delayed:
            branch_delayed[job_branch[j]] += 1
        if tr is not None:
            if was_delayed:  # the park visit ends with the fill, now
                parked_us = now - tr.enter_at(j, job_pos[j])
                tr.leave(j, job_pos[j], now)
                cls_j = CLS_DELAYED
            else:
                parked_us = 0.0
                cls_j = (CLS_MISS if branch_is_miss[job_branch[j]]
                         else CLS_HIT)
            tr.complete(j, job_branch[j], cls_j, job_pos[j] + 1, parked_us)
            tr.start(j, now)
        if sk is not None:  # delayed hits count as misses (miss branches)
            sk.done(now, job_branch[j],
                    is_hit=not branch_is_miss[job_branch[j]],
                    delayed=was_delayed)
        done += 1
        if warm_c is None and done >= warm_target:
            warm_c, warm_t, warm_d = done, now, delayed
            warm_dlvl[:] = delayed_lvl
            warm_bd[:] = branch_done
            warm_bdel[:] = branch_delayed
        job_flow[j] = -1
        b = new_branch()
        job_branch[j] = b
        job_pos[j] = 0
        k0 = int(visits[b, 0])
        heapq.heappush(heap, (now + sample(k0), j, k0))

    def free_slot(slot: int, now: float) -> None:
        """The fill for ``slot`` landed: retire the leader entry and
        complete everyone parked on it as delayed hits; their own held
        entries are fills that just landed too — free them recursively
        (strictly shallower levels, so the recursion is bounded)."""
        nonlocal delayed
        leader.pop(slot, None)
        for w, lvl in parked.pop(slot, []):
            delayed += 1
            delayed_lvl[lvl] += 1
            held_w = job_held[w]
            job_held[w] = [-1] * max_held
            complete(w, now, was_delayed=True)
            for sl in held_w:
                if sl >= 0:
                    free_slot(sl, now)

    while done < n_requests:
        t, j, k = heapq.heappop(heap)
        if tr is not None:
            tr.leave(j, job_pos[j], t)

        # fill: completing this visit may release one of j's held entries.
        b = job_branch[j]
        rel = int(rel_slot[b, job_pos[j]])
        if rel >= 0 and job_held[j][rel] >= 0:
            slot = job_held[j][rel]
            job_held[j][rel] = -1
            free_slot(slot, t)

        if is_q[k]:
            if queues[k]:
                w = queues[k].pop(0)
                heapq.heappush(heap, (t + sample(k), w, k))
            else:
                busy[k] -= 1
        pos = job_pos[j] + 1
        if pos >= visits.shape[1] or visits[b, pos] < 0:
            complete(j, t)
            continue
        job_pos[j] = pos
        if tr is not None:
            tr.enter(j, pos, t)
        k2 = int(visits[b, pos])
        g = int(acq_group[b, pos])
        if g >= 0:
            if job_flow[j] < 0:
                job_flow[j] = sample_flow()
                if sk is not None:  # first (shallowest) acquire only
                    sk.key(job_flow[j])
            slot = g * F + job_flow[j]
            if slot in leader:  # fetch in flight: park across the tier
                parked.setdefault(slot, []).append(
                    (j, int(acq_slot[b, pos])))
                continue
            leader[slot] = j
            job_held[j][int(acq_slot[b, pos])] = slot
        if is_q[k2]:
            if busy[k2] >= servers[k2]:
                queues[k2].append(j)
                continue
            busy[k2] += 1
        heapq.heappush(heap, (t + sample(k2), j, k2))

    n_meas = done - warm_c
    x = n_meas / (t - warm_t)
    if not full:
        return x
    return {
        "x": x,
        "delayed": delayed - warm_d,
        "delayed_frac": (delayed - warm_d) / n_meas,
        "delayed_tier_frac": (np.array(delayed_lvl)
                              - np.array(warm_dlvl)) / n_meas,
        "branch_done": np.array(branch_done) - np.array(warm_bd),
        "branch_delayed": np.array(branch_delayed) - np.array(warm_bdel),
        "t_measured": t - warm_t,
        "warm_done": warm_c,
        "trace": tr.finish(visits) if tr is not None else None,
        "sketch": sk.estimates() if sk is not None else None,
    }


def _simulate_py_open(
    rng, is_q, svc, dist, cum, visits, servers, disk_rank, sample,
    new_branch, sample_flow, n_requests, warmup_frac, coalesce_flows,
    arrival_rate, max_in_system, burst=None, trace: int = 0,
    sketch_cap: int = 0, window_us: float = 0.0,
):
    """Open-loop heapq twin of simulator._simulate_open (same semantics:
    Poisson — or ON-OFF burst — arrivals into a bounded slot pool,
    sojourn + class records per completion, parked delayed hits completing
    at fill time, shard-local MSHR flow groups per disk station)."""
    K = len(is_q)
    N = max_in_system
    F = max(coalesce_flows, 1)
    vis_rank = disk_rank[np.maximum(visits, 0)]
    branch_has_disk = ((vis_rank >= 0) & (visits >= 0)).any(axis=1)
    use_burst = burst is not None
    if use_burst:
        duty, mean_on_us = float(burst[0]), float(burst[1])
        if not 0.0 < duty <= 1.0 or mean_on_us <= 0.0:
            raise ValueError(f"burst=(duty, mean_on_us) needs 0<duty<=1 and "
                             f"mean_on_us>0, got {burst}")
        mean_off_us = mean_on_us * (1.0 - duty) / duty
        on_rate = arrival_rate / duty
        phase_on = True
        arr_gen = 0  # invalidates pending arrivals across OFF periods

    heap: list = []  # (t, j, k); j == -1 arrival, j == -2 phase toggle
    queues = {k: [] for k in range(K) if is_q[k]}
    busy = {k: 0 for k in range(K) if is_q[k]}
    leader: dict = {}
    parked: dict = {}
    job_flow = [-1] * N
    job_branch = [0] * N
    job_pos = [0] * N
    arrive_t = [0.0] * N
    free = list(range(N))
    tr = PyTraceCollector(trace, N, visits.shape[1]) if trace else None
    sk = (PyStreamSketch(sketch_cap, n_branches=len(cum),
                         window_us=window_us) if sketch_cap else None)

    records: list = []  # (sojourn, class) in completion order
    done = 0
    delayed = 0
    dropped = 0
    warm_target = int(n_requests * warmup_frac)
    warm_c = warm_t = None

    def record(j: int, now: float, c: int) -> None:
        nonlocal done, warm_c, warm_t
        if tr is not None:
            if c == CLS_DELAYED:  # the park visit ends with the fill, now
                parked_us = now - tr.enter_at(j, job_pos[j])
                tr.leave(j, job_pos[j], now)
            else:
                parked_us = 0.0
            tr.complete(j, job_branch[j], c, job_pos[j] + 1, parked_us)
        if sk is not None:  # delayed hits count as misses (miss branches)
            sk.done(now, job_branch[j], is_hit=(c == CLS_HIT),
                    delayed=(c == CLS_DELAYED))
        done += 1
        records.append((now - arrive_t[j], c))
        free.append(j)
        if warm_c is None and done >= warm_target:
            warm_c, warm_t = done, now

    if use_burst:
        heapq.heappush(heap, (rng.expovariate(on_rate), -1, arr_gen))
        heapq.heappush(heap, (rng.expovariate(1.0 / mean_on_us), -2, 0))
    else:
        heapq.heappush(heap, (rng.expovariate(arrival_rate), -1, -1))
    t = 0.0
    while done < n_requests:
        t, j, k = heapq.heappop(heap)

        if j == -2:  # ON/OFF phase toggle
            phase_on = not phase_on
            if phase_on:
                heapq.heappush(heap, (t + rng.expovariate(on_rate), -1,
                                      arr_gen))
                heapq.heappush(heap, (t + rng.expovariate(1.0 / mean_on_us),
                                      -2, 0))
            else:
                arr_gen += 1  # invalidate the arrival pending from ON
                off = (rng.expovariate(1.0 / mean_off_us)
                       if mean_off_us > 0.0 else 0.0)
                heapq.heappush(heap, (t + off, -2, 0))
            continue

        if j == -1:  # arrival
            if use_burst:
                if k != arr_gen:  # pending arrival from a closed ON period
                    continue
                heapq.heappush(heap, (t + rng.expovariate(on_rate), -1,
                                      arr_gen))
            else:
                heapq.heappush(heap, (t + rng.expovariate(arrival_rate),
                                      -1, -1))
            if sk is not None:  # every offered arrival, admitted or not
                sk.arrival(t)
            if not free:
                dropped += 1
                continue
            s = free.pop(0)
            b = new_branch()
            job_branch[s] = b
            job_pos[s] = 0
            arrive_t[s] = t
            if tr is not None:
                tr.start(s, t)
            k0 = int(visits[b, 0])  # think station by network validation
            heapq.heappush(heap, (t + sample(k0), s, k0))
            continue

        if tr is not None:  # j's service at its current visit ends now
            tr.leave(j, job_pos[j], t)

        # MSHR fill: parked delayed hits complete with the fill.
        if coalesce_flows and disk_rank[k] >= 0 and job_flow[j] >= 0:
            f = job_flow[j]
            for w in parked.pop(f, []):
                delayed += 1
                job_flow[w] = -1
                record(w, t, CLS_DELAYED)
            del leader[f]
            job_flow[j] = -1

        if is_q[k]:
            if queues[k]:
                w = queues[k].pop(0)
                heapq.heappush(heap, (t + sample(k), w, k))
            else:
                busy[k] -= 1
        b = job_branch[j]
        pos = job_pos[j] + 1
        if pos >= visits.shape[1] or visits[b, pos] < 0:
            record(j, t, CLS_MISS if branch_has_disk[b] else CLS_HIT)
            continue
        job_pos[j] = pos
        if tr is not None:
            tr.enter(j, pos, t)
        k2 = int(visits[b, pos])
        if coalesce_flows and disk_rank[k2] >= 0:
            f = int(disk_rank[k2]) * F + sample_flow()
            job_flow[j] = f
            if sk is not None:  # every disk arrival, park or lead
                sk.key(f)
            if f in leader:
                parked.setdefault(f, []).append(j)
                continue
            leader[f] = j
        if is_q[k2]:
            if busy[k2] >= servers[k2]:
                queues[k2].append(j)
                continue
            busy[k2] += 1
        heapq.heappush(heap, (t + sample(k2), j, k2))

    n_meas = done - warm_c
    soj = np.array([r[0] for r in records[warm_c:]])
    cls = np.array([r[1] for r in records[warm_c:]])
    class_frac = np.array([(cls == c).mean() for c in range(3)])
    class_soj = np.array([
        soj[cls == c].mean() if (cls == c).any() else np.nan
        for c in range(3)
    ])
    return {
        "x": n_meas / (t - warm_t),
        "sojourn_mean": float(soj.mean()),
        "sojourn_p50": float(np.percentile(soj, 50)),
        "sojourn_p99": float(np.percentile(soj, 99)),
        "class_frac": class_frac,
        "class_sojourn": class_soj,
        "delayed_frac": float((cls == CLS_DELAYED).mean()),
        "dropped": dropped,
        "drop_frac": dropped / max(done + dropped, 1),
        "warm_done": warm_c,
        "trace": tr.finish(visits) if tr is not None else None,
        "sketch": sk.estimates() if sk is not None else None,
    }
