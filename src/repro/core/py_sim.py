"""Pure-Python reference simulator (heapq event loop).

Oracle for the JAX simulator in :mod:`repro.core.simulator` — same network
semantics, independent implementation.  Used by tests and for debugging;
~100x slower than the jitted simulator, so keep ``n_requests`` modest.
"""

from __future__ import annotations

import heapq
import random

import numpy as np

from repro.core.queueing import ClosedNetwork
from repro.core.simulator import compile_network


def simulate_py(
    net: ClosedNetwork,
    p_hit: float,
    n_requests: int = 20_000,
    seed: int = 0,
    warmup_frac: float = 0.25,
) -> float:
    """Simulate and return throughput in requests/µs.

    Service distributions: det and exp are honored; bounded-Pareto stations
    are sampled at their mean (det) — the paper (and our tests) show the
    throughput is insensitive to this.
    """
    rng = random.Random(seed)
    spec = compile_network(net, p_hit)
    is_q = np.asarray(spec.is_queue)
    svc = np.asarray(spec.svc_ns) / 1e3  # µs
    dist = np.asarray(spec.dist_id)
    cum = np.asarray(spec.branch_cum)
    visits = np.asarray(spec.visits)
    servers = np.asarray(spec.servers)
    K = len(is_q)
    N = net.mpl

    def sample(k: int) -> float:
        if dist[k] == 1:
            return svc[k] * rng.expovariate(1.0)
        return float(svc[k])

    def new_branch() -> int:
        return int(np.searchsorted(cum, rng.random()))

    heap: list = []
    queues = {k: [] for k in range(K) if is_q[k]}
    # busy count per queue station: jobs in service, <= servers[k] (matches
    # the JAX simulator's busy-count semantics; c-server FCFS).
    busy = {k: 0 for k in range(K) if is_q[k]}
    job_branch = [0] * N
    job_pos = [0] * N
    for j in range(N):
        b = new_branch()
        job_branch[j] = b
        k = int(visits[b, 0])
        heapq.heappush(heap, (sample(k), j, k))

    t = 0.0
    done = 0
    warm_target = int(n_requests * warmup_frac)
    warm_t = warm_c = None
    while done < n_requests:
        t, j, k = heapq.heappop(heap)
        if is_q[k]:
            if queues[k]:
                w = queues[k].pop(0)  # waiter takes over the freed server
                heapq.heappush(heap, (t + sample(k), w, k))
            else:
                busy[k] -= 1
        b = job_branch[j]
        pos = job_pos[j] + 1
        if pos >= visits.shape[1] or visits[b, pos] < 0:
            done += 1
            if warm_c is None and done >= warm_target:
                warm_c, warm_t = done, t
            b = new_branch()
            job_branch[j] = b
            pos = 0
        job_pos[j] = pos
        k2 = int(visits[b, pos])
        if is_q[k2]:
            if busy[k2] >= servers[k2]:
                queues[k2].append(j)
                continue
            busy[k2] += 1
        heapq.heappush(heap, (t + sample(k2), j, k2))

    return (done - warm_c) / (t - warm_t)
