"""Event-driven simulation of the closed queueing networks — prong B.

A generic discrete-event simulator for :class:`repro.core.queueing.ClosedNetwork`,
written against ``jax.lax`` so the full ``p_hit`` × ``seed`` grid simulates
as one ``vmap``-ed, jitted program.

Design notes
------------
* **Closed loop.**  Exactly ``mpl`` jobs exist; a completed request
  immediately re-enters as a new request (samples a fresh branch).
* **Stations.**  Think stations are infinite-server (a job entering one is
  immediately "in service"); queue stations are c-server FCFS.  Each queue
  station tracks a *busy count* (jobs currently in service); an arriving job
  starts service while ``busy_count < servers`` and otherwise waits, and a
  departure hands the freed server to the earliest waiter.  The FIFO
  discipline is implemented via per-job enqueue sequence numbers; with
  ``servers=1`` the behaviour is exactly the seed single-server semantics.
* **Clock.**  Integer *nanoseconds*, rebased to zero at every event so the
  clock never overflows int32 regardless of simulation length; total elapsed
  time accumulates separately in float32 microseconds (increments are
  O(service time), so accumulation error is ~1e-4 relative — negligible
  against the simulation's own CI).
* **Distributions.**  det / exp / bounded-Pareto, all rescaled to the
  station's mean (the paper reports insensitivity to the service
  distribution; tests confirm).
* **Miss coalescing** (``coalesce_flows > 0``).  An MSHR-style
  outstanding-miss table over F hot-key "flows": a job arriving at the
  ``disk`` station whose flow already has a fetch in flight parks (no
  duplicate I/O, no bounded-depth slot) and completes when the fill
  lands — the event-level counterpart of
  :func:`repro.core.queueing.coalesced_network`.

One loop iteration processes exactly one event (a service completion);
a disk completion may additionally retire any parked delayed hits.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.queueing import QUEUE, ClosedNetwork

INF_NS = np.int32(2**31 - 1)
BIG_SEQ = np.int32(2**31 - 1)

_DIST_IDS = {"det": 0, "exp": 1, "pareto": 2}


class SimSpec(NamedTuple):
    """A closed network compiled to arrays at one (or a grid of) p_hit."""

    is_queue: jax.Array  # (K,) bool
    svc_ns: jax.Array  # (K,) f32 mean service in ns
    dist_id: jax.Array  # (K,) i32
    dist_params: jax.Array  # (K, 4) f32: alpha, lo, hi, raw_mean (pareto)
    branch_cum: jax.Array  # (B,) f32 cumulative branch probabilities
    visits: jax.Array  # (B, L) i32 station indices, -1 padded
    servers: jax.Array  # (K,) i32 FCFS server count (1 for think stations)
    disk_idx: jax.Array  # () i32 backing-store station index, -1 if none
    mpl: int


def _bounded_pareto_mean(alpha: float, lo: float, hi: float) -> float:
    if abs(alpha - 1.0) < 1e-9:
        return lo * hi / (hi - lo) * math.log(hi / lo)
    num = lo**alpha * alpha * (lo ** (1 - alpha) - hi ** (1 - alpha))
    den = (alpha - 1.0) * (1.0 - (lo / hi) ** alpha)
    return num / den


def compile_network(net: ClosedNetwork, p_hit: float) -> SimSpec:
    """Freeze a network at a given hit ratio into simulator arrays."""
    names = [s.name for s in net.stations]
    idx = {n: i for i, n in enumerate(names)}
    K = len(names)
    is_queue = np.array([s.kind == QUEUE for s in net.stations], dtype=bool)
    svc_ns = np.array(
        [s.mean_service(p_hit) * 1e3 for s in net.stations], dtype=np.float32
    )
    dist_id = np.array([_DIST_IDS[s.dist] for s in net.stations], dtype=np.int32)
    dist_params = np.zeros((K, 4), dtype=np.float32)
    for i, s in enumerate(net.stations):
        if s.dist == "pareto":
            alpha, lo, hi = s.dist_params
            dist_params[i] = (alpha, lo, hi, _bounded_pareto_mean(alpha, lo, hi))
        else:
            dist_params[i] = (1.0, 1.0, 1.0, 1.0)

    probs = np.array([b.probability(p_hit) for b in net.branches], dtype=np.float64)
    if not math.isclose(probs.sum(), 1.0, abs_tol=1e-5):
        raise ValueError(f"branch probs sum to {probs.sum()} at p={p_hit}")
    probs = np.maximum(probs, 0.0)
    branch_cum = np.cumsum(probs / probs.sum()).astype(np.float32)

    L = max(len(b.visits) for b in net.branches)
    if min(len(b.visits) for b in net.branches) == 0:
        raise ValueError("empty branch routes are not supported")
    visits = np.full((len(net.branches), L), -1, dtype=np.int32)
    for bi, b in enumerate(net.branches):
        for vi, v in enumerate(b.visits):
            visits[bi, vi] = idx[v]
    if is_queue[visits[:, 0]].any():
        # init places all mpl jobs straight into service at their first
        # station; a queue-first route would bypass the busy accounting.
        raise ValueError("branch routes must start at a think station")

    servers = np.array(
        [s.servers if s.kind == QUEUE else 1 for s in net.stations],
        dtype=np.int32,
    )

    return SimSpec(
        is_queue=jnp.asarray(is_queue),
        svc_ns=jnp.asarray(svc_ns),
        dist_id=jnp.asarray(dist_id),
        dist_params=jnp.asarray(dist_params),
        branch_cum=jnp.asarray(branch_cum),
        visits=jnp.asarray(visits),
        servers=jnp.asarray(servers),
        disk_idx=jnp.int32(idx.get("disk", -1)),
        mpl=net.mpl,
    )


def stack_specs(specs) -> SimSpec:
    """Stack per-p_hit specs along a leading axis for vmap."""
    mpl = specs[0].mpl
    assert all(s.mpl == mpl for s in specs)
    return SimSpec(
        *[jnp.stack([getattr(s, f) for s in specs]) for f in SimSpec._fields[:-1]],
        mpl=mpl,
    )


# ---------------------------------------------------------------------------
# The simulator kernel
# ---------------------------------------------------------------------------


def _sample_service_ns(key, spec: SimSpec, k) -> jnp.ndarray:
    """Sample a service time (ns, int32 >= 1) for station k."""
    mean = spec.svc_ns[k]
    u = jax.random.uniform(key, (), minval=1e-7, maxval=1.0 - 1e-7)
    # exp
    s_exp = -jnp.log(u)
    # bounded pareto via inverse CDF, rescaled to unit mean
    alpha, lo, hi, raw_mean = (spec.dist_params[k, i] for i in range(4))
    ratio = 1.0 - (lo / hi) ** alpha
    s_par = lo * (1.0 - u * ratio) ** (-1.0 / alpha) / raw_mean
    unit = jnp.select(
        [spec.dist_id[k] == 0, spec.dist_id[k] == 1, spec.dist_id[k] == 2],
        [jnp.float32(1.0), s_exp, s_par],
    )
    return jnp.maximum(jnp.round(unit * mean), 1.0).astype(jnp.int32)


class _SimState(NamedTuple):
    key: jax.Array
    ready_ns: jax.Array  # (N,) i32, INF when waiting in a queue (or parked)
    station: jax.Array  # (N,) i32
    branch: jax.Array  # (N,) i32
    pos: jax.Array  # (N,) i32
    enq_seq: jax.Array  # (N,) i32, BIG when not waiting
    busy_count: jax.Array  # (K,) i32 jobs in service (<= servers[k])
    seq_ctr: jax.Array  # i32
    completed: jax.Array  # i32
    elapsed_us: jax.Array  # f32
    warm_completed: jax.Array  # i32
    warm_elapsed_us: jax.Array  # f32
    # --- outstanding-miss (MSHR) table, used only when n_flows > 0 ---
    flow: jax.Array  # (N,) i32 flow a job fetches/parks on, -1 otherwise
    leader: jax.Array  # (F,) i32 job id leading each flow's fetch, -1 idle
    delayed: jax.Array  # i32 completed requests that were delayed hits
    warm_delayed: jax.Array  # i32 `delayed` at the warmup crossing


@partial(jax.jit,
         static_argnames=("n_requests", "warmup", "mpl", "max_events",
                          "n_flows"))
def _simulate(spec: SimSpec, seed, n_requests: int, warmup: int, mpl: int,
              max_events: int, n_flows: int = 0) -> tuple:
    N = mpl
    F = max(n_flows, 1)  # leader-table shape must be static even when unused
    key = jax.random.PRNGKey(seed)

    def sample_branch(key):
        u = jax.random.uniform(key, ())
        return jnp.searchsorted(spec.branch_cum, u).astype(jnp.int32)

    # --- init: every job starts a fresh request at its first (think) station.
    key, bk, sk = jax.random.split(key, 3)
    branch0 = jax.vmap(sample_branch)(jax.random.split(bk, N))
    station0 = spec.visits[branch0, 0]
    svc0 = jax.vmap(lambda k, s: _sample_service_ns(k, spec, s))(
        jax.random.split(sk, N), station0
    )
    # First station is a think station in every policy network (cache lookup);
    # queue stations at t=0 would need arbitration — assert via construction.
    state = _SimState(
        key=key,
        ready_ns=svc0,
        station=station0,
        branch=branch0,
        pos=jnp.zeros((N,), jnp.int32),
        enq_seq=jnp.full((N,), BIG_SEQ),
        busy_count=jnp.zeros(spec.is_queue.shape, jnp.int32),
        seq_ctr=jnp.int32(0),
        completed=jnp.int32(0),
        elapsed_us=jnp.float32(0.0),
        warm_completed=jnp.int32(-1),
        warm_elapsed_us=jnp.float32(0.0),
        flow=jnp.full((N,), -1, jnp.int32),
        leader=jnp.full((F,), -1, jnp.int32),
        delayed=jnp.int32(0),
        warm_delayed=jnp.int32(0),
    )

    def cond(carry):
        state, events = carry
        return (state.completed < n_requests) & (events < max_events)

    def body(carry):
        state, events = carry
        if n_flows:
            (key, k_svc1, k_svc2, k_branch, k_flow, k_wake_b,
             k_wake_s) = jax.random.split(state.key, 7)
        else:
            key, k_svc1, k_svc2, k_branch = jax.random.split(state.key, 4)

        j = jnp.argmin(state.ready_ns).astype(jnp.int32)
        t = state.ready_ns[j]
        finite = state.ready_ns < INF_NS
        ready = jnp.where(finite, state.ready_ns - t, INF_NS)
        elapsed_us = state.elapsed_us + t.astype(jnp.float32) * 1e-3

        k_cur = state.station[j]
        busy_count = state.busy_count
        enq_seq = state.enq_seq
        station = state.station
        branch = state.branch
        pos = state.pos
        flow = state.flow
        leader = state.leader
        completed = state.completed
        delayed = state.delayed

        # ---- MSHR fill: j's fetch landed — wake every request parked on it.
        # Parked jobs are NOT in the disk queue (ready=INF but enq_seq=BIG),
        # so they never hold an I/O-depth slot and the FIFO release below
        # can never mistake them for queue waiters.  A delayed hit skips the
        # fill metadata: it completes its request on the spot and starts a
        # fresh one at a first (think) station.
        if n_flows:
            f_cur = flow[j]
            fill = (k_cur == spec.disk_idx) & (f_cur >= 0)
            woken = (flow == f_cur) & fill
            woken = woken.at[j].set(False)
            wake_branch = jax.vmap(sample_branch)(jax.random.split(k_wake_b, N))
            wake_station = spec.visits[wake_branch, 0]
            wake_svc = jax.vmap(lambda k, s: _sample_service_ns(k, spec, s))(
                jax.random.split(k_wake_s, N), wake_station
            )
            ready = jnp.where(woken, wake_svc, ready)
            station = jnp.where(woken, wake_station, station)
            branch = jnp.where(woken, wake_branch, branch)
            pos = jnp.where(woken, 0, pos)
            n_woken = woken.sum().astype(jnp.int32)
            completed = completed + n_woken
            delayed = delayed + n_woken
            leader = jnp.where(
                fill, leader.at[jnp.maximum(f_cur, 0)].set(-1), leader
            )
            flow = jnp.where(woken | ((jnp.arange(N) == j) & fill), -1, flow)

        # ---- hand the server job j held (if any) to its FIFO successor.
        def release(args):
            ready, busy_count, enq_seq = args
            waiting = (station == k_cur) & (ready == INF_NS)
            waiting = waiting.at[j].set(False)
            seqs = jnp.where(waiting, enq_seq, BIG_SEQ)
            w = jnp.argmin(seqs).astype(jnp.int32)
            has_waiter = seqs[w] < BIG_SEQ
            svc = _sample_service_ns(k_svc1, spec, k_cur)
            ready = jnp.where(has_waiter, ready.at[w].set(svc), ready)
            enq_seq = jnp.where(has_waiter, enq_seq.at[w].set(BIG_SEQ), enq_seq)
            # a waiter takes over j's server (count unchanged); otherwise the
            # server goes idle.
            busy_count = busy_count.at[k_cur].add(
                jnp.where(has_waiter, 0, -1).astype(jnp.int32)
            )
            return ready, busy_count, enq_seq

        ready, busy_count, enq_seq = jax.lax.cond(
            spec.is_queue[k_cur], release, lambda a: a,
            (ready, busy_count, enq_seq),
        )

        # ---- advance job j along its route (or complete + start new request).
        nxt_pos = pos[j] + 1
        L = spec.visits.shape[1]
        route_next = jnp.where(nxt_pos < L, spec.visits[branch[j], nxt_pos % L], -1)
        done = route_next < 0

        new_branch = sample_branch(k_branch)
        branch_j = jnp.where(done, new_branch, branch[j])
        pos_j = jnp.where(done, 0, nxt_pos)
        k_next = jnp.where(done, spec.visits[new_branch, 0], route_next)
        completed = completed + done.astype(jnp.int32)

        # ---- place j at k_next.
        svc_next = _sample_service_ns(k_svc2, spec, k_next)
        is_q = spec.is_queue[k_next]
        has_slot = busy_count[k_next] < spec.servers[k_next]
        if n_flows:
            # Arriving at the backing store: sample which (hot) key this
            # miss fetches.  If a fetch for that key is already in flight,
            # park on the outstanding-miss table — no duplicate disk I/O,
            # no I/O-depth slot, no queue position.
            at_disk = k_next == spec.disk_idx
            f_new = jax.random.randint(k_flow, (), 0, n_flows)
            parks = at_disk & (leader[f_new] >= 0)
            starts_now = ((~is_q) | has_slot) & ~parks
            waits = is_q & ~has_slot & ~parks
            leader = jnp.where(at_disk & ~parks, leader.at[f_new].set(j),
                               leader)
            flow = flow.at[j].set(jnp.where(at_disk, f_new, flow[j]))
        else:
            starts_now = (~is_q) | has_slot
            waits = ~starts_now
        ready = ready.at[j].set(jnp.where(starts_now, svc_next, INF_NS))
        enq_seq = enq_seq.at[j].set(jnp.where(waits, state.seq_ctr, BIG_SEQ))
        seq_ctr = state.seq_ctr + waits.astype(jnp.int32)
        busy_count = busy_count.at[k_next].add((is_q & starts_now).astype(jnp.int32))

        # ---- warmup bookkeeping.
        warm_now = (completed >= warmup) & (state.warm_completed < 0)
        warm_completed = jnp.where(warm_now, completed, state.warm_completed)
        warm_elapsed_us = jnp.where(warm_now, elapsed_us, state.warm_elapsed_us)
        warm_delayed = jnp.where(warm_now, delayed, state.warm_delayed)

        new_state = _SimState(
            key=key,
            ready_ns=ready,
            station=station.at[j].set(k_next),
            branch=branch.at[j].set(branch_j),
            pos=pos.at[j].set(pos_j),
            enq_seq=enq_seq,
            busy_count=busy_count,
            seq_ctr=seq_ctr,
            completed=completed,
            elapsed_us=elapsed_us,
            warm_completed=warm_completed,
            warm_elapsed_us=warm_elapsed_us,
            flow=flow,
            leader=leader,
            delayed=delayed,
            warm_delayed=warm_delayed,
        )
        return new_state, events + 1

    state, events = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))

    n_measured = state.completed - state.warm_completed
    t_measured = state.elapsed_us - state.warm_elapsed_us
    x = n_measured.astype(jnp.float32) / jnp.maximum(t_measured, 1e-6)
    delayed_frac = (
        (state.delayed - state.warm_delayed).astype(jnp.float32)
        / jnp.maximum(n_measured, 1).astype(jnp.float32)
    )
    return x, state.completed, events, delayed_frac


@dataclasses.dataclass(frozen=True)
class SimResult:
    p_hit: np.ndarray
    throughput: np.ndarray  # requests/µs == M req/s
    ci95: np.ndarray  # 95% CI half-width across seeds
    n_requests: int
    # fraction of measured completions that were delayed hits (coalesced
    # onto an in-flight fetch); zeros unless coalesce_flows > 0.
    delayed_frac: np.ndarray | None = None


def simulate_network(
    net: ClosedNetwork,
    p_hits,
    n_requests: int = 40_000,
    seeds=(0, 1, 2),
    warmup_frac: float = 0.25,
    coalesce_flows: int = 0,
) -> SimResult:
    """Simulate ``net`` over a grid of hit ratios.

    The full (p_hit × seed) grid dispatches as ONE vmapped, jitted program:
    the per-p_hit spec arrays are tiled across seeds so every (p, seed) cell
    is an independent lane of the same kernel.

    ``coalesce_flows > 0`` turns on miss coalescing (delayed hits): a job
    arriving at the ``disk`` station samples one of ``coalesce_flows`` hot
    keys; if a fetch for that key is already outstanding the job parks on
    an MSHR-style table (issuing no duplicate I/O and holding no bounded
    ``disk_servers`` slot) and completes when the fill lands.  This is the
    event-level counterpart of
    :func:`repro.core.queueing.coalesced_network`; 0 leaves the compiled
    program bit-identical to the non-coalesced simulator.
    """
    p_hits = np.atleast_1d(np.asarray(p_hits, dtype=np.float64))
    spec = stack_specs([compile_network(net, float(p)) for p in p_hits])
    warmup = int(n_requests * warmup_frac)
    # one event per station visit; bound with headroom
    max_events = int(n_requests * (spec.visits.shape[-1] + 2) * 3)

    runner = jax.vmap(
        lambda sp, seed: _simulate(
            SimSpec(*sp, mpl=net.mpl), seed, n_requests=n_requests,
            warmup=warmup, mpl=net.mpl, max_events=max_events,
            n_flows=coalesce_flows,
        ),
        in_axes=(0, 0),
    )
    P, S = len(p_hits), len(seeds)
    # strip the static mpl field for vmap; tile (P, ...) -> (S*P, ...)
    spec_arrays = tuple(
        jnp.concatenate([a] * S, axis=0) if S > 1 else a for a in spec[:-1]
    )
    seed_v = jnp.concatenate(
        [jnp.full((P,), s, jnp.int32) * 1000 + jnp.arange(P, dtype=jnp.int32)
         for s in seeds]
    )
    out = runner(spec_arrays, seed_v)
    xs = np.asarray(out[0]).reshape(S, P)
    dl = np.asarray(out[3]).reshape(S, P)
    mean = xs.mean(axis=0)
    ci = 1.96 * xs.std(axis=0, ddof=1) / math.sqrt(len(seeds)) if len(seeds) > 1 else np.zeros_like(mean)
    return SimResult(p_hit=p_hits, throughput=mean, ci95=ci,
                     n_requests=n_requests, delayed_frac=dl.mean(axis=0))
