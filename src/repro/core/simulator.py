"""Event-driven simulation of the closed queueing networks — prong B.

A generic discrete-event simulator for :class:`repro.core.queueing.ClosedNetwork`,
written against ``jax.lax`` so the full ``p_hit`` × ``seed`` grid simulates
as one ``vmap``-ed, jitted program.

Design notes
------------
* **Closed loop.**  Exactly ``mpl`` jobs exist; a completed request
  immediately re-enters as a new request (samples a fresh branch).
* **Stations.**  Think stations are infinite-server (a job entering one is
  immediately "in service"); queue stations are c-server FCFS.  Each queue
  station tracks a *busy count* (jobs currently in service); an arriving job
  starts service while ``busy_count < servers`` and otherwise waits, and a
  departure hands the freed server to the earliest waiter.  The FIFO
  discipline is implemented via per-job enqueue sequence numbers; with
  ``servers=1`` the behaviour is exactly the seed single-server semantics.
* **Clock.**  Integer *nanoseconds*, rebased to zero at every event so the
  clock never overflows int32 regardless of simulation length; total elapsed
  time accumulates separately in float32 microseconds (increments are
  O(service time), so accumulation error is ~1e-4 relative — negligible
  against the simulation's own CI).
* **Distributions.**  det / exp / bounded-Pareto, all rescaled to the
  station's mean (the paper reports insensitivity to the service
  distribution; tests confirm).
* **Miss coalescing** (``coalesce_flows > 0``).  An MSHR-style
  outstanding-miss table over F hot-key "flows": a job arriving at a
  disk station whose flow already has a fetch in flight parks (no
  duplicate I/O, no bounded-depth slot) and completes when the fill
  lands — the event-level counterpart of
  :func:`repro.core.queueing.coalesced_network`.  A network may carry
  several disk stations (the cluster composition's per-shard ``sK:disk``
  replicas): each owns its own flow group in the leader table, so
  coalescing is shard-local.
* **Per-branch accounting.**  The closed kernel counts completions and
  delayed hits per branch (post-warmup), which is how the cluster prong
  recovers per-shard throughput / hit-ratio / delayed-hit breakdowns
  from one compiled dispatch.

One loop iteration processes exactly one event (a service completion);
a disk completion may additionally retire any parked delayed hits.

* **Open loop** (``arrival_rate`` set on :func:`simulate_network`).  The
  same networks under Poisson arrivals: jobs enter at rate lambda, flow
  through their branch route, and *leave* — the latency prong's
  arrival-driven mode.  Every completion records a per-request sojourn
  (arrival to completion, including time parked on the MSHR table) and a
  class (true hit / true miss / delayed hit), carried through the scan in
  a fixed record buffer, so the simulator returns mean/percentile response
  times and per-class latency breakdowns instead of just throughput.
  Jobs live in a pool of ``max_in_system`` slots; an arrival finding no
  free slot is counted as dropped (finite-capacity system — keep
  ``drop_frac`` at 0 by sizing the pool, or you are measuring admission
  control, not the queue).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.queueing import ClosedNetwork
from repro.core.simspec import (BIG_SEQ, INF_NS, SimResult, SimSpec,
                                compile_network, stack_specs)
from repro.obs.streaming import (decode_sketch_grid, sketch_init,
                                 stream_arrival, stream_done,
                                 stream_done_many, stream_key, stream_tick)
from repro.obs.trace import (TraceScratch, decode_trace_grid, init_trace,
                             ring_write_many, ring_write_one)

__all__ = [
    "BIG_SEQ", "INF_NS", "SimResult", "SimSpec", "OpenSimResult",
    "CLS_MISS", "CLS_HIT", "CLS_DELAYED",
    "compile_network", "stack_specs", "simulate_network",
]

# Sojourn classes, value-compatible with repro.cache.replay's classifier
# (TRUE_MISS/TRUE_HIT/DELAYED_HIT) so prong B and prong C breakdowns line up.
CLS_MISS = 0
CLS_HIT = 1
CLS_DELAYED = 2


# ---------------------------------------------------------------------------
# The simulator kernel
# ---------------------------------------------------------------------------


def _sample_service_ns(key, spec: SimSpec, k) -> jnp.ndarray:
    """Sample a service time (ns, int32 >= 1) for station k."""
    mean = spec.svc_ns[k]
    u = jax.random.uniform(key, (), minval=1e-7, maxval=1.0 - 1e-7)
    # exp
    s_exp = -jnp.log(u)
    # bounded pareto via inverse CDF, rescaled to unit mean
    alpha, lo, hi, raw_mean = (spec.dist_params[k, i] for i in range(4))
    ratio = 1.0 - (lo / hi) ** alpha
    s_par = lo * (1.0 - u * ratio) ** (-1.0 / alpha) / raw_mean
    unit = jnp.select(
        [spec.dist_id[k] == 0, spec.dist_id[k] == 1, spec.dist_id[k] == 2],
        [jnp.float32(1.0), s_exp, s_par],
    )
    return jnp.maximum(jnp.round(unit * mean), 1.0).astype(jnp.int32)


def _sample_flow(key, n_flows: int, theta: float):
    """Sample the hot-key flow a miss fetches.  theta=0 keeps the original
    uniform ``randint`` draw (bit-identical RNG stream); theta>0 samples
    Zipf(theta)-weighted flows via inverse CDF over the model's own weight
    vector (queueing.zipf_flow_weights) — the ensemble matched to a skewed
    trace, so measured coalescing is predictable from the per-key miss
    spectrum.  ``n_flows``/``theta`` are static, so the CDF constant-folds
    into the compiled kernel."""
    if theta == 0.0:
        return jax.random.randint(key, (), 0, n_flows)
    from repro.core.queueing import zipf_flow_weights

    cum = jnp.asarray(np.cumsum(zipf_flow_weights(n_flows, theta)),
                      jnp.float32)
    u = jax.random.uniform(key, ())
    return jnp.searchsorted(cum, u).astype(jnp.int32)


class _SimState(NamedTuple):
    key: jax.Array
    ready_ns: jax.Array  # (N,) i32, INF when waiting in a queue (or parked)
    station: jax.Array  # (N,) i32
    branch: jax.Array  # (N,) i32
    pos: jax.Array  # (N,) i32
    enq_seq: jax.Array  # (N,) i32, BIG when not waiting
    busy_count: jax.Array  # (K,) i32 jobs in service (<= servers[k])
    seq_ctr: jax.Array  # i32
    completed: jax.Array  # i32
    elapsed_us: jax.Array  # f32
    warm_completed: jax.Array  # i32
    warm_elapsed_us: jax.Array  # f32
    # --- outstanding-miss (MSHR) table, used only when n_flows > 0.
    # With D disk stations (a sharded cluster) the table holds D*n_flows
    # entries: the fetch for flow f at the disk of rank r lives at
    # r*n_flows + f, so coalescing never crosses shards.
    flow: jax.Array  # (N,) i32 flow a job fetches/parks on, -1 otherwise
    leader: jax.Array  # (D*F,) i32 job id leading each flow's fetch, -1 idle
    delayed: jax.Array  # i32 completed requests that were delayed hits
    warm_delayed: jax.Array  # i32 `delayed` at the warmup crossing
    # --- per-branch completion accounting (cluster per-shard stats) ---
    branch_done: jax.Array  # (B,) i32 completions per branch
    branch_delayed: jax.Array  # (B,) i32 delayed-hit completions per branch
    warm_branch_done: jax.Array  # (B,) i32 snapshots at the warmup crossing
    warm_branch_delayed: jax.Array  # (B,) i32


@partial(jax.jit,
         static_argnames=("n_requests", "warmup", "mpl", "max_events",
                          "n_flows", "flow_theta", "n_disks", "trace_cap",
                          "sketch_cap", "window_us"))
def _simulate(spec: SimSpec, seed, n_requests: int, warmup: int, mpl: int,
              max_events: int, n_flows: int = 0,
              flow_theta: float = 0.0, n_disks: int = 1,
              trace_cap: int = 0, sketch_cap: int = 0,
              window_us: float = 0.0) -> tuple:
    N = mpl
    F = max(n_flows, 1)  # leader-table shape must be static even when unused
    L = spec.visits.shape[1]
    B = spec.branch_cum.shape[0]
    key = jax.random.PRNGKey(seed)
    if trace_cap or sketch_cap:
        # sojourn class of a completed branch: any disk visit => miss route
        vis_rank = spec.disk_rank[jnp.maximum(spec.visits, 0)]
        branch_has_disk = ((vis_rank >= 0) & (spec.visits >= 0)).any(axis=1)

    def sample_branch(key):
        u = jax.random.uniform(key, ())
        return jnp.searchsorted(spec.branch_cum, u).astype(jnp.int32)

    # --- init: every job starts a fresh request at its first (think) station.
    key, bk, sk = jax.random.split(key, 3)
    branch0 = jax.vmap(sample_branch)(jax.random.split(bk, N))
    station0 = spec.visits[branch0, 0]
    svc0 = jax.vmap(lambda k, s: _sample_service_ns(k, spec, s))(
        jax.random.split(sk, N), station0
    )
    # First station is a think station in every policy network (cache lookup);
    # queue stations at t=0 would need arbitration — assert via construction.
    state = _SimState(
        key=key,
        ready_ns=svc0,
        station=station0,
        branch=branch0,
        pos=jnp.zeros((N,), jnp.int32),
        enq_seq=jnp.full((N,), BIG_SEQ),
        busy_count=jnp.zeros(spec.is_queue.shape, jnp.int32),
        seq_ctr=jnp.int32(0),
        completed=jnp.int32(0),
        elapsed_us=jnp.float32(0.0),
        warm_completed=jnp.int32(-1),
        warm_elapsed_us=jnp.float32(0.0),
        flow=jnp.full((N,), -1, jnp.int32),
        leader=jnp.full((max(n_disks, 1) * F,), -1, jnp.int32),
        delayed=jnp.int32(0),
        warm_delayed=jnp.int32(0),
        branch_done=jnp.zeros((B,), jnp.int32),
        branch_delayed=jnp.zeros((B,), jnp.int32),
        warm_branch_done=jnp.zeros((B,), jnp.int32),
        warm_branch_delayed=jnp.zeros((B,), jnp.int32),
    )
    tr0 = init_trace(trace_cap, N, L)
    sk0 = sketch_init(sketch_cap, B)

    def cond(carry):
        state, events, _tr, _sk = carry
        return (state.completed < n_requests) & (events < max_events)

    def body(carry):
        state, events, tr, sk = carry
        if trace_cap:
            rings, scr = tr
        if n_flows:
            (key, k_svc1, k_svc2, k_branch, k_flow, k_wake_b,
             k_wake_s) = jax.random.split(state.key, 7)
        else:
            key, k_svc1, k_svc2, k_branch = jax.random.split(state.key, 4)

        j = jnp.argmin(state.ready_ns).astype(jnp.int32)
        t = state.ready_ns[j]
        finite = state.ready_ns < INF_NS
        ready = jnp.where(finite, state.ready_ns - t, INF_NS)
        elapsed_us = state.elapsed_us + t.astype(jnp.float32) * 1e-3
        if sketch_cap:
            sk, w_slot = stream_tick(sk, elapsed_us, window_us)

        k_cur = state.station[j]
        busy_count = state.busy_count
        enq_seq = state.enq_seq
        station = state.station
        branch = state.branch
        pos = state.pos
        flow = state.flow
        leader = state.leader
        completed = state.completed
        delayed = state.delayed
        branch_done = state.branch_done
        branch_delayed = state.branch_delayed

        # ---- MSHR fill: j's fetch landed — wake every request parked on it.
        # Parked jobs are NOT in the disk queue (ready=INF but enq_seq=BIG),
        # so they never hold an I/O-depth slot and the FIFO release below
        # can never mistake them for queue waiters.  A delayed hit skips the
        # fill metadata: it completes its request on the spot and starts a
        # fresh one at a first (think) station.
        if n_flows:
            f_cur = flow[j]
            fill = (spec.disk_rank[k_cur] >= 0) & (f_cur >= 0)
            woken = (flow == f_cur) & fill
            woken = woken.at[j].set(False)
            wake_branch = jax.vmap(sample_branch)(jax.random.split(k_wake_b, N))
            wake_station = spec.visits[wake_branch, 0]
            wake_svc = jax.vmap(lambda k, s: _sample_service_ns(k, spec, s))(
                jax.random.split(k_wake_s, N), wake_station
            )
            # count the woken jobs' completions under the branch they parked
            # on (a miss route) before the wake resamples their branch
            wcount = woken.astype(jnp.int32)
            branch_done = branch_done.at[branch].add(wcount)
            branch_delayed = branch_delayed.at[branch].add(wcount)
            if sketch_cap:
                sk = stream_done_many(sk, w_slot, branch, woken)
            if trace_cap:
                # the woken requests' park visit ends now; they completed
                # their whole parked interval at the visit they parked at.
                rows = jnp.where(woken, jnp.arange(N), N)
                leave_m = scr.leave_us.at[rows, pos].set(elapsed_us)
                parked_w = elapsed_us - scr.enter_us[jnp.arange(N), pos]
                rings = ring_write_many(
                    rings, woken, state.completed, branch,
                    jnp.full((N,), CLS_DELAYED, jnp.int32), pos + 1,
                    jnp.where(woken, parked_w, 0.0), scr.enter_us, leave_m,
                )
                # the fresh requests the woken jobs start enter visit 0 now
                scr = TraceScratch(
                    enter_us=scr.enter_us.at[rows, 0].set(elapsed_us),
                    leave_us=leave_m,
                )
            ready = jnp.where(woken, wake_svc, ready)
            station = jnp.where(woken, wake_station, station)
            branch = jnp.where(woken, wake_branch, branch)
            pos = jnp.where(woken, 0, pos)
            n_woken = woken.sum().astype(jnp.int32)
            completed = completed + n_woken
            delayed = delayed + n_woken
            leader = jnp.where(
                fill, leader.at[jnp.maximum(f_cur, 0)].set(-1), leader
            )
            flow = jnp.where(woken | ((jnp.arange(N) == j) & fill), -1, flow)

        # ---- hand the server job j held (if any) to its FIFO successor.
        def release(args):
            ready, busy_count, enq_seq = args
            waiting = (station == k_cur) & (ready == INF_NS)
            waiting = waiting.at[j].set(False)
            seqs = jnp.where(waiting, enq_seq, BIG_SEQ)
            w = jnp.argmin(seqs).astype(jnp.int32)
            has_waiter = seqs[w] < BIG_SEQ
            svc = _sample_service_ns(k_svc1, spec, k_cur)
            ready = jnp.where(has_waiter, ready.at[w].set(svc), ready)
            enq_seq = jnp.where(has_waiter, enq_seq.at[w].set(BIG_SEQ), enq_seq)
            # a waiter takes over j's server (count unchanged); otherwise the
            # server goes idle.
            busy_count = busy_count.at[k_cur].add(
                jnp.where(has_waiter, 0, -1).astype(jnp.int32)
            )
            return ready, busy_count, enq_seq

        ready, busy_count, enq_seq = jax.lax.cond(
            spec.is_queue[k_cur], release, lambda a: a,
            (ready, busy_count, enq_seq),
        )

        # ---- advance job j along its route (or complete + start new request).
        nxt_pos = pos[j] + 1
        route_next = jnp.where(nxt_pos < L, spec.visits[branch[j], nxt_pos % L], -1)
        done = route_next < 0

        new_branch = sample_branch(k_branch)
        branch_done = branch_done.at[branch[j]].add(done.astype(jnp.int32))
        if sketch_cap:
            sk = stream_done(sk, w_slot, branch[j],
                             ~branch_has_disk[branch[j]], jnp.bool_(False),
                             done)
        branch_j = jnp.where(done, new_branch, branch[j])
        pos_j = jnp.where(done, 0, nxt_pos)
        k_next = jnp.where(done, spec.visits[new_branch, 0], route_next)
        if trace_cap:
            # j's visit ends now; on completion, emit its record (req id
            # follows the woken jobs retired above, matching `completed`).
            leave_m = scr.leave_us.at[j, pos[j]].set(elapsed_us)
            cls_j = jnp.where(branch_has_disk[branch[j]], CLS_MISS,
                              CLS_HIT).astype(jnp.int32)
            rings = ring_write_one(rings, done, completed, branch[j], cls_j,
                                   pos[j] + 1, jnp.float32(0.0),
                                   scr.enter_us[j], leave_m[j])
            scr = TraceScratch(
                enter_us=scr.enter_us.at[j, pos_j].set(elapsed_us),
                leave_us=leave_m,
            )
        completed = completed + done.astype(jnp.int32)

        # ---- place j at k_next.
        svc_next = _sample_service_ns(k_svc2, spec, k_next)
        is_q = spec.is_queue[k_next]
        has_slot = busy_count[k_next] < spec.servers[k_next]
        if n_flows:
            # Arriving at the backing store: sample which (hot) key this
            # miss fetches.  If a fetch for that key is already in flight,
            # park on the outstanding-miss table — no duplicate disk I/O,
            # no I/O-depth slot, no queue position.  Flows are local to the
            # disk group (shard) the job arrived at.
            rank_next = spec.disk_rank[k_next]
            at_disk = rank_next >= 0
            f_new = (jnp.maximum(rank_next, 0) * F
                     + _sample_flow(k_flow, n_flows, flow_theta))
            if sketch_cap:
                # every miss arrival at the store observes its flow key
                # (leader or parked alike) — the popularity stream.
                sk = stream_key(sk, f_new, at_disk)
            parks = at_disk & (leader[f_new] >= 0)
            starts_now = ((~is_q) | has_slot) & ~parks
            waits = is_q & ~has_slot & ~parks
            leader = jnp.where(at_disk & ~parks, leader.at[f_new].set(j),
                               leader)
            flow = flow.at[j].set(jnp.where(at_disk, f_new, flow[j]))
        else:
            starts_now = (~is_q) | has_slot
            waits = ~starts_now
        ready = ready.at[j].set(jnp.where(starts_now, svc_next, INF_NS))
        enq_seq = enq_seq.at[j].set(jnp.where(waits, state.seq_ctr, BIG_SEQ))
        seq_ctr = state.seq_ctr + waits.astype(jnp.int32)
        busy_count = busy_count.at[k_next].add((is_q & starts_now).astype(jnp.int32))

        # ---- warmup bookkeeping.
        warm_now = (completed >= warmup) & (state.warm_completed < 0)
        warm_completed = jnp.where(warm_now, completed, state.warm_completed)
        warm_elapsed_us = jnp.where(warm_now, elapsed_us, state.warm_elapsed_us)
        warm_delayed = jnp.where(warm_now, delayed, state.warm_delayed)
        warm_branch_done = jnp.where(warm_now, branch_done,
                                     state.warm_branch_done)
        warm_branch_delayed = jnp.where(warm_now, branch_delayed,
                                        state.warm_branch_delayed)

        new_state = _SimState(
            key=key,
            ready_ns=ready,
            station=station.at[j].set(k_next),
            branch=branch.at[j].set(branch_j),
            pos=pos.at[j].set(pos_j),
            enq_seq=enq_seq,
            busy_count=busy_count,
            seq_ctr=seq_ctr,
            completed=completed,
            elapsed_us=elapsed_us,
            warm_completed=warm_completed,
            warm_elapsed_us=warm_elapsed_us,
            flow=flow,
            leader=leader,
            delayed=delayed,
            warm_delayed=warm_delayed,
            branch_done=branch_done,
            branch_delayed=branch_delayed,
            warm_branch_done=warm_branch_done,
            warm_branch_delayed=warm_branch_delayed,
        )
        return (new_state, events + 1,
                ((rings, scr) if trace_cap else tr), sk)

    state, events, tr, sk = jax.lax.while_loop(
        cond, body, (state, jnp.int32(0), tr0, sk0)
    )

    n_measured = state.completed - state.warm_completed
    t_measured = state.elapsed_us - state.warm_elapsed_us
    x = n_measured.astype(jnp.float32) / jnp.maximum(t_measured, 1e-6)
    delayed_frac = (
        (state.delayed - state.warm_delayed).astype(jnp.float32)
        / jnp.maximum(n_measured, 1).astype(jnp.float32)
    )
    out = (x, state.completed, events, delayed_frac,
           state.branch_done - state.warm_branch_done,
           state.branch_delayed - state.warm_branch_delayed,
           jnp.maximum(t_measured, 1e-6))
    if trace_cap:
        out = out + (tr[0],)
    if sketch_cap:
        out = out + (sk,)
    return out


class _TieredState(NamedTuple):
    """Closed-loop state extended with the cross-tier MSHR tables.

    A job can hold up to ``max_held`` outstanding-fetch entries at once
    (its L1 client table + a shard-local origin table) and can be parked
    on any one entry; fills cascade — releasing an entry completes every
    request parked on it as a delayed hit, which force-frees *their*
    held entries, waking their own followers (bounded by ``max_held``
    strictly-deeper-level waves, so the unroll is static)."""

    key: jax.Array
    ready_ns: jax.Array  # (N,) i32, INF when waiting or parked
    station: jax.Array  # (N,) i32
    branch: jax.Array  # (N,) i32
    pos: jax.Array  # (N,) i32
    enq_seq: jax.Array  # (N,) i32, BIG when not waiting
    busy_count: jax.Array  # (K,) i32
    seq_ctr: jax.Array  # i32
    completed: jax.Array  # i32
    elapsed_us: jax.Array  # f32
    warm_completed: jax.Array  # i32
    warm_elapsed_us: jax.Array  # f32
    flow_f: jax.Array  # (N,) i32 per-request hot-key flow, -1 until acquired
    held: jax.Array  # (N, max_held) i32 leader slot per level, -1 = none
    parked_on: jax.Array  # (N,) i32 slot the job is parked on, -1 = live
    parked_lvl: jax.Array  # (N,) i32 acq level it parked at, -1 = live
    leader: jax.Array  # (G*F,) i32 job leading each (group, flow), -1 idle
    delayed: jax.Array  # i32
    warm_delayed: jax.Array  # i32
    delayed_lvl: jax.Array  # (max_held+1,) i32, last entry = scatter pad
    warm_delayed_lvl: jax.Array  # (max_held+1,) i32
    branch_done: jax.Array  # (B,) i32
    branch_delayed: jax.Array  # (B,) i32
    warm_branch_done: jax.Array  # (B,) i32
    warm_branch_delayed: jax.Array  # (B,) i32


@partial(jax.jit,
         static_argnames=("n_requests", "warmup", "mpl", "max_events",
                          "n_flows", "flow_theta", "n_groups", "max_held",
                          "trace_cap", "sketch_cap", "window_us"))
def _simulate_tiered(spec: SimSpec, acq_group, acq_slot, rel_slot, seed,
                     n_requests: int, warmup: int, mpl: int,
                     max_events: int, n_flows: int,
                     flow_theta: float = 0.0, n_groups: int = 1,
                     max_held: int = 1, trace_cap: int = 0,
                     sketch_cap: int = 0, window_us: float = 0.0) -> tuple:
    """Tiered (hierarchy) twin of :func:`_simulate`.

    The ``disk_rank`` convention is replaced by explicit
    :class:`~repro.core.simspec.MshrSpec` tables: ``acq_*[b, i]`` marks
    the MSHR group a job acquires on ARRIVAL at visit ``(b, i)`` (or
    parks behind, if that group×flow entry already has a leader) and
    ``rel_slot[b, i]`` the held level it releases on COMPLETION of that
    visit.  One flow is sampled per request at its first acquire and
    reused at every deeper acquire (it is the same key that missed), so
    an L1 miss can coalesce at its client's table *or* — leading there —
    at the shard-local origin table.  Fills cascade: completing a fill
    wakes the requests parked on it as delayed hits; a woken job's own
    held entries are force-freed (its fills just landed too), waking
    their followers — at most ``max_held`` waves, because a job parked
    at acquire level ``l`` holds entries strictly shallower than ``l``.
    """
    N = mpl
    F = n_flows
    GF = n_groups * F
    L = spec.visits.shape[1]
    B = spec.branch_cum.shape[0]
    key = jax.random.PRNGKey(seed)
    if trace_cap or sketch_cap:
        # a branch is a miss route if it ever acquires an MSHR entry or
        # visits a disk-ranked station (the tiered networks use acq_*).
        vis_rank = spec.disk_rank[jnp.maximum(spec.visits, 0)]
        branch_has_disk = ((vis_rank >= 0) & (spec.visits >= 0)).any(axis=1)
        branch_is_miss = branch_has_disk | (acq_group >= 0).any(axis=1)

    def sample_branch(key):
        u = jax.random.uniform(key, ())
        return jnp.searchsorted(spec.branch_cum, u).astype(jnp.int32)

    key, bk, sk = jax.random.split(key, 3)
    branch0 = jax.vmap(sample_branch)(jax.random.split(bk, N))
    station0 = spec.visits[branch0, 0]
    svc0 = jax.vmap(lambda k, s: _sample_service_ns(k, spec, s))(
        jax.random.split(sk, N), station0
    )
    state = _TieredState(
        key=key,
        ready_ns=svc0,
        station=station0,
        branch=branch0,
        pos=jnp.zeros((N,), jnp.int32),
        enq_seq=jnp.full((N,), BIG_SEQ),
        busy_count=jnp.zeros(spec.is_queue.shape, jnp.int32),
        seq_ctr=jnp.int32(0),
        completed=jnp.int32(0),
        elapsed_us=jnp.float32(0.0),
        warm_completed=jnp.int32(-1),
        warm_elapsed_us=jnp.float32(0.0),
        flow_f=jnp.full((N,), -1, jnp.int32),
        held=jnp.full((N, max_held), -1, jnp.int32),
        parked_on=jnp.full((N,), -1, jnp.int32),
        parked_lvl=jnp.full((N,), -1, jnp.int32),
        leader=jnp.full((GF,), -1, jnp.int32),
        delayed=jnp.int32(0),
        warm_delayed=jnp.int32(0),
        delayed_lvl=jnp.zeros((max_held + 1,), jnp.int32),
        warm_delayed_lvl=jnp.zeros((max_held + 1,), jnp.int32),
        branch_done=jnp.zeros((B,), jnp.int32),
        branch_delayed=jnp.zeros((B,), jnp.int32),
        warm_branch_done=jnp.zeros((B,), jnp.int32),
        warm_branch_delayed=jnp.zeros((B,), jnp.int32),
    )
    tr0 = init_trace(trace_cap, N, L)
    sk0 = sketch_init(sketch_cap, B)

    def cond(carry):
        state, events, _tr, _sk = carry
        return (state.completed < n_requests) & (events < max_events)

    def body(carry):
        state, events, tr, sk = carry
        if trace_cap:
            rings, scr = tr
        (key, k_svc1, k_svc2, k_branch, k_flow, k_wake_b,
         k_wake_s) = jax.random.split(state.key, 7)

        j = jnp.argmin(state.ready_ns).astype(jnp.int32)
        t = state.ready_ns[j]
        finite = state.ready_ns < INF_NS
        ready = jnp.where(finite, state.ready_ns - t, INF_NS)
        elapsed_us = state.elapsed_us + t.astype(jnp.float32) * 1e-3
        if sketch_cap:
            sk, w_slot = stream_tick(sk, elapsed_us, window_us)

        k_cur = state.station[j]
        busy_count = state.busy_count
        enq_seq = state.enq_seq
        station = state.station
        branch = state.branch
        pos = state.pos
        flow_f = state.flow_f
        held = state.held
        parked_on = state.parked_on
        parked_lvl = state.parked_lvl
        leader = state.leader
        completed = state.completed
        delayed = state.delayed
        delayed_lvl = state.delayed_lvl
        branch_done = state.branch_done
        branch_delayed = state.branch_delayed

        # ---- fill: j completes visit (branch, pos); if this visit
        # releases a held level, the fill lands — wake every request
        # parked on that entry, cascading their own held entries.
        rel = rel_slot[branch[j], pos[j]]
        rel_entry = held[j, jnp.maximum(rel, 0)]
        valid0 = (rel >= 0) & (rel_entry >= 0)
        slot0 = jnp.where(valid0, rel_entry, GF)
        held = held.at[j, jnp.maximum(rel, 0)].set(
            jnp.where(rel >= 0, -1, rel_entry)
        )
        freed = jnp.zeros((GF + 1,), bool).at[slot0].set(True)
        freed = freed.at[GF].set(False)
        freed_all = freed
        woken = jnp.zeros((N,), bool)
        for _ in range(max_held):
            wave = (parked_on >= 0) & freed[jnp.maximum(parked_on, 0)] & ~woken
            nf = jnp.zeros((GF + 1,), bool)
            for lvl in range(max_held):
                sl = jnp.where(wave & (held[:, lvl] >= 0), held[:, lvl], GF)
                nf = nf.at[sl].set(True)
            nf = nf.at[GF].set(False)
            woken = woken | wave
            freed_all = freed_all | nf
            freed = nf
        leader = jnp.where(freed_all[:GF], -1, leader)
        held = jnp.where(woken[:, None], -1, held)

        # woken jobs complete as delayed hits under the branch they parked
        # on, split by the tier level of the entry they parked behind.
        wcount = woken.astype(jnp.int32)
        branch_done = branch_done.at[branch].add(wcount)
        branch_delayed = branch_delayed.at[branch].add(wcount)
        if sketch_cap:
            sk = stream_done_many(sk, w_slot, branch, woken)
        delayed_lvl = delayed_lvl.at[
            jnp.where(woken, jnp.maximum(parked_lvl, 0), max_held)
        ].add(wcount)
        if trace_cap:
            rows = jnp.where(woken, jnp.arange(N), N)
            leave_m = scr.leave_us.at[rows, pos].set(elapsed_us)
            parked_w = elapsed_us - scr.enter_us[jnp.arange(N), pos]
            rings = ring_write_many(
                rings, woken, state.completed, branch,
                jnp.full((N,), CLS_DELAYED, jnp.int32), pos + 1,
                jnp.where(woken, parked_w, 0.0), scr.enter_us, leave_m,
            )
            scr = TraceScratch(
                enter_us=scr.enter_us.at[rows, 0].set(elapsed_us),
                leave_us=leave_m,
            )
        wake_branch = jax.vmap(sample_branch)(jax.random.split(k_wake_b, N))
        wake_station = spec.visits[wake_branch, 0]
        wake_svc = jax.vmap(lambda k, s: _sample_service_ns(k, spec, s))(
            jax.random.split(k_wake_s, N), wake_station
        )
        ready = jnp.where(woken, wake_svc, ready)
        station = jnp.where(woken, wake_station, station)
        branch = jnp.where(woken, wake_branch, branch)
        pos = jnp.where(woken, 0, pos)
        n_woken = woken.sum().astype(jnp.int32)
        completed = completed + n_woken
        delayed = delayed + n_woken
        parked_on = jnp.where(woken, -1, parked_on)
        parked_lvl = jnp.where(woken, -1, parked_lvl)
        flow_f = jnp.where(woken, -1, flow_f)

        # ---- hand the server job j held (if any) to its FIFO successor.
        def release(args):
            ready, busy_count, enq_seq = args
            waiting = (station == k_cur) & (ready == INF_NS)
            waiting = waiting.at[j].set(False)
            seqs = jnp.where(waiting, enq_seq, BIG_SEQ)
            w = jnp.argmin(seqs).astype(jnp.int32)
            has_waiter = seqs[w] < BIG_SEQ
            svc = _sample_service_ns(k_svc1, spec, k_cur)
            ready = jnp.where(has_waiter, ready.at[w].set(svc), ready)
            enq_seq = jnp.where(has_waiter, enq_seq.at[w].set(BIG_SEQ), enq_seq)
            busy_count = busy_count.at[k_cur].add(
                jnp.where(has_waiter, 0, -1).astype(jnp.int32)
            )
            return ready, busy_count, enq_seq

        ready, busy_count, enq_seq = jax.lax.cond(
            spec.is_queue[k_cur], release, lambda a: a,
            (ready, busy_count, enq_seq),
        )

        # ---- advance job j (or complete + start a new request).
        nxt_pos = pos[j] + 1
        route_next = jnp.where(nxt_pos < L, spec.visits[branch[j], nxt_pos % L], -1)
        done = route_next < 0

        new_branch = sample_branch(k_branch)
        branch_done = branch_done.at[branch[j]].add(done.astype(jnp.int32))
        if sketch_cap:
            sk = stream_done(sk, w_slot, branch[j],
                             ~branch_is_miss[branch[j]], jnp.bool_(False),
                             done)
        branch_j = jnp.where(done, new_branch, branch[j])
        pos_j = jnp.where(done, 0, nxt_pos)
        k_next = jnp.where(done, spec.visits[new_branch, 0], route_next)
        if trace_cap:
            leave_m = scr.leave_us.at[j, pos[j]].set(elapsed_us)
            cls_j = jnp.where(branch_is_miss[branch[j]], CLS_MISS,
                              CLS_HIT).astype(jnp.int32)
            rings = ring_write_one(rings, done, completed, branch[j], cls_j,
                                   pos[j] + 1, jnp.float32(0.0),
                                   scr.enter_us[j], leave_m[j])
            scr = TraceScratch(
                enter_us=scr.enter_us.at[j, pos_j].set(elapsed_us),
                leave_us=leave_m,
            )
        completed = completed + done.astype(jnp.int32)

        # ---- place j at k_next, acquiring / parking on the MSHR tables.
        # Position 0 never acquires (MshrSpec.validate), so a fresh
        # request can't park before sampling its flow.
        acq_g = acq_group[branch_j, pos_j]
        acq_s = acq_slot[branch_j, pos_j]
        at_acq = acq_g >= 0
        f_req = jnp.where(flow_f[j] >= 0, flow_f[j],
                          _sample_flow(k_flow, n_flows, flow_theta))
        if sketch_cap:
            # the request's key enters the popularity stream once, at its
            # first (shallowest) MSHR acquire — the same flow is reused at
            # every deeper acquire.
            sk = stream_key(sk, f_req, at_acq & (flow_f[j] < 0))
        slot_new = jnp.maximum(acq_g, 0) * F + f_req
        parks = at_acq & (leader[slot_new] >= 0)
        leads = at_acq & ~parks
        leader = jnp.where(leads, leader.at[slot_new].set(j), leader)
        held = jnp.where(
            leads,
            held.at[j, jnp.maximum(acq_s, 0)].set(slot_new),
            held,
        )
        flow_f = flow_f.at[j].set(
            jnp.where(at_acq, f_req, jnp.where(done, -1, flow_f[j]))
        )
        parked_on = parked_on.at[j].set(jnp.where(parks, slot_new, -1))
        parked_lvl = parked_lvl.at[j].set(jnp.where(parks, acq_s, -1))

        svc_next = _sample_service_ns(k_svc2, spec, k_next)
        is_q = spec.is_queue[k_next]
        has_slot = busy_count[k_next] < spec.servers[k_next]
        starts_now = ((~is_q) | has_slot) & ~parks
        waits = is_q & ~has_slot & ~parks
        ready = ready.at[j].set(jnp.where(starts_now, svc_next, INF_NS))
        enq_seq = enq_seq.at[j].set(jnp.where(waits, state.seq_ctr, BIG_SEQ))
        seq_ctr = state.seq_ctr + waits.astype(jnp.int32)
        busy_count = busy_count.at[k_next].add((is_q & starts_now).astype(jnp.int32))

        # ---- warmup bookkeeping.
        warm_now = (completed >= warmup) & (state.warm_completed < 0)
        warm_completed = jnp.where(warm_now, completed, state.warm_completed)
        warm_elapsed_us = jnp.where(warm_now, elapsed_us, state.warm_elapsed_us)
        warm_delayed = jnp.where(warm_now, delayed, state.warm_delayed)
        warm_delayed_lvl = jnp.where(warm_now, delayed_lvl,
                                     state.warm_delayed_lvl)
        warm_branch_done = jnp.where(warm_now, branch_done,
                                     state.warm_branch_done)
        warm_branch_delayed = jnp.where(warm_now, branch_delayed,
                                        state.warm_branch_delayed)

        new_state = _TieredState(
            key=key,
            ready_ns=ready,
            station=station.at[j].set(k_next),
            branch=branch.at[j].set(branch_j),
            pos=pos.at[j].set(pos_j),
            enq_seq=enq_seq,
            busy_count=busy_count,
            seq_ctr=seq_ctr,
            completed=completed,
            elapsed_us=elapsed_us,
            warm_completed=warm_completed,
            warm_elapsed_us=warm_elapsed_us,
            flow_f=flow_f,
            held=held,
            parked_on=parked_on,
            parked_lvl=parked_lvl,
            leader=leader,
            delayed=delayed,
            warm_delayed=warm_delayed,
            delayed_lvl=delayed_lvl,
            warm_delayed_lvl=warm_delayed_lvl,
            branch_done=branch_done,
            branch_delayed=branch_delayed,
            warm_branch_done=warm_branch_done,
            warm_branch_delayed=warm_branch_delayed,
        )
        return (new_state, events + 1,
                ((rings, scr) if trace_cap else tr), sk)

    state, events, tr, sk = jax.lax.while_loop(
        cond, body, (state, jnp.int32(0), tr0, sk0)
    )

    n_measured = state.completed - state.warm_completed
    t_measured = state.elapsed_us - state.warm_elapsed_us
    x = n_measured.astype(jnp.float32) / jnp.maximum(t_measured, 1e-6)
    delayed_frac = (
        (state.delayed - state.warm_delayed).astype(jnp.float32)
        / jnp.maximum(n_measured, 1).astype(jnp.float32)
    )
    tier_delayed = (
        (state.delayed_lvl - state.warm_delayed_lvl)[:max_held]
        .astype(jnp.float32)
        / jnp.maximum(n_measured, 1).astype(jnp.float32)
    )
    out = (x, state.completed, events, delayed_frac,
           state.branch_done - state.warm_branch_done,
           state.branch_delayed - state.warm_branch_delayed,
           jnp.maximum(t_measured, 1e-6),
           tier_delayed)
    if trace_cap:
        out = out + (tr[0],)
    if sketch_cap:
        out = out + (sk,)
    return out


class _OpenState(NamedTuple):
    key: jax.Array
    ready_ns: jax.Array  # (N,) i32, INF when idle / waiting / parked
    station: jax.Array  # (N,) i32, -1 marks a free slot
    branch: jax.Array  # (N,) i32
    pos: jax.Array  # (N,) i32
    enq_seq: jax.Array  # (N,) i32, BIG when not waiting
    busy_count: jax.Array  # (K,) i32
    seq_ctr: jax.Array  # i32
    next_arrival_ns: jax.Array  # i32, rebased with the job clocks
    age_us: jax.Array  # (N,) f32 time the slot's job has been in system
    completed: jax.Array  # i32
    elapsed_us: jax.Array  # f32
    warm_completed: jax.Array  # i32
    warm_elapsed_us: jax.Array  # f32
    dropped: jax.Array  # i32 arrivals that found no free slot
    flow: jax.Array  # (N,) i32 MSHR flow, -1 otherwise
    leader: jax.Array  # (D*F,) i32, one flow group per disk station
    delayed: jax.Array  # i32
    warm_delayed: jax.Array  # i32
    soj_us: jax.Array  # (R,) f32 per-completion sojourn records
    cls: jax.Array  # (R,) i8 per-completion class records
    phase_on: jax.Array  # bool, ON/OFF burst phase (always ON when Poisson)
    phase_to_ns: jax.Array  # i32 time to the next phase toggle (INF: none)


@partial(jax.jit,
         static_argnames=("n_requests", "warmup", "max_in_system",
                          "max_events", "n_flows", "flow_theta", "n_disks",
                          "burst", "trace_cap", "sketch_cap", "window_us"))
def _simulate_open(spec: SimSpec, seed, arrival_mean_ns, n_requests: int,
                   warmup: int, max_in_system: int, max_events: int,
                   n_flows: int = 0, flow_theta: float = 0.0,
                   n_disks: int = 1, burst=None, trace_cap: int = 0,
                   sketch_cap: int = 0, window_us: float = 0.0) -> tuple:
    """Arrival-driven (open-loop) twin of :func:`_simulate`.

    One extra event type — a Poisson arrival — competes with service
    completions in the same min-reduction; a completing request *leaves*
    (its slot frees) instead of restarting, and its sojourn + class land in
    a fixed record buffer indexed by completion order.  MSHR semantics
    match the closed kernel: parked delayed hits complete at fill time,
    with the parked interval included in their recorded sojourn.

    ``burst=(duty, mean_on_us)`` replaces the Poisson process with an
    ON-OFF MMPP of the same *mean* rate: exponential ON periods of mean
    ``mean_on_us`` during which arrivals are Poisson at ``rate/duty``,
    alternating with exponential OFF periods of mean
    ``mean_on_us*(1-duty)/duty`` with no arrivals.  Phase toggles are a
    third event type in the same min-reduction.  ``None`` keeps the
    original Poisson program.

    Sojourns are accumulated per slot as a sum of event increments (like
    the global elapsed clock) rather than as differences of absolute f32
    timestamps — the increments are O(service time), so the error stays
    ~1e-4 *relative* to the sojourn regardless of how long the run gets.
    """
    N = max_in_system
    F = max(n_flows, 1)
    R = n_requests + N  # a fill can complete up to N-1 parked jobs past n_requests
    L = spec.visits.shape[1]
    key = jax.random.PRNGKey(seed)
    vis_rank = spec.disk_rank[jnp.maximum(spec.visits, 0)]
    branch_has_disk = ((vis_rank >= 0) & (spec.visits >= 0)).any(axis=1)
    if burst is not None:
        duty, mean_on_us = float(burst[0]), float(burst[1])
        if not 0.0 < duty <= 1.0 or mean_on_us <= 0.0:
            raise ValueError(f"burst=(duty, mean_on_us) needs 0<duty<=1 and "
                             f"mean_on_us>0, got {burst}")
        mean_on_ns = mean_on_us * 1e3
        mean_off_ns = mean_on_ns * (1.0 - duty) / duty

    def sample_branch(key):
        u = jax.random.uniform(key, ())
        return jnp.searchsorted(spec.branch_cum, u).astype(jnp.int32)

    def exp_ns(key, mean_ns):
        u = jax.random.uniform(key, (), minval=1e-7, maxval=1.0 - 1e-7)
        return jnp.maximum(jnp.round(-jnp.log(u) * mean_ns), 1.0
                           ).astype(jnp.int32)

    def interarrival(key):
        # during ON periods the MMPP arrives at rate/duty, i.e. the mean
        # interarrival shrinks by duty; the OFF gaps restore the mean rate.
        mean = arrival_mean_ns * duty if burst is not None else arrival_mean_ns
        return exp_ns(key, mean)

    key, k0 = jax.random.split(key)
    if burst is not None:
        key, kp = jax.random.split(key)
        phase_to0 = exp_ns(kp, mean_on_ns)
    else:
        phase_to0 = jnp.int32(INF_NS)
    state = _OpenState(
        key=key,
        ready_ns=jnp.full((N,), INF_NS),
        station=jnp.full((N,), -1, jnp.int32),
        branch=jnp.zeros((N,), jnp.int32),
        pos=jnp.zeros((N,), jnp.int32),
        enq_seq=jnp.full((N,), BIG_SEQ),
        busy_count=jnp.zeros(spec.is_queue.shape, jnp.int32),
        seq_ctr=jnp.int32(0),
        next_arrival_ns=interarrival(k0),
        age_us=jnp.zeros((N,), jnp.float32),
        completed=jnp.int32(0),
        elapsed_us=jnp.float32(0.0),
        warm_completed=jnp.int32(-1),
        warm_elapsed_us=jnp.float32(0.0),
        dropped=jnp.int32(0),
        flow=jnp.full((N,), -1, jnp.int32),
        leader=jnp.full((max(n_disks, 1) * F,), -1, jnp.int32),
        delayed=jnp.int32(0),
        warm_delayed=jnp.int32(0),
        soj_us=jnp.zeros((R,), jnp.float32),
        cls=jnp.zeros((R,), jnp.int8),
        phase_on=jnp.bool_(True),
        phase_to_ns=phase_to0,
    )
    tr0 = init_trace(trace_cap, N, L)
    sk0 = sketch_init(sketch_cap, spec.visits.shape[0])

    def cond(carry):
        state, events, _tr, _sk = carry
        return (state.completed < n_requests) & (events < max_events)

    def body(carry):
        state, events, tr, sk = carry
        n_keys = 7 if n_flows else 6
        if burst is not None:
            n_keys += 2
        keys = jax.random.split(state.key, n_keys)
        key, k_svc1, k_svc2, k_branch, k_svc0, k_ia = keys[:6]
        k_flow = keys[6] if n_flows else None
        k_tog_a, k_tog_p = (keys[-2], keys[-1]) if burst is not None else (None, None)

        j = jnp.argmin(state.ready_ns).astype(jnp.int32)
        t_dep = state.ready_ns[j]
        if burst is not None:
            # arrivals win ties against departures (as before) and toggles
            is_arrival = state.next_arrival_ns <= jnp.minimum(
                t_dep, state.phase_to_ns)
            is_toggle = (~is_arrival) & (state.phase_to_ns <= t_dep)
            t = jnp.minimum(jnp.minimum(state.next_arrival_ns, t_dep),
                            state.phase_to_ns)
            next_arrival = jnp.where(state.next_arrival_ns < INF_NS,
                                     state.next_arrival_ns - t, INF_NS)
            phase_to = state.phase_to_ns - t
        else:
            is_arrival = state.next_arrival_ns <= t_dep
            t = jnp.minimum(state.next_arrival_ns, t_dep)
            next_arrival = state.next_arrival_ns - t
            phase_to = state.phase_to_ns
        finite = state.ready_ns < INF_NS
        ready = jnp.where(finite, state.ready_ns - t, INF_NS)
        dt_us = t.astype(jnp.float32) * 1e-3
        elapsed_us = state.elapsed_us + dt_us
        if sketch_cap:
            sk, w_slot = stream_tick(sk, elapsed_us, window_us)
        state = state._replace(
            key=key, ready_ns=ready,
            next_arrival_ns=next_arrival,
            phase_to_ns=phase_to,
            elapsed_us=elapsed_us,
            # jobs in system (incl. waiting and MSHR-parked) age by dt
            age_us=jnp.where(state.station >= 0, state.age_us + dt_us,
                             state.age_us),
        )

        def toggle(args):
            # ON -> OFF: arrivals pause; OFF -> ON: fresh arrival clock.
            s, tr, sk = args
            going_on = ~s.phase_on
            return s._replace(
                phase_on=going_on,
                next_arrival_ns=jnp.where(going_on, interarrival(k_tog_a),
                                          jnp.int32(INF_NS)),
                phase_to_ns=jnp.where(going_on, exp_ns(k_tog_p, mean_on_ns),
                                      exp_ns(k_tog_p, mean_off_ns)),
            ), tr, sk

        def arrive(args):
            s, tr, sk = args
            if sketch_cap:
                # every offered arrival counts, admitted or dropped — the
                # windowed arrival rate estimates the *offered* load.
                sk = stream_arrival(sk, w_slot, jnp.bool_(True))
            free = s.station < 0
            admit = free.any()
            slot = jnp.argmax(free).astype(jnp.int32)
            b = sample_branch(k_branch)
            st0 = spec.visits[b, 0]  # think station by network validation
            svc = _sample_service_ns(k_svc0, spec, st0)
            if trace_cap:
                rings, scr = tr
                # the admitted request enters its first visit now
                row = jnp.where(admit, slot, N)
                scr = TraceScratch(
                    enter_us=scr.enter_us.at[row, 0].set(s.elapsed_us),
                    leave_us=scr.leave_us,
                )
                tr = (rings, scr)
            return s._replace(
                ready_ns=jnp.where(admit, s.ready_ns.at[slot].set(svc),
                                   s.ready_ns),
                station=jnp.where(admit, s.station.at[slot].set(st0),
                                  s.station),
                branch=jnp.where(admit, s.branch.at[slot].set(b), s.branch),
                pos=jnp.where(admit, s.pos.at[slot].set(0), s.pos),
                age_us=jnp.where(admit, s.age_us.at[slot].set(0.0),
                                 s.age_us),
                dropped=s.dropped + (~admit).astype(jnp.int32),
                next_arrival_ns=interarrival(k_ia),
            ), tr, sk

        def depart(args):
            s, tr, sk = args
            if trace_cap:
                rings, scr = tr
            ready, station, branch = s.ready_ns, s.station, s.branch
            pos, enq_seq, busy_count = s.pos, s.enq_seq, s.busy_count
            flow, leader = s.flow, s.leader
            completed, delayed = s.completed, s.delayed
            soj_us, cls = s.soj_us, s.cls
            k_cur = station[j]
            now_soj = s.age_us  # (N,) valid for live jobs

            # ---- MSHR fill: parked delayed hits complete at fill time.
            if n_flows:
                f_cur = flow[j]
                fill = (spec.disk_rank[k_cur] >= 0) & (f_cur >= 0)
                woken = (flow == f_cur) & fill
                woken = woken.at[j].set(False)
                widx = jnp.where(woken, completed + jnp.cumsum(woken) - 1, R)
                soj_us = soj_us.at[widx].set(now_soj)  # OOB rows dropped
                cls = cls.at[widx].set(jnp.int8(CLS_DELAYED))
                if trace_cap:
                    rows = jnp.where(woken, jnp.arange(N), N)
                    leave_m = scr.leave_us.at[rows, pos].set(s.elapsed_us)
                    parked_w = (s.elapsed_us
                                - scr.enter_us[jnp.arange(N), pos])
                    rings = ring_write_many(
                        rings, woken, completed, branch,
                        jnp.full((N,), CLS_DELAYED, jnp.int32), pos + 1,
                        jnp.where(woken, parked_w, 0.0), scr.enter_us,
                        leave_m,
                    )
                    scr = TraceScratch(enter_us=scr.enter_us,
                                       leave_us=leave_m)
                n_woken = woken.sum().astype(jnp.int32)
                completed = completed + n_woken
                delayed = delayed + n_woken
                if sketch_cap:
                    sk = stream_done_many(sk, w_slot, branch, woken)
                ready = jnp.where(woken, INF_NS, ready)
                station = jnp.where(woken, -1, station)
                leader = jnp.where(
                    fill, leader.at[jnp.maximum(f_cur, 0)].set(-1), leader
                )
                flow = jnp.where(
                    woken | ((jnp.arange(N) == j) & fill), -1, flow
                )

            # ---- hand the server job j held (if any) to its FIFO successor.
            def release(args):
                ready, busy_count, enq_seq = args
                waiting = (station == k_cur) & (ready == INF_NS)
                waiting = waiting.at[j].set(False)
                seqs = jnp.where(waiting, enq_seq, BIG_SEQ)
                w = jnp.argmin(seqs).astype(jnp.int32)
                has_waiter = seqs[w] < BIG_SEQ
                svc = _sample_service_ns(k_svc1, spec, k_cur)
                ready = jnp.where(has_waiter, ready.at[w].set(svc), ready)
                enq_seq = jnp.where(has_waiter, enq_seq.at[w].set(BIG_SEQ),
                                    enq_seq)
                busy_count = busy_count.at[k_cur].add(
                    jnp.where(has_waiter, 0, -1).astype(jnp.int32)
                )
                return ready, busy_count, enq_seq

            ready, busy_count, enq_seq = jax.lax.cond(
                spec.is_queue[k_cur], release, lambda a: a,
                (ready, busy_count, enq_seq),
            )

            # ---- advance along the route, or record the finished request.
            nxt_pos = pos[j] + 1
            route_next = jnp.where(
                nxt_pos < L, spec.visits[branch[j], nxt_pos % L], -1
            )
            done = route_next < 0
            jdx = jnp.where(done, completed, R)
            soj_us = soj_us.at[jdx].set(now_soj[j])
            cls = cls.at[jdx].set(
                jnp.where(branch_has_disk[branch[j]], CLS_MISS,
                          CLS_HIT).astype(jnp.int8)
            )
            if sketch_cap:
                sk = stream_done(sk, w_slot, branch[j],
                                 ~branch_has_disk[branch[j]],
                                 jnp.bool_(False), done)
            if trace_cap:
                leave_m = scr.leave_us.at[j, pos[j]].set(s.elapsed_us)
                cls_j = jnp.where(branch_has_disk[branch[j]], CLS_MISS,
                                  CLS_HIT).astype(jnp.int32)
                rings = ring_write_one(rings, done, completed, branch[j],
                                       cls_j, pos[j] + 1, jnp.float32(0.0),
                                       scr.enter_us[j], leave_m[j])
                # if j advances, it enters its next visit now
                row = jnp.where(done, N, j)
                scr = TraceScratch(
                    enter_us=scr.enter_us.at[
                        row, jnp.minimum(nxt_pos, L - 1)
                    ].set(s.elapsed_us),
                    leave_us=leave_m,
                )
            completed = completed + done.astype(jnp.int32)

            # ---- place j at its next station (no-op masks when done).
            k_next = jnp.maximum(route_next, 0)
            svc_next = _sample_service_ns(k_svc2, spec, k_next)
            is_q = spec.is_queue[k_next] & ~done
            has_slot = busy_count[k_next] < spec.servers[k_next]
            if n_flows:
                rank_next = spec.disk_rank[jnp.maximum(route_next, 0)]
                at_disk = (rank_next >= 0) & (route_next >= 0) & ~done
                f_new = (jnp.maximum(rank_next, 0) * F
                         + _sample_flow(k_flow, n_flows, flow_theta))
                if sketch_cap:
                    sk = stream_key(sk, f_new, at_disk)
                parks = at_disk & (leader[f_new] >= 0)
                starts_now = ((~is_q) | has_slot) & ~parks & ~done
                waits = is_q & ~has_slot & ~parks
                leader = jnp.where(at_disk & ~parks,
                                   leader.at[f_new].set(j), leader)
                flow = flow.at[j].set(jnp.where(at_disk, f_new, flow[j]))
            else:
                starts_now = ((~is_q) | has_slot) & ~done
                waits = is_q & ~has_slot
            ready = ready.at[j].set(jnp.where(starts_now, svc_next, INF_NS))
            enq_seq = enq_seq.at[j].set(
                jnp.where(waits, s.seq_ctr, BIG_SEQ)
            )
            seq_ctr = s.seq_ctr + waits.astype(jnp.int32)
            busy_count = busy_count.at[k_next].add(
                (is_q & starts_now).astype(jnp.int32)
            )
            station = station.at[j].set(jnp.where(done, -1, route_next))
            pos = pos.at[j].set(jnp.where(done, 0, nxt_pos))

            warm_now = (completed >= warmup) & (s.warm_completed < 0)
            return s._replace(
                ready_ns=ready, station=station, branch=branch, pos=pos,
                enq_seq=enq_seq, busy_count=busy_count, seq_ctr=seq_ctr,
                completed=completed,
                warm_completed=jnp.where(warm_now, completed,
                                         s.warm_completed),
                warm_elapsed_us=jnp.where(warm_now, s.elapsed_us,
                                          s.warm_elapsed_us),
                flow=flow, leader=leader, delayed=delayed,
                warm_delayed=jnp.where(warm_now, delayed, s.warm_delayed),
                soj_us=soj_us, cls=cls,
            ), ((rings, scr) if trace_cap else tr), sk

        if burst is not None:
            new_state, tr, sk = jax.lax.cond(
                is_arrival, arrive,
                lambda a: jax.lax.cond(is_toggle, toggle, depart, a),
                (state, tr, sk),
            )
        else:
            new_state, tr, sk = jax.lax.cond(is_arrival, arrive, depart,
                                             (state, tr, sk))
        return new_state, events + 1, tr, sk

    state, events, tr, sk = jax.lax.while_loop(
        cond, body, (state, jnp.int32(0), tr0, sk0)
    )

    n_measured = state.completed - state.warm_completed
    t_measured = state.elapsed_us - state.warm_elapsed_us
    x = n_measured.astype(jnp.float32) / jnp.maximum(t_measured, 1e-6)
    delayed_frac = (
        (state.delayed - state.warm_delayed).astype(jnp.float32)
        / jnp.maximum(n_measured, 1).astype(jnp.float32)
    )
    out = (x, state.completed, events, delayed_frac, state.dropped,
           state.soj_us, state.cls)
    if trace_cap:
        out = out + (tr[0],)
    if sketch_cap:
        out = out + (sk,)
    return out


@dataclasses.dataclass(frozen=True)
class OpenSimResult:
    """Open-loop (arrival-driven) simulation result — the latency prong.

    All sojourn statistics are computed over post-warmup completions;
    percentiles pool the per-request records of every seed, while
    ``sojourn_ci95`` is the seed-to-seed CI of the mean.  ``class_*``
    columns are indexed [true miss, true hit, delayed hit] (the
    :data:`CLS_MISS`/:data:`CLS_HIT`/:data:`CLS_DELAYED` order, matching
    the prong-C classifier); ``class_sojourn`` is NaN for an empty class.
    """

    p_hit: np.ndarray
    arrival_rate: np.ndarray  # (P,) offered Poisson rate, requests/µs
    throughput: np.ndarray  # measured completion rate (== arrival_rate
    ci95: np.ndarray        # when stable and drop-free)
    sojourn_mean: np.ndarray  # (P,) µs
    sojourn_ci95: np.ndarray
    sojourn_p50: np.ndarray
    sojourn_p99: np.ndarray
    class_frac: np.ndarray  # (P, 3)
    class_sojourn: np.ndarray  # (P, 3) mean µs per class
    delayed_frac: np.ndarray
    drop_frac: np.ndarray  # arrivals refused for want of a job slot
    # lanes that exhausted the event budget before completing n_requests
    # (deep overload): their statistics cover fewer completions than asked.
    truncated: np.ndarray
    n_requests: int
    # decoded per-lane trace records ([seed][p] TraceRecords), None unless
    # simulate_network(trace=K) requested in-kernel trace rings.
    traces: list | None = None
    # decoded per-lane streaming estimators ([seed][p] SketchEstimates),
    # None unless simulate_network(sketch_cap=K) requested them.
    sketches: list | None = None


def simulate_network(
    net: ClosedNetwork,
    p_hits,
    n_requests: int = 40_000,
    seeds=(0, 1, 2),
    warmup_frac: float = 0.25,
    coalesce_flows: int = 0,
    coalesce_theta: float = 0.0,
    arrival_rate=None,
    max_in_system: int = 128,
    burst=None,
    backend: str = "jax",
    tiers=None,
    trace: int = 0,
    sketch_cap: int = 0,
    window_us: float = 0.0,
):
    """Simulate ``net`` over a grid of hit ratios.

    The full (p_hit × seed) grid dispatches as ONE vmapped, jitted program:
    the per-p_hit spec arrays are tiled across seeds so every (p, seed) cell
    is an independent lane of the same kernel.

    ``coalesce_flows > 0`` turns on miss coalescing (delayed hits): a job
    arriving at the ``disk`` station samples one of ``coalesce_flows`` hot
    keys; if a fetch for that key is already outstanding the job parks on
    an MSHR-style table (issuing no duplicate I/O and holding no bounded
    ``disk_servers`` slot) and completes when the fill lands.  This is the
    event-level counterpart of
    :func:`repro.core.queueing.coalesced_network`; 0 leaves the compiled
    program bit-identical to the non-coalesced simulator.
    ``coalesce_theta > 0`` samples the hot-key flow Zipf(theta)-weighted
    instead of uniformly (0 keeps the exact original RNG stream).

    ``arrival_rate`` switches to the **open-loop** latency mode: Poisson
    arrivals at that rate (a scalar, or one rate per ``p_hits`` entry —
    e.g. a fixed fraction of the stability boundary) instead of the closed
    MPL loop, returning an :class:`OpenSimResult` with per-request sojourn
    statistics (mean / p50 / p99, per-class breakdown including the time
    delayed hits spend parked on the MSHR table).  ``max_in_system`` sizes
    the job-slot pool; arrivals beyond it are counted in ``drop_frac``
    (keep it 0 — size the pool generously relative to lambda·R).

    ``burst=(duty, mean_on_us)`` (open mode only) makes the arrivals an
    ON-OFF MMPP at the same mean rate: exponential ON periods of mean
    ``mean_on_us`` µs during which arrivals run at ``arrival_rate/duty``,
    separated by arrival-free OFF periods sized to restore the mean.
    ``None`` keeps Poisson arrivals (the exact original program).

    ``tiers`` (an :class:`repro.core.simspec.MshrSpec`, built by
    :func:`repro.hierarchy.model.compose_tiers`) switches the MSHR
    machinery to **cross-tier** leader tables: acquire/park/release
    points come from the per-(branch, position) annotation arrays
    instead of the ``disk_rank`` convention — an L1 miss can park behind
    its client's in-flight L2 fetch *or*, leading there, behind a
    shard-local in-flight origin fetch, and fills cascade across tiers.
    Requires ``coalesce_flows > 0`` to do anything (it sizes each
    table's flow group); with 0 the annotations are ignored and the
    plain closed kernel runs (the no-coalescing reference at identical
    RNG).  Closed loop only.  The returned :class:`SimResult` carries
    ``delayed_tier_frac`` — delayed hits split by the tier level parked
    at (column 0: client-local L1 table; later: shard-local origin
    tables).

    ``trace > 0`` fills a fixed-capacity in-kernel ring buffer of
    per-request trace records (:mod:`repro.obs.trace`) per lane — ``trace``
    is the ring capacity (a static shape; on overflow the **last** ``trace``
    records survive and the drop count is reported).  The decoded
    ``[seed][p]`` :class:`~repro.obs.trace.TraceRecords` land on the
    result's ``traces`` field.  ``trace=0`` (default) compiles no tracing
    at all and is bit-identical to the untraced simulator; tracing draws
    no RNG, so enabling it does not perturb the simulated system either.

    ``sketch_cap > 0`` threads the in-kernel streaming estimators
    (:mod:`repro.obs.streaming`) through every lane: tumbling-window
    hit/arrival/σ counters, EWMA smoothers, and a count-min + SpaceSaving
    key-popularity sketch sized for ``sketch_cap`` tracked keys, sampled
    every ``window_us`` µs of simulated time (required > 0).  The decoded
    ``[seed][p]`` :class:`~repro.obs.streaming.SketchEstimates` land on the
    result's ``sketches`` field.  Like tracing, ``sketch_cap=0`` (default)
    compiles no estimator state at all and is bit-identical to current
    behaviour, and the estimators draw no RNG.

    ``backend="pallas"`` routes the closed-loop grid to the accelerator
    event-sim kernel (:func:`repro.kernels.event_sim.simulate_grid_pallas`)
    — the whole (p_hit x seed) grid as one pallas dispatch with per-lane
    state in kernel scratch.  Its counter-based RNG draws a different (but
    statistically matched) stream than the threefry engine, so results
    agree statistically, not bit-for-bit; the coalescing / open-loop /
    burst extensions stay on the ``"jax"`` backend.
    """
    if backend not in ("jax", "pallas"):
        raise ValueError(f"unknown backend {backend!r} (want 'jax' or "
                         "'pallas')")
    if sketch_cap and window_us <= 0.0:
        raise ValueError("sketch_cap > 0 requires window_us > 0 (the "
                         "tumbling-window width in simulated µs)")
    if backend == "pallas":
        if (coalesce_flows or arrival_rate is not None or burst is not None
                or tiers is not None):
            raise ValueError(
                "backend='pallas' runs the plain closed loop only — "
                "coalescing, tiered MSHR tables, open-loop arrivals and "
                "bursts need backend='jax'")
        if sketch_cap:
            raise ValueError(
                "backend='pallas' does not thread the streaming sketch "
                "estimators — use backend='jax' for sketch_cap > 0")
        from repro.kernels.event_sim import simulate_grid_pallas  # lazy

        return simulate_grid_pallas(net, p_hits, n_requests=n_requests,
                                    seeds=seeds, warmup_frac=warmup_frac,
                                    trace=trace)
    p_hits = np.atleast_1d(np.asarray(p_hits, dtype=np.float64))
    specs = [compile_network(net, float(p)) for p in p_hits]
    spec = stack_specs(specs)
    n_disks = int(max(1, int(np.asarray(specs[0].disk_rank).max()) + 1))
    warmup = int(n_requests * warmup_frac)
    # one event per station visit; bound with headroom
    max_events = int(n_requests * (spec.visits.shape[-1] + 2) * 3)

    P, S = len(p_hits), len(seeds)

    def tile(arrays):
        # strip the static mpl field for vmap; tile (P, ...) -> (S*P, ...)
        return tuple(
            jnp.concatenate([a] * S, axis=0) if S > 1 else a for a in arrays
        )

    spec_arrays = tile(spec[:-1])
    seed_v = jnp.concatenate(
        [jnp.full((P,), s, jnp.int32) * 1000 + jnp.arange(P, dtype=jnp.int32)
         for s in seeds]
    )

    if arrival_rate is None:
        if burst is not None:
            raise ValueError("burst arrivals require arrival_rate "
                             "(open-loop mode)")
        if tiers is not None and coalesce_flows:
            tiers.validate(np.asarray(specs[0].visits))
            acq_g = jnp.asarray(np.asarray(tiers.acq_group, np.int32))
            acq_s = jnp.asarray(np.asarray(tiers.acq_slot, np.int32))
            rel_s = jnp.asarray(np.asarray(tiers.rel_slot, np.int32))
            runner = jax.vmap(
                lambda sp, seed: _simulate_tiered(
                    SimSpec(*sp, mpl=net.mpl), acq_g, acq_s, rel_s, seed,
                    n_requests=n_requests, warmup=warmup, mpl=net.mpl,
                    max_events=max_events, n_flows=coalesce_flows,
                    flow_theta=coalesce_theta,
                    n_groups=int(tiers.n_groups),
                    max_held=int(tiers.max_held),
                    trace_cap=trace,
                    sketch_cap=sketch_cap, window_us=float(window_us),
                ),
                in_axes=(0, 0),
            )
            tiered = True
        else:
            runner = jax.vmap(
                lambda sp, seed: _simulate(
                    SimSpec(*sp, mpl=net.mpl), seed, n_requests=n_requests,
                    warmup=warmup, mpl=net.mpl, max_events=max_events,
                    n_flows=coalesce_flows, flow_theta=coalesce_theta,
                    n_disks=n_disks, trace_cap=trace,
                    sketch_cap=sketch_cap, window_us=float(window_us),
                ),
                in_axes=(0, 0),
            )
            tiered = False
        out = runner(spec_arrays, seed_v)
        xs = np.asarray(out[0]).reshape(S, P)
        dl = np.asarray(out[3]).reshape(S, P)
        t_meas = np.asarray(out[6]).reshape(S, P, 1)
        bx = np.asarray(out[4]).reshape(S, P, -1) / t_meas
        bd = np.asarray(out[5]).reshape(S, P, -1) / t_meas
        tier_dl = (np.asarray(out[7]).reshape(S, P, -1).mean(axis=0)
                   if tiered else None)
        base = 8 if tiered else 7
        traces = (decode_trace_grid(out[base], specs[0].visits, S, P)
                  if trace else None)
        sketches = (decode_sketch_grid(out[base + (1 if trace else 0)],
                                       S, P, float(window_us))
                    if sketch_cap else None)
        mean = xs.mean(axis=0)
        ci = 1.96 * xs.std(axis=0, ddof=1) / math.sqrt(len(seeds)) if len(seeds) > 1 else np.zeros_like(mean)
        return SimResult(p_hit=p_hits, throughput=mean, ci95=ci,
                         n_requests=n_requests, delayed_frac=dl.mean(axis=0),
                         branch_throughput=bx.mean(axis=0),
                         branch_delayed=bd.mean(axis=0),
                         delayed_tier_frac=tier_dl,
                         traces=traces,
                         sketches=sketches)

    if tiers is not None:
        raise ValueError("tiered MSHR coalescing runs the closed loop only "
                         "(no arrival_rate/burst)")
    lam = np.broadcast_to(
        np.asarray(arrival_rate, dtype=np.float64), (P,)
    ).copy()
    if np.any(lam <= 0.0):
        raise ValueError("arrival_rate must be > 0")
    # arrivals add ~one event per admitted request on top of the visits
    max_events = int(n_requests * (spec.visits.shape[-1] + 3) * 3)
    mean_ns = jnp.asarray(
        np.concatenate([1e3 / lam] * S), jnp.float32
    ) if S > 1 else jnp.asarray(1e3 / lam, jnp.float32)
    runner = jax.vmap(
        lambda sp, seed, m: _simulate_open(
            SimSpec(*sp, mpl=net.mpl), seed, m, n_requests=n_requests,
            warmup=warmup, max_in_system=max_in_system,
            max_events=max_events, n_flows=coalesce_flows,
            flow_theta=coalesce_theta, n_disks=n_disks,
            burst=tuple(burst) if burst is not None else None,
            trace_cap=trace,
            sketch_cap=sketch_cap, window_us=float(window_us),
        ),
        in_axes=(0, 0, 0),
    )
    out = runner(spec_arrays, seed_v, mean_ns)
    x, completed, _events, delayed, dropped, soj, cls = out[:7]
    traces = (decode_trace_grid(out[7], specs[0].visits, S, P)
              if trace else None)
    sketches = (decode_sketch_grid(out[7 + (1 if trace else 0)],
                                   S, P, float(window_us))
                if sketch_cap else None)
    xs = np.asarray(x).reshape(S, P)
    comp = np.asarray(completed).reshape(S, P)
    dl = np.asarray(delayed).reshape(S, P)
    drop = np.asarray(dropped).reshape(S, P)
    soj = np.asarray(soj).reshape(S, P, -1)
    cls = np.asarray(cls).reshape(S, P, -1)

    mean = np.empty(P)
    m_ci = np.empty(P)
    p50 = np.empty(P)
    p99 = np.empty(P)
    cfrac = np.zeros((P, 3))
    csoj = np.full((P, 3), np.nan)
    for i in range(P):
        pooled = []
        per_seed_mean = []
        for s in range(S):
            rec = soj[s, i, warmup:comp[s, i]]
            pooled.append(rec)
            per_seed_mean.append(rec.mean() if rec.size else np.nan)
        rec = np.concatenate(pooled)
        all_cls = np.concatenate(
            [cls[s, i, warmup:comp[s, i]] for s in range(S)]
        )
        mean[i] = rec.mean() if rec.size else np.nan
        p50[i] = np.percentile(rec, 50) if rec.size else np.nan
        p99[i] = np.percentile(rec, 99) if rec.size else np.nan
        m_ci[i] = (
            1.96 * np.nanstd(per_seed_mean, ddof=1) / math.sqrt(S)
            if S > 1 else 0.0
        )
        for c in range(3):
            sel = all_cls == c
            if rec.size:
                cfrac[i, c] = sel.mean()
            if sel.any():
                csoj[i, c] = rec[sel].mean()

    ci = 1.96 * xs.std(axis=0, ddof=1) / math.sqrt(S) if S > 1 else np.zeros(P)
    total_arrivals = comp.sum(axis=0) + drop.sum(axis=0)
    truncated = (comp < n_requests).any(axis=0)
    if truncated.any():
        import warnings

        warnings.warn(
            "open-loop simulation exhausted its event budget before "
            f"completing n_requests at p_hit={p_hits[truncated]} "
            "(offered rate far past the stability boundary?); statistics "
            "cover fewer completions than requested", RuntimeWarning,
            stacklevel=2)
    return OpenSimResult(
        p_hit=p_hits, arrival_rate=lam, throughput=xs.mean(axis=0), ci95=ci,
        sojourn_mean=mean, sojourn_ci95=m_ci, sojourn_p50=p50,
        sojourn_p99=p99, class_frac=cfrac, class_sojourn=csoj,
        delayed_frac=dl.mean(axis=0),
        drop_frac=drop.sum(axis=0) / np.maximum(total_arrivals, 1),
        truncated=truncated,
        n_requests=n_requests,
        traces=traces,
        sketches=sketches,
    )
