"""Event-level cluster simulation: prong B lifted to N shards.

Two differential twins:

* :func:`simulate_cluster` — the composed cluster network through the
  existing JAX machinery: one ``vmap``-ed, jitted dispatch over the
  (global-p × seed) grid, with every shard's station set, disk, and —
  when coalescing is on — its own MSHR flow group living inside the one
  compiled program (``sK:disk`` stations each own a slice of the leader
  table, so delayed hits never coalesce across shards).  Per-branch
  completion counters fold back into per-shard throughput / hit-ratio /
  delayed-hit breakdowns.
* :func:`simulate_cluster_py` — an independent heapq oracle that does
  what a real router does: every request draws a *key* from the workload
  popularity, hashes it through the ring's assignment to pick its shard,
  and then walks that shard's station copies.  Per-shard traffic shares
  are never configured — they *emerge* from the key stream — which is
  what makes the oracle a genuine check of the JAX side's
  weight-compiled branch probabilities.

Both run the same closed loop (``mpl`` clients that immediately start a
new request on completion); open-loop cluster runs go straight through
``simulate_network(model.network, arrival_rate=...)``.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
import random

import numpy as np

from repro.cluster.model import ClusterModel
from repro.core.py_sim import _flow_sampler
from repro.core.simulator import compile_network, simulate_network

__all__ = ["ClusterSimResult", "simulate_cluster", "simulate_cluster_py"]


@dataclasses.dataclass(frozen=True)
class ClusterSimResult:
    """Cluster-level and per-shard statistics over the global-p grid.

    ``shard_hit_ratio`` counts delayed hits as misses (they ride miss
    branches on both twins), matching the policy-level convention.
    Shards with no measured completions report NaN ratios.
    """

    p_hit: np.ndarray  # (P,) global hit-ratio grid
    throughput: np.ndarray  # (P,) cluster completions / µs
    ci95: np.ndarray  # (P,)
    shard_throughput: np.ndarray  # (P, N)
    shard_hit_ratio: np.ndarray  # (P, N)
    shard_delayed_frac: np.ndarray  # (P, N)
    delayed_frac: np.ndarray  # (P,)
    n_requests: int
    # [seed][p] per-request TraceRecords when trace=K was requested (the
    # record's branch id resolves to a shard via model.branch_shard).
    traces: list | None = None
    # [seed][p] SketchEstimates when sketch_cap=K was requested (flow keys
    # on the jax side; shard heat via SketchEstimates.shard_heat +
    # model.branch_shard).
    sketches: list | None = None


def simulate_cluster(model: ClusterModel, p_hits, n_requests: int = 40_000,
                     seeds=(0, 1, 2), warmup_frac: float = 0.25,
                     coalesce_flows: int = 0, coalesce_theta: float = 0.0,
                     trace: int = 0, sketch_cap: int = 0,
                     window_us: float = 0.0) -> ClusterSimResult:
    """Simulate the composed cluster over a grid of *global* hit ratios.

    ``coalesce_flows`` is the per-shard MSHR hot-flow count (each shard's
    disk owns its own flow group); ``trace=K`` keeps the last K
    per-request trace records per lane (see :mod:`repro.obs.trace`);
    ``sketch_cap=K`` threads the in-kernel streaming estimators
    (:mod:`repro.obs.streaming`, windowed every ``window_us`` simulated
    µs) onto ``sketches``.  Everything else matches
    :func:`repro.core.simulator.simulate_network`, which this wraps.
    """
    res = simulate_network(model.network, p_hits, n_requests=n_requests,
                           seeds=seeds, warmup_frac=warmup_frac,
                           coalesce_flows=coalesce_flows,
                           coalesce_theta=coalesce_theta, trace=trace,
                           sketch_cap=sketch_cap, window_us=window_us)
    shard = np.asarray(model.branch_shard)
    is_hit = ~np.asarray(model.branch_has_disk)
    N = model.n_shards
    P = len(res.p_hit)
    sx = np.zeros((P, N))
    shit = np.full((P, N), np.nan)
    sdel = np.zeros((P, N))
    for k in range(N):
        sel = shard == k
        tot = res.branch_throughput[:, sel].sum(axis=1)
        hits = res.branch_throughput[:, sel & is_hit].sum(axis=1)
        dl = res.branch_delayed[:, sel].sum(axis=1)
        sx[:, k] = tot
        nz = tot > 0
        shit[nz, k] = hits[nz] / tot[nz]
        sdel[nz, k] = dl[nz] / tot[nz]
    return ClusterSimResult(
        p_hit=res.p_hit, throughput=res.throughput, ci95=res.ci95,
        shard_throughput=sx, shard_hit_ratio=shit, shard_delayed_frac=sdel,
        delayed_frac=res.delayed_frac, n_requests=n_requests,
        traces=res.traces, sketches=res.sketches,
    )


def simulate_cluster_py(model: ClusterModel, key_probs, assign,
                        p_hit: float, n_requests: int = 20_000,
                        seed: int = 0, warmup_frac: float = 0.25,
                        coalesce_flows: int = 0,
                        coalesce_theta: float = 0.0,
                        sketch_cap: int = 0,
                        window_us: float = 0.0) -> dict:
    """Key-routing heapq oracle for :func:`simulate_cluster` at one
    global hit ratio.

    ``model.network.mpl`` closed-loop clients; each fresh request samples
    a key from ``key_probs``, routes through ``assign`` (the hash ring's
    key → shard map), then samples a route of the *base* network at that
    shard's local hit ratio ``model.profile.shard_p(p_hit)[k]``.  Station
    state (c-server FCFS queues, bounded disks, MSHR flow groups) is kept
    per (shard, base-station) — fully shard-local, like the JAX twin.

    Returns a dict with cluster ``x``, per-shard ``shard_x`` /
    ``shard_hit_ratio`` / ``shard_delayed_frac``, measured ``shard_share``
    (the emergent routing weights), and ``delayed_frac``.

    ``sketch_cap > 0`` attaches the exact-counting estimator twin
    (:class:`repro.obs.streaming.PyStreamSketch`): because this oracle is
    the one engine that sees *true workload keys* (not coalescing flows),
    its sketch counts the routed key stream itself — the decoded
    estimates under ``"sketch"`` feed
    :func:`repro.obs.streaming.observed_profile` /
    ``observed_shard_profile`` directly.  Branch lanes in its windowed
    per-branch counters are ``shard * B + base_branch`` (so
    ``SketchEstimates.shard_heat`` recovers per-shard completion heat
    with an ``assign`` of ``lane // B``).
    """
    rng = random.Random(seed)
    base = model.base
    pk = model.profile.shard_p(p_hit)
    N = model.n_shards
    assign = np.asarray(assign)
    key_cum = np.cumsum(np.asarray(key_probs, np.float64))
    key_cum = key_cum / key_cum[-1]

    specs = [compile_network(base, float(pk[k])) for k in range(N)]
    is_q = np.asarray(specs[0].is_queue)
    servers = np.asarray(specs[0].servers)
    disk_rank = np.asarray(specs[0].disk_rank)
    visits = np.stack([np.asarray(s.visits) for s in specs])  # (N, B, L)
    svc = np.stack([np.asarray(s.svc_ns) for s in specs]) / 1e3  # (N, K) µs
    dist = np.asarray(specs[0].dist_id)
    cum = np.stack([np.asarray(s.branch_cum) for s in specs])  # (N, B)
    K = len(is_q)
    B = cum.shape[1]
    hit_branch = ~(((disk_rank[np.maximum(visits[0], 0)] >= 0)
                    & (visits[0] >= 0)).any(axis=1))
    sample_flow = (_flow_sampler(rng, coalesce_flows, coalesce_theta)
                   if coalesce_flows else None)
    if sketch_cap:
        from repro.obs.streaming import PyStreamSketch

        sk = PyStreamSketch(sketch_cap, n_branches=N * B,
                            window_us=window_us)
    else:
        sk = None

    def sample(sh: int, k: int) -> float:
        if dist[k] == 1:
            return svc[sh, k] * rng.expovariate(1.0)
        return float(svc[sh, k])

    def new_request() -> tuple:
        key = int(np.searchsorted(key_cum, rng.random()))
        sh = int(assign[key])
        b = int(np.searchsorted(cum[sh], rng.random()))
        if sk is not None:  # the true routed key, pre-hash
            sk.key(key)
        return sh, b

    M = model.network.mpl
    heap: list = []
    queues: dict = {}  # (shard, station) -> waiters
    busy: dict = {}  # (shard, station) -> in-service count
    leader: dict = {}  # (shard, flow) -> leading job
    parked: dict = {}  # (shard, flow) -> parked jobs
    job_shard = [0] * M
    job_branch = [0] * M
    job_pos = [0] * M
    job_flow: list = [None] * M

    done = 0
    delayed = 0
    sh_done = np.zeros(N, np.int64)
    sh_hit = np.zeros(N, np.int64)
    sh_del = np.zeros(N, np.int64)
    warm_target = int(n_requests * warmup_frac)
    warm = None  # (done, t, delayed, sh_done, sh_hit, sh_del)

    def complete(j: int, now: float, was_delayed: bool = False) -> None:
        nonlocal done, delayed, warm
        sh, b = job_shard[j], job_branch[j]
        if sk is not None:  # delayed hits count as misses (miss branches)
            sk.done(now, sh * B + b, is_hit=bool(hit_branch[b]),
                    delayed=was_delayed)
        done += 1
        sh_done[sh] += 1
        if hit_branch[b]:
            sh_hit[sh] += 1
        if was_delayed:
            delayed += 1
            sh_del[sh] += 1
        if warm is None and done >= warm_target:
            warm = (done, now, delayed, sh_done.copy(), sh_hit.copy(),
                    sh_del.copy())
        sh2, b2 = new_request()
        job_shard[j], job_branch[j], job_pos[j] = sh2, b2, 0
        k0 = int(visits[sh2, b2, 0])
        heapq.heappush(heap, (now + sample(sh2, k0), j, k0))

    for j in range(M):
        sh, b = new_request()
        job_shard[j], job_branch[j] = sh, b
        k0 = int(visits[sh, b, 0])
        heapq.heappush(heap, (sample(sh, k0), j, k0))

    t = 0.0
    while done < n_requests:
        t, j, k = heapq.heappop(heap)
        sh = job_shard[j]

        # MSHR fill: wake everything parked on this shard-local flow.
        if coalesce_flows and disk_rank[k] >= 0 and job_flow[j] is not None:
            f = job_flow[j]
            for w in parked.pop(f, []):
                job_flow[w] = None
                complete(w, t, was_delayed=True)
            del leader[f]
            job_flow[j] = None

        if is_q[k]:
            q = queues.get((sh, k))
            if q:
                w = q.pop(0)
                heapq.heappush(heap, (t + sample(sh, k), w, k))
            else:
                busy[(sh, k)] = busy.get((sh, k), 1) - 1
        b = job_branch[j]
        pos = job_pos[j] + 1
        if pos >= visits.shape[2] or visits[sh, b, pos] < 0:
            complete(j, t)
            continue
        job_pos[j] = pos
        k2 = int(visits[sh, b, pos])
        if coalesce_flows and disk_rank[k2] >= 0:
            f = (sh, int(disk_rank[k2]) * coalesce_flows + sample_flow())
            job_flow[j] = f
            if f in leader:
                parked.setdefault(f, []).append(j)
                continue
            leader[f] = j
        if is_q[k2]:
            if busy.get((sh, k2), 0) >= servers[k2]:
                queues.setdefault((sh, k2), []).append(j)
                continue
            busy[(sh, k2)] = busy.get((sh, k2), 0) + 1
        heapq.heappush(heap, (t + sample(sh, k2), j, k2))

    w_done, w_t, w_del, w_sd, w_sh, w_sdel = warm
    n_meas = done - w_done
    span = t - w_t
    sd = sh_done - w_sd
    shh = sh_hit - w_sh
    sdl = sh_del - w_sdel
    with np.errstate(invalid="ignore", divide="ignore"):
        hit_ratio = np.where(sd > 0, shh / np.maximum(sd, 1), math.nan)
        del_frac = np.where(sd > 0, sdl / np.maximum(sd, 1), 0.0)
    return {
        "x": n_meas / span,
        "shard_x": sd / span,
        "shard_share": sd / n_meas,
        "shard_hit_ratio": hit_ratio,
        "shard_delayed_frac": del_frac,
        "delayed_frac": (delayed - w_del) / n_meas,
        "sketch": sk.estimates() if sk is not None else None,
    }
