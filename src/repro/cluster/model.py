"""Analytic cluster layer: per-shard queueing networks composed into one.

A cluster of ``N`` cache shards behind a hash router is modeled as a
single :class:`~repro.core.queueing.ClosedNetwork` whose queue/disk
stations are replicated per shard (``s3:head``, ``s3:disk``, ...) and
whose branches carry the routing: a request follows shard ``k``'s copy of
a single-node route with probability ``w_k * b.prob(p_k)``, where ``w_k``
is shard ``k``'s request share and ``p_k`` its *local* hit ratio.  The
composition preserves everything the single-node stack already knows how
to do — Thm-7.1 bounds, exact/approximate MVA, the event-driven
simulators, the open-loop Erlang-C layer — so the cluster inherits all
three prongs at once:

* closed bound: ``X <= min(M/(D+Z), min_{k,st} c_st / (w_k D_st(p_k)))``
  — the saturated term is the *hot shard's* bottleneck station, so skew
  (``w_max > 1/N``) caps the cluster below ``N×`` single-node peak;
* open boundary: ``lambda_max(p) = min_k lambda_max^{(k)}(p_k) / w_k``
  (the hash router cannot rebalance, so the hot shard binds); the
  rebalanced ideal ``sum_k lambda_max^{(k)}`` — what the ISSUE's
  per-shard min-law sum would deliver — is exposed separately, and the
  gap between the two is the price of hashing under skew;
* cluster response time: the branch mixture *is* the routing-weighted
  mixture ``R(p, lambda) = sum_k w_k R_k(p_k, w_k lambda)``.

The second ingredient is the ``p -> p_k`` map: at one global operating
point the shards do NOT sit at the same local hit ratio.  A shard owning
hotter keys serves a more concentrated substream, so at equal per-shard
capacity its local hit ratio runs *above* the cluster average — which is
exactly why the cluster-level throughput-optimal hit ratio ``p*`` falls
below the single-node forecast for LRU-like policies: the hot shard's
hit-path metadata saturates while the cluster average still looks safe.
:class:`ShardProfile` captures the map as per-shard hit-ratio curves over
a shared per-shard capacity grid, built either analytically from the key
popularity (:func:`ideal_shard_profile`) or measured from a partitioned
trace via per-shard Mattson sweeps (:func:`measured_shard_profile`).

Miss coalescing: the simulators keep shard-local MSHR tables (each
``sK:disk`` owns its own flow group; see ``repro.cluster.sim``), and the
analytic composition matches them through
:meth:`ClusterModel.coalesced` — ``coalesced_network`` solves one
``sigma_k`` fixed point per shard disk against that shard's own miss
rate, so hot shards coalesce more (the former single-flat-sigma caveat
is closed).
"""

from __future__ import annotations

import dataclasses
import numpy as np

from repro.core.policy_models import POLICY_BUILDERS
from repro.core.queueing import QUEUE, Branch, ClosedNetwork

__all__ = [
    "ShardProfile", "uniform_profile", "zipf_key_probs",
    "ideal_shard_profile", "measured_shard_profile",
    "compose_cluster", "ClusterModel", "cluster_network",
]


def zipf_key_probs(key_space: int, theta: float = 0.99,
                   seed: int = 0) -> np.ndarray:
    """Per-key-id request probabilities of :func:`repro.core.harness.zipf_trace`.

    Reproduces the trace generator's construction exactly — Zipf(theta)
    rank masses scattered through the same seeded identity permutation —
    so analytic shard weights/profiles line up with traces drawn at the
    same ``seed``.
    """
    from repro.core.harness import _seed_streams

    rng = np.random.default_rng(_seed_streams(seed)[0])
    ranks = np.arange(1, key_space + 1, dtype=np.float64)
    probs = ranks ** (-float(theta))
    probs /= probs.sum()
    perm = rng.permutation(key_space)
    out = np.empty(key_space, np.float64)
    out[perm] = probs
    return out


@dataclasses.dataclass(frozen=True)
class ShardProfile:
    """Routing weights + the global-p → per-shard local hit-ratio map.

    ``shard_hit[k, c]`` is shard ``k``'s hit ratio at per-shard capacity
    ``caps[c]`` (each row non-decreasing).  The cluster's *global* hit
    ratio at that capacity is the routing-weighted mixture
    ``g(c) = sum_k w_k shard_hit[k, c]``; :meth:`shard_p` inverts ``g``
    (continuously, by interpolation) and reads each shard's curve at the
    common capacity — one global knob, N coupled local operating points,
    exactly how a real deployment sweeps cache size.
    """

    weights: np.ndarray  # (N,) request shares, sum 1
    caps: np.ndarray  # (C,) increasing per-shard capacity grid
    shard_hit: np.ndarray  # (N, C) per-shard hit-ratio curves

    def __post_init__(self):
        w = np.asarray(self.weights, np.float64)
        caps = np.asarray(self.caps, np.float64)
        sh = np.atleast_2d(np.asarray(self.shard_hit, np.float64))
        if sh.shape != (len(w), len(caps)):
            raise ValueError(f"shard_hit {sh.shape} vs "
                             f"({len(w)}, {len(caps)})")
        if not np.isclose(w.sum(), 1.0):
            raise ValueError(f"weights sum to {w.sum()}")
        if np.any(np.diff(caps) <= 0):
            raise ValueError("caps must be strictly increasing")
        if np.any(np.diff(sh, axis=1) < -1e-9):
            raise ValueError("per-shard hit curves must be non-decreasing")
        object.__setattr__(self, "weights", w)
        object.__setattr__(self, "caps", caps)
        object.__setattr__(self, "shard_hit", sh)

    @property
    def n_shards(self) -> int:
        return len(self.weights)

    @property
    def global_hit(self) -> np.ndarray:
        return self.weights @ self.shard_hit

    def p_range(self) -> tuple:
        g = self.global_hit
        return float(g[0]), float(g[-1])

    def shard_p(self, p: float) -> np.ndarray:
        """Local hit ratios at the capacity where the global ratio is ``p``
        (clamped to the profile's achievable range)."""
        g = self.global_hit
        c = np.interp(float(p), g, self.caps)
        return np.array([np.interp(c, self.caps, self.shard_hit[k])
                         for k in range(self.n_shards)])

    def imbalance(self) -> float:
        from repro.cluster.hashing import imbalance

        return imbalance(self.weights)


def uniform_profile(n_shards: int) -> ShardProfile:
    """Perfectly balanced, homogeneous shards: every shard at the global
    hit ratio (``shard_p(p) == [p]*N`` exactly).  The composition collapses
    to N scaled copies of the single node — the identity baseline the
    tests pin."""
    return ShardProfile(
        weights=np.full(n_shards, 1.0 / n_shards),
        caps=np.array([0.0, 1.0]),
        shard_hit=np.tile(np.array([0.0, 1.0]), (n_shards, 1)),
    )


def _default_caps(max_cap: int) -> np.ndarray:
    caps = np.unique(np.round(np.geomspace(1, max(max_cap, 2), 25)))
    return np.concatenate([[0.0], caps])


def ideal_shard_profile(assign, key_probs, caps=None,
                        n_shards: int | None = None) -> ShardProfile:
    """Analytic profile from the key popularity: a shard holding its
    ``c`` most popular keys serves their conditional mass.

    This is the ideal working-set (LFU-like) approximation — optimistic
    in level vs an LRU replay, but with the right *shape*: shards owning
    hotter keys have steeper curves, which is the mechanism the cluster
    knee shift rides on.  Use :func:`measured_shard_profile` for exact
    LRU curves from a real trace.  ``n_shards`` defaults to the largest
    shard id + 1; pass it explicitly when shard ids are sparse (a ring
    after :meth:`~repro.cluster.hashing.HashRing.without` keeps its
    surviving ids), or the gaps become zero-weight phantom shards.
    """
    assign = np.asarray(assign)
    q = np.asarray(key_probs, np.float64)
    n = int(n_shards or assign.max() + 1)
    weights = np.bincount(assign, weights=q, minlength=n)
    weights = weights / weights.sum()
    sizes = np.bincount(assign, minlength=n)
    if caps is None:
        caps = _default_caps(int(sizes.max()))
    caps = np.asarray(caps, np.float64)
    hit = np.zeros((n, len(caps)))
    for k in range(n):
        qk = np.sort(q[assign == k])[::-1]
        if qk.size == 0 or qk.sum() <= 0:
            continue
        cum = np.concatenate([[0.0], np.cumsum(qk)]) / qk.sum()
        hit[k] = cum[np.minimum(caps.astype(int), len(qk))]
    return ShardProfile(weights=weights, caps=caps, shard_hit=hit)


def measured_shard_profile(trace, assign, caps=None,
                           warmup_frac: float = 0.25,
                           n_shards: int | None = None) -> ShardProfile:
    """Measured profile: partition ``trace`` by the router and run one
    exact Mattson stack-distance LRU sweep per substream.

    Weights are the observed per-shard request shares; ``shard_hit[k]``
    is substream ``k``'s post-warmup LRU hit ratio at every per-shard
    capacity — prong C feeding the cluster model the same way
    ``sweep_cache_sizes`` feeds the single-node one.  ``n_shards``
    follows the :func:`ideal_shard_profile` convention (dense ids;
    default largest id + 1).
    """
    from repro.cache.replay import lru_sweep
    from repro.cluster.hashing import partition_trace

    trace = np.asarray(trace)
    if trace.size == 0:
        raise ValueError("measured_shard_profile needs a non-empty trace")
    subs = partition_trace(trace, assign, n_shards=n_shards)
    n = len(subs)
    weights = np.array([len(s) / trace.size for s in subs])
    if caps is None:
        caps = _default_caps(int(max(len(np.unique(s)) for s in subs
                                     if len(s)) or 2))
    caps = np.asarray(caps, np.float64)
    icaps = np.maximum(caps.astype(int), 0)
    hit = np.zeros((n, len(caps)))
    for k, sub in enumerate(subs):
        if len(sub) < 8:
            continue
        hits, _ = lru_sweep(sub, np.maximum(icaps, 1))
        w = int(len(sub) * warmup_frac)
        frac = hits[:, w:].mean(axis=1)
        hit[k] = np.where(icaps >= 1, frac, 0.0)
        hit[k] = np.maximum.accumulate(hit[k])  # guard tiny non-monotonicity
    return ShardProfile(weights=weights, caps=caps, shard_hit=hit)


def compose_cluster(net: ClosedNetwork, profile: ShardProfile,
                    mpl: int | None = None,
                    name: str | None = None) -> "ClusterModel":
    """Replicate ``net``'s queue + disk stations per shard and route
    branches through them with the profile's weights and local hit ratios.

    Shared infinite-server stations (the client-side lookup/think work)
    stay single copies — an infinite server partitions trivially.  Every
    replicated station's service time is evaluated at the *shard's* local
    hit ratio (CLOCK's p-dependent tail scan, say, scans the hot shard's
    longer-resident list).  ``mpl`` defaults to ``net.mpl * n_shards``
    (one node's worth of closed-loop clients per shard).
    """
    n = profile.n_shards
    w = profile.weights
    memo: dict = {}

    def sp(p: float) -> np.ndarray:
        key = round(float(p), 12)
        if key not in memo:
            memo[key] = profile.shard_p(key)
        return memo[key]

    replicated = {s.name for s in net.stations
                  if s.kind == QUEUE or s.name.split(":")[-1] == "disk"}
    stations = [s for s in net.stations if s.name not in replicated]
    for k in range(n):
        for s in net.stations:
            if s.name not in replicated:
                continue
            stations.append(dataclasses.replace(
                s, name=f"s{k}:{s.name}",
                service=(lambda p, s=s, k=k: s.mean_service(float(sp(p)[k]))),
            ))

    branches = []
    branch_shard = []
    branch_has_disk = []
    for k in range(n):
        for b in net.branches:
            visits = tuple(f"s{k}:{v}" if v in replicated else v
                           for v in b.visits)
            branches.append(Branch(
                f"s{k}:{b.name}",
                (lambda p, b=b, k=k: float(w[k]) * b.probability(
                    float(sp(p)[k]))),
                visits,
            ))
            branch_shard.append(k)
            branch_has_disk.append(
                any(v.split(":")[-1] == "disk" for v in b.visits))

    network = ClosedNetwork(
        name or f"{net.name}-cluster{n}",
        tuple(stations), tuple(branches),
        int(mpl or net.mpl * n),
        description=f"{n}-shard hash-routed cluster of {net.name} "
                    f"(imbalance {profile.imbalance():.3f})",
    )
    return ClusterModel(base=net, network=network, profile=profile,
                        branch_shard=tuple(branch_shard),
                        branch_has_disk=tuple(branch_has_disk))


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """A composed cluster: the network plus its shard bookkeeping."""

    base: ClosedNetwork
    network: ClosedNetwork
    profile: ShardProfile
    branch_shard: tuple  # composed-branch index -> shard
    branch_has_disk: tuple  # composed-branch index -> visits a disk?

    @property
    def n_shards(self) -> int:
        return self.profile.n_shards

    # ---- closed loop -----------------------------------------------------
    def throughput_upper(self, p_hit, tail_mode: str = "zero"):
        """Cluster Thm-7.1 bound (== summed per-shard throughput: each
        shard carries ``w_k X``)."""
        return self.network.throughput_upper(p_hit, tail_mode=tail_mode)

    def shard_throughput_upper(self, p_hit, tail_mode: str = "zero"):
        """(N,) per-shard completion rates ``w_k X(p)`` at one global p."""
        x = float(self.network.throughput_upper(p_hit, tail_mode=tail_mode))
        return self.profile.weights * x

    def p_star(self, tail_mode: str = "zero", grid: int = 20001) -> float:
        return self.network.p_star(tail_mode=tail_mode, grid=grid)

    def mva_throughput(self, p_hit, **kw):
        return self.network.mva_throughput(p_hit, **kw)

    def coalesced(self, flows: int = 64, window_us=None,
                  flow_theta: float = 0.0, window_mode: str = "service",
                  ) -> ClosedNetwork:
        """Analytic shard-local miss coalescing: the composed network
        with one ``sigma_k`` fixed point per shard disk (matching the
        simulator's per-shard MSHR flow groups — ``flows`` hot flows per
        shard).  See :func:`repro.core.queueing.coalesced_network`."""
        from repro.core.queueing import coalesced_network

        return coalesced_network(self.network, flows=flows,
                                 window_us=window_us,
                                 window_mode=window_mode,
                                 flow_theta=flow_theta)

    # ---- open loop -------------------------------------------------------
    def lambda_max(self, p_hit, tail_mode: str = "zero"):
        """Hash-routed stability boundary min_k lambda_max^{(k)}(p_k)/w_k:
        the hot shard saturates first and the router cannot rebalance."""
        from repro.latency import lambda_max

        return lambda_max(self.network, p_hit, tail_mode=tail_mode)

    def ideal_lambda_max(self, p_hit, tail_mode: str = "zero"):
        """Rebalanced ideal: the per-shard min-law sum
        ``sum_k lambda_max^{(k)}(p_k)`` — what N shards could sustain if
        load were spread to saturate every shard simultaneously.  The
        ratio to :meth:`lambda_max` is the skew penalty of hashing."""
        from repro.latency import lambda_max

        p_arr = np.atleast_1d(np.asarray(p_hit, np.float64))
        out = np.empty_like(p_arr)
        for i, p in enumerate(p_arr):
            pk = self.profile.shard_p(float(p))
            out[i] = sum(
                float(lambda_max(self.base, float(pk[k]),
                                 tail_mode=tail_mode))
                for k in range(self.n_shards)
            )
        return out if np.ndim(p_hit) else float(out[0])

    def response_time(self, p_hit, arrival_rate: float,
                      tail_mode: str = "nominal"):
        """Cluster mean sojourn R(p, lambda) — the routing-weighted
        mixture over shards, via the open Erlang-C layer."""
        from repro.latency import response_time

        return response_time(self.network, p_hit, arrival_rate,
                             tail_mode=tail_mode)


def cluster_network(policy: str, n_shards: int,
                    profile: ShardProfile | None = None,
                    disk_us: float = 100.0, mpl: int | None = None,
                    cores: int | None = None, disk_servers: int = 0,
                    **kw) -> ClusterModel:
    """Build a policy's single-node network and lift it to an N-shard
    cluster.  ``profile`` defaults to perfectly balanced homogeneous
    shards; pass an :func:`ideal_shard_profile` / :func:`measured_shard_profile`
    to model Zipf skew.  ``mpl`` is the *cluster-wide* closed-loop
    population (default: one single-node complement per shard)."""
    if profile is None:
        profile = uniform_profile(n_shards)
    if profile.n_shards != n_shards:
        raise ValueError(f"profile has {profile.n_shards} shards, "
                         f"asked for {n_shards}")
    base = POLICY_BUILDERS[policy](disk_us=disk_us, cores=cores,
                                   disk_servers=disk_servers, **kw)
    return compose_cluster(base, profile, mpl=mpl)
