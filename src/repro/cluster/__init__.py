"""repro.cluster — the sharded cache-cluster prong (fourth subsystem).

Lifts all three single-node prongs to an N-shard hash-routed cluster:

* routing     -> repro.cluster.hashing  (consistent-hash ring, two-choice
                 maps, trace partitioning, measured imbalance)
* theory      -> repro.cluster.model    (per-shard station sets composed
                 into one ClosedNetwork; shard profiles p -> p_k; cluster
                 bounds, MVA, lambda_max, R(p, lambda))
* simulation  -> repro.cluster.sim      (one vmapped dispatch with
                 shard-local MSHR tables + a key-routing heapq oracle)

The headline: under Zipf skew the hot shard's hit-path metadata
saturates while the cluster-average hit ratio still looks safe, so the
cluster-level throughput-optimal p* sits strictly below the single-node
forecast for LRU-like policies; FIFO-like policies stay monotone.
"""

from repro.cluster.hashing import (
    HashRing,
    imbalance,
    partition_trace,
    shard_weights,
    two_choice_assignment,
)
from repro.cluster.model import (
    ClusterModel,
    ShardProfile,
    cluster_network,
    compose_cluster,
    ideal_shard_profile,
    measured_shard_profile,
    uniform_profile,
    zipf_key_probs,
)
from repro.cluster.sim import (
    ClusterSimResult,
    simulate_cluster,
    simulate_cluster_py,
)

__all__ = [
    "HashRing", "imbalance", "partition_trace", "shard_weights",
    "two_choice_assignment",
    "ClusterModel", "ShardProfile", "cluster_network", "compose_cluster",
    "ideal_shard_profile", "measured_shard_profile", "uniform_profile",
    "zipf_key_probs",
    "ClusterSimResult", "simulate_cluster", "simulate_cluster_py",
]
