"""Consistent-hash routing: how a key stream becomes per-shard substreams.

A cache *cluster* sits behind a hash router: every key is owned by exactly
one shard, so the cluster-level workload is the single-node workload
partitioned by the router.  Two routers are provided:

* :class:`HashRing` — classic consistent hashing (Karger et al. 1997):
  each shard owns ``vnodes`` pseudo-random points on a 64-bit ring and a
  key belongs to the first shard point clockwise of its hash.  Removing a
  shard only re-homes the keys that shard owned (the property the scheme
  exists for); load balance improves with ``vnodes`` but stays imperfect.
* :func:`two_choice_assignment` — a static power-of-two-choices map: keys
  are placed, heaviest first, on the lighter-loaded of two hash
  candidates (Mitzenmacher 1996).  Much tighter balance than the ring at
  the cost of storing the full key→shard map.

Everything downstream consumes a plain ``assign`` array (key id → shard),
so the two routers — or any external placement — are interchangeable.
The *measured* skew of a placement is summarized by
:func:`shard_weights` (exact per-shard request shares under a known key
popularity) and :func:`imbalance` (hottest shard's load relative to a
perfectly balanced split); under Zipf popularity the ring's imbalance is
what moves the cluster's saturation knee (see ``repro.cluster.model``).

Hashing is splitmix64 — deterministic, dependency-free, vectorized over
numpy uint64 arrays.
"""

from __future__ import annotations

import dataclasses

import numpy as np
from numpy.typing import ArrayLike

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)


def _mix64(x: ArrayLike) -> np.ndarray:
    """splitmix64, vectorized: the generator's golden-ratio state
    increment (so x and x+1 land far apart) followed by its finalizer."""
    x = np.asarray(x).astype(np.uint64) + _GOLDEN
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def _hash2(a: ArrayLike, b: ArrayLike, seed: int) -> np.ndarray:
    return _mix64(_mix64(np.uint64(seed) ^ np.asarray(a, np.uint64))
                  ^ np.asarray(b, np.uint64))


@dataclasses.dataclass(frozen=True)
class HashRing:
    """Consistent-hash ring over integer keys.

    ``shards`` are arbitrary integer ids (default ``0..n_shards-1``);
    each contributes ``vnodes`` ring points.  Construction is pure, so
    :meth:`without` / :meth:`with_shard` return *new* rings sharing every
    surviving shard's points — the membership-change stability tests pin
    exactly that.
    """

    n_shards: int
    vnodes: int = 64
    seed: int = 0
    shards: tuple[int, ...] = ()
    _pos: np.ndarray = dataclasses.field(init=False, repr=False, compare=False)
    _owner: np.ndarray = dataclasses.field(init=False, repr=False, compare=False)

    def __post_init__(self) -> None:
        shards = self.shards or tuple(range(self.n_shards))
        if len(set(shards)) != len(shards) or not shards:
            raise ValueError(f"bad shard id list {shards}")
        object.__setattr__(self, "shards", tuple(int(s) for s in shards))
        object.__setattr__(self, "n_shards", len(shards))
        sid = np.repeat(np.asarray(self.shards, np.uint64), self.vnodes)
        rep = np.tile(np.arange(self.vnodes, dtype=np.uint64),
                      len(self.shards))
        pos = _hash2(sid, rep, self.seed)
        order = np.argsort(pos, kind="stable")
        object.__setattr__(self, "_pos", pos[order])
        object.__setattr__(self, "_owner",
                           sid[order].astype(np.int64))

    def shard_of(self, keys: ArrayLike) -> np.ndarray | int:
        """Vectorized key → shard lookup (first ring point clockwise)."""
        h = _mix64(np.asarray(keys, np.uint64) ^ np.uint64(self.seed))
        idx = np.searchsorted(self._pos, h, side="left") % len(self._pos)
        out = self._owner[idx]
        return out if np.ndim(keys) else int(out)

    def assignment(self, key_space: int) -> np.ndarray:
        """Dense key → shard map for keys ``0..key_space-1``."""
        return self.shard_of(np.arange(key_space))

    def without(self, shard: int) -> "HashRing":
        """Ring with ``shard`` removed; all other shards keep their keys."""
        rest = tuple(s for s in self.shards if s != shard)
        if len(rest) == len(self.shards):
            raise KeyError(shard)
        return HashRing(len(rest), self.vnodes, self.seed, shards=rest)

    def with_shard(self, shard: int) -> "HashRing":
        return HashRing(self.n_shards + 1, self.vnodes, self.seed,
                        shards=self.shards + (int(shard),))


def two_choice_assignment(key_weights: ArrayLike, n_shards: int,
                          seed: int = 0) -> np.ndarray:
    """Static power-of-two-choices key placement.

    Keys are placed in descending weight order; each goes to whichever of
    its two hash candidates currently carries less total weight.  With
    uniform weights this is the classic balls-into-bins two-choice
    process (max load within O(log log n) of the mean); with Zipf weights
    it mainly stops the few hottest keys from landing on one shard.
    """
    w = np.asarray(key_weights, np.float64)
    if w.ndim != 1 or len(w) == 0 or np.any(w < 0):
        raise ValueError("key_weights must be a non-negative 1-D array")
    keys = np.arange(len(w), dtype=np.uint64)
    c1 = (_hash2(keys, 1, seed) % np.uint64(n_shards)).astype(np.int64)
    c2 = (_hash2(keys, 2, seed) % np.uint64(n_shards)).astype(np.int64)
    assign = np.empty(len(w), np.int64)
    loads = np.zeros(n_shards, np.float64)
    for k in np.argsort(-w, kind="stable"):
        a, b = c1[k], c2[k]
        pick = a if loads[a] <= loads[b] else b
        assign[k] = pick
        loads[pick] += w[k]
    return assign


def shard_weights(assign: ArrayLike, key_weights: ArrayLike,
                  n_shards: int | None = None) -> np.ndarray:
    """Exact per-shard request shares: the popularity mass each shard owns.

    This is the routing weight vector the analytic cluster model and the
    JAX cluster simulator consume; the heapq oracle never sees it — its
    per-shard traffic emerges from hashing sampled keys — which is what
    makes the weight calculation differentially testable.
    """
    assign = np.asarray(assign)
    w = np.bincount(assign, weights=np.asarray(key_weights, np.float64),
                    minlength=n_shards or int(assign.max()) + 1)
    tot = w.sum()
    if tot <= 0:
        raise ValueError("key_weights carry no mass")
    return w / tot


def imbalance(weights: ArrayLike) -> float:
    """Hot-shard load factor: max shard share / balanced share (>= 1)."""
    w = np.asarray(weights, np.float64)
    return float(w.max() * len(w) / w.sum())


def partition_trace(trace: ArrayLike, assign: ArrayLike,
                    n_shards: int | None = None) -> list[np.ndarray]:
    """Split a key trace into per-shard substreams (order preserved).

    Returns ``[sub_0, ..., sub_{N-1}]`` with ``sub_k`` the requests routed
    to shard ``k`` — the inputs to per-shard Mattson sweeps / prong-C
    replay.  Empty shards yield empty arrays.  ``n_shards`` defaults to
    the largest shard id + 1 — pass it explicitly for sparse id sets
    (e.g. a ring after :meth:`HashRing.without`, whose surviving ids are
    not contiguous).
    """
    trace = np.asarray(trace)
    assign = np.asarray(assign)
    shard_of_req = assign[trace]
    n = int(n_shards or assign.max() + 1)
    return [trace[shard_of_req == k] for k in range(n)]
