"""Tiered cache hierarchies (L1 clients → sharded L2 → origin).

Composition and analytics live in :mod:`repro.hierarchy.model`; the
tiered simulator twins in :mod:`repro.hierarchy.sim`.
"""

from repro.hierarchy.model import (
    HierarchyModel,
    TierSpec,
    TieredProfile,
    che_hit,
    coalesced_hierarchy,
    compose_tiers,
    hierarchy_network,
    measured_tiered_profile,
    tier_sigma_of,
    tiered_profile,
)

__all__ = [
    "HierarchyModel", "TierSpec", "TieredProfile", "che_hit",
    "coalesced_hierarchy", "compose_tiers", "hierarchy_network",
    "measured_tiered_profile", "tier_sigma_of", "tiered_profile",
    "HierarchySimResult", "simulate_hierarchy", "simulate_hierarchy_py",
]


def __getattr__(name):
    if name in ("HierarchySimResult", "simulate_hierarchy",
                "simulate_hierarchy_py"):
        from repro.hierarchy import sim

        return getattr(sim, name)
    raise AttributeError(name)
