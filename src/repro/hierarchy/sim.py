"""Simulating tiered hierarchies — the tentpole's measurement layer.

* :func:`simulate_hierarchy` — the composed hierarchy network through
  the JAX event machinery: one vmapped, jitted dispatch over the
  (global-p × seed) grid running the cross-tier MSHR kernel
  (``simulate_network(tiers=...)``), with per-branch completion counters
  folded back into per-level (L1-hit / L2-hit / origin) throughput
  shares and per-tier delayed-hit fractions.
* :func:`simulate_hierarchy_py` — the heapq oracle twin at one global p
  (``simulate_py(tiers=...)``), folded the same way.

Both accept ``coalesce_flows=0`` as the no-coalescing reference: the
same composed network through the plain kernels, annotations ignored.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.py_sim import simulate_py
from repro.core.simulator import simulate_network
from repro.hierarchy.model import HierarchyModel

__all__ = ["HierarchySimResult", "simulate_hierarchy",
           "simulate_hierarchy_py"]


@dataclasses.dataclass(frozen=True)
class HierarchySimResult:
    """Tier-folded view of a hierarchy simulation.

    ``level_throughput`` columns are [served at L1, served at L2,
    served at origin] — delayed hits count where their *fill* came from
    (the branch they parked on).  ``delayed_l1_frac`` is the fraction of
    completions that coalesced at a client-local L1 table,
    ``delayed_l2_frac`` at a shard-local origin table.
    """

    p_hit: np.ndarray  # (P,) global L1 hit-ratio knob
    throughput: np.ndarray  # (P,) requests/µs
    ci95: np.ndarray  # (P,)
    level_throughput: np.ndarray  # (P, 3) requests/µs per serving level
    shard_throughput: np.ndarray  # (P, N) L1-miss stream per L2 shard
    delayed_frac: np.ndarray  # (P,)
    delayed_l1_frac: np.ndarray  # (P,) parked at the client's L1 table
    delayed_l2_frac: np.ndarray  # (P,) parked at a shard origin table
    n_requests: int
    # per-request trace records when the run asked for tracing
    # (``trace=K``): [seed][p] TraceRecords from the jax engine, a single
    # TraceRecords from the heapq oracle.  None otherwise.
    traces: object = None
    # streaming-estimator decodes when the run asked for sketches
    # (``sketch_cap=K``): [seed][p] SketchEstimates from the jax engine,
    # a single SketchEstimates from the heapq oracle.  None otherwise.
    sketches: object = None


def _fold(model: HierarchyModel, p_hit, x, ci, bx, delayed, tier_dl,
          n_requests: int, traces=None, sketches=None) -> HierarchySimResult:
    level = np.asarray(model.branch_level)
    shard = np.asarray(model.branch_shard)
    P = len(p_hit)
    lvl_x = np.zeros((P, 3))
    for lv in range(3):
        lvl_x[:, lv] = bx[:, level == lv].sum(axis=1)
    sh_x = np.zeros((P, model.n_shards))
    for k in range(model.n_shards):
        sh_x[:, k] = bx[:, shard == k].sum(axis=1)
    if tier_dl is None:
        tier_dl = np.zeros((P, 2))
    return HierarchySimResult(
        p_hit=np.asarray(p_hit), throughput=np.asarray(x),
        ci95=np.asarray(ci), level_throughput=lvl_x, shard_throughput=sh_x,
        delayed_frac=np.asarray(delayed),
        delayed_l1_frac=tier_dl[:, 0], delayed_l2_frac=tier_dl[:, 1],
        n_requests=n_requests, traces=traces, sketches=sketches,
    )


def simulate_hierarchy(model: HierarchyModel, p_hits,
                       n_requests: int = 40_000, seeds=(0, 1, 2),
                       warmup_frac: float = 0.25,
                       coalesce_flows: int = 0,
                       coalesce_theta: float = 0.0,
                       trace: int = 0,
                       sketch_cap: int = 0,
                       window_us: float = 0.0) -> HierarchySimResult:
    """Simulate the composed hierarchy over a grid of global hit ratios.

    ``coalesce_flows`` sizes every MSHR table's hot-flow group (per
    client at L1, per shard at the origin); 0 runs the plain kernel as
    the no-coalescing reference.  ``trace=K`` keeps the last K
    per-request trace records per (seed, p) lane (see
    :mod:`repro.obs.trace`) on the result's ``traces`` field — the
    branch id in each record resolves a request to its client / shard /
    serving level through ``model.branch_client`` & friends.
    ``sketch_cap=K`` threads the in-kernel streaming estimators
    (:mod:`repro.obs.streaming`, sampled every ``window_us`` simulated
    µs) and decodes them onto ``sketches``.  Wraps
    :func:`repro.core.simulator.simulate_network`.
    """
    res = simulate_network(
        model.network, p_hits, n_requests=n_requests, seeds=seeds,
        warmup_frac=warmup_frac, coalesce_flows=coalesce_flows,
        coalesce_theta=coalesce_theta,
        tiers=model.mshr if coalesce_flows else None,
        trace=trace, sketch_cap=sketch_cap, window_us=window_us,
    )
    return _fold(model, res.p_hit, res.throughput, res.ci95,
                 res.branch_throughput, res.delayed_frac,
                 res.delayed_tier_frac, n_requests, traces=res.traces,
                 sketches=res.sketches)


def simulate_hierarchy_py(model: HierarchyModel, p_hit: float,
                          n_requests: int = 20_000, seed: int = 0,
                          warmup_frac: float = 0.25,
                          coalesce_flows: int = 0,
                          coalesce_theta: float = 0.0,
                          trace: int = 0,
                          sketch_cap: int = 0,
                          window_us: float = 0.0) -> HierarchySimResult:
    """Heapq-oracle twin of :func:`simulate_hierarchy` at one global p."""
    out = simulate_py(
        model.network, float(p_hit), n_requests=n_requests, seed=seed,
        warmup_frac=warmup_frac, coalesce_flows=coalesce_flows,
        coalesce_theta=coalesce_theta, full=True,
        tiers=model.mshr if coalesce_flows else None,
        trace=trace, sketch_cap=sketch_cap, window_us=window_us,
    )
    bx = (np.asarray(out["branch_done"], np.float64)
          / out["t_measured"])[None, :]
    tier_dl = out.get("delayed_tier_frac")
    tier_dl = (np.asarray(tier_dl)[None, :] if tier_dl is not None
               else None)
    return _fold(model, np.array([float(p_hit)]),
                 np.array([out["x"]]), np.array([0.0]), bx,
                 np.array([out["delayed_frac"]]), tier_dl, n_requests,
                 traces=out.get("trace"), sketches=out.get("sketch"))
