"""Tiered cache hierarchies: L1 clients → sharded L2 → origin, as ONE network.

This module generalizes :mod:`repro.cluster.model`'s compose machinery
from "N parallel shards" to a tiered DAG.  A hierarchy is described by
:class:`TierSpec`s (per-tier policy network + instance count) and a
:class:`TieredProfile` (the single-knob global-p → per-tier hit-ratio
map); :func:`compose_tiers` splices the tiers' routes into one
:class:`~repro.core.queueing.ClosedNetwork`:

* the L1 tier is replicated per *client* (an in-process cache per app
  server: ``l1_0:head``, ``l1_3:delink``, ...) — every client serves
  ``1/n_clients`` of the traffic at the same local hit ratio ``p1``;
* the L2 tier is replicated per *shard* (``l2_0:head``, ...) with the
  PR 5 cluster weights/local hit ratios ``(w_k, p2_k)``, but its
  backing-store placeholder is replaced by the next tier down;
* one shared ``disk`` station is the origin.

An L1 miss route is the L1 miss prefix, then a full L2 route at the
sampled shard (which may itself miss to the origin), then the L1 fill
suffix.  Branch probabilities multiply along the DAG —
``(1/n1) · b1(p1) · w_k · b2(p2_k)`` — so they still sum to 1 at every
``p`` and Thm 7.1 / MVA / Erlang-C work **unchanged** on the composed
network.

Cross-tier delayed hits ride on a :class:`~repro.core.simspec.MshrSpec`:
each composed miss branch acquires an outstanding-fetch entry in its
*client's* table when it enters the L2 segment (held-slot 0) and, if the
L2 misses too, a second entry in the *shard-local* origin table at the
``disk`` visit (held-slot 1).  A same-flow request parks behind either —
an in-flight L2 fetch or an in-flight origin fetch — and fills cascade:
when an origin fetch lands, the requests parked on it complete as
delayed hits and release their own L1 entries, waking *their* followers.
:func:`coalesced_hierarchy` is the analytic counterpart: per-level,
per-shard coalescing factors ``sigma1`` / ``sigma2_k`` solved as a joint
fixed point (the tiered generalization of
:func:`repro.core.queueing.coalesced_network`).

Why can raising the *L1* hit ratio hurt *cluster* throughput?  With
strong coalescing most L1 misses are nearly free — they park behind an
in-flight fetch and complete with it — so the marginal benefit of more
L1 hits is small, while every extra hit still pays the L1 eviction-list
metadata (LRU delink/head).  Growing L1 also *starves* the deeper
coalescing: it absorbs exactly the hot keys whose concurrent misses used
to share fetches, so ``sigma`` falls as ``p1`` rises and misses get
more expensive per miss.  Past the tiered ``p*`` the metadata cost wins
and throughput falls — ``benchmarks/fig_hierarchy.py`` asserts both this
regime and the monotone regime (no coalescing) on the same hierarchy.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.policy_models import POLICY_BUILDERS
from repro.core.queueing import QUEUE, THINK, Branch, ClosedNetwork, Station
from repro.core.queueing import _as_fn, zipf_flow_weights
from repro.core.simspec import MshrSpec

__all__ = [
    "TierSpec", "TieredProfile", "che_hit",
    "tiered_profile", "measured_tiered_profile",
    "compose_tiers", "HierarchyModel", "hierarchy_network",
    "coalesced_hierarchy", "tier_sigma_of",
]


# --------------------------------------------------------------------------
# Per-tier hit profiles
# --------------------------------------------------------------------------


def che_hit(key_probs, cap: float) -> np.ndarray:
    """Per-key hit probabilities of an LRU-like cache of ``cap`` objects
    under IRM traffic — Che's characteristic-time (TTL) approximation.

    Every key behaves as if cached with a common TTL ``Tc``:
    ``h_i = 1 - exp(-q_i Tc)`` with ``Tc`` solving
    ``sum_i h_i = cap`` (the expected occupancy fills the cache).  Scale
    invariant in ``key_probs``, exact in the large-cache limit, and the
    standard workhorse for cache *networks* (Gallo et al.): the L2 tier
    sees the L1-filtered masses ``q_i (1 - h_i)``.
    """
    q = np.asarray(key_probs, np.float64)
    pos = q > 0
    n_pos = int(pos.sum())
    out = np.zeros_like(q)
    if cap <= 0 or n_pos == 0:
        return out
    if cap >= n_pos:
        out[pos] = 1.0
        return out
    qp = q[pos]

    def occupancy(tc: float) -> float:
        return float((1.0 - np.exp(-qp * tc)).sum())

    hi = 1.0 / float(qp.max())
    for _ in range(200):
        if occupancy(hi) >= cap:
            break
        hi *= 2.0
    lo = 0.0
    for _ in range(100):
        mid = 0.5 * (lo + hi)
        if occupancy(mid) < cap:
            lo = mid
        else:
            hi = mid
    tc = 0.5 * (lo + hi)
    out[pos] = 1.0 - np.exp(-qp * tc)
    return out


@dataclasses.dataclass(frozen=True)
class TieredProfile:
    """The single-knob map global-L1-hit-ratio → per-tier operating points.

    Sweeping a hierarchy means sweeping the *L1 capacity*; everything
    else follows.  Row ``c`` of the arrays describes the hierarchy with
    per-client L1 capacity ``caps[c]``: the L1 hit ratio ``l1_hit[c]``,
    and — because L1 filters the head of the popularity curve — the
    *reshaped* L2 stream: shard shares ``shard_weights[c]`` and local L2
    hit ratios ``l2_hit[c]`` of the filtered masses at the (fixed) L2
    capacity.  :meth:`tier_p` inverts ``l1_hit`` continuously, exactly
    like :class:`repro.cluster.model.ShardProfile` inverts its global
    curve — one scalar knob ``p``, all tiers coupled through it.
    """

    caps: np.ndarray  # (C,) increasing per-client L1 capacity grid
    l1_hit: np.ndarray  # (C,) non-decreasing global L1 hit ratio
    shard_weights: np.ndarray  # (C, N) L1-miss-stream share per L2 shard
    l2_hit: np.ndarray  # (C, N) per-shard local L2 hit ratio

    def __post_init__(self):
        caps = np.asarray(self.caps, np.float64)
        h1 = np.asarray(self.l1_hit, np.float64)
        w = np.atleast_2d(np.asarray(self.shard_weights, np.float64))
        h2 = np.atleast_2d(np.asarray(self.l2_hit, np.float64))
        if h1.shape != caps.shape:
            raise ValueError(f"l1_hit {h1.shape} vs caps {caps.shape}")
        if w.shape != h2.shape or w.shape[0] != len(caps):
            raise ValueError(f"shard_weights {w.shape} vs l2_hit "
                             f"{h2.shape} vs {len(caps)} capacities")
        if np.any(np.diff(caps) <= 0):
            raise ValueError("caps must be strictly increasing")
        if np.any(np.diff(h1) < -1e-9):
            raise ValueError("l1_hit must be non-decreasing")
        if not np.allclose(w.sum(axis=1), 1.0):
            raise ValueError("shard_weights rows must sum to 1")
        object.__setattr__(self, "caps", caps)
        object.__setattr__(self, "l1_hit", h1)
        object.__setattr__(self, "shard_weights", w)
        object.__setattr__(self, "l2_hit", h2)

    @property
    def n_shards(self) -> int:
        return self.shard_weights.shape[1]

    def p_range(self) -> tuple:
        return float(self.l1_hit[0]), float(self.l1_hit[-1])

    def l1_cap(self, p: float) -> float:
        """Per-client L1 capacity achieving global L1 hit ratio ``p``."""
        return float(np.interp(float(p), self.l1_hit, self.caps))

    def tier_p(self, p: float) -> tuple:
        """``(p1, w, p2)`` at the L1 capacity where the L1 hit ratio is
        ``p`` (clamped to the achievable range): the local L1 hit ratio,
        the (N,) shard shares of the miss stream, and the (N,) local L2
        hit ratios."""
        lo, hi = self.p_range()
        p1 = min(max(float(p), lo), hi)
        c = np.interp(p1, self.l1_hit, self.caps)
        w = np.array([np.interp(c, self.caps, self.shard_weights[:, k])
                      for k in range(self.n_shards)])
        w = w / w.sum()
        p2 = np.array([np.interp(c, self.caps, self.l2_hit[:, k])
                       for k in range(self.n_shards)])
        return p1, w, p2

    @classmethod
    def constant(cls, p2, n_shards: int | None = None,
                 weights=None) -> "TieredProfile":
        """Degenerate profile: the knob *is* the L1 hit ratio
        (``p1 == p`` over [0, 1]) while the L2 operating point stays
        fixed — balanced shards at hit ratio ``p2`` (scalar, or one per
        shard).  The serving engine's natural hierarchy view: the pod's
        measured hit ratio is known, sweep the client-side L1 in front
        of it."""
        p2 = np.atleast_1d(np.asarray(p2, np.float64))
        n = int(n_shards or len(p2))
        p2 = np.broadcast_to(p2, (n,))
        w = (np.full(n, 1.0 / n) if weights is None
             else np.asarray(weights, np.float64))
        return cls(caps=np.array([0.0, 1.0]),
                   l1_hit=np.array([0.0, 1.0]),
                   shard_weights=np.tile(w, (2, 1)),
                   l2_hit=np.tile(p2, (2, 1)))


def tiered_profile(key_probs, l1_caps, l2_cap: float, assign,
                   n_shards: int | None = None) -> TieredProfile:
    """Analytic profile via Che's characteristic-time approximation.

    Each client's L1 sees the full key-popularity distribution (clients
    draw iid from the same workload), so one Che solve per L1 capacity
    gives ``h1``; the L2 tier sees the *filtered* masses
    ``q_i (1 - h1_i)``, partitioned by ``assign`` (the hash ring's
    key → shard map) and solved per shard at the fixed per-shard
    capacity ``l2_cap``.  This is the mechanism the headline inversion
    rides on: growing L1 absorbs exactly the head of the Zipf curve,
    flattening (and thinning) the stream the L2 coalescer feeds on.
    """
    q = np.asarray(key_probs, np.float64)
    q = q / q.sum()
    assign = np.asarray(assign)
    n = int(n_shards or assign.max() + 1)
    l1_caps = np.asarray(l1_caps, np.float64)
    C = len(l1_caps)
    l1_hit = np.zeros(C)
    w = np.full((C, n), 1.0 / n)
    l2_hit = np.zeros((C, n))
    for ci, c1 in enumerate(l1_caps):
        h1 = che_hit(q, float(c1))
        l1_hit[ci] = float((q * h1).sum())
        m = q * (1.0 - h1)  # filtered (L2-visible) masses
        tot = m.sum()
        if tot <= 0:
            w[ci] = w[ci - 1] if ci else 1.0 / n
            l2_hit[ci] = l2_hit[ci - 1] if ci else 0.0
            continue
        for k in range(n):
            mk = m[assign == k]
            sk = mk.sum()
            if sk <= 0:
                continue
            w[ci, k] = sk / tot
            cond = mk / sk
            l2_hit[ci, k] = float((cond * che_hit(cond, float(l2_cap))).sum())
        w[ci] = w[ci] / w[ci].sum()
    return TieredProfile(caps=l1_caps, l1_hit=l1_hit, shard_weights=w,
                         l2_hit=l2_hit)


def measured_tiered_profile(trace, l1_caps, l2_cap: float, assign,
                            n_clients: int, seed: int = 0,
                            warmup_frac: float = 0.25,
                            n_shards: int | None = None) -> TieredProfile:
    """Measured profile: per-client L1 Mattson sweeps, then per-shard L2
    sweeps of the interleaved miss stream, per L1 capacity.

    Requests are assigned to clients iid-uniformly (seeded); each
    client's substream gets one exact LRU stack-distance sweep over the
    whole ``l1_caps`` grid at once, and for every capacity the surviving
    misses — re-interleaved in trace order, routed by ``assign`` — feed
    one LRU sweep per shard at ``l2_cap``.  Prong C feeding the tiered
    model the way ``measured_shard_profile`` feeds the flat cluster.
    """
    from repro.cache.replay import lru_sweep

    trace = np.asarray(trace)
    if trace.size == 0:
        raise ValueError("measured_tiered_profile needs a non-empty trace")
    assign = np.asarray(assign)
    n = int(n_shards or assign.max() + 1)
    l1_caps = np.asarray(l1_caps, np.float64)
    icaps = np.maximum(l1_caps.astype(int), 0)
    C = len(l1_caps)
    rng = np.random.default_rng(seed)
    client = rng.integers(0, n_clients, size=trace.size)
    warm = int(trace.size * warmup_frac)

    # per-client hits over the whole capacity grid at once: (C, T) bool
    hit_at = np.zeros((C, trace.size), bool)
    for c in range(n_clients):
        sel = client == c
        sub = trace[sel]
        if len(sub) < 8:
            continue
        hits, _ = lru_sweep(sub, np.maximum(icaps, 1))
        hit_at[:, sel] = np.asarray(hits, bool) & (icaps >= 1)[:, None]

    l1_hit = np.zeros(C)
    w = np.full((C, n), 1.0 / n)
    l2_hit = np.zeros((C, n))
    for ci in range(C):
        l1_hit[ci] = float(hit_at[ci, warm:].mean())
        miss_keys = trace[~hit_at[ci]]  # trace order preserved
        if miss_keys.size == 0:
            continue
        shard = assign[miss_keys]
        shares = np.bincount(shard, minlength=n).astype(np.float64)
        if shares.sum() > 0:
            w[ci] = shares / shares.sum()
        for k in range(n):
            sub2 = miss_keys[shard == k]
            if len(sub2) < 8 or l2_cap < 1:
                continue
            hits2, _ = lru_sweep(sub2, np.array([max(int(l2_cap), 1)]))
            w2 = int(len(sub2) * warmup_frac)
            l2_hit[ci, k] = float(np.asarray(hits2)[0, w2:].mean())
    l1_hit = np.maximum.accumulate(l1_hit)  # guard tiny non-monotonicity
    return TieredProfile(caps=l1_caps, l1_hit=l1_hit, shard_weights=w,
                         l2_hit=l2_hit)


# --------------------------------------------------------------------------
# Tier composition
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One tier of the hierarchy: a policy network replicated
    ``n_instances`` times (per client for the L1 tier, per shard for the
    L2 tier).  ``policy`` names a :data:`POLICY_BUILDERS` entry built
    with ``kwargs``; pass ``net`` instead to use an explicit base
    network (the serving engine wraps its measured pod network this
    way).  The tier net's ``disk`` station is a *placeholder* for the
    next tier down and is stripped during composition."""

    policy: str | None = None
    n_instances: int = 1
    name: str = "l1"
    net: ClosedNetwork | None = None
    kwargs: dict = dataclasses.field(default_factory=dict)

    def build(self) -> ClosedNetwork:
        if self.net is not None:
            return self.net
        if self.policy is None:
            raise ValueError(f"tier {self.name!r} needs a policy or a net")
        return POLICY_BUILDERS[self.policy](**self.kwargs)


def _split_at_disk(visits: tuple) -> tuple:
    """(pre, post) around the tier's backing-store placeholder."""
    names = [v.split(":")[-1] for v in visits]
    i = names.index("disk")
    return visits[:i], visits[i + 1:]


def _tier_rename(net: ClosedNetwork, tier: TierSpec):
    """Station name map for instance ``i`` of a tier: queue stations are
    replicated per instance (``l1_0:head``), infinite-server think
    stations are shared across instances (``l1:lookup`` — an infinite
    server partitions trivially, as in the flat cluster composition).
    The tier's own ``disk`` placeholder is excluded (spliced away)."""
    repl = {s.name for s in net.stations
            if s.kind == QUEUE and s.name.split(":")[-1] != "disk"}

    def rename(v: str, i: int) -> str:
        return (f"{tier.name}_{i}:{v}" if v in repl else f"{tier.name}:{v}")

    return repl, rename


def compose_tiers(l1: TierSpec, l2: TierSpec,
                  profile: TieredProfile | None = None,
                  disk_us: float = 100.0, disk_servers: int = 0,
                  mpl: int | None = None,
                  name: str | None = None) -> "HierarchyModel":
    """Compose an L1 tier, a sharded L2 tier and an origin disk into one
    closed network with cross-tier MSHR annotations.

    Composed branch families, per L1 client ``i``:

    * L1 hit routes — client ``i``'s copy of each L1 hit branch,
      probability ``(1/n1) · b1(p1)``;
    * L1 miss routes — for every shard ``k`` and L2 branch ``b2``, the
      L1 miss prefix, then shard ``k``'s copy of ``b2`` (its ``disk``
      placeholder replaced by the shared origin), then the L1 fill
      suffix; probability ``(1/n1) · b1(p1) · w_k · b2(p2_k)``.

    MSHR annotations: every miss route acquires client ``i``'s table at
    its first L2 visit (held-slot 0, released when the last L2-segment
    visit completes — the data is back at the client; the L1 insertion
    suffix happens after the fill lands) and, on an L2-miss route, shard
    ``k``'s origin table at the ``disk`` visit (held-slot 1, released
    when the origin service completes).
    """
    if profile is None:
        profile = TieredProfile.constant(0.5, n_shards=l2.n_instances)
    if profile.n_shards != l2.n_instances:
        raise ValueError(f"profile has {profile.n_shards} shards, tier "
                         f"{l2.name!r} has {l2.n_instances} instances")
    n1, n2 = int(l1.n_instances), int(l2.n_instances)
    if n1 < 1 or n2 < 1:
        raise ValueError("tiers need n_instances >= 1")
    net1, net2 = l1.build(), l2.build()
    memo: dict = {}

    def tp(p: float) -> tuple:
        key = round(float(p), 12)
        if key not in memo:
            memo[key] = profile.tier_p(key)
        return memo[key]

    repl1, ren1 = _tier_rename(net1, l1)
    repl2, ren2 = _tier_rename(net2, l2)

    # ---- stations --------------------------------------------------------
    from repro.core.queueing import disk_station

    stations = [disk_station(disk_us, disk_servers)]
    # L1: shared think stations at p1, queue stations per client at p1.
    for s in net1.stations:
        if s.name.split(":")[-1] == "disk":
            continue
        svc = (lambda p, s=s: s.mean_service(tp(p)[0]))
        if s.name in repl1:
            stations += [dataclasses.replace(s, name=ren1(s.name, i),
                                             service=svc)
                         for i in range(n1)]
        else:
            stations.append(dataclasses.replace(s, name=ren1(s.name, 0),
                                                service=svc))
    # L2: shared think stations at the weight-averaged p2 (all current
    # policies' think services are constant, so this is cosmetic), queue
    # stations per shard at that shard's local p2_k.
    for s in net2.stations:
        if s.name.split(":")[-1] == "disk":
            continue
        if s.name in repl2:
            stations += [dataclasses.replace(
                s, name=ren2(s.name, k),
                service=(lambda p, s=s, k=k: s.mean_service(
                    float(tp(p)[2][k]))))
                for k in range(n2)]
        else:
            stations.append(dataclasses.replace(
                s, name=ren2(s.name, 0),
                service=(lambda p, s=s: s.mean_service(
                    float(np.dot(tp(p)[1], tp(p)[2]))))))

    # ---- branches + MSHR annotations ------------------------------------
    hits1 = [b for b in net1.branches
             if "disk" not in [v.split(":")[-1] for v in b.visits]]
    miss1 = [b for b in net1.branches if b not in hits1]
    hits2 = [b for b in net2.branches
             if "disk" not in [v.split(":")[-1] for v in b.visits]]
    miss2 = [b for b in net2.branches if b not in hits2]
    if not miss1 or not miss2:
        raise ValueError("both tier networks need a miss ('disk') branch")

    branches = []
    branch_client: list = []
    branch_shard: list = []
    branch_level: list = []
    acquires: list = []  # per branch: ((pos, group, slot), ...)
    releases: list = []  # per branch: ((pos, slot), ...)

    def add(b, client, shard, level, acq=(), rel=()):
        branches.append(b)
        branch_client.append(client)
        branch_shard.append(shard)
        branch_level.append(level)
        acquires.append(tuple(acq))
        releases.append(tuple(rel))

    for i in range(n1):
        for b1 in hits1:
            visits = tuple(ren1(v, i) for v in b1.visits)
            add(Branch(
                f"c{i}:{b1.name}",
                (lambda p, b1=b1: b1.probability(tp(p)[0]) / n1),
                visits,
            ), i, -1, 0)
        for b1 in miss1:
            pre1, post1 = _split_at_disk(b1.visits)
            pre1 = tuple(ren1(v, i) for v in pre1)
            post1 = tuple(ren1(v, i) for v in post1)
            for k in range(n2):
                def prob2(p, b1=b1, b2=None, k=k):
                    p1, w, p2 = tp(p)
                    return (b1.probability(p1) / n1 * float(w[k])
                            * b2.probability(float(p2[k])))

                for b2 in hits2:
                    seg = tuple(ren2(v, k) for v in b2.visits)
                    a0 = len(pre1)  # acquire client table entering L2
                    r0 = len(pre1) + len(seg) - 1  # fill: data back at L1
                    add(Branch(
                        f"c{i}:s{k}:{b1.name}.{b2.name}",
                        (lambda p, b2=b2, _f=prob2: _f(p, b2=b2)),
                        pre1 + seg + post1,
                    ), i, k, 1, acq=[(a0, i, 0)], rel=[(r0, 0)])
                for b2 in miss2:
                    pre2, post2 = _split_at_disk(b2.visits)
                    seg = (tuple(ren2(v, k) for v in pre2) + ("disk",)
                           + tuple(ren2(v, k) for v in post2))
                    a0 = len(pre1)
                    a1 = len(pre1) + len(pre2)  # the origin visit
                    r0 = len(pre1) + len(seg) - 1
                    if r0 == a1 and post2:
                        raise AssertionError("release collision")
                    rel = [(a1, 1), (r0, 0)] if r0 != a1 else [(r0, 0)]
                    if r0 == a1:
                        # origin is the last L2 visit: both fills land at
                        # its completion — but distinct slots must release
                        # at distinct positions for the flat (B, L) table.
                        raise ValueError(
                            f"branch {b2.name}: route ends at the disk "
                            "visit; tier networks need at least one "
                            "post-disk fill station")
                    add(Branch(
                        f"c{i}:s{k}:{b1.name}.{b2.name}",
                        (lambda p, b2=b2, _f=prob2: _f(p, b2=b2)),
                        pre1 + seg + post1,
                    ), i, k, 2, acq=[(a0, i, 0), (a1, n1 + k, 1)], rel=rel)

    # rel_slot is one entry per position; merge the (pos, slot) pairs.
    B = len(branches)
    L = max(len(b.visits) for b in branches)
    acq_group = np.full((B, L), -1, np.int32)
    acq_slot = np.full((B, L), -1, np.int32)
    rel_slot = np.full((B, L), -1, np.int32)
    for bi in range(B):
        for pos, g, s in acquires[bi]:
            acq_group[bi, pos] = g
            acq_slot[bi, pos] = s
        for pos, s in releases[bi]:
            if rel_slot[bi, pos] >= 0:
                raise ValueError(f"branch {bi}: two releases at position "
                                 f"{pos}")
            rel_slot[bi, pos] = s
    mshr = MshrSpec(acq_group=acq_group, acq_slot=acq_slot,
                    rel_slot=rel_slot, n_groups=n1 + n2, max_held=2)

    network = ClosedNetwork(
        name or f"{net1.name}-x{n1}->{net2.name}-x{n2}->origin",
        tuple(stations), tuple(branches),
        int(mpl or net1.mpl * n1),
        description=(f"tiered hierarchy: {n1} {net1.name} L1 clients -> "
                     f"{n2} {net2.name} L2 shards -> origin "
                     f"({disk_us:g}us)"),
    )
    network.validate()
    visits_pad = np.full((B, L), -1, np.int32)
    for bi, b in enumerate(branches):
        visits_pad[bi, :len(b.visits)] = 0  # shape/structure check only
    mshr.validate(visits_pad)
    return HierarchyModel(
        l1=net1, l2=net2, network=network, profile=profile,
        n_clients=n1, n_shards=n2,
        branch_client=tuple(branch_client),
        branch_shard=tuple(branch_shard),
        branch_level=tuple(branch_level),
        mshr=mshr,
    )


@dataclasses.dataclass(frozen=True)
class HierarchyModel:
    """A composed hierarchy: the network plus its tier bookkeeping.

    ``branch_level`` classifies every composed branch by where its
    request is ultimately served: 0 = L1 hit, 1 = L2 hit, 2 = origin.
    """

    l1: ClosedNetwork
    l2: ClosedNetwork
    network: ClosedNetwork
    profile: TieredProfile
    n_clients: int
    n_shards: int
    branch_client: tuple  # composed-branch index -> client (-1 n/a)
    branch_shard: tuple  # composed-branch index -> shard (-1 for L1 hits)
    branch_level: tuple  # 0 = L1 hit, 1 = L2 hit, 2 = origin
    mshr: MshrSpec

    # ---- analytic delegation --------------------------------------------
    def throughput_upper(self, p_hit, tail_mode: str = "zero"):
        return self.network.throughput_upper(p_hit, tail_mode=tail_mode)

    def mva_throughput(self, p_hit, **kw):
        return self.network.mva_throughput(p_hit, **kw)

    def p_star(self, tail_mode: str = "zero", grid: int = 2001) -> float:
        return self.network.p_star(tail_mode=tail_mode, grid=grid)

    def lambda_max(self, p_hit, tail_mode: str = "zero"):
        from repro.latency import lambda_max

        return lambda_max(self.network, p_hit, tail_mode=tail_mode)

    def response_time(self, p_hit, arrival_rate: float,
                      tail_mode: str = "nominal"):
        from repro.latency import response_time

        return response_time(self.network, p_hit, arrival_rate,
                             tail_mode=tail_mode)

    def level_fractions(self, p_hit: float) -> np.ndarray:
        """Analytic [L1-hit, L2-hit, origin] shares of completions."""
        out = np.zeros(3)
        for b, lvl in zip(self.network.branches, self.branch_level):
            out[lvl] += b.probability(p_hit)
        return out

    def coalesced(self, flows: int = 64, window_us=None,
                  flow_theta: float = 0.0) -> ClosedNetwork:
        """Analytic cross-tier coalescing transform of this hierarchy
        (see :func:`coalesced_hierarchy`)."""
        return coalesced_hierarchy(self, flows=flows, window_us=window_us,
                                   flow_theta=flow_theta)


def hierarchy_network(l1_policy: str, l2_policy: str, n_clients: int,
                      n_shards: int,
                      profile: TieredProfile | None = None,
                      disk_us: float = 100.0, disk_servers: int = 0,
                      mpl: int | None = None, l1_kwargs: dict | None = None,
                      l2_kwargs: dict | None = None) -> HierarchyModel:
    """Convenience builder mirroring ``cluster_network``: two policy
    names and instance counts in, a composed :class:`HierarchyModel`
    out."""
    return compose_tiers(
        TierSpec(l1_policy, n_clients, name="l1",
                 kwargs=dict(l1_kwargs or {})),
        TierSpec(l2_policy, n_shards, name="l2",
                 kwargs=dict(l2_kwargs or {})),
        profile=profile, disk_us=disk_us, disk_servers=disk_servers,
        mpl=mpl,
    )


# --------------------------------------------------------------------------
# Analytic cross-tier coalescing
# --------------------------------------------------------------------------


def coalesced_hierarchy(model: HierarchyModel, flows: int = 64,
                        window_us=None,
                        flow_theta: float = 0.0) -> ClosedNetwork:
    """Tiered generalization of
    :func:`repro.core.queueing.coalesced_network`: one coalescing factor
    per MSHR *table* — ``sigma1`` for the (symmetric) per-client L1
    tables and ``sigma2_k`` for each shard-local origin table — solved
    as a joint fixed point with the throughput bound.

    Every miss branch splits three ways:

    * **park@L1** (probability × ``sigma1``): a same-flow fetch from this
      client is already in flight — the request keeps its pre-L2 visits,
      parks on ``l1:inflight`` for the expected wait (:func:`_wait_frac`
      of the L1 window — mean residual for fresh arrivals, the *full*
      next window for fill-synchronized re-parkers) and completes with
      the fill;
    * **park@origin** (× ``(1-sigma1)·sigma2_k``, L2-miss routes only):
      it leads its client's table but finds shard ``k``'s origin fetch
      in flight — pre-origin visits, then the expected origin wait on
      ``l2:inflight``;
    * **survivor** (× the complement): the full original route.

    Windows: the origin window is the origin service time (or
    ``window_us``); the L1 window is the expected L2 round-trip of a
    *leader* — hit-segment services, or miss pre-visits plus either the
    full origin trip + fill metadata (surviving) or the expected origin
    wait (parked), mixed over shards.  The fixed point evaluates X with
    exact MVA on the transformed network (the asymptotic bound is far
    too optimistic at moderate MPL and circularly inflates sigma).  Per-flow fill rates scale the
    miss masses the way the simulators route them: ``X(1-p1)/n1`` per
    client table, ``X(1-p1)w_k(1-p2_k)(1-sigma1)`` per origin table —
    the ``(1-sigma1)`` is the *starvation coupling*: the more the L1
    tables coalesce (or the higher p1 itself), the thinner the stream
    feeding the origin tables, so deep coalescing dies first.
    """
    net = model.network
    n1, n2 = model.n_clients, model.n_shards
    weights = zipf_flow_weights(flows, flow_theta)
    origin = net.station("disk")
    w2_fn = _as_fn(window_us) if window_us is not None else origin.mean_service

    hits2 = [b for b in model.l2.branches
             if "disk" not in [v.split(":")[-1] for v in b.visits]]
    miss2 = [b for b in model.l2.branches if b not in hits2]
    svc2 = {s.name: s for s in model.l2.stations}

    def seg_service(visits, p2k: float) -> float:
        return sum(svc2[v].mean_service(p2k) for v in visits
                   if v.split(":")[-1] != "disk")

    # per-branch annotation views (positions of the acquires)
    ann = []
    ag, asl = np.asarray(model.mshr.acq_group), np.asarray(model.mshr.acq_slot)
    for bi in range(len(net.branches)):
        a0 = np.nonzero(asl[bi] == 0)[0]
        a1 = np.nonzero(asl[bi] == 1)[0]
        ann.append((int(a0[0]) if a0.size else -1,
                    int(a1[0]) if a1.size else -1))

    memo: dict = {}

    def solve(p: float) -> tuple:
        key = round(float(p), 12)
        if key in memo:
            return memo[key]
        p1, w, p2 = model.profile.tier_p(p)
        W2 = float(w2_fn(p))
        s1, s2 = 0.0, np.zeros(n2)

        def l1_window(s1v, s2v) -> float:
            tot = 0.0
            for k in range(n2):
                p2k = float(p2[k])
                hit = sum(b.probability(p2k)
                          * seg_service(b.visits, p2k) for b in hits2)
                ms = 0.0
                for b in miss2:
                    pre, post = _split_at_disk(b.visits)
                    ms += b.probability(p2k) * (
                        seg_service(pre, p2k)
                        + (1.0 - s2v[k]) * (W2 + seg_service(post, p2k))
                        + s2v[k] * _wait_frac(s2v[k]) * W2)
                tot += float(w[k]) * (hit + ms)
            return tot

        for _ in range(100):
            W1 = l1_window(s1, s2)
            wait1 = _wait_frac(s1) * W1
            wait2 = _wait_frac(float(s2.mean())) * W2
            net_s = _build(model, ann, lambda _p: s1,
                           lambda _p: s2, lambda _p: wait1,
                           lambda _p: wait2)
            X = float(net_s.mva_throughput(p))
            mu1 = X * (1.0 - p1) / n1 * weights
            s1_new = float((weights * mu1 * W1 / (1.0 + mu1 * W1)).sum())
            s2_new = np.zeros(n2)
            for k in range(n2):
                mu2 = (X * (1.0 - p1) * float(w[k])
                       * (1.0 - float(p2[k])) * (1.0 - s1_new) * weights)
                s2_new[k] = float(
                    (weights * mu2 * W2 / (1.0 + mu2 * W2)).sum())
            if (abs(s1_new - s1) < 1e-12
                    and float(np.abs(s2_new - s2).max()) < 1e-12):
                s1, s2 = s1_new, s2_new
                break
            # W1 couples to sigma2; damp the joint iteration
            s1 = 0.5 * (s1 + s1_new)
            s2 = 0.5 * (s2 + s2_new)
        memo[key] = (s1, s2.copy(),
                     _wait_frac(s1) * l1_window(s1, s2),
                     _wait_frac(float(s2.mean())) * W2)
        return memo[key]

    return _build(model, ann,
                  lambda p: solve(p)[0], lambda p: solve(p)[1],
                  lambda p: solve(p)[2], lambda p: solve(p)[3])


def tier_sigma_of(net: ClosedNetwork, p_hit: float) -> tuple:
    """Recover ``(sigma1, sigma2)`` of a :func:`coalesced_hierarchy`
    network from its branch masses: the fraction of L1 misses that
    parked at a client table, and the fraction of *L1-table leaders*
    whose origin fetch was already in flight.  (0.0, 0.0) for a network
    without the tiered transform — the tiered counterpart of
    :func:`repro.core.queueing.sigma_of`, reading the ``_park1`` /
    ``_park2`` naming this module's transform creates."""
    park1 = sum(b.probability(p_hit) for b in net.branches
                if b.name.endswith("_park1"))
    park2 = sum(b.probability(p_hit) for b in net.branches
                if b.name.endswith("_park2"))
    lead = sum(
        b.probability(p_hit) for b in net.branches
        if "disk" in [v.split(":")[-1] for v in b.visits]
        or b.name.endswith("_park2")
        or (not b.name.endswith(("_park1", "_park2"))
            and any(v.startswith("l2") for v in b.visits))
    )
    misses = park1 + lead
    s1 = park1 / misses if misses > 0 else 0.0
    s2 = park2 / lead if lead > 0 else 0.0
    return s1, s2


def _wait_frac(sigma: float) -> float:
    """Expected parked wait as a fraction of the in-flight window.

    A job arriving at a busy MSHR entry mid-window waits the mean
    residual (0.5 of the window), but a job *woken by a fill* that
    immediately re-misses on the same flow parks at the very start of
    the next window and waits all of it.  The fill-synchronized share
    of parked arrivals is approximately ``sigma`` itself (the fraction
    of miss completions that were themselves parked), giving the convex
    mix ``0.5·(1-sigma) + 1.0·sigma``."""
    return 0.5 * (1.0 + float(sigma))


def _build(model: HierarchyModel, ann, s1_fn, s2_fn, w1_fn, w2_fn
           ) -> ClosedNetwork:
    """Materialize the park/survive branch variants at given sigma/window
    functions (all callables of the global p).  ``w1_fn``/``w2_fn``
    give the *expected parked wait* directly (residual weighting
    included by the caller)."""
    net = model.network
    stations = net.stations + (
        Station("l1:inflight", THINK, w1_fn, dist="exp"),
        Station("l2:inflight", THINK, w2_fn, dist="exp"),
    )
    branches = []
    for bi, b in enumerate(net.branches):
        a0, a1 = ann[bi]
        if a0 < 0:
            branches.append(b)
            continue
        pf = _as_fn(b.prob)
        k = model.branch_shard[bi]
        branches.append(Branch(
            b.name + "_park1",
            (lambda p, pf=pf: pf(p) * s1_fn(p)),
            b.visits[:a0] + ("l1:inflight",),
        ))
        if a1 < 0:
            branches.append(dataclasses.replace(
                b, prob=(lambda p, pf=pf: pf(p) * (1.0 - s1_fn(p)))))
        else:
            branches.append(Branch(
                b.name + "_park2",
                (lambda p, pf=pf, k=k: pf(p) * (1.0 - s1_fn(p))
                 * float(s2_fn(p)[k])),
                b.visits[:a1] + ("l2:inflight",),
            ))
            branches.append(dataclasses.replace(
                b, prob=(lambda p, pf=pf, k=k: pf(p) * (1.0 - s1_fn(p))
                         * (1.0 - float(s2_fn(p)[k])))))
    return dataclasses.replace(
        net, name=net.name + "+coalesce", stations=stations,
        branches=tuple(branches),
    )
