"""Entry point: ``python -m tools.analysis [--only a,b] [--root PATH]``.

Exit status 0 when clean, 1 when any violation survives waivers.  CI
gates on this (the ``static-analysis`` job); the docs job runs
``--only docs_paths``.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import Dict, List

from . import CHECKERS, RULES
from .base import Note, SourceFile, Violation, apply_waivers, load_sources

SOURCE_DIRS = ("src/repro",)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.analysis",
        description="Twin-contract & jit-safety static analysis suite.",
    )
    parser.add_argument(
        "--only", default=None, metavar="CHECKERS",
        help="comma-separated subset of: " + ", ".join(CHECKERS),
    )
    parser.add_argument(
        "--root", default=None, metavar="PATH",
        help="repo root to analyze (default: this file's repo)",
    )
    parser.add_argument(
        "--quiet", action="store_true",
        help="suppress informational notes (violations still print)",
    )
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the rule ids per checker and exit",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for checker, rules in RULES.items():
            print(f"{checker}: {', '.join(rules)}")
        return 0

    root = Path(args.root).resolve() if args.root \
        else Path(__file__).resolve().parents[2]
    selected = list(CHECKERS)
    if args.only:
        selected = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = [s for s in selected if s not in CHECKERS]
        if unknown:
            parser.error(
                f"unknown checker(s) {', '.join(unknown)}; "
                f"choose from {', '.join(CHECKERS)}"
            )

    sources_list = load_sources(root, SOURCE_DIRS)
    sources: Dict[Path, SourceFile] = {s.path: s for s in sources_list}

    violations: List[Violation] = []
    notes: List[Note] = []
    for src in sources_list:
        if src.parse_error is not None:
            violations.append(Violation(
                "syntax", src.path, src.parse_error.lineno or 1,
                f"cannot parse: {src.parse_error.msg}",
            ))
        violations.extend(src.waiver_violations)

    for name in selected:
        found, info = CHECKERS[name](root, sources)
        violations.extend(found)
        notes.extend(info)

    violations = apply_waivers(sources, violations)
    violations.sort(key=lambda v: (str(v.path), v.line, v.rule))

    if not args.quiet:
        for note in notes:
            print(f"note: {note.text}")
    for v in violations:
        print(v.render(root))
    if violations:
        print(f"{len(violations)} violation(s) "
              f"[checkers: {', '.join(selected)}]", file=sys.stderr)
        return 1
    if not args.quiet:
        print(f"clean [checkers: {', '.join(selected)}]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
