"""Import-graph report for ``src/repro``: cycles, dead imports, and the
dormant-wing map.

* ``imports-cycle`` — a cycle in the ``repro.*`` module DAG gates CI:
  the repo's layering (``cache/`` and ``core/queueing.py`` at the
  bottom, ``serving/`` on top — see ``docs/ARCHITECTURE.md``) only stays
  enforceable while the graph is acyclic.
* ``imports-dead`` — a name imported but never used in its module.
  ``__init__.py`` re-exports are exempt when listed in ``__all__``.
* The **dormant-wing report** (notes, not violations) classifies modules
  unreachable from any test/benchmark/example import — the
  machine-generated map ROADMAP item 1's wiring work starts from.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

from .base import Note, SourceFile, Violation, module_name_for

_ROOT_DIRS = ("tests", "benchmarks", "examples")


def _resolve_relative(package: str, level: int,
                      target: Optional[str]) -> str:
    """Resolve ``from ..x import y`` seen in a module whose enclosing
    package is ``package`` (level 1 = that package itself)."""
    parts = package.split(".") if package else []
    if level > 1:
        parts = parts[: len(parts) - (level - 1)]
    base = ".".join(parts)
    if target:
        return f"{base}.{target}" if base else target
    return base


def _imported_modules(src: SourceFile, module: str,
                      known: Set[str], is_init: bool = False) -> Set[str]:
    """repro.* modules imported by ``src`` (edges of the DAG)."""
    package = module if is_init else module.rpartition(".")[0]
    out: Set[str] = set()
    assert src.tree is not None
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out.add(alias.name)
        elif isinstance(node, ast.ImportFrom):
            base = node.module or ""
            if node.level:
                base = _resolve_relative(package, node.level, node.module)
            if base:
                # `from repro.core import simulator` imports submodules;
                # only names NOT resolving to a submodule pull in the
                # package __init__ itself (else every re-export package
                # would look like a cycle)
                needs_base = False
                for alias in node.names:
                    cand = f"{base}.{alias.name}"
                    if cand in known:
                        out.add(cand)
                    else:
                        needs_base = True
                if needs_base:
                    out.add(base)
    resolved: Set[str] = set()
    for name in out:
        # collapse to the nearest known repro module (package __init__)
        probe = name
        while probe:
            if probe in known:
                resolved.add(probe)
                break
            probe = probe.rpartition(".")[0]
    resolved.discard(module)
    return resolved


def _find_cycles(graph: Dict[str, Set[str]]) -> List[List[str]]:
    WHITE, GREY, BLACK = 0, 1, 2
    color = {n: WHITE for n in graph}
    stack: List[str] = []
    cycles: List[List[str]] = []

    def dfs(n: str) -> None:
        color[n] = GREY
        stack.append(n)
        for m in sorted(graph.get(n, ())):
            if m not in color:
                continue
            if color[m] == GREY:
                i = stack.index(m)
                cycles.append(stack[i:] + [m])
            elif color[m] == WHITE:
                dfs(m)
        stack.pop()
        color[n] = BLACK

    for n in sorted(graph):
        if color[n] == WHITE:
            dfs(n)
    return cycles


def _used_names(tree: ast.Module) -> Set[str]:
    used: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            # record the root of dotted access: `repro.core.x` uses `repro`
            v = node
            while isinstance(v, ast.Attribute):
                v = v.value
            if isinstance(v, ast.Name):
                used.add(v.id)
    return used


def _all_exports(tree: ast.Module) -> Set[str]:
    out: Set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__" \
                        and isinstance(node.value, (ast.List, ast.Tuple)):
                    for elt in node.value.elts:
                        if isinstance(elt, ast.Constant) \
                                and isinstance(elt.value, str):
                            out.add(elt.value)
    return out


def _dead_imports(src: SourceFile, is_init: bool) -> List[Violation]:
    assert src.tree is not None
    used = _used_names(src.tree)
    exports = _all_exports(src.tree)
    out: List[Violation] = []
    for node in ast.walk(src.tree):
        names: List[Tuple[str, str, int]] = []  # (bound name, shown, line)
        if isinstance(node, ast.Import):
            for alias in node.names:
                bound = alias.asname or alias.name.split(".")[0]
                names.append((bound, alias.name, node.lineno))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for alias in node.names:
                if alias.name == "*":
                    continue
                bound = alias.asname or alias.name
                names.append((bound, alias.name, node.lineno))
        for bound, shown, line in names:
            if bound.startswith("_"):
                continue
            if bound in used:
                continue
            if is_init and (bound in exports or not exports):
                continue  # re-export surface
            if bound in exports:
                continue
            out.append(Violation(
                "imports-dead", src.path, line,
                f"'{shown}' is imported but never used (and not "
                f"re-exported via __all__)",
            ))
    return out


_WING_LABELS = {
    "repro.models": "model zoo",
    "repro.training": "training scaffolding",
    "repro.launch": "launch scaffolding",
    "repro.configs": "config presets",
    "repro.kernels": "Pallas kernels",
}


def run(
    root: Path, sources: Mapping[Path, SourceFile]
) -> Tuple[List[Violation], List[Note]]:
    # --- module universe: everything under src/repro -------------------
    modules: Dict[str, SourceFile] = {}
    for path, src in sources.items():
        name = module_name_for(root, path)
        if name and src.tree is not None:
            modules[name] = src
    known = set(modules)

    graph: Dict[str, Set[str]] = {
        name: _imported_modules(src, name, known,
                                is_init=src.path.name == "__init__.py")
        for name, src in modules.items()
    }

    violations: List[Violation] = []
    notes: List[Note] = []

    # --- cycles --------------------------------------------------------
    seen_cycles: Set[frozenset] = set()
    for cycle in _find_cycles(graph):
        key = frozenset(cycle)
        if key in seen_cycles:
            continue
        seen_cycles.add(key)
        head = cycle[0]
        violations.append(Violation(
            "imports-cycle", modules[head].path, 1,
            "import cycle: " + " -> ".join(cycle),
        ))

    # --- dead imports --------------------------------------------------
    for name in sorted(modules):
        src = modules[name]
        violations.extend(_dead_imports(src, src.path.name == "__init__.py"))

    # --- dormant-wing report (informational) ---------------------------
    roots: Set[str] = set()
    for rel in _ROOT_DIRS:
        base = root / rel
        if not base.is_dir():
            continue
        for path in base.rglob("*.py"):
            try:
                tree = ast.parse(path.read_text())
            except SyntaxError:
                continue
            probe_src = SourceFile(path, path.read_text())
            if probe_src.tree is None:
                continue
            roots |= _imported_modules(probe_src, f"__root__.{path.stem}",
                                       known)
    reachable: Set[str] = set()
    frontier = [m for m in roots if m in graph]
    while frontier:
        m = frontier.pop()
        if m in reachable:
            continue
        reachable.add(m)
        frontier.extend(graph.get(m, ()))
        # importing a module pulls in its package __init__ chain
        parent = m.rpartition(".")[0]
        if parent in graph:
            frontier.append(parent)

    dormant = sorted(set(modules) - reachable)
    wings: Dict[str, List[str]] = {}
    isolated: List[str] = []
    for m in dormant:
        for prefix, label in _WING_LABELS.items():
            if m == prefix or m.startswith(prefix + "."):
                wings.setdefault(f"{prefix} ({label})", []).append(m)
                break
        else:
            isolated.append(m)
    notes.append(Note(
        f"import-graph: {len(modules)} modules, "
        f"{len(reachable)} reachable from {'/'.join(_ROOT_DIRS)}, "
        f"{len(dormant)} dormant"
    ))
    for wing in sorted(wings):
        mods = wings[wing]
        notes.append(Note(
            f"  dormant wing {wing}: {len(mods)} modules — "
            + ", ".join(m.removeprefix('repro.') for m in mods)
        ))
    if isolated:
        notes.append(Note(
            "  dormant outside known wings: " + ", ".join(isolated)
        ))
    return violations, notes
