"""Static-analysis suite for the repo's twin contracts and jit/unit
conventions.  Run ``python -m tools.analysis`` from the repo root; see
``docs/ARCHITECTURE.md`` for the rule reference.

Checkers (selectable via ``--only``):

=============  =====================================================
``contracts``  twin-contract registry (jax fast path vs Python oracle)
``jit``        tracing-safety lint over jit/scan/vmap-reachable code
``units``      ``_ns``/``_us``/``_rate`` suffix-mixing lint
``imports``    import-graph cycles, dead imports, dormant-wing report
``docs_paths`` README/docs path references must exist
``obs``        telemetry conventions: metric-name unit suffixes,
               shape-static trace rings under jit
=============  =====================================================
"""

from __future__ import annotations

from . import (contracts, docs_paths, import_graph, jit_lint, obs_lint,
               units_lint)

CHECKERS = {
    "contracts": contracts.run,
    "jit": jit_lint.run,
    "units": units_lint.run,
    "imports": import_graph.run,
    "docs_paths": docs_paths.run,
    "obs": obs_lint.run,
}

RULES = {
    "contracts": ("twin-missing", "twin-kwargs", "twin-default",
                  "twin-allowlist"),
    "jit": ("jit-pyflow", "jit-coerce", "jit-mutable-default",
            "jit-hash64"),
    "units": ("units-mix", "units-assign"),
    "imports": ("imports-cycle", "imports-dead"),
    "docs_paths": ("docs-paths",),
    "obs": ("obs-units", "obs-ring-static"),
    "_base": ("waiver-reason",),
}

__all__ = ["CHECKERS", "RULES", "main"]


def main(argv=None) -> int:
    from .__main__ import main as _main
    return _main(argv)
