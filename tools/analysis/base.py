"""Shared infrastructure for the static-analysis suite.

Everything here is stdlib-only (``ast`` + ``pathlib``): the checkers parse
source text and never import the code under analysis, so the suite runs in
any environment — including ones without jax.

Violations, waivers
-------------------
A checker emits :class:`Violation` records.  Any violation can be waived
in the source with a trailing (or immediately preceding, comment-only-line)
marker::

    x_ns = t_us + 3  # analysis: ignore[units-mix] -- t_us is pre-scaled

The rule list is comma-separated; ``ignore[*]`` waives every rule on that
line.  The ``-- reason`` clause is mandatory: a waiver without one is
itself reported (rule ``waiver-reason``), so suppressions stay auditable.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Sequence, Set, Tuple

WAIVER_RE = re.compile(
    r"#\s*analysis:\s*ignore\[([^\]]*)\]\s*(?:--\s*(\S.*))?"
)


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding: ``path:line: [rule] message``."""

    rule: str
    path: Path
    line: int
    message: str

    def render(self, root: Path) -> str:
        try:
            rel = self.path.relative_to(root)
        except ValueError:
            rel = self.path
        return f"{rel}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Note:
    """Informational output (reports, not gates) — e.g. the dormant-wing map."""

    text: str


class SourceFile:
    """A parsed source file plus its waiver table."""

    def __init__(self, path: Path, text: str | None = None):
        self.path = path
        self.text = path.read_text() if text is None else text
        self.lines = self.text.splitlines()
        self.tree: ast.Module | None = None
        self.parse_error: SyntaxError | None = None
        try:
            self.tree = ast.parse(self.text)
        except SyntaxError as exc:  # surfaced by the runner, not swallowed
            self.parse_error = exc
        self.waivers, self.waiver_violations = _collect_waivers(
            self.path, self.lines
        )

    def waived(self, rule: str, line: int) -> bool:
        rules = self.waivers.get(line)
        return bool(rules) and ("*" in rules or rule in rules)


def _collect_waivers(
    path: Path, lines: Sequence[str]
) -> Tuple[Dict[int, Set[str]], List[Violation]]:
    waivers: Dict[int, Set[str]] = {}
    problems: List[Violation] = []
    for i, raw in enumerate(lines, start=1):
        m = WAIVER_RE.search(raw)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        reason = (m.group(2) or "").strip()
        if not rules:
            problems.append(Violation(
                "waiver-reason", path, i,
                "waiver lists no rules: use ignore[rule] or ignore[*]",
            ))
            continue
        if not reason:
            problems.append(Violation(
                "waiver-reason", path, i,
                "waiver is missing a reason: write "
                "'# analysis: ignore[rule] -- why'",
            ))
            continue
        target = i
        # A line that is *only* the waiver comment waives the next line.
        if raw.split("#", 1)[0].strip() == "":
            target = i + 1
        waivers.setdefault(target, set()).update(rules)
    return waivers, problems


def iter_py_files(root: Path, rel_dirs: Iterable[str]) -> List[Path]:
    """Python files under ``root`` restricted to ``rel_dirs`` (sorted)."""
    out: List[Path] = []
    for rel in rel_dirs:
        base = root / rel
        if base.is_file() and base.suffix == ".py":
            out.append(base)
        elif base.is_dir():
            out.extend(p for p in base.rglob("*.py"))
    return sorted(set(out))


def load_sources(root: Path, rel_dirs: Iterable[str]) -> List[SourceFile]:
    sources = []
    for path in iter_py_files(root, rel_dirs):
        sources.append(SourceFile(path))
    return sources


def apply_waivers(
    sources: Dict[Path, SourceFile], violations: Iterable[Violation]
) -> List[Violation]:
    """Drop violations waived at their line; keep everything else."""
    kept = []
    for v in violations:
        src = sources.get(v.path)
        if src is not None and src.waived(v.rule, v.line):
            continue
        kept.append(v)
    return kept


def module_name_for(root: Path, path: Path) -> str | None:
    """``src/repro/core/simulator.py`` -> ``repro.core.simulator``."""
    src = root / "src"
    try:
        rel = path.relative_to(src)
    except ValueError:
        return None
    parts = list(rel.with_suffix("").parts)
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def resolve_module_path(root: Path, module: str) -> Path | None:
    """``repro.core.simulator`` -> ``src/repro/core/simulator.py`` (or
    the package ``__init__.py``)."""
    base = root / "src" / Path(*module.split("."))
    if base.with_suffix(".py").is_file():
        return base.with_suffix(".py")
    if (base / "__init__.py").is_file():
        return base / "__init__.py"
    return None
