"""Observability conventions lint (the telemetry prong's gate).

Two rules over ``src/repro``:

``obs-units``
    Metric names are self-describing only if they carry a unit suffix
    (``_ns``/``_us``/``_ms``/``_s``/``_rate``/``_count``/``_frac``/
    ``_ratio``/``_bytes`` — the :data:`repro.obs.metrics.UNIT_SUFFIXES`
    convention).  Flags (a) string-literal metric names passed to
    ``<...>.metrics.count/gauge/observe(...)`` registry calls that lack
    one, and (b) time-like record fields declared in ``repro.obs``
    schema classes (``enter``/``leave``/``parked``/``sojourn``/
    ``elapsed``/``latency``/``duration`` stems) without a time suffix —
    a trace schema whose timestamps don't say their unit is how µs/ns
    bugs get in.

``obs-units`` additionally covers estimator state: fields of
``repro.obs`` schema classes whose stem marks them as windowed or EWMA
estimator state (``win``/``window``/``ewma``) must say what they hold —
a unit suffix, or an ``_id``/``_index``/``_key`` identity suffix.

``obs-ring-static``
    Every in-kernel observability buffer must be shape-static under
    jit: a ``jax.jit``-decorated function that takes any of the
    :data:`_STATIC_OBS_PARAMS` (``trace_cap``, ``sketch_cap``,
    ``window_us``) must list it in ``static_argnames`` — a traced
    capacity would make the ring/sketch shapes dynamic (and ``if
    trace_cap:`` gating silently truthy on the tracer); a traced
    ``window_us`` would retrace the tumbling-window arithmetic per
    value anyway.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Mapping, Optional, Tuple

from .base import Note, SourceFile, Violation

# Mirrors repro.obs.metrics.UNIT_SUFFIXES (kept literal: the analysis
# suite is stdlib-only and never imports the code under test).
UNIT_SUFFIXES = ("_ns", "_us", "_ms", "_s", "_rate", "_count", "_frac",
                 "_ratio", "_bytes")

_REGISTRY_METHODS = {"count", "gauge", "observe"}
_TIME_STEMS = ("enter", "leave", "parked", "sojourn", "elapsed", "latency",
               "duration", "start", "end", "wall", "compile")
_TIME_SUFFIXES = ("_ns", "_us", "_ms", "_s")
# Estimator-state stems: windowed / EWMA fields must say what they hold —
# a unit suffix, or an identity suffix for ids and sketch keys.
_ESTIMATOR_STEMS = ("win", "window", "ewma")
_IDENTITY_SUFFIXES = ("_id", "_index", "_key")
# In-kernel observability knobs that size compiled buffers (or, for
# window_us, parameterize shape-adjacent arithmetic): must be static.
_STATIC_OBS_PARAMS = ("trace_cap", "sketch_cap", "window_us")


def _has_unit_suffix(name: str) -> bool:
    return any(name.endswith(s) and len(name) > len(s)
               for s in UNIT_SUFFIXES)


def _is_metrics_registry(node: ast.AST) -> bool:
    """True for ``metrics`` / ``self.metrics`` / ``eng.metrics`` — the
    receiver idiom of :class:`repro.obs.metrics.Metrics` calls."""
    if isinstance(node, ast.Name):
        return node.id in ("metrics", "_metrics")
    if isinstance(node, ast.Attribute):
        return node.attr in ("metrics", "_metrics")
    return False


def _check_metric_calls(src: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    assert src.tree is not None
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if not (isinstance(fn, ast.Attribute)
                and fn.attr in _REGISTRY_METHODS
                and _is_metrics_registry(fn.value)):
            continue
        if not node.args:
            continue
        name_arg = node.args[0]
        if isinstance(name_arg, ast.Constant) \
                and isinstance(name_arg.value, str) \
                and not _has_unit_suffix(name_arg.value):
            out.append(Violation(
                "obs-units", src.path, node.lineno,
                f"metric name {name_arg.value!r} lacks a unit suffix "
                f"({', '.join(UNIT_SUFFIXES)}) — see repro.obs.metrics",
            ))
    return out


def _check_schema_fields(src: SourceFile) -> List[Violation]:
    """Time-like fields of obs schema classes must carry a time suffix."""
    out: List[Violation] = []
    assert src.tree is not None
    for cls in src.tree.body:
        if not isinstance(cls, ast.ClassDef):
            continue
        for stmt in cls.body:
            if not (isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)):
                continue
            name = stmt.target.id
            if name.startswith("_"):
                continue
            stem = name.split("_")[0]
            if stem in _TIME_STEMS and not any(
                    name.endswith(s) for s in _TIME_SUFFIXES):
                out.append(Violation(
                    "obs-units", src.path, stmt.lineno,
                    f"time-like schema field '{cls.name}.{name}' lacks a "
                    f"time-unit suffix ({', '.join(_TIME_SUFFIXES)})",
                ))
            elif stem in _ESTIMATOR_STEMS and not (
                    _has_unit_suffix(name)
                    or any(name.endswith(s) for s in _IDENTITY_SUFFIXES)):
                out.append(Violation(
                    "obs-units", src.path, stmt.lineno,
                    f"estimator state field '{cls.name}.{name}' lacks a "
                    f"unit suffix ({', '.join(UNIT_SUFFIXES)}) or identity "
                    f"suffix ({', '.join(_IDENTITY_SUFFIXES)})",
                ))
    return out


def _jit_static_argnames(dec: ast.AST) -> Optional[List[str]]:
    """``static_argnames`` of a jit decorator, or None if not a jit.

    Handles ``@jax.jit``, ``@jit``, ``@functools.partial(jax.jit, ...)``
    and ``@partial(jit, static_argnames=(...))``.
    """
    def leaf(n: ast.AST) -> str:
        if isinstance(n, ast.Attribute):
            return n.attr
        if isinstance(n, ast.Name):
            return n.id
        return ""

    if leaf(dec) == "jit":
        return []
    if isinstance(dec, ast.Call):
        if leaf(dec.func) == "jit":
            call = dec
        elif leaf(dec.func) == "partial" and dec.args \
                and leaf(dec.args[0]) == "jit":
            call = dec
        else:
            return None
        for kw in call.keywords:
            if kw.arg in ("static_argnames", "static_argnums"):
                names: List[str] = []
                for n in ast.walk(kw.value):
                    if isinstance(n, ast.Constant) \
                            and isinstance(n.value, str):
                        names.append(n.value)
                return names
        return []
    return None


def _check_ring_static(src: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    assert src.tree is not None
    for node in ast.walk(src.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        params = {a.arg for a in (node.args.posonlyargs + node.args.args
                                  + node.args.kwonlyargs)}
        obs_params = [p for p in _STATIC_OBS_PARAMS if p in params]
        if not obs_params:
            continue
        for dec in node.decorator_list:
            statics = _jit_static_argnames(dec)
            if statics is None:
                continue
            for p in obs_params:
                if p not in statics:
                    out.append(Violation(
                        "obs-ring-static", src.path, node.lineno,
                        f"jit-decorated '{node.name}' takes {p} but does "
                        f"not list it in static_argnames — in-kernel "
                        f"observability buffers must be compile-time "
                        f"static",
                    ))
    return out


def run(
    root: Path, sources: Mapping[Path, SourceFile]
) -> Tuple[List[Violation], List[Note]]:
    violations: List[Violation] = []
    obs_dir = root / "src" / "repro" / "obs"
    checked = 0
    for path in sorted(sources):
        src = sources[path]
        if src.tree is None:
            continue
        checked += 1
        violations.extend(_check_metric_calls(src))
        violations.extend(_check_ring_static(src))
        if str(path).startswith(str(obs_dir)):
            violations.extend(_check_schema_fields(src))
    notes = [Note(f"obs-lint: {checked} files (metric suffixes, trace-ring "
                  f"static shapes)")]
    return violations, notes
