"""``docs-paths``: every repo path mentioned in README/docs must exist.

Folded in from ``tools/check_readme_paths.py`` (which now delegates
here) so the docs CI job and the static-analysis job share one entry
point: ``python -m tools.analysis --only docs_paths``.
"""

from __future__ import annotations

import re
from pathlib import Path
from typing import List, Mapping, Tuple

from .base import Note, SourceFile, Violation

PATH_RE = re.compile(
    r"\b((?:benchmarks|examples|tools|src|tests|docs)/[\w./-]+\.(?:py|md))\b"
)


def _check_file(root: Path, doc: Path) -> List[Violation]:
    out: List[Violation] = []
    for lineno, line in enumerate(doc.read_text().splitlines(), start=1):
        for m in PATH_RE.finditer(line):
            rel = m.group(1)
            if not (root / rel).exists():
                out.append(Violation(
                    "docs-paths", doc, lineno,
                    f"references '{rel}' which does not exist",
                ))
    return out


def run(
    root: Path, sources: Mapping[Path, SourceFile]
) -> Tuple[List[Violation], List[Note]]:
    docs = []
    readme = root / "README.md"
    if readme.is_file():
        docs.append(readme)
    docs_dir = root / "docs"
    if docs_dir.is_dir():
        docs.extend(sorted(docs_dir.glob("*.md")))
    violations: List[Violation] = []
    for doc in docs:
        violations.extend(_check_file(root, doc))
    notes = [Note(f"docs-paths: {len(docs)} documents scanned")]
    return violations, notes
