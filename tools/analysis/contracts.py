"""Twin-contract checker: jax fast paths and their Python oracles must
keep matching keyword surfaces.

The repo's credibility rests on differential twins (see
``docs/ARCHITECTURE.md``): every compiled kernel has a slow oracle, and a
kwarg added to one side only — ``fail_prob``, ``burst``,
``coalesce_theta`` were all fought by hand in PRs 3–5 — silently unpairs
them.  :data:`REGISTRY` declares each pair with an explicit allowlist of
side-specific parameters; everything else must match by *name set* (order
insensitive) and by *default value* (textual, after ``ast`` round-trip
normalization), with per-parameter exemptions that carry a reason.

Rules
-----
``twin-missing``   a registered function cannot be found (refactor broke
                   the registry, or the registry is stale)
``twin-kwargs``    parameter present on one side only and not allowlisted
``twin-allowlist`` an allowlisted side-specific parameter no longer
                   exists — the allowlist is stale
``twin-default``   a shared parameter's defaults differ and are not
                   exempted
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Tuple

from .base import Note, SourceFile, Violation, resolve_module_path

_SENTINEL = "<required>"


@dataclasses.dataclass(frozen=True)
class TwinPair:
    """One (fast path, oracle) contract.

    ``fast``/``oracle`` are ``"module:qualname"`` references resolved
    against the source tree (``qualname`` may be ``Class.method``).
    ``fast_only``/``oracle_only`` allowlist parameters that legitimately
    exist on one side (batching axes, seeds, debug switches).
    ``default_exempt`` maps parameter name -> reason for twins whose
    defaults intentionally differ (e.g. the oracle runs shorter traces).
    """

    name: str
    fast: str
    oracle: str
    fast_only: Tuple[str, ...] = ()
    oracle_only: Tuple[str, ...] = ()
    default_exempt: Mapping[str, str] = dataclasses.field(default_factory=dict)


_POLICY_TWINS = [
    # jittable init (policies.py) vs pure-Python class (py_ref.py); the
    # jax side adds the key/pad axes required by pad_to shape uniformity.
    TwinPair(
        name=f"policy-{name}",
        fast=f"repro.cache.policies:{init}",
        oracle=f"repro.cache.py_ref:{cls}.__init__",
        fast_only=("key_space", "pad_to"),
    )
    for name, init, cls in [
        ("lru", "lru_init", "LRU"),
        ("fifo", "lru_init", "FIFO"),  # fifo shares the LRU dlist state
        ("prob-lru", "prob_lru_init", "ProbLRU"),
        ("clock", "clock_init", "Clock"),
        ("slru", "slru_init", "SLRU"),
        ("s3fifo", "s3fifo_init", "S3FIFO"),
        ("sieve", "sieve_init", "Sieve"),
    ]
]

REGISTRY: Tuple[TwinPair, ...] = (
    TwinPair(
        name="event-simulator",
        fast="repro.core.simulator:simulate_network",
        oracle="repro.core.py_sim:simulate_py",
        # vmapped (p_hit x seed) grid; backend routes to the pallas kernel
        fast_only=("p_hits", "seeds", "backend"),
        oracle_only=("p_hit", "seed", "full"),
        default_exempt={
            "n_requests": "heapq oracle runs shorter traces (statistical "
                          "agreement, not bit-identity)",
        },
    ),
    TwinPair(
        name="inflight-classifier",
        fast="repro.cache.replay:classify_inflight",
        oracle="repro.cache.py_ref:classify_inflight_py",
        fast_only=("key_space",),            # scatter-table sizing only
    ),
    TwinPair(
        name="cluster-simulator",
        fast="repro.cluster.sim:simulate_cluster",
        oracle="repro.cluster.sim:simulate_cluster_py",
        # the key-routing oracle has no per-request ring buffers; shard
        # attribution of traced requests rides the jax side's branch ids
        fast_only=("p_hits", "seeds", "trace"),
        oracle_only=("key_probs", "assign", "p_hit", "seed"),
        default_exempt={
            "n_requests": "heapq oracle runs shorter traces (statistical "
                          "agreement, not bit-identity)",
        },
    ),
    TwinPair(
        name="hierarchy-simulator",
        fast="repro.hierarchy.sim:simulate_hierarchy",
        oracle="repro.hierarchy.sim:simulate_hierarchy_py",
        fast_only=("p_hits", "seeds"),
        oracle_only=("p_hit", "seed"),
        default_exempt={
            "n_requests": "heapq oracle runs shorter traces (statistical "
                          "agreement, not bit-identity)",
        },
    ),
    TwinPair(
        name="pallas-replay-grid",
        fast="repro.kernels.replay:replay_grid_pallas",
        oracle="repro.cache.replay:replay_grid",
        # the kernel additionally fuses the delayed-hit classifier
        # (window/fail_*) and exposes the executable switch (interpret);
        # the scan twin runs those as separate post-passes.
        fast_only=("window", "fail_prob", "fail_seed", "interpret"),
    ),
    TwinPair(
        name="pallas-event-sim",
        fast="repro.kernels.event_sim:simulate_grid_pallas",
        oracle="repro.core.simulator:simulate_network",
        fast_only=("interpret",),
        # the scan simulator keeps the coalescing / open-loop / burst /
        # tiered-MSHR / streaming-estimator extensions (and the backend
        # switch that routes here).
        oracle_only=("coalesce_flows", "coalesce_theta", "arrival_rate",
                     "max_in_system", "burst", "backend", "tiers",
                     "sketch_cap", "window_us"),
    ),
    TwinPair(
        name="trace-records",
        fast="repro.obs.trace:trace_from_rings",
        oracle="repro.obs.trace:make_records",
        # the ring decoder additionally consumes the emitted-count scalar
        # (n) to report drops; the oracle collector passes its own count.
        fast_only=("n",),
        oracle_only=("n_emitted",),
    ),
    TwinPair(
        name="stream-sketch",
        fast="repro.obs.streaming:sketch_trace",
        oracle="repro.obs.streaming:sketch_trace_py",
        # identical surfaces by design: one jitted lax.scan over the
        # in-kernel estimators vs the exact-counting PyStreamSketch.
    ),
    TwinPair(
        name="drift-cusum",
        fast="repro.obs.drift:cusum_scan",
        oracle="repro.obs.drift:Cusum.__init__",
        # the scan form additionally takes the series it sweeps
        fast_only=("xs",),
    ),
    TwinPair(
        name="drift-page-hinkley",
        fast="repro.obs.drift:page_hinkley_scan",
        oracle="repro.obs.drift:PageHinkley.__init__",
        fast_only=("xs",),
    ),
    TwinPair(
        name="mattson-sweep",
        fast="repro.cache.replay:lru_sweep",
        oracle="repro.cache.replay:replay_grid",
        # lru_sweep is the O(T log^2 T) LRU-only special case of the
        # general replay grid: it has no policy/state axes at all.
        oracle_only=("policy", "us", "key_space", "pad_to", "params"),
    ),
    TwinPair(
        name="cache-sweep",
        fast="repro.core.harness:sweep_cache_sizes",
        oracle="repro.core.harness:measure_cache",
        fast_only=("sizes", "simulate", "sim_requests"),
        oracle_only=("capacity",),
        default_exempt={
            "backend": "the sweep defaults to the compiled grid path; the "
                       "single-point measurement defaults to the oracle",
        },
    ),
    *_POLICY_TWINS,
)


def _find_toplevel(tree: ast.Module, name: str) -> Optional[ast.AST]:
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)) and node.name == name:
            return node
    return None


def _find_method(
    tree: ast.Module, cls: ast.ClassDef, name: str, depth: int = 0
) -> Optional[ast.FunctionDef]:
    """Find ``name`` in ``cls``, following same-module base classes (the
    py_ref policies inherit ``__init__`` from ``_ListCache``)."""
    if depth > 8:
        return None
    for node in cls.body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    for base in cls.bases:
        if isinstance(base, ast.Name):
            parent = _find_toplevel(tree, base.id)
            if isinstance(parent, ast.ClassDef):
                found = _find_method(tree, parent, name, depth + 1)
                if found is not None:
                    return found
    return None


def _find_function(
    tree: ast.Module, qualname: str
) -> Optional[ast.FunctionDef]:
    head, _, rest = qualname.partition(".")
    node = _find_toplevel(tree, head)
    if node is None:
        return None
    if not rest:
        return node if isinstance(node, ast.FunctionDef) else None
    if isinstance(node, ast.ClassDef) and "." not in rest:
        return _find_method(tree, node, rest)
    return None


def _signature_of(fn: ast.FunctionDef) -> Dict[str, str]:
    """Parameter name -> normalized default text (``_SENTINEL`` if
    required).  ``self`` is dropped; ``*args``/``**kwargs`` appear under
    their bare names so e.g. ``**params`` can be allowlisted."""
    sig: Dict[str, str] = {}
    args = fn.args
    positional = list(args.posonlyargs) + list(args.args)
    defaults = list(args.defaults)
    pad = [None] * (len(positional) - len(defaults))
    for arg, default in zip(positional, pad + defaults):
        if arg.arg == "self":
            continue
        sig[arg.arg] = _SENTINEL if default is None else ast.unparse(default)
    if args.vararg is not None:
        sig[args.vararg.arg] = _SENTINEL
    for arg, default in zip(args.kwonlyargs, args.kw_defaults):
        sig[arg.arg] = _SENTINEL if default is None else ast.unparse(default)
    if args.kwarg is not None:
        sig[args.kwarg.arg] = _SENTINEL
    return sig


class _Resolver:
    """Caches parsed modules; honors pre-parsed sources from the runner."""

    def __init__(self, root: Path, sources: Mapping[Path, SourceFile]):
        self.root = root
        self.sources = dict(sources)

    def lookup(self, ref: str) -> Tuple[Optional[ast.FunctionDef],
                                        Optional[Path], int]:
        module, _, qualname = ref.partition(":")
        path = resolve_module_path(self.root, module)
        if path is None:
            return None, None, 0
        src = self.sources.get(path)
        if src is None:
            src = SourceFile(path)
            self.sources[path] = src
        if src.tree is None:
            return None, path, 0
        fn = _find_function(src.tree, qualname)
        return fn, path, (fn.lineno if fn is not None else 0)


def check_pair(
    pair: TwinPair, resolver: _Resolver
) -> List[Violation]:
    out: List[Violation] = []
    fast_fn, fast_path, fast_line = resolver.lookup(pair.fast)
    oracle_fn, oracle_path, oracle_line = resolver.lookup(pair.oracle)
    for ref, fn, path in [(pair.fast, fast_fn, fast_path),
                          (pair.oracle, oracle_fn, oracle_path)]:
        if fn is None:
            out.append(Violation(
                "twin-missing", path or resolver.root, 1,
                f"twin '{pair.name}': cannot resolve {ref} — update the "
                f"registry in tools/analysis/contracts.py or restore the "
                f"function",
            ))
    if fast_fn is None or oracle_fn is None:
        return out
    assert fast_path is not None and oracle_path is not None

    fast_sig = _signature_of(fast_fn)
    oracle_sig = _signature_of(oracle_fn)
    fast_only = set(pair.fast_only)
    oracle_only = set(pair.oracle_only)

    for name in sorted(fast_only - set(fast_sig)):
        out.append(Violation(
            "twin-allowlist", fast_path, fast_line,
            f"twin '{pair.name}': fast_only lists '{name}' but "
            f"{pair.fast} has no such parameter (stale allowlist)",
        ))
    for name in sorted(oracle_only - set(oracle_sig)):
        out.append(Violation(
            "twin-allowlist", oracle_path, oracle_line,
            f"twin '{pair.name}': oracle_only lists '{name}' but "
            f"{pair.oracle} has no such parameter (stale allowlist)",
        ))

    only_fast = set(fast_sig) - set(oracle_sig) - fast_only
    only_oracle = set(oracle_sig) - set(fast_sig) - oracle_only
    for name in sorted(only_fast):
        out.append(Violation(
            "twin-kwargs", fast_path, fast_line,
            f"twin '{pair.name}': parameter '{name}' exists on the fast "
            f"path ({pair.fast}) but not on the oracle ({pair.oracle}); "
            f"add it to the oracle or allowlist it as fast_only",
        ))
    for name in sorted(only_oracle):
        out.append(Violation(
            "twin-kwargs", oracle_path, oracle_line,
            f"twin '{pair.name}': parameter '{name}' exists on the oracle "
            f"({pair.oracle}) but not on the fast path ({pair.fast}); "
            f"add it to the fast path or allowlist it as oracle_only",
        ))

    shared = set(fast_sig) & set(oracle_sig)
    for name in sorted(shared):
        if name in pair.default_exempt:
            continue
        if fast_sig[name] != oracle_sig[name]:
            out.append(Violation(
                "twin-default", fast_path, fast_line,
                f"twin '{pair.name}': default for '{name}' differs — "
                f"fast={fast_sig[name]!r} vs oracle={oracle_sig[name]!r}; "
                f"align them or add a default_exempt with a reason",
            ))
    for name in sorted(set(pair.default_exempt) - shared):
        out.append(Violation(
            "twin-allowlist", fast_path, fast_line,
            f"twin '{pair.name}': default_exempt lists '{name}' which is "
            f"not a shared parameter (stale exemption)",
        ))
    return out


def run(
    root: Path, sources: Mapping[Path, SourceFile]
) -> Tuple[List[Violation], List[Note]]:
    resolver = _Resolver(root, sources)
    violations: List[Violation] = []
    for pair in REGISTRY:
        violations.extend(check_pair(pair, resolver))
    notes = [Note(
        f"twin-contracts: {len(REGISTRY)} registered pairs checked"
    )]
    return violations, notes
