"""Time-unit suffix lint for ``core/``, ``latency/`` and ``cluster/``.

The queueing model runs in **microseconds** (``*_us``), the event
simulator's integer clock in **nanoseconds** (``*_ns``), and the two meet
in conversions like ``mean_on_ns = mean_on_us * 1e3``.  The convention is
carried by name suffixes (``_ns``, ``_us``, ``_ms``, ``_s`` — and
``_rate`` for the reciprocal); this lint flags *additive* arithmetic and
comparisons that mix two different time units without an explicit
conversion.

Inference is deliberately shallow and sound-by-construction:

* a name/attribute ending in a known suffix carries that unit;
* multiplication/division clears the unit (that *is* the conversion
  idiom — ``x_us * 1e3`` no longer claims to be microseconds, and
  ``n / rate`` produces a time);
* ``+``/``-``, ``<``/``<=``/``>``/``>=``/``==`` and ``min``/``max`` over
  mixed known units are violations (``units-mix``);
* assigning an expression with known unit X to a target suffixed with
  unit Y is a violation (``units-assign``).

Anything un-suffixed is unknown and never flagged — the lint cannot
produce a false positive on unit-free code, only miss.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Mapping, Optional, Tuple

from .base import Note, SourceFile, Violation

_SUFFIXES = ("_ns", "_us", "_ms", "_s", "_rate")
_UNIT_OF = {"_ns": "ns", "_us": "us", "_ms": "ms", "_s": "s", "_rate": "rate"}

CHECKED_DIRS = ("src/repro/core", "src/repro/latency", "src/repro/cluster")


def unit_of_name(name: str) -> Optional[str]:
    for suf in _SUFFIXES:
        if name.endswith(suf) and len(name) > len(suf):
            return _UNIT_OF[suf]
    return None


def _unit(node: ast.AST, emit) -> Optional[str]:
    """Unit of an expression, or None when unknown/mixed-and-reported."""
    if isinstance(node, ast.Name):
        return unit_of_name(node.id)
    if isinstance(node, ast.Attribute):
        _unit(node.value, emit)
        return unit_of_name(node.attr)
    if isinstance(node, ast.Subscript):
        _unit(node.slice, emit)
        return _unit(node.value, emit)
    if isinstance(node, ast.UnaryOp):
        return _unit(node.operand, emit)
    if isinstance(node, ast.BinOp):
        lu = _unit(node.left, emit)
        ru = _unit(node.right, emit)
        if isinstance(node.op, (ast.Add, ast.Sub)):
            if lu and ru and lu != ru:
                emit(node, f"adds/subtracts `{ast.unparse(node.left)}` "
                           f"[{lu}] and `{ast.unparse(node.right)}` [{ru}] "
                           f"without a conversion")
                return None
            return lu or ru
        if isinstance(node.op, (ast.Mod, ast.FloorDiv)):
            return lu
        # Mult/Div/Pow...: the conversion idiom — result unit unknown
        return None
    if isinstance(node, ast.Compare):
        units = [_unit(node.left, emit)]
        units += [_unit(c, emit) for c in node.comparators]
        known = [u for u in units if u]
        if len(set(known)) > 1:
            emit(node, f"compares values of different time units "
                       f"({', '.join(sorted(set(known)))}) in "
                       f"`{ast.unparse(node)}`")
        return None
    if isinstance(node, ast.Call):
        chain = node.func
        leaf = None
        if isinstance(chain, ast.Name):
            leaf = chain.id
        elif isinstance(chain, ast.Attribute):
            leaf = chain.attr
        arg_units = [_unit(a, emit) for a in node.args]
        for kw in node.keywords:
            _unit(kw.value, emit)
        if leaf in {"min", "max", "minimum", "maximum", "fmin", "fmax",
                    "clip", "where"}:
            known = [u for u in arg_units if u]
            if len(set(known)) > 1:
                emit(node, f"`{leaf}` over mixed time units "
                           f"({', '.join(sorted(set(known)))}) in "
                           f"`{ast.unparse(node)}`")
                return None
            if leaf in {"min", "max", "minimum", "maximum", "fmin", "fmax"}:
                return known[0] if known else None
        return None
    if isinstance(node, ast.IfExp):
        _unit(node.test, emit)
        bu = _unit(node.body, emit)
        ou = _unit(node.orelse, emit)
        return bu if bu == ou else None
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for elt in node.elts:
            _unit(elt, emit)
        return None
    # other expression kinds: walk children, unknown unit
    for child in ast.iter_child_nodes(node):
        if isinstance(child, ast.expr):
            _unit(child, emit)
    return None


class _FileLint:
    def __init__(self, src: SourceFile):
        self.src = src
        self.violations: List[Violation] = []

    def _emit_mix(self, node: ast.AST, message: str) -> None:
        v = Violation("units-mix", self.src.path,
                      getattr(node, "lineno", 1), message)
        if v not in self.violations:
            self.violations.append(v)

    def run(self) -> None:
        assert self.src.tree is not None
        for node in ast.walk(self.src.tree):
            if isinstance(node, ast.Assign):
                vu = _unit(node.value, self._emit_mix)
                for target in node.targets:
                    self._check_target(target, vu, node)
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                vu = _unit(node.value, self._emit_mix)
                self._check_target(node.target, vu, node)
            elif isinstance(node, ast.AugAssign):
                tu = _target_unit(node.target)
                vu = _unit(node.value, self._emit_mix)
                if isinstance(node.op, (ast.Add, ast.Sub)) and tu and vu \
                        and tu != vu:
                    self.violations.append(Violation(
                        "units-mix", self.src.path, node.lineno,
                        f"augmented assignment mixes [{tu}] target with "
                        f"[{vu}] value in `{ast.unparse(node)}`",
                    ))
            elif isinstance(node, (ast.Expr, ast.Return)) \
                    and node.value is not None:
                _unit(node.value, self._emit_mix)
            elif isinstance(node, (ast.If, ast.While)):
                _unit(node.test, self._emit_mix)
            elif isinstance(node, ast.Call):
                self._check_kwargs(node)

    def _check_target(self, target: ast.AST, value_unit: Optional[str],
                      stmt: ast.AST) -> None:
        tu = _target_unit(target)
        if tu and value_unit and tu != value_unit:
            self.violations.append(Violation(
                "units-assign", self.src.path, stmt.lineno,
                f"assigns a [{value_unit}] expression to "
                f"`{ast.unparse(target)}` [{tu}] without a conversion "
                f"(multiply by the factor explicitly, e.g. `* 1e3`)",
            ))

    def _check_kwargs(self, call: ast.Call) -> None:
        for kw in call.keywords:
            if kw.arg is None:
                continue
            pu = unit_of_name(kw.arg)
            vu = _unit(kw.value, self._emit_mix)
            if pu and vu and pu != vu:
                self.violations.append(Violation(
                    "units-mix", self.src.path, call.lineno,
                    f"passes a [{vu}] value to keyword `{kw.arg}` [{pu}] "
                    f"in `{ast.unparse(call)[:80]}`",
                ))


def _target_unit(target: ast.AST) -> Optional[str]:
    if isinstance(target, ast.Name):
        return unit_of_name(target.id)
    if isinstance(target, ast.Attribute):
        return unit_of_name(target.attr)
    return None


def run(
    root: Path, sources: Mapping[Path, SourceFile]
) -> Tuple[List[Violation], List[Note]]:
    violations: List[Violation] = []
    checked = 0
    prefixes = tuple((root / d) for d in CHECKED_DIRS)
    for path in sorted(sources):
        if not any(str(path).startswith(str(p)) for p in prefixes):
            continue
        src = sources[path]
        if src.tree is None:
            continue
        checked += 1
        lint = _FileLint(src)
        lint.run()
        violations.extend(lint.violations)
    notes = [Note(f"units-lint: {checked} files under "
                  f"{', '.join(CHECKED_DIRS)}")]
    return violations, notes
