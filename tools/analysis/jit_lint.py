"""JIT/tracing-safety lint for the compiled paths in ``src/repro``.

The repo's jit conventions (``docs/ARCHITECTURE.md``) are easy to break
silently: Python control flow on a traced value recompiles per value or
crashes, a ``float()``/``np.*`` coercion forces a device sync inside a
jitted body, a mutable default in a scan carry aliases state across
calls, and 64-bit hash arithmetic truncates to 32 bits unless x64 mode
is on.  This lint finds *traced scopes* statically and taints values
flowing from traced parameters.

Traced scopes
-------------
* functions decorated ``@jax.jit`` / ``@partial(jax.jit, ...)`` — their
  parameters are tainted except ``static_argnames``;
* functions passed (by name) to ``jax.jit`` / ``jax.vmap`` /
  ``lax.scan`` / ``lax.while_loop`` / ``lax.fori_loop`` / ``lax.cond``
  call sites — all parameters tainted;
* ``def``/``lambda`` nested inside a traced scope (scan bodies, cond
  branches) — parameters tainted, enclosing taint inherited;
* module-level helpers *called from* traced scopes — analyzed once per
  call-site taint signature, so a helper invoked only with static
  arguments (e.g. a Zipf-weight table builder) is not flagged for
  branching on them.

Taint escapes: ``.shape``/``.ndim``/``.dtype``, ``len()``, ``range()``
and constants are static under tracing.  ``x is None`` tests are static
(tracers are never ``None``).

Rules
-----
``jit-pyflow``           Python ``if``/``while``/``for`` on a traced value
``jit-coerce``           ``float()``/``int()``/``bool()``/``.item()``/
                         ``.tolist()``/``np.*`` applied to a traced value
``jit-mutable-default``  mutable default argument in a traced scope
``jit-hash64``           64-bit integer dtype inside a traced scope in a
                         module that never touches the x64 switch
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, FrozenSet, List, Mapping, Optional, Set, Tuple

from .base import Note, SourceFile, Violation

_JIT_NAMES = {"jit"}
_VMAP_NAMES = {"vmap", "pmap"}
# callable-argument positions for the lax control-flow combinators
_CALLBACK_SLOTS = {
    "scan": (0,),
    "while_loop": (0, 1),
    "fori_loop": (2,),
    "cond": (1, 2),
    "switch": None,  # every arg after the index may be a branch
    "map": (0,),
}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
_STATIC_CALLS = {"len", "range", "isinstance", "type", "getattr", "hasattr"}
_COERCE_CALLS = {"float", "int", "bool", "complex"}
_COERCE_METHODS = {"item", "tolist", "block_until_ready"}
_INT64_ATTRS = {"uint64", "int64"}


def _attr_chain(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit_ref(node: ast.AST) -> bool:
    chain = _attr_chain(node)
    return chain is not None and chain.split(".")[-1] in _JIT_NAMES


def _static_argnames(call: ast.Call) -> Set[str]:
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                names.add(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                for elt in v.elts:
                    if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                        names.add(elt.value)
    return names


def _mutable_default(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return True
    if isinstance(node, ast.Call):
        chain = _attr_chain(node.func)
        return chain in {"list", "dict", "set"}
    return False


def _param_names(fn: ast.AST) -> List[str]:
    args = fn.args
    names = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    if args.vararg:
        names.append(args.vararg.arg)
    if args.kwarg:
        names.append(args.kwarg.arg)
    return names


class _Root:
    def __init__(self, fn: ast.AST, static: FrozenSet[str]):
        self.fn = fn
        self.static = static


class _ModuleLint:
    def __init__(self, src: SourceFile):
        self.src = src
        self.violations: List[Violation] = []
        self.module_funcs: Dict[str, ast.FunctionDef] = {}
        self.roots: Dict[int, _Root] = {}  # id-keyed by lineno to dedupe
        # (func name, tainted-param tuple) -> analyzed?
        self._helper_memo: Set[Tuple[str, FrozenSet[str]]] = set()
        self._helper_queue: List[Tuple[ast.FunctionDef, FrozenSet[str]]] = []
        self.has_x64_guard = "x64" in src.text

    # ------------------------------------------------------------- roots
    def collect_roots(self) -> None:
        tree = self.src.tree
        assert tree is not None
        for node in tree.body:
            if isinstance(node, ast.FunctionDef):
                self.module_funcs[node.name] = node
        self._scan_block(tree.body, dict(self.module_funcs))

    def _scan_block(self, stmts, scope: Dict[str, ast.FunctionDef]) -> None:
        """Recurse through nested function bodies carrying a name->def
        scope, so ``lax.scan(step, ...)`` resolves ``step`` even when it
        is a local def inside a non-jitted function."""
        local = dict(scope)
        for node in stmts:
            if isinstance(node, ast.FunctionDef):
                local[node.name] = node
        for node in stmts:
            if isinstance(node, ast.FunctionDef):
                static = self._decorated_static(node)
                if static is not None:
                    self._add_root(node, static)
                self._scan_block(node.body, local)
            elif isinstance(node, ast.ClassDef):
                self._scan_block(node.body, local)
            else:
                for call in ast.walk(node):
                    if isinstance(call, ast.Call):
                        self._call_site_roots(call, local)

    def _decorated_static(self, fn: ast.FunctionDef) -> Optional[FrozenSet[str]]:
        """frozenset of static argnames if jit-decorated, else None."""
        for dec in fn.decorator_list:
            if _is_jit_ref(dec):
                return frozenset()
            if isinstance(dec, ast.Call):
                if _is_jit_ref(dec.func):
                    return frozenset(_static_argnames(dec))
                # partial(jax.jit, static_argnames=...)
                chain = _attr_chain(dec.func) or ""
                if chain.split(".")[-1] == "partial" and dec.args \
                        and _is_jit_ref(dec.args[0]):
                    return frozenset(_static_argnames(dec))
        return None

    def _call_site_roots(self, call: ast.Call,
                         scope: Dict[str, ast.FunctionDef]) -> None:
        chain = _attr_chain(call.func)
        if chain is None:
            return
        leaf = chain.split(".")[-1]
        candidates: List[Tuple[ast.AST, FrozenSet[str]]] = []
        if leaf in _JIT_NAMES or leaf in _VMAP_NAMES:
            if call.args:
                static = frozenset(_static_argnames(call)) \
                    if leaf in _JIT_NAMES else frozenset()
                candidates.append((call.args[0], static))
        elif leaf in _CALLBACK_SLOTS:
            # only trust lax./jax.lax. qualified combinators; a bare
            # ``map``/``scan`` helper of our own is not jax
            if not (chain.startswith("lax.") or chain.startswith("jax.lax.")):
                return
            slots = _CALLBACK_SLOTS[leaf]
            idxs = range(1, len(call.args)) if slots is None else slots
            for i in idxs:
                if i < len(call.args):
                    candidates.append((call.args[i], frozenset()))
        for arg, static in candidates:
            if isinstance(arg, ast.Name) and arg.id in scope:
                self._add_root(scope[arg.id], static)
            elif isinstance(arg, ast.Lambda):
                self._add_root(arg, static)

    def _add_root(self, fn: ast.AST, static: FrozenSet[str]) -> None:
        key = getattr(fn, "lineno", 0)
        prev = self.roots.get(key)
        if prev is None:
            self.roots[key] = _Root(fn, static)
        else:  # keep the *smaller* static set (more taint = more checks)
            prev.static = frozenset(prev.static & static)

    # ----------------------------------------------------------- analyze
    def analyze(self) -> None:
        analyzed_fns = {id(r.fn) for r in self.roots.values()}
        for root in self.roots.values():
            tainted = frozenset(
                n for n in _param_names(root.fn) if n not in root.static
            )
            self._analyze_scope(root.fn, tainted)
        # drain helper queue (helpers reached from traced call sites)
        while self._helper_queue:
            fn, tainted = self._helper_queue.pop()
            if id(fn) in analyzed_fns:
                continue
            self._analyze_scope(fn, tainted)

    def _analyze_scope(self, fn: ast.AST, tainted: FrozenSet[str]) -> None:
        env: Set[str] = set(tainted)
        self._check_defaults(fn)
        body = fn.body if isinstance(fn.body, list) else [ast.Expr(fn.body)]
        for stmt in body:
            self._stmt(stmt, env)

    def _check_defaults(self, fn: ast.AST) -> None:
        args = fn.args
        for default in list(args.defaults) + [d for d in args.kw_defaults if d]:
            if _mutable_default(default):
                self._emit(
                    "jit-mutable-default", default,
                    "mutable default argument in a traced scope — defaults "
                    "are evaluated once and alias across calls; use None "
                    "and construct inside",
                )

    # -------------------------------------------------------- statements
    def _stmt(self, node: ast.AST, env: Set[str]) -> None:
        if isinstance(node, ast.If):
            if self._taint(node.test, env):
                self._emit(
                    "jit-pyflow", node.test,
                    f"Python `if` on traced value "
                    f"`{ast.unparse(node.test)}` — use jnp.where / "
                    f"lax.cond or hoist to a static argument",
                )
            for s in node.body + node.orelse:
                self._stmt(s, env)
        elif isinstance(node, ast.While):
            if self._taint(node.test, env):
                self._emit(
                    "jit-pyflow", node.test,
                    f"Python `while` on traced value "
                    f"`{ast.unparse(node.test)}` — use lax.while_loop",
                )
            for s in node.body + node.orelse:
                self._stmt(s, env)
        elif isinstance(node, ast.For):
            if self._taint(node.iter, env):
                self._emit(
                    "jit-pyflow", node.iter,
                    f"Python `for` over traced value "
                    f"`{ast.unparse(node.iter)}` — use lax.scan / "
                    f"lax.fori_loop",
                )
            self._bind(node.target, self._taint(node.iter, env), env)
            for s in node.body + node.orelse:
                self._stmt(s, env)
        elif isinstance(node, (ast.Assign,)):
            t = self._taint(node.value, env)
            for target in node.targets:
                self._bind(target, t, env)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            self._bind(node.target, self._taint(node.value, env), env)
        elif isinstance(node, ast.AugAssign):
            t = self._taint(node.value, env) or self._taint(node.target, env)
            self._bind(node.target, t, env)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            inner = env | set(_param_names(node))
            self._analyze_scope(node, frozenset(inner))
        elif isinstance(node, (ast.Return, ast.Expr)):
            if node.value is not None:
                self._taint(node.value, env)
        elif isinstance(node, (ast.With,)):
            for s in node.body:
                self._stmt(s, env)
        elif isinstance(node, ast.Try):
            for s in node.body + node.orelse + node.finalbody:
                self._stmt(s, env)
            for h in node.handlers:
                for s in h.body:
                    self._stmt(s, env)
        # other statements (pass, raise, assert, ...) — walk exprs for
        # coercion checks without control-flow semantics
        else:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.expr):
                    self._taint(child, env)

    def _bind(self, target: ast.AST, tainted: bool, env: Set[str]) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                env.add(target.id)
            else:
                env.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, tainted, env)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, tainted, env)
        # attribute/subscript targets: container already tracked by name

    # ------------------------------------------------------- expressions
    def _taint(self, node: ast.AST, env: Set[str]) -> bool:
        """Taint of an expression; emits coercion/hash64 findings inline."""
        if isinstance(node, ast.Name):
            return node.id in env
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Attribute):
            if node.attr in _INT64_ATTRS and not self.has_x64_guard:
                chain = _attr_chain(node) or ""
                if chain.split(".")[0] in {"jnp", "jax", "np", "numpy"}:
                    self._emit(
                        "jit-hash64", node,
                        f"`{chain}` inside a traced scope: without the x64 "
                        f"switch jax silently truncates to 32 bits — guard "
                        f"with jax.config x64 or keep 64-bit hashing on the "
                        f"host (numpy)",
                    )
            if node.attr in _STATIC_ATTRS:
                self._taint(node.value, env)  # still walk for findings
                return False
            return self._taint(node.value, env)
        if isinstance(node, ast.Call):
            return self._call_taint(node, env)
        if isinstance(node, ast.Lambda):
            inner = set(env) | set(_param_names(node))
            self._taint(node.body, inner)
            return False
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not None` is a static predicate under
            # tracing (a tracer is never None)
            if len(node.ops) == 1 and isinstance(node.ops[0], (ast.Is, ast.IsNot)):
                self._taint(node.left, env)
                self._taint(node.comparators[0], env)
                return False
            parts = [node.left] + list(node.comparators)
            return any(self._taint(p, env) for p in parts)
        if isinstance(node, (ast.IfExp,)):
            test_t = self._taint(node.test, env)
            if test_t:
                self._emit(
                    "jit-pyflow", node.test,
                    f"conditional expression on traced value "
                    f"`{ast.unparse(node.test)}` — use jnp.where",
                )
            return self._taint(node.body, env) | self._taint(node.orelse, env)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            inner = set(env)
            tainted_iter = False
            for gen in node.generators:
                it = self._taint(gen.iter, inner)
                tainted_iter |= it
                self._bind(gen.target, it, inner)
                for cond in gen.ifs:
                    self._taint(cond, inner)
            if isinstance(node, ast.DictComp):
                self._taint(node.key, inner)
                self._taint(node.value, inner)
            else:
                self._taint(node.elt, inner)
            return tainted_iter
        # generic: tainted if any child expression is
        out = False
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                out |= self._taint(child, env)
        return out

    def _call_taint(self, node: ast.Call, env: Set[str]) -> bool:
        chain = _attr_chain(node.func) or ""
        leaf = chain.split(".")[-1] if chain else ""
        arg_taints = [self._taint(a, env) for a in node.args]
        kw_taints = {kw.arg: self._taint(kw.value, env) for kw in node.keywords}
        any_tainted = any(arg_taints) or any(kw_taints.values())

        if isinstance(node.func, ast.Name) and leaf in _STATIC_CALLS:
            return False
        if isinstance(node.func, ast.Name) and leaf in _COERCE_CALLS \
                and any_tainted:
            self._emit(
                "jit-coerce", node,
                f"`{leaf}()` on a traced value forces concretization "
                f"inside a jitted body — keep it an array (jnp) or hoist "
                f"out of the compiled region",
            )
            return False
        if isinstance(node.func, ast.Attribute):
            if node.func.attr in _COERCE_METHODS \
                    and self._taint(node.func.value, env):
                self._emit(
                    "jit-coerce", node,
                    f"`.{node.func.attr}()` on a traced value inside a "
                    f"jitted body — device sync / concretization",
                )
                return False
            root = chain.split(".")[0]
            if root in {"np", "numpy"} and any_tainted:
                self._emit(
                    "jit-coerce", node,
                    f"`{chain}(...)` applied to a traced value — numpy "
                    f"concretizes tracers; use jnp inside jitted code",
                )
                return True
        # helper reachable from traced code: analyze with this call
        # site's taint signature
        if isinstance(node.func, ast.Name) and node.func.id in self.module_funcs:
            fn = self.module_funcs[node.func.id]
            params = _param_names(fn)
            tainted_params: Set[str] = set()
            pos = [a for a in fn.args.posonlyargs + fn.args.args]
            for i, t in enumerate(arg_taints):
                if t and i < len(pos):
                    tainted_params.add(pos[i].arg)
            for name, t in kw_taints.items():
                if t and name in params:
                    tainted_params.add(name)
            key = (node.func.id, frozenset(tainted_params))
            if tainted_params and key not in self._helper_memo:
                self._helper_memo.add(key)
                self._helper_queue.append((fn, frozenset(tainted_params)))
        else:
            self._taint(node.func, env)
        return any_tainted

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 1)
        v = Violation(rule, self.src.path, line, message)
        if v not in self.violations:
            self.violations.append(v)


def run(
    root: Path, sources: Mapping[Path, SourceFile]
) -> Tuple[List[Violation], List[Note]]:
    violations: List[Violation] = []
    n_roots = 0
    for path in sorted(sources):
        src = sources[path]
        if src.tree is None:
            continue
        lint = _ModuleLint(src)
        lint.collect_roots()
        n_roots += len(lint.roots)
        lint.analyze()
        violations.extend(lint.violations)
    notes = [Note(f"jit-lint: {n_roots} traced roots across "
                  f"{len(sources)} files")]
    return violations, notes
