"""Guard against doc drift: every repo path named in README.md (and
docs/*.md) must exist.

    python tools/check_readme_paths.py

Scans the markdown for `benchmarks/...py`, `examples/...py`,
`src/...py`, `tests/...py`, `docs/...md` and `tools/...py` references —
inline code spans and links alike — and fails listing any that don't
resolve relative to the repo root.  CI runs this in the docs job so a
renamed benchmark can't leave the README pointing at nothing.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
PATTERN = re.compile(
    r"\b((?:benchmarks|examples|tools|src|tests|docs)/[\w./-]+\.(?:py|md))\b"
)


def main() -> int:
    missing = []
    checked = set()
    for doc in DOCS:
        if not doc.exists():
            missing.append((str(doc.relative_to(ROOT)), "(doc itself)"))
            continue
        for ref in PATTERN.findall(doc.read_text()):
            checked.add(ref)
            if not (ROOT / ref).exists():
                missing.append((str(doc.relative_to(ROOT)), ref))
    if missing:
        for doc, ref in missing:
            print(f"STALE: {doc} references missing path {ref}")
        return 1
    print(f"ok: {len(checked)} referenced paths exist "
          f"across {len(DOCS)} docs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
