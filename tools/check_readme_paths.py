"""Guard against doc drift: every repo path named in README.md (and
docs/*.md) must exist.

This check now lives in the static-analysis suite as the ``docs-paths``
rule (see ``tools/analysis/docs_paths.py``); this script remains as a
thin back-compat shim so older invocations keep working:

    python tools/check_readme_paths.py
    python -m tools.analysis --only docs_paths   # the canonical form
"""

from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from tools.analysis import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(["--only", "docs_paths"]))
