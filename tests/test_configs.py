"""Registry-level smoke tests for the ``repro.configs`` wing.

The per-arch config modules are mostly exercised indirectly (model smoke
tests build reduced params); these tests pin the registry contract itself
so a dormant module can't silently rot: every module listed in ``ARCHS``
imports and produces a validated full-size :class:`ModelConfig`, every
file in the package is reachable from the registry (no dead modules), and
``reduced()`` / override plumbing behave as the smoke tests assume.
"""

import dataclasses
import pathlib

import pytest

from repro.configs.registry import ARCHS, get_config

FAMILIES = {"dense", "moe", "vlm", "ssm", "hybrid", "audio"}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_loads_and_is_sane(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.family in FAMILIES
    assert cfg.n_layers > 0 and cfg.d_model > 0 and cfg.vocab > 0
    assert cfg.d_head > 0  # __post_init__ resolved the default
    if cfg.block == "attn":
        assert cfg.n_heads % cfg.n_kv_heads == 0
    if cfg.family == "moe":
        assert cfg.moe is not None and cfg.moe.n_experts >= cfg.moe.top_k
    if cfg.family == "audio":
        assert cfg.encdec and cfg.enc_layers > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_shrinks_but_keeps_shape_of_the_family(arch):
    full = get_config(arch)
    cfg = get_config(arch, reduced=True)
    assert cfg.name == arch + "-reduced"
    assert cfg.d_model < full.d_model and cfg.vocab <= full.vocab
    # family-defining structure survives the shrink
    assert (cfg.family, cfg.block, cfg.encdec) == (
        full.family, full.block, full.encdec)
    assert (cfg.moe is None) == (full.moe is None)
    assert cfg.param_dtype == "float32"  # CPU smoke tests need f32


def test_overrides_and_unknown_arch():
    cfg = get_config("internlm2-1.8b", reduced=True, max_seq=1024)
    assert cfg.max_seq == 1024
    assert dataclasses.is_dataclass(cfg)
    with pytest.raises(KeyError, match="unknown arch"):
        get_config("not-a-model")


def test_registry_covers_every_config_module():
    """No dormant modules: configs/*.py <-> ARCHS is a bijection."""
    pkg = pathlib.Path(__file__).resolve().parents[1] / "src/repro/configs"
    modules = {p.stem for p in pkg.glob("*.py")} - {"registry", "__init__"}
    from_registry = {a.replace("-", "_").replace(".", "_") for a in ARCHS}
    assert modules == from_registry
