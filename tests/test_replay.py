"""Differential tests for the batched trace-replay engine.

The compiled scan engine must be *bit-identical* to the pure-Python
references on every policy — hits, evicted keys, and op vectors — for a
shared (trace, u) sequence, including padded states (pad_to > capacity,
non-power-of-two capacity).  The vmapped (capacity x seed) grid must
reproduce the per-capacity scans, and the Mattson one-pass LRU sweep must
agree with both.
"""

import numpy as np
import pytest

from repro.cache.policies import POLICIES
from repro.cache.py_ref import PY_POLICIES
from repro.cache.replay import lru_sweep, replay_grid, replay_trace

KEY_SPACE = 24

JAX_PARAMS = {
    "lru": {},
    "fifo": {},
    "prob_lru": {"q": 0.5},
    "clock": {"max_scan": 3},
    "slru": {"protected_frac": 0.5},
    "s3fifo": {"small_frac": 0.25, "max_scan": 3},
    "sieve": {},
}
PY_PARAMS = {**JAX_PARAMS, "s3fifo": {"small_frac": 0.25}}


def _trace(n=1500, seed=0):
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, KEY_SPACE + 1)
    probs = (1.0 / ranks**0.99) / np.sum(1.0 / ranks**0.99)
    keys = rng.choice(KEY_SPACE, size=n, p=probs)
    us = rng.random(n, dtype=np.float32)
    return keys, us


def _oracle(policy, capacity, keys, us):
    ref = PY_POLICIES[policy](capacity, **PY_PARAMS[policy])
    hits, evicted, ops = [], [], []
    for k, u in zip(keys, us):
        a = ref.access(int(k), float(u))
        hits.append(a.hit)
        evicted.append(a.evicted_key)
        ops.append(a.ops)
    return (np.asarray(hits), np.asarray(evicted, np.int64),
            np.asarray(ops, np.int64))


@pytest.mark.parametrize("policy", sorted(PY_POLICIES))
@pytest.mark.parametrize("capacity,pad_to", [(7, 16), (8, 8)])
def test_scan_engine_matches_py_ref(policy, capacity, pad_to):
    """Element-wise identical hit/evicted/op sequences, padded and exact."""
    keys, us = _trace()
    res = replay_trace(policy, keys, us, capacity, key_space=KEY_SPACE,
                       pad_to=pad_to, **JAX_PARAMS[policy])
    hits, evicted, ops = _oracle(policy, capacity, keys, us)
    np.testing.assert_array_equal(res.hits, hits, err_msg=f"{policy} hits")
    np.testing.assert_array_equal(res.evicted, evicted,
                                  err_msg=f"{policy} evicted")
    np.testing.assert_array_equal(res.ops, ops, err_msg=f"{policy} ops")


@pytest.mark.parametrize("policy", ["lru", "prob_lru", "s3fifo"])
def test_grid_reproduces_per_capacity(policy):
    """Stacked capacities under vmap == independent per-capacity scans."""
    rng = np.random.default_rng(1)
    S, T = 2, 600
    keys = rng.integers(0, KEY_SPACE, size=(S, T))
    us = rng.random((S, T), dtype=np.float32)
    caps = [5, 8, 12]
    grid = replay_grid(policy, keys, us, caps, key_space=KEY_SPACE,
                       pad_to=16, **JAX_PARAMS[policy])
    assert grid.hits.shape == (len(caps), S, T)
    assert grid.ops.shape == (len(caps), S, T, 4)
    for i, c in enumerate(caps):
        for s in range(S):
            one = replay_trace(policy, keys[s], us[s], c,
                               key_space=KEY_SPACE, pad_to=16,
                               **JAX_PARAMS[policy])
            np.testing.assert_array_equal(grid.hits[i, s], one.hits)
            np.testing.assert_array_equal(grid.evicted[i, s], one.evicted)
            np.testing.assert_array_equal(grid.ops[i, s], one.ops)


def test_grid_matches_oracle_across_capacities():
    """The vmapped grid is oracle-exact at every capacity, not just
    self-consistent."""
    keys, us = _trace(800, seed=2)
    caps = [3, 7, 10]
    grid = replay_grid("lru", keys, us, caps, key_space=KEY_SPACE)
    for i, c in enumerate(caps):
        hits, evicted, ops = _oracle("lru", c, keys, us)
        np.testing.assert_array_equal(grid.hits[i, 0], hits)
        np.testing.assert_array_equal(grid.evicted[i, 0], evicted)
        np.testing.assert_array_equal(grid.ops[i, 0], ops)


def test_lru_sweep_matches_scan_and_oracle():
    """Mattson one-pass sweep == scan engine == py_ref, every capacity."""
    keys, us = _trace(2000, seed=3)
    caps = [3, 7, 8, 15]
    hits_m, ops_m = lru_sweep(keys, caps)
    for i, c in enumerate(caps):
        res = replay_trace("lru", keys, us, c, key_space=KEY_SPACE)
        np.testing.assert_array_equal(hits_m[i], res.hits, err_msg=f"C={c}")
        np.testing.assert_array_equal(ops_m[i], res.ops, err_msg=f"C={c}")
        hits, _, ops = _oracle("lru", c, keys, us)
        np.testing.assert_array_equal(hits_m[i], hits)
        np.testing.assert_array_equal(ops_m[i], ops)


def test_pad_to_validation():
    with pytest.raises(ValueError, match="pad_to"):
        POLICIES["lru"].init(8, KEY_SPACE, pad_to=4)


def test_out_of_range_keys_rejected():
    """JAX clamps gathers / drops OOB scatters, so a too-small key_space
    must raise instead of silently aliasing keys."""
    keys = np.array([0, 5, 300])
    us = np.zeros(3, np.float32)
    with pytest.raises(ValueError, match="out of range"):
        replay_trace("lru", keys, us, 4, key_space=256)
    with pytest.raises(ValueError, match="non-negative"):
        replay_trace("lru", np.array([-1, 2]), us[:2], 4, key_space=256)


def test_batched_init_stacks_states():
    states = POLICIES["lru"].batched_init([4, 8], KEY_SPACE)
    assert states.table.slot2key.shape == (2, 8)
    assert states.capacity.tolist() == [4, 8]


def test_run_cache_trace_backends_agree():
    from repro.core.harness import run_cache_trace, zipf_trace

    trace = zipf_trace(4000, 256, 0.99, seed=5)
    # q = 1 - 1/72 is not float32-representable: regression for the py
    # oracle comparing the coin against a float64 threshold
    for policy, kw in [("lru", {}), ("prob_lru", {"q": 0.5}),
                       ("prob_lru", {"q": 1 - 1 / 72}),
                       ("s3fifo", {"small_frac": 0.1})]:
        h_py, o_py = run_cache_trace(policy, 48, trace, seed=5,
                                     backend="py", **kw)
        h_jx, o_jx = run_cache_trace(policy, 48, trace, seed=5,
                                     backend="jax", key_space=256, **kw)
        np.testing.assert_array_equal(h_py, h_jx, err_msg=policy)
        np.testing.assert_array_equal(o_py, o_jx, err_msg=policy)


def test_sweep_backends_agree():
    from repro.core.harness import sweep_cache_sizes

    kw = dict(key_space=512, n_requests=6000)
    for policy in ("lru", "clock"):
        out_j = sweep_cache_sizes(policy, [16, 64, 128], backend="jax", **kw)
        out_p = sweep_cache_sizes(policy, [16, 64, 128], backend="py", **kw)
        np.testing.assert_array_equal(out_j["p_hit"], out_p["p_hit"])
        np.testing.assert_allclose(out_j["x_bound"], out_p["x_bound"])


def test_coin_stream_independent_of_trace():
    """Regression for the correlated-RNG bug: the admission coins must not
    reproduce the trace generator's stream."""
    from repro.core.harness import coin_stream, zipf_trace

    n, seed = 2000, 7
    us = coin_stream(n, seed)
    # the old (buggy) coin stream: default_rng(seed).random, the same
    # stream zipf_trace consumes for its permutation/choice draws
    old = np.random.default_rng(seed).random(n)
    assert not np.allclose(us, old.astype(np.float32))
    # determinism + independence across seeds
    np.testing.assert_array_equal(us, coin_stream(n, seed))
    assert not np.array_equal(us, coin_stream(n, seed + 1))
    # and the trace itself is unchanged by drawing coins
    t1 = zipf_trace(n, 64, seed=seed)
    coin_stream(n, seed)
    np.testing.assert_array_equal(t1, zipf_trace(n, 64, seed=seed))
