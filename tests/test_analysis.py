"""Tests for the static-analysis suite (tools/analysis/).

Fixture-based: tests/fixtures/analysis/ is a miniature repo tree with one
known violation per rule plus clean counterparts, so both directions are
pinned — the rules fire where they must and stay silent where they must.
The twin-contract registry additionally gets a live run against the real
codebase and a seeded-drift run against a mutated copy of it (the
acceptance path: a kwarg added to one twin must fail the suite).

The suite is stdlib-only by design; none of these tests import jax.
"""

import shutil
import subprocess
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT))

from tools.analysis import CHECKERS, main  # noqa: E402
from tools.analysis import contracts  # noqa: E402
from tools.analysis.base import load_sources  # noqa: E402

FIXTURE_ROOT = REPO_ROOT / "tests" / "fixtures" / "analysis"


def run_checker(name, root):
    sources = {s.path: s for s in load_sources(root, ("src/repro",))}
    violations, notes = CHECKERS[name](root, sources)
    # apply waivers the way the runner does
    from tools.analysis.base import apply_waivers
    return apply_waivers(sources, violations), notes


def line_of(path: Path, needle: str) -> int:
    for i, line in enumerate(path.read_text().splitlines(), start=1):
        if needle in line:
            return i
    raise AssertionError(f"{needle!r} not found in {path}")


# --------------------------------------------------------------- jit lint

class TestJitLint:
    @pytest.fixture(scope="class")
    def found(self):
        violations, _ = run_checker("jit", FIXTURE_ROOT)
        return violations

    def fixture_path(self):
        return FIXTURE_ROOT / "src" / "repro" / "bad_jit.py"

    def test_pyflow_on_traced_if(self, found):
        want = line_of(self.fixture_path(), "# jit-pyflow: `x` is traced")
        assert any(v.rule == "jit-pyflow" and v.line == want for v in found)

    def test_pyflow_in_scan_body(self, found):
        want = line_of(self.fixture_path(), "carry is traced in a scan body")
        assert any(v.rule == "jit-pyflow" and v.line == want for v in found)

    def test_pyflow_via_helper_taint(self, found):
        want = line_of(self.fixture_path(),
                       "jit-pyflow when a traced value reaches")
        assert any(v.rule == "jit-pyflow" and v.line == want for v in found)

    def test_coercions(self, found):
        path = self.fixture_path()
        for marker in ("# jit-coerce: concretizes a tracer",
                       "# jit-coerce: numpy on a traced value",
                       "# jit-coerce: device sync"):
            want = line_of(path, marker)
            assert any(v.rule == "jit-coerce" and v.line == want
                       for v in found), marker

    def test_mutable_default(self, found):
        want = line_of(self.fixture_path(), "# jit-mutable-default")
        assert any(v.rule == "jit-mutable-default" and v.line == want
                   for v in found)

    def test_hash64(self, found):
        want = line_of(self.fixture_path(), "module never enables wide ints")
        assert any(v.rule == "jit-hash64" and v.line == want for v in found)

    def test_clean_lines_stay_clean(self, found):
        text = self.fixture_path().read_text().splitlines()
        clean_lines = {i for i, line in enumerate(text, start=1)
                       if "clean" in line and "#" in line}
        hits = {v.line for v in found if v.path == self.fixture_path()}
        assert not (hits & clean_lines), sorted(hits & clean_lines)

    def test_static_args_not_tainted(self, found):
        # `for _ in range(n)` with static n, and _helper(x, mode) with a
        # static mode, must not be flagged
        path = self.fixture_path()
        for marker in ("`n` is static", "`flag` stays static"):
            line = line_of(path, marker)
            assert not any(v.line == line for v in found), marker

    def test_waiver_suppresses(self, found):
        line = line_of(self.fixture_path(), "exercising the waiver path")
        assert not any(v.line == line for v in found)


# ------------------------------------------------------------- units lint

class TestUnitsLint:
    @pytest.fixture(scope="class")
    def found(self):
        violations, _ = run_checker("units", FIXTURE_ROOT)
        return violations

    def fixture_path(self):
        return FIXTURE_ROOT / "src" / "repro" / "core" / "bad_units.py"

    @pytest.mark.parametrize("rule,marker", [
        ("units-mix", "# units-mix: ns minus us"),
        ("units-assign", "# units-assign: us into a _ns name"),
        ("units-mix", "# units-mix: compares ns to us"),
        ("units-mix", "# units-mix: min over mixed units"),
        ("units-mix", "# units-mix: ns value, us keyword"),
        ("units-mix", "# units-mix: time plus rate"),
    ])
    def test_violation_lines(self, found, rule, marker):
        want = line_of(self.fixture_path(), marker)
        assert any(v.rule == rule and v.line == want for v in found), marker

    def test_clean_lines_stay_clean(self, found):
        path = self.fixture_path()
        text = path.read_text().splitlines()
        clean = {i for i, line in enumerate(text, start=1)
                 if "clean" in line or "fine" in line}
        hits = {v.line for v in found if v.path == path}
        assert not (hits & clean), sorted(hits & clean)

    def test_waiver_suppresses(self, found):
        line = line_of(self.fixture_path(), "pre-scaled by the caller")
        assert not any(v.line in (line, line + 1) for v in found)


# ---------------------------------------------------------- import graph

class TestImportGraph:
    @pytest.fixture(scope="class")
    def result(self):
        return run_checker("imports", FIXTURE_ROOT)

    def test_cycle_detected(self, result):
        violations, _ = result
        cyc = [v for v in violations if v.rule == "imports-cycle"]
        assert len(cyc) == 1
        assert "cyc_a" in cyc[0].message and "cyc_b" in cyc[0].message

    def test_dead_import(self, result):
        violations, _ = result
        dead = [v for v in violations if v.rule == "imports-dead"]
        assert any("'os'" in v.message for v in dead)
        assert not any("'math'" in v.message for v in dead)

    def test_real_tree_has_no_cycles(self):
        violations, _ = run_checker("imports", REPO_ROOT)
        assert [v for v in violations if v.rule == "imports-cycle"] == []

    def test_real_tree_dormant_wings_reported(self):
        _, notes = run_checker("imports", REPO_ROOT)
        assert any("dormant" in n.text for n in notes)


# ------------------------------------------------------------- docs paths

class TestDocsPaths:
    def test_missing_path_flagged(self):
        violations, _ = run_checker("docs_paths", FIXTURE_ROOT)
        assert len(violations) == 1
        assert "does_not_exist.py" in violations[0].message


# --------------------------------------------------------------- obs lint

class TestObsLint:
    @pytest.fixture(scope="class")
    def found(self):
        violations, _ = run_checker("obs", FIXTURE_ROOT)
        return violations

    def fixture_path(self):
        return FIXTURE_ROOT / "src" / "repro" / "obs" / "bad_obs.py"

    def test_metric_name_without_suffix(self, found):
        want = line_of(self.fixture_path(),
                       "# obs-units: metric name without suffix")
        assert any(v.rule == "obs-units" and v.line == want for v in found)

    def test_time_like_schema_field(self, found):
        want = line_of(self.fixture_path(),
                       "# obs-units: time-like field without a unit")
        assert any(v.rule == "obs-units" and v.line == want for v in found)

    def test_nonstatic_trace_cap(self, found):
        want = line_of(self.fixture_path(), "def bad_ring")
        assert any(v.rule == "obs-ring-static" and v.line == want
                   for v in found)

    def test_estimator_field_without_unit(self, found):
        path = self.fixture_path()
        for marker in ("# obs-units: estimator field without a unit",
                       "# obs-units: EWMA field without a unit"):
            want = line_of(path, marker)
            assert any(v.rule == "obs-units" and v.line == want
                       for v in found), marker

    def test_nonstatic_sketch_window(self, found):
        want = line_of(self.fixture_path(), "def bad_sketch")
        hits = [v for v in found
                if v.rule == "obs-ring-static" and v.line == want]
        assert len(hits) == 1 and "window_us" in hits[0].message

    def test_clean_lines_stay_clean(self, found):
        path = self.fixture_path()
        text = path.read_text().splitlines()
        clean = {i for i, line in enumerate(text, start=1)
                 if "clean" in line}
        clean.add(line_of(path, "def good_ring"))
        clean.add(line_of(path, "def good_sketch"))
        hits = {v.line for v in found if v.path == path}
        assert not (hits & clean), sorted(hits & clean)

    def test_real_tree_is_clean(self):
        violations, notes = run_checker("obs", REPO_ROOT)
        assert violations == []
        assert any("obs-lint" in n.text for n in notes)


# ---------------------------------------------------------- twin contracts

class TestTwinContracts:
    def resolver(self, root):
        return contracts._Resolver(root, {})

    def test_matched_pair_is_clean(self):
        pair = contracts.TwinPair(
            name="fixture-fn",
            fast="repro.twin_fast:fast_fn",
            oracle="repro.twin_oracle:oracle_fn",
            fast_only=("p_hits", "seeds"),
            oracle_only=("p_hit", "seed"),
        )
        assert contracts.check_pair(pair, self.resolver(FIXTURE_ROOT)) == []

    def test_class_init_resolution(self):
        pair = contracts.TwinPair(
            name="fixture-class",
            fast="repro.twin_fast:fast_fn",
            oracle="repro.twin_oracle:Oracle.__init__",
            fast_only=("p_hits", "seeds"),
            oracle_only=("p_hit", "seed"),
        )
        assert contracts.check_pair(pair, self.resolver(FIXTURE_ROOT)) == []

    def test_kwarg_drift_named(self):
        pair = contracts.TwinPair(
            name="fixture-drift",
            fast="repro.twin_fast:drifted_fast",
            oracle="repro.twin_oracle:drifted_oracle",
            fast_only=("p_hits",),
            oracle_only=("p_hit",),
        )
        found = contracts.check_pair(pair, self.resolver(FIXTURE_ROOT))
        rules = {v.rule for v in found}
        assert rules == {"twin-kwargs"}
        assert any("'fail_prob'" in v.message for v in found)
        assert any("'n_requests'" in v.message for v in found)

    def test_default_drift_named(self):
        pair = contracts.TwinPair(
            name="fixture-default",
            fast="repro.twin_fast:fast_fn",
            oracle="repro.twin_oracle:drifted_oracle",
            fast_only=("p_hits", "seeds", "coalesce_theta", "burst"),
            oracle_only=("p_hit",),
        )
        found = contracts.check_pair(pair, self.resolver(FIXTURE_ROOT))
        assert any(v.rule == "twin-default" and "'n_requests'" in v.message
                   for v in found)

    def test_stale_allowlist_flagged(self):
        pair = contracts.TwinPair(
            name="fixture-stale",
            fast="repro.twin_fast:fast_fn",
            oracle="repro.twin_oracle:oracle_fn",
            fast_only=("p_hits", "seeds", "not_a_param"),
            oracle_only=("p_hit", "seed"),
        )
        found = contracts.check_pair(pair, self.resolver(FIXTURE_ROOT))
        assert any(v.rule == "twin-allowlist" and "'not_a_param'" in v.message
                   for v in found)

    def test_missing_function_flagged(self):
        pair = contracts.TwinPair(
            name="fixture-missing",
            fast="repro.twin_fast:gone_fn",
            oracle="repro.twin_oracle:oracle_fn",
        )
        found = contracts.check_pair(pair, self.resolver(FIXTURE_ROOT))
        assert any(v.rule == "twin-missing" for v in found)

    def test_live_registry_is_clean(self):
        violations, notes = run_checker("contracts", REPO_ROOT)
        assert violations == []
        assert any("19 registered pairs" in n.text for n in notes)


# ----------------------------------------------- acceptance: seeded drift

class TestSeededDrift:
    @pytest.fixture()
    def mutated_tree(self, tmp_path):
        """Copy the real src/ tree and add a kwarg to one oracle only."""
        shutil.copytree(REPO_ROOT / "src", tmp_path / "src")
        py_sim = tmp_path / "src" / "repro" / "core" / "py_sim.py"
        text = py_sim.read_text()
        old = "def simulate_py(\n    net: ClosedNetwork,\n    p_hit: float,"
        assert old in text
        py_sim.write_text(text.replace(
            old, old + "\n    drift_knob: int = 7,", 1))
        return tmp_path

    def test_suite_exits_nonzero_on_drift(self, mutated_tree, capsys):
        rc = main(["--root", str(mutated_tree), "--only", "contracts",
                   "--quiet"])
        out = capsys.readouterr().out
        assert rc == 1
        assert "twin-kwargs" in out and "drift_knob" in out

    def test_suite_exits_zero_on_repaired_tree(self, capsys):
        rc = main(["--root", str(REPO_ROOT), "--only", "contracts",
                   "--quiet"])
        assert rc == 0


# ------------------------------------------------------------ CLI surface

class TestCli:
    def test_fixture_tree_fails_with_waiver_reason(self, capsys):
        rc = main(["--root", str(FIXTURE_ROOT), "--quiet"])
        out = capsys.readouterr().out
        assert rc == 1
        assert "waiver-reason" in out       # bad_waiver.py: no reason given
        assert "jit-pyflow" in out
        assert "units-mix" in out
        assert "imports-cycle" in out
        assert "docs-paths" in out

    def test_module_entry_point(self):
        # the exact invocation CI gates on (docs subset: fast, no jax)
        proc = subprocess.run(
            [sys.executable, "-m", "tools.analysis", "--only", "docs_paths"],
            cwd=REPO_ROOT, capture_output=True, text=True, timeout=120,
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_unknown_checker_rejected(self):
        with pytest.raises(SystemExit):
            main(["--only", "nope"])

    def test_list_rules(self, capsys):
        assert main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        assert "twin-kwargs" in out and "jit-pyflow" in out
