"""c-server queue stations: model / JAX simulator / Python oracle agreement.

The multi-server extension must (a) leave every single-server result
bit-identical to the seed code, and (b) keep the three prongs consistent
with each other on genuinely multi-server networks.
"""

import numpy as np
import pytest

from repro.core import (
    QUEUE,
    THINK,
    Branch,
    ClosedNetwork,
    Station,
    exponential_analogue,
    lru_network,
)
from repro.core.py_sim import simulate_py
from repro.core.simulator import compile_network, simulate_network


def _two_server_network(mpl: int = 8) -> ClosedNetwork:
    """Tiny LRU-shaped network whose metadata op runs on TWO servers."""
    stations = (
        Station("lookup", THINK, 0.5, dist="det"),
        Station("disk", THINK, 20.0, dist="exp"),
        Station("head", QUEUE, 0.6, dist="exp", servers=2),
    )
    branches = (
        Branch("hit", lambda p: p, ("lookup", "head")),
        Branch("miss", lambda p: 1.0 - p, ("lookup", "disk", "head")),
    )
    return ClosedNetwork("lru2srv", stations, branches, mpl)


# ---------------------------------------------------------------------------
# servers=1 must reproduce the seed single-server numbers exactly
# ---------------------------------------------------------------------------


def test_throughput_upper_servers_one_reproduces_seed():
    """With all servers=1, the c/D law IS the seed's min(N/(D+Z), 1/Dmax)."""
    net = lru_network(disk_us=100.0)
    assert all(s.servers == 1 for s in net.stations)
    P = np.linspace(0.0, 0.999, 41)
    ours = net.throughput_upper(P)
    seed = np.empty_like(ours)
    for i, p in enumerate(P):
        d = net.demands(float(p))
        seed[i] = min(net.mpl / (sum(d.values()) + net.think_time(float(p))),
                      1.0 / max(d.values()))
    np.testing.assert_array_equal(ours, seed)


def test_mva_servers_one_reproduces_seed():
    """Both multiserver modes reduce to the seed recursion, bit for bit."""
    net = lru_network(disk_us=100.0)
    for p in (0.3, 0.84, 0.99):
        d = net.demands(p, tail_mode="nominal")
        D = np.array(list(d.values()))
        Z = net.think_time(p)
        Q = np.zeros_like(D)
        X = 0.0
        for k in range(1, net.mpl + 1):  # the seed's exact recursion
            R = D * (1.0 + Q)
            X = k / (Z + float(R.sum()))
            Q = X * R
        assert net.mva(p, multiserver="exact")[0] == X
        assert net.mva(p, multiserver="seidmann")[0] == X


# ---------------------------------------------------------------------------
# multi-server model properties
# ---------------------------------------------------------------------------


def test_multiserver_bottleneck_law():
    """A c-server station saturates at c/D, not 1/D."""
    net = _two_server_network(mpl=64)
    p = 0.95
    d_head = net.demands(p)["head"]
    assert net.throughput_upper(p) == pytest.approx(2.0 / d_head)
    one = ClosedNetwork(
        net.name, tuple(
            s if s.name != "head" else
            Station("head", QUEUE, 0.6, dist="exp", servers=1)
            for s in net.stations
        ), net.branches, net.mpl,
    )
    assert one.throughput_upper(p) == pytest.approx(1.0 / d_head)


def test_seidmann_underestimates_exact():
    """Seidmann's tandem decomposition is pessimistic near pop ~ c."""
    net = lru_network(disk_us=100.0, cores=16, disk_servers=16)
    for p in (0.5, 0.8):
        seid = net.mva(p, multiserver="seidmann")[0]
        exact = net.mva(p, multiserver="exact")[0]
        assert seid <= exact + 1e-12
        assert exact <= net.throughput_upper(p, tail_mode="nominal") * (1 + 1e-9)


def test_queue_first_route_rejected():
    """Simulators start all jobs in service at their first station — routes
    must begin at a think station, and both entry points enforce it."""
    stations = (Station("q", QUEUE, 1.0), Station("z", THINK, 1.0))
    net = ClosedNetwork("bad", stations, (Branch("b", 1.0, ("q", "z")),), 4)
    with pytest.raises(ValueError, match="think station"):
        net.validate()
    with pytest.raises(ValueError, match="think station"):
        compile_network(net, 0.5)


def test_compile_network_exposes_servers():
    spec = compile_network(_two_server_network(), 0.5)
    servers = np.asarray(spec.servers)
    is_q = np.asarray(spec.is_queue)
    assert servers[is_q].tolist() == [2]
    assert np.all(servers[~is_q] == 1)


# ---------------------------------------------------------------------------
# differential: JAX simulator vs heapq oracle on 2- and 8-server networks
# ---------------------------------------------------------------------------


def test_jax_matches_py_oracle_two_server():
    net = _two_server_network(mpl=8)
    for p in (0.5, 0.9):
        res = simulate_network(net, [p], n_requests=12_000, seeds=(0, 1, 2))
        x_py = simulate_py(net, p, n_requests=12_000, seed=3)
        x_jax = float(res.throughput[0])
        assert abs(x_py - x_jax) / x_py < 0.05, (p, x_py, x_jax)


def test_jax_matches_py_oracle_eight_server():
    """8-server disk station under a 16-client closed loop."""
    net = lru_network(disk_us=50.0, cores=16, disk_servers=8)
    for p in (0.4, 0.9):
        res = simulate_network(net, [p], n_requests=12_000, seeds=(0, 1, 2))
        x_py = simulate_py(net, p, n_requests=12_000, seed=5)
        x_jax = float(res.throughput[0])
        assert abs(x_py - x_jax) / x_py < 0.05, (p, x_py, x_jax)


def test_multiserver_sim_respects_bound_and_mva():
    """Sim below the c/D bound; exact LD-MVA tracks the exponential analogue."""
    net = _two_server_network(mpl=16)
    p = 0.9
    res = simulate_network(exponential_analogue(net), [p],
                           n_requests=20_000, seeds=(0, 1, 2), warmup_frac=0.4)
    x = float(res.throughput[0])
    assert x <= net.throughput_upper(p, tail_mode="nominal") * 1.03
    mva = net.mva(p)[0]
    assert abs(x - mva) / mva < 0.05, (x, mva)


def test_bypass_reaches_queue_station_disk():
    """Bypassed requests must still hit the backing store when it is a
    c-server queue station (disk_servers > 0), not only when it is a think
    station."""
    from repro.core import bypass_network

    net = lru_network(disk_us=100.0, cores=16, disk_servers=16)
    byp = bypass_network(net, 0.5)
    bypass_branch = next(b for b in byp.branches if b.name == "bypass")
    assert "disk" in bypass_branch.visits
    byp.validate()


def test_optimal_bypass_with_queue_station_disk():
    """Regression: with a bounded-I/O-depth disk, bypassing adds disk load,
    so the old cap-the-bottleneck bisection walked to beta=1 (a ~9x
    throughput LOSS); the maximizer must strictly improve on no bypass and
    never land on full bypass."""
    from repro.core import bypass_network, optimal_bypass_beta

    net = lru_network(disk_us=100.0, disk_servers=16)
    p = 0.999
    beta = optimal_bypass_beta(net, p)
    assert 0.0 < beta < 0.99, beta
    x_plain = net.throughput_upper(p)
    x_bypass = bypass_network(net, beta).throughput_upper(p)
    x_full = bypass_network(net, 1.0).throughput_upper(p)
    assert x_bypass > x_plain
    assert x_bypass > x_full


# ---------------------------------------------------------------------------
# Schweitzer / approximate MVA fallback for very large MPL
# ---------------------------------------------------------------------------


def test_amva_within_2pct_of_exact_at_mpl_500():
    """ROADMAP item: AMVA must track the exact recursion within 2% at
    MPL=500 (where exact is still affordable to cross-check)."""
    net = lru_network(disk_us=100.0)
    for p in (0.3, 0.84, 0.99):
        exact = net.mva(p, n=500)[0]
        amva = net.mva(p, n=500, mode="amva")[0]
        assert abs(amva - exact) / exact < 0.02, (p, exact, amva)


def test_amva_multiserver_within_2pct():
    net = lru_network(disk_us=100.0, cores=64, disk_servers=16)
    for p in (0.5, 0.9):
        exact = net.mva(p, n=500)[0]
        amva = net.mva(p, n=500, mode="amva")[0]
        assert abs(amva - exact) / exact < 0.02, (p, exact, amva)


def test_mva_auto_mode_switches_on_population():
    """auto == exact at small N; switches to AMVA above the threshold and
    stays cheap + bound-consistent at MPL = 10^5."""
    import time

    net = lru_network(disk_us=100.0)
    p = 0.9
    assert net.mva(p, n=200, mode="auto")[0] == net.mva(p, n=200)[0]
    n_big = 100_000
    t0 = time.time()
    x_auto = net.mva(p, n=n_big, mode="auto")[0]
    assert time.time() - t0 < 1.0, "AMVA must be O(1) in the population"
    assert x_auto == net.mva(p, n=n_big, mode="amva")[0]
    assert x_auto <= net.throughput_upper(p, tail_mode="nominal") * (1 + 1e-6)


def test_mva_rejects_unknown_mode():
    with pytest.raises(ValueError, match="mva mode"):
        lru_network().mva(0.5, mode="bogus")


def test_future_systems_p_star_shrinks():
    """The paper's closing claim, analytically: more cores + faster disk
    move the critical hit ratio strictly earlier."""
    p_now = lru_network(disk_us=100.0, cores=1, disk_servers=16).p_star()
    p_future = lru_network(disk_us=10.0, cores=64, disk_servers=16).p_star()
    assert p_future < p_now
    # and cores alone (disk fixed) already shrink it
    p_few = lru_network(disk_us=10.0, cores=4, disk_servers=16).p_star()
    assert p_future <= p_few <= p_now
