"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, shape + finiteness checks, and prefill/decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config
from repro.models import encdec, transformer
from repro.models.layers import param_values, tree_bytes

B, T = 2, 16


def _np(x):
    return np.asarray(jax.device_get(x))


@pytest.fixture(scope="module")
def built():
    """Init reduced params once per arch (module-scoped: compile cache)."""
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch, reduced=True)
        key = jax.random.PRNGKey(0)
        if cfg.encdec:
            params = param_values(encdec.init_params(cfg, key))
        else:
            params = param_values(transformer.init_params(cfg, key))
        out[arch] = (cfg, params)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, built):
    cfg, params = built[arch]
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab)
    if cfg.encdec:
        frames = jax.random.normal(key, (B, cfg.enc_positions, cfg.d_model),
                                   jnp.float32)
        logits = encdec.forward(params, frames, tokens, cfg)
    else:
        logits, _, aux = transformer.forward(params, tokens, cfg)
        for v in aux.values():
            assert np.isfinite(_np(v))
    assert logits.shape == (B, T, cfg.vocab)
    assert np.isfinite(_np(logits)).all(), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_decreases_loss_direction(arch, built):
    """Gradient step on the reduced model: loss finite, grads finite."""
    cfg, params = built[arch]
    key = jax.random.PRNGKey(2)
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab)

    if cfg.encdec:
        frames = jax.random.normal(key, (B, cfg.enc_positions, cfg.d_model))

        def loss_fn(p):
            logits = encdec.forward(p, frames, tokens, cfg)
            lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
            ll = jnp.take_along_axis(lp, tokens[:, 1:, None], axis=-1)
            return -ll.mean()
    else:
        def loss_fn(p):
            logits, _, aux = transformer.forward(p, tokens, cfg)
            lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
            ll = jnp.take_along_axis(lp, tokens[:, 1:, None], axis=-1)
            return -ll.mean() + 0.01 * aux["moe_aux_loss"]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(_np(loss)), arch
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(_np(g)).all() for g in flat), f"{arch}: bad grads"
    gnorm = float(sum((_np(g).astype(np.float64) ** 2).sum() for g in flat) ** 0.5)
    assert gnorm > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "whisper-tiny"])
def test_prefill_decode_matches_forward(arch, built):
    """Teacher-forced logits at position t == prefill(t) + decode logits."""
    cfg, params = built[arch]
    key = jax.random.PRNGKey(3)
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab)

    full, _, _ = transformer.forward(params, tokens, cfg)

    t_pre = T - 1
    caches = transformer.init_cache(cfg, B, max_seq=64)
    _, caches, _ = transformer.forward(
        params, tokens[:, :t_pre], cfg, caches=caches, cache_len=jnp.int32(0)
    )
    logits_step, _ = transformer.decode_step(
        params, tokens[:, t_pre:], caches, jnp.int32(t_pre), cfg
    )
    np.testing.assert_allclose(
        _np(logits_step[:, 0]), _np(full[:, -1]), rtol=2e-2, atol=2e-3,
    )


def test_whisper_decode_cache_matches_forward(built):
    cfg, params = built["whisper-tiny"]
    key = jax.random.PRNGKey(4)
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab)
    frames = jax.random.normal(key, (B, cfg.enc_positions, cfg.d_model))
    enc_out = encdec.encode(params, frames, cfg)
    full, _ = encdec.decode(params, tokens, enc_out, cfg)

    caches = encdec.init_dec_cache(params, enc_out, cfg, B, max_seq=64)
    _, caches = encdec.decode(params, tokens[:, : T - 1], enc_out, cfg,
                              caches=caches, cache_len=jnp.int32(0))
    step, _ = encdec.decode(params, tokens[:, T - 1 :], enc_out, cfg,
                            caches=caches, cache_len=jnp.int32(T - 1))
    np.testing.assert_allclose(_np(step[:, 0]), _np(full[:, -1]),
                               rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_formula_close(arch, built):
    cfg, params = built[arch]
    actual = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))
    est = cfg.param_count()
    assert abs(actual - est) / actual < 0.35, (arch, actual, est)


def test_full_config_param_counts():
    """Full-size analytic counts land near the advertised model sizes."""
    checks = {
        "arctic-480b": (400e9, 560e9),
        "llama4-scout-17b-a16e": (90e9, 130e9),  # 16 experts resident
        "qwen3-32b": (25e9, 40e9),
        "gemma3-27b": (20e9, 32e9),
        "internlm2-1.8b": (1.5e9, 2.4e9),
        "nemotron-4-15b": (12e9, 19e9),
        "rwkv6-7b": (5e9, 9e9),
        "zamba2-1.2b": (0.9e9, 1.7e9),
        "chameleon-34b": (28e9, 42e9),
        "whisper-tiny": (25e6, 60e6),
    }
    for arch, (lo, hi) in checks.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of range"


def test_moe_active_params_much_smaller():
    cfg = get_config("arctic-480b")
    assert cfg.active_param_count() < 0.15 * cfg.param_count()


def test_local_global_pattern_cycles():
    cfg = get_config("gemma3-27b")
    kinds = cfg.layer_kinds()
    assert len(kinds) == 62
    assert kinds[:6] == ["local"] * 5 + ["global"]
    assert sum(k == "global" for k in kinds) == 10
