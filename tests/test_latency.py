"""Open-loop latency prong (PR 4).

Analytic: the Erlang-C layer against M/M/c closed forms, the stability
boundary against the closed-loop knee, and the latency inversion /
operating-point divergence.  Simulation: the arrival-driven JAX simulator
against the heapq oracle (sojourns, classes) and against the analytics at
low utilization.  Satellites: the queueing-aware (MVA) in-flight window,
Zipf-weighted coalescing flows, and per-request classifier windows.
"""

import dataclasses
import math

import numpy as np
import pytest

from repro.core import (
    build,
    exponential_analogue,
    fifo_network,
    lru_network,
    sigma_of,
    zipf_flow_weights,
)
from repro.core.queueing import QUEUE, THINK, Branch, ClosedNetwork, Station
from repro.latency import (
    analyze_open,
    erlang_c,
    lambda_max,
    max_arrival_for_slo,
    response_percentile,
    response_time,
    slo_forecast,
)


def _mm1(service: float) -> ClosedNetwork:
    return ClosedNetwork(
        "mm1",
        (Station("z", THINK, 0.0), Station("q", QUEUE, service, dist="exp")),
        (Branch("all", 1.0, ("z", "q")),),
        mpl=1,
    )


# ---------------------------------------------------------------------------
# Analytic layer
# ---------------------------------------------------------------------------


def test_mm1_closed_form():
    """Single M/M/1 visit: R = S/(1-rho) and an exactly exponential sojourn."""
    s, lam = 2.0, 0.3
    a = analyze_open(_mm1(s), 0.5, lam)
    rho = lam * s
    assert a.mean == pytest.approx(s / (1.0 - rho), rel=1e-12)
    want_p99 = -s / (1.0 - rho) * math.log(0.01)
    assert a.percentile(0.99) == pytest.approx(want_p99, rel=1e-6)


def test_erlang_c_known_values():
    assert erlang_c(1, 0.5) == pytest.approx(0.5)  # M/M/1: P{wait} = rho
    assert erlang_c(2, 1.0) == pytest.approx(1.0 / 3.0)  # classic c=2 value
    assert erlang_c(4, 0.0) == 0.0


def test_mmc_wait_formula():
    """c-server station: W = C(c,a)·S/(c-a) + S, via a 2-server network."""
    net = ClosedNetwork(
        "mm2",
        (Station("z", THINK, 0.0),
         Station("q", QUEUE, 1.0, dist="exp", servers=2)),
        (Branch("all", 1.0, ("z", "q")),),
        mpl=1,
    )
    lam = 1.0  # a = 1.0 on 2 servers
    a = analyze_open(net, 0.5, lam)
    assert a.mean == pytest.approx(erlang_c(2, 1.0) * 1.0 / (2 - 1) + 1.0)


def test_lambda_max_is_closed_saturated_bound():
    """lambda_max(p) = min_k c_k/D_k — the Thm-7.1 saturated term."""
    for policy in ("lru", "fifo", "s3fifo"):
        net = build(policy, disk_us=100.0, disk_servers=4)
        for p in (0.3, 0.7, 0.95):
            d = net.demands(p)
            servers = net.queue_servers()
            want = min(servers[k] / dk for k, dk in d.items() if dk > 0)
            assert lambda_max(net, p) == pytest.approx(want, rel=1e-12)


def test_stability_knee_recovers_closed_pstar():
    """The open-loop knee (largest p maximizing lambda_max) is the
    closed-loop p* for both dichotomy poles."""
    grid = np.linspace(0.0, 1.0, 2001)
    for policy in ("lru", "fifo"):
        net = build(policy, disk_us=100.0)
        f = slo_forecast(net, arrival_rate=0.5, slo_us=1e4, p_grid=grid)
        assert f.p_star_throughput == pytest.approx(
            net.p_star(grid=2001), abs=1e-3)


def test_unstable_point_is_inf():
    net = lru_network(disk_us=100.0)
    lmax = lambda_max(net, 0.99)
    a = analyze_open(net, 0.99, 1.1 * lmax)
    assert not a.stable and math.isinf(a.mean)
    assert math.isinf(a.percentile(0.99))
    assert math.isinf(response_time(net, 0.99, 1.1 * lmax))


def test_response_monotone_in_lambda():
    net = lru_network(disk_us=100.0)
    lams = np.array([0.2, 0.6, 1.0, 1.3]) * lambda_max(net, 0.8)
    rs = [response_time(net, 0.8, float(l)) for l in lams[:-1]]
    assert np.all(np.diff(rs) > 0)


def test_latency_inversion_and_pstar_divergence():
    """At a fixed high load, LRU's mean/tail response RISES past the
    latency-optimal hit ratio, which sits away from the throughput-optimal
    knee; FIFO stays monotone with every optimum at p=1."""
    grid = np.linspace(0.0, 1.0, 201)
    lru = lru_network(disk_us=100.0)
    lam = 0.85 * float(np.max(lambda_max(lru, grid)))
    f = slo_forecast(lru, lam, slo_us=250.0, p_grid=grid)
    assert 0.5 < f.p_star_latency < 0.999
    assert abs(f.p_star_latency - f.p_star_throughput) > 0.02
    i_lat = int(np.argmin(np.abs(grid - f.p_star_latency)))
    i_hi = int(np.argmin(np.abs(grid - 0.98)))
    assert f.r_mean[i_hi] > 1.2 * f.r_mean[i_lat]
    assert f.r_tail[i_hi] > 1.2 * f.r_tail[i_lat]

    ff = slo_forecast(fifo_network(disk_us=100.0), lam, slo_us=250.0,
                      p_grid=grid)
    fin = np.isfinite(ff.r_mean)
    assert np.all(np.diff(ff.r_mean[fin]) <= 1e-9)
    assert ff.p_star_latency == 1.0 and ff.p_star_slo == 1.0


def test_percentiles_ordered():
    a = analyze_open(lru_network(disk_us=100.0), 0.8, 1.0)
    assert 0 < a.percentile(0.5) < a.percentile(0.9) < a.percentile(0.99)


def test_max_arrival_for_slo():
    net = lru_network(disk_us=100.0)
    # infeasible SLO (below the bare no-wait response) -> 0
    assert max_arrival_for_slo(net, 0.5, 1.0) == 0.0
    lam = max_arrival_for_slo(net, 0.95, 400.0)
    assert 0.0 < lam < lambda_max(net, 0.95)
    assert analyze_open(net, 0.95, lam).percentile(0.99) <= 400.0 + 1e-6


# ---------------------------------------------------------------------------
# Open-loop simulation: JAX vs heapq oracle vs analytics
# ---------------------------------------------------------------------------

DISK_TIERS = [
    {"disk_us": 100.0, "disk_servers": 0},  # paper's infinite-server disk
    {"disk_us": 500.0, "disk_servers": 8},  # bounded I/O depth
]


def _open_rate(net, p, frac):
    return frac * float(lambda_max(net, p, tail_mode="nominal"))


@pytest.mark.parametrize("policy", ["lru", "fifo", "clock"])
@pytest.mark.parametrize("tier", range(len(DISK_TIERS)))
def test_open_sim_matches_oracle(policy, tier):
    """The acceptance differential: arrival-driven JAX simulator vs the
    independent heapq oracle agree on throughput and mean sojourn."""
    from repro.core.py_sim import simulate_py
    from repro.core.simulator import simulate_network

    net = exponential_analogue(build(policy, **DISK_TIERS[tier]))
    p = 0.7
    lam = _open_rate(net, p, 0.55)
    py = [simulate_py(net, p, n_requests=5_000, seed=s, arrival_rate=lam)
          for s in (3, 4)]
    x_py = np.mean([r["x"] for r in py])
    r_py = np.mean([r["sojourn_mean"] for r in py])
    jx = simulate_network(net, [p], arrival_rate=lam, n_requests=10_000,
                          seeds=(0, 1, 2))
    assert np.all(jx.drop_frac == 0.0)
    assert all(r["drop_frac"] == 0.0 for r in py)
    assert abs(x_py - jx.throughput[0]) / x_py < 0.06, (x_py, jx.throughput)
    assert abs(r_py - jx.sojourn_mean[0]) / r_py < 0.12, (
        policy, tier, r_py, jx.sojourn_mean[0])


def test_open_sim_matches_analytic_at_low_utilization():
    from repro.core.simulator import simulate_network

    net = exponential_analogue(lru_network(disk_us=100.0))
    p = np.array([0.4, 0.8])
    lam = _open_rate(net, 0.8, 0.35)
    jx = simulate_network(net, p, arrival_rate=lam, n_requests=20_000,
                          seeds=(0, 1))
    want = response_time(net, p, lam)
    rel = np.abs(jx.sojourn_mean - want) / want
    assert np.all(rel < 0.08), (jx.sojourn_mean, want)
    # throughput == offered rate in a stable drop-free system
    assert np.all(np.abs(jx.throughput - lam) / lam < 0.05)
    assert np.all(jx.sojourn_p99 > jx.sojourn_mean)


def test_open_sim_class_breakdown_and_parked_sojourns():
    """Delayed hits carry the parked interval in their sojourn: slower than
    true hits, faster than true misses when the fetch is deterministic."""
    from repro.core.simulator import simulate_network

    net = lru_network(disk_us=100.0, disk_servers=8)
    net = dataclasses.replace(net, stations=tuple(
        dataclasses.replace(s, dist="det") if s.name == "disk" else s
        for s in net.stations))
    jx = simulate_network(net, [0.5], arrival_rate=0.1, n_requests=10_000,
                          seeds=(0, 1), coalesce_flows=16, max_in_system=256)
    assert jx.class_frac[0].sum() == pytest.approx(1.0)
    assert jx.class_frac[0, 2] > 0.03  # delayed hits present
    assert jx.delayed_frac[0] == pytest.approx(jx.class_frac[0, 2], abs=1e-6)
    hit, miss, delayed = (jx.class_sojourn[0, 1], jx.class_sojourn[0, 0],
                          jx.class_sojourn[0, 2])
    assert hit < delayed < miss, jx.class_sojourn


def test_open_sim_oracle_agrees_with_coalescing():
    from repro.core.py_sim import simulate_py
    from repro.core.simulator import simulate_network

    net = exponential_analogue(lru_network(disk_us=100.0, disk_servers=8))
    lam = 0.1
    py = simulate_py(net, 0.5, n_requests=5_000, seed=5, arrival_rate=lam,
                     coalesce_flows=16)
    jx = simulate_network(net, [0.5], arrival_rate=lam, n_requests=10_000,
                          seeds=(0, 1, 2), coalesce_flows=16,
                          max_in_system=256)
    assert abs(py["sojourn_mean"] - jx.sojourn_mean[0]) / py["sojourn_mean"] \
        < 0.15, (py["sojourn_mean"], jx.sojourn_mean)
    assert abs(py["delayed_frac"] - jx.delayed_frac[0]) < 0.05


def test_open_sim_deterministic_given_seed():
    from repro.core.simulator import simulate_network

    net = lru_network(disk_us=100.0)
    a = simulate_network(net, [0.8], arrival_rate=1.0, n_requests=3_000,
                         seeds=(7,))
    b = simulate_network(net, [0.8], arrival_rate=1.0, n_requests=3_000,
                         seeds=(7,))
    np.testing.assert_array_equal(a.sojourn_mean, b.sojourn_mean)
    np.testing.assert_array_equal(a.throughput, b.throughput)


def test_open_sim_rejects_bad_rate():
    from repro.core.simulator import simulate_network

    with pytest.raises(ValueError):
        simulate_network(lru_network(), [0.5], arrival_rate=0.0,
                         n_requests=100)


# ---------------------------------------------------------------------------
# Satellite: moment-matched hypoexponential per-branch tails
# ---------------------------------------------------------------------------


def test_hypoexp_tail_tightens_p99_at_high_utilization():
    """The moment-matched per-branch (gamma / generalized-Erlang) tail
    must land closer to the simulated p99 than the legacy per-branch
    exponential mixture at high utilization — a multi-stage branch has
    cv² < 1, nothing like an exponential."""
    from repro.core.simulator import simulate_network

    net = exponential_analogue(build("lru", disk_us=5.0))
    grid = np.linspace(0.0, 1.0, 201)
    lam = 0.838 * float(np.max(lambda_max(net, grid)))
    p = 0.9
    sim = simulate_network(net, [p], arrival_rate=lam, n_requests=30_000,
                           seeds=(0, 1, 2), max_in_system=256)
    a = analyze_open(net, p, lam)
    hypo = a.percentile(0.99)
    legacy = a.percentile(0.99, tail="exp")
    p99 = float(sim.sojourn_p99[0])
    assert abs(hypo - p99) < abs(legacy - p99), (hypo, legacy, p99)
    assert abs(hypo - p99) / p99 < 0.25, (hypo, p99)


def test_hypoexp_tail_lighter_than_exp_mixture():
    """Sums of stages are lighter-tailed than exponentials at the same
    mean, so the new p99 sits strictly below the legacy one on every
    multi-stage network."""
    a = analyze_open(lru_network(disk_us=100.0), 0.8, 1.0)
    assert a.percentile(0.99) < a.percentile(0.99, tail="exp")
    assert 0 < a.percentile(0.5) < a.percentile(0.9) < a.percentile(0.99)


def test_percentile_rejects_unknown_tail():
    a = analyze_open(lru_network(disk_us=100.0), 0.5, 0.5)
    with pytest.raises(ValueError):
        a.percentile(0.99, tail="weibull")


def test_branch_variance_is_mm1_exact():
    """c=1 station: the recorded branch variance must equal the exact
    M/M/1 sojourn variance (S/(1-rho))^2, making the gamma fit collapse
    to the true exponential."""
    s, lam = 2.0, 0.3
    a = analyze_open(_mm1(s), 0.5, lam)
    (_, _, rb, vb), = [b for b in a.branches]
    want = (s / (1.0 - lam * s)) ** 2
    assert vb == pytest.approx(want, rel=1e-12)
    assert rb * rb == pytest.approx(vb, rel=1e-12)  # cv^2 == 1


# ---------------------------------------------------------------------------
# Satellite: MAP / ON-OFF burst arrivals
# ---------------------------------------------------------------------------


def test_burst_preserves_mean_rate():
    from repro.core.simulator import simulate_network

    net = exponential_analogue(lru_network(disk_us=100.0))
    lam = 0.5
    jx = simulate_network(net, [0.7], arrival_rate=lam, n_requests=30_000,
                          seeds=(0, 1), burst=(0.8, 500.0))
    assert np.all(jx.drop_frac == 0.0)
    assert abs(jx.throughput[0] - lam) / lam < 0.1, jx.throughput


def test_burst_raises_sojourn_at_load():
    """Same mean rate, bursty arrivals: the ON-period overload pushes the
    mean and tail sojourn above Poisson."""
    from repro.core.simulator import simulate_network

    net = exponential_analogue(lru_network(disk_us=100.0))
    lam = 0.9
    kw = dict(arrival_rate=lam, n_requests=25_000, seeds=(0, 1),
              max_in_system=512)
    po = simulate_network(net, [0.7], **kw)
    bu = simulate_network(net, [0.7], burst=(0.55, 2_000.0), **kw)
    assert bu.sojourn_mean[0] > 1.3 * po.sojourn_mean[0], (
        bu.sojourn_mean, po.sojourn_mean)
    assert bu.sojourn_p99[0] > po.sojourn_p99[0]


def test_burst_oracle_agrees():
    from repro.core.py_sim import simulate_py
    from repro.core.simulator import simulate_network

    net = exponential_analogue(lru_network(disk_us=100.0))
    lam, burst = 0.8, (0.6, 1_000.0)
    py = [simulate_py(net, 0.7, n_requests=8_000, seed=s, arrival_rate=lam,
                      burst=burst, max_in_system=256) for s in (3, 4)]
    jx = simulate_network(net, [0.7], arrival_rate=lam, n_requests=12_000,
                          seeds=(0, 1, 2), burst=burst, max_in_system=256)
    r_py = np.mean([r["sojourn_mean"] for r in py])
    x_py = np.mean([r["x"] for r in py])
    assert abs(x_py - jx.throughput[0]) / x_py < 0.1, (x_py, jx.throughput)
    assert abs(r_py - jx.sojourn_mean[0]) / r_py < 0.2, (
        r_py, jx.sojourn_mean)


def test_burst_validation():
    from repro.core.py_sim import simulate_py
    from repro.core.simulator import simulate_network

    net = lru_network(disk_us=100.0)
    with pytest.raises(ValueError):  # burst needs the open-loop mode
        simulate_network(net, [0.5], n_requests=100, burst=(0.5, 100.0))
    with pytest.raises(ValueError):  # bad duty
        simulate_network(net, [0.5], arrival_rate=0.5, n_requests=100,
                         burst=(1.5, 100.0))
    with pytest.raises(ValueError):
        simulate_py(net, 0.5, n_requests=100, burst=(0.5, 100.0))


# ---------------------------------------------------------------------------
# Satellite: queueing-aware (MVA) in-flight window
# ---------------------------------------------------------------------------


def test_mva_window_identity_on_think_disk():
    """With the paper's infinite-server disk there is no queueing wait, so
    the mva window must not change anything."""
    a = build("lru", disk_us=100.0, coalesce_flows=16)
    b = build("lru", disk_us=100.0, coalesce_flows=16,
              coalesce_window_mode="mva")
    P = np.linspace(0.05, 0.95, 7)
    np.testing.assert_allclose(a.throughput_upper(P), b.throughput_upper(P),
                               rtol=1e-12)


def test_mva_window_closes_simulator_gap():
    """ROADMAP gap: at p=0.5 with a saturated IO_DEPTH=8 disk the simulator
    shows ~0.42 delayed completions but the service-window sigma predicts
    only ~0.25 — the fetch stays outstanding through its queueing delay.
    The MVA window must land much closer to the simulator."""
    from repro.core.simulator import simulate_network

    p = 0.5
    net = lru_network(disk_us=500.0, disk_servers=8)
    sim = simulate_network(net, [p], n_requests=12_000, seeds=(0, 1, 2),
                           coalesce_flows=16).delayed_frac[0]
    kw = dict(disk_us=500.0, disk_servers=8, coalesce_flows=16)
    pred_svc = sigma_of(build("lru", **kw), p) * (1 - p)
    pred_mva = sigma_of(
        build("lru", coalesce_window_mode="mva", **kw), p) * (1 - p)
    assert abs(pred_mva - sim) < abs(pred_svc - sim)
    assert abs(pred_mva - sim) < 0.08, (pred_mva, sim)


def test_mva_window_with_pinned_sigma_validates():
    net = build("lru", disk_us=500.0, disk_servers=8, coalesce_flows=16,
                coalesce_sigma=0.4, coalesce_window_mode="mva")
    net.validate()
    assert sigma_of(net, 0.5) == pytest.approx(0.4)
    # the inflight park time reflects the queueing-aware window: longer
    # than half the bare service
    assert net.station("inflight").mean_service(0.5) > 0.5 * 500.0


# ---------------------------------------------------------------------------
# Satellite: Zipf-weighted coalescing flows
# ---------------------------------------------------------------------------


def test_zipf_flow_weights_basics():
    w = zipf_flow_weights(64, 0.9)
    assert w.shape == (64,) and w.sum() == pytest.approx(1.0)
    assert np.all(np.diff(w) < 0)  # descending popularity
    np.testing.assert_allclose(zipf_flow_weights(8, 0.0), np.full(8, 1 / 8))


def test_zipf_theta_zero_matches_uniform_fixed_point():
    a = build("lru", disk_us=100.0, coalesce_flows=32)
    b = build("lru", disk_us=100.0, coalesce_flows=32,
              coalesce_flow_theta=0.0)
    for p in (0.3, 0.7):
        assert sigma_of(a, p) == sigma_of(b, p)


def test_zipf_flows_increase_sigma_and_predict_simulator():
    """Skewed flows collide more; the weighted fixed point predicts the
    simulator's delayed fraction about as well as the uniform one does for
    uniform flows (same known model bias, same direction)."""
    from repro.core.simulator import simulate_network

    p, flows, theta = 0.5, 64, 0.9
    net = lru_network(disk_us=100.0)
    uni = simulate_network(net, [p], n_requests=12_000, seeds=(0, 1, 2),
                           coalesce_flows=flows).delayed_frac[0]
    zipf = simulate_network(net, [p], n_requests=12_000, seeds=(0, 1, 2),
                            coalesce_flows=flows,
                            coalesce_theta=theta).delayed_frac[0]
    assert zipf > uni + 0.02  # skew -> more coalescing, event level
    m_uni = sigma_of(build("lru", disk_us=100.0, coalesce_flows=flows), p) \
        * (1 - p)
    m_zipf = sigma_of(build("lru", disk_us=100.0, coalesce_flows=flows,
                            coalesce_flow_theta=theta), p) * (1 - p)
    assert m_zipf > m_uni  # model moves the same direction
    assert abs(m_zipf - zipf) / zipf < 0.2, (m_zipf, zipf)


def test_py_oracle_zipf_flows_agree():
    from repro.core.py_sim import simulate_py
    from repro.core.simulator import simulate_network

    net = lru_network(disk_us=100.0)
    py = simulate_py(net, 0.5, n_requests=8_000, seed=3, coalesce_flows=64,
                     coalesce_theta=0.9, full=True)
    jx = simulate_network(net, [0.5], n_requests=12_000, seeds=(0, 1, 2),
                          coalesce_flows=64, coalesce_theta=0.9)
    assert abs(py["delayed_frac"] - jx.delayed_frac[0]) < 0.05


# ---------------------------------------------------------------------------
# Satellite: per-request classifier windows
# ---------------------------------------------------------------------------


def test_classifier_per_request_windows_match_py_reference():
    from repro.cache import classify_inflight, classify_inflight_py
    from repro.core.harness import miss_window_stream

    rng = np.random.default_rng(0)
    keys = rng.integers(0, 97, 4_000)
    hits = rng.random(4_000) < 0.6
    wins = miss_window_stream(4_000, 25.0, seed=3)
    np.testing.assert_array_equal(
        classify_inflight(keys, hits, wins),
        classify_inflight_py(keys, hits, wins),
    )


def test_classifier_constant_array_equals_scalar():
    from repro.cache import classify_inflight

    rng = np.random.default_rng(1)
    keys = rng.integers(0, 50, 2_000)
    hits = rng.random(2_000) < 0.5
    for w in (0, 9, 33):
        np.testing.assert_array_equal(
            classify_inflight(keys, hits, w),
            classify_inflight(keys, hits, np.full(2_000, w)),
        )


def test_classifier_zero_windows_bit_identical_to_hits():
    from repro.cache import DELAYED_HIT, TRUE_HIT, classify_inflight
    from repro.core.harness import coin_stream, zipf_trace
    from repro.cache.replay import replay_trace

    trace = zipf_trace(6_000, 1024, seed=2)
    res = replay_trace("lru", trace, coin_stream(6_000, 2), 128,
                       key_space=1024)
    cls = classify_inflight(trace, res.hits, np.zeros(6_000, np.int64),
                            key_space=1024)
    assert not np.any(cls == DELAYED_HIT)
    np.testing.assert_array_equal(cls == TRUE_HIT, res.hits)


def test_classifier_rejects_bad_windows():
    from repro.cache import classify_inflight

    keys = np.zeros(10, np.int64)
    hits = np.zeros(10, bool)
    with pytest.raises(ValueError):
        classify_inflight(keys, hits, np.full(10, -1))
    with pytest.raises(ValueError):
        classify_inflight(keys, hits, np.zeros(7, np.int64))


def test_measure_and_sweep_accept_window_streams():
    from repro.core.harness import (measure_cache, miss_window_stream,
                                    sweep_cache_sizes)

    wins = miss_window_stream(10_000, 40.0, seed=0)
    m = measure_cache("lru", 128, key_space=1024, n_requests=10_000,
                      backend="jax", miss_latency_requests=wins)
    assert m.class_fracs is not None
    assert 0.0 < m.coalesce_sigma < 1.0
    assert m.miss_latency_requests == int(round(float(wins.mean())))
    out = sweep_cache_sizes("lru", [64, 512], key_space=1024,
                            n_requests=10_000, miss_latency_requests=wins)
    assert out["sigma"][0] > out["sigma"][-1] >= 0.0
    # py/jax backends classify per-request windows identically
    a = measure_cache("clock", 64, key_space=512, n_requests=5_000,
                      backend="py",
                      miss_latency_requests=miss_window_stream(5_000, 20.0))
    b = measure_cache("clock", 64, key_space=512, n_requests=5_000,
                      backend="jax",
                      miss_latency_requests=miss_window_stream(5_000, 20.0))
    np.testing.assert_allclose(a.class_fracs, b.class_fracs)
