"""Launch layer: spec machinery, drivers, elastic restore — on a 1-device
mesh (the 512-device dry-run itself runs via repro.launch.dryrun)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding as shardlib
from repro.configs.registry import ARCHS, get_config
from repro.launch.mesh import single_device_context
from repro.launch.specs import SHAPES, ShapeSpec, build_cell, _is_spec


def test_is_spec_classifier():
    assert _is_spec((None, "model"))
    assert _is_spec((("batch", "model"), None))
    assert _is_spec(())
    assert not _is_spec(({"a": 1},))
    from repro.models.attention import KVCache

    assert not _is_spec(KVCache((None,), (None,), (None,)))
    assert not _is_spec(((None, "x"), {"d": 2}))


SMALL_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 64, 4, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 64, 2, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 64, 4, "decode"),
    "long_500k": ShapeSpec("long_500k", 128, 1, "decode"),
}


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "rwkv6-7b", "zamba2-1.2b",
                                  "llama4-scout-17b-a16e", "whisper-tiny",
                                  "gemma3-27b"])
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_cell_lowers_on_single_device(arch, shape_name):
    """Every plan kind traces + lowers with reduced configs (fast check of
    the sharding/spec machinery; full configs run in the dry-run sweep)."""
    if (arch, shape_name) in {("whisper-tiny", "long_500k")}:
        pytest.skip("skipped cell (DESIGN.md)")
    cfg = get_config(arch, reduced=True)
    ctx = single_device_context()
    with shardlib.use_mesh(ctx):
        plan = build_cell(arch, shape_name, cfg=cfg,
                          shape=SMALL_SHAPES[shape_name])
        jitted = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                         out_shardings=plan.out_shardings,
                         donate_argnums=plan.donate)
        lowered = jitted.lower(*plan.args)
        assert "module" in lowered.as_text()[:200]


def test_train_driver_loss_decreases(tmp_path):
    from repro.launch import train as train_cli

    losses = train_cli.main([
        "--arch", "internlm2-1.8b", "--reduced", "--steps", "30",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path / "ck"),
        "--ckpt-every", "15",
    ])
    assert np.mean(losses[-5:]) < np.mean(losses[:5])

    # resume from checkpoint continues at the saved step
    more = train_cli.main([
        "--arch", "internlm2-1.8b", "--reduced", "--steps", "35",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path / "ck"),
        "--resume",
    ])
    assert len(more) == 5  # only steps 30..35 ran


def test_serve_driver_runs():
    from repro.launch import serve as serve_cli

    stats = serve_cli.main(["--requests", "10", "--max-new", "4"])
    assert stats["decode_steps"] > 0
    assert 0.0 <= stats["chunk_hit_ratio"] <= 1.0


def test_elastic_reshard(tmp_path):
    from repro.launch.elastic import reshard
    from repro.training import checkpoint as ckpt_lib
    from repro.training.optimizer import OptimizerConfig
    from repro.training.train_state import init_train_state

    cfg = get_config("internlm2-1.8b", reduced=True)
    opt = OptimizerConfig()
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    ckpt_lib.save(str(tmp_path), state, step=7)

    # restore under a different (1-device) mesh context
    restored = reshard(str(tmp_path), like=state, ctx=single_device_context())
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_collective_parser():
    from repro.launch.dryrun import parse_collectives

    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[512]{0} all-reduce(%y), to_apply=%add
  %ars = f32[8,2]{1,0} all-reduce-start(%z)
  %ard = f32[8,2]{1,0} all-reduce-done(%ars)
  %a2a = s8[64]{0} all-to-all(%w)
"""
    out = parse_collectives(hlo)
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["all-reduce"] == (512 * 4 + 8 * 2 * 4) * 2  # ring 2x, start counted once
    assert out["all-to-all"] == 64
    assert out["total_bytes"] == sum(
        v for k, v in out.items() if not k.startswith("count") and k != "total_bytes"
    )


def test_shape_table_matches_assignment():
    assert SHAPES["train_4k"].seq == 4096 and SHAPES["train_4k"].batch == 256
    assert SHAPES["prefill_32k"].seq == 32768 and SHAPES["prefill_32k"].batch == 32
    assert SHAPES["decode_32k"].seq == 32768 and SHAPES["decode_32k"].batch == 128
    assert SHAPES["long_500k"].seq == 524288 and SHAPES["long_500k"].batch == 1
    assert len(ARCHS) == 10
