"""Differential tests for the pallas replay & event-sim backend.

Three executables share the per-policy step functions — the pallas kernel
body (``interpret=True``, the CI fallback that runs on CPU), the compiled
vmapped scan twin (``interpret=None`` off-TPU), and the dlist scan engine
— and must be *bit-identical* on every policy: hits, evicted keys, op
vectors, and the fused delayed-hit classification, including padded
states (pad_to > capacity) and capacities that are not a multiple of any
tile.  The py_ref oracle pins the whole stack to the pure-Python ground
truth, and the harness must report identical measurements whichever
backend it is pointed at.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import classify_inflight, classify_inflight_py
from repro.cache.py_ref import PY_POLICIES
from repro.cache.replay import replay_grid
from repro.core import lru_network
from repro.core.harness import (
    coin_stream,
    measure_cache,
    run_cache_trace,
    sweep_cache_sizes,
    zipf_trace,
)
from repro.core.simulator import simulate_network
from repro.kernels import ops, ref
from repro.kernels.event_sim import simulate_grid_pallas
from repro.kernels.replay import replay_grid_pallas, unpack_grid_ops

KEY_SPACE = 24

JAX_PARAMS = {
    "lru": {},
    "fifo": {},
    "prob_lru": {"q": 0.5},
    "clock": {"max_scan": 3},
    "slru": {"protected_frac": 0.5},
    "s3fifo": {"small_frac": 0.25, "max_scan": 3},
    "sieve": {},
}
PY_PARAMS = {**JAX_PARAMS, "s3fifo": {"small_frac": 0.25}}


def _trace(n=1500, seed=0):
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, KEY_SPACE + 1)
    probs = (1.0 / ranks**0.99) / np.sum(1.0 / ranks**0.99)
    keys = rng.choice(KEY_SPACE, size=n, p=probs)
    us = rng.random(n, dtype=np.float32)
    return keys, us


def _oracle(policy, capacity, keys, us):
    py = PY_POLICIES[policy](capacity, **PY_PARAMS[policy])
    hits, evicted, ops4 = [], [], []
    for k, u in zip(keys, us):
        a = py.access(int(k), float(u))
        hits.append(a.hit)
        evicted.append(a.evicted_key)
        ops4.append(a.ops)
    return (np.asarray(hits), np.asarray(evicted, np.int64),
            np.asarray(ops4, np.int64))


@pytest.mark.parametrize("policy", sorted(PY_POLICIES))
@pytest.mark.parametrize("capacity,pad_to", [(7, 16), (8, 8)])
def test_twin_matches_scan_and_py_ref(policy, capacity, pad_to):
    """The compiled twin == dlist scan engine == py_ref oracle."""
    keys, us = _trace()
    res = replay_grid_pallas(policy, keys, us, [capacity],
                             key_space=KEY_SPACE, pad_to=pad_to,
                             **JAX_PARAMS[policy])
    hits, evicted, ops4 = _oracle(policy, capacity, keys, us)
    np.testing.assert_array_equal(np.asarray(res.hits)[0, 0], hits,
                                  err_msg=f"{policy} hits")
    np.testing.assert_array_equal(np.asarray(res.evicted)[0, 0], evicted,
                                  err_msg=f"{policy} evicted")
    np.testing.assert_array_equal(unpack_grid_ops(res)[0, 0], ops4,
                                  err_msg=f"{policy} ops")
    assert res.cls is None  # no window requested

    scan = replay_grid(policy, keys, us, [capacity], key_space=KEY_SPACE,
                       pad_to=pad_to, **JAX_PARAMS[policy])
    np.testing.assert_array_equal(np.asarray(res.hits), scan.hits)
    np.testing.assert_array_equal(unpack_grid_ops(res), scan.ops)


@pytest.mark.parametrize("policy", sorted(PY_POLICIES))
def test_kernel_interpreter_bit_identical(policy):
    """interpret=True runs the actual kernel body on CPU and must equal
    the twin bit-for-bit — the CI fallback contract, with pad > capacity
    and a window so the fused classifier path is exercised too."""
    keys, us = _trace(400, seed=1)
    kw = dict(key_space=KEY_SPACE, pad_to=16, window=8,
              **JAX_PARAMS[policy])
    twin = replay_grid_pallas(policy, keys, us, [7, 11], **kw)
    kern = replay_grid_pallas(policy, keys, us, [7, 11], interpret=True,
                              **kw)
    for field in ("hits", "evicted", "ops", "cls"):
        np.testing.assert_array_equal(
            np.asarray(getattr(kern, field)),
            np.asarray(getattr(twin, field)),
            err_msg=f"{policy} {field}")


def test_non_tile_multiple_capacity():
    """C=700-class shapes: capacity not a multiple of any tile/pad size,
    pad rounding above it, seeds > 1."""
    rng = np.random.default_rng(2)
    S, T = 2, 500
    keys = rng.integers(0, KEY_SPACE, size=(S, T))
    us = rng.random((S, T), dtype=np.float32)
    caps = [5, 13]
    kw = dict(key_space=KEY_SPACE, pad_to=32, max_scan=3)
    twin = replay_grid_pallas("clock", keys, us, caps, **kw)
    kern = replay_grid_pallas("clock", keys, us, caps, interpret=True, **kw)
    assert twin.hits.shape == (len(caps), S, T)
    np.testing.assert_array_equal(np.asarray(kern.hits),
                                  np.asarray(twin.hits))
    scan = replay_grid("clock", keys, us, caps, key_space=KEY_SPACE,
                       pad_to=32, max_scan=3)
    np.testing.assert_array_equal(np.asarray(twin.hits), scan.hits)
    np.testing.assert_array_equal(unpack_grid_ops(twin), scan.ops)


def test_lru_batch_update_non_tile_multiple():
    """The demo kernel handles n not a multiple of the tile (700/512)."""
    rng = np.random.default_rng(3)
    ts = jnp.asarray(rng.integers(0, 10_000, 700, dtype=np.int32))
    acc = jnp.asarray(rng.choice(700, 96, replace=False).astype(np.int32))
    new_ts, victim = ops.lru_batch_update(ts, acc, jnp.int32(99_999),
                                          tile=512, interpret=True)
    ref_ts, ref_victim = ref.lru_batch_update_ref(ts, acc, jnp.int32(99_999))
    np.testing.assert_array_equal(np.asarray(new_ts), np.asarray(ref_ts))
    assert int(victim) == int(ref_victim)


def test_fused_classification_matches_classifier():
    """The in-kernel expiry table == classify_inflight == the py oracle,
    with retry stretching (fail_prob > 0) and per-request windows."""
    keys, us = _trace(1200, seed=4)
    per_req = (np.arange(1200) % 7 + 2).astype(np.int32)
    for window in (9, per_req):
        res = replay_grid_pallas("lru", keys, us, [6, 10],
                                 key_space=KEY_SPACE, window=window,
                                 fail_prob=0.3, fail_seed=5)
        cls_ref = classify_inflight(keys, np.asarray(res.hits)[:, 0],
                                    window, key_space=KEY_SPACE,
                                    fail_prob=0.3, fail_seed=5)
        np.testing.assert_array_equal(np.asarray(res.cls)[:, 0], cls_ref)
        cls_py = classify_inflight_py(keys, np.asarray(res.hits)[0, 0],
                                      window, fail_prob=0.3, fail_seed=5)
        np.testing.assert_array_equal(np.asarray(res.cls)[0, 0], cls_py)


def test_device_resident_classification():
    """classify_inflight accepts device hits without a host round-trip:
    returns a jax.Array, equal to the host path, and insists on an
    explicit key_space (inference would sync the device)."""
    keys, us = _trace(800, seed=6)
    res = replay_grid_pallas("lru", keys, us, [8], key_space=KEY_SPACE)
    cls_dev = classify_inflight(keys, res.hits[:, 0], 6,
                                key_space=KEY_SPACE)
    assert isinstance(cls_dev, jax.Array)
    cls_host = classify_inflight(keys, np.asarray(res.hits)[:, 0], 6,
                                 key_space=KEY_SPACE)
    np.testing.assert_array_equal(np.asarray(cls_dev), cls_host)
    with pytest.raises(ValueError, match="key_space"):
        classify_inflight(keys, res.hits[:, 0], 6)


def test_event_sim_kernel_matches_twin():
    """The event-sim kernel body (interpreter) == its compiled twin."""
    net = lru_network(disk_us=100.0)
    p = np.array([0.5, 0.9])
    twin = simulate_grid_pallas(net, p, n_requests=300, seeds=(0,))
    kern = simulate_grid_pallas(net, p, n_requests=300, seeds=(0,),
                                interpret=True)
    np.testing.assert_array_equal(twin.throughput, kern.throughput)
    np.testing.assert_array_equal(twin.p_hit, kern.p_hit)


def test_event_sim_statistics_match_threefry():
    """Counter-RNG engine agrees with the threefry scan simulator within
    sampling error and preserves the paper's hit-ratio inversion."""
    net = lru_network(disk_us=100.0)
    p = np.array([0.7, 0.9, 0.99])
    a = simulate_network(net, p, n_requests=8000, seeds=(0, 1))
    b = simulate_network(net, p, n_requests=8000, seeds=(0, 1),
                         backend="pallas")
    np.testing.assert_allclose(b.throughput, a.throughput, rtol=0.06)
    assert b.throughput[2] < b.throughput[1]  # 0.99 slower than 0.9


def test_harness_backend_agreement():
    """run/measure/sweep report identical numbers for jax and pallas."""
    trace = zipf_trace(2000, 256, 0.99, 0)
    h_j, o_j = run_cache_trace("sieve", 32, trace, backend="jax",
                               key_space=256)
    h_p, o_p = run_cache_trace("sieve", 32, trace, backend="pallas",
                               key_space=256)
    np.testing.assert_array_equal(h_j, h_p)
    np.testing.assert_array_equal(o_j, o_p)

    m_j = measure_cache("clock", 32, key_space=256, n_requests=2000,
                        backend="jax", miss_latency_requests=5,
                        fetch_fail_prob=0.1, max_scan=3)
    m_p = measure_cache("clock", 32, key_space=256, n_requests=2000,
                        backend="pallas", miss_latency_requests=5,
                        fetch_fail_prob=0.1, max_scan=3)
    assert m_j.hit_ratio == m_p.hit_ratio
    np.testing.assert_allclose(m_p.class_fracs, m_j.class_fracs)

    for mlr in (5, np.array([3, 7])):
        s_j = sweep_cache_sizes("slru", [16, 48], key_space=256,
                                n_requests=2000, backend="jax",
                                miss_latency_requests=mlr,
                                protected_frac=0.5)
        s_p = sweep_cache_sizes("slru", [16, 48], key_space=256,
                                n_requests=2000, backend="pallas",
                                miss_latency_requests=mlr,
                                protected_frac=0.5)
        for k in s_j:
            np.testing.assert_allclose(s_p[k], s_j[k], err_msg=k,
                                       rtol=1e-12)


def test_validation_errors():
    keys, us = _trace(100)
    with pytest.raises(ValueError, match="shape mismatch"):
        replay_grid_pallas("lru", keys, us[:-1], [8], key_space=KEY_SPACE)
    with pytest.raises(ValueError, match="at least one capacity"):
        replay_grid_pallas("lru", keys, us, [], key_space=KEY_SPACE)
    net = lru_network(disk_us=100.0)
    with pytest.raises(ValueError, match="unknown backend"):
        simulate_network(net, [0.5], backend="nope")
    with pytest.raises(ValueError, match="closed loop"):
        simulate_network(net, [0.5], backend="pallas", arrival_rate=0.1)
    with pytest.raises(ValueError, match="closed loop"):
        simulate_network(net, [0.5], backend="pallas", coalesce_flows=4)


@pytest.mark.slow
def test_kernel_interpreter_grid_large():
    """A bigger (capacity x seed) interpreter grid — the pallas-grid
    bench shape, deselected from tier-1 (-m 'not slow')."""
    rng = np.random.default_rng(7)
    S, T = 2, 2500
    keys = rng.integers(0, KEY_SPACE, size=(S, T))
    us = rng.random((S, T), dtype=np.float32)
    caps = [4, 9, 17]
    kw = dict(key_space=KEY_SPACE, window=10, max_scan=3,
              small_frac=0.25)
    twin = replay_grid_pallas("s3fifo", keys, us, caps, **kw)
    kern = replay_grid_pallas("s3fifo", keys, us, caps, interpret=True,
                              **kw)
    for field in ("hits", "evicted", "ops", "cls"):
        np.testing.assert_array_equal(np.asarray(getattr(kern, field)),
                                      np.asarray(getattr(twin, field)))
