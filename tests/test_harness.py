"""Prong C: virtual-time measurement of the implemented caches, and the
paper's model-vs-implementation agreement claim."""

import numpy as np
import pytest

from repro.core import build
from repro.core.harness import (
    PAPER_SERVICES,
    measure_cache,
    run_cache_trace,
    sweep_cache_sizes,
    zipf_trace,
)


def test_zipf_trace_is_skewed():
    t = zipf_trace(20_000, key_space=1000, theta=0.99, seed=0)
    _, counts = np.unique(t, return_counts=True)
    top = np.sort(counts)[::-1]
    assert top[0] > 20 * np.median(counts)  # heavy head
    assert t.min() >= 0 and t.max() < 1000


def test_hit_ratio_increases_with_cache_size():
    trace = zipf_trace(30_000, key_space=2048, theta=0.99, seed=1)
    ratios = []
    for cap in [32, 128, 512, 1536]:
        hits, _ = run_cache_trace("lru", cap, trace)
        ratios.append(hits[len(hits) // 4:].mean())
    assert all(b > a for a, b in zip(ratios, ratios[1:])), ratios
    assert ratios[-1] > 0.9


def test_lru_beats_fifo_on_hit_ratio():
    """Sanity: LRU's whole selling point — better hit ratio than FIFO."""
    trace = zipf_trace(30_000, key_space=2048, theta=0.99, seed=2)
    h_lru, _ = run_cache_trace("lru", 128, trace)
    h_fifo, _ = run_cache_trace("fifo", 128, trace)
    assert h_lru.mean() > h_fifo.mean()


def test_empirical_network_matches_model_lru():
    """The measured-profile network's demands match the Bernoulli model's
    at the measured hit ratio (within a few %) — the paper's model
    validation, done structurally."""
    meas = measure_cache("lru", capacity=512, key_space=4096, n_requests=40_000)
    p = meas.hit_ratio
    model = build("lru", disk_us=100.0)
    d_model = model.demands(p, tail_mode="nominal")
    d_meas = meas.network.demands(p, tail_mode="nominal")
    # same station demand structure
    assert abs(d_meas["delink"] - d_model["delink"]) / d_model["delink"] < 0.05
    assert abs(d_meas["head"] - d_model["head"]) / d_model["head"] < 0.05


def test_implementation_within_5pct_of_model_simulation():
    """Paper Sec. 3.4: implementation and (model) simulation within 5%."""
    from repro.core.simulator import simulate_network

    meas = measure_cache("lru", capacity=512, key_space=4096, n_requests=40_000)
    p = meas.hit_ratio
    x_impl = simulate_network(meas.network, [p], n_requests=15_000, seeds=(0, 1))
    x_model = simulate_network(build("lru"), [p], n_requests=15_000, seeds=(0, 1))
    rel = abs(x_impl.throughput[0] - x_model.throughput[0]) / x_model.throughput[0]
    assert rel < 0.05, (x_impl.throughput, x_model.throughput)


def test_clock_scan_ops_grow_with_hit_ratio():
    """Paper Sec. 4.3: E[S_tail] grows with p_hit because more bits are set."""
    trace = zipf_trace(40_000, key_space=2048, theta=0.99, seed=3)
    scans = []
    for cap in [64, 1024]:
        hits, ops = run_cache_trace("clock", cap, trace)
        miss = ~hits
        scans.append(ops[miss, 3].mean())
    assert scans[1] > scans[0]  # larger cache -> higher p_hit -> more scanning


def test_sweep_cache_sizes_produces_curve():
    out = sweep_cache_sizes(
        "fifo", sizes=[64, 256, 1024], key_space=4096, n_requests=20_000
    )
    assert len(out["p_hit"]) == 3
    assert np.all(np.diff(out["p_hit"]) > 0)
    assert np.all(out["x_bound"] > 0)


def test_paper_services_cover_all_policies():
    from repro.cache import PY_POLICIES

    for name in PY_POLICIES:
        assert name in PAPER_SERVICES
