"""Prong B validation: the event-driven simulator against theory + MVA."""

import numpy as np
import pytest

from repro.core import build, fifo_network, lru_network
from repro.core.simulator import SimResult, compile_network, simulate_network

P_GRID = np.array([0.4, 0.7, 0.9, 0.99])


@pytest.fixture(scope="module")
def lru_sim() -> SimResult:
    return simulate_network(lru_network(disk_us=100.0), P_GRID,
                            n_requests=12_000, seeds=(0, 1))


def test_simulation_below_upper_bound(lru_sim):
    """Thm 7.1 is an upper bound: the exact (simulated) X must sit below it."""
    ub = lru_network(disk_us=100.0).throughput_upper(P_GRID)
    assert np.all(lru_sim.throughput <= ub * 1.02)  # 2% sim noise allowance


def test_simulation_close_to_bound_when_saturated(lru_sim):
    """At saturation (p near the bound's flat region) sim ~= bound."""
    net = lru_network(disk_us=100.0)
    ub = net.throughput_upper(P_GRID)
    # high-MPL closed networks run close to their bottleneck bound
    assert np.all(lru_sim.throughput >= 0.80 * ub)


def test_simulation_matches_mva(lru_sim):
    """MVA (exponential analogue) within ~12% of the simulated network."""
    net = lru_network(disk_us=100.0)
    mva = net.mva_throughput(P_GRID)
    rel = np.abs(lru_sim.throughput - mva) / mva
    assert np.max(rel) < 0.12, rel


def test_lru_inversion_in_simulation(lru_sim):
    """The paper's headline: LRU simulated throughput DROPS at high p_hit."""
    x = dict(zip(P_GRID.tolist(), lru_sim.throughput.tolist()))
    assert x[0.99] < x[0.9], x


def test_fifo_monotone_in_simulation():
    res = simulate_network(fifo_network(disk_us=100.0), P_GRID,
                           n_requests=12_000, seeds=(0,))
    assert np.all(np.diff(res.throughput) > 0), res.throughput


@pytest.mark.parametrize("policy", ["clock", "s3fifo", "slru"])
def test_other_policies_simulate(policy):
    net = build(policy, disk_us=100.0)
    res = simulate_network(net, np.array([0.5, 0.95]), n_requests=16_000, seeds=(0, 1))
    ub = net.throughput_upper(res.p_hit)
    assert np.all(res.throughput > 0)
    assert np.all(res.throughput <= ub * 1.05)


def test_jax_simulator_matches_python_oracle():
    """Independent heapq reference implementation agrees within sim noise."""
    from repro.core.py_sim import simulate_py

    net = lru_network(disk_us=100.0)
    for p in (0.5, 0.95):
        x_py = simulate_py(net, p, n_requests=12_000, seed=3)
        x_jax = simulate_network(net, [p], n_requests=12_000, seeds=(0, 1)).throughput[0]
        assert abs(x_py - x_jax) / x_py < 0.05, (p, x_py, x_jax)


def test_compile_network_shapes():
    spec = compile_network(build("s3fifo"), 0.9)
    assert spec.visits.shape[0] == 4  # four branches
    assert spec.branch_cum.shape == (4,)
    assert abs(float(spec.branch_cum[-1]) - 1.0) < 1e-6


def test_deterministic_given_seed():
    net = lru_network(disk_us=100.0)
    a = simulate_network(net, [0.8], n_requests=3_000, seeds=(7,)).throughput
    b = simulate_network(net, [0.8], n_requests=3_000, seeds=(7,)).throughput
    np.testing.assert_array_equal(a, b)
