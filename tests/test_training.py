"""Training substrate: loss goes down, checkpoint/restore/resume works,
optimizers + compression behave."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.training import checkpoint as ckpt
from repro.training import grad_compress as gc
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import OptimizerConfig, init_state, zero1_moment_spec
from repro.training.train_state import init_train_state, make_train_step


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("internlm2-1.8b", reduced=True)
    opt = OptimizerConfig(lr=3e-3, warmup_steps=5, moment_dtype="float32")
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    step_fn = jax.jit(make_train_step(cfg, opt, remat="none"))
    return cfg, opt, state, data, step_fn


def _run(state, data, step_fn, n):
    losses = []
    for i in range(n):
        state, metrics = step_fn(state, data.batch_at(i))
        losses.append(float(metrics["loss"]))
    return state, losses


def test_loss_decreases(tiny_setup):
    _, _, state, data, step_fn = tiny_setup
    _, losses = _run(state, data, step_fn, 40)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert np.isfinite(losses).all()
    assert last < 0.7 * first, (first, last)


def test_adafactor_also_trains(tiny_setup):
    cfg, _, _, data, _ = tiny_setup
    opt = OptimizerConfig(name="adafactor", lr=1e-2, warmup_steps=5,
                          factored_min_dim=32)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, opt, remat="none"))
    _, losses = _run(state, data, step_fn, 30)
    assert np.mean(losses[-5:]) < 0.85 * np.mean(losses[:5])


def test_moe_trains_and_reports_aux():
    cfg = get_config("llama4-scout-17b-a16e", reduced=True)
    opt = OptimizerConfig(lr=3e-3, warmup_steps=5)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    step_fn = jax.jit(make_train_step(cfg, opt, remat="none"))
    state, m = step_fn(state, data.batch_at(0))
    assert float(m["moe_aux_loss"]) > 0.0
    state, losses = _run(state, data, step_fn, 25)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_checkpoint_roundtrip_and_resume(tiny_setup, tmp_path):
    _, _, state, data, step_fn = tiny_setup
    state10, _ = _run(state, data, step_fn, 10)
    path = str(tmp_path / "ckpt")
    ckpt.save(path, state10, step=10)
    assert ckpt.latest_step(path) == 10

    restored = ckpt.restore(path, like=state10)
    for a, b in zip(jax.tree_util.tree_leaves(state10),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # deterministic resume: continuing from restore == continuing original
    cont_a, la = _run(state10, data, step_fn, 5)
    cont_b, lb = _run(restored, data, step_fn, 5)
    np.testing.assert_allclose(la, lb, rtol=1e-6)


def test_async_checkpoint_and_atomicity(tiny_setup, tmp_path):
    _, _, state, _, _ = tiny_setup
    path = str(tmp_path / "ckpt")
    ac = ckpt.AsyncCheckpointer(path)
    ac.save(state, step=1)
    ac.save(state, step=2)  # joins the first save internally
    ac.join()
    assert ckpt.latest_step(path) == 2
    # a .tmp dir must never be visible as a checkpoint
    assert not any(n.endswith(".tmp") for n in os.listdir(path))


def test_data_pipeline_deterministic_and_resumable():
    d1 = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=2, seed=3))
    d2 = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=2, seed=3))
    np.testing.assert_array_equal(
        np.asarray(d1.batch_at(7)["tokens"]), np.asarray(d2.batch_at(7)["tokens"])
    )
    a = np.asarray(d1.batch_at(8)["tokens"])
    b = np.asarray(d1.batch_at(9)["tokens"])
    assert not np.array_equal(a, b)


def test_zero1_spec_transform():
    assert zero1_moment_spec((None, "model"), (1024, 64), 16) == ("batch", "model")
    assert zero1_moment_spec(("model", None), (64, 1024), 16) == ("model", "batch")
    assert zero1_moment_spec((None,), (7,), 16) == (None,)


def test_grad_compression_error_feedback():
    g = jax.random.normal(jax.random.PRNGKey(0), (257, 33)) * 0.01
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    # bf16 EF: accumulated compressed sum converges to the true sum
    for _ in range(20):
        wire, err = gc.compress_grad(g, err, "bf16")
        total = total + gc.decompress_grad(wire, "bf16")
    np.testing.assert_allclose(np.asarray(total), np.asarray(20 * g),
                               rtol=0, atol=2e-4)
    # int8 roundtrip error bounded by scale
    wire, e8 = gc.compress_grad(g, jnp.zeros_like(g), "int8")
    deq = gc.decompress_grad(wire, "int8")
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(deq - g))) <= scale * 0.51 + 1e-9


def test_moment_dtype_bf16_halves_bytes(tiny_setup):
    cfg, _, state, _, _ = tiny_setup
    opt16 = OptimizerConfig(moment_dtype="bfloat16")
    s16 = init_state(opt16, state.params)
    bytes16 = sum(x.nbytes for x in jax.tree_util.tree_leaves(s16))
    s32 = init_state(OptimizerConfig(moment_dtype="float32"), state.params)
    bytes32 = sum(x.nbytes for x in jax.tree_util.tree_leaves(s32))
    assert bytes16 * 2 == bytes32
