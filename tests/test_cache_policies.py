"""Property tests: jittable cache policies vs pure-Python oracles.

For every policy, random traces must produce identical hit sequences,
eviction sequences, and per-request op counts (the op counts feed the
queueing model, so they are load-bearing, not just diagnostics).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # collected (and the non-property tests run) regardless
    given = settings = st = None

from repro.cache import dlist
from repro.cache.policies import POLICIES, run_trace
from repro.cache.py_ref import PY_POLICIES

KEY_SPACE = 24
CAPACITY = 8

POLICY_PARAMS = {
    "lru": {},
    "fifo": {},
    "prob_lru": {"q": 0.5},
    "clock": {"max_scan": 3},
    "slru": {"protected_frac": 0.5},
    "s3fifo": {"small_frac": 0.25, "max_scan": 3},
    "sieve": {},
}
PY_PARAMS = {
    "lru": {},
    "fifo": {},
    "prob_lru": {"q": 0.5},
    "clock": {"max_scan": 3},
    "slru": {"protected_frac": 0.5},
    "s3fifo": {"small_frac": 0.25},
    "sieve": {},
}

trace_strategy = st.lists(
    st.integers(min_value=0, max_value=KEY_SPACE - 1), min_size=1, max_size=120
) if st is not None else None


def _run_both(policy: str, keys, us):
    pdef = POLICIES[policy]
    state = pdef.init(CAPACITY, KEY_SPACE, **POLICY_PARAMS[policy])
    # Pad to a fixed length so jit compiles once per policy (padding accesses
    # happen after every compared index, so they cannot affect the prefix).
    n = len(keys)
    pad = -len(keys) % 128 if len(keys) % 128 else 0
    keys_p = list(keys) + [0] * pad
    us_p = list(us) + [0.0] * pad
    _, hits, ops = run_trace(
        policy, state, jnp.asarray(keys_p, jnp.int32), jnp.asarray(us_p, jnp.float32)
    )
    hits = hits[:n]
    ops = type(ops)(*(o[:n] for o in ops))
    ref = PY_POLICIES[policy](CAPACITY, **PY_PARAMS[policy])
    ref_hits, ref_ops = [], []
    for k, u in zip(keys, us):
        a = ref.access(int(k), float(u))
        ref_hits.append(a.hit)
        ref_ops.append(a.ops)
    return (
        np.asarray(hits),
        np.stack([np.asarray(o) for o in ops], axis=1),
        np.asarray(ref_hits),
        np.asarray(ref_ops, dtype=np.int64),
    )


if st is not None:

    @pytest.mark.parametrize("policy", sorted(POLICIES))
    @given(keys=trace_strategy, data=st.data())
    @settings(max_examples=15, deadline=None)
    def test_policy_matches_oracle(policy, keys, data):
        us = [
            data.draw(st.floats(min_value=0.0, max_value=0.999)) for _ in keys
        ]
        hits, ops, ref_hits, ref_ops = _run_both(policy, keys, us)
        np.testing.assert_array_equal(hits, ref_hits, err_msg=f"{policy} hit seq")
        np.testing.assert_array_equal(ops, ref_ops, err_msg=f"{policy} op counts")

else:

    def test_policy_matches_oracle():
        pytest.importorskip("hypothesis")


@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_long_zipf_trace_matches_oracle(policy):
    """Longer adversarial-ish trace: zipf-weighted keys exercise evictions."""
    rng = np.random.default_rng(0)
    ranks = np.arange(1, KEY_SPACE + 1)
    probs = (1.0 / ranks**0.99) / np.sum(1.0 / ranks**0.99)
    keys = rng.choice(KEY_SPACE, size=2000, p=probs)
    us = rng.random(2000)
    hits, ops, ref_hits, ref_ops = _run_both(policy, keys.tolist(), us.tolist())
    np.testing.assert_array_equal(hits, ref_hits)
    np.testing.assert_array_equal(ops, ref_ops)


@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_capacity_respected(policy):
    """Never more than `capacity` distinct resident keys."""
    rng = np.random.default_rng(1)
    keys = rng.integers(0, KEY_SPACE, size=400)
    us = rng.random(400)
    ref = PY_POLICIES[policy](CAPACITY, **PY_PARAMS[policy])
    resident = set()
    for k, u in zip(keys, us):
        a = ref.access(int(k), float(u))
        resident.add(int(k))
        if a.evicted_key >= 0:
            resident.discard(a.evicted_key)
        assert len(resident) <= CAPACITY


def test_lru_eviction_order_exact():
    """Classic LRU semantics on a hand-written trace."""
    ref = PY_POLICIES["lru"](3)
    for k in [1, 2, 3]:
        ref.access(k)
    ref.access(1)  # order now: 1,3,2
    a = ref.access(4)  # evicts 2
    assert a.evicted_key == 2
    a = ref.access(5)  # evicts 3
    assert a.evicted_key == 3


def test_fifo_ignores_hits():
    ref = PY_POLICIES["fifo"](3)
    for k in [1, 2, 3]:
        ref.access(k)
    ref.access(1)  # no reordering
    a = ref.access(4)
    assert a.evicted_key == 1  # oldest, despite the recent hit


def test_clock_second_chance():
    ref = PY_POLICIES["clock"](3)
    for k in [1, 2, 3]:
        ref.access(k)
    ref.access(1)  # bit[1] = 1
    a = ref.access(4)  # 1 gets a second chance; 2 evicted
    assert a.evicted_key == 2


def test_hit_path_op_invariant():
    """The paper's structural dichotomy, verified on the implementations:
    LRU-like policies do list ops on hits; FIFO-like do none."""
    rng = np.random.default_rng(2)
    keys = rng.integers(0, KEY_SPACE, size=1500)
    us = rng.random(1500)
    for policy, pdef in POLICIES.items():
        ref = PY_POLICIES[policy](CAPACITY, **PY_PARAMS[policy])
        hit_ops = 0
        hits = 0
        for k, u in zip(keys, us):
            a = ref.access(int(k), float(u))
            if a.hit:
                hits += 1
                hit_ops += sum(a.ops)
        assert hits > 50, policy
        if pdef.lru_like:
            assert hit_ops > 0, f"{policy} should touch the list on hits"
        else:
            assert hit_ops == 0, f"{policy} must not touch the list on hits"


def test_dlist_primitives():
    dl = dlist.empty(4)
    dl = dlist.push_head(dl, 0)
    dl = dlist.push_head(dl, 1)
    dl = dlist.push_head(dl, 2)  # list: 2,1,0
    assert int(dl.head) == 2 and int(dl.tail) == 0
    assert int(dlist.length(dl, 4)) == 3
    dl = dlist.delink(dl, 1)  # list: 2,0
    assert int(dl.nxt[2]) == 0 and int(dl.prv[0]) == 2
    dl, t = dlist.pop_tail(dl)
    assert int(t) == 0
    assert int(dl.head) == 2 and int(dl.tail) == 2
    dl, t = dlist.pop_tail(dl)
    assert int(t) == 2
    assert int(dl.head) == dlist.NIL and int(dl.tail) == dlist.NIL
