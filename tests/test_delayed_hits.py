"""Delayed hits / miss coalescing across all three prongs (PR 3).

Prong A: the coalesced_network transform (sigma fixed point, identity at
window 0, p* shift).  Prong B: the outstanding-miss table in the JAX
simulator vs the heapq py_sim oracle — throughput AND delayed-hit counts.
Prong C: the in-flight-window classifier vs its pure-Python twin, and the
measured coalescing factor feeding back into the model.
"""

import numpy as np
import pytest

from repro.core import (
    build,
    coalesced_network,
    fifo_network,
    lru_network,
    sigma_of,
)
from repro.core.harness import (
    coin_stream,
    measure_cache,
    sweep_cache_sizes,
    zipf_trace,
)

P_TEST = np.array([0.3, 0.6, 0.9])


# ---------------------------------------------------------------------------
# Prong A — analytic transform
# ---------------------------------------------------------------------------


def test_coalesced_network_validates_and_sigma_in_range():
    for policy in ("lru", "fifo", "clock", "s3fifo", "slru"):
        net = build(policy, disk_us=100.0, coalesce_flows=32)
        net.validate()
        for p in P_TEST:
            s = sigma_of(net, float(p))
            assert 0.0 <= s <= 1.0, (policy, p, s)


def test_window_zero_is_identity():
    """With no in-flight window the transform must be exact identity."""
    base = lru_network(disk_us=100.0)
    co = build("lru", disk_us=100.0, coalesce_flows=8, coalesce_window_us=0.0)
    P = np.linspace(0.01, 0.99, 25)
    np.testing.assert_allclose(
        co.throughput_upper(P), base.throughput_upper(P), rtol=1e-12
    )
    np.testing.assert_allclose(
        co.mva_throughput(P[::6]), base.mva_throughput(P[::6]), rtol=1e-9
    )


def test_sigma_decreases_with_more_flows():
    """Spreading the miss stream over more hot keys means fewer collisions."""
    few = build("lru", disk_us=100.0, coalesce_flows=8)
    many = build("lru", disk_us=100.0, coalesce_flows=512)
    for p in (0.3, 0.7):
        assert sigma_of(few, p) > sigma_of(many, p) > 0.0


def test_pinned_sigma_bypasses_fixed_point():
    net = coalesced_network(lru_network(disk_us=100.0), sigma=0.25)
    for p in (0.2, 0.8):
        assert sigma_of(net, p) == pytest.approx(0.25)


def test_lru_pstar_shifts_under_coalescing_fifo_stays_monotone():
    """Coalescing relieves the miss path, so LRU's hit-path bottleneck
    (the delink) overtakes earlier: p* drops measurably.  FIFO-like
    policies keep their monotone bound (p* = 1) — the paper's dichotomy
    survives the delayed-hits regime."""
    base = lru_network(disk_us=100.0)
    co = build("lru", disk_us=100.0, coalesce_flows=8)
    p_base, p_co = base.p_star(grid=2001), co.p_star(grid=2001)
    assert p_co < p_base - 0.01, (p_base, p_co)
    assert build("fifo", disk_us=100.0, coalesce_flows=8).p_star(grid=2001) \
        == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Prong B — event-level coalescing, JAX simulator vs heapq oracle
# ---------------------------------------------------------------------------

DISK_TIERS = [
    {"disk_us": 100.0, "disk_servers": 0},  # paper's infinite-server disk
    {"disk_us": 500.0, "disk_servers": 8},  # bounded I/O depth
]


@pytest.mark.parametrize("policy", ["lru", "fifo", "clock"])
@pytest.mark.parametrize("tier", range(len(DISK_TIERS)))
def test_sim_matches_oracle_with_coalescing(policy, tier):
    """The acceptance differential: coalescing-enabled JAX simulator vs the
    independent heapq oracle agree on throughput and delayed-hit counts."""
    from repro.core.py_sim import simulate_py
    from repro.core.simulator import simulate_network

    net = build(policy, mpl=72, **DISK_TIERS[tier])
    p = 0.7
    runs = [simulate_py(net, p, n_requests=12_000, seed=s,
                        coalesce_flows=16, full=True) for s in (3, 4, 5)]
    x_py = np.mean([r["x"] for r in runs])
    df_py = np.mean([r["delayed_frac"] for r in runs])
    jx = simulate_network(net, [p], n_requests=12_000, seeds=(0, 1, 2, 3),
                          coalesce_flows=16)
    rel = abs(x_py - jx.throughput[0]) / x_py
    # the bounded slow-disk tier mixes slowly (bursty flow collisions), so
    # short differential runs carry ~2x the seed noise of the think-disk
    # tier; both converge to <2% gaps at 40k requests.
    tol = 0.07 if DISK_TIERS[tier]["disk_servers"] == 0 else 0.12
    assert rel < tol, (policy, tier, x_py, jx.throughput[0])
    assert df_py > 0.0
    assert abs(df_py - jx.delayed_frac[0]) < 0.04, (
        policy, tier, df_py, jx.delayed_frac[0])


def test_parked_requests_do_not_hold_io_depth():
    """With a bounded-depth slow disk, duplicate in-flight misses clog the
    I/O queue; parking them on the MSHR table must recover throughput."""
    from repro.core.simulator import simulate_network

    net = lru_network(disk_us=100.0, disk_servers=4)
    plain = simulate_network(net, [0.5], n_requests=8_000, seeds=(0, 1))
    co = simulate_network(net, [0.5], n_requests=8_000, seeds=(0, 1),
                          coalesce_flows=16)
    assert co.throughput[0] > 2.0 * plain.throughput[0], (
        plain.throughput, co.throughput)
    assert co.delayed_frac[0] > 0.1


def test_sim_delayed_frac_tracks_model_sigma():
    """Event-level coalescing and the analytic sigma fixed point describe
    the same mechanism: delayed completions ~= sigma * (1 - p)."""
    from repro.core.simulator import simulate_network

    flows, p = 16, 0.5
    jx = simulate_network(lru_network(disk_us=100.0), [p],
                          n_requests=12_000, seeds=(0, 1, 2),
                          coalesce_flows=flows)
    model = build("lru", disk_us=100.0, coalesce_flows=flows)
    want = sigma_of(model, p) * (1.0 - p)
    assert jx.delayed_frac[0] == pytest.approx(want, rel=0.25), (
        jx.delayed_frac[0], want)


def test_disabled_coalescing_unchanged():
    """coalesce_flows=0 must leave the simulator's numbers untouched
    (same RNG stream, same program) and report zero delayed hits."""
    from repro.core.simulator import simulate_network

    net = lru_network(disk_us=100.0)
    a = simulate_network(net, [0.8], n_requests=3_000, seeds=(7,))
    b = simulate_network(net, [0.8], n_requests=3_000, seeds=(7,))
    np.testing.assert_array_equal(a.throughput, b.throughput)
    assert np.all(a.delayed_frac == 0.0)


# ---------------------------------------------------------------------------
# Prong C — in-flight-window classification of replayed traces
# ---------------------------------------------------------------------------


def test_classifier_matches_py_reference():
    from repro.cache import classify_inflight, classify_inflight_py

    rng = np.random.default_rng(0)
    for window in (0, 1, 7, 64):
        keys = rng.integers(0, 97, 4_000)
        hits = rng.random(4_000) < 0.6
        np.testing.assert_array_equal(
            classify_inflight(keys, hits, window),
            classify_inflight_py(keys, hits, window),
        )


def test_classifier_grid_matches_per_lane_reference():
    """The vmapped (capacity x seed) classification must equal the python
    walk on every lane of a real policy replay."""
    from repro.cache import classify_inflight, classify_inflight_py
    from repro.cache.replay import replay_grid

    trace = zipf_trace(10_000, 1024, seed=1)
    us = coin_stream(10_000, 1)
    res = replay_grid("s3fifo", trace, us, [32, 128, 512], key_space=1024)
    cls = classify_inflight(trace, res.hits, 25, key_space=1024)
    assert cls.shape == res.hits.shape
    for i in range(3):
        np.testing.assert_array_equal(
            cls[i, 0], classify_inflight_py(trace, res.hits[i, 0], 25))


def test_window_zero_classification_is_bit_identical():
    """miss latency -> 0: delayed hits vanish and the classes reduce to the
    policy's own hit/miss split, bit for bit."""
    from repro.cache import DELAYED_HIT, TRUE_HIT, classify_inflight
    from repro.cache.replay import replay_trace

    trace = zipf_trace(8_000, 1024, seed=2)
    res = replay_trace("lru", trace, coin_stream(8_000, 2), 128,
                       key_space=1024)
    cls = classify_inflight(trace, res.hits, 0, key_space=1024)
    assert not np.any(cls == DELAYED_HIT)
    np.testing.assert_array_equal(cls == TRUE_HIT, res.hits)


def test_measured_sigma_reaches_the_model():
    """Prong C -> prong A loop: the measured coalescing factor produces a
    coalesced bound, and delayed-hit relief never lowers it."""
    m = measure_cache("lru", 128, key_space=1024, n_requests=20_000,
                      backend="jax", miss_latency_requests=40)
    assert m.class_fracs is not None
    assert m.class_fracs.sum() == pytest.approx(1.0)
    assert 0.0 < m.coalesce_sigma < 1.0
    assert m.true_hit_ratio <= m.hit_ratio
    assert float(m.coalesced_throughput_bound()) >= \
        float(m.throughput_bound()) - 1e-12


def test_sweep_reports_delayed_columns_and_sigma_decreases():
    out = sweep_cache_sizes("lru", [32, 128, 512], key_space=1024,
                            n_requests=20_000, miss_latency_requests=40)
    assert set(out) >= {"p_true_hit", "p_delayed", "sigma",
                        "x_bound_coalesced"}
    # larger cache -> fewer misses in flight -> less coalescing
    assert out["sigma"][0] > out["sigma"][-1]
    np.testing.assert_array_compare(
        np.less_equal, out["p_true_hit"], out["p_hit"] + 1e-12)


def test_backends_agree_on_classification():
    """measure_cache's py and jax backends classify identically."""
    kw = dict(key_space=512, n_requests=6_000, miss_latency_requests=20)
    a = measure_cache("clock", 64, backend="py", **kw)
    b = measure_cache("clock", 64, backend="jax", **kw)
    np.testing.assert_allclose(a.class_fracs, b.class_fracs)
    assert a.coalesce_sigma == pytest.approx(b.coalesce_sigma)


# ---------------------------------------------------------------------------
# Satellite (PR 5): TTL / failed-fetch re-issue in the classifiers
# ---------------------------------------------------------------------------


def test_refetch_zero_fail_prob_bit_identical():
    """q=0 (and any W=0) must leave the classification bit-identical."""
    from repro.cache import DELAYED_HIT, classify_inflight, refetch_attempts

    rng = np.random.default_rng(0)
    keys = rng.integers(0, 97, 4_000)
    hits = rng.random(4_000) < 0.6
    np.testing.assert_array_equal(refetch_attempts(100, 0.0), np.ones(100))
    np.testing.assert_array_equal(
        classify_inflight(keys, hits, 20),
        classify_inflight(keys, hits, 20, fail_prob=0.0, fail_seed=9))
    z = classify_inflight(keys, hits, 0, fail_prob=0.7)
    assert not np.any(z == DELAYED_HIT)


def test_refetch_twins_agree_and_delay_grows_with_q():
    """jax == py under failure/re-issue, and the extended in-flight
    windows strictly increase the delayed-hit mass with q."""
    from repro.cache import DELAYED_HIT, classify_inflight, classify_inflight_py

    rng = np.random.default_rng(1)
    keys = rng.integers(0, 97, 4_000)
    hits = rng.random(4_000) < 0.6
    fracs = []
    for q in (0.0, 0.4, 0.8):
        j = classify_inflight(keys, hits, 20, fail_prob=q, fail_seed=7)
        p = classify_inflight_py(keys, hits, 20, fail_prob=q, fail_seed=7)
        np.testing.assert_array_equal(j, p)
        fracs.append(float((j == DELAYED_HIT).mean()))
    assert fracs[0] < fracs[1] < fracs[2], fracs


def test_refetch_validation_and_harness_plumbing():
    from repro.cache import classify_inflight, refetch_attempts

    with pytest.raises(ValueError):
        refetch_attempts(10, 1.0)
    with pytest.raises(ValueError):
        classify_inflight(np.zeros(4, np.int64), np.zeros(4, bool), 5,
                          fail_prob=-0.1)
    m0 = measure_cache("lru", 128, key_space=1024, n_requests=10_000,
                       backend="jax", miss_latency_requests=25)
    m1 = measure_cache("lru", 128, key_space=1024, n_requests=10_000,
                       backend="jax", miss_latency_requests=25,
                       fetch_fail_prob=0.5)
    assert m1.coalesce_sigma > m0.coalesce_sigma
    out = sweep_cache_sizes("lru", [64, 256], key_space=1024,
                            n_requests=10_000, miss_latency_requests=25,
                            fetch_fail_prob=0.5)
    base = sweep_cache_sizes("lru", [64, 256], key_space=1024,
                             n_requests=10_000, miss_latency_requests=25)
    assert np.all(out["p_delayed"] >= base["p_delayed"])
