"""Per-kernel validation: shape/dtype sweeps, interpret=True vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

# the heaviest interpret-mode parameterizations are marked slow so CI can
# split them out (`-m "not slow"` / `-m slow`); the tier-1 command still
# runs everything.
FLASH_CASES = [
    # (B, T, S, H, KV, dh, causal, window, dtype)
    pytest.param((1, 128, 128, 4, 4, 64, True, 0, jnp.float32),
                 marks=pytest.mark.slow),
    pytest.param((2, 256, 256, 4, 2, 64, True, 0, jnp.float32),
                 marks=pytest.mark.slow),
    pytest.param((1, 128, 128, 8, 2, 128, True, 0, jnp.bfloat16),
                 marks=pytest.mark.slow),
    (1, 256, 256, 4, 4, 64, True, 128, jnp.float32),  # sliding window
    (2, 64, 192, 4, 2, 64, False, 0, jnp.float32),  # bidir, ragged blocks
    (1, 100, 100, 2, 2, 64, True, 0, jnp.float32),  # non-multiple of block
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    B, T, S, H, KV, dh, causal, window, dtype = case
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, T, H, dh), dtype)
    k = jax.random.normal(k2, (B, S, KV, dh), dtype)
    v = jax.random.normal(k3, (B, S, KV, dh), dtype)

    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=64, bk=64, interpret=True)
    want = ref.flash_attention_ref(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal=causal, window=window,
    ).swapaxes(1, 2)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_flash_attention_matches_model_reference():
    """Kernel vs the model's chunked_attention (two independent paths)."""
    from repro.models.attention import chunked_attention

    B, T, H, KV, dh = 2, 128, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, T, H, dh))
    k = jax.random.normal(ks[1], (B, T, KV, dh))
    v = jax.random.normal(ks[2], (B, T, KV, dh))
    pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    want = chunked_attention(q, k, v, pos, T, causal=True, chunk=64)
    # chunked_attention folds the 1/sqrt scale into q
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

PAGED_CASES = [
    # (B, H, KV, dh, page, n_pages, P, dtype)
    pytest.param((2, 4, 2, 64, 16, 4, 16, jnp.float32),
                 marks=pytest.mark.slow),
    pytest.param((3, 8, 8, 64, 32, 3, 12, jnp.float32),
                 marks=pytest.mark.slow),
    (2, 4, 4, 128, 16, 2, 8, jnp.bfloat16),
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_attention_matches_ref(case):
    B, H, KV, dh, page, n_pages, P, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    q = jax.random.normal(ks[0], (B, H, dh), dtype)
    pages_k = jax.random.normal(ks[1], (P, page, KV, dh), dtype)
    pages_v = jax.random.normal(ks[2], (P, page, KV, dh), dtype)
    # distinct random pages per sequence + ragged lengths
    bt = jax.random.permutation(ks[3], P)[: B * n_pages].reshape(B, n_pages)
    bt = bt.astype(jnp.int32)
    seq_lens = jax.random.randint(ks[4], (B,), 1, n_pages * page + 1,
                                  dtype=jnp.int32)
    out = ops.paged_attention(q, pages_k, pages_v, bt, seq_lens, interpret=True)
    want = ref.paged_attention_ref(q, pages_k, pages_v, bt, seq_lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_paged_matches_dense_when_contiguous():
    """Paged with identity block table == dense cache attention."""
    B, H, KV, dh, page = 2, 4, 2, 64, 16
    n_pages, S = 4, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, dh))
    k = jax.random.normal(ks[1], (B, S, KV, dh))
    v = jax.random.normal(ks[2], (B, S, KV, dh))
    pages_k = k.reshape(B * n_pages, page, KV, dh)
    pages_v = v.reshape(B * n_pages, page, KV, dh)
    bt = jnp.arange(B * n_pages, dtype=jnp.int32).reshape(B, n_pages)
    seq_lens = jnp.full((B,), S, jnp.int32)
    out = ops.paged_attention(q, pages_k, pages_v, bt, seq_lens, interpret=True)
    want = ref.flash_attention_ref(
        q[:, :, None, :], k.swapaxes(1, 2), v.swapaxes(1, 2), causal=False
    )[:, :, 0, :]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# wkv6 linear scan
# ---------------------------------------------------------------------------

WKV_CASES = [
    # (B, T, H, dh, chunk, dtype)
    (2, 128, 2, 32, 32, jnp.float32),
    (1, 256, 4, 64, 128, jnp.float32),
    (1, 100, 2, 32, 32, jnp.float32),  # padding path
    (2, 64, 2, 64, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("case", WKV_CASES)
def test_wkv6_scan_matches_ref(case):
    B, T, H, dh, chunk, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    r = jax.random.normal(ks[0], (B, T, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, T, H, dh), dtype)
    v = jax.random.normal(ks[2], (B, T, H, dh), dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, dh))).astype(dtype)
    u = jax.random.normal(ks[4], (H, dh), dtype)
    out = ops.wkv6_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    want = ref.wkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        **(_tol(dtype) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)),
    )


# ---------------------------------------------------------------------------
# batched LRU cache update
# ---------------------------------------------------------------------------

LRU_CASES = [(1024, 64, 512), (2048, 128, 512), (512, 16, 128)]


@pytest.mark.parametrize("C,N,tile", LRU_CASES)
def test_lru_batch_update_matches_ref(C, N, tile):
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    ts = jax.random.randint(ks[0], (C,), 1, 10_000, dtype=jnp.int32)
    accessed = jax.random.choice(ks[1], C, (N,), replace=False).astype(jnp.int32)
    now = jnp.int32(50_000)
    new_ts, victim = ops.lru_batch_update(ts, accessed, now, tile=tile,
                                          interpret=True)
    want_ts, want_victim = ref.lru_batch_update_ref(ts, accessed, now)
    np.testing.assert_array_equal(np.asarray(new_ts), np.asarray(want_ts))
    # argmin ties can differ between tiles; compare the *timestamp* values
    assert new_ts[victim] == want_ts[want_victim]


def test_lru_batch_update_non_multiple_capacity():
    """C=700 is not a multiple of tile=512: the sentinel padding must keep
    results identical to the unpadded reference (regression for the old
    `C % tile == 0` assert)."""
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    C, N, tile = 700, 32, 512
    ts = jax.random.randint(ks[0], (C,), 1, 10_000, dtype=jnp.int32)
    accessed = jax.random.choice(ks[1], C, (N,), replace=False).astype(jnp.int32)
    now = jnp.int32(50_000)
    new_ts, victim = ops.lru_batch_update(ts, accessed, now, tile=tile,
                                          interpret=True)
    want_ts, want_victim = ref.lru_batch_update_ref(ts, accessed, now)
    assert new_ts.shape == (C,)
    assert 0 <= int(victim) < C
    np.testing.assert_array_equal(np.asarray(new_ts), np.asarray(want_ts))
    assert new_ts[victim] == want_ts[want_victim]


def test_lru_batch_update_semantics():
    """Victim is the LRU slot; accessed slots become most-recent."""
    ts = jnp.array([5, 3, 9, 1, 7, 2, 8, 6], jnp.int32)
    accessed = jnp.array([3, 5], jnp.int32)  # touch the two oldest
    new_ts, victim = ops.lru_batch_update(ts, accessed, jnp.int32(100),
                                          tile=8, interpret=True)
    assert int(new_ts[3]) == 100 and int(new_ts[5]) == 100
    assert int(victim) == 1  # ts=3 is now the oldest
