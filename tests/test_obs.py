"""Telemetry prong tests (repro.obs): trace twin contracts, ring-buffer
semantics, Perfetto export, provenance stamping, and the metric registry.

The load-bearing guarantees, in order:

1. ``trace=0`` is bit-identical to not compiling tracing in at all, on
   every backend (the observability layer must never perturb results).
2. Tracing draws no RNG, so the traced run's summary statistics equal
   the untraced run's bit-for-bit too.
3. The in-kernel ring decodes to the exact per-request accounting the
   kernel's own counters report (``branch_throughput`` ≡ per-branch
   trace record counts) — the satellite bugfix sweep's reconciliation.
4. The heapq oracles emit the identical schema, and their class mixes /
   sojourns agree statistically with the jax kernels across the
   (policy × loop-mode) grid — trace equality as a differential twin
   contract (registered in ``tools/analysis/contracts.py``).
"""

import json
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.policy_models import (clock_network, fifo_network,
                                      lru_network)
from repro.core.py_sim import simulate_py
from repro.core.simulator import simulate_network
from repro.hierarchy.model import hierarchy_network
from repro.hierarchy.sim import simulate_hierarchy, simulate_hierarchy_py
from repro.latency import lambda_max, observed_response
from repro.obs.export import (read_perfetto, summarize_events, to_perfetto,
                              write_perfetto)
from repro.obs.metrics import (DistSketch, Metrics, check_metric_name,
                               convoy_stats, station_utilization,
                               trace_summary)
from repro.obs.provenance import (config_hash, lineage_diff, stamp,
                                  validate_payload)
from repro.obs.provenance import main as provenance_main
from repro.obs.trace import (CLS_DELAYED, CLS_HIT, CLS_MISS,
                             PyTraceCollector, TraceRecords, make_records,
                             trace_from_rings)

REPO_ROOT = Path(__file__).resolve().parents[1]

N_REQ = 2_500
WARMUP = N_REQ // 4  # simulate_network's warmup_frac=0.25 default


# ---------------------------------------------------------------- fixtures


@pytest.fixture(scope="module")
def closed_traced():
    """Closed loop, no coalescing: one (p, seed) lane, lossless ring."""
    net = lru_network(disk_us=100.0)
    res = simulate_network(net, [0.7], n_requests=N_REQ, seeds=(0,),
                           trace=2 * N_REQ)
    return net, res


@pytest.fixture(scope="module")
def coalesced_pair():
    """Closed loop with MSHR coalescing: traced and untraced twins."""
    net = lru_network(disk_us=100.0)
    kw = dict(n_requests=N_REQ, seeds=(0, 1), coalesce_flows=4)
    base = simulate_network(net, [0.5, 0.9], **kw)
    traced = simulate_network(net, [0.5, 0.9], trace=128, **kw)
    return net, base, traced


@pytest.fixture(scope="module")
def oracle_coalesced():
    net = lru_network(disk_us=100.0)
    out = simulate_py(net, 0.7, n_requests=N_REQ, seed=0, coalesce_flows=4,
                      full=True, trace=2 * N_REQ)
    return net, out


# ------------------------------------------------- 1+2: tracing is inert


class TestTracingIsInert:
    def test_closed_coalesced_bit_identical(self, coalesced_pair):
        _, base, traced = coalesced_pair
        assert np.array_equal(base.throughput, traced.throughput)
        assert np.array_equal(base.ci95, traced.ci95)
        assert np.array_equal(base.delayed_frac, traced.delayed_frac)
        assert np.array_equal(base.branch_throughput,
                              traced.branch_throughput)
        assert base.traces is None
        assert len(traced.traces) == 2 and len(traced.traces[0]) == 2

    def test_open_bit_identical(self):
        net = lru_network(disk_us=100.0)
        lam = 0.5 * float(lambda_max(net, 0.7, tail_mode="nominal"))
        kw = dict(arrival_rate=lam, n_requests=N_REQ, seeds=(0,))
        base = simulate_network(net, [0.7], **kw)
        traced = simulate_network(net, [0.7], trace=256, **kw)
        assert np.array_equal(base.throughput, traced.throughput)
        assert np.array_equal(base.sojourn_mean, traced.sojourn_mean)
        assert np.array_equal(base.sojourn_p99, traced.sojourn_p99)
        assert np.array_equal(base.class_frac, traced.class_frac)
        assert base.traces is None and traced.traces is not None

    def test_pallas_backend_bit_identical(self):
        from repro.kernels.event_sim import simulate_grid_pallas

        net = lru_network(disk_us=100.0)
        kw = dict(n_requests=1_500, seeds=(0,))
        base = simulate_grid_pallas(net, [0.7], **kw)
        traced = simulate_grid_pallas(net, [0.7], trace=128, **kw)
        assert np.array_equal(base.throughput, traced.throughput)
        assert np.array_equal(base.branch_throughput,
                              traced.branch_throughput)
        tr = traced.traces[0][0]
        assert len(tr) == 128 and tr.n_dropped > 0
        # the counter-RNG engine classifies by branch: no delayed hits
        assert not (tr.cls == CLS_DELAYED).any()


# ------------------------- 3: trace records reconcile with the counters


class TestCounterReconciliation:
    def test_ring_is_lossless_and_ordered(self, closed_traced):
        _, res = closed_traced
        tr = res.traces[0][0]
        assert tr.n_emitted == N_REQ and tr.n_dropped == 0
        assert np.array_equal(tr.req, np.arange(N_REQ))

    def test_branch_throughput_matches_trace_counts(self, closed_traced):
        """branch_throughput ≡ per-branch post-warmup record counts."""
        net, res = closed_traced
        tr = res.traces[0][0]
        measured = tr.req >= WARMUP
        counts = np.bincount(tr.branch[measured],
                             minlength=len(net.branches))
        want = res.branch_throughput[0] / res.throughput[0]
        np.testing.assert_allclose(counts / counts.sum(), want, rtol=1e-6,
                                   atol=1e-9)

    def test_classes_follow_the_hit_knob(self, closed_traced):
        _, res = closed_traced
        tr = res.traces[0][0]
        measured = tr.req >= WARMUP
        frac_hit = (tr.cls[measured] == CLS_HIT).mean()
        assert abs(frac_hit - 0.7) < 0.05
        assert not (tr.cls == CLS_DELAYED).any()  # no coalescing

    def test_timestamps_are_well_formed(self, closed_traced):
        _, res = closed_traced
        tr = res.traces[0][0]
        assert (tr.nvis >= 1).all()
        assert (tr.sojourn_us > 0).all()
        cols = np.arange(tr.enter_us.shape[1])[None, :]
        live = cols < tr.nvis[:, None]
        assert np.isnan(tr.enter_us[~live]).all()
        assert (tr.leave_us[live] >= tr.enter_us[live]).all()
        assert (tr.station[live] >= 0).all()
        assert (tr.station[~live] == -1).all()

    def test_oracle_counters_reconcile_exactly(self, oracle_coalesced):
        """The heapq oracle's measured counters are recomputable from
        its own trace records — including the delayed-hit split."""
        net, out = oracle_coalesced
        tr = out["trace"]
        assert tr.n_dropped == 0
        measured = tr.req >= out["warm_done"]
        counts = np.bincount(tr.branch[measured],
                             minlength=len(net.branches))
        assert np.array_equal(counts, np.asarray(out["branch_done"]))
        delayed = measured & (tr.cls == CLS_DELAYED)
        assert int(delayed.sum()) == int(out["delayed"])
        dcounts = np.bincount(tr.branch[delayed],
                              minlength=len(net.branches))
        assert np.array_equal(dcounts, np.asarray(out["branch_delayed"]))
        x = measured.sum() / out["t_measured"]
        assert np.isclose(x, out["x"], rtol=1e-6)  # x is stored float32

    def test_oracle_parked_iff_delayed(self, oracle_coalesced):
        _, out = oracle_coalesced
        tr = out["trace"]
        assert (tr.parked_us[tr.cls != CLS_DELAYED] == 0).all()
        assert (tr.parked_us[tr.cls == CLS_DELAYED] >= 0).all()
        assert tr.parked_us[tr.cls == CLS_DELAYED].sum() > 0


# ------------------------------ 4: jax vs oracle trace-level agreement


def _class_fracs(tr: TraceRecords, warm: int) -> np.ndarray:
    m = tr.req >= warm
    return np.array([(tr.cls[m] == c).mean()
                     for c in (CLS_MISS, CLS_HIT, CLS_DELAYED)])


class TestTwinTraceAgreement:
    @pytest.mark.parametrize("build", [lru_network, fifo_network,
                                       clock_network])
    def test_closed_coalesced(self, build):
        net = build(disk_us=100.0)
        jx = simulate_network(net, [0.7], n_requests=N_REQ, seeds=(0,),
                              coalesce_flows=4, trace=2 * N_REQ)
        py = simulate_py(net, 0.7, n_requests=N_REQ, seed=1,
                         coalesce_flows=4, full=True, trace=2 * N_REQ)
        tj, tp = jx.traces[0][0], py["trace"]
        assert tj.n_emitted >= N_REQ and tp.n_emitted >= N_REQ
        fj = _class_fracs(tj, WARMUP)
        fp = _class_fracs(tp, py["warm_done"])
        np.testing.assert_allclose(fj, fp, atol=0.06)
        mj = tj.req >= WARMUP
        mp = tp.req >= py["warm_done"]
        sj = tj.sojourn_us[mj].mean()
        sp = tp.sojourn_us[mp].mean()
        assert abs(sj - sp) / sp < 0.25, (build.__name__, sj, sp)

    def test_open(self):
        net = lru_network(disk_us=100.0)
        lam = 0.5 * float(lambda_max(net, 0.7, tail_mode="nominal"))
        jx = simulate_network(net, [0.7], arrival_rate=lam,
                              n_requests=N_REQ, seeds=(0,), trace=2 * N_REQ)
        py = simulate_py(net, 0.7, n_requests=N_REQ, seed=1,
                         arrival_rate=lam, trace=2 * N_REQ)
        tj, tp = jx.traces[0][0], py["trace"]
        fj = _class_fracs(tj, int(jx.n_requests * 0.25))
        fp = _class_fracs(tp, py["warm_done"])
        np.testing.assert_allclose(fj, fp, atol=0.06)
        sj = tj.sojourn_us[tj.req >= int(jx.n_requests * 0.25)].mean()
        sp = tp.sojourn_us[tp.req >= py["warm_done"]].mean()
        assert abs(sj - sp) / sp < 0.25, (sj, sp)

    def test_tiered_hierarchy(self):
        model = hierarchy_network("lru", "lru", n_clients=2, n_shards=2,
                                  mpl=16, disk_us=50.0)
        jx = simulate_hierarchy(model, [0.6], n_requests=N_REQ, seeds=(0,),
                                coalesce_flows=4, trace=2 * N_REQ)
        py = simulate_hierarchy_py(model, 0.6, n_requests=N_REQ, seed=1,
                                   coalesce_flows=4, trace=2 * N_REQ)
        tj, tp = jx.traces[0][0], py.traces
        level = np.asarray(model.branch_level)
        for tr in (tj, tp):
            assert len(tr) >= N_REQ
            # every record's branch resolves to a serving level
            assert set(np.unique(level[tr.branch])) <= {0, 1, 2}
        # per-level completion mix agrees between the twins
        lj = np.bincount(level[tj.branch], minlength=3) / len(tj)
        lp = np.bincount(level[tp.branch], minlength=3) / len(tp)
        np.testing.assert_allclose(lj, lp, atol=0.06)
        # both engines saw cross-tier coalescing
        assert (tj.cls == CLS_DELAYED).sum() > 0
        assert (tp.cls == CLS_DELAYED).sum() > 0


# ----------------------------------------------------- ring-buffer edges


class TestRingOverflow:
    def test_last_cap_records_survive(self):
        net = lru_network(disk_us=100.0)
        cap = 256
        res = simulate_network(net, [0.7], n_requests=1_500, seeds=(0,),
                               trace=cap)
        tr = res.traces[0][0]
        assert tr.n_emitted == 1_500
        assert len(tr) == cap and tr.n_dropped == 1_500 - cap
        assert np.array_equal(tr.req, np.arange(1_500 - cap, 1_500))

    def test_oracle_capping_matches(self):
        net = lru_network(disk_us=100.0)
        out = simulate_py(net, 0.7, n_requests=1_500, seed=0, full=True,
                          trace=256)
        tr = out["trace"]
        assert tr.n_emitted == 1_500 and len(tr) == 256
        assert np.array_equal(tr.req, np.arange(1_500 - 256, 1_500))

    def test_decode_drops_scrap_row(self):
        cap = 4
        req = np.array([4, 5, 2, 3, 99])  # last row is scrap
        tr = trace_from_rings(
            6, req, np.zeros(5, np.int32), np.zeros(5, np.int32),
            np.ones(5, np.int32), np.zeros(5), np.zeros((5, 2)),
            np.ones((5, 2)))
        assert len(tr) == cap and tr.n_emitted == 6 and tr.n_dropped == 2
        assert np.array_equal(tr.req, [2, 3, 4, 5])

    def test_decode_drops_never_written(self):
        req = np.array([0, -1, -1, -1, -1])
        tr = trace_from_rings(
            1, req, np.zeros(5, np.int32), np.zeros(5, np.int32),
            np.ones(5, np.int32), np.zeros(5), np.zeros((5, 2)),
            np.ones((5, 2)))
        assert len(tr) == 1 and tr.n_dropped == 0


class TestPyTraceCollector:
    def test_collects_and_caps(self):
        col = PyTraceCollector(cap=2, n_jobs=1, route_len=2)
        for i in range(3):
            col.start(0, 10.0 * i)
            col.leave(0, 0, 10.0 * i + 1)
            col.enter(0, 1, 10.0 * i + 1)
            col.leave(0, 1, 10.0 * i + 5)
            col.complete(0, branch=i, cls=CLS_HIT, nvis=2, parked_us=0.0)
        tr = col.finish(visits=np.array([[0, 1], [0, 1], [0, 1]]))
        assert tr.n_emitted == 3 and len(tr) == 2
        assert np.array_equal(tr.req, [1, 2])
        np.testing.assert_allclose(tr.sojourn_us, [5.0, 5.0])

    def test_empty_finish(self):
        col = PyTraceCollector(cap=8, n_jobs=1, route_len=2)
        tr = col.finish()
        assert len(tr) == 0 and tr.n_emitted == 0
        assert tr.class_counts() == {"miss": 0, "hit": 0, "delayed": 0}


# -------------------------------------------------------- Perfetto export


class TestPerfettoExport:
    def test_round_trip(self, closed_traced, tmp_path):
        net, res = closed_traced
        tr = res.traces[0][0]
        path = tmp_path / "sample.trace.json"
        names = [s.name for s in net.stations]
        write_perfetto(path, tr, station_names=names)
        summary = summarize_events(read_perfetto(path))
        assert summary["requests_count"] == len(tr)
        assert summary["by_cat_count"]["visit"] == int(tr.nvis.sum())
        assert summary["by_cat_count"].get("mshr", 0) == int(
            (tr.parked_us > 0).sum())
        counts = tr.class_counts()
        for name, n in summary["by_cls_count"].items():
            assert counts[name] == n
        assert summary["total_dur_us"] > 0

    def test_slices_are_finite_and_named(self, closed_traced):
        net, res = closed_traced
        tr = res.traces[0][0]
        obj = to_perfetto(tr, station_names=[s.name for s in net.stations])
        slices = [e for e in obj["traceEvents"] if e["ph"] == "X"]
        names = {s.name for s in net.stations} | {"mshr_park"}
        for e in slices:
            assert np.isfinite(e["ts"]) and e["dur"] >= 0
            assert e["name"] in names


# ----------------------------------------------------------- provenance


class TestProvenance:
    def payload(self):
        return stamp({"replay": {"x": 1.0}, "failures": {}},
                     config={"n": 16_000}, seeds=(0, 1, 2))

    def test_stamped_payload_validates(self):
        assert validate_payload(self.payload()) == []

    def test_config_hash_deterministic_and_sensitive(self):
        a = config_hash({"n": 1, "p": [0.5, 0.9]})
        b = config_hash({"p": [0.5, 0.9], "n": 1})  # key order irrelevant
        c = config_hash({"n": 2, "p": [0.5, 0.9]})
        assert a == b and a != c

    def test_failures_must_be_tracebacks(self):
        bad = self.payload()
        bad["failures"] = ["fig3_lru"]
        assert any("failures" in p for p in validate_payload(bad))
        bad["failures"] = {"fig3_lru": ""}
        assert any("traceback" in p for p in validate_payload(bad))

    def test_missing_provenance_flagged(self):
        assert any("provenance" in p
                   for p in validate_payload({"replay": {}}))

    def test_lineage_diff_finds_losses(self):
        old = self.payload()
        new = stamp({"failures": {}}, config={})
        new["latency"] = {}
        diff = lineage_diff(old, new)
        assert diff["removed"] == ["replay"] and diff["added"] == ["latency"]

    def test_cli_check_and_diff(self, tmp_path):
        ok = tmp_path / "BENCH_a.json"
        ok.write_text(json.dumps(self.payload()))
        assert provenance_main(["check", str(ok)]) == 0
        guard = tmp_path / "expected.json"
        guard.write_text(json.dumps({"*": ["replay", "latency"]}))
        assert provenance_main(
            ["check", str(ok), "--expect", str(guard)]) == 1
        lost = tmp_path / "BENCH_b.json"
        lost.write_text(json.dumps(stamp({"failures": {}, "latency": {}})))
        assert provenance_main(["diff", str(ok), str(lost)]) == 1
        assert provenance_main(["diff", str(ok), str(ok)]) == 0

    def test_repo_guard_file_loads(self):
        guard = json.loads(
            (REPO_ROOT / "benchmarks" / "expected_series.json").read_text())
        assert "*" in guard and isinstance(guard["*"], list)


# ------------------------------------------------------ metric registry


class TestMetrics:
    def test_unit_suffix_enforced(self):
        m = Metrics()
        with pytest.raises(ValueError, match="unit suffix"):
            m.count("events")
        with pytest.raises(ValueError):
            m.gauge("depth", 1)
        with pytest.raises(ValueError):
            m.observe("sojourn", 1.0)

    def test_snapshot_round_trip(self):
        m = Metrics()
        m.count("events_count")
        m.count("events_count", 2)
        m.gauge("depth_count", 7)
        for v in (1.0, 10.0, 100.0):
            m.observe("sojourn_us", v)
        snap = m.snapshot()
        assert snap["counters"]["events_count"] == 3
        assert snap["gauges"]["depth_count"] == 7.0
        d = snap["dists"]["sojourn_us"]
        assert d["count"] == 3 and d["min"] == 1.0 and d["max"] == 100.0
        assert d["mean"] == pytest.approx(37.0)

    def test_sketch_quantiles_monotonic(self):
        s = DistSketch()
        rng = np.random.default_rng(0)
        s.extend(rng.lognormal(3.0, 1.0, size=2_000))
        qs = [s.quantile(q) for q in (0.0, 0.5, 0.9, 0.99, 1.0)]
        assert qs == sorted(qs)
        assert qs[0] == s.min_v and qs[-1] == s.max_v
        # log-bucketed: p50 within a bucket's width of the true median
        assert s.quantile(0.5) == pytest.approx(np.exp(3.0), rel=0.5)


class TestTimelines:
    def test_station_utilization(self, closed_traced):
        net, res = closed_traced
        tr = res.traces[0][0]
        util = station_utilization(tr, len(net.stations))
        assert util  # at least CPU + one cache/disk station observed
        for st, row in util.items():
            assert 0.0 < row["busy_frac"] <= 1.0
            assert row["mean_occupancy_count"] <= net.mpl + 1e-6
            assert row["span_us"] > 0

    def test_convoy_stats(self, closed_traced):
        net, res = closed_traced
        tr = res.traces[0][0]
        busiest = max(
            station_utilization(tr, len(net.stations)).items(),
            key=lambda kv: kv[1]["busy_frac"])[0]
        stats = convoy_stats(tr, busiest)
        assert stats["n_count"] >= 1
        assert stats["total_us"] >= stats["max_us"] >= stats["mean_us"] > 0
        assert convoy_stats(tr, 10_000)["n_count"] == 0

    def test_trace_summary(self, closed_traced):
        net, res = closed_traced
        tr = res.traces[0][0]
        s = trace_summary(tr, n_stations=len(net.stations))
        assert s["records_count"] == len(tr)
        assert s["dropped_count"] == 0
        assert sum(s["classes_count"].values()) == len(tr)
        assert s["sojourn_mean_us"] > 0 and s["stations"]

    def test_observed_response(self, closed_traced):
        _, res = closed_traced
        tr = res.traces[0][0]
        obs = observed_response(tr)
        assert obs["n_count"] == len(tr)
        pct = obs["percentiles_us"]
        assert pct[0.5] <= pct[0.95] <= pct[0.99]
        per_cls = obs["by_class"]
        assert per_cls["hit"]["mean_us"] < per_cls["miss"]["mean_us"]
        n = sum(c["n_count"] for c in per_cls.values())
        assert n == len(tr)


# ------------------------------------------------------- twin registry


def test_trace_pair_registered():
    sys.path.insert(0, str(REPO_ROOT))
    try:
        from tools.analysis.contracts import REGISTRY
    finally:
        sys.path.pop(0)
    names = {p.name for p in REGISTRY}
    assert "trace-records" in names
    pair = next(p for p in REGISTRY if p.name == "trace-records")
    assert pair.fast.endswith("trace_from_rings")
    assert pair.oracle.endswith("make_records")


def test_make_records_sorts_and_pads():
    tr = make_records(
        req=[2, 0, 1], branch=[0, 1, 0], cls=[CLS_HIT] * 3, nvis=[1, 2, 1],
        parked_us=[0.0] * 3,
        enter_us=[[5.0, 0.0], [0.0, 1.0], [3.0, 0.0]],
        leave_us=[[6.0, 0.0], [1.0, 2.0], [4.0, 0.0]],
        visits=np.array([[0, -1], [0, 1]]))
    assert np.array_equal(tr.req, [0, 1, 2])
    assert np.array_equal(tr.branch, [1, 0, 0])
    assert np.isnan(tr.enter_us[1, 1]) and tr.station[1, 1] == -1
    np.testing.assert_allclose(tr.sojourn_us, [2.0, 1.0, 1.0])
