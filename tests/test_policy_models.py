"""Prong A reproduction tests: our generic network machinery must reproduce
the paper's closed-form throughput bounds (Eqs. 1-6 and Sec. 4) exactly."""

import numpy as np
import pytest

from repro.core import (
    FIFO_LIKE,
    LRU_LIKE,
    build,
    bypass_network,
    classify_by_throughput,
    classify_structural,
    clock_network,
    fifo_network,
    lru_network,
    optimal_bypass_beta,
    paper_fifo_bound,
    paper_lru_bound,
    paper_prob_lru_bound,
    prob_lru_network,
    s3fifo_network,
    slru_network,
)
from repro.core.policy_models import clock_g, slru_ell

P = np.linspace(0.0, 0.999, 97)


def test_networks_validate():
    for name in ["lru", "fifo", "clock", "slru", "s3fifo"]:
        build(name).validate()
    build("prob_lru", q=0.5).validate()
    build("prob_lru", q=1 - 1 / 72).validate()


# ---------------------------------------------------------------------------
# LRU: Eq. (1), (2), (3)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("disk_us,c0,c1", [(100.0, 101.1, 99.3), (5.0, 6.1, 4.3), (500.0, 501.1, 499.3)])
def test_lru_matches_paper_equations(disk_us, c0, c1):
    net = lru_network(disk_us=disk_us)
    ours = net.throughput_upper(P)
    paper = np.minimum(72.0 / (c0 - c1 * P), 1.0 / np.maximum(0.59, 0.7 * P))
    np.testing.assert_allclose(ours, paper, rtol=1e-12)
    np.testing.assert_allclose(ours, paper_lru_bound(P, disk_us=disk_us), rtol=1e-12)


def test_lru_bottleneck_switch_at_084():
    """Sec. 3.2: delink overtakes head update at p_hit = 0.59/0.7 = 0.843."""
    net = lru_network(disk_us=100.0)
    assert net.bottleneck(0.80) == "head"
    assert net.bottleneck(0.90) == "delink"
    p_star = net.p_star()
    assert abs(p_star - 0.59 / 0.7) < 2e-3


def test_lru_throughput_drops_at_high_hit_ratio():
    net = lru_network(disk_us=100.0)
    assert net.throughput_upper(0.999) < net.throughput_upper(0.84)


def test_lru_p_star_moves_earlier_with_faster_disk():
    """Sec. 3.2 / Fig. 3: p* decreases as disks get faster."""
    p500 = lru_network(disk_us=500.0).p_star()
    p100 = lru_network(disk_us=100.0).p_star()
    p5 = lru_network(disk_us=5.0).p_star()
    assert p5 <= p100 <= p500


def test_lru_tail_insensitivity():
    """Sec. 3.2: using the nominal S_tail changes X by < 0.5%."""
    net = lru_network(disk_us=100.0)
    a = net.throughput_upper(P, tail_mode="zero")
    b = net.throughput_upper(P, tail_mode="nominal")
    assert np.all(b <= a + 1e-15)
    rel = (a - b) / a
    assert np.max(rel) < 0.006  # the paper's "< 0.5%" claim (their rounding)


# ---------------------------------------------------------------------------
# FIFO: Eq. (4), (5), (6)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("disk_us,c0,c1", [(100.0, 101.24, 100.73), (5.0, 6.24, 5.73), (500.0, 501.24, 500.73)])
def test_fifo_matches_paper_equations(disk_us, c0, c1):
    net = fifo_network(disk_us=disk_us)
    ours = net.throughput_upper(P)
    paper = np.minimum(72.0 / (c0 - c1 * P), 1.0 / (0.73 * (1.0 - P)))
    np.testing.assert_allclose(ours, paper, rtol=1e-12)
    np.testing.assert_allclose(ours, paper_fifo_bound(P, disk_us=disk_us), rtol=1e-12)


@pytest.mark.parametrize("disk_us", [500.0, 100.0, 5.0])
def test_fifo_monotone_increasing(disk_us):
    x = fifo_network(disk_us=disk_us).throughput_upper(P)
    assert np.all(np.diff(x) >= -1e-12)


# ---------------------------------------------------------------------------
# Probabilistic LRU — Sec. 4.2
# ---------------------------------------------------------------------------


def test_prob_lru_q05_matches_paper():
    net = prob_lru_network(q=0.5, disk_us=100.0)
    ours = net.throughput_upper(P)
    paper = np.minimum(
        72.0 / (101.16 - 99.935 * P),
        1.0 / np.maximum(0.39 * P, 0.65 - 0.325 * P),
    )
    np.testing.assert_allclose(ours, paper, rtol=1e-9)
    np.testing.assert_allclose(ours, paper_prob_lru_bound(P, q=0.5), rtol=1e-12)


def test_prob_lru_q0986_is_fifo_like_and_q05_is_not():
    q_hi = 1.0 - 1.0 / 72.0
    assert classify_by_throughput(prob_lru_network(q=q_hi, disk_us=100.0)) == FIFO_LIKE
    assert classify_by_throughput(prob_lru_network(q=0.5, disk_us=100.0)) == LRU_LIKE


def test_prob_lru_needs_extremely_high_q():
    """Sec 4.2 finding: q must be >= 1-1/N for FIFO-like behaviour."""
    assert classify_by_throughput(prob_lru_network(q=0.9, disk_us=5.0)) == LRU_LIKE


def test_prob_lru_endpoints_interpolate_lru():
    np.testing.assert_allclose(
        prob_lru_network(q=0.0).throughput_upper(P),
        lru_network().throughput_upper(P),
        rtol=1e-12,
    )


# ---------------------------------------------------------------------------
# CLOCK — Sec. 4.3
# ---------------------------------------------------------------------------


def test_clock_matches_paper_bound():
    net = clock_network(disk_us=100.0)
    g = clock_g(P)
    A = 72.0 / (101.16 + 0.3 * g - (100.65 + 0.3 * g) * P)
    B = 1.0 / ((1.0 - P) * (0.65 + 0.3 * g))
    np.testing.assert_allclose(net.throughput_upper(P), np.minimum(A, B), rtol=1e-9)


@pytest.mark.parametrize("disk_us", [500.0, 100.0, 5.0])
def test_clock_monotone_increasing(disk_us):
    x = clock_network(disk_us=disk_us).throughput_upper(P)
    assert np.all(np.diff(x) >= -1e-9)


# ---------------------------------------------------------------------------
# SLRU — Sec. 4.4
# ---------------------------------------------------------------------------


def test_slru_matches_paper_bound():
    net = slru_network(disk_us=100.0)
    ell = slru_ell(P)
    A = 72.0 / (101.1 - 98.71 * P - 0.59 * ell)  # paper prints 88.71; see DESIGN.md
    B = 1.0 / np.maximum.reduce([0.7 * ell, 0.59 * P, 0.59 * (1.0 - ell)])
    np.testing.assert_allclose(net.throughput_upper(P), np.minimum(A, B), rtol=1e-9)


def test_slru_is_lru_like():
    assert classify_by_throughput(slru_network(disk_us=100.0)) == LRU_LIKE
    assert classify_structural(slru_network()) == LRU_LIKE


def test_slru_p_star_moves_earlier_with_mpl_and_disk():
    """Fig. 12 trends: higher MPL and faster disk move p* earlier."""
    p_72 = slru_network(disk_us=100.0, mpl=72).p_star()
    p_144 = slru_network(disk_us=100.0, mpl=144).p_star()
    assert p_144 <= p_72 + 1e-9
    p_fast = slru_network(disk_us=5.0, mpl=72).p_star()
    assert p_fast <= p_72 + 1e-9


# ---------------------------------------------------------------------------
# S3-FIFO — Sec. 4.5
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("disk_us", [500.0, 100.0, 5.0])
def test_s3fifo_monotone_increasing(disk_us):
    x = s3fifo_network(disk_us=disk_us).throughput_upper(np.linspace(0.3, 0.999, 200))
    assert np.all(np.diff(x) >= -1e-9)
    assert classify_structural(s3fifo_network()) == FIFO_LIKE


# ---------------------------------------------------------------------------
# Classification + MVA + mitigation
# ---------------------------------------------------------------------------


def test_classification_matches_table1():
    assert classify_by_throughput(lru_network()) == LRU_LIKE
    assert classify_by_throughput(fifo_network()) == FIFO_LIKE
    assert classify_by_throughput(clock_network()) == FIFO_LIKE
    assert classify_structural(lru_network()) == LRU_LIKE
    assert classify_structural(fifo_network()) == FIFO_LIKE


def test_mva_below_upper_bound_and_saturates():
    for name in ["lru", "fifo", "clock", "slru", "s3fifo"]:
        net = build(name)
        for p in [0.3, 0.6, 0.9, 0.99]:
            x_mva = net.mva(p)[0]
            x_ub = net.throughput_upper(p, tail_mode="nominal")
            assert x_mva <= x_ub * (1.0 + 1e-9), (name, p)
            assert x_mva > 0.25 * x_ub, (name, p)  # MVA not degenerate


def test_mva_shows_lru_inversion():
    net = lru_network(disk_us=5.0)
    xs = net.mva_throughput(np.array([0.85, 0.999]))
    assert xs[1] < xs[0]


def test_bypass_mitigation_keeps_throughput_flat():
    """Sec. 5.2: bypass keeps X ~ constant past p* instead of dropping."""
    net = lru_network(disk_us=100.0)
    p_star = net.p_star()
    x_star = net.throughput_upper(p_star)
    for p in [0.9, 0.95, 0.99]:
        beta = optimal_bypass_beta(net, p)
        x_bypass = bypass_network(net, beta).throughput_upper(p)
        x_plain = net.throughput_upper(p)
        assert x_bypass >= x_plain - 1e-9
        assert abs(x_bypass - x_star) / x_star < 0.05


def test_response_time_increases_past_p_star():
    """Sec. 3.2: in a closed loop, R = N/X, so R rises when X falls."""
    net = lru_network(disk_us=100.0)
    r = net.response_time_upper(np.array([0.84, 0.99]))
    assert r[1] > r[0]
