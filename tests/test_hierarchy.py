"""Tiered hierarchy prong (PR 8): composition, tier profiles, cross-tier
MSHR twins, the analytic coalescing transform, and the per-shard sigma_k
generalization of ``coalesced_network``.

The twin tests here are the fast differential smoke layer; the headline
(LRU-client inversion, forecast tolerances) is asserted in
``benchmarks/fig_hierarchy.py`` and the property layer in
``tests/test_properties.py``.
"""

import numpy as np
import pytest

from repro.cluster import (
    HashRing,
    cluster_network,
    ideal_shard_profile,
    simulate_cluster,
    zipf_key_probs,
)
from repro.core import build
from repro.core.harness import zipf_trace
from repro.core.queueing import (
    THINK,
    Branch,
    ClosedNetwork,
    Station,
    coalesced_network,
    sigma_of,
)
from repro.core.simulator import simulate_network
from repro.hierarchy import (
    TieredProfile,
    TierSpec,
    che_hit,
    coalesced_hierarchy,
    compose_tiers,
    hierarchy_network,
    measured_tiered_profile,
    simulate_hierarchy,
    simulate_hierarchy_py,
    tier_sigma_of,
    tiered_profile,
)

KEY_SPACE = 128


@pytest.fixture(scope="module")
def small_model():
    """2 LRU clients -> 2 LRU shards -> origin, constant p2=0.5."""
    return hierarchy_network("lru", "lru", n_clients=2, n_shards=2,
                             mpl=16, disk_us=50.0)


@pytest.fixture(scope="module")
def che_profile():
    probs = zipf_key_probs(KEY_SPACE, 0.9, seed=0)
    assign = np.arange(KEY_SPACE) % 2
    return tiered_profile(probs, np.array([4, 16, 48, 96]), l2_cap=16,
                          assign=assign, n_shards=2)


# ---------------------------------------------------------------------------
# Tier profiles (Che / measured)
# ---------------------------------------------------------------------------


def test_che_hit_basic_properties():
    probs = zipf_key_probs(64, 1.0, seed=0)
    h_small = che_hit(probs, 4)
    h_big = che_hit(probs, 32)
    assert h_small.shape == (64,)
    assert np.all((0.0 <= h_small) & (h_small <= 1.0))
    # monotone in capacity, and popular keys hit more
    assert np.all(h_big >= h_small - 1e-12)
    assert probs @ h_big > probs @ h_small
    # the characteristic-time constraint: expected occupancy == capacity
    assert h_big.sum() == pytest.approx(32, rel=1e-6)
    # degenerate: cache the whole key space
    assert np.allclose(che_hit(probs, 64), 1.0)


def test_tiered_profile_filters_the_shards(che_profile):
    prof = che_profile
    assert np.all(np.diff(prof.l1_hit) > 0)
    np.testing.assert_allclose(prof.shard_weights.sum(axis=1), 1.0,
                               atol=1e-9)
    # filtering: a bigger L1 strips the head of the Zipf curve, so the
    # residual stream seen by the shards is colder
    p2 = (prof.shard_weights * prof.l2_hit).sum(axis=1)
    assert p2[-1] < p2[0]
    p1, w, p2k = prof.tier_p(0.5 * sum(prof.p_range()))
    assert 0.0 < p1 < 1.0 and w.shape == (2,) and p2k.shape == (2,)


def test_measured_profile_matches_che_shape(che_profile):
    trace = zipf_trace(6_000, KEY_SPACE, 0.9, seed=0)
    assign = np.arange(KEY_SPACE) % 2
    meas = measured_tiered_profile(trace, np.array([4, 16, 48, 96]),
                                   l2_cap=16, assign=assign, n_clients=2,
                                   seed=0)
    assert np.all(np.diff(meas.l1_hit) >= 0)
    np.testing.assert_allclose(meas.shard_weights.sum(axis=1), 1.0,
                               atol=1e-9)
    # same qualitative filtering as the analytic profile, and the two
    # agree on the L1 hit curve within Che-approximation error
    np.testing.assert_allclose(meas.l1_hit, che_profile.l1_hit, atol=0.12)


def test_constant_profile_knob_is_p1():
    prof = TieredProfile.constant(0.5, n_shards=3)
    p1, w, p2 = prof.tier_p(0.42)
    assert p1 == pytest.approx(0.42)
    np.testing.assert_allclose(w, 1.0 / 3)
    np.testing.assert_allclose(p2, 0.5)


# ---------------------------------------------------------------------------
# Composition
# ---------------------------------------------------------------------------


def test_compose_probabilities_and_levels(small_model):
    net = small_model.network
    net.validate()
    for p in (0.01, 0.3, 0.77, 0.99):
        total = sum(b.probability(p) for b in net.branches)
        assert total == pytest.approx(1.0, abs=1e-12)
        lvl = small_model.level_fractions(p)
        assert lvl[0] == pytest.approx(p, abs=1e-12)
        assert lvl[1] == pytest.approx((1 - p) * 0.5, abs=1e-12)
        assert lvl.sum() == pytest.approx(1.0, abs=1e-12)


def test_compose_station_naming_and_mpl(small_model):
    names = {s.name for s in small_model.network.stations}
    # queue stations replicate per instance; thinks are shared per tier
    assert {"l1_0:head", "l1_1:head", "l2_0:head", "l2_1:head",
            "l1:lookup", "l2:lookup", "disk"} <= names
    assert not any(n.endswith(":disk") for n in names)
    assert small_model.network.mpl == 16  # explicit override respected
    # default cluster MPL: per-client closed loops times n_clients
    default = hierarchy_network("lru", "lru", n_clients=2, n_shards=2)
    assert default.network.mpl == 2 * build("lru").mpl


def test_compose_rejects_route_ending_at_origin():
    bare = ClosedNetwork(
        "bare",
        (Station("lookup", THINK, 0.5), Station("disk", THINK, 50.0)),
        (Branch("hit", lambda p: p, ("lookup",)),
         Branch("miss", lambda p: 1.0 - p, ("lookup", "disk"))),
        mpl=8,
    )
    with pytest.raises(ValueError, match="disk"):
        compose_tiers(TierSpec(policy="lru", n_instances=2),
                      TierSpec(net=bare, n_instances=2, name="l2"))


def test_mshr_annotations_validate(small_model):
    mshr = small_model.mshr
    assert mshr.n_groups == 2 + 2  # per-client L1 + per-shard origin
    B = len(small_model.network.branches)
    assert np.asarray(mshr.acq_group).shape[0] == B
    # L1-hit branches acquire nothing; origin branches acquire both slots
    ag = np.asarray(mshr.acq_group)
    for bi in range(B):
        lvl = small_model.branch_level[bi]
        n_acq = int((ag[bi] >= 0).sum())
        assert n_acq == (0 if lvl == 0 else 1 if lvl == 1 else 2)


def test_analytics_delegate(small_model):
    p = np.array([0.3, 0.6])
    assert np.all(small_model.throughput_upper(p) > 0)
    assert small_model.mva_throughput(0.5) > 0
    assert 0.0 < small_model.p_star(grid=501) <= 1.0


# ---------------------------------------------------------------------------
# Tiered simulator twins
# ---------------------------------------------------------------------------


def test_plain_path_is_the_untiered_kernel(small_model):
    """coalesce_flows=0 must dispatch the exact plain kernel."""
    ref = simulate_network(small_model.network, [0.5], n_requests=3_000,
                           seeds=(0,))
    res = simulate_hierarchy(small_model, [0.5], n_requests=3_000,
                             seeds=(0,))
    assert res.throughput[0] == ref.throughput[0]
    assert res.delayed_l1_frac[0] == 0.0
    np.testing.assert_allclose(res.level_throughput.sum(axis=1),
                               res.throughput, rtol=1e-6)


def test_tiered_twins_agree(small_model):
    """Cross-tier MSHR: JAX kernel vs heapq oracle, X and tier splits."""
    jx = simulate_hierarchy(small_model, [0.35], n_requests=8_000,
                            seeds=(0, 1), coalesce_flows=2)
    py = simulate_hierarchy_py(small_model, 0.35, n_requests=4_000,
                               seed=2, coalesce_flows=2)
    assert jx.throughput[0] == pytest.approx(py.throughput[0], rel=0.15)
    assert jx.delayed_l1_frac[0] == pytest.approx(py.delayed_l1_frac[0],
                                                  abs=0.08)
    assert jx.delayed_l2_frac[0] == pytest.approx(py.delayed_l2_frac[0],
                                                  abs=0.05)
    # the tier split partitions the delayed mass
    for r in (jx, py):
        assert r.delayed_frac[0] == pytest.approx(
            r.delayed_l1_frac[0] + r.delayed_l2_frac[0], abs=1e-6)
        assert r.delayed_l1_frac[0] > r.delayed_l2_frac[0] > 0.0


def test_tiered_sim_levels_match_analytic(small_model):
    res = simulate_hierarchy(small_model, [0.4], n_requests=8_000,
                             seeds=(0,), coalesce_flows=2)
    frac = res.level_throughput[0] / res.throughput[0]
    np.testing.assert_allclose(frac, small_model.level_fractions(0.4),
                               atol=0.05)
    np.testing.assert_allclose(res.shard_throughput[0].sum(),
                               res.level_throughput[0, 1:].sum(), rtol=1e-6)


def test_tiers_requires_coalescing_and_closed_loop(small_model):
    with pytest.raises(ValueError):
        simulate_network(small_model.network, [0.5], n_requests=500,
                         tiers=small_model.mshr, coalesce_flows=2,
                         arrival_rate=0.5)
    with pytest.raises(ValueError):
        simulate_network(small_model.network, [0.5], n_requests=500,
                         tiers=small_model.mshr, coalesce_flows=2,
                         backend="pallas")


# ---------------------------------------------------------------------------
# Analytic cross-tier coalescing
# ---------------------------------------------------------------------------


def test_coalesced_hierarchy_masses_and_sigma(small_model):
    net = small_model.coalesced(flows=2)
    for p in (0.2, 0.5, 0.8):
        assert sum(b.probability(p) for b in net.branches) == pytest.approx(
            1.0, abs=1e-9)
    s1_lo, s2_lo = tier_sigma_of(net, 0.2)
    s1_hi, s2_hi = tier_sigma_of(net, 0.9)
    assert 0.0 < s1_lo < 1.0 and 0.0 < float(np.mean(s2_lo)) < 1.0
    # starvation: a higher L1 hit ratio thins both park streams
    assert s1_hi < s1_lo
    assert float(np.mean(s2_hi)) < float(np.mean(s2_lo))
    # the plain-network reader sees no single-node "_delayed" branches
    assert sigma_of(net, 0.5) == 0.0


def test_coalesced_sigma_tracks_sim(small_model):
    """The analytic sigma1 must track the sim's measured park share
    (loose: MVA cannot represent fill-synchronized convoys)."""
    p = 0.35
    net = small_model.coalesced(flows=2)
    s1, _ = tier_sigma_of(net, p)
    sim = simulate_hierarchy(small_model, [p], n_requests=8_000,
                             seeds=(0, 1), coalesce_flows=2)
    sim_s1 = sim.delayed_l1_frac[0] / (1.0 - p)
    assert s1 == pytest.approx(sim_s1, rel=0.3)


# ---------------------------------------------------------------------------
# Per-shard sigma_k in coalesced_network (PR 5 carried-over item)
# ---------------------------------------------------------------------------


def _shard_delayed_frac(net, p, k):
    """Delayed-hit share of shard k's traffic (the sim-comparable
    quantity: ``(1 - p_k) * sigma_k``)."""
    mine = [b for b in net.branches
            if any(v.startswith(f"s{k}:") for v in b.visits)]
    delayed = sum(b.probability(p) for b in mine
                  if b.name.endswith("_delayed"))
    total = sum(b.probability(p) for b in mine)
    return delayed / total


def test_single_disk_fixed_point_unchanged():
    """The multi-disk generalization must reduce exactly to the old
    single-node fixed point when there is one disk."""
    net = build("lru", disk_us=100.0)
    coal = coalesced_network(net, flows=8)
    sig = sigma_of(coal, 0.5)
    assert 0.0 < sig < 1.0
    names = {s.name for s in coal.stations}
    assert "inflight" in names and not any(":" in n and n.endswith("inflight")
                                           for n in names)


def test_cluster_coalescing_per_shard_sigma():
    probs, assign = (zipf_key_probs(KEY_SPACE, 1.0, seed=0),
                     HashRing(2, vnodes=64, seed=1).assignment(KEY_SPACE))
    prof = ideal_shard_profile(assign, probs)
    cm = cluster_network("lru", 2, profile=prof, disk_us=100.0, mpl=24)
    coal = cm.coalesced(flows=8)
    names = {s.name for s in coal.stations}
    assert {"s0:inflight", "s1:inflight"} <= names
    # shard-locality (the fig_cluster sim claim, now analytic too): the
    # hot shard runs at a higher local hit ratio, so a smaller share of
    # its traffic parks as delayed hits than on the cold shard
    pk = prof.shard_p(0.6)
    hot, cold = int(np.argmax(pk)), int(np.argmin(pk))
    assert (_shard_delayed_frac(coal, 0.6, hot)
            < _shard_delayed_frac(coal, 0.6, cold))


def test_cluster_coalesced_analytic_vs_sim_regression():
    """Regression pin for the per-shard fixed point: the analytic
    per-shard delayed-hit fractions track the shard-local-MSHR cluster
    sim shard by shard — the quantity a single global sigma cannot
    produce at all (it collapses the hot/cold split)."""
    probs, assign = (zipf_key_probs(KEY_SPACE, 1.0, seed=0),
                     HashRing(2, vnodes=64, seed=1).assignment(KEY_SPACE))
    prof = ideal_shard_profile(assign, probs)
    cm = cluster_network("lru", 2, profile=prof, disk_us=100.0, mpl=24)
    coal = cm.coalesced(flows=8)
    p = 0.6
    sim = simulate_cluster(cm, np.array([p]), n_requests=12_000,
                           seeds=(0, 1), coalesce_flows=8)
    ana = np.array([_shard_delayed_frac(coal, p, k) for k in range(2)])
    np.testing.assert_allclose(ana, sim.shard_delayed_frac[0], atol=0.1)
    # the cross-shard ordering matches the sim's
    assert ((ana[0] < ana[1])
            == (sim.shard_delayed_frac[0, 0] < sim.shard_delayed_frac[0, 1]))
    # total delayed mass within the same band
    total = sum(b.probability(p) for b in coal.branches
                if b.name.endswith("_delayed"))
    assert total == pytest.approx(float(sim.delayed_frac[0]), abs=0.1)
